"""Paper Table 4 / Sec. 4.6: sensitivity to nonzeros per row (Q1 vs Q2).

The paper's refuted hypothesis: the block advantage does NOT grow with
nonzeros per row — index compression matters most in the index-bound,
low-nnz regime.  We measure block/scalar ratios for hot SpMV and KSPSolve
on Q1 (~81 nnz/row) and Q2 (~187 nnz/row) elasticity, plus the exact
per-row byte model that explains the trend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401
from repro.core import gamg
from repro.core.scalar_path import recompute_scalar
from repro.core.krylov import pcg
from repro.core.scalar_csr import bcsr_matrix_bytes, csr_matrix_bytes, \
    expand_bcsr
from repro.core.spmv import spmv_ell
from repro.core.vcycle import vcycle
from repro.fem.assemble import assemble_elasticity

from benchmarks.common import emit, time_fn


def run(sizes=((1, 10), (2, 6))) -> None:
    for order, m in sizes:
        prob = assemble_elasticity(m, order=order)
        # fp64 pin: blocked/scalar parity rows are an fp64 contract
        setupd = gamg.setup(prob.A, prob.B, coarse_size=30,
                            precision="f64")
        hier_b = gamg.recompute(setupd, prob.A.data)
        hier_s = recompute_scalar(setupd, prob.A.data)
        nnz_row = prob.A.nnzb * 9 / prob.A.shape[0]

        x = jnp.ones(prob.A.shape[0], prob.A.data.dtype)
        f = jax.jit(lambda h, v: spmv_ell(h.levels[0].a_ell, v))
        us_b = time_fn(f, hier_b, x)
        us_s = time_fn(f, hier_s, x)

        def solve(h):
            return pcg(lambda v: spmv_ell(h.levels[0].a_ell, v),
                       lambda r: vcycle(h, r), prob.b, rtol=1e-8,
                       maxiter=100)

        sol = jax.jit(solve)
        us_kb = time_fn(sol, hier_b)
        us_ks = time_fn(sol, hier_s)
        q = f"q{order}"
        emit(f"t4.spmv.ratio.{q}", 0.0,
             f"block_div_scalar={us_b/us_s:.3f};nnz_row={nnz_row:.0f}")
        emit(f"t4.ksp.ratio.{q}", 0.0,
             f"block_div_scalar={us_kb/us_ks:.3f}")
        # exact byte model: bytes per scalar nnz in each format
        S = expand_bcsr(prob.A)
        bpn_b = bcsr_matrix_bytes(prob.A) / (prob.A.nnzb * 9)
        bpn_s = csr_matrix_bytes(S) / (prob.A.nnzb * 9)
        emit(f"t4.bytes_per_nnz.{q}", 0.0,
             f"block={bpn_b:.2f};scalar={bpn_s:.2f};"
             f"traffic_ceiling={bpn_s/bpn_b:.2f}x")


if __name__ == "__main__":
    run()
