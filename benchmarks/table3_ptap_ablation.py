"""Paper Table 3: hot PtAP ablation — ungated vs state-gated reuse.

"Ungated" re-does the prolongator-side work every recompute (symbolic
transpose/plans + the P_oth-equivalent staging); "state-gated" serves it
from the cache and runs the numeric phase only.  The single-process
measurable quantities mirror the paper's decomposition:

  triple-product compute   = cached-plan numeric phase (both paths)
  prolongator-side rebuild = the symbolic work the gate removes
  off-process reduction    = distributed-only; its collective bytes are
                             reported from the AMG dry-run census
                             (launch_artifacts/dryrun_results.json).
"""
from __future__ import annotations

import json
import os
import time

import jax

import repro.core  # noqa: F401
from repro.core import gamg
from repro.core.ptap import ptap_numeric_data, ptap_symbolic
from repro.fem.assemble import assemble_elasticity

from benchmarks.common import emit, time_fn


def run(m: int = 10) -> None:
    prob = assemble_elasticity(m)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30)

    def gated(a_data):
        outs = []
        for ls in setupd.levels:
            a_data = ptap_numeric_data(ls.ptap_cache, a_data, ls.P.data,
                                       path="reference")
            outs.append(a_data)
        return outs

    gated_j = jax.jit(gated)
    us_gated = time_fn(gated_j, prob.A.data)

    # fused vs unfused numeric phase: wall time + peak HBM intermediates.
    # The unfused path materializes the (npairs, br, bc) pair products; the
    # fused tiled kernel reduces them in VMEM (plan.numeric_intermediate
    # accounting is exact, not sampled).
    def fused(a_data):
        outs = []
        for ls in setupd.levels:
            a_data = ptap_numeric_data(ls.ptap_cache, a_data, ls.P.data,
                                       path="fused", interpret=True)
            outs.append(a_data)
        return outs

    us_fused = time_fn(jax.jit(fused), prob.A.data)
    plans = [p for ls in setupd.levels
             for p in (ls.ptap_cache.ap_plan, ls.ptap_cache.ac_plan)]
    peak_unfused = max(p.numeric_intermediate_bytes("reference")
                       for p in plans)
    peak_fused = max(p.numeric_intermediate_bytes("fused") for p in plans)
    fill = min(p.tile_fill for p in plans)
    emit(f"t3.ptap.numeric_unfused.m{m}", us_gated,
         f"peak_intermediate_bytes={peak_unfused}")
    emit(f"t3.ptap.numeric_fused.m{m}", us_fused,
         f"peak_intermediate_bytes={peak_fused};"
         f"bytes_ratio={peak_unfused/max(peak_fused,1):.2f}x;"
         f"min_tile_fill={fill:.2f};"
         f"note=fused_runs_interpret_on_cpu")

    # ungated: rebuild the prolongator-side cache every recompute
    def ungated(a_data):
        t0 = time.perf_counter()
        outs = []
        Acur_data = a_data
        for ls in setupd.levels:
            cache = ptap_symbolic(ls.A0.with_data(Acur_data), ls.P)
            Acur_data = ptap_numeric_data(cache, Acur_data, ls.P.data)
            outs.append(Acur_data)
        jax.block_until_ready(outs[-1])
        return (time.perf_counter() - t0) * 1e6

    ungated(prob.A.data)  # warm numerics
    us_ungated = min(ungated(prob.A.data) for _ in range(3))

    emit(f"t3.ptap.gated.m{m}", us_gated, "numeric-only (cache hit)")
    emit(f"t3.ptap.ungated.m{m}", us_ungated,
         f"gate_speedup={us_ungated/us_gated:.2f}x")

    # autotuned vs default kernel tiling (PR 8): sweep the level-0 SpMV
    # signature in-process (no cache write — the nightly baseline must not
    # depend on ~/.cache state) and report the winner next to the static
    # default's time.  On interpret-mode CPU the spread is modest; on TPU
    # the same sweep keys the winner per machine/backend.
    from repro.kernels import autotune
    ell0 = setupd.levels[0].A0.to_ell()
    sig = dict(br=ell0.br, bc=ell0.bc, kmax=ell0.kmax,
               dtype=str(ell0.data.dtype))
    swept = autotune.sweep("block_spmv", sig, nbr=min(ell0.nbr, 512),
                           repeats=3, interpret=True, record_winner=False)
    us_default = swept["table"]["tile_rows=8"]  # the static default
    emit(f"t3.autotune.block_spmv.m{m}", swept["best_us"],
         f"tuned={swept['params']};default_us={us_default:.1f};"
         f"speedup_vs_default={us_default/max(swept['best_us'],1e-9):.2f}x;"
         f"sig={autotune.entry_key('block_spmv', sig)}")

    # distributed off-process reduction: report bytes from the AMG dry-run
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "launch_artifacts",
        "dryrun_results.json")
    if os.path.exists(path):
        with open(path) as f:
            res = json.load(f)
        for key, rec in sorted(res.items()):
            if key.startswith("amg-") and rec.get("status") == "OK":
                c = rec["collectives"]
                emit(f"t3.dist.{key.split('|')[0]}.{key.split('|')[2]}",
                     0.0,
                     f"a2a_bytes={c['all-to-all']['bytes']};"
                     f"permute_bytes={c['collective-permute']['bytes']};"
                     f"halo={rec.get('halo_strategy')}")


if __name__ == "__main__":
    run()
