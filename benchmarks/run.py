"""Benchmark driver — one module per paper table.

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        table1_weak_scaling,
        table2_backends,
        table3_ptap_ablation,
        table4_nnz_row,
        table5_traffic,
        table6_multirhs,
        table7_assembly,
    )
    print("name,us_per_call,derived")
    failures = 0
    for mod in (table1_weak_scaling, table2_backends, table3_ptap_ablation,
                table4_nnz_row, table5_traffic, table6_multirhs,
                table7_assembly):
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"{mod.__name__},FAILED,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
