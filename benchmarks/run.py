"""Benchmark driver — one module per paper table.

Default output is the legacy ``name,us_per_call,derived`` CSV
(``benchmarks/common.emit``).  ``--json DIR`` additionally writes one
schema-versioned ``BENCH_<table>.json`` per table via the regression
tracker (``repro.obs.bench``), each carrying the context a later diff
needs: git revision, backend name, ``PrecisionPolicy``, machine, JAX
version.  ``--quick`` runs the same code paths at CI-sized problems —
the nightly regression job's mode.

    PYTHONPATH=src python benchmarks/run.py                 # CSV, full
    PYTHONPATH=src python benchmarks/run.py --quick --json bench_out
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized problems (same code paths)")
    ap.add_argument("--json", metavar="DIR", default=None,
                    help="also write BENCH_<table>.json results into DIR")
    ap.add_argument("--tables", nargs="*", default=None, metavar="TABLE",
                    help="subset of table module names")
    args = ap.parse_args(argv)

    from repro.obs.bench import TABLES, run_tables
    names = list(TABLES) if args.tables is None else args.tables
    unknown = [n for n in names if n not in TABLES]
    if unknown:
        ap.error(f"unknown tables {unknown}: expected from {sorted(TABLES)}")

    print("name,us_per_call,derived")
    if args.json is not None:
        # the tracker runs the tables itself (capturing emit rows); the
        # CSV above still streams to stdout through benchmarks.common.emit
        paths = run_tables(args.json, quick=args.quick, tables=names)
        import json
        failed = []
        for p in paths:
            with open(p) as f:
                if json.load(f).get("error"):
                    failed.append(p)
        if failed:
            print(f"benchmark failures recorded in: {failed}",
                  file=sys.stderr)
            sys.exit(1)
        return

    failures = 0
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        kwargs = TABLES[name] if args.quick else {}
        try:
            mod.run(**kwargs)
        except Exception:
            failures += 1
            print(f"benchmarks.{name},FAILED,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
