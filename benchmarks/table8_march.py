"""Table 8 (new workload): the device-resident quasi-static time march —
per-step cost of the three re-coarsening policies on the softening
scenario (``repro.sim``).

The reuse story end to end: ``frozen`` never re-coarsens (one setup, the
whole march one traced scan, cheapest per step but its CG counts drift
up as the prolongator goes stale), ``resetup`` rebuilds the hierarchy
before every step (the accuracy baseline, setup-dominated), and
``adaptive`` lets the device-side staleness monitor cut frozen segments
only when the hierarchy has measurably degraded — the policy the
acceptance test pins as fewest total CG iterations per setup.

Rows (CSV ``name,us_per_call,derived``):

* ``t8.<mode>.m<m>``   wall microseconds per march step (one full run,
  setups + solves amortized over the steps), with
  ``steps=...;iters=...;setups=...;recoveries=...;status=...`` derived.
"""
from __future__ import annotations

import time

import repro.core  # noqa: F401
from repro.fem.assemble import assemble_elasticity
from repro.sim import MarchConfig, SofteningScenario, StalenessConfig, march

from benchmarks.common import emit

SETUP_OPTS = {"coarse_size": 8}


def run(m: int = 5, n_steps: int = 8) -> None:
    prob = assemble_elasticity(m)
    scen = SofteningScenario.build(prob, rate=0.25, d_max=0.99)
    cfg = MarchConfig(n_steps=n_steps, seg_len=8, rtol=1e-8, maxiter=400,
                      staleness=StalenessConfig(iter_drift=2, ref_window=2,
                                                coeff_rtol=0.25))
    for mode in ("frozen", "adaptive", "resetup"):
        t0 = time.perf_counter()
        res = march(prob, scen, cfg, mode=mode, setup_opts=SETUP_OPTS)
        dt = time.perf_counter() - t0
        emit(f"t8.{mode}.m{m}", dt * 1e6 / max(res.steps_done, 1),
             f"steps={res.steps_done};iters={res.total_iters};"
             f"setups={res.n_setups};recoveries={res.n_recoveries};"
             f"status={res.status}")


if __name__ == "__main__":
    run()
