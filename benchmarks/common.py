"""Benchmark helpers: timing, CSV emission, and the dtype-parameterized
traffic model.

All benchmarks run the REAL implementations on CPU at reduced scale (the
paper's A100 ladder does not fit a CPU container); the quantities compared
are the same ones the paper tables compare, and byte/traffic models are
evaluated exactly.  CSV schema: ``name,us_per_call,derived``.

The analytic models (``value_itemsize``, ``vcycle_traffic``,
``dist_cycle_comm``) now live in ``repro.obs.model`` so the solver stack
can attach modeled bytes to live counters without importing this harness;
they are re-exported here unchanged for every existing table module and
script.

``recording()`` is the machine-readable capture hook the regression
tracker (``repro.obs.bench``) uses: inside the context, every ``emit``
row is also appended to the given list as ``(name, us, derived)``.
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, List, Optional, Tuple

import jax

from repro.obs.model import (       # noqa: F401  (re-exported, see above)
    _ell_apply_bytes,
    dist_cycle_comm,
    value_itemsize,
    vcycle_traffic,
)

#: Active capture sinks (``recording``); ``emit`` appends to every one.
_RECORDS: List[list] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-iters wall time (us) of a jitted fn, fully blocked."""
    for _ in range(warmup):
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(
                x, "block_until_ready") else x, out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(
                x, "block_until_ready") else x, out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


@contextlib.contextmanager
def recording(rows: Optional[list] = None):
    """Capture every ``emit`` row as ``(name, us, derived)`` tuples.

    Yields the sink list.  Nested recordings each get every row (the
    tracker records per-table while a caller records the whole run).
    """
    sink: List[Tuple[str, float, str]] = [] if rows is None else rows
    _RECORDS.append(sink)
    try:
        yield sink
    finally:
        _RECORDS.remove(sink)


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    for sink in _RECORDS:
        sink.append((name, float(us), derived))
    return line
