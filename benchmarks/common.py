"""Benchmark helpers: timing + CSV emission.

All benchmarks run the REAL implementations on CPU at reduced scale (the
paper's A100 ladder does not fit a CPU container); the quantities compared
are the same ones the paper tables compare, and byte/traffic models are
evaluated exactly.  CSV schema: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-iters wall time (us) of a jitted fn, fully blocked."""
    for _ in range(warmup):
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(
                x, "block_until_ready") else x, out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(
                x, "block_until_ready") else x, out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
