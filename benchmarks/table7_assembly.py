"""Table 7 (new workload): device-resident FEM assembly + the coefficient
hot loop — assemble/recompute time and update bytes vs the host path.

The paper's recurring-recompute scenario starts from "a new blocked COO
assembly path": with assembly itself device-resident, a quasi-static
operator update ships two per-element coefficient arrays (2 * ne * 8
bytes) instead of a host-assembled ``(n_input, 3, 3)`` value stream
(ne * nn^2 * 9 * 8 bytes) — a factor of ``nn^2 * 9 / 2`` (288x for Q1,
2916x for Q2) less host->device traffic per update, before counting the
host flops the device path sheds.

Timed on the real implementations at CPU scale:

* ``t7.device_assemble``         jitted fields -> assembled payload
  (vmapped quadrature + cached COO scatter)
* ``t7.device_update_recompute`` the fused hot loop: fields -> hierarchy
  (``gamg.make_coeff_recompute``) — ONE traced program, zero host bytes
* ``t7.host_assemble``           the numpy golden loop (per-element Ke)
  + the value-stream upload + the jitted recompute, the pre-ISSUE-5 path
"""
from __future__ import annotations

import time

import numpy as np

import repro.core  # noqa: F401
import jax
import jax.numpy as jnp

from repro.core import gamg
from repro.core.block_coo import set_values_coo_data
from repro.fem.assemble import assemble_elasticity, element_centroids

from benchmarks.common import emit, time_fn


def update_bytes(prob) -> tuple:
    """(device, host) host->device bytes of one coefficient update."""
    ne = prob.mesh.n_elements
    nn = prob.mesh.connectivity.shape[1]
    return 2 * ne * 8, ne * nn * nn * 9 * 8


def run(m: int = 8, order: int = 1) -> None:
    prob = assemble_elasticity(m, order=order)
    asm = prob.assembler
    ne = prob.mesh.n_elements
    c = element_centroids(prob.mesh)
    E = 1.0 + 4.0 * c[:, 0]
    nu = np.full(ne, 0.3)
    Ej, nuj = asm.as_fields(E, nu)

    # device assembly alone: fields -> (nnzb, 3, 3) payload
    assemble = jax.jit(asm.coo_data)
    us_dev = time_fn(assemble, Ej, nuj)
    dev_b, host_b = update_bytes(prob)
    emit(f"t7.device_assemble.m{m}.q{order}", us_dev,
         f"ne={ne};update_bytes={dev_b}")

    # the fused coefficient hot loop: fields -> hierarchy, one program
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30)
    coeff_recompute = gamg.make_coeff_recompute(setupd, asm)
    us_loop = time_fn(coeff_recompute, Ej, nuj)
    emit(f"t7.device_update_recompute.m{m}.q{order}", us_loop,
         f"traced_programs=1;update_bytes={dev_b}")

    # host golden path: numpy per-element loop + value-stream upload +
    # jitted recompute (what the hot loop replaces)
    from repro.fem.assemble import _host_value_stream
    recompute = gamg.make_recompute(setupd)
    plan = prob.coo_plan

    def host_update():
        vals = _host_value_stream(prob.mesh, E, nu)     # host flops
        data = set_values_coo_data(plan, jnp.asarray(vals))  # upload+scatter
        return recompute(data)

    # steady state: warm the jitted recompute first (the device rows are
    # timed warm too), then best-of-n so the row measures the recurring
    # host assembly + upload cost, not one-time XLA compiles
    jax.block_until_ready(host_update().coarse_chol)
    us_host = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(host_update().coarse_chol)
        us_host = min(us_host, (time.perf_counter() - t0) * 1e6)
    emit(f"t7.host_assemble.m{m}.q{order}", us_host,
         f"update_bytes={host_b}")

    ratio = host_b / dev_b
    emit(f"t7.update_bytes_ratio.m{m}.q{order}", 0.0,
         f"host_over_device={ratio:.0f}x")
    assert dev_b * 100 < host_b, (dev_b, host_b)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
