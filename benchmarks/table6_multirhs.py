"""Table 6 (new workload): multi-RHS amortization — intensity and
time-per-RHS vs panel width k.

The blocked SpMV streams A's values once per solve *per vector*; a k-wide
panel streams them once for k vectors, so the modeled arithmetic intensity

    flops(k) / bytes(k)
      = 2 * nnzb * br * bc * k
        / (values + indices + gathered-x(k) + y(k))

rises monotonically with k: the k-independent operator traffic (values +
one int32 index per block — the paper's Sec. 4.2 accounting) is amortized
while the per-column traffic (x gather, y write) scales linearly.  The
gathered-x term uses the no-reuse upper bound (one bc-panel load per ELL
slot), the conservative end of the paper's traffic model.

Also times the real kernels on CPU at reduced scale: ``spmm_ell`` per-RHS
latency, and the end-to-end batched AMG-PCG solve (the solve server's hot
path) per-RHS vs k.
"""
from __future__ import annotations

import numpy as np

import repro.core  # noqa: F401
import jax.numpy as jnp

from repro.core import gamg
from repro.core.spmv import spmm_ell
from repro.fem.assemble import assemble_elasticity

from benchmarks.common import emit, time_fn


def spmm_traffic_model(ell, k: int):
    """(flops, bytes) of one ELL panel apply at width k (fp64 values)."""
    nbr, kmax, br, bc = ell.nbr, ell.kmax, ell.br, ell.bc
    values = nbr * kmax * br * bc * 8
    indices = nbr * kmax * 4
    x_gather = nbr * kmax * bc * 8 * k     # no-reuse bound on panel loads
    y_write = nbr * br * 8 * k
    flops = 2 * nbr * kmax * br * bc * k
    return flops, values + indices + x_gather + y_write


def run(m: int = 8, ks=(1, 2, 4, 8, 16)) -> None:
    prob = assemble_elasticity(m)
    ell = prob.A.to_ell()
    rng = np.random.default_rng(0)

    intensities = []
    for k in ks:
        X = jnp.asarray(rng.standard_normal((prob.n, k)))
        us = time_fn(spmm_ell, ell, X)
        flops, nbytes = spmm_traffic_model(ell, k)
        ai = flops / nbytes
        intensities.append(ai)
        emit(f"t6.spmm.m{m}.k{k}", us,
             f"us_per_rhs={us / k:.1f};flops={flops};bytes={nbytes};"
             f"intensity={ai:.4f}")
    assert all(b > a for a, b in zip(intensities, intensities[1:])), \
        f"modeled intensity must rise monotonically with k: {intensities}"
    emit(f"t6.intensity_gain.m{m}", 0.0,
         f"k{ks[-1]}_over_k1={intensities[-1] / intensities[0]:.2f}x")

    # end-to-end: the solve server's hot path — batched AMG-PCG per RHS
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=40, rtol=1e-8,
                             maxiter=100)
    for k in ks:
        B = jnp.asarray(rng.standard_normal((prob.n, k)))
        res = solver.solve_many(B)          # warm the k-trace
        assert bool(np.asarray(res.converged).all())
        us = time_fn(solver._solve_many, solver.hierarchy, B)
        emit(f"t6.batched_solve.m{m}.k{k}", us,
             f"us_per_rhs={us / k:.1f};"
             f"iters={int(np.asarray(res.iters).max())}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
