"""Paper Table 1: hot KSPSolve / SpMV / PtAP, blocked vs scalar.

CPU-scale ladder (m^3 Q1 elasticity grids).  Measures the same three hot
events as the paper with both storage formats running the identical
algorithm (same hierarchy, same iteration counts — asserted), plus the
analytic traffic model that explains the ratios.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.core import gamg
from repro.core.scalar_path import recompute_scalar  # noqa: F401
from repro.core.scalar_csr import bcsr_matrix_bytes, csr_matrix_bytes, \
    expand_bcsr
from repro.core.krylov import pcg
from repro.core.spmv import spmv_ell
from repro.core.vcycle import vcycle
from repro.fem.assemble import assemble_elasticity

from benchmarks.common import emit, time_fn


def run(ladder=(7, 10, 13)) -> None:
    for m in ladder:
        prob = assemble_elasticity(m)
        # the paper's fp64 setting; the blocked/scalar iteration-parity
        # assert below is an fp64 contract, so pin against REPRO_PRECISION
        setupd = gamg.setup(prob.A, prob.B, coarse_size=30,
                            precision="f64")
        recompute_b = gamg.make_recompute(setupd)
        hier_b = recompute_b(prob.A.data)
        hier_s = recompute_scalar(setupd, prob.A.data)
        n = prob.A.shape[0]

        # --- hot SpMV (finest level operator) ---------------------------
        x = jnp.ones(n, prob.A.data.dtype)
        f_b = jax.jit(lambda h, v: spmv_ell(h.levels[0].a_ell, v))
        f_s = jax.jit(lambda h, v: spmv_ell(h.levels[0].a_ell, v))
        us_b = time_fn(f_b, hier_b, x)
        us_s = time_fn(f_s, hier_s, x)
        emit(f"t1.spmv.block.m{m}", us_b, f"n={n}")
        emit(f"t1.spmv.scalar.m{m}", us_s,
             f"block_speedup={us_s/us_b:.2f}x")

        # --- hot KSPSolve ------------------------------------------------
        def solve(h):
            return pcg(lambda v: spmv_ell(h.levels[0].a_ell, v),
                       lambda r: vcycle(h, r), prob.b, rtol=1e-8,
                       maxiter=100)

        sol_b = jax.jit(solve)
        rb = sol_b(hier_b)
        rs = sol_b(hier_s)
        assert int(rb.iters) == int(rs.iters), "iteration parity violated"
        us_b = time_fn(sol_b, hier_b)
        us_s = time_fn(sol_b, hier_s)
        emit(f"t1.ksp.block.m{m}", us_b, f"iters={int(rb.iters)}")
        emit(f"t1.ksp.scalar.m{m}", us_s,
             f"block_speedup={us_s/us_b:.2f}x")

        # --- hot PtAP (numeric chain, cached plans, both formats) ---------
        from repro.core.scalar_path import build_scalar_ptap_chain
        from repro.core.ptap import ptap_numeric_data

        def blocked_chain(a_data):
            outs = []
            for ls in setupd.levels:
                a_data = ptap_numeric_data(ls.ptap_cache, a_data,
                                           ls.P.data)
                outs.append(a_data)
            return outs

        blk_chain = jax.jit(blocked_chain)
        sc_chain = build_scalar_ptap_chain(setupd)
        us_b = time_fn(blk_chain, prob.A.data)
        us_s = time_fn(sc_chain, prob.A.data)
        emit(f"t1.ptap.block.m{m}", us_b, f"levels={len(setupd.levels)+1}")
        emit(f"t1.ptap.scalar.m{m}", us_s,
             f"block_speedup={us_s/us_b:.2f}x")

        # --- traffic model (the paper's Sec. 4.2 accounting) --------------
        A = prob.A
        S = expand_bcsr(A)
        bb, sb = bcsr_matrix_bytes(A), csr_matrix_bytes(S)
        emit(f"t1.matrix_bytes.block.m{m}", 0.0, f"bytes={bb}")
        emit(f"t1.matrix_bytes.scalar.m{m}", 0.0,
             f"bytes={sb};ceiling={sb/bb:.2f}x")


if __name__ == "__main__":
    run()
