"""Paper Table 1: hot KSPSolve / SpMV / PtAP, blocked vs scalar — plus the
distributed per-level comm model behind coarse-level agglomeration.

CPU-scale ladder (m^3 Q1 elasticity grids).  Measures the same three hot
events as the paper with both storage formats running the identical
algorithm (same hierarchy, same iteration counts — asserted), plus the
analytic traffic model that explains the ratios.

``comm_model`` evaluates the per-cycle message/latency/byte accounting of
the distributed V-cycle for both placements (fully sharded vs
agglomerated coarse levels) at the paper's weak-scaling rank counts —
the latency-bound coarse grids are exactly where the paper is fastest,
and the rows show the agglomeration crossover paying from ndev >= 8
(asserted).  ``overlap_model`` extends the ladder to 2-D process meshes
(8/27/64 devices) where interior rows exist, emits the
``hidden_latency`` overlap split, and pins the model against the traced
collective counts of the actual V-cycle (``repro.dist.measure``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.core import gamg
from repro.core.scalar_path import recompute_scalar  # noqa: F401
from repro.core.scalar_csr import bcsr_matrix_bytes, csr_matrix_bytes, \
    expand_bcsr
from repro.core.krylov import pcg
from repro.core.spmv import spmv_ell
from repro.core.vcycle import vcycle
from repro.fem.assemble import assemble_elasticity

from benchmarks.common import dist_cycle_comm, emit, time_fn


def run(ladder=(7, 10, 13)) -> None:
    for m in ladder:
        prob = assemble_elasticity(m)
        # the paper's fp64 setting; the blocked/scalar iteration-parity
        # assert below is an fp64 contract, so pin against REPRO_PRECISION
        setupd = gamg.setup(prob.A, prob.B, coarse_size=30,
                            precision="f64")
        recompute_b = gamg.make_recompute(setupd)
        hier_b = recompute_b(prob.A.data)
        hier_s = recompute_scalar(setupd, prob.A.data)
        n = prob.A.shape[0]

        # --- hot SpMV (finest level operator) ---------------------------
        x = jnp.ones(n, prob.A.data.dtype)
        f_b = jax.jit(lambda h, v: spmv_ell(h.levels[0].a_ell, v))
        f_s = jax.jit(lambda h, v: spmv_ell(h.levels[0].a_ell, v))
        us_b = time_fn(f_b, hier_b, x)
        us_s = time_fn(f_s, hier_s, x)
        emit(f"t1.spmv.block.m{m}", us_b, f"n={n}")
        emit(f"t1.spmv.scalar.m{m}", us_s,
             f"block_speedup={us_s/us_b:.2f}x")

        # --- hot KSPSolve ------------------------------------------------
        def solve(h):
            return pcg(lambda v: spmv_ell(h.levels[0].a_ell, v),
                       lambda r: vcycle(h, r), prob.b, rtol=1e-8,
                       maxiter=100)

        sol_b = jax.jit(solve)
        rb = sol_b(hier_b)
        rs = sol_b(hier_s)
        assert int(rb.iters) == int(rs.iters), "iteration parity violated"
        us_b = time_fn(sol_b, hier_b)
        us_s = time_fn(sol_b, hier_s)
        emit(f"t1.ksp.block.m{m}", us_b, f"iters={int(rb.iters)}")
        emit(f"t1.ksp.scalar.m{m}", us_s,
             f"block_speedup={us_s/us_b:.2f}x")

        # --- hot PtAP (numeric chain, cached plans, both formats) ---------
        from repro.core.scalar_path import build_scalar_ptap_chain
        from repro.core.ptap import ptap_numeric_data

        def blocked_chain(a_data):
            outs = []
            for ls in setupd.levels:
                a_data = ptap_numeric_data(ls.ptap_cache, a_data,
                                           ls.P.data)
                outs.append(a_data)
            return outs

        blk_chain = jax.jit(blocked_chain)
        sc_chain = build_scalar_ptap_chain(setupd)
        us_b = time_fn(blk_chain, prob.A.data)
        us_s = time_fn(sc_chain, prob.A.data)
        emit(f"t1.ptap.block.m{m}", us_b, f"levels={len(setupd.levels)+1}")
        emit(f"t1.ptap.scalar.m{m}", us_s,
             f"block_speedup={us_s/us_b:.2f}x")

        # --- traffic model (the paper's Sec. 4.2 accounting) --------------
        A = prob.A
        S = expand_bcsr(A)
        bb, sb = bcsr_matrix_bytes(A), csr_matrix_bytes(S)
        emit(f"t1.matrix_bytes.block.m{m}", 0.0, f"bytes={bb}")
        emit(f"t1.matrix_bytes.scalar.m{m}", 0.0,
             f"bytes={sb};ceiling={sb/bb:.2f}x")
    comm_model()


def comm_model(m: int = 7, ndevs=(8, 27, 64)) -> None:
    """Distributed V-cycle comm rows: sharded vs agglomerated placement.

    Host-only (``build_dist_gamg`` is pure staging — no devices needed),
    so the paper's rank counts evaluate exactly on the CPU-scale grid.
    Emits per-level message counts / latency units / byte split and the
    crossover row, and asserts the agglomerated coarse tail is strictly
    cheaper in both messages and latency at every ndev >= 8.
    """
    from repro.dist.solver import build_dist_gamg

    prob = assemble_elasticity(m)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    assert len(setupd.levels) >= 2, "comm model needs a mid level"
    for ndev in ndevs:
        sh = dist_cycle_comm(build_dist_gamg(setupd, ndev,
                                             coarse_eq_limit=0))
        ag_dg = build_dist_gamg(setupd, ndev)   # default placement policy
        ag = dist_cycle_comm(ag_dg)
        switch = len(ag_dg.levels)
        for r_sh, r_ag in zip(sh, ag):
            li = r_sh["level"]
            emit(f"t1.comm.sharded.nd{ndev}.L{li}", 0.0,
                 f"msgs={r_sh['msgs']};lat={r_sh['latency']};"
                 f"hidden={r_sh['hidden_latency']:.3f};"
                 f"halo_bytes={r_sh['halo_bytes']};"
                 f"gather_bytes={r_sh['gather_bytes']}")
            emit(f"t1.comm.agg.nd{ndev}.L{li}", 0.0,
                 f"placement={r_ag['placement']};"
                 f"msgs={r_ag['msgs']};lat={r_ag['latency']};"
                 f"halo_bytes={r_ag['halo_bytes']};"
                 f"gather_bytes={r_ag['gather_bytes']}")
        # whole-cycle totals: the agglomerated boundary pays one
        # all-gather where the sharded placement pays the boundary R/P
        # halos *plus* every coarse level's halo and the coarse-solve
        # gather — the crossover the placement policy buys
        msgs_sh = sum(r["msgs"] for r in sh)
        msgs_ag = sum(r["msgs"] for r in ag)
        lat_sh = sum(r["latency"] for r in sh)
        lat_ag = sum(r["latency"] for r in ag)
        emit(f"t1.comm.crossover.nd{ndev}", 0.0,
             f"switch_level={switch};"
             f"coarse_eq_limit={ag_dg.coarse_eq_limit};"
             f"cycle_msgs={msgs_sh}->{msgs_ag};"
             f"cycle_lat={lat_sh}->{lat_ag}")
        if ndev >= 8:
            assert ag_dg.repl, \
                f"default placement agglomerated nothing at ndev={ndev}"
            assert msgs_ag < msgs_sh and lat_ag < lat_sh, \
                (f"agglomeration must beat sharding at ndev={ndev}: "
                 f"msgs {msgs_sh}->{msgs_ag} lat {lat_sh}->{lat_ag}")
            for r_sh, r_ag in zip(sh[switch:], ag[switch:]):
                assert r_ag["msgs"] == 0 < r_sh["msgs"], (r_sh, r_ag)
                assert r_ag["latency"] == 0 < r_sh["latency"], (r_sh, r_ag)
    overlap_model()


def overlap_model(m: int = 7, meshes=((2, 4), (2, 16), (2, 32))) -> None:
    """Overlap accounting on 2-D process meshes, up to 64 fake devices.

    1-D slabs of a 3-D stencil stop having interior rows once the slab is
    thinner than the stencil reach — exactly the regime of the paper's
    large rank counts — so the weak-scaling meshes here keep the row axis
    at two slabs (at the CPU-scale grid even three-way slabs leave the
    middle rank interior-free) and scale through the column axis
    (``pc``): interior rows exist, and ``dist_cycle_comm`` charges each
    exchange as ``max(alpha, t_interior)``.  Emits the ``hidden_latency`` /
    ``eff_latency`` split per sharded level (asserted nonzero at every
    ndev >= 8 mesh) and closes with a model-vs-measured message-count
    column at the 64-device point: ``repro.dist.measure`` (subprocess —
    it needs ``pr`` fake devices) counts the collective equations in the
    traced V-cycle, and the model must agree exactly.
    """
    import json
    import os
    import subprocess
    import sys

    from repro.dist.partition import ProcessMesh
    from repro.dist.solver import build_dist_gamg
    from repro.obs.model import dist_cycle_comm as comm_rows

    prob = assemble_elasticity(m)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    for shape in meshes:
        nd = shape[0] * shape[1]
        dg = build_dist_gamg(setupd, ProcessMesh(shape))
        for r in comm_rows(dg):
            emit(f"t1.overlap.nd{nd}.L{r['level']}", 0.0,
                 f"mesh={shape[0]}x{shape[1]};"
                 f"placement={r['placement']};lat={r['latency']};"
                 f"hidden={r['hidden_latency']:.3f};"
                 f"eff={r['eff_latency']:.3f}")
            if nd >= 8 and r["placement"] == "sharded" \
                    and r["halo_bytes"] > 0:
                assert r["hidden_latency"] > 0.0, \
                    (f"no overlap headroom on sharded level "
                     f"{r['level']} of mesh {shape}: {r}")
    pr, pc = meshes[-1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={pr}"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.dist.measure",
         str(m), str(pr), str(pc)],
        capture_output=True, text=True, timeout=520, env=env)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    measured = rep["measured"]["cycle"]["msgs"]
    model = rep["model_msgs"]
    err = abs(model - measured) / max(measured, 1)
    emit(f"t1.overlap.measured.nd{pr * pc}", 0.0,
         f"model_msgs={model};measured_msgs={measured};err={err:.3f}")
    assert model == measured, \
        f"comm model drifted from the traced cycle: {model} != {measured}"


if __name__ == "__main__":
    run()       # run() ends with the comm_model + overlap rows
