"""Paper Table 5 + Fig. 3: traffic model and the capacity cliff.

Table 5 (ncu DRAM traffic) is re-derived as exact byte accounting from the
containers: the blocked SpGEMM moves one 4-byte index per block against
bs^2 for scalar, so the traffic ratio approaches bs^2 (the paper measures
10.2x vs the 9x model for bs=3).

The model is parameterized by *value-dtype width* (the ``PrecisionPolicy``
lever): the V-cycle section reports blocked-fp64 vs blocked-fp32 vs
scalar-fp64 rows, separating the value bytes a reduced-precision hierarchy
halves from the index bytes the blocked format sheds.

Fig. 3 (the cuSPARSE OOM at 128^3 on 8 GPUs) is reproduced as a *predicted*
capacity cliff: measure the scalar/blocked SpGEMM plan bytes on a ladder of
grids, fit the per-unknown slope (it is linear in unknowns for fixed
stencil), extrapolate to 6.29M unknowns on 8 devices, and compare against
the A100's 80 GB.
"""
from __future__ import annotations

import numpy as np

import repro.core  # noqa: F401
from repro.core import gamg
from repro.core.block_coo import scalar_coo_plan_bytes
from repro.core.spgemm import spgemm_symbolic
from repro.core.scalar_csr import expand_bcsr
from repro.fem.assemble import assemble_elasticity

from benchmarks.common import emit, value_itemsize, vcycle_traffic
from repro.obs.model import hierarchy_storage_bytes


def run(ladder=(6, 8, 10)) -> None:
    per_unknown = []
    for m in ladder:
        prob = assemble_elasticity(m)
        setupd = gamg.setup(prob.A, prob.B, coarse_size=30)
        ls = setupd.levels[0]
        n = prob.A.shape[0]

        # blocked plan bytes (A @ P of the first Galerkin product)
        plan_b = spgemm_symbolic(ls.A0, ls.P)
        b_bytes = plan_b.plan_bytes
        s_bytes_model = plan_b.scalar_plan_bytes(ls.A0.bc)
        # measured scalar plan (actually built on the expanded operators)
        plan_s = spgemm_symbolic(expand_bcsr(ls.A0), expand_bcsr(ls.P))
        s_bytes = plan_s.plan_bytes
        emit(f"t5.spgemm_plan.block.m{m}", 0.0, f"bytes={b_bytes};n={n}")
        # the fused path's tiled (ELL-of-pairs) layout pays padding to the
        # histogram width; keep the traffic model honest by reporting it
        # next to the flat pair-list bytes.
        emit(f"t5.spgemm_plan.tiled.m{m}", 0.0,
             f"bytes={plan_b.plan_tiled_bytes};"
             f"vs_flat={plan_b.plan_tiled_bytes/b_bytes:.2f}x;"
             f"kmax={plan_b.pair_kmax};fill={plan_b.tile_fill:.2f}")
        emit(f"t5.spgemm_plan.scalar.m{m}", 0.0,
             f"bytes={s_bytes};ratio={s_bytes/b_bytes:.1f}x;"
             f"model_ratio={s_bytes_model/b_bytes:.1f}x")
        # traffic of the numeric phase: values + one index per pair, at the
        # operator's actual value width and at the fp32 policy width
        bs = ls.A0.br
        isz = value_itemsize(ls.A0.data.dtype)
        for tag, w in (("", isz), (".f32", 4)):
            t_block = plan_b.npairs * (bs * bs * w * 2 + 4)
            t_scalar = plan_s.npairs * (w * 2 + 4 + 4)
            emit(f"t5.numeric_traffic{tag}.m{m}", 0.0,
                 f"block={t_block};scalar={t_scalar};"
                 f"ratio={t_scalar/t_block:.2f}x;bs2={bs*bs};"
                 f"value_bytes={w}")

        # V-cycle traffic at the PrecisionPolicy widths: blocked fp64 vs
        # blocked fp32 vs scalar fp64.  The value-byte column is the lever
        # a reduced-precision-resident hierarchy pulls (~2x), orthogonal
        # to the index-byte lever of the blocked format.
        t64 = vcycle_traffic(setupd, itemsize=value_itemsize("f64"))
        t32 = vcycle_traffic(setupd, itemsize=value_itemsize("f32"))
        ts = vcycle_traffic(setupd, itemsize=value_itemsize("f64"),
                            scalar=True)
        emit(f"t5.vcycle_traffic.block_f64.m{m}", 0.0,
             f"value={t64['value']};index={t64['index']};"
             f"total={t64['total']}")
        emit(f"t5.vcycle_traffic.block_f32.m{m}", 0.0,
             f"value={t32['value']};index={t32['index']};"
             f"total={t32['total']};"
             f"value_ratio_vs_f64={t64['value']/t32['value']:.2f}x;"
             f"total_ratio_vs_f64={t64['total']/t32['total']:.2f}x")
        emit(f"t5.vcycle_traffic.scalar_f64.m{m}", 0.0,
             f"value={ts['value']};index={ts['index']};"
             f"total={ts['total']};"
             f"index_ratio_vs_block={ts['index']/t64['index']:.1f}x")

        # transpose-free restriction (PR 8): the setup above is the
        # transpose-free default; a stored-R setup duplicates the
        # prolongator payload.  Report both the per-cycle traffic delta
        # (restriction stops charging a second value+index stream) and
        # the resident hierarchy storage (transfer side roughly halves).
        setupd_st = gamg.setup(prob.A, prob.B, coarse_size=30,
                               restriction="stored")
        t_st = vcycle_traffic(setupd_st, itemsize=value_itemsize("f64"))
        assert t64["total"] < t_st["total"], (t64, t_st)
        emit(f"t5.restriction_traffic.m{m}", 0.0,
             f"transpose_free={t64['total']};stored={t_st['total']};"
             f"saved={t_st['total']-t64['total']};"
             f"ratio={t_st['total']/t64['total']:.3f}x")
        s_tf = hierarchy_storage_bytes(setupd)
        s_st = hierarchy_storage_bytes(setupd_st)
        assert s_tf["transfer"] < s_st["transfer"]
        emit(f"t5.hierarchy_storage.m{m}", 0.0,
             f"transfer_free={s_tf['transfer']};"
             f"transfer_stored={s_st['transfer']};"
             f"transfer_ratio={s_st['transfer']/s_tf['transfer']:.2f}x;"
             f"total_free={s_tf['total']};total_stored={s_st['total']}")
        per_unknown.append((n, s_bytes / n, b_bytes / n))

        # blocked COO assembly plan vs scalar equivalent (Sec. 5)
        cp = prob.coo_plan
        emit(f"t5.coo_plan.m{m}", 0.0,
             f"block={cp.plan_bytes};scalar={scalar_coo_plan_bytes(cp)};"
             f"ratio={scalar_coo_plan_bytes(cp)/cp.plan_bytes:.1f}x")

    # capacity cliff extrapolation (Fig. 3): 128^3 grid on 8 devices.
    # The symbolic buffers exist for BOTH Galerkin stages (A@P and R@AP, a
    # further ~6x pairs for the R@AP stage in scalar form) at the same time
    # as the matrix, vectors and hierarchy; the paper's cuSPARSE buffers are
    # larger still.  We report the first-stage plan alone and its share of
    # an 80 GB A100.
    n_target = 128 ** 3 * 3
    s_slope = float(np.mean([s for _, s, _ in per_unknown[-2:]]))
    b_slope = float(np.mean([b for _, _, b in per_unknown[-2:]]))
    per_dev_scalar = s_slope * n_target / 8
    per_dev_block = b_slope * n_target / 8
    a100 = 80e9
    emit("t5.capacity.scalar_128cubed_8dev", 0.0,
         f"stage1_plan_gb={per_dev_scalar/1e9:.1f};"
         f"hbm_frac={per_dev_scalar/a100:.2f};"
         f"both_stages_est_gb={per_dev_scalar*3.5/1e9:.0f};"
         f"ooms_with_solver_state=LIKELY")
    emit("t5.capacity.block_128cubed_8dev", 0.0,
         f"stage1_plan_gb={per_dev_block/1e9:.2f};"
         f"hbm_frac={per_dev_block/a100:.3f};fits=YES")


if __name__ == "__main__":
    run()
