"""Paper Table 2: scalar backend comparison vs the block format.

The paper compares two *scalar* backends (vendor cuSPARSE vs portable
Kokkos Kernels) against its block code.  The JAX analogues:

  scalar BCOO     jax.experimental.sparse (the "vendor library" route)
  scalar CSR      gather + sorted segment-sum (the portable native route)
  block BELL      this framework

measured on hot SpMV and the hot PtAP numeric phase of the same operator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401
from repro.core import gamg
from repro.core.scalar_csr import expand_bcsr
from repro.core.scalar_path import build_scalar_ptap_chain
from repro.core.spmv import spmv_csr_ref, spmv_ell
from repro.core.ptap import ptap_numeric_data
from repro.fem.assemble import assemble_elasticity

from benchmarks.common import emit, time_fn


def run(m: int = 10) -> None:
    prob = assemble_elasticity(m)
    A = prob.A
    S = expand_bcsr(A)
    n = A.shape[0]
    x = jnp.ones(n, A.data.dtype)

    # block BELL
    ell = A.to_ell()
    f_block = jax.jit(lambda e, v: spmv_ell(e, v))
    us_block = time_fn(f_block, ell, x)

    # scalar CSR via gather+segment-sum (portable native analogue)
    rows = jnp.asarray(np.repeat(np.arange(S.nbr), np.diff(S.indptr)))
    idx = jnp.asarray(S.indices.astype(np.int32))
    sdata = S.data.reshape(-1)
    f_csr = jax.jit(lambda d, v: spmv_csr_ref(idx, d, rows,
                                              nrows=S.nbr, x=v))
    us_csr = time_fn(f_csr, sdata, x)

    # scalar BCOO via jax.experimental.sparse (vendor-library analogue)
    from jax.experimental import sparse as jsparse
    coo_rows = np.repeat(np.arange(S.nbr), np.diff(S.indptr))
    bcoo = jsparse.BCOO((sdata, jnp.asarray(
        np.stack([coo_rows, S.indices], axis=1))), shape=(n, n))
    f_bcoo = jax.jit(lambda M, v: M @ v)
    us_bcoo = time_fn(f_bcoo, bcoo, x)

    emit(f"t2.spmv.block.m{m}", us_block, f"n={n}")
    emit(f"t2.spmv.scalar_csr.m{m}", us_csr,
         f"block_speedup={us_csr/us_block:.2f}x")
    emit(f"t2.spmv.scalar_bcoo.m{m}", us_bcoo,
         f"block_speedup={us_bcoo/us_block:.2f}x")

    # hot PtAP: blocked numeric chain vs scalar numeric chain
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30)

    def blocked_chain(a_data):
        outs = []
        for ls in setupd.levels:
            a_data = ptap_numeric_data(ls.ptap_cache, a_data, ls.P.data)
            outs.append(a_data)
        return outs

    blk = jax.jit(blocked_chain)
    sc = build_scalar_ptap_chain(setupd)
    us_blk = time_fn(blk, prob.A.data)
    us_sc = time_fn(sc, prob.A.data)
    emit(f"t2.ptap.block.m{m}", us_blk, "")
    emit(f"t2.ptap.scalar_csr.m{m}", us_sc,
         f"block_speedup={us_sc/us_blk:.2f}x")


if __name__ == "__main__":
    run()
