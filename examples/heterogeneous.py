"""Heterogeneous quasi-static loop: device assembly + coefficient updates.

A two-material problem (stiff spherical inclusion in a soft matrix) whose
inclusion stiffness ramps over "load steps".  Each step runs the fused
device hot loop — per-element material fields in, hierarchy out, solve —
as one jitted program: no per-step host assembly, no value-stream upload,
no retraces (the paper's recurring-recompute scenario with the assembly
itself device-resident).

Run:  PYTHONPATH=src python examples/heterogeneous.py [m]
"""
import sys
import time

import numpy as np

import repro.core  # noqa: F401  (enables fp64)
from repro.core import gamg
from repro.fem.assemble import assemble_elasticity, inclusion_fields


def main(m: int = 7) -> None:
    print(f"assembling {m}^3 Q1 elasticity on device (vmapped quadrature)")
    prob = assemble_elasticity(m)                  # path="device" default
    ne = prob.mesh.n_elements
    print(f"  n = {prob.n} unknowns, {ne} elements, coefficient update "
          f"payload = {2 * ne * 8} bytes (vs "
          f"{np.asarray(prob.values).nbytes} value-stream bytes)")

    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=40,
                             rtol=1e-8, maxiter=100)
    solver.bind_assembler(prob.assembler)
    print(f"cold setup: {solver.setup_data.n_levels} levels, "
          f"rows/level = {solver.setup_data.stats['level_rows']}")

    for step, contrast in enumerate((1.0, 10.0, 100.0, 1000.0)):
        E, nu = inclusion_fields(prob.mesh, E_inclusion=contrast)
        t0 = time.perf_counter()
        solver.update_coefficients(E, nu)   # assemble+recompute, one program
        t_up = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = solver.solve(prob.b)
        t_solve = time.perf_counter() - t0
        print(f"step {step}: E_inclusion {contrast:7.1f} | "
              f"update {t_up * 1e3:7.1f} ms | solve {t_solve * 1e3:7.1f} ms"
              f" | iters {int(res.iters):3d} | relres {float(res.relres):.2e}")
        assert bool(res.converged)
    assert solver._coeff_recompute._cache_size() == 1, "retraced!"
    print("converged; one traced update program served every step.")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 7)
