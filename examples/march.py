"""Device-resident quasi-static time march with adaptive re-coarsening.

Marches the built-in damage-softening scenario (``repro.sim``): each
step feeds the previous solution into the coefficient-update law, runs
the fused device assembly -> state-gated PtAP recompute -> warm-started
AMG-PCG step, and the device-side staleness monitor decides when the
frozen hierarchy has degraded enough to be worth a host rebuild.  The
three policies are run on the same trajectory:

* ``frozen``    one setup, the whole march one traced ``lax.scan``;
* ``adaptive``  frozen segments cut by the staleness monitor;
* ``resetup``   a full ``gamg.setup`` before every step (baseline).

Run:  PYTHONPATH=src python examples/march.py [m] [n_steps]
"""
import sys
import time

import numpy as np

import repro.core  # noqa: F401  (enables fp64)
from repro.fem.assemble import assemble_elasticity
from repro.sim import MarchConfig, SofteningScenario, StalenessConfig, march


def main(m: int = 5, n_steps: int = 8) -> None:
    print(f"assembling {m}^3 Q1 elasticity on device")
    prob = assemble_elasticity(m)
    scen = SofteningScenario.build(prob, rate=0.25, d_max=0.99)
    cfg = MarchConfig(n_steps=n_steps, seg_len=8, rtol=1e-8, maxiter=400,
                      staleness=StalenessConfig(iter_drift=2, ref_window=2,
                                                coeff_rtol=0.25))
    results = {}
    for mode in ("frozen", "adaptive", "resetup"):
        t0 = time.perf_counter()
        res = march(prob, scen, cfg, mode=mode,
                    setup_opts={"coarse_size": 8})
        dt = time.perf_counter() - t0
        results[mode] = res
        segs = " ".join(f"{s.steps}@setup{s.setup_id}({s.reason})"
                        for s in res.segments)
        print(f"{mode:>8}: {dt:6.1f} s | setups {res.n_setups} | "
              f"iters {res.iters.tolist()} (total {res.total_iters}) | "
              f"segments: {segs}")
        assert res.status == "ok", res.status

    frozen, adaptive, resetup = (results["frozen"], results["adaptive"],
                                 results["resetup"])
    x_ref = np.asarray(resetup.x)
    rel = (np.linalg.norm(np.asarray(adaptive.x) - x_ref)
           / np.linalg.norm(x_ref))
    print(f"adaptive vs per-step-resetup final state: rel diff {rel:.2e} "
          f"with {adaptive.n_setups}/{resetup.n_setups} of the setups")
    print(f"adaptive vs frozen total CG iterations: "
          f"{adaptive.total_iters} vs {frozen.total_iters}")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:3]))
