"""Observe AMG: the full telemetry surface around one serving session.

Drives every layer of ``repro.obs`` (ISSUE 7) against a live solve
server:

* ``counters`` mode — a device-side ``CycleTally`` rides the CG carry,
  so the solve itself reports what it did (per-level visits, smoother /
  operator / coarse applications) and what the traffic model says it
  should have cost — compared here against the analytic expectation;
* per-request residual **histories** (NaN-padded per-column traces) from
  the panel solve, rendered as a convergence sketch;
* the server's always-on ``ServerMetrics``: queue wait, end-to-end
  latency, blocked solve wall time, padding efficiency, per-bucket and
  per-status counts — polled via ``snapshot()`` and exported both as
  Prometheus text and as a JSONL sink a dashboard could tail;
* the ``measure()`` compile/steady split on the hot recompute.

Run:  PYTHONPATH=src python examples/observe_amg.py [m]
"""
import sys

import numpy as np

import repro.core  # noqa: F401  (enables fp64)
from repro.core import gamg
from repro.fem.assemble import assemble_elasticity
from repro.multirhs import AMGSolveServer
from repro.obs import MetricsRegistry, describe_tally, use


def main(m: int = 6) -> None:
    print(f"assembling {m}^3 Q1 elasticity ...")
    prob = assemble_elasticity(m)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=40)
    print(f"hierarchy: {setupd.n_levels} levels, n = {prob.n}, "
          f"precision: {setupd.precision.describe()}")

    # ---- device-side counters on a single solve -------------------------
    # obs mode is consumed at trace time: build the closure inside the
    # scope (or set REPRO_OBS=counters before constructing the solver)
    with use("counters"):
        solve = gamg.make_solve(setupd, rtol=1e-8, maxiter=100)
    hier = gamg.make_recompute(setupd)(prob.A.data)
    res = solve(hier, prob.b)
    print(f"\nsolve: {int(res.iters)} iters, relres {float(res.relres):.2e}")
    print(f"tally: {describe_tally(res.counters)}")
    cycles = int(res.iters) + 1
    print(f"check: {cycles} cycles expected -> "
          f"{cycles} V-cycles, {2 * cycles} smoother sweeps/level, "
          f"{cycles} coarse solves")

    # ---- server metrics + per-request histories -------------------------
    # record_history defaults to "on when obs is on"; force it explicitly
    # so the demo works regardless of REPRO_OBS
    server = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2, 4, 8),
                            rtol=1e-8, maxiter=100, record_history=True)
    rng = np.random.default_rng(0)
    for burst in (3, 8, 1):
        for _ in range(burst):
            server.submit(rng.standard_normal(prob.n))
        server.flush()
    reports = server.serve([np.asarray(prob.b)])
    r = reports[0]
    live = r.history[np.isfinite(r.history)]
    print(f"\nresidual history (request {r.request_id}, "
          f"{r.iters} iters, latency {r.latency_s * 1e3:.1f} ms):")
    marks = [0, len(live) // 2, len(live) - 1]
    print("  " + "  ".join(f"it{k:>3}: {live[k]:.2e}" for k in marks))

    snap = server.snapshot()
    print("\nserver snapshot:")
    for key in ("requests", "batches", "padded_columns",
                "padding_efficiency", "solves_per_k", "status"):
        print(f"  {key:>20}: {snap[key]}")
    print(f"  {'latency p50/p99':>20}: {snap['latency_p50_s'] * 1e3:.1f} / "
          f"{snap['latency_p99_s'] * 1e3:.1f} ms")
    print(f"  {'solve wall p50':>20}: {snap['solve_wall_p50_s'] * 1e3:.1f} ms")

    # ---- compile/steady split on the hot recompute ----------------------
    reg = MetricsRegistry()
    recompute = gamg.make_recompute(setupd)
    for scale in (1.0, 1.1, 1.2):
        reg.measure("recompute", recompute, scale * prob.A.data)
    cold = reg.get("recompute/compile").snapshot()
    hot = reg.get("recompute/steady").snapshot()
    print(f"\nrecompute: compile {cold['max'] * 1e3:.1f} ms (x{cold['count']})"
          f", steady {hot['max'] * 1e3:.1f} ms (x{hot['count']})")

    # ---- exporters ------------------------------------------------------
    prom = server.metrics().to_prometheus()
    wanted = ("server_request_latency_seconds_count",
              "server_padding_efficiency", "server_batches_total")
    print("\nprometheus exposition (excerpt):")
    for line in prom.splitlines():
        if line.startswith(wanted):
            print(f"  {line}")
    jsonl = server.metrics().to_jsonl()
    print(f"jsonl export: {len(jsonl.splitlines())} instrument lines "
          f"(tail one file per poll for a dashboard)")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 6)
