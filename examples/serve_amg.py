"""Serve AMG: the hierarchy-reusing multi-RHS solve server, end to end.

The production story the ROADMAP aims at: one cold GAMG setup serves a
*stream* of solve requests (load cases, client queries, Newton steps).
The server buckets arriving right-hand sides into static panel widths
(k in {1, 2, 4, 8} here), pads the remainder columns with zeros (frozen
from iteration 0 by the masked PCG), and runs batched panel solves on the
cached hierarchy — each request gets its own iteration count and residual
back, identical to a dedicated solve.

Run:  PYTHONPATH=src python examples/serve_amg.py [m]
"""
import sys
import time

import numpy as np

import repro.core  # noqa: F401  (enables fp64)
from repro.core import gamg
from repro.fem.assemble import assemble_elasticity
from repro.multirhs import AMGSolveServer


def main(m: int = 7) -> None:
    print(f"assembling {m}^3 Q1 elasticity ...")
    prob = assemble_elasticity(m)
    t0 = time.perf_counter()
    # REPRO_PRECISION=f32 hosts an fp32-resident hierarchy that still
    # serves fp64 requests (fp64 outer CG, preconditioner-boundary cast)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=40)
    server = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2, 4, 8),
                            rtol=1e-8, maxiter=100)
    print(f"cold setup + hierarchy: {time.perf_counter() - t0:.2f}s, "
          f"n = {prob.n}, buckets = {server.buckets}, "
          f"precision: {setupd.precision.describe()}")

    rng = np.random.default_rng(0)
    # bursty request stream: arrival counts deliberately off-bucket
    for burst in (1, 3, 8, 5):
        for _ in range(burst):
            server.submit(rng.standard_normal(prob.n))
        t0 = time.perf_counter()
        reports = server.flush()
        dt = time.perf_counter() - t0
        ks = sorted({r.k_bucket for r in reports})
        its = [r.iters for r in reports]
        print(f"burst of {burst}: buckets {ks} | iters {min(its)}-{max(its)}"
              f" | {dt * 1e3:7.1f} ms total | {dt * 1e3 / burst:6.1f}"
              f" ms/rhs | all converged: {all(r.converged for r in reports)}")

    # operator update mid-stream (a Newton step): hierarchy structure and
    # the traced bucket solves are reused, only the values recompute
    a_new = prob.reassemble(1.2)
    t0 = time.perf_counter()
    server.update_operator(a_new.data)
    print(f"hot operator update: {(time.perf_counter() - t0) * 1e3:.1f} ms")
    reports = server.serve([np.asarray(prob.b) for _ in range(4)])
    assert all(r.converged for r in reports)
    print(f"post-update burst: iters {[r.iters for r in reports]}")
    print(f"stats: {server.stats}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 7)
