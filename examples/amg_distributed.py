"""Distributed AMG example: the paper's production solve on N shards.

Runs the shard_map distributed solver (halo-exchange SpMV, state-gated
P_oth cache, all_to_all off-process reduction) on host placeholder devices
and checks parity with the single-device result.

Run:  PYTHONPATH=src python examples/amg_distributed.py [ndev] [m]
      (re-execs itself to set the device-count flag before jax loads)
"""
import os
import subprocess
import sys


def main() -> None:
    ndev = sys.argv[1] if len(sys.argv) > 1 else "8"
    m = sys.argv[2] if len(sys.argv) > 2 else "6"
    env = dict(os.environ)
    env["REPRO_SELFTEST_NDEV"] = ndev
    env.setdefault("PYTHONPATH", "src")
    raise SystemExit(subprocess.run(
        [sys.executable, "-m", "repro.dist.selftest", m], env=env).returncode)


if __name__ == "__main__":
    main()
