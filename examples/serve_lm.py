"""Serving driver: batched prefill + decode with KV cache.

Builds a reduced falcon-mamba (constant-memory state) and a reduced qwen2
(KV cache) model, prefetches a batch of prompts and generates continuations
— the serve_step path the decode dry-run cells lower at production scale.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.train.steps import make_serve_step

B, PROMPT, GEN = 4, 32, 32


def serve(arch: str) -> None:
    cfg = get_config(arch).reduced()
    params = T.init_lm(cfg, jax.random.key(0))
    serve_step = jax.jit(make_serve_step(cfg, cdt=jnp.float32))
    cache = T.init_full_cache(cfg, B, PROMPT + GEN, cdt=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, PROMPT)),
                          jnp.int32)
    # prefill via the decode path (token-by-token; production uses the
    # fused prefill lowering benchmarked by the prefill_32k cells)
    t0 = time.perf_counter()
    for pos in range(PROMPT):
        logits, cache = serve_step(params, cache, prompts[:, pos:pos + 1],
                                   jnp.asarray(pos, jnp.int32))
    toks = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
    for pos in range(PROMPT, PROMPT + GEN - 1):
        logits, cache = serve_step(params, cache, toks[-1],
                                   jnp.asarray(pos, jnp.int32))
        toks.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
    dt = time.perf_counter() - t0
    out = np.concatenate([np.asarray(t) for t in toks], axis=1)
    print(f"{arch}: generated {B}x{GEN} tokens in {dt:.2f}s "
          f"({B*(PROMPT+GEN)/dt:,.0f} tok/s incl. prefill)")
    print(f"  sample continuation: {out[0][:12].tolist()}")


def main() -> None:
    serve("qwen2-0.5b")          # KV-cache attention path
    serve("falcon-mamba-7b")     # constant-state SSM path


if __name__ == "__main__":
    main()
