"""End-to-end driver: train a ~100M-parameter qwen2-family model.

Exercises the full training substrate on CPU: synthetic data pipeline,
AdamW, per-layer remat, checkpointing every N steps, restart-on-failure
semantics, and loss reporting.  (The production mesh path is exercised by
the dry-run; this driver runs a real optimization.)

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticTokens
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: qwen2 family, scaled
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"), n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32000,
        tie_embeddings=True)
    params = T.init_lm(cfg, jax.random.key(0))
    n_params = T.count_params(params)
    print(f"model: {cfg.name}-derived, {n_params/1e6:.1f}M params")

    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-4, warmup_steps=50), cdt=jnp.bfloat16))
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                      global_batch=args.batch,
                                      seq_len=args.seq + 1))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    state = {"params": params, "opt": opt_state}
    start = 0
    restored = ckpt.restore_latest(state)
    if restored:
        start, state, _ = restored
        print(f"resumed from step {start}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch_at(step).items()}
        p, o, m = step_fn(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tok_s = (step - start + 1) * args.batch * args.seq / dt
            print(f"step {step:4d} | loss {float(m['loss']):7.4f} | "
                  f"gnorm {float(m['grad_norm']):6.2f} | {tok_s:,.0f} tok/s")
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, state)
    ckpt.save(args.steps, state)
    print("done.")


if __name__ == "__main__":
    main()
