"""Quickstart: blocked AMG on 3D elasticity (the paper's workflow).

Assembles a Q1 hex elasticity operator through the blocked COO primitive,
builds the GAMG hierarchy once, then runs the production loop: the operator
changes every "Newton step", the hierarchy is reused, the hot PtAP
recompute and the hot KSPSolve stay on-device in blocks.

Run:  PYTHONPATH=src python examples/quickstart.py [m]
"""
import sys
import time

import jax.numpy as jnp

import repro.core  # noqa: F401  (enables fp64)
from repro.core import gamg
from repro.fem.assemble import assemble_elasticity


def main(m: int = 9) -> None:
    print(f"assembling {m}^3 Q1 elasticity via blocked COO ...")
    prob = assemble_elasticity(m)
    print(f"  n = {prob.n} unknowns, {prob.A.nnzb} 3x3 blocks, "
          f"COO plan {prob.coo_plan.plan_bytes/1e6:.2f} MB")

    t0 = time.perf_counter()
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=40,
                             rtol=1e-8, maxiter=100)
    print(f"cold setup: {time.perf_counter()-t0:.2f}s, "
          f"{solver.setup_data.n_levels} levels, "
          f"rows/level = {solver.setup_data.stats['level_rows']}, "
          f"bs/level = {solver.setup_data.stats['level_bs']}")

    # production loop: operator changes, hierarchy (aggregates + P) reused
    for step in range(3):
        scale = 1.0 + 0.1 * step           # stand-in for a Newton update
        a_new = prob.reassemble(scale)     # one MatSetValuesCOO scatter
        t0 = time.perf_counter()
        solver.update_operator(a_new.data)  # hot PtAP chain (state-gated)
        t_ptap = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = solver.solve(prob.b)
        t_solve = time.perf_counter() - t0
        print(f"step {step}: hot PtAP {t_ptap*1e3:7.1f} ms | "
              f"hot KSPSolve {t_solve*1e3:7.1f} ms | "
              f"iters {int(res.iters):3d} | relres {float(res.relres):.2e}")
    assert bool(res.converged)
    print("converged.")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 9)
