"""Distributed-AMG dry-run rows (the paper's own solver on the production
devices).

Lowers + compiles the full distributed hot path — recompute (chained
state-gated PtAP with cached P_oth) followed by the AMG-preconditioned CG
solve — via shard_map over the production devices flattened to a 1-D rank
axis (PETSc-style row slabs), for both the single-pod (256 ranks) and
multi-pod (512 ranks) device sets.  Records the same memory / cost /
collective census as the LM cells into the shared results JSON.

The grid is sized so host plan construction stays in CPU budget; the paper's
full weak-scaling ladder is exercised numerically by ``benchmarks/``.
"""
from __future__ import annotations

import json
import time

import jax

from repro.launch.dryrun import (
    RESULTS_PATH,
    _load_results,
    _save_results,
    collective_census,
)


def run_amg_dryrun(force: bool = False, m: int = 21) -> int:
    import numpy as np
    import repro.core  # noqa: F401  (x64)
    from repro.core import gamg
    from repro.dist.solver import build_dist_gamg, make_dist_solver
    from repro.fem.assemble import assemble_elasticity

    results = _load_results()
    prob = assemble_elasticity(m)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=60)
    failures = 0
    for mesh_name, ndev in (("single", 256), ("multi", 512)):
        key = f"amg-elasticity-q1-m{m}|solve|{mesh_name}|base"
        if key in results and not force and \
                results[key].get("status") == "OK":
            print(f"[cached] {key}")
            continue
        print(f"[run]    {key} (ndev={ndev}) ...", flush=True)
        try:
            mesh = jax.make_mesh((ndev,), ("rank",))
            t0 = time.time()
            dg = build_dist_gamg(setupd, ndev)
            args = dg.sharded_args(setupd)
            a0 = dg.scatter_fine_payloads(prob.A.data)
            b = dg.scatter_vector(prob.b)
            run = make_dist_solver(dg, setupd, mesh, rtol=1e-8,
                                   maxiter=100)
            lowered = run.lower(args, a0, b)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            rec = {
                "status": "OK", "kind": "amg_solve",
                "mesh": [ndev], "n_devices": ndev,
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "grid": f"{m}^3 Q1 elasticity "
                        f"({prob.A.shape[0]} unknowns, "
                        f"{len(setupd.levels) + 1} levels)",
                "halo_strategy": dg.levels[0].a_op.halo.strategy,
                "memory": {
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "peak_bytes": int(getattr(ma, "peak_memory_in_bytes",
                                              0)),
                    "alias_bytes": int(ma.alias_size_in_bytes),
                },
                "cost": {
                    "flops_per_device": float(ca.get("flops", -1.0)),
                    "bytes_accessed_per_device":
                        float(ca.get("bytes accessed", -1.0)),
                },
                "collectives": collective_census(compiled.as_text()),
            }
            results[key] = rec
            _save_results(results)
            print(f"         OK compile={rec['compile_s']}s "
                  f"peak/dev={rec['memory']['peak_bytes']/2**20:.1f}MiB "
                  f"coll={rec['collectives']['total_bytes']/2**20:.2f}MiB",
                  flush=True)
        except Exception as e:
            import traceback
            results[key] = {"status": "FAIL", "error": repr(e),
                            "trace": traceback.format_exc()[-2000:]}
            _save_results(results)
            failures += 1
            print(f"         FAIL {e!r}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(run_amg_dryrun())
