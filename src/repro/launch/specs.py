"""input_specs + sharding construction for every (arch x shape x mesh) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every input of the
lowered step (params, optimizer state, batch / cache) — weak-type-correct,
shardable, no device allocation.  ``cell_shardings`` pairs them with
NamedShardings: FSDP x TP for parameters (divisibility-sanitized per mesh),
batch over the data axes, decode caches sequence-sharded over "model".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, data_axes
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeSpec
from repro.models.sharding import tree_partition_specs
from repro.train.optimizer import init_opt_state
from repro.train.steps import make_init

Array = jax.Array


def _sds(tree):
    """Pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs via eval_shape (never materialized)."""
    return jax.eval_shape(make_init(cfg), jax.random.key(0))


def abstract_opt_state(params):
    return jax.eval_shape(init_opt_state, params)


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        if dim % axis_size(mesh, part) != 0:
            out.append(None)
        else:
            out.append(part)
    return P(*out)


def tree_shardings(tree, specs, mesh):
    return jax.tree_util.tree_map(
        lambda leaf, spec: NamedSharding(
            mesh, sanitize_spec(spec, leaf.shape, mesh)),
        tree, specs)


def param_shardings(cfg: ModelConfig, mesh):
    params = abstract_params(cfg)
    specs = tree_partition_specs(params, data_axes=data_axes(mesh),
                                 model_axis="model")
    return params, tree_shardings(params, specs, mesh)


def _batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    da = data_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    specs = {"tokens": P(da, None), "labels": P(da, None)}
    if cfg.encdec is not None:
        e = cfg.encdec
        batch["enc_feats"] = jax.ShapeDtypeStruct(
            (B, e.encoder_frames, cfg.d_model), jnp.float32)
        specs["enc_feats"] = P(da, None, None)
    return batch, specs


def _cache_spec_tree(cfg: ModelConfig, cache, mesh):
    """Decode-cache PartitionSpecs: batch over data, long dims over model.

    K/V caches shard over *kv heads* when the TP degree divides them, else
    over sequence — matching the in-kernel attention strategy.  A mismatch
    makes XLA re-shard the full cache every layer every step (measured 30x
    the cache-read floor on gemma-7b decode_32k — §Perf iteration 6).
    """
    ms = axis_size(mesh, "model")

    def spec_of(path, leaf):
        name = str(path[-1].key)
        nd = leaf.ndim
        if name in ("k", "v"):          # (L, B, S, Hkv, hd)
            if cfg.n_kv_heads % ms == 0:
                return P(None, "data", None, "model", None)
            return P(None, "data", "model", None, None)
        if name == "c_kv" or name == "k_rope":   # (L, B, S, r)
            return P(None, "data", "model", None)
        if name == "conv":              # (L, B, K, Din)
            return P(None, "data", None, "model")
        if name == "ssm":               # (L, B, Din, N)
            return P(None, "data", "model", None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_of(p, l) for p, l in flat])


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    kind: str                  # train|prefill|decode
    args: tuple                # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh) -> CellSpec:
    da = data_axes(mesh)
    params, p_shard = param_shardings(cfg, mesh)
    if shape.kind == "train":
        opt = abstract_opt_state(params)
        # optimizer moments shard exactly like their parameters (ZeRO)
        o_shard = {"mu": p_shard, "nu": p_shard,
                   "step": NamedSharding(mesh, P())}
        batch, b_specs = _batch_specs(cfg, shape, mesh)
        b_shard = jax.tree_util.tree_map(
            lambda l, s: NamedSharding(mesh, sanitize_spec(s, l.shape,
                                                           mesh)),
            batch, b_specs)
        return CellSpec(
            kind="train",
            args=(params, opt, batch),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard,
                           {"loss": NamedSharding(mesh, P()),
                            "grad_norm": NamedSharding(mesh, P())}))
    if shape.kind == "prefill":
        batch, b_specs = _batch_specs(cfg, shape, mesh)
        logits_shard = NamedSharding(mesh, sanitize_spec(
            P(da, None, "model"),
            (shape.global_batch, shape.seq_len, cfg.vocab_size), mesh))
        args = [params, batch["tokens"]]
        shards = [p_shard, b_shard_one(batch["tokens"], b_specs["tokens"],
                                       mesh)]
        if cfg.encdec is not None:
            args.append(batch["enc_feats"])
            shards.append(b_shard_one(batch["enc_feats"],
                                      b_specs["enc_feats"], mesh))
        return CellSpec(kind="prefill", args=tuple(args),
                        in_shardings=tuple(shards),
                        out_shardings=logits_shard)
    # decode: one new token against a seq_len cache
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: T.init_full_cache(cfg, B, S, cdt=jnp.bfloat16))
    c_specs = _cache_spec_tree(cfg, cache, mesh)
    c_shard = tree_shardings(cache, c_specs, mesh)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = NamedSharding(mesh, sanitize_spec(P(da, None),
                                                  (B, 1), mesh))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    args = [params, cache, token, pos]
    shards = [p_shard, c_shard, tok_shard, pos_shard]
    if cfg.encdec is not None:
        enc_out = jax.ShapeDtypeStruct(
            (B, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16)
        args.append(enc_out)
        shards.append(NamedSharding(
            mesh, sanitize_spec(P(da, None, None), enc_out.shape, mesh)))
    logits_shard = NamedSharding(mesh, sanitize_spec(
        P(da, None, "model"), (B, 1, cfg.vocab_size), mesh))
    return CellSpec(kind="decode", args=tuple(args),
                    in_shardings=tuple(shards),
                    out_shardings=(logits_shard, c_shard))


def b_shard_one(leaf, spec, mesh):
    return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))
