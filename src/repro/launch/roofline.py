"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from the compiled dry-run:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(cost_analysis on the SPMD-partitioned module is already per-device, so no
division by chip count is needed — verified against a hand-counted matmul.)

Also reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per device,
the MODEL_FLOPS/HLO ratio (useful-compute fraction; catches remat and
dispatch waste), the dominant term, and the roofline fraction
T_ideal / T_bound where T_ideal = MODEL_FLOPS/peak and T_bound = max(terms).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one direction)

RESULTS_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))))),
    "launch_artifacts", "dryrun_results.json")


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6*N*D training, 2*N*D per generated token for decode
# ---------------------------------------------------------------------------

def model_params(arch: str) -> Dict[str, float]:
    """Total and active parameter counts from the abstract param tree."""
    from repro.configs.registry import get_config
    from repro.launch.specs import abstract_params
    import jax
    import numpy as np
    cfg = get_config(arch)
    params = abstract_params(cfg)
    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        n = int(np.prod(leaf.shape))
        total += n
        if "we_" in name and cfg.moe:                 # routed experts
            frac = min(1.0, cfg.moe.top_k / cfg.moe.n_experts)
            active += n * frac
        elif name.endswith("embed") or "lm_head" in name:
            active += 0      # embedding lookups are not matmul FLOPs
        else:
            active += n
    return {"total": total, "active": active}


def model_flops(arch: str, shape_kind: str, seq_len: int, global_batch: int,
                n_devices: int) -> float:
    """Useful model FLOPs per device for one step."""
    mp = model_params(arch)
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * mp["active"] * tokens / n_devices
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * mp["active"] * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * mp["active"] * global_batch / n_devices


def _n_units(arch: str) -> int:
    from repro.configs.registry import get_config
    cfg = get_config(arch)
    per = 2 if (cfg.moe and cfg.moe.moe_every == 2) else 1
    return cfg.n_layers // per


def _loop_corrected(base: float, d1: float, d2: float, units: int) -> float:
    """outside + units*body, from depth-1/2 probes (XLA counts a while-loop
    body once, so body = d2-d1, outside = d1-body)."""
    body = max(d2 - d1, 0.0)
    outside = max(d1 - body, 0.0)
    return outside + units * body


def _ssm_scan_terms(arch: str, kind: str, seq_len: int, global_batch: int,
                    ndev: int):
    """Analytic flops/bytes of the chunked selective scan.

    The inner chunk loop is opaque to both cost_analysis and the depth
    probes (nested while body counted once); its matmul-free elementwise
    traffic is significant for SSM archs, so it is added analytically:
    ~6 array passes over (B, S, Din, N) fp32, ~10 flops/element.
    """
    from repro.configs.registry import get_config
    cfg = get_config(arch)
    if cfg.ssm is None or kind == "decode":
        return 0.0, 0.0
    d_in = cfg.ssm.expand * cfg.d_model
    n = cfg.ssm.d_state
    elems = (seq_len * global_batch) * d_in * n / ndev  # per layer
    return 10.0 * elems * cfg.n_layers, 6.0 * 4.0 * elems * cfg.n_layers


def _model_min_bytes(arch: str, kind: str, seq_len: int, global_batch: int,
                     ndev: int) -> float:
    """Lower bound on bytes/step/device: touch active params (bf16) once,
    plus (decode) read the KV/state cache once — the bandwidth floor that
    makes decode roofline fractions meaningful."""
    mp = model_params(arch)
    param_bytes = 2.0 * mp["active"] / ndev
    if kind != "decode":
        return param_bytes
    from repro.configs.registry import get_config
    from repro.launch.specs import abstract_params  # noqa: F401
    import jax
    import numpy as np
    from repro.models import transformer as T
    import jax.numpy as jnp
    cfg = get_config(arch)
    cache = jax.eval_shape(lambda: T.init_full_cache(
        cfg, global_batch, seq_len, cdt=jnp.bfloat16))
    cache_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(cache))
    return param_bytes + cache_bytes / ndev


def analyze_cell(key: str, rec: dict, probes: Optional[dict] = None
                 ) -> Optional[dict]:
    if rec.get("status") != "OK":
        return None
    arch, shape, mesh, variant = key.split("|")
    from repro.models.config import shape_by_name
    sh = shape_by_name(shape)
    ndev = rec["n_devices"]
    flops_dev = rec["cost"]["flops_per_device"]
    bytes_dev = rec["cost"]["bytes_accessed_per_device"]
    coll_dev = rec["collectives"]["total_bytes"]
    corrected = False
    if probes:
        pfx = "probe" if variant == "base" else f"{variant}-probe"
        d1 = probes.get(f"{arch}|{shape}|single|{pfx}-d1")
        d2 = probes.get(f"{arch}|{shape}|single|{pfx}-d2")
        if d1 and d2 and d1.get("status") == "OK" \
                and d2.get("status") == "OK":
            units = _n_units(arch)
            flops_dev = _loop_corrected(
                flops_dev, d1["cost"]["flops_per_device"],
                d2["cost"]["flops_per_device"], units)
            bytes_dev = _loop_corrected(
                bytes_dev, d1["cost"]["bytes_accessed_per_device"],
                d2["cost"]["bytes_accessed_per_device"], units)
            coll_dev = _loop_corrected(
                coll_dev, d1["collectives"]["total_bytes"],
                d2["collectives"]["total_bytes"], units)
            corrected = True
    sf, sb = _ssm_scan_terms(arch, rec["kind"], sh.seq_len,
                             sh.global_batch, ndev)
    flops_dev += sf
    bytes_dev += sb
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    mf = model_flops(arch, rec["kind"], sh.seq_len, sh.global_batch, ndev)
    mb = _model_min_bytes(arch, rec["kind"], sh.seq_len, sh.global_batch,
                          ndev)
    t_ideal = max(mf / PEAK_FLOPS, mb / HBM_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = terms[dominant]
    return {
        "key": key, "arch": arch, "shape": shape, "mesh": mesh,
        "variant": variant, "kind": rec["kind"], "n_devices": ndev,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "hlo_flops_per_device": flops_dev,
        "useful_fraction": mf / flops_dev if flops_dev > 0 else 0.0,
        "roofline_fraction": t_ideal / t_bound if t_bound > 0 else 0.0,
        "peak_bytes_per_device": rec["memory"]["peak_bytes"],
        "fits_16g": rec["memory"]["peak_bytes"] < 16e9,
        "loop_corrected": corrected,
    }


def analyze_all(variant: str = "base") -> list:
    with open(RESULTS_PATH) as f:
        results = json.load(f)
    probes = {k: v for k, v in results.items() if "|probe-" in k}
    rows = []
    for key, rec in sorted(results.items()):
        if not key.endswith("|" + variant) or key.startswith("amg-"):
            continue
        row = analyze_cell(key, rec, probes)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | mesh | kind | compute s | memory s | coll s | "
           "dominant | useful | roofline | peak GiB | fits 16G |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | "
            f"{r['t_collective_s']:.2e} | **{r['dominant']}** | "
            f"{r['useful_fraction']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['peak_bytes_per_device']/2**30:.1f} | "
            f"{'Y' if r['fits_16g'] else 'N'} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="base")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = analyze_all(args.variant)
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(markdown_table(rows))
        worst = sorted((r for r in rows if r["mesh"] == "single"),
                       key=lambda r: r["roofline_fraction"])
        if worst:
            print("\nworst roofline fraction (single-pod):")
            for r in worst[:5]:
                print(f"  {r['arch']} {r['shape']}: "
                      f"{r['roofline_fraction']:.3f} ({r['dominant']})")
            coll = sorted((r for r in rows if r["mesh"] == "single"),
                          key=lambda r: -r["t_collective_s"])
            print("most collective-bound (single-pod):")
            for r in coll[:5]:
                print(f"  {r['arch']} {r['shape']}: "
                      f"coll={r['t_collective_s']:.2e}s "
                      f"({r['dominant']})")


if __name__ == "__main__":
    main()
