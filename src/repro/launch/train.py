"""Production training launcher (``python -m repro.launch.train``).

Composes the whole stack: production mesh, FSDP x TP parameter shardings,
host-sharded synthetic data, jitted train_step, checkpoint/restart, and the
straggler monitor.  On the CPU container it runs reduced configs on a 1-dev
mesh; on a real pod the same entry point takes ``--mesh single|multi`` (the
dry-run proves those lower+compile for every assigned arch x shape).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (CPU-sized); full configs are "
                         "compile-validated by repro.launch.dryrun")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.train.checkpoint import CheckpointManager
    from repro.train.data import DataConfig, SyntheticTokens
    from repro.train.fault import StragglerMonitor
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.steps import make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_lm(cfg, jax.random.key(0))
    print(f"[launch] {args.arch}: {T.count_params(params)/1e6:.1f}M params "
          f"(reduced={args.reduced}), devices={len(jax.devices())}")
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3),
                                      cdt=jnp.float32))
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch,
        seq_len=args.seq + 1,
        enc_frames=cfg.encdec.encoder_frames if cfg.encdec else 0,
        d_model=cfg.d_model))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir \
        else None
    monitor = StragglerMonitor(n_hosts=1)

    state = {"params": params, "opt": opt_state}
    start = 0
    if ckpt:
        restored = ckpt.restore_latest(state)
        if restored:
            start, state, _ = restored
            print(f"[launch] resumed from step {start}")
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        p, o, m = step_fn(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        dur = time.perf_counter() - t0
        flagged = monitor.observe([dur])
        if flagged:
            monitor.mitigate(flagged, 1)
        print(f"[launch] step {step} loss={float(m['loss']):.4f} "
              f"({dur*1e3:.0f} ms)")
        if ckpt and (step + 1) % args.save_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(args.steps, state)
    print("[launch] done")


if __name__ == "__main__":
    main()
