import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place the 512 placeholder
# devices exist; tests and benches see 1 device.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: build ShapeDtypeStruct inputs + NamedShardings, ``.lower()``
+ ``.compile()`` on the single-pod (16,16) and multi-pod (2,16,16) meshes,
record ``memory_analysis()`` (fits-per-device proof), ``cost_analysis()``
(FLOPs/bytes for the roofline) and the collective-op byte census parsed from
the compiled HLO.  Results append incrementally to
``launch_artifacts/dryrun_results.json`` so interrupted sweeps resume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun             # full sweep
  ... dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  ... dryrun --amg                                         # AMG solver rows
"""
import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import specs as S                 # noqa: E402
from repro.models.config import (                   # noqa: E402
    LM_SHAPES,
    cell_applicable,
    shape_by_name,
)
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.models.sharding import axis_env          # noqa: E402
from repro.train.optimizer import AdamWConfig       # noqa: E402
from repro.train.steps import (                     # noqa: E402
    make_prefill,
    make_serve_step,
    make_train_step,
)

RESULTS_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))))),
    "launch_artifacts", "dryrun_results.json")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_census(hlo_text: str) -> dict:
    """Sum result bytes of every collective op, by op kind (per device)."""
    out = {k: {"bytes": 0, "count": 0}
           for k in ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shapes_blob, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count each channel once
        span_line = hlo_text[:m.start()].rfind("\n")
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_blob):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        key = (kind, m.start())
        if "-done" in hlo_text[m.start():m.end()]:
            continue  # counted at -start
        out[kind]["bytes"] += nbytes
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def _load_results() -> dict:
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            return json.load(f)
    return {}


def _save_results(res: dict) -> None:
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    tmp = RESULTS_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
    os.replace(tmp, RESULTS_PATH)


def _probe_config(cfg, depth: int):
    """Same model at scan depth ``depth`` (for loop-cost decomposition).

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count (verified empirically), so the roofline derives per-layer costs
    from two shallow probes: body = cost(d=2) - cost(d=1), outside =
    cost(d=1) - body, total = outside + n_units * body.
    """
    import dataclasses
    per_unit = 2 if (cfg.moe and cfg.moe.moe_every == 2) else 1
    kw = {"n_layers": depth * per_unit}
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(cfg.encdec,
                                           n_encoder_layers=depth)
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             variant: str = "base", depth_override: int | None = None
             ) -> dict:
    """Lower+compile one cell; returns the recorded analysis dict."""
    from repro.models import transformer as _T
    cfg = get_config(arch)
    _T.UNROLL_LAYERS = depth_override is not None
    if depth_override is not None:
        cfg = _probe_config(cfg, depth_override)
    shape = shape_by_name(shape_name)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"status": "SKIP", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    da = ("pod", "data") if mesh_name == "multi" else ("data",)
    t0 = time.time()
    cell = S.build_cell(cfg, shape, mesh)
    if cell.kind == "train":
        fn = make_train_step(cfg, AdamWConfig())
    elif cell.kind == "prefill":
        fn = make_prefill(cfg)
    else:
        fn = make_serve_step(cfg)
    donate = (1,) if cell.kind == "decode" else ()  # cache aliases in place
    with mesh, axis_env(da, "model", dict(mesh.shape)):
        jitted = jax.jit(fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    census = collective_census(compiled.as_text())
    rec = {
        "status": "OK",
        "kind": cell.kind,
        "mesh": list(mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0)),
            "alias_bytes": int(ma.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device": float(ca.get("flops", -1.0)),
            "bytes_accessed_per_device": float(ca.get("bytes accessed",
                                                      -1.0)),
        },
        "collectives": census,
    }
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single",
                                                     "multi"])
    ap.add_argument("--variant", default="base",
                    help="perf-iteration tag recorded alongside results")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--amg", action="store_true",
                    help="run the distributed-AMG dry-run rows instead")
    ap.add_argument("--probe", action="store_true",
                    help="lower depth-1/2 probes (loop-cost decomposition)")
    args = ap.parse_args()

    if args.amg:
        from repro.launch.dryrun_amg import run_amg_dryrun
        return run_amg_dryrun(force=args.force)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else [s.name for s in LM_SHAPES]
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    depths = [1, 2] if args.probe else [None]
    results = _load_results()
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
              for depth in depths:
                if depth is None:
                    variant = args.variant
                elif args.variant == "base":
                    variant = f"probe-d{depth}"
                else:
                    variant = f"{args.variant}-probe-d{depth}"
                key = f"{arch}|{shape}|{mesh_name}|{variant}"
                if key in results and not args.force \
                        and results[key].get("status") in ("OK", "SKIP"):
                    print(f"[cached] {key}: {results[key]['status']}")
                    continue
                print(f"[run]    {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_name, variant,
                                   depth_override=depth)
                except Exception as e:  # record failures: they are bugs
                    rec = {"status": "FAIL", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                results[key] = rec
                _save_results(results)
                if rec["status"] == "OK":
                    mb = rec["memory"]["peak_bytes"] / 2**20
                    print(f"         OK kind={rec['kind']} "
                          f"compile={rec['compile_s']}s "
                          f"peak/dev={mb:.0f}MiB "
                          f"coll={rec['collectives']['total_bytes']/2**20:.1f}"
                          f"MiB", flush=True)
                else:
                    print(f"         {rec['status']}: "
                          f"{rec.get('reason', rec.get('error'))}",
                          flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
