"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The single-pod production mesh is 16x16 =
256 chips ("data", "model"); the multi-pod mesh adds a leading "pod" axis
(2 pods = 512 chips).  Batch/FSDP shard over ("pod","data"), tensor/expert
parallel over "model"; the AMG solver uses the same devices flattened to a
1-D "rank" axis (PETSc-style slabs).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_amg_mesh(ndev: int):
    """Flattened 1-D mesh for the distributed AMG row slabs."""
    return jax.make_mesh((ndev,), ("rank",))


def data_axes(mesh) -> tuple:
    """Axes that shard the global batch (pod folds into data parallel)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
