"""Analytic traffic/communication models (the paper's byte accounting).

Moved here from ``benchmarks/common.py`` (which re-exports them for
back-compat) so the observability layer can attach modeled bytes to live
spans and device counters without the solver stack importing the
benchmark harness: the whole point of ISSUE 7 is that these models are
finally *validated against live runs* — ``repro.obs.trace`` multiplies
``vcycle_traffic``'s per-cycle total into the counter carry, and the
bench tracker reports model-vs-measured side by side.

All models are evaluated exactly (no timing involved): byte counts
separate value bytes (scale with the hierarchy dtype width — the
``PrecisionPolicy`` lever) from index bytes (always int32), the two
halves of the paper's bytes-per-nonzero argument.
"""
from __future__ import annotations

import math

import numpy as np


def value_itemsize(dtype) -> int:
    """Bytes per stored value for a dtype / dtype name ('f32' -> 4)."""
    names = {"f64": 8, "f32": 4, "bf16": 2}
    if isinstance(dtype, str) and dtype in names:
        return names[dtype]
    return int(np.dtype(dtype).itemsize)


def _ell_apply_bytes(nbr, kmax, br, bc, itemsize, scalar=False):
    """Modeled HBM bytes of one blocked-ELL operator apply.

    values  (nbr*kmax) blocks of br*bc values   — scale with itemsize
    indices one int32 per block — or per *scalar* nnz in scalar storage
            (the paper's bs^2 index-traffic blowup)
    vectors x gather at the no-reuse bound (one bc-block per slot blocked,
            one value per scalar nnz in scalar form) + the y write
    """
    values = nbr * kmax * br * bc * itemsize
    if scalar:
        indices = nbr * kmax * br * bc * 4
        x_gather = nbr * kmax * br * bc * itemsize
    else:
        indices = nbr * kmax * 4
        x_gather = nbr * kmax * bc * itemsize
    y_write = nbr * br * itemsize
    return values, indices, x_gather + y_write


def vcycle_traffic(setupd, itemsize: int = 8, scalar: bool = False) -> dict:
    """Modeled HBM traffic of one V(degree,degree) cycle at a value width.

    Per level (down + up): ``2*degree + 1`` applications of A (degree
    smoothing each side + the residual), ``2*degree`` pbjacobi applies of
    the dinv blocks, one R and one P apply; the coarsest level pays the
    dense triangular solves.  Returns ``{"value", "index", "vector",
    "total"}`` bytes so callers can report the value-byte lever (what a
    reduced-precision hierarchy halves) next to the index-byte lever
    (what the blocked format sheds) — the two halves of the paper's
    bytes-per-nonzero argument.
    """
    degree = setupd.degree
    v = ix = vec = 0
    for ls in setupd.levels:
        nbr, kmax = ls.a_ell_plan.indices.shape
        bs = ls.A0.br
        av, ai, avec = _ell_apply_bytes(nbr, kmax, bs, bs, itemsize, scalar)
        n_apply = 2 * degree + 1
        v += n_apply * av
        ix += n_apply * ai
        vec += n_apply * avec
        # pbjacobi: dinv blocks + r read + x update, per smoothing step
        vec += 2 * degree * 3 * nbr * bs * itemsize
        v += 2 * degree * nbr * bs * bs * itemsize
        pe = ls.p_ell
        pv, pi, pvec = _ell_apply_bytes(pe.nbr, pe.kmax, pe.br, pe.bc,
                                        itemsize, scalar)
        v += pv
        ix += pi
        vec += pvec
        if ls.r_ell is not None:
            re = ls.r_ell
            rv, ri, rvec = _ell_apply_bytes(re.nbr, re.kmax, re.br, re.bc,
                                            itemsize, scalar)
            v += rv
            ix += ri
            vec += rvec
        elif scalar:
            # the scalar baseline always stores an expanded restriction
            # (CSR cannot reuse P's blocks transposed-on-register) — charge
            # the stored-equivalent streams, derived from the plan dims
            nbc_t, tkmax = ls.pt.rows.shape
            rv, ri, rvec = _ell_apply_bytes(nbc_t, tkmax, pe.bc, pe.br,
                                            itemsize, True)
            v += rv
            ix += ri
            vec += rvec
        else:
            # transpose-free restriction (apply_ell_t): the value stream is
            # P's own payload, already charged once above by the
            # prolongation; restriction re-reads only the plan's two int32
            # streams per slot plus the vector gather/write
            nbc_t, tkmax = ls.pt.rows.shape
            ix += 2 * nbc_t * tkmax * 4
            vec += (nbc_t * tkmax * pe.br * itemsize
                    + nbc_t * pe.bc * itemsize)
    nc = setupd.coarse_struct.nbr * setupd.coarse_struct.br
    v += nc * nc * itemsize          # two triangular solves over the factor
    vec += 2 * nc * itemsize
    return {"value": v, "index": ix, "vector": vec,
            "total": v + ix + vec}


def hierarchy_storage_bytes(setupd, itemsize: int = 8) -> dict:
    """Device-resident bytes of the solve-phase hierarchy at a value width.

    Splits ``{"operator", "transfer", "coarse", "total"}``: the level
    operators' ELL payloads+indices+dinv blocks, the transfer operators
    (P — and either a stored R duplicate or the transpose-free plan's two
    int32 streams, whichever the setup built), and the dense coarse
    factor.  This is the "prolongator-side hierarchy memory roughly
    halves" accounting: a transpose-free setup swaps R's value+index
    streams (``nnzb*(br*bc*itemsize + 4)``) for ``nnzb*(2*4 + 1)`` plan
    bytes (rows/gather int32 + the bool mask).
    """
    op = tr = 0
    for ls in setupd.levels:
        nbr, kmax = ls.a_ell_plan.indices.shape
        bs = ls.A0.br
        op += nbr * kmax * (bs * bs * itemsize + 4)     # a_ell data + idx
        op += nbr * bs * bs * itemsize                  # dinv blocks
        pe = ls.p_ell
        tr += pe.nbr * pe.kmax * (pe.br * pe.bc * itemsize + 4)
        if ls.r_ell is not None:
            re = ls.r_ell
            tr += re.nbr * re.kmax * (re.br * re.bc * itemsize + 4)
        else:
            nbc_t, tkmax = ls.pt.rows.shape
            tr += nbc_t * tkmax * (2 * 4 + 1)           # rows+gather+mask
    nc = setupd.coarse_struct.nbr * setupd.coarse_struct.br
    coarse = nc * nc * itemsize
    return {"operator": op, "transfer": tr, "coarse": coarse,
            "total": op + tr + coarse}


#: Rank-local HBM bytes whose streaming time equals one alpha of network
#: latency — the single model constant converting interior-apply work into
#: alpha units for the overlap accounting (order-of-magnitude: ~1 us of
#: latency over ~64 GB/s of effective local bandwidth).
ALPHA_BYTES = 64 * 1024


def dist_cycle_comm(dg, itemsize: int = 8,
                    alpha_bytes: int = ALPHA_BYTES) -> list:
    """Per-level, per-rank comm model of one distributed V-cycle.

    The latency-vs-bandwidth accounting behind coarse-level agglomeration
    (``repro.dist.solver``): every halo-window exchange is one *event*
    whose ppermutes run concurrently (one alpha of latency) and move
    ``exchanged_slabs`` messages; an all-gather is one event of
    ``ceil(log2(ndev))`` alphas (recursive doubling) moving ``ndev - 1``
    slab-messages.  Per sharded level and cycle: ``2*degree + 1`` operator
    applies (degree smoothing each side + the residual) plus one R and one
    P transfer; the sharded coarsest adds the solve-side rhs all-gather.
    A replicated level is one all-gather event at the switch (the boundary
    restriction) and *zero* everywhere else — prolongation back across the
    boundary is communication-free by construction.

    Overlap accounting (the ``REPRO_OVERLAP=on`` split apply): each window
    event's latency is charged as ``max(alpha_exchange, t_interior)`` —
    the exchange hides behind the interior rows' communication-free work.
    ``t_interior`` is the interior partition's modeled apply bytes (from
    the build-time split counts, the *minimum* over ranks — the rank with
    the least interior work hides the least) converted to alpha units via
    ``alpha_bytes``.  ``latency`` stays the blocking charge (back-compat);
    ``hidden_latency = sum_events min(alpha_event, t_interior)`` is what
    the overlap removes and ``eff_latency = latency - hidden_latency`` is
    what remains on the critical path.

    A 2-D ``ProcessMesh`` (``dg.mesh``) divides each rank's halo traffic
    and interior work by ``pc`` — the column ranks of one row group split
    the slab's boundary-facing streams.

    Returns one dict per level (+ the coarsest):
    ``{level, placement, msgs, latency, hidden_latency, eff_latency,
    halo_bytes, gather_bytes}`` — message count and latency are per rank
    per cycle, bytes split the neighbor-halo traffic from the all-gather
    traffic so benchmarks can report both levers separately.
    """
    ndev = dg.ndev
    pc = dg.mesh.pc if getattr(dg, "mesh", None) is not None else 1
    ag_lat = max(1, math.ceil(math.log2(max(ndev, 2))))
    degree = dg.degree
    rows = []
    ns = len(dg.levels)

    def event_lat(halo):
        """Alphas of one window exchange: ppermutes overlap (1), an
        allgather-fallback window is a full collective (ag_lat)."""
        if not halo.exchanged_slabs:
            return 0
        return ag_lat if halo.strategy == "allgather" else 1

    def interior_alphas(op):
        """Alpha-units of the interior partition's apply work per rank:
        the communication-free compute available to hide one exchange."""
        if op is None or op.int_counts is None or not op.int_counts.size:
            return 0.0
        icnt = int(op.int_counts.min())
        b = (icnt * op.kmax * (op.br * op.bc * itemsize + 4
                               + op.bc * itemsize)
             + icnt * op.br * itemsize)
        return (b / pc) / alpha_bytes

    for li, lv in enumerate(dg.levels):
        n_apply = 2 * degree + 1
        halo = lv.a_op.halo
        vec_bytes = halo.cpad * lv.bs * itemsize        # one exchanged slab
        msgs = n_apply * halo.exchanged_slabs
        lat = n_apply * event_lat(halo)
        hidden = n_apply * min(event_lat(halo), interior_alphas(lv.a_op))
        halo_bytes = msgs * vec_bytes
        gather_bytes = 0
        boundary = li == ns - 1 and dg.repl
        if boundary:
            # restriction crosses the switch: one all-gather of the fine
            # residual slabs; prolongation back is free (replicated halo).
            # The gather feeds a rank-redundant global apply — no interior
            # partition exists to hide it behind.
            msgs += ndev - 1
            lat += ag_lat
            gather_bytes += (ndev - 1) * lv.rpad * lv.bs * itemsize
        else:
            for t in (lv.r_op, lv.p_op):
                t_halo = t.halo
                # the windowed operand's slabs: (cpad, bc-block) vectors
                t_bytes = t_halo.cpad * t.bc * itemsize
                msgs += t_halo.exchanged_slabs
                lat += event_lat(t_halo)
                hidden += min(event_lat(t_halo), interior_alphas(t))
                halo_bytes += t_halo.exchanged_slabs * t_bytes
        rows.append(dict(level=li, placement="sharded", msgs=msgs,
                         latency=lat, hidden_latency=hidden,
                         eff_latency=lat - hidden,
                         halo_bytes=halo_bytes // pc,
                         gather_bytes=gather_bytes))
    for off, rl in enumerate(dg.repl):
        rows.append(dict(level=ns + off, placement="replicated", msgs=0,
                         latency=0, hidden_latency=0.0, eff_latency=0,
                         halo_bytes=0, gather_bytes=0))
    if dg.repl:
        rows.append(dict(level=dg.n_levels, placement="replicated",
                         msgs=0, latency=0, hidden_latency=0.0,
                         eff_latency=0, halo_bytes=0, gather_bytes=0))
    else:
        c = dg.coarse
        rows.append(dict(level=dg.n_levels, placement="sharded",
                         msgs=ndev - 1, latency=ag_lat,
                         hidden_latency=0.0, eff_latency=ag_lat,
                         halo_bytes=0,
                         gather_bytes=(ndev - 1) * c.rpad * c.bs
                         * itemsize))
    return rows
