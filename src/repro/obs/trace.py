"""Device-resident tracing: named scopes + the solve counter carry.

The device half of the observability layer (ISSUE 7).  Two opt-in
mechanisms, gated by one knob (``REPRO_OBS``, resolved by
``repro.kernels.backend.resolve_obs``):

``"spans"``     every kernel family (``block_spmv``, ``block_spmm``,
                ``pbjacobi``, ``fused_pair_gemm``, the pair/seg SpGEMM
                stages) and every V-cycle stage
                (``vcycle/level{i}/smooth|restrict|prolong``, ``coarse``)
                runs inside a ``jax.named_scope`` + profiler
                ``TraceAnnotation``, so a ``jax.profiler.trace`` capture
                reads as a legible per-level timeline instead of a wall
                of fused HLO.  Scopes are metadata only: the lowered
                computation is numerically identical, pinned bitwise by
                ``tests/test_obs.py``.

``"counters"``  spans *plus* a device-side ``CycleTally`` threaded
                through the ``pcg``/``block_pcg``/``vcycle`` carries:
                per-level visit counts, smoother applications, coarse
                solves, operator/preconditioner applications, and the
                modeled HBM bytes of the cycle
                (``repro.obs.model.vcycle_traffic``) multiplied in — so
                a converged ``CGResult.counters`` states exactly what the
                solve did and what it should have cost.

``"off"``       (default) both mechanisms vanish **at trace time**: the
                ``span`` helper returns a null context and no tally is
                threaded, so the jaxpr carries zero residue and nothing
                retraces — the same contract ``repro.robust.inject``
                pins for the fault hooks.

Mode is read at *trace* time (like the kernel-path knobs): programs
jitted while the mode was ``off`` keep their clean traces even if the
mode is flipped later — set ``REPRO_OBS`` (or enter ``use(...)``) before
building the solver under observation.
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

MODES = ("off", "spans", "counters")

#: Explicit override (``use`` context manager); ``None`` defers to the
#: ``REPRO_OBS`` env knob via ``backend.resolve_obs``.
_MODE: Optional[str] = None


def resolve(mode: Optional[str] = None) -> str:
    """Active observability mode: explicit arg > ``use`` scope > env."""
    from repro.kernels import backend
    if mode is not None:
        return backend.resolve_obs(mode)
    if _MODE is not None:
        return _MODE
    return backend.resolve_obs()


def spans_enabled(mode: Optional[str] = None) -> bool:
    return resolve(mode) in ("spans", "counters")


def counters_enabled(mode: Optional[str] = None) -> bool:
    return resolve(mode) == "counters"


@contextlib.contextmanager
def use(mode: str):
    """Scoped mode override (tests and ad-hoc profiling runs).

    Only affects programs *traced* inside the scope — a closure jitted
    before entry keeps its cached trace, mirroring ``inject.active``.
    """
    from repro.kernels import backend
    global _MODE
    prev = _MODE
    _MODE = backend.resolve_obs(mode)
    try:
        yield
    finally:
        _MODE = prev


def span(name: str, mode: Optional[str] = None):
    """Named scope around one solver stage (trace-time no-op when off).

    Inside a traced program this nests the stage under ``name`` in the
    XLA metadata/name stack, which is what ``jax.profiler`` renders as
    the per-level timeline; outside a trace it additionally opens a
    profiler ``TraceAnnotation`` so eager stages show up too.  With the
    mode off it returns a null context — zero jaxpr residue, nothing to
    retrace.
    """
    if not spans_enabled(mode):
        return contextlib.nullcontext()
    ctx = contextlib.ExitStack()
    ctx.enter_context(jax.named_scope(name))
    try:
        ctx.enter_context(jax.profiler.TraceAnnotation(name))
    except Exception:  # pragma: no cover - profiler backend missing
        pass
    return ctx


# ---------------------------------------------------------------------------
# Device-side counter carry
# ---------------------------------------------------------------------------

class CycleTally(NamedTuple):
    """Device-side solve counters, threaded through the Krylov carries.

    All int32 except ``modeled_bytes``; per-level arrays are indexed by
    hierarchy level (0 = finest).  Lives inside the jitted programs as
    ordinary carry state — reading it costs one host transfer *after*
    the solve, never a sync inside the loop.
    """

    level_visits: Array      # (n_levels,) down-leg visits per level
    smoother_applies: Array  # (n_levels,) smoother calls (pre + post)
    coarse_solves: Array     # ()  direct coarse solves
    operator_applies: Array  # ()  fine-operator applications (Krylov)
    precond_applies: Array   # ()  V-cycle invocations
    modeled_bytes: Array     # ()  modeled HBM bytes (vcycle_traffic model)


def zero_tally(n_levels: int) -> CycleTally:
    """Fresh all-zero tally for an ``n_levels``-deep hierarchy (the count
    includes the coarse level; per-level arrays cover the smoothed ones)."""
    nl = max(int(n_levels) - 1, 0)
    z = jnp.zeros((), jnp.int32)
    return CycleTally(level_visits=jnp.zeros((nl,), jnp.int32),
                      smoother_applies=jnp.zeros((nl,), jnp.int32),
                      coarse_solves=z, operator_applies=z,
                      precond_applies=z,
                      modeled_bytes=jnp.zeros((), jnp.float64)
                      if jax.config.jax_enable_x64
                      else jnp.zeros((), jnp.float32))


def attach_model_bytes(tally: CycleTally, cycle_bytes: float) -> CycleTally:
    """Fill ``modeled_bytes`` = preconditioner applications x the modeled
    per-cycle traffic (``repro.obs.model.vcycle_traffic(...)["total"]``).
    Pure and jittable — the gamg solve closures call it on exit."""
    total = tally.precond_applies.astype(tally.modeled_bytes.dtype) \
        * cycle_bytes
    return tally._replace(modeled_bytes=total)


def describe_tally(tally: CycleTally) -> str:
    """One human line (host-side; forces the transfer)."""
    import numpy as np
    lv = np.asarray(tally.level_visits)
    sm = np.asarray(tally.smoother_applies)
    return (f"precond={int(tally.precond_applies)} "
            f"op={int(tally.operator_applies)} "
            f"coarse={int(tally.coarse_solves)} "
            f"level_visits={lv.tolist()} smoother={sm.tolist()} "
            f"modeled_MB={float(tally.modeled_bytes) / 1e6:.2f}")


# ---------------------------------------------------------------------------
# Host-side spans for the distributed path
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def rank0_span(name: str, registry=None):
    """Host-side timing span emitted only on process rank 0.

    The dist solvers run inside ``shard_map`` where per-rank host work
    would desynchronize collectives; this span therefore wraps the
    *call site* (staging, the jitted shard_map invocation) on the host,
    and only rank 0 records — every other process runs the identical
    code path with recording skipped, so multi-process runs stay
    collective-safe by construction.  Always yields a ``stop(out)``
    callable that blocks on device output before the clock stops.
    """
    emit = jax.process_index() == 0 and spans_enabled()
    state = {"out": None}

    def stop(out):
        state["out"] = out
        return out

    t0 = time.perf_counter()
    try:
        yield stop
    finally:
        if emit:
            from repro.obs.metrics import block_ready, default_registry
            if state["out"] is not None:
                block_ready(state["out"])
            dt = time.perf_counter() - t0
            reg = registry if registry is not None else default_registry()
            reg.histogram(f"{name}/seconds",
                          help="rank-0 host span").observe(dt)


def wrap_threaded_precond(apply_m: Callable, precond_dtype,
                          outer_dtype) -> Callable:
    """Tally-threaded twin of ``repro.core.krylov.wrap_precond``:
    ``apply_m`` has signature ``(r, tally) -> (z, tally)`` and the
    mixed-precision boundary casts around it exactly like the untallied
    wrapper (bitwise no-op when the dtypes already agree)."""
    if precond_dtype is None:
        return apply_m
    pd = jnp.dtype(precond_dtype)
    outer = jnp.dtype(outer_dtype)
    if pd == outer:
        return apply_m

    def wrapped(r, tally):
        z, tally = apply_m(r.astype(pd), tally)
        return z.astype(outer), tally

    return wrapped
