"""End-to-end AMGSolveServer instrumentation (ISSUE 7).

``ServerMetrics`` is the host-side measurement surface the solve server
owns: every request's queue wait and end-to-end latency, every batch's
blocked solve wall time, padding efficiency per flush, recompute /
coefficient-update timing and per-status outcome counts — the numbers a
deployment dashboard needs to answer "is the reuse model paying off"
without touching a single traced program.

Always on: these are pure host clocks and Python counters around calls
the server already makes (and the solve wall clock blocks on results the
server was about to convert with ``np.asarray`` anyway), so they never
perturb the device programs — the ``REPRO_OBS=off`` zero-residue
contract lives entirely in ``repro.obs.trace`` and is untouched by this
module.

Instrument names (all under the server's private ``MetricsRegistry``):

========================================  ==========  ====================
name                                      kind        meaning
========================================  ==========  ====================
``server/queue_wait_seconds``             histogram   submit -> batch start
``server/solve_wall_seconds``             histogram   blocked panel solve
``server/request_latency_seconds``        histogram   submit -> report
                                                      (retries included)
``server/recompute_seconds``              histogram   ``update_operator``
``server/coeff_update_seconds``           histogram   ``update_coefficients``
``server/retry_seconds``                  histogram   ``_retry_column``
``server/padding_efficiency``             gauge       useful/total columns
                                                      (cumulative)
``server/pending``                        gauge       queue depth
``server/requests_total``                 counter     accepted submits
``server/rejected_total``                 counter     validation rejects
``server/batches_total``                  counter     panel solves
``server/padded_columns_total``           counter     padding columns
``server/solves_k{k}_total``              counter     per-bucket solves
``server/status_{s}_total``               counter     report outcomes
``server/iters``                          histogram   per-request iterations
========================================  ==========  ====================
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.obs.metrics import ITER_BUCKETS, MetricsRegistry

STATUSES = ("ok", "degraded", "failed", "recovered")


class ServerMetrics:
    """The solve server's measurement surface (one registry per server)."""

    def __init__(self, buckets: Sequence[int],
                 registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        r = self.registry
        self.queue_wait = r.histogram(
            "server/queue_wait_seconds",
            help="per-request wait from submit to its batch starting")
        self.solve_wall = r.histogram(
            "server/solve_wall_seconds",
            help="blocked wall time of one bucketed panel solve")
        self.request_latency = r.histogram(
            "server/request_latency_seconds",
            help="per-request submit-to-report latency, retries included")
        self.recompute_seconds = r.histogram(
            "server/recompute_seconds",
            help="blocked wall time of update_operator")
        self.coeff_update_seconds = r.histogram(
            "server/coeff_update_seconds",
            help="blocked wall time of update_coefficients")
        self.retry_seconds = r.histogram(
            "server/retry_seconds",
            help="blocked wall time of one flagged-column retry")
        self.iters = r.histogram(
            "server/iters", help="per-request CG iterations",
            buckets=ITER_BUCKETS)
        self.padding_efficiency = r.gauge(
            "server/padding_efficiency",
            help="useful columns / solved columns, cumulative over flushes")
        self.pending = r.gauge("server/pending", help="queue depth")
        self.requests = r.counter("server/requests_total",
                                  help="accepted submits")
        self.rejected = r.counter("server/rejected_total",
                                  help="submit validation rejects")
        self.batches = r.counter("server/batches_total", help="panel solves")
        self.padded_columns = r.counter("server/padded_columns_total",
                                        help="padding columns solved")
        self._useful_columns = 0
        self._total_columns = 0
        self._solves_k = {
            int(k): r.counter(f"server/solves_k{int(k)}_total",
                              help=f"panel solves at bucket width {int(k)}")
            for k in buckets}
        self._status = {
            s: r.counter(f"server/status_{s}_total",
                         help=f"requests reported {s}")
            for s in STATUSES}

    # ---- recording hooks the server calls --------------------------------
    def record_batch(self, k_bucket: int, n_requests: int,
                     solve_seconds: float) -> None:
        """One drained panel: bucket width, real request count, blocked
        solve wall time.  Updates the cumulative padding-efficiency gauge
        (useful columns / solved columns across the server's lifetime)."""
        self.batches.inc()
        self.solve_wall.observe(solve_seconds)
        self._solves_k[int(k_bucket)].inc()
        self.padded_columns.inc(int(k_bucket) - int(n_requests))
        self._useful_columns += int(n_requests)
        self._total_columns += int(k_bucket)
        if self._total_columns:
            self.padding_efficiency.set(
                self._useful_columns / self._total_columns)

    def record_request(self, status: str, iters: int, queue_wait_s: float,
                       latency_s: float) -> None:
        """One finished report.  ``latency_s`` is submit-to-report and must
        include any recovery retry the request triggered — the client
        waited through the retry, so its latency owns it."""
        self._status[status].inc()
        self.iters.observe(iters)
        self.queue_wait.observe(queue_wait_s)
        self.request_latency.observe(latency_s)

    # ---- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-dict summary (medians/p99 via the histograms' estimator)."""
        lat = self.request_latency
        return {
            "requests": self.requests.value(),
            "rejected": self.rejected.value(),
            "batches": self.batches.value(),
            "padded_columns": self.padded_columns.value(),
            "padding_efficiency": self.padding_efficiency.value(),
            "pending": self.pending.value(),
            "status": {s: c.value() for s, c in self._status.items()},
            "solves_per_k": {k: c.value()
                             for k, c in self._solves_k.items()},
            "latency_p50_s": lat.quantile(0.5),
            "latency_p99_s": lat.quantile(0.99),
            "solve_wall_p50_s": self.solve_wall.quantile(0.5),
            "queue_wait_p50_s": self.queue_wait.quantile(0.5),
        }

    def to_prometheus(self) -> str:
        return self.registry.to_prometheus()

    def to_jsonl(self, fileobj=None, timestamp: Optional[float] = None
                 ) -> str:
        return self.registry.to_jsonl(fileobj, timestamp)
