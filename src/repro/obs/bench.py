"""Benchmark regression tracker: schema-versioned results + baseline diff.

Wraps the paper-table benchmark driver (``benchmarks/run.py``) in a
machine-readable envelope: every table run lands in its own
``BENCH_<table>.json`` carrying the rows the table printed **plus** the
header a later reader needs to interpret them — schema version, machine
and platform, JAX version, active backend, ``PrecisionPolicy``, git
revision and timestamp.  ``compare_baseline`` diffs two such result
directories row by row and flags timing regressions beyond a threshold,
which is what the nightly CI job fails on.

The committed reference lives in ``benchmarks/baselines/`` (quick-mode
numbers from the machine that produced them; CI compares with a lenient
threshold because container-to-container variance is real).

CLI (run from the repo root so ``benchmarks`` imports)::

    PYTHONPATH=src python -m repro.obs.bench run --out bench_out --quick
    PYTHONPATH=src python -m repro.obs.bench compare \
        --baseline benchmarks/baselines --current bench_out --threshold 0.5
    PYTHONPATH=src python -m repro.obs.bench update-baseline --quick
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import time
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

#: Per-table quick-mode kwargs: the same code paths at CI-sized problems.
TABLES: Dict[str, dict] = {
    "table1_weak_scaling": {"ladder": (5, 6)},
    "table2_backends": {"m": 6},
    "table3_ptap_ablation": {"m": 6},
    "table4_nnz_row": {"sizes": ((1, 6), (2, 4))},
    "table5_traffic": {"ladder": (5, 6)},
    "table6_multirhs": {"m": 5, "ks": (1, 2, 4)},
    "table7_assembly": {"m": 5},
    "table8_march": {"m": 4, "n_steps": 3},
}

DEFAULT_BASELINE_DIR = os.path.join("benchmarks", "baselines")


def git_rev() -> str:
    """Current commit hash, or "unknown" outside a work tree."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def result_header() -> dict:
    """The context every ``BENCH_*.json`` must carry to be comparable."""
    import jax
    from repro.kernels.backend import backend, resolve_precision
    policy = resolve_precision(None)
    return {
        "schema_version": SCHEMA_VERSION,
        "git_rev": git_rev(),
        "timestamp": time.time(),
        "machine": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax_version": jax.__version__,
        "backend": backend(),
        "precision_policy": {
            "describe": policy.describe(),
            "krylov_dtype": str(policy.krylov_dtype),
            "hierarchy_dtype": str(policy.hierarchy_dtype),
            "smoother_dtype": str(policy.smoother_dtype),
            "accum_dtype": str(policy.accum_dtype),
        },
    }


def run_tables(out_dir: str, quick: bool = False,
               tables: Optional[List[str]] = None) -> List[str]:
    """Run the requested table benchmarks, one ``BENCH_<table>.json`` each.

    Rows are captured through ``benchmarks.common.recording`` (the same
    ``emit`` lines the CSV run prints).  A table that *raises* still
    produces a result file, with ``"error"`` set — a nightly must be able
    to tell "regressed" from "did not run".  Returns the written paths.
    """
    import importlib
    from benchmarks import common as bench_common

    names = list(TABLES) if tables is None else list(tables)
    unknown = [n for n in names if n not in TABLES]
    if unknown:
        raise ValueError(f"unknown benchmark tables {unknown}: "
                         f"expected names from {sorted(TABLES)}")
    os.makedirs(out_dir, exist_ok=True)
    header = result_header()
    header["quick"] = bool(quick)
    paths = []
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        kwargs = TABLES[name] if quick else {}
        error = None
        t0 = time.perf_counter()
        with bench_common.recording() as rows:
            try:
                mod.run(**kwargs)
            except Exception as e:  # keep the run alive; record the loss
                error = f"{type(e).__name__}: {e}"
        doc = {
            "table": name,
            "header": header,
            "wall_seconds": time.perf_counter() - t0,
            "rows": [{"name": n, "us": us, "derived": d}
                     for n, us, d in rows],
            "error": error,
        }
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        paths.append(path)
        print(f"[bench] wrote {path} ({len(doc['rows'])} rows"
              + (f", ERROR: {error}" if error else "") + ")", flush=True)
    return paths


def _load_results(directory: str) -> Dict[str, dict]:
    out = {}
    for fn in sorted(os.listdir(directory)):
        if fn.startswith("BENCH_") and fn.endswith(".json"):
            with open(os.path.join(directory, fn)) as f:
                doc = json.load(f)
            out[doc["table"]] = doc
    if not out:
        raise FileNotFoundError(f"no BENCH_*.json results in {directory!r}")
    return out


def compare_baseline(current_dir: str,
                     baseline_dir: str = DEFAULT_BASELINE_DIR,
                     threshold: float = 0.15,
                     min_us: float = 200.0) -> List[dict]:
    """Row-by-row timing diff of two result directories.

    A row regresses when ``current > baseline * (1 + threshold)`` and the
    baseline is above the ``min_us`` noise floor (sub-floor rows are
    dispatch-overhead-dominated and flap).  Rows are matched by name
    within each table; a row or table missing from ``current`` is itself
    reported (a silently vanished benchmark must not read as "no
    regressions"), as is a table that recorded an ``error``.  Returns the
    list of findings (empty = clean); raising is the CLI's job.
    """
    base = _load_results(baseline_dir)
    cur = _load_results(current_dir)
    findings: List[dict] = []
    for table, bdoc in sorted(base.items()):
        cdoc = cur.get(table)
        if cdoc is None:
            findings.append({"table": table, "kind": "missing_table"})
            continue
        if cdoc.get("error"):
            findings.append({"table": table, "kind": "error",
                             "error": cdoc["error"]})
            continue
        crows = {r["name"]: r for r in cdoc["rows"]}
        for brow in bdoc["rows"]:
            crow = crows.get(brow["name"])
            if crow is None:
                findings.append({"table": table, "kind": "missing_row",
                                 "name": brow["name"]})
                continue
            b_us, c_us = float(brow["us"]), float(crow["us"])
            if b_us < min_us:
                continue
            if c_us > b_us * (1.0 + threshold):
                findings.append({
                    "table": table, "kind": "regression",
                    "name": brow["name"], "baseline_us": b_us,
                    "current_us": c_us,
                    "ratio": c_us / b_us if b_us else float("inf")})
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="repro.obs.bench",
        description="benchmark regression tracker (BENCH_*.json)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    runp = sub.add_parser("run", help="run tables, write BENCH_*.json")
    runp.add_argument("--out", default="bench_out")
    runp.add_argument("--quick", action="store_true",
                      help="CI-sized problems (same code paths)")
    runp.add_argument("--tables", nargs="*", default=None,
                      metavar="TABLE", help=f"subset of {sorted(TABLES)}")

    cmp_ = sub.add_parser("compare", help="diff results against a baseline")
    cmp_.add_argument("--current", default="bench_out")
    cmp_.add_argument("--baseline", default=DEFAULT_BASELINE_DIR)
    cmp_.add_argument("--threshold", type=float, default=0.15,
                      help="relative slowdown that counts as a regression")
    cmp_.add_argument("--min-us", type=float, default=200.0,
                      help="noise floor: skip rows with baseline below this")

    upd = sub.add_parser("update-baseline",
                         help="re-run quick tables into the baseline dir")
    upd.add_argument("--out", default=DEFAULT_BASELINE_DIR)
    upd.add_argument("--quick", action="store_true", default=True)

    args = ap.parse_args(argv)
    if args.cmd == "run":
        run_tables(args.out, quick=args.quick, tables=args.tables)
        return 0
    if args.cmd == "update-baseline":
        run_tables(args.out, quick=True)
        return 0
    findings = compare_baseline(args.current, baseline_dir=args.baseline,
                                threshold=args.threshold,
                                min_us=args.min_us)
    for f in findings:
        print(f"[bench] {json.dumps(f, sort_keys=True)}")
    if findings:
        print(f"[bench] {len(findings)} finding(s) vs baseline "
              f"{args.baseline!r} at threshold {args.threshold:.0%}")
        return 1
    print("[bench] no regressions vs baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
