"""Process-local solver metrics: counters, gauges, histograms, timers.

The host half of the observability layer (ISSUE 7).  A jitted JAX stack
hides its hot paths behind traced programs, so the instrumentation the
paper's measurements rest on — per-phase wall time, request latency,
padding efficiency — must be designed in rather than sampled in: every
span here blocks on device results (``block_until_ready``) before it
stops its clock, and the first observation of a phase is recorded
separately so trace/compile time never pollutes the steady-state
distribution.

Three instrument kinds, Prometheus-shaped:

* ``Counter``   — monotone float (requests served, faults detected);
* ``Gauge``     — last-write-wins float (padding efficiency, queue depth);
* ``Histogram`` — cumulative-bucket distribution with solver-scale
                  default buckets (1 us .. 100 s, log-spaced), plus
                  ``sum``/``count`` so rates and means survive export.

Two exporters:

* ``MetricsRegistry.to_jsonl``       — one JSON object per instrument
  line, append-friendly (a long-running server dumps snapshots into one
  growing file a dashboard tails);
* ``MetricsRegistry.to_prometheus``  — the text exposition format
  (``# TYPE``/``# HELP``, ``_bucket{le=...}``/``_sum``/``_count``),
  round-trippable through ``parse_prometheus`` (pinned by
  ``tests/test_obs.py``).

Everything here is host-side and registry-local: importing or using this
module never touches a traced program — the device-side contract
(zero jaxpr residue under ``REPRO_OBS=off``) lives in ``repro.obs.trace``.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple

import jax

#: Default histogram buckets for solver-scale wall times, in seconds:
#: log-spaced from 1 us (a cached scalar op) to 100 s (a cold multi-level
#: setup trace), ~4 buckets per decade.
SOLVER_TIME_BUCKETS: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 4.0), 12) for e in range(-24, 9))

#: Buckets for iteration-count-like quantities.
ITER_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)


def _label_key(labels: Optional[dict]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    """Monotone counter.  ``inc`` rejects negative deltas loudly — a
    decreasing counter silently breaks every rate() a dashboard computes."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: Dict[Tuple, float] = {}

    def inc(self, value: float = 1.0, labels: Optional[dict] = None) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc by {value})")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + float(value)

    def value(self, labels: Optional[dict] = None) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self) -> Dict[Tuple, float]:
        return dict(self._values)


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: Dict[Tuple, float] = {}

    def set(self, value: float, labels: Optional[dict] = None) -> None:
        self._values[_label_key(labels)] = float(value)

    def value(self, labels: Optional[dict] = None) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self) -> Dict[Tuple, float]:
        return dict(self._values)


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "min", "max")

    def __init__(self, nbuckets: int):
        self.counts = [0] * nbuckets   # per-bucket (non-cumulative) counts
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf


class Histogram:
    """Fixed-bucket histogram (upper bounds + implicit +Inf overflow).

    Stores *non-cumulative* per-bucket counts internally; the Prometheus
    exporter emits the cumulative ``le`` convention.  ``quantile`` gives
    the classic linear-in-bucket estimate — good enough for an SLO line,
    explicitly not an exact order statistic.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = SOLVER_TIME_BUCKETS):
        self.name, self.help = name, help
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError(f"histogram {name} needs at least one bucket")
        if len(set(bs)) != len(bs):
            raise ValueError(f"duplicate histogram buckets for {name}: {bs}")
        self.buckets: Tuple[float, ...] = tuple(bs)
        self._series: Dict[Tuple, _HistSeries] = {}

    def _get(self, labels: Optional[dict]) -> _HistSeries:
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.buckets) + 1)
        return s

    def observe(self, value: float, labels: Optional[dict] = None) -> None:
        v = float(value)
        s = self._get(labels)
        # first bucket whose upper bound holds v; the trailing slot is +Inf
        lo, hi = 0, len(self.buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        s.counts[lo] += 1
        s.sum += v
        s.count += 1
        s.min = min(s.min, v)
        s.max = max(s.max, v)

    def snapshot(self, labels: Optional[dict] = None) -> dict:
        s = self._get(labels)
        return {"count": s.count, "sum": s.sum,
                "min": None if s.count == 0 else s.min,
                "max": None if s.count == 0 else s.max,
                "buckets": dict(zip(list(self.buckets) + [math.inf],
                                    s.counts))}

    def quantile(self, q: float, labels: Optional[dict] = None) -> float:
        """Linear-in-bucket quantile estimate (NaN on an empty series)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        s = self._get(labels)
        if s.count == 0:
            return math.nan
        rank = q * s.count
        seen = 0.0
        prev_bound = 0.0
        for i, c in enumerate(s.counts):
            if seen + c >= rank and c > 0:
                bound = (self.buckets[i] if i < len(self.buckets)
                         else s.max)
                frac = (rank - seen) / c
                return prev_bound + frac * (bound - prev_bound)
            seen += c
            if i < len(self.buckets):
                prev_bound = self.buckets[i]
        return s.max

    def series(self) -> Dict[Tuple, _HistSeries]:
        return dict(self._series)


def block_ready(out):
    """Block until every device array in ``out`` is computed — the only
    honest clock stop for a timed span over lazily executed JAX calls."""
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready()
        if hasattr(x, "block_until_ready") else x, out)
    return out


class Timer:
    """Wall-clock span recording into a histogram on exit.

    Use ``block(out)`` on the device results produced inside the span —
    async dispatch means the Python line finishes long before the device
    does, and an unblocked span times the *enqueue*, not the solve.

        with registry.timer("solve_wall") as t:
            res = solve(hier, b)
            t.block(res)
    """

    def __init__(self, hist: Histogram, labels: Optional[dict] = None):
        self._hist = hist
        self._labels = labels
        self.seconds: Optional[float] = None

    def block(self, out):
        return block_ready(out)

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = time.perf_counter() - self._t0
        if exc_type is None:
            self._hist.observe(self.seconds, labels=self._labels)


class MetricsRegistry:
    """Process-local named-instrument registry (thread-safe creation).

    One registry per concern (a server owns one, a benchmark run owns
    one); ``default_registry()`` is the shared process-wide fallback the
    ad-hoc spans in the dist path use.
    """

    def __init__(self):
        self._instruments: Dict[str, object] = {}
        self._lock = threading.Lock()
        self._seen_phases: set = set()

    def _make(self, name: str, cls, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, **kw)
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._make(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._make(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = SOLVER_TIME_BUCKETS
                  ) -> Histogram:
        return self._make(name, Histogram, help=help, buckets=buckets)

    def timer(self, name: str, help: str = "",
              labels: Optional[dict] = None) -> Timer:
        return Timer(self.histogram(name, help=help), labels=labels)

    def instruments(self) -> List[object]:
        with self._lock:
            return list(self._instruments.values())

    def get(self, name: str):
        return self._instruments.get(name)

    # ---- trace/compile vs steady-state ----------------------------------
    def measure(self, name: str, fn, *args, labels: Optional[dict] = None):
        """Run ``fn(*args)`` fully blocked, filing the duration under
        ``{name}/compile`` on the *first* observation of ``name`` (+labels)
        and ``{name}/steady`` afterwards.

        The split is the JAX-specific timing discipline the benchmarks
        already apply by hand (warmup before best-of): the first call
        through a jitted closure pays trace + compile, which can be 1000x
        the steady-state time — folding it into one histogram makes both
        numbers meaningless.  Returns ``fn``'s (blocked) result.
        """
        key = (name, _label_key(labels))
        first = key not in self._seen_phases
        self._seen_phases.add(key)
        suffix = "/compile" if first else "/steady"
        with self.timer(name + suffix, labels=labels) as t:
            out = fn(*args)
            t.block(out)
        return out

    # ---- exporters -------------------------------------------------------
    def to_jsonl(self, fileobj=None, timestamp: Optional[float] = None
                 ) -> str:
        """One JSON object per instrument (per label set), newline-joined.

        Appends to ``fileobj`` when given (the sink idiom of
        ``examples/observe_amg.py``); always returns the text.
        """
        ts = time.time() if timestamp is None else timestamp
        lines = []
        for inst in self.instruments():
            if isinstance(inst, (Counter, Gauge)):
                for key, val in inst.series().items():
                    lines.append(json.dumps(
                        {"ts": ts, "name": inst.name, "type": inst.kind,
                         "labels": dict(key), "value": val},
                        sort_keys=True))
            else:
                for key in inst.series():
                    snap = inst.snapshot(dict(key))
                    lines.append(json.dumps(
                        {"ts": ts, "name": inst.name, "type": inst.kind,
                         "labels": dict(key), "count": snap["count"],
                         "sum": snap["sum"], "min": snap["min"],
                         "max": snap["max"],
                         "buckets": {str(k): v for k, v
                                     in snap["buckets"].items()}},
                        sort_keys=True))
        text = "\n".join(lines)
        if fileobj is not None and text:
            fileobj.write(text + "\n")
        return text

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, version 0.0.4."""
        out = []
        for inst in self.instruments():
            name = _prom_name(inst.name)
            if inst.help:
                out.append(f"# HELP {name} {inst.help}")
            out.append(f"# TYPE {name} {inst.kind}")
            if isinstance(inst, (Counter, Gauge)):
                for key, val in sorted(inst.series().items()):
                    out.append(f"{name}{_label_str(key)} {_fmt(val)}")
            else:
                for key, s in sorted(inst.series().items()):
                    cum = 0
                    for i, bound in enumerate(inst.buckets):
                        cum += s.counts[i]
                        lab = _label_str(key + (("le", _fmt(bound)),))
                        out.append(f"{name}_bucket{lab} {cum}")
                    cum += s.counts[-1]
                    lab = _label_str(key + (("le", "+Inf"),))
                    out.append(f"{name}_bucket{lab} {cum}")
                    out.append(f"{name}_sum{_label_str(key)} {_fmt(s.sum)}")
                    out.append(f"{name}_count{_label_str(key)} {s.count}")
        return "\n".join(out) + "\n"


def _prom_name(name: str) -> str:
    """Metric names here use '/' for phase nesting; Prometheus only
    allows [a-zA-Z0-9_:], so slashes and dashes export as '_'."""
    return "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def parse_prometheus(text: str) -> dict:
    """Parse the exposition text back into ``{name: {labels_str: value}}``.

    Only what ``to_prometheus`` emits (the round-trip test's other half) —
    not a general Prometheus parser.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_labels, _, value = line.rpartition(" ")
        if "{" in name_labels:
            name, _, rest = name_labels.partition("{")
            labels = "{" + rest
        else:
            name, labels = name_labels, ""
        v = math.inf if value == "+Inf" else float(value)
        out.setdefault(name, {})[labels] = v
    return out


_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The shared process-local registry (lazily created)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = MetricsRegistry()
        return _DEFAULT


def reset_default_registry() -> None:
    """Drop the shared registry (tests isolate themselves with this)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = None
