"""repro.obs — solver observability: metrics, tracing, regression tracking.

Four layers (ISSUE 7):

* ``metrics``        process-local ``MetricsRegistry`` (counters, gauges,
                     solver-scale histograms), compile/steady-aware
                     ``Timer`` spans, JSONL + Prometheus exporters;
* ``trace``          ``jax.named_scope`` spans on every kernel family and
                     V-cycle stage, plus the opt-in device-side
                     ``CycleTally`` counter carry — both trace-time
                     no-ops under ``REPRO_OBS=off`` (zero jaxpr residue);
* ``model``          the analytic HBM-traffic / dist-comm byte models
                     (moved from ``benchmarks/common``) the live counters
                     are validated against;
* ``server_metrics`` end-to-end ``AMGSolveServer`` instrumentation
                     (queue wait, solve wall, padding efficiency, health
                     statuses) behind ``server.metrics()``/``snapshot()``;
* ``bench``          the schema-versioned ``BENCH_*.json`` regression
                     tracker wrapping ``benchmarks/run.py``.

Knob: ``REPRO_OBS=off|spans|counters`` (default off), resolved by
``repro.kernels.backend.resolve_obs`` at trace time.
"""
from repro.obs.metrics import (          # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Timer,
    default_registry,
    parse_prometheus,
)
from repro.obs.server_metrics import ServerMetrics   # noqa: F401
from repro.obs.trace import (            # noqa: F401
    CycleTally,
    attach_model_bytes,
    counters_enabled,
    describe_tally,
    span,
    spans_enabled,
    use,
    zero_tally,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "ServerMetrics",
    "Timer", "default_registry", "parse_prometheus", "CycleTally",
    "attach_model_bytes", "counters_enabled", "describe_tally", "span",
    "spans_enabled", "use", "zero_tally",
]
