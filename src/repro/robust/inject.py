"""Deterministic, schedule-driven fault injection (the testing harness).

The health monitoring and the recovery ladder need failures on demand:
this module plants NaN/Inf values or bit-flips into well-defined sites of
the solver stack, deterministically, inside the jitted programs.

Sites (each hot loop calls ``maybe(site, x, ...)`` at these points):

=============  ============================================================
``"spmv"``     operator-apply output in ``pcg``/``block_pcg``/``_rank_pcg``
               (step-gated: fires at CG iteration ``step``)
``"precond"``  preconditioner (V-cycle) output in the same loops
               (step-gated)
``"vcycle"``   restricted residual inside the V-cycle (level-gated)
``"coarse"``   coarse-level direct-solve output inside the V-cycle
``"hierarchy"``level operator payloads inside ``gamg.recompute``
               (level-gated; the coarsest payload is level ``n_levels-1``)
``"halo"``     dist halo-exchange windows — the site lives in
               ``repro.dist.pamg.finish_halo_exchange`` on the *assembled*
               ppermute/allgather window, so it fires identically on the
               blocking path (``halo_window``) and on the overlapped split
               path (where the corrupted window feeds
               ``dist_ell_apply_boundary``); fires on every exchange
=============  ============================================================

Zero-overhead contract: with no schedule installed, ``maybe`` returns its
input *at trace time* — the healthy jaxpr is bitwise identical to an
uninstrumented build and nothing retraces (pinned by
``tests/test_robust.py``).  Installing or clearing a schedule changes
what new traces contain; programs jitted *before* ``install`` keep their
(clean) traces, so a schedule must be installed before the solver under
test is built.

Determinism: a fault is a pure function of (site, step/level, index) —
no RNG, no wall clock — so a faulted run is exactly reproducible, which
is what lets the battery assert detection instead of flakiness.

``REPRO_FAULTS`` env knob (parsed at import): semicolon-separated specs
``site:kind[@step][:level=N][:index=N][:persistent]``, e.g.
``REPRO_FAULTS="precond:nan@3;halo:bitflip:index=7"``.  Faults default to
*transient* (the recovery ladder's retries run with them suppressed —
the SDC model of a one-off flipped bit); ``:persistent`` keeps a fault
live across retries, forcing the explicit-``failed`` path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

KINDS = ("nan", "inf", "bitflip")
SITES = ("spmv", "precond", "vcycle", "coarse", "hierarchy", "halo")

_UINT = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


@dataclasses.dataclass(frozen=True)
class Fault:
    """One deterministic corruption.

    ``step``/``level`` gate step-aware and level-aware sites; a gate of
    ``None`` (or a site that carries no counter) fires unconditionally.
    ``index`` is the flat element index corrupted (modulo the array size,
    so any index is valid for any site).
    """

    site: str
    kind: str
    step: Optional[int] = None
    level: Optional[int] = None
    index: int = 0
    transient: bool = True

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"invalid fault site {self.site!r}: "
                             f"expected one of {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"invalid fault kind {self.kind!r}: "
                             f"expected one of {KINDS}")

    def corrupt(self, x: Array, step) -> Array:
        """Corrupted copy of ``x``; gated on ``step`` when both sides
        carry one.  jit-compatible (runs inside while_loop bodies)."""
        flat = x.reshape(-1)
        idx = self.index % flat.shape[0]
        if self.kind == "bitflip":
            uint = _UINT[jnp.dtype(x.dtype).itemsize]
            bits = lax.bitcast_convert_type(flat[idx], uint)
            # flip the exponent MSB: a small value becomes a huge one —
            # the classic silent-data-corruption rendering of an SEU
            flipped = bits ^ jnp.asarray(
                1 << (8 * jnp.dtype(x.dtype).itemsize - 2), uint)
            bad_val = lax.bitcast_convert_type(flipped, x.dtype)
        else:
            bad_val = jnp.asarray(
                jnp.nan if self.kind == "nan" else jnp.inf, x.dtype)
        bad = flat.at[idx].set(bad_val).reshape(x.shape)
        if self.step is None or step is None:
            return bad
        return jnp.where(jnp.asarray(step) == self.step, bad, x)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An ordered set of faults; applied wherever site/level match."""

    faults: Tuple[Fault, ...]

    def apply(self, site: str, x: Array, step=None, level=None) -> Array:
        for f in self.faults:
            if f.site != site:
                continue
            if f.level is not None and level is not None \
                    and f.level != level:
                continue
            x = f.corrupt(x, step)
        return x

    def without_transient(self) -> Optional["FaultSchedule"]:
        keep = tuple(f for f in self.faults if not f.transient)
        return FaultSchedule(keep) if keep else None


def parse_schedule(spec: str) -> FaultSchedule:
    """Parse the ``REPRO_FAULTS`` mini-language (module docstring)."""
    faults = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        if len(fields) < 2:
            raise ValueError(
                f"invalid fault spec {part!r}: expected "
                f"site:kind[@step][:level=N][:index=N][:persistent]")
        site = fields[0].strip()
        kind = fields[1].strip()
        step = None
        if "@" in kind:
            kind, step_s = kind.split("@", 1)
            step = int(step_s)
        kw = dict(site=site, kind=kind, step=step)
        for opt in fields[2:]:
            opt = opt.strip()
            if opt == "persistent":
                kw["transient"] = False
            elif "=" in opt:
                key, val = opt.split("=", 1)
                if key not in ("level", "index"):
                    raise ValueError(f"invalid fault option {opt!r} in "
                                     f"{part!r}")
                kw[key] = int(val)
            else:
                raise ValueError(f"invalid fault option {opt!r} in {part!r}")
        faults.append(Fault(**kw))
    if not faults:
        raise ValueError(f"empty fault spec {spec!r}")
    return FaultSchedule(tuple(faults))


# ---------------------------------------------------------------------------
# The (single, module-global) active schedule
# ---------------------------------------------------------------------------

_SCHEDULE: Optional[FaultSchedule] = None


def install(schedule: Optional[FaultSchedule]) -> None:
    """Activate a schedule for *subsequently traced* programs."""
    global _SCHEDULE
    if schedule is not None and not isinstance(schedule, FaultSchedule):
        raise ValueError(f"expected a FaultSchedule or None, got "
                         f"{schedule!r}")
    _SCHEDULE = schedule


def clear() -> None:
    install(None)


def current() -> Optional[FaultSchedule]:
    return _SCHEDULE


@contextlib.contextmanager
def active(schedule: FaultSchedule):
    """Scoped installation — the battery's idiom (always restores)."""
    prev = _SCHEDULE
    install(schedule)
    try:
        yield schedule
    finally:
        install(prev)


@contextlib.contextmanager
def suppress_transient():
    """Scoped transient-fault suppression: the recovery ladder's retries
    run under this, modelling one-off corruption (persistent faults stay
    live and force the explicit-``failed`` path)."""
    prev = _SCHEDULE
    if prev is not None:
        install(prev.without_transient())
    try:
        yield
    finally:
        install(prev)


def maybe(site: str, x: Array, *, step=None, level=None) -> Array:
    """The hook the hot loops call.  Identity (at trace time — zero jaxpr
    residue) unless a schedule is installed."""
    if _SCHEDULE is None:
        return x
    return _SCHEDULE.apply(site, x, step=step, level=level)


# env knob: a set REPRO_FAULTS arms the schedule for the whole process
# (the dist selftest's REPRO_SELFTEST_FAULT sections and ad-hoc runs);
# tier-1 never sets it, so tier-1 traces stay injection-free.
_env_spec = os.environ.get("REPRO_FAULTS")
if _env_spec:
    install(parse_schedule(_env_spec))
del _env_spec
