"""Policy-driven breakdown recovery — the escalation ladder.

When a monitored solve comes back flagged (``SolveHealth.status != 0``),
the failure is usually one of four things, each with a cheapest-possible
fix.  ``RobustSolver`` walks them in order, bounded by
``RecoveryPolicy.max_attempts``:

``"recompute"``
    transient fault / corrupted or stale hierarchy.  Rebuild the jitted
    closures *fresh* and recompute the hierarchy from the stored fine
    operator values.  Retries run under ``inject.suppress_transient()``:
    injection is baked into traces at trace time, so a fresh trace is
    clean of transient faults — the SDC model of a one-off flipped bit —
    while *persistent* faults survive and force the explicit-``failed``
    path.

``"re-setup"``
    corrupted symbolic state (aggregation, prolongator smoothing, PtAP
    plans).  Run the full cold ``gamg.setup`` again from the stored
    operator and rebuild everything above it.

``"f64-rebuild"``
    reduced-precision breakdown: an fp32/bf16-resident hierarchy whose
    V-cycle went indefinite (the classic ``BREAKDOWN`` source).  Re-setup
    at full fp64 via ``PrecisionPolicy.double()`` — slower, but the
    bitwise-legacy configuration that is known-good.

``"reference-path"``
    suspected fused-kernel miscompile.  Rebuild with the kernel dispatch
    forced to the jnp reference paths (``REPRO_SPGEMM_PATH=reference``,
    ``REPRO_SPMM_PATH=reference`` — the ``repro.kernels.backend``
    resolvers re-read the env per call, so scoping the env around the
    rung's tracing is sufficient and process-global state is restored
    after).

A recovered solve reports ``"recovered"``; an exhausted ladder reports
``"degraded"`` when the best iterate still made progress
(finite ``best_relres < 1`` — the minimum-residual iterate is returned,
never a diverged or NaN one) and ``"failed"`` otherwise (the solution is
zeroed: an explicit failure must never look like an answer).

``REPRO_RECOVER`` env knob (via ``repro.kernels.backend.resolve_recover``):
``off`` disables the ladder, ``on`` enables the defaults, an integer sets
``max_attempts``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import gamg
from repro.core.krylov import CGResult
from repro.core.precision import PrecisionPolicy
from repro.robust import inject
from repro.robust.health import HEALTHY, STATUS_NAMES, hierarchy_finite


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Which rungs the ladder may climb, and how many in total."""

    max_attempts: int = 3
    allow_recompute: bool = True
    allow_resetup: bool = True
    allow_f64_rebuild: bool = True
    allow_reference_path: bool = True

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")


@dataclasses.dataclass
class RecoverOutcome:
    """One ladder-mediated solve.

    ``status``: ``"ok"`` (healthy first try), ``"recovered"`` (a rung
    fixed it), ``"degraded"`` (exhausted, best iterate returned) or
    ``"failed"`` (exhausted, no usable iterate — ``result.x`` is zeroed).
    ``attempts`` lists the rung names tried, in order.
    """

    status: str
    result: CGResult
    attempts: Tuple[str, ...] = ()

    @property
    def x(self):
        return self.result.x


@contextlib.contextmanager
def _env_scope(overrides: dict):
    """Scoped os.environ overrides (the backend resolvers re-read per
    call, so scoping the env around a rung's tracing is sufficient)."""
    saved = {k: os.environ.get(k) for k in overrides}
    os.environ.update(overrides)
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


class RobustSolver:
    """``GAMGSolver`` with health-gated solves and the recovery ladder.

    Same front door (setup once, ``update_operator`` hot, ``solve`` many)
    but ``solve`` returns a ``RecoverOutcome`` whose ``result`` is the
    underlying ``CGResult``.  The healthy path is exactly one monitored
    solve on the cached jitted closures — the ladder only wakes up on a
    flagged result.
    """

    def __init__(self, A, B, *, recovery: Optional[RecoveryPolicy] = None,
                 rtol: float = 1e-8, maxiter: int = 200, **setup_opts):
        from repro.kernels.backend import resolve_recover
        self._A = A
        self._B = jnp.asarray(B)
        self.recovery = resolve_recover(recovery) or RecoveryPolicy()
        self._rtol = rtol
        self._maxiter = maxiter
        self._setup_opts = dict(setup_opts)
        self._a_fine_data = jnp.asarray(A.data)
        self.n_recoveries = 0
        self.last_attempts: Tuple[str, ...] = ()
        self._stage(self._setup_opts)

    # ---- staging (everything a rung may need to rebuild) ----------------
    def _stage(self, setup_opts: dict) -> None:
        """Cold setup + fresh jitted closures + hierarchy recompute."""
        self.setupd = gamg.setup(self._A.with_data(self._a_fine_data),
                                 self._B, **setup_opts)
        self._recompute = gamg.make_recompute(self.setupd)
        self._solve = gamg.make_solve(self.setupd, rtol=self._rtol,
                                      maxiter=self._maxiter)
        self.hierarchy = self._recompute(self._a_fine_data)

    def _refresh(self) -> None:
        """Fresh traces + hierarchy from the *existing* setup."""
        self._recompute = gamg.make_recompute(self.setupd)
        self._solve = gamg.make_solve(self.setupd, rtol=self._rtol,
                                      maxiter=self._maxiter)
        self.hierarchy = self._recompute(self._a_fine_data)

    # ---- operator lifecycle ---------------------------------------------
    def update_operator(self, a_fine_data) -> None:
        self._a_fine_data = jnp.asarray(a_fine_data)
        self.hierarchy = self._recompute(self._a_fine_data)

    # ---- the ladder ------------------------------------------------------
    def _rungs(self):
        pol = self.recovery
        rungs = []
        if pol.allow_recompute:
            rungs.append(("recompute", {}, self._refresh))
        if pol.allow_resetup:
            rungs.append(("re-setup", {},
                          lambda: self._stage(self._setup_opts)))
        if pol.allow_f64_rebuild and \
                self.setupd.precision != PrecisionPolicy.double():
            opts = dict(self._setup_opts, precision="f64")
            rungs.append(("f64-rebuild", {}, lambda: self._stage(opts)))
        if pol.allow_reference_path:
            env = {"REPRO_SPGEMM_PATH": "reference",
                   "REPRO_SPMM_PATH": "reference"}
            rungs.append(("reference-path", env,
                          lambda: self._stage(self._setup_opts)))
        return rungs[:pol.max_attempts]

    def solve(self, b) -> RecoverOutcome:
        b = jnp.asarray(b)
        res = self._solve(self.hierarchy, b)
        if int(np.asarray(res.health.status)) == HEALTHY:
            self.last_attempts = ()
            return RecoverOutcome("ok", res)
        attempts = []
        best = res
        for name, env, rebuild in self._rungs():
            attempts.append(name)
            # fresh traces under suppress_transient: one-off faults are
            # gone from the rebuilt programs, persistent ones survive
            with _env_scope(env), inject.suppress_transient():
                rebuild()
                res = self._solve(self.hierarchy, b)
            if int(np.asarray(res.health.status)) == HEALTHY:
                self.n_recoveries += 1
                self.last_attempts = tuple(attempts)
                return RecoverOutcome("recovered", res, tuple(attempts))
            if self._better(res, best):
                best = res
        self.last_attempts = tuple(attempts)
        best_rel = float(np.asarray(best.health.best_relres))
        if np.isfinite(best_rel) and best_rel < 1.0 \
                and bool(np.isfinite(np.asarray(best.x)).all()):
            return RecoverOutcome("degraded", best, tuple(attempts))
        # an explicit failure must never look like an answer
        zero = best._replace(x=jnp.zeros_like(best.x))
        return RecoverOutcome("failed", zero, tuple(attempts))

    @staticmethod
    def _better(a: CGResult, b: CGResult) -> bool:
        ra = float(np.asarray(a.health.best_relres))
        rb = float(np.asarray(b.health.best_relres))
        if not np.isfinite(ra):
            return False
        return (not np.isfinite(rb)) or ra < rb

    # ---- diagnostics ----------------------------------------------------
    def hierarchy_ok(self) -> bool:
        """Host bool: no NaN/Inf anywhere in the cached hierarchy (used to
        classify corrupted-hierarchy failures before a re-setup)."""
        return bool(np.asarray(hierarchy_finite(self.hierarchy)))

    def describe_last(self) -> str:
        return " -> ".join(self.last_attempts) if self.last_attempts \
            else "(no recovery needed)"


def ladder_solve(A, B, b, *, recovery: Optional[RecoveryPolicy] = None,
                 rtol: float = 1e-8, maxiter: int = 200,
                 **setup_opts) -> RecoverOutcome:
    """One-shot convenience: setup + monitored solve + ladder on ``b``."""
    solver = RobustSolver(A, B, recovery=recovery, rtol=rtol,
                          maxiter=maxiter, **setup_opts)
    return solver.solve(b)


__all__ = ["RecoveryPolicy", "RecoverOutcome", "RobustSolver",
           "ladder_solve", "STATUS_NAMES"]
