"""Device-side solver health flags (jit-compatible, zero-sync).

The monitoring contract (ISSUE 6): the Krylov loops carry a handful of
scalar (or per-column) flags alongside their CG state —

* ``nonfinite``   — NaN/Inf reached the residual norm, ``p·Ap`` or
                    ``r·z`` (a corrupted kernel output, poisoned payload
                    or Inf overflow is visible there within one outer
                    iteration, because every quantity of the recurrence
                    flows through those reductions);
* ``breakdown``   — CG breakdown proper: non-positive ``p·Ap`` or
                    ``r·z`` on an active step, i.e. the operator or the
                    preconditioner stopped being SPD (the classic
                    reduced-precision failure mode of an indefinite fp32
                    V-cycle);
* ``stagnation``  — no new best residual norm for ``stall_window``
                    consecutive iterations: the solve is flat-lining or
                    diverging and further iterations are wasted work.

All of it is computed from reductions the recurrence already performs
(dot products and norms), so the healthy path pays no extra device->host
syncs and no retraces — ``tests/test_robust.py`` pins the healthy trace
bitwise against the unmonitored recurrence and the jit cache size at 1.

Severity order for the structured status code: ``NONFINITE`` >
``BREAKDOWN`` > ``STAGNATION`` > ``MAXITER`` > ``HEALTHY``.  Best-iterate
tracking rides in the same carry: on any early or failed termination the
solve returns its minimum-residual iterate (never the last, possibly
diverged, one), so a flagged result is still the best available answer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

#: Structured status codes (int32, device-side).
HEALTHY = 0      # converged, no flags
MAXITER = 1      # ran out of iterations, no breakdown — best iterate returned
STAGNATION = 2   # no residual progress over the stall window
BREAKDOWN = 3    # non-positive p·Ap / r·z: lost positive-definiteness
NONFINITE = 4    # NaN/Inf reached the recurrence

STATUS_NAMES = {HEALTHY: "healthy", MAXITER: "maxiter",
                STAGNATION: "stagnation", BREAKDOWN: "breakdown",
                NONFINITE: "nonfinite"}


class SolveHealth(NamedTuple):
    """Structured health record on a ``CGResult`` / ``BlockCGResult``.

    Scalar per solve for ``pcg`` / ``_rank_pcg``; per-column ``(k,)``
    arrays for the masked panel solves (a broken column is frozen and
    flagged without touching its panel neighbours — the quarantine the
    solve server's per-request statuses are built on).
    """

    status: Array       # int32 code (see STATUS_NAMES)
    breakdown: Array    # bool
    nonfinite: Array    # bool
    stagnation: Array   # bool
    best_iter: Array    # int32 iteration index of the best iterate
    best_relres: Array  # minimum relative residual seen


def status_of(converged: Array, breakdown: Array, nonfinite: Array,
              stagnation: Array) -> Array:
    """Fold the flags into one int32 code, most severe wins.

    Elementwise, so the per-column panel case is the same call.
    """
    code = jnp.where(converged, HEALTHY, MAXITER)
    code = jnp.where(stagnation, STAGNATION, code)
    code = jnp.where(breakdown, BREAKDOWN, code)
    code = jnp.where(nonfinite, NONFINITE, code)
    return code.astype(jnp.int32)


def describe(health: SolveHealth) -> str:
    """Host-side, human-readable one-liner (syncs; not for the hot loop)."""
    import numpy as np
    status = np.asarray(health.status)
    names = [STATUS_NAMES.get(int(s), f"?{int(s)}")
             for s in np.atleast_1d(status)]
    best = np.atleast_1d(np.asarray(health.best_relres))
    return " ".join(f"{n}(best_relres={float(b):.3e})"
                    for n, b in zip(names, best))


def worst_status(statuses) -> Array:
    """Fold many status codes into one — the march-level aggregate.

    The codes are *numerically ordered by severity* (``NONFINITE`` >
    ``BREAKDOWN`` > ``STAGNATION`` > ``MAXITER`` > ``HEALTHY``), so the
    worst status over a march's steps — or a panel's columns, or a
    fleet of segments — is a plain ``max``.  Works on device arrays
    (jittable, e.g. over a ``StepRecord.status`` buffer) and on host
    numpy alike.
    """
    return jnp.max(jnp.asarray(statuses, jnp.int32))


def summarize_statuses(statuses) -> dict:
    """Host-side march summary: ``{status_name: count}`` over the steps
    (only names that occur), plus ``"worst"`` — what the march driver
    logs and the battery asserts on.  Syncs; not for the hot loop.
    """
    import numpy as np
    codes = np.asarray(statuses).reshape(-1).astype(np.int64)
    out = {}
    for code in np.unique(codes):
        name = STATUS_NAMES.get(int(code), f"?{int(code)}")
        out[name] = int((codes == code).sum())
    out["worst"] = STATUS_NAMES.get(
        int(codes.max()) if codes.size else HEALTHY, "?")
    return out


def hierarchy_finite(hier) -> Array:
    """Device bool: every floating payload of a hierarchy pytree is finite.

    Not part of the per-iteration hot loop (the in-loop flags already see
    payload corruption through ``r·z``) — used by the recovery driver to
    classify a corrupted-hierarchy failure before re-setup.
    """
    ok = jnp.asarray(True)
    for leaf in jax.tree_util.tree_leaves(hier):
        leaf = jnp.asarray(leaf)
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            ok = ok & jnp.isfinite(leaf).all()
    return ok
