"""Solver robustness: health monitoring, fault injection, recovery.

Three layers (ISSUE 6):

``repro.robust.health``
    device-side, jit-compatible health flags (``SolveHealth``) threaded
    through the Krylov carries (``pcg`` / ``block_pcg`` / ``_rank_pcg``):
    NaN/Inf detection on the residual, CG breakdown detection
    (non-positive ``p·Ap`` / ``r·z`` — an indefinite preconditioner under
    reduced precision), stagnation detection, and best-iterate tracking so
    a diverging solve returns its best point rather than its last.

``repro.robust.inject``
    deterministic, schedule-driven fault injection into kernel outputs,
    hierarchy payloads and dist halo payloads — the testing harness for
    the layer above (``REPRO_FAULTS`` env knob).

``repro.robust.recover``
    the policy-driven escalation ladder (``RobustSolver``): stale
    hierarchy -> full re-setup, reduced-precision hierarchy -> fp64
    rebuild, fused kernel path -> reference path — with bounded attempts
    and explicit ``ok``/``recovered``/``degraded``/``failed`` statuses
    (``REPRO_RECOVER`` env knob).

``recover`` is exported lazily: it imports the solver stack, which itself
imports ``health``/``inject`` (the monitoring hooks live inside the hot
loops), and an eager import here would cycle.
"""
from repro.robust import inject  # noqa: F401
from repro.robust.health import (  # noqa: F401
    BREAKDOWN,
    HEALTHY,
    MAXITER,
    NONFINITE,
    STAGNATION,
    STATUS_NAMES,
    SolveHealth,
    describe,
    hierarchy_finite,
    status_of,
)

_LAZY = ("RecoveryPolicy", "RecoverOutcome", "RobustSolver", "ladder_solve")


def __getattr__(name):
    if name == "recover" or name in _LAZY:
        # importlib, not ``from repro.robust import recover``: the from-
        # import's hasattr probe re-enters this __getattr__ before the
        # submodule is bound and recurses forever
        import importlib
        recover = importlib.import_module("repro.robust.recover")
        if name == "recover":
            return recover
        return getattr(recover, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
