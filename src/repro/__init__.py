"""repro — a natively blocked, device-resident AMG framework in JAX.

Subpackages:
  core     blocked sparse containers + SA-AMG (the paper's contribution)
  dist     shard_map distributed runtime (halo plans, distributed AMG)
  fem      Q1/Q2 hex elasticity model problems (PETSc ex56 analogues)
  kernels  Pallas TPU kernels for the bandwidth-bound hot spots
  models   assigned LM architecture zoo (dense/MoE/MLA/SSM/hybrid/enc-dec)
  train    optimizer, train/serve steps, checkpointing, data, fault tolerance
  configs  one module per assigned architecture + the paper's elasticity cfg
  launch   production mesh, multi-pod dry-run, roofline extraction
"""
