"""Scalar (AIJ) solve path — the paper's baseline, kept out of the blocked
coarsening path.

Builds a scalar-format hierarchy from the *same* GAMG setup: identical
aggregates, prolongator values, smoother data and Chebyshev bounds, with the
level operators and transfer operators expanded to 1x1-block CSR.  Because
it is the same algorithm in a different storage format, CG converges in the
*same iteration count to the same true residual* — the paper's Sec. 4.1
parity claim, asserted by ``tests/test_amg_convergence.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_csr import BlockCSR
from repro.core.gamg import GAMGSetup, level_state, restriction_bcsr
from repro.core.ptap import ptap_numeric_data
from repro.core.scalar_csr import expand_bcsr
from repro.core.vcycle import Hierarchy, LevelState

Array = jax.Array


def expand_map(A: BlockCSR) -> "np.ndarray":
    """Flat gather map: scalar CSR data = blocked.data.reshape(-1)[map].

    Lets the scalar numeric path run as a pure jitted gather of the blocked
    payloads (no host conversion on the timed path).
    """
    import numpy as np
    br, bc = A.br, A.bc
    counts = np.diff(A.indptr)
    blk_rows = np.repeat(np.arange(A.nbr), counts)
    k_idx = np.arange(A.nnzb)
    base_in_row = (k_idx - A.indptr[blk_rows]) * bc
    s_counts = np.repeat(counts, br) * bc
    s_indptr = np.zeros(A.nbr * br + 1, dtype=np.int64)
    np.cumsum(s_counts, out=s_indptr[1:])
    out = np.empty(int(s_indptr[-1]), dtype=np.int64)
    for a in range(br):
        pos = s_indptr[blk_rows * br + a] + base_in_row
        pos_flat = (pos[:, None] + np.arange(bc)[None, :]).reshape(-1)
        src = (k_idx[:, None] * (br * bc) + a * bc
               + np.arange(bc)[None, :]).reshape(-1)
        out[pos_flat] = src
    return out


def build_scalar_ptap_chain(setupd: GAMGSetup):
    """Scalar-format hot PtAP chain with cached symbolic plans.

    Mirrors the blocked ``gamg.make_recompute`` PtAP chain but in expanded
    AIJ storage: the cold phase expands every level operator/prolongator and
    builds scalar SpGEMM plans; the returned jitted fn is numeric-only (the
    scalar baseline's hot PtAP, paper Table 1).
    """
    import numpy as np
    from repro.core.ptap import ptap_symbolic
    stages = []
    for ls in setupd.levels:
        A_s = expand_bcsr(ls.A0)
        P_s = expand_bcsr(ls.P)
        cache_s = ptap_symbolic(A_s, P_s)
        stages.append((expand_map(ls.A0), cache_s,
                       P_s.data, ls.A0.br * ls.A0.bc))

    # The scalar product pattern of expanded operators equals the expansion
    # of the blocked product pattern (both keep all structural entries and
    # sort by scalar (row, col)), so each level's scalar PtAP output feeds
    # the next level's scalar PtAP directly — a pure scalar chain, exactly
    # like the blocked one.  Verified in tests/test_scalar_chain.py.
    def chain_full(a_fine_data: Array):
        emap0 = stages[0][0]
        s_data = a_fine_data.reshape(-1)[
            jnp.asarray(emap0)].reshape(-1, 1, 1)
        outs = []
        for lvl, (emap, cache_s, p_data, area) in enumerate(stages):
            if lvl > 0:
                s_data = outs[-1]
            outs.append(ptap_numeric_data(cache_s, s_data, p_data))
        return outs

    return jax.jit(chain_full)


def recompute_scalar(setupd: GAMGSetup, a_fine_data: Array) -> Hierarchy:
    """Numeric hierarchy rebuild with scalar-CSR level/transfer operators.

    The PtAP chain itself still runs blocked (this is the paper's production
    structure: the baseline differs in the *solve-phase format*); the
    benchmark harness separately times scalar-format PtAP via expanded
    SpGEMM plans (``benchmarks/table1_weak_scaling.py``).

    Honors ``setupd.precision`` exactly like the blocked ``gamg.recompute``
    (hierarchy payloads at ``hierarchy_dtype``, shared dinv/lam data), so
    the format-parity claim can be exercised per policy.
    """
    from repro.core.gamg import coarse_cholesky
    policy = setupd.precision
    h = jnp.dtype(policy.hierarchy_dtype)
    states = []
    a_data = jnp.asarray(a_fine_data).astype(h)
    for ls in setupd.levels:
        blocked = level_state(ls, a_data, policy)    # reuse dinv + lam
        A = ls.A0.with_data(a_data)
        a_ell = expand_bcsr(A).to_ell()
        p_ell = expand_bcsr(ls.P).to_ell().astype(h)
        # scalar CSR cannot reuse P's blocks transposed-on-register, so the
        # baseline keeps an expanded stored restriction regardless of the
        # setup's restriction mode
        r_ell = expand_bcsr(restriction_bcsr(ls)).to_ell().astype(h)
        states.append(LevelState(a_ell=a_ell, p_ell=p_ell, r_ell=r_ell,
                                 dinv=blocked.dinv, lam_max=blocked.lam_max))
        a_data = ptap_numeric_data(ls.ptap_cache, a_data,
                                   ls.P.data.astype(h),
                                   accum_dtype=policy.kernel_accum_dtype)
    Ac = setupd.coarse_struct.with_data(a_data)
    chol = coarse_cholesky(Ac.to_dense(), policy)
    a_fine_ell = None
    if policy.mixed and setupd.levels:
        # krylov-dtype copy of the (expanded) finest operator, mirroring
        # the blocked path — the fp64 outer CG must never apply the
        # reduced-precision operator or its residual monitor lies
        a_fine_ell = expand_bcsr(setupd.levels[0].A0.with_data(
            jnp.asarray(a_fine_data).astype(policy.krylov_dtype))).to_ell()
    return Hierarchy(levels=tuple(states), coarse_chol=chol,
                     a_fine_ell=a_fine_ell)
