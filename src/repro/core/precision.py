"""Precision policy — dtype assignments for the mixed-precision AMG stack.

The paper's traffic argument is bytes-per-nonzero: the blocked format sheds
*index* bytes, and this module governs the other half of the lever — the
*value* bytes.  Following Demidov (arXiv:2202.09056), a reduced-precision
AMG preconditioner inside a full-precision Krylov loop halves the
bandwidth-bound V-cycle traffic with negligible iteration growth, so the
policy splits the solve into four dtype roles:

``hierarchy_dtype``
    storage of the device-resident hierarchy: every level operator's
    ``A_l`` payloads, the P/R transfer payloads, the pbjacobi ``dinv``
    blocks and the coarse Cholesky factor.

``smoother_dtype``
    the dtype the V-cycle (smoother + transfer chain) *runs* at.  Equal to
    ``hierarchy_dtype`` in the stock policies; kept separate so a policy
    can e.g. store bf16 payloads but smooth in fp32.

``krylov_dtype``
    the outer Krylov iteration (PCG vectors, dot products, residual
    monitor) and the finest-level operator it applies.  ``pcg`` /
    ``block_pcg`` cast at the preconditioner boundary
    (iterative-refinement style), so a reduced-precision hierarchy never
    degrades the convergence monitor.

``accum_dtype``
    the accumulator the blocked kernels contract in when fed inputs below
    fp32 (the ``preferred_element_type`` of every einsum/kernel reduction).

Policies are resolved by ``repro.kernels.backend.resolve_precision`` —
``None`` falls back to the ``REPRO_PRECISION`` env override ("f64" | "f32"
| "bf16"), default full double (the paper's setting, bitwise-identical to
the pre-policy behaviour).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_NAMES = ("f64", "f32", "bf16")


def _dt(x) -> np.dtype:
    """Canonical np.dtype (ml_dtypes names like 'bfloat16' resolve too)."""
    if isinstance(x, str) and x in _ALIASES:
        x = _ALIASES[x]
    try:
        return np.dtype(x)
    except TypeError as e:  # pragma: no cover - exotic dtype objects
        raise ValueError(f"not a dtype: {x!r}") from e


def _bf16() -> np.dtype:
    import ml_dtypes
    return np.dtype(ml_dtypes.bfloat16)


_ALIASES = {"f64": np.float64, "fp64": np.float64, "float64": np.float64,
            "f32": np.float32, "fp32": np.float32, "float32": np.float32}


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Frozen, hashable dtype assignment for one solver configuration."""

    hierarchy_dtype: np.dtype
    smoother_dtype: np.dtype
    krylov_dtype: np.dtype
    accum_dtype: np.dtype

    def __post_init__(self):
        for f in dataclasses.fields(self):
            object.__setattr__(self, f.name, _dt(getattr(self, f.name)))

    # ---- constructors ---------------------------------------------------
    @staticmethod
    def double() -> "PrecisionPolicy":
        """All-fp64 (the paper's setting; bitwise legacy behaviour)."""
        return PrecisionPolicy(np.float64, np.float64, np.float64,
                               np.float64)

    @staticmethod
    def from_name(name: str) -> "PrecisionPolicy":
        """Stock policies by hierarchy-dtype shorthand.

        "f64"   all double.
        "f32"   fp32-resident hierarchy + smoother, fp64 outer Krylov,
                fp32 accumulators (Demidov's mixed-precision SA-AMG).
        "bf16"  bf16-resident hierarchy + smoother, fp64 outer Krylov,
                fp32 accumulators (kernel-level support; the dense coarse
                factorization still runs in fp32 — see ``factor_dtype``).
        """
        if not isinstance(name, str):
            raise ValueError(f"precision must be a name or policy: {name!r}")
        key = name.strip().lower()
        if key in ("f64", "fp64", "float64", "double"):
            return PrecisionPolicy.double()
        if key in ("f32", "fp32", "float32", "single"):
            return PrecisionPolicy(np.float32, np.float32, np.float64,
                                   np.float32)
        if key in ("bf16", "bfloat16"):
            bf = _bf16()
            return PrecisionPolicy(bf, bf, np.float64, np.float32)
        raise ValueError(
            f"invalid precision {name!r}: expected one of {_NAMES} "
            f"(from REPRO_PRECISION or the precision= knob)")

    # ---- derived properties --------------------------------------------
    @property
    def mixed(self) -> bool:
        """True when the hierarchy is stored below the Krylov dtype (the
        solve then keeps a krylov-dtype copy of the finest operator for
        the outer iteration — ``Hierarchy.a_fine_ell``)."""
        return self.hierarchy_dtype != self.krylov_dtype

    @property
    def factor_dtype(self) -> np.dtype:
        """Dtype for dense factorizations (diag inverses, coarse Cholesky):
        LAPACK only speaks f32/f64, so sub-f32 hierarchies factor in the
        accumulator dtype and store the result at ``hierarchy_dtype``."""
        if self.hierarchy_dtype in (np.dtype(np.float32),
                                    np.dtype(np.float64)):
            return self.hierarchy_dtype
        return self.accum_dtype

    @property
    def kernel_accum_dtype(self):
        """``accum_dtype=`` knob for the blocked kernels: ``None`` (native
        accumulation) unless the hierarchy runs below the accumulator."""
        if self.hierarchy_dtype.itemsize < self.accum_dtype.itemsize:
            return self.accum_dtype
        return None

    def coarse_jitter_scale(self) -> float:
        """Relative diagonal jitter for the coarse Cholesky.  fp64 keeps the
        legacy 1e-12 (bitwise compatibility); reduced-precision chains carry
        O(eps) rounding into the coarse operator, so the guard scales with
        the hierarchy's eps."""
        if self.hierarchy_dtype == np.dtype(np.float64):
            return 1e-12
        return 100.0 * float(np.finfo(self.factor_dtype).eps)

    def coarse_retry_scale(self) -> float:
        """Escalated relative jitter for the coarse-Cholesky *retry* rung:
        when the base-jitter factorization comes back NaN (an indefinite
        or rank-deficient coarse operator — aggregation collapse, payload
        corruption), the factorization is retried once with this larger
        ``sqrt(eps)``-of-the-factor-dtype shift, which regularizes any
        eigenvalue the first jitter could not lift while perturbing the
        preconditioner (not the solution — CG re-monitors the true
        residual) by only O(sqrt(eps))."""
        return float(np.sqrt(np.finfo(self.factor_dtype).eps))

    def describe(self) -> str:
        return (f"hierarchy={self.hierarchy_dtype.name} "
                f"smoother={self.smoother_dtype.name} "
                f"krylov={self.krylov_dtype.name} "
                f"accum={self.accum_dtype.name}")


DOUBLE = PrecisionPolicy.double()
