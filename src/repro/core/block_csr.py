"""Rectangular-block sparse containers — the JAX analogue of MATBAIJKOKKOS.

The paper's contribution 1 is a portable blocked sparse matrix type whose
kernels are templated on *independent* row and column block sizes
(``bs_r x bs_c``).  Here the container is a host-symbolic / device-numeric
split:

* ``indptr`` / ``indices`` (the *structure*) are host ``numpy`` arrays.  All
  symbolic phases (SpGEMM plans, transpose plans, COO plans, strength graphs)
  consume them on the host, exactly as PETSc's symbolic phases do.
* ``data`` (the *values*) is a ``jax`` array of dense ``(nnzb, br, bc)``
  blocks, resident on the device.  All numeric phases are jitted functions of
  ``data`` (+ small device index arrays derived once from the structure).

This split is the functional rendering of PETSc's ``PetscObjectState`` gate
(paper Sec. 3.5): a *plan* is valid exactly as long as the structure it was
derived from; numeric recomputes reuse plans without any symbolic work.

Two layouts are provided:

``BlockCSR``
    the general container (BAIJ analogue), used by every symbolic phase.

``BlockELL``
    a padded fixed-width layout (``indices: (nbr, kmax)``) used by the SpMV
    kernels.  TPUs want regular grids: the ELL padding removes the
    data-dependent row loop, and rows are padded with index 0 + an explicit
    validity mask so padded lanes contribute exactly zero.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_STATE_COUNTER = [0]


def _next_state_token() -> int:
    """Monotone counter mirroring PetscObjectState (paper Sec. 3.5)."""
    _STATE_COUNTER[0] += 1
    return _STATE_COUNTER[0]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockCSR:
    """Rectangular-block CSR: ``nbr x nbc`` grid of ``br x bc`` dense blocks.

    Scalar shape is ``(nbr*br, nbc*bc)``.  ``br == bc == 1`` degenerates to
    scalar CSR (used by the scalar-AIJ baseline, see ``scalar_csr.py``).
    """

    indptr: np.ndarray      # (nbr+1,) int64/int32, host
    indices: np.ndarray     # (nnzb,)  int32, host
    data: Array             # (nnzb, br, bc), device
    nbc: int                # number of block columns
    state_token: int = 0    # bumped whenever structure is (re)created

    # ---- basic properties -------------------------------------------------
    @property
    def nbr(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnzb(self) -> int:
        return int(self.indices.shape[0])

    @property
    def br(self) -> int:
        return int(self.data.shape[1])

    @property
    def bc(self) -> int:
        return int(self.data.shape[2])

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.nbr * self.br, self.nbc * self.bc)

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (self.br, self.bc)

    # ---- constructors -----------------------------------------------------
    @staticmethod
    def from_arrays(indptr, indices, data, nbc) -> "BlockCSR":
        indptr = np.asarray(indptr, dtype=np.int64)
        indices = np.asarray(indices, dtype=np.int32)
        data = jnp.asarray(data)
        assert data.ndim == 3, "data must be (nnzb, br, bc)"
        assert data.shape[0] == indices.shape[0]
        return BlockCSR(indptr, indices, data, int(nbc),
                        state_token=_next_state_token())

    def with_data(self, data: Array) -> "BlockCSR":
        """Same structure, new values (numeric update — keeps state token)."""
        assert data.shape == self.data.shape, (data.shape, self.data.shape)
        return BlockCSR(self.indptr, self.indices, data, self.nbc,
                        self.state_token)

    # ---- conversions ------------------------------------------------------
    def to_dense(self) -> Array:
        """Densify (tests / coarse solve only — never on the hot path)."""
        br, bc = self.br, self.bc
        out = jnp.zeros((self.nbr, self.nbc, br, bc), self.data.dtype)
        rows = np.repeat(np.arange(self.nbr), np.diff(self.indptr))
        out = out.at[rows, self.indices].add(self.data)
        return out.transpose(0, 2, 1, 3).reshape(self.shape)

    def ell_plan(self, pad_to: int | None = None) -> "ELLPlan":
        """Host symbolic phase of the BCSR->BlockELL conversion.

        The plan (padded indices + gather map + validity mask) depends only
        on the structure; hot numeric recomputes rebuild ELL values with
        ``ell_data(plan, new_data)`` — no host round trip (paper Sec. 3.5).
        """
        counts = np.diff(self.indptr)
        kmax = int(counts.max()) if len(counts) else 0
        if pad_to is not None:
            kmax = max(kmax, pad_to)
        nbr = self.nbr
        idx = np.zeros((nbr, kmax), dtype=np.int32)
        sel = np.full((nbr, kmax), -1, dtype=np.int64)  # gather map into data
        for_r = np.repeat(np.arange(nbr), counts)
        within = np.arange(self.nnzb) - np.repeat(self.indptr[:-1], counts)
        idx[for_r, within] = self.indices
        sel[for_r, within] = np.arange(self.nnzb)
        mask = sel >= 0
        gather = np.where(mask, sel, 0)
        return ELLPlan(indices=idx, gather=gather, mask=mask, nbc=self.nbc,
                       state_token=self.state_token)

    def to_ell(self, pad_to: int | None = None) -> "BlockELL":
        """Convert to padded ELL layout for the SpMV kernels."""
        plan = self.ell_plan(pad_to)
        return plan.build(self.data)

    def block_norms(self) -> Array:
        """Frobenius norm of every block — strength-of-connection input.

        Paper Sec. 3.2: operator inspection runs over the bs x bs blocks of
        the block storage directly (no scalar expansion).
        """
        return jnp.sqrt(jnp.sum(self.data * self.data, axis=(1, 2)))

    def diagonal_blocks(self) -> Array:
        """(nbr, br, bc) array of diagonal blocks (zero where absent)."""
        assert self.br == self.bc, "diagonal blocks need square blocks"
        rows = np.repeat(np.arange(self.nbr), np.diff(self.indptr))
        is_diag = rows == self.indices
        out = jnp.zeros((self.nbr, self.br, self.bc), self.data.dtype)
        out = out.at[rows[is_diag]].set(self.data[np.flatnonzero(is_diag)])
        return out

    # ---- pytree protocol ----------------------------------------------
    # ``data`` is the only traced leaf; the structure is static aux data so a
    # jitted numeric phase retraces iff the structure object changes — the
    # functional analogue of the paper's state gate.
    def tree_flatten(self):
        aux = (_HashableArray(self.indptr), _HashableArray(self.indices),
               self.nbc, self.state_token)
        return (self.data,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        indptr, indices, nbc, tok = aux
        return cls(indptr.a, indices.a, children[0], nbc, tok)


@dataclasses.dataclass(frozen=True)
class ELLPlan:
    """Cached structure of a BCSR->ELL conversion (host symbolic)."""

    indices: np.ndarray   # (nbr, kmax) int32, padded -> block col 0
    gather: np.ndarray    # (nbr, kmax) int64 into BCSR data
    mask: np.ndarray      # (nbr, kmax) bool
    nbc: int
    state_token: int

    def ell_data(self, data: Array) -> Array:
        """Numeric phase: scatter BCSR values into the ELL layout (device)."""
        return data[jnp.asarray(self.gather)] * jnp.asarray(
            self.mask, data.dtype)[..., None, None]

    def build(self, data: Array) -> "BlockELL":
        return BlockELL(indices=jnp.asarray(self.indices),
                        data=self.ell_data(data),
                        mask=jnp.asarray(self.mask),
                        nbc=self.nbc,
                        state_token=self.state_token)


class _HashableArray:
    """Identity-hashed numpy array wrapper for use in pytree aux data."""

    __slots__ = ("a", "_key")

    def __init__(self, a: np.ndarray):
        self.a = a
        self._key = (a.shape, a.dtype.str, a.tobytes())

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, _HashableArray) and self._key == other._key


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockELL:
    """Padded fixed-width blocked layout (TPU-regular SpMV operand)."""

    indices: Array   # (nbr, kmax) int32, padded entries point at column 0
    data: Array      # (nbr, kmax, br, bc); padded blocks are exactly zero
    mask: Array      # (nbr, kmax) bool
    nbc: int
    state_token: int = 0

    @property
    def nbr(self) -> int:
        return int(self.indices.shape[0])

    @property
    def kmax(self) -> int:
        return int(self.indices.shape[1])

    @property
    def br(self) -> int:
        return int(self.data.shape[2])

    @property
    def bc(self) -> int:
        return int(self.data.shape[3])

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.nbr * self.br, self.nbc * self.bc)

    def astype(self, dtype) -> "BlockELL":
        """Same structure, values cast to ``dtype`` (precision policies).

        Returns ``self`` when the dtype already matches, so full-precision
        policies stay bitwise on the original arrays.
        """
        if self.data.dtype == jnp.dtype(dtype):
            return self
        return BlockELL(indices=self.indices,
                        data=self.data.astype(dtype), mask=self.mask,
                        nbc=self.nbc, state_token=self.state_token)

    def tree_flatten(self):
        return (self.indices, self.data, self.mask), (self.nbc,
                                                      self.state_token)

    @classmethod
    def tree_unflatten(cls, aux, children):
        nbc, tok = aux
        return cls(children[0], children[1], children[2], nbc, tok)


# ---------------------------------------------------------------------------
# Structure helpers (host, numpy)
# ---------------------------------------------------------------------------

def coo_to_csr_structure(rows: np.ndarray, cols: np.ndarray, nbr: int,
                         sum_duplicates: bool = True):
    """Sort/unique (row, col) COO coordinates into CSR structure.

    Returns ``(indptr, indices, order, out_idx, nnzb)`` where ``order``
    stably sorts the input coordinates and ``out_idx[i]`` is the output slot
    of input coordinate ``i`` (after dedup).  This is the symbolic half of
    blocked COO assembly (paper Sec. 3.4 / 5).
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    ncols = int(cols.max()) + 1 if len(cols) else 0
    key = rows * max(ncols, 1) + cols
    order = np.argsort(key, kind="stable")
    skey = key[order]
    if sum_duplicates:
        uniq, inv_sorted = np.unique(skey, return_inverse=True)
    else:
        uniq, inv_sorted = skey, np.arange(len(skey))
    nnzb = len(uniq)
    out_idx = np.empty(len(key), dtype=np.int64)
    out_idx[order] = inv_sorted
    u_rows = uniq // max(ncols, 1)
    u_cols = uniq % max(ncols, 1)
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, u_rows + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, u_cols.astype(np.int32), order, out_idx, nnzb


def transpose_structure(indptr: np.ndarray, indices: np.ndarray, nbc: int):
    """Symbolic CSR transpose: returns (t_indptr, t_indices, perm).

    ``perm[k]`` is the position in the input data of output nnz ``k``; the
    numeric transpose is ``data[perm].transpose(0, 2, 1)`` — this permutation
    is exactly the cached ``R = P^T`` of the paper's PtAP cache.
    """
    nbr = len(indptr) - 1
    rows = np.repeat(np.arange(nbr), np.diff(indptr))
    cols = np.asarray(indices, dtype=np.int64)
    key = cols * nbr + rows
    perm = np.argsort(key, kind="stable")
    t_rows = cols[perm]
    t_cols = rows[perm]
    t_indptr = np.zeros(nbc + 1, dtype=np.int64)
    np.add.at(t_indptr, t_rows + 1, 1)
    t_indptr = np.cumsum(t_indptr)
    return t_indptr, t_cols.astype(np.int32), perm


def transpose_bcsr(A: BlockCSR) -> BlockCSR:
    """Full (symbolic + numeric) blocked transpose."""
    t_indptr, t_indices, perm = transpose_structure(A.indptr, A.indices,
                                                    A.nbc)
    t_data = A.data[perm].transpose(0, 2, 1)
    return BlockCSR.from_arrays(t_indptr, t_indices, t_data, A.nbr)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EllTransposePlan:
    """Build-time plan for applying ``A^T`` straight off A's ELL blocks.

    The transpose-free restriction (``repro.core.spmv.apply_ell_t``): each
    output block row ``c`` lists the ELL *slots* of A holding a block in
    column ``c``, so the apply gathers from ``A``'s own ``(nbr, kmax, br,
    bc)`` payload, transposing block-local on register — no duplicated
    ``r_ell`` values or indices ever stored.  Slot order per output row
    matches ``transpose_structure``'s (fine rows ascending), so the
    summation order equals the stored-``r_ell`` apply's.

    Like ``BlockELL``, the index arrays are traced pytree leaves (constants
    inside jitted solves); ``nbr`` — A's block-row count, needed to fold
    the input vector into blocks — is static aux data.
    """

    rows: Array     # (nbc, tkmax) int32 — A's block row per slot, pad -> 0
    gather: Array   # (nbc, tkmax) int32 — flattened (nbr*kmax) ELL slots
    mask: Array     # (nbc, tkmax) bool — False on padded slots
    nbr: int        # block rows of the underlying A

    @property
    def nbc(self) -> int:
        return int(self.rows.shape[0])

    @property
    def tkmax(self) -> int:
        return int(self.rows.shape[1])

    def tree_flatten(self):
        return (self.rows, self.gather, self.mask), (self.nbr,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0])


def transpose_apply_plan(A: BlockCSR, kmax: int) -> EllTransposePlan:
    """Host symbolic phase of the transpose-free ``A^T`` apply.

    ``kmax`` is the slot width of A's ELL form (``A.to_ell().kmax``); the
    ELL slot of BCSR nonzero ``j`` is ``row(j) * kmax + within-row(j)``,
    which is what ``gather`` indexes after flattening A's ELL payload.
    """
    counts = np.diff(A.indptr)
    for_r = np.repeat(np.arange(A.nbr), counts)
    within = np.arange(A.nnzb) - np.repeat(A.indptr[:-1], counts)
    slot = for_r * kmax + within
    t_indptr, t_rows, perm = transpose_structure(A.indptr, A.indices, A.nbc)
    t_counts = np.diff(t_indptr)
    tkmax = max(int(t_counts.max()) if len(t_counts) else 0, 1)
    rows = np.zeros((A.nbc, tkmax), dtype=np.int32)
    gather = np.zeros((A.nbc, tkmax), dtype=np.int32)
    mask = np.zeros((A.nbc, tkmax), dtype=bool)
    out_r = np.repeat(np.arange(A.nbc), t_counts)
    out_w = np.arange(A.nnzb) - np.repeat(t_indptr[:-1], t_counts)
    rows[out_r, out_w] = t_rows
    gather[out_r, out_w] = slot[perm]
    mask[out_r, out_w] = True
    return EllTransposePlan(rows=jnp.asarray(rows),
                            gather=jnp.asarray(gather),
                            mask=jnp.asarray(mask), nbr=A.nbr)


@partial(jax.jit, static_argnames=("nbr", "br", "bc"))
def _zeros_blocks(nbr: int, br: int, bc: int, dtype) -> Array:
    return jnp.zeros((nbr, br, bc), dtype)


def identity_bcsr(nbr: int, bs: int, dtype=jnp.float64) -> BlockCSR:
    indptr = np.arange(nbr + 1, dtype=np.int64)
    indices = np.arange(nbr, dtype=np.int32)
    eye = jnp.broadcast_to(jnp.eye(bs, dtype=dtype), (nbr, bs, bs))
    return BlockCSR.from_arrays(indptr, indices, eye, nbr)
