"""Core blocked-AMG library (the paper's contribution, in JAX).

AMG runs in fp64 (the paper's setting); enable x64 before any core module
builds arrays.  LM-model code uses explicit bf16/f32 dtypes and is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.block_csr import (  # noqa: E402,F401
    BlockCSR,
    BlockELL,
    identity_bcsr,
    transpose_bcsr,
)
