"""Device-resident V-cycle — the paper's hot KSPSolve phase (Sec. 3.1).

The cycle is expressed entirely over the padded BlockELL layout: SpMV with
the level operator, restriction/prolongation with R/P (rectangular blocks,
one block per fine row), point-block Jacobi or pbjacobi-preconditioned
Chebyshev smoothing, and a dense Cholesky coarse solve.  Everything is
jittable with static level structure, so one ``jax.jit`` wraps the whole
hot solve, exactly matching the paper's "fully device-resident in blocks"
invariant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.block_csr import BlockELL, EllTransposePlan
from repro.core.spmv import apply_ell, apply_ell_t
from repro.obs import trace as obs_trace
from repro.robust import inject

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LevelState:
    """Numeric per-level state (pytree).  Structure lives in the specs.

    Restriction is stored one of two ways (``apply_restriction`` picks):
    ``p_t`` — the transpose-free default — applies ``P^T`` straight off
    ``p_ell``'s blocks via the build-time plan, so the prolongator-side
    payload exists once; ``r_ell`` is the legacy explicit ``P^T`` copy
    (``gamg.setup(restriction="stored")``), kept for the scalar baseline
    and bitwise comparisons.
    """

    a_ell: BlockELL       # level operator (bs x bs blocks)
    p_ell: BlockELL       # prolongator (bs_f x bs_c blocks), fixed values
    r_ell: Optional[BlockELL]            # stored restriction = P^T, or None
    dinv: Array           # (nbr, bs, bs) inverted diagonal blocks
    lam_max: Array        # chebyshev upper bound for D^{-1}A
    p_t: Optional[EllTransposePlan] = None   # transpose-free P^T plan

    def tree_flatten(self):
        return (self.a_ell, self.p_ell, self.r_ell, self.dinv,
                self.lam_max, self.p_t), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Hierarchy:
    """Device-resident numeric hierarchy, stored at the policy's
    ``hierarchy_dtype``.

    ``a_fine_ell`` is only populated by mixed-precision policies
    (``PrecisionPolicy.mixed``): a krylov-dtype copy of the finest
    operator for the *outer* Krylov iteration, so the residual monitor
    never sees the reduced-precision rounding of ``levels[0].a_ell``
    (which the smoother keeps using).  ``fine_operator`` picks the right
    one.
    """

    levels: Tuple[LevelState, ...]
    coarse_chol: Array    # lower Cholesky factor of the coarsest operator
    a_fine_ell: Optional[BlockELL] = None   # krylov-dtype finest operator

    def tree_flatten(self):
        return (self.levels, self.coarse_chol, self.a_fine_ell), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def fine_operator(hier: Hierarchy) -> BlockELL:
    """The finest-level operator the Krylov loop should apply: the
    krylov-dtype copy under a mixed policy, else level 0's operator."""
    return hier.a_fine_ell if hier.a_fine_ell is not None \
        else hier.levels[0].a_ell


def pbjacobi_apply(dinv: Array, r: Array) -> Array:
    """Point-block Jacobi apply; ``r`` is ``(n,)`` or a panel ``(n, k)``.

    The block-diagonal solve is column-independent, so the panel case is
    the same einsum with the panel axis broadcast along the ellipsis —
    this (together with ``apply_ell`` and the trailing-dim broadcast of
    ``cho_solve``) is what makes the whole V-cycle multi-RHS for free.
    """
    nbr, bs = dinv.shape[0], dinv.shape[1]
    rb = r.reshape((nbr, bs) + r.shape[1:])
    out = jnp.einsum("nab,nb...->na...", dinv, rb,
                     preferred_element_type=dinv.dtype)
    return out.reshape((nbr * bs,) + r.shape[1:])


def chebyshev_recurrence(spmv, pbj, lam_max: Array, b: Array, x: Array,
                         degree: int = 2, lo_frac: float = 0.1,
                         hi_frac: float = 1.05) -> Array:
    """pbjacobi-preconditioned Chebyshev on [lo_frac, hi_frac]*lam_max.

    Shape-agnostic and closure-parameterized so the single-device path and
    the distributed path (``repro.dist.solver``) run the *same* recurrence
    with the same constants — the iteration-parity invariant the dist
    selftest asserts depends on this being the single source of truth.
    """
    lo = lo_frac * lam_max
    hi = hi_frac * lam_max
    theta = 0.5 * (hi + lo)
    delta = 0.5 * (hi - lo)
    sigma = theta / delta
    rho = 1.0 / sigma
    r = b - spmv(x)
    z = pbj(r)
    d = z / theta
    x = x + d
    for _ in range(degree - 1):
        rho_new = 1.0 / (2.0 * sigma - rho)
        r = r - spmv(d)
        z = pbj(r)
        d = (rho_new * rho) * d + (2.0 * rho_new / delta) * z
        x = x + d
        rho = rho_new
    return x


def pbjacobi_recurrence(spmv, pbj, b: Array, x: Array, its: int = 2,
                        omega: float = 0.6) -> Array:
    """Damped point-block Jacobi, closure-parameterized like Chebyshev."""
    for _ in range(its):
        r = b - spmv(x)
        x = x + omega * pbj(r)
    return x


def chebyshev_smooth(lv: LevelState, b: Array, x: Array,
                     degree: int = 2, lo_frac: float = 0.1,
                     hi_frac: float = 1.05) -> Array:
    """GAMG's default smoother; degree 2 matches the paper's production
    setup of cheap, SpMV-dominated smoothing (Sec. 4.2)."""
    return chebyshev_recurrence(lambda v: apply_ell(lv.a_ell, v),
                                lambda r: pbjacobi_apply(lv.dinv, r),
                                lv.lam_max, b, x, degree, lo_frac, hi_frac)


def pbjacobi_smooth(lv: LevelState, b: Array, x: Array,
                    omega: float = 0.6, its: int = 2) -> Array:
    """Plain damped point-block Jacobi (the paper's pbjacobi option)."""
    return pbjacobi_recurrence(lambda v: apply_ell(lv.a_ell, v),
                               lambda r: pbjacobi_apply(lv.dinv, r),
                               b, x, its, omega)


def _fused_step(lv: LevelState, b: Array, x: Array, d: Array, c1, c2):
    """One fused recurrence step ``d' = c1*d + c2*D^{-1}(b - A x);
    x' = x + d'`` through the single-pass Pallas kernel."""
    from repro.kernels import backend as _backend
    from repro.kernels.fused_smoother import ops as _fs
    return _fs.smoother_step(lv.a_ell, lv.dinv, b, x, d, c1, c2,
                             interpret=_backend.resolve_interpret(None))


def chebyshev_smooth_fused(lv: LevelState, b: Array, x: Array,
                           degree: int = 2, lo_frac: float = 0.1,
                           hi_frac: float = 1.05) -> Array:
    """Chebyshev smoothing with each recurrence step as one fused pass.

    Same recurrence constants as ``chebyshev_recurrence``; the residual is
    formed fresh from the current iterate inside the kernel (``b - A x``,
    mathematically identical to the incremental ``r -= A d`` update), so
    the fused path differs from the unfused one only in rounding.
    """
    lo = lo_frac * lv.lam_max
    hi = hi_frac * lv.lam_max
    theta = 0.5 * (hi + lo)
    delta = 0.5 * (hi - lo)
    sigma = theta / delta
    rho = 1.0 / sigma
    x, d = _fused_step(lv, b, x, jnp.zeros_like(b), 0.0, 1.0 / theta)
    for _ in range(degree - 1):
        rho_new = 1.0 / (2.0 * sigma - rho)
        x, d = _fused_step(lv, b, x, d, rho_new * rho,
                           2.0 * rho_new / delta)
        rho = rho_new
    return x


def pbjacobi_smooth_fused(lv: LevelState, b: Array, x: Array,
                          omega: float = 0.6, its: int = 2) -> Array:
    """Damped point-block Jacobi with each step as one fused pass."""
    d = jnp.zeros_like(b)
    for _ in range(its):
        x, d = _fused_step(lv, b, x, d, 0.0, omega)
    return x


def apply_smoother(lv, b, x, smoother: str, degree: int,
                   path: str | None = None):
    """Smoother-name dispatch — the single source of truth shared by the
    V-cycle here and the distributed path's replicated (agglomerated)
    levels, whose exact-parity argument depends on running this verbatim.

    ``path`` selects the execution strategy via ``repro.kernels.backend
    .resolve_smooth_path`` (``REPRO_SMOOTH_PATH``): "fused" runs each
    recurrence step as one Pallas pass (``repro.kernels.fused_smoother``,
    TPU default — the ``r``/``z`` intermediates never touch HBM),
    "reference" the unfused jnp recurrences (CPU default, the bitwise
    legacy path).  Resolution happens at trace time, like the other knobs.
    """
    from repro.kernels.backend import resolve_smooth_path
    if resolve_smooth_path(path) == "fused":
        if smoother == "chebyshev":
            return chebyshev_smooth_fused(lv, b, x, degree=degree)
        return pbjacobi_smooth_fused(lv, b, x, its=degree)
    if smoother == "chebyshev":
        return chebyshev_smooth(lv, b, x, degree=degree)
    return pbjacobi_smooth(lv, b, x, its=degree)


def apply_restriction(lv: LevelState, r: Array) -> Array:
    """Restrict a fine-level residual: ``P^T r`` via the stored ``r_ell``
    when the level carries one, else transpose-free off ``p_ell``'s own
    blocks (``apply_ell_t``).  Shared by the single-device V-cycle and the
    dist replicated tail — the dispatch is structural (trace-time)."""
    if lv.r_ell is not None:
        return apply_ell(lv.r_ell, r)
    return apply_ell_t(lv.p_ell, lv.p_t, r)


def vcycle(hier: Hierarchy, b: Array, smoother: str = "chebyshev",
           degree: int = 2, tally: "obs_trace.CycleTally | None" = None):
    """One V(degree,degree) cycle with zero initial guess (preconditioner).

    The recursion is a static Python loop over levels — unrolled in the
    jitted graph, all device-resident.  ``b`` may be a single vector
    ``(n,)`` or a column panel ``(n, k)``: every stage is column-
    independent — ELL SpMV/SpMM via ``apply_ell``, the block-diagonal
    smoother einsums broadcast along the trailing axis, and the coarse
    ``cho_solve`` natively accepts matrix right-hand sides — so the
    panel cycle is per-column identical to k single cycles (tested in
    ``tests/test_multirhs.py``).

    Observability (ISSUE 7, all governed by ``REPRO_OBS``): every stage
    runs inside a named scope (``vcycle/level{i}/smooth|restrict|prolong``
    and ``vcycle/coarse``) so a profiler capture reads as a per-level
    timeline; with a ``tally`` (a ``repro.obs.trace.CycleTally``) the
    cycle additionally returns ``(x, tally')`` with level visits, smoother
    applications and the coarse solve counted on device.  ``tally=None``
    (the default) leaves both signature and jaxpr exactly the pre-obs
    ones — zero residue, pinned by ``tests/test_obs.py``.
    """
    span = obs_trace.span
    counted = tally is not None
    bs_stack = []
    x_stack = []
    rhs = b
    if counted:
        tally = tally._replace(
            precond_applies=tally.precond_applies + 1)
    for li, lv in enumerate(hier.levels):
        with span(f"vcycle/level{li}/smooth"):
            x = apply_smoother(lv, rhs, jnp.zeros_like(rhs), smoother,
                               degree)
        r = rhs - apply_ell(lv.a_ell, x)
        bs_stack.append(rhs)
        x_stack.append(x)
        # restrict; inject.maybe is a trace-time identity unless a fault
        # schedule is installed (repro.robust.inject)
        with span(f"vcycle/level{li}/restrict"):
            rhs = inject.maybe("vcycle", apply_restriction(lv, r), level=li)
        if counted:
            tally = tally._replace(
                level_visits=tally.level_visits.at[li].add(1),
                smoother_applies=tally.smoother_applies.at[li].add(1))
    with span("vcycle/coarse"):
        xc = inject.maybe(
            "coarse",
            jax.scipy.linalg.cho_solve((hier.coarse_chol, True), rhs))
    if counted:
        tally = tally._replace(coarse_solves=tally.coarse_solves + 1)
    nlev = len(hier.levels)
    for up, (lv, rhs_l, x) in enumerate(zip(reversed(hier.levels),
                                            reversed(bs_stack),
                                            reversed(x_stack))):
        li = nlev - 1 - up
        with span(f"vcycle/level{li}/prolong"):
            x = x + apply_ell(lv.p_ell, xc)       # prolong + correct
        with span(f"vcycle/level{li}/smooth"):
            xc = apply_smoother(lv, rhs_l, x, smoother, degree)
        if counted:
            tally = tally._replace(
                smoother_applies=tally.smoother_applies.at[li].add(1))
    return (xc, tally) if counted else xc


def vcycle_apply_op(hier: Hierarchy, x: Array) -> Array:
    """Finest-level operator application (for the Krylov wrapper)."""
    return apply_ell(fine_operator(hier), x)
