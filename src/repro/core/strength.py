"""Strength-of-connection graph from the block format (paper Sec. 3.2).

SA-AMG needs, before any product, (a) a scalar measure per block row and
(b) a graph whose edges are the strong couplings

    N_i(eps) = { j : |a_ij| >= eps * sqrt(a_ii * a_jj) }

GAMG's historical code demanded a scalar AIJ operator for both; here both
are computed *directly from the block storage*: one graph vertex per block
row, one candidate edge per stored block, strength weight = block Frobenius
norm.  No bs^2 expansion anywhere — the invariant the paper establishes.

As in the paper, graph construction is host work (irregular, serial-leaning,
built once and amortized across every reused solve); the norms themselves
are computed on device over the block payloads and pulled once.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.block_csr import BlockCSR


@dataclasses.dataclass(frozen=True)
class StrengthGraph:
    """Symmetric strong-coupling graph over block rows (CSR, host)."""

    indptr: np.ndarray     # (n+1,)
    indices: np.ndarray    # strong neighbors, diagonal excluded
    weights: np.ndarray    # block-norm weight per edge
    n: int

    @property
    def nedges(self) -> int:
        return int(self.indices.shape[0])

    def neighbor_lists(self):
        """Python list-of-arrays view used by the greedy aggregator."""
        return [self.indices[self.indptr[i]:self.indptr[i + 1]]
                for i in range(self.n)]


def strength_graph(A: BlockCSR, theta: float = 0.08) -> StrengthGraph:
    """Build the strong-coupling graph from block norms.

    ``theta`` is the SA strength threshold (eps in the paper's Sec. 2.2);
    0.08 is standard for 3D elasticity.  The graph is symmetrized (an edge
    survives if either direction is strong) so aggregates are well-defined
    on mildly nonsymmetric operators.
    """
    assert A.nbr == A.nbc, "strength graph needs a square block operator"
    n = A.nbr
    norms = np.asarray(A.block_norms())          # device -> host, once
    rows = np.repeat(np.arange(n), np.diff(A.indptr))
    cols = A.indices.astype(np.int64)
    # diagonal block norms (rows with no stored diagonal get +inf => weak)
    diag_norm = np.full(n, np.inf)
    is_diag = rows == cols
    diag_norm[rows[is_diag]] = norms[is_diag]
    off = ~is_diag
    strong = norms[off] >= theta * np.sqrt(diag_norm[rows[off]]
                                           * diag_norm[cols[off]])
    er, ec = rows[off][strong], cols[off][strong]
    ew = norms[off][strong]
    # symmetrize: union of (er,ec) and (ec,er)
    sr = np.concatenate([er, ec])
    sc = np.concatenate([ec, er])
    sw = np.concatenate([ew, ew])
    key = sr * n + sc
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    first = np.ones(len(key_s), dtype=bool)
    first[1:] = key_s[1:] != key_s[:-1]
    sr, sc, sw = sr[order][first], sc[order][first], sw[order][first]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, sr + 1, 1)
    return StrengthGraph(indptr=np.cumsum(indptr),
                         indices=sc.astype(np.int32), weights=sw, n=n)
