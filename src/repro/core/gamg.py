"""GAMG — smoothed-aggregation AMG with the paper's hot/cold split.

``setup``      cold symbolic phase (paper Sec. 3.1): strength graph,
               aggregation, tentative + smoothed prolongators, every SpGEMM/
               transpose/ELL plan, all computed *on the block format* — the
               coarsening path never touches scalar AIJ (the paper's first
               invariant; ``tests/test_no_scalar_expansion.py`` enforces it).

``recompute``  hot numeric phase: given new fine-operator values (same
               structure — a Newton/time step), rebuild every level operator
               through the cached, state-gated PtAP plans, plus the smoother
               data (pbjacobi inverses, Chebyshev bounds).  One jitted
               device graph, no host symbolic work — the paper's hot PtAP.

``solve``      hot KSPSolve: AMG-preconditioned CG, fully device-resident.

Reuse model = PETSc ``-pc_gamg_reuse_interpolation true``: aggregates and
prolongator *values* are fixed across recomputes; only operators and
smoother data refresh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    Aggregation,
    aggregation_from_device,
    graph_to_ell,
    greedy_aggregate,
    mis_aggregate_device,
)
from repro.core.block_csr import BlockCSR, ELLPlan, transpose_bcsr
from repro.core.ptap import PtAPCache, ptap_numeric_data, ptap_symbolic
from repro.core.smooth import (
    invert_diag_blocks,
    lambda_max_dinv_a,
    smoothed_prolongator,
)
from repro.core.strength import strength_graph
from repro.core.tentative import tentative_prolongator
from repro.core.vcycle import Hierarchy, LevelState, vcycle
from repro.core.spmv import spmv_ell
from repro.core.krylov import CGResult, pcg

Array = jax.Array


@dataclasses.dataclass
class LevelSetup:
    """Cold, host-side symbolic data for one level (structure + plans)."""

    A0: BlockCSR            # level operator at setup time
    P: BlockCSR             # smoothed prolongator (values fixed on reuse)
    R: BlockCSR             # cached transpose (prolongator-side cache)
    ptap_cache: PtAPCache
    a_ell_plan: ELLPlan
    p_ell: "object"         # BlockELL (fixed values)
    r_ell: "object"
    aggr: Aggregation
    omega: Array
    n_fine: int
    n_coarse: int


@dataclasses.dataclass
class GAMGSetup:
    levels: List[LevelSetup]
    coarse_struct: BlockCSR   # coarsest-level operator structure
    bs_fine: int
    nns_dim: int
    smoother: str
    degree: int
    theta: float
    coarsener: str
    stats: dict

    @property
    def n_levels(self) -> int:
        return len(self.levels) + 1


def setup(A: BlockCSR, B: Array, *, theta: float = 0.08,
          max_levels: int = 10, coarse_size: int = 100,
          smoother: str = "chebyshev", degree: int = 2,
          coarsener: str = "greedy") -> GAMGSetup:
    """Cold GAMG setup on the block format (no scalar expansion anywhere)."""
    assert A.br == A.bc, "system operator must have square blocks"
    levels: List[LevelSetup] = []
    Acur, Bcur = A, jnp.asarray(B)
    nns = int(Bcur.shape[1])
    stats = {"level_rows": [A.nbr * A.br], "level_nnzb": [A.nnzb],
             "level_bs": [A.br], "conversions_to_scalar": 0}
    while Acur.nbr > coarse_size and len(levels) < max_levels - 1:
        bs = Acur.br
        graph = strength_graph(Acur, theta)
        if coarsener == "mis":
            idx, mask = graph_to_ell(graph)
            aggr = aggregation_from_device(mis_aggregate_device(idx, mask))
            aggr = _repair_small_aggregates(aggr, graph,
                                            min_size=-(-nns // bs))
        else:
            aggr = greedy_aggregate(graph, min_size=-(-nns // bs))
        if aggr.n_agg >= Acur.nbr:        # no coarsening possible
            break
        Ptent, Bc = tentative_prolongator(aggr, Bcur, bs)
        P, omega, lam, _plans = smoothed_prolongator(Acur, Ptent)
        cache = ptap_symbolic(Acur, P)
        a_next_data = ptap_numeric_data(cache, Acur.data, P.data)
        Anext = BlockCSR.from_arrays(cache.ac_plan.indptr,
                                     cache.ac_plan.indices, a_next_data,
                                     cache.n_coarse)
        R = transpose_bcsr(P)
        levels.append(LevelSetup(
            A0=Acur, P=P, R=R, ptap_cache=cache,
            a_ell_plan=Acur.ell_plan(), p_ell=P.to_ell(), r_ell=R.to_ell(),
            aggr=aggr, omega=omega, n_fine=Acur.nbr, n_coarse=aggr.n_agg))
        stats["level_rows"].append(Anext.nbr * Anext.br)
        stats["level_nnzb"].append(Anext.nnzb)
        stats["level_bs"].append(Anext.br)
        Acur, Bcur = Anext, Bc
    return GAMGSetup(levels=levels, coarse_struct=Acur, bs_fine=A.br,
                     nns_dim=nns, smoother=smoother, degree=degree,
                     theta=theta, coarsener=coarsener, stats=stats)


def _repair_small_aggregates(aggr: Aggregation, graph, min_size: int
                             ) -> Aggregation:
    """Merge undersized MIS aggregates into neighbors (host, cold)."""
    agg = aggr.node_to_agg.copy()
    sizes = np.bincount(agg, minlength=aggr.n_agg)
    indptr, indices = graph.indptr, graph.indices
    for i in range(len(agg)):
        a = agg[i]
        if sizes[a] >= min_size:
            continue
        nbrs = indices[indptr[i]:indptr[i + 1]]
        cand = nbrs[agg[nbrs] != a] if len(nbrs) else nbrs
        if len(cand):
            t = agg[cand[0]]
            sizes[t] += sizes[a]
            sizes[a] = 0
            agg[agg == a] = t
    uniq, agg = np.unique(agg, return_inverse=True)
    return Aggregation(node_to_agg=agg.astype(np.int64), n_agg=len(uniq))


# ---------------------------------------------------------------------------
# Hot numeric recompute (the paper's state-gated PtAP chain).
# ---------------------------------------------------------------------------

def _level_state(ls: LevelSetup, a_data: Array) -> LevelState:
    A = ls.A0.with_data(a_data)
    diag = A.diagonal_blocks()
    dinv = invert_diag_blocks(diag)
    a_ell = ls.a_ell_plan.build(a_data)
    dinva_ell = jnp.einsum("nab,nkbc->nkac", dinv, a_ell.data,
                           preferred_element_type=a_data.dtype)
    lam = lambda_max_dinv_a(a_ell.indices, dinva_ell, a_ell.mask,
                            A.nbr, A.br)
    return LevelState(a_ell=a_ell, p_ell=ls.p_ell, r_ell=ls.r_ell,
                      dinv=dinv, lam_max=lam)


def recompute(setupd: GAMGSetup, a_fine_data: Array) -> Hierarchy:
    """Hot numeric hierarchy rebuild: pure function of the fine values.

    Wrap with ``make_recompute`` for the jitted production entry point.
    """
    states = []
    a_data = a_fine_data
    for ls in setupd.levels:
        states.append(_level_state(ls, a_data))
        a_data = ptap_numeric_data(ls.ptap_cache, a_data, ls.P.data)
    Ac = setupd.coarse_struct.with_data(a_data)
    dense = Ac.to_dense()
    n = dense.shape[0]
    jitter = 1e-12 * jnp.trace(dense) / n
    chol = jnp.linalg.cholesky(dense + jitter * jnp.eye(n, dtype=dense.dtype))
    return Hierarchy(levels=tuple(states), coarse_chol=chol)


def make_recompute(setupd: GAMGSetup):
    """Jitted hot-recompute closure (symbolic data baked in as constants)."""
    return jax.jit(partial(recompute, setupd))


def make_solve(setupd: GAMGSetup, rtol: float = 1e-8, maxiter: int = 200):
    """Jitted hot KSPSolve: AMG-preconditioned CG on a Hierarchy pytree."""
    smoother, degree = setupd.smoother, setupd.degree

    @partial(jax.jit, static_argnames=())
    def solve(hier: Hierarchy, b: Array) -> CGResult:
        def apply_a(x):
            return spmv_ell(hier.levels[0].a_ell, x)

        def apply_m(r):
            return vcycle(hier, r, smoother=smoother, degree=degree)

        return pcg(apply_a, apply_m, b, rtol=rtol, maxiter=maxiter)

    return solve


# ---------------------------------------------------------------------------
# Convenience front door
# ---------------------------------------------------------------------------

class GAMGSolver:
    """PETSc-shaped convenience wrapper: setup once, re-solve many times."""

    def __init__(self, A: BlockCSR, B: Array, **opts):
        solve_opts = {k: opts.pop(k) for k in ("rtol", "maxiter")
                      if k in opts}
        self.setup_data = setup(A, B, **opts)
        self._recompute = make_recompute(self.setup_data)
        self._solve = make_solve(self.setup_data, **solve_opts)
        self._solve_opts = solve_opts
        self._solve_many = None
        self.hierarchy = self._recompute(A.data)
        self.n_recomputes = 0

    def update_operator(self, a_fine_data: Array) -> None:
        """Hot path: new operator values, same structure (Newton step)."""
        self.hierarchy = self._recompute(a_fine_data)
        self.n_recomputes += 1

    def solve(self, b: Array) -> CGResult:
        return self._solve(self.hierarchy, b)

    def solve_many(self, B: Array):
        """Panel solve: ``B (n, k)`` -> ``BlockCGResult`` (per-column
        masked PCG, one operator stream for all k columns).

        Retraces once per distinct k — stream workloads should go through
        ``repro.multirhs.AMGSolveServer``, which buckets k statically.
        """
        if self._solve_many is None:
            from repro.multirhs.block_krylov import make_block_solve
            self._solve_many = make_block_solve(self.setup_data,
                                                **self._solve_opts)
        return self._solve_many(self.hierarchy, B)
