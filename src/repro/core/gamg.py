"""GAMG — smoothed-aggregation AMG with the paper's hot/cold split.

``setup``      cold symbolic phase (paper Sec. 3.1): strength graph,
               aggregation, tentative + smoothed prolongators, every SpGEMM/
               transpose/ELL plan, all computed *on the block format* — the
               coarsening path never touches scalar AIJ (the paper's first
               invariant; ``tests/test_no_scalar_expansion.py`` enforces it).

``recompute``  hot numeric phase: given new fine-operator values (same
               structure — a Newton/time step), rebuild every level operator
               through the cached, state-gated PtAP plans, plus the smoother
               data (pbjacobi inverses, Chebyshev bounds).  One jitted
               device graph, no host symbolic work — the paper's hot PtAP.

``solve``      hot KSPSolve: AMG-preconditioned CG, fully device-resident.

Reuse model = PETSc ``-pc_gamg_reuse_interpolation true``: aggregates and
prolongator *values* are fixed across recomputes; only operators and
smoother data refresh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    Aggregation,
    aggregation_from_device,
    graph_to_ell,
    greedy_aggregate,
    mis_aggregate_device,
)
from repro.core.block_csr import (
    BlockCSR,
    ELLPlan,
    transpose_apply_plan,
    transpose_bcsr,
)
from repro.core.ptap import PtAPCache, ptap_numeric_data, ptap_symbolic
from repro.core.smooth import (
    invert_diag_blocks,
    lambda_max_dinv_a,
    smoothed_prolongator,
)
from repro.core.precision import PrecisionPolicy
from repro.core.strength import strength_graph
from repro.core.tentative import tentative_prolongator
from repro.core.vcycle import Hierarchy, LevelState, fine_operator, vcycle
from repro.core.spmv import spmv_ell
from repro.core.krylov import CGResult, pcg
from repro.obs import trace as obs_trace
from repro.robust import inject

Array = jax.Array


@dataclasses.dataclass
class LevelSetup:
    """Cold, host-side symbolic data for one level (structure + plans).

    Under the transpose-free default (``setup(restriction=
    "transpose_free")``) ``R``/``r_ell`` are ``None`` and ``pt`` carries
    the build-time ``P^T``-apply plan instead: the hot path restricts
    straight off ``p_ell``'s blocks and the hierarchy never stores the
    transposed duplicate.  Cold consumers that genuinely need the stored
    form (the scalar baseline's expansion, the dist sharded staging) go
    through ``restriction_bcsr``.
    """

    A0: BlockCSR            # level operator at setup time
    P: BlockCSR             # smoothed prolongator (values fixed on reuse)
    R: "BlockCSR | None"    # stored transpose (restriction="stored" only)
    ptap_cache: PtAPCache
    a_ell_plan: ELLPlan
    p_ell: "object"         # BlockELL (fixed values)
    r_ell: "object"         # BlockELL or None (transpose-free default)
    aggr: Aggregation
    omega: Array
    n_fine: int
    n_coarse: int
    pt: "object" = None     # EllTransposePlan (transpose-free default)


def restriction_bcsr(ls: LevelSetup) -> BlockCSR:
    """The stored-form restriction of a level, computing the transpose on
    demand when the setup is transpose-free (cold consumers only — the hot
    path restricts via ``vcycle.apply_restriction`` without it)."""
    return ls.R if ls.R is not None else transpose_bcsr(ls.P)


@dataclasses.dataclass
class GAMGSetup:
    levels: List[LevelSetup]
    coarse_struct: BlockCSR   # coarsest-level operator structure
    bs_fine: int
    nns_dim: int
    smoother: str
    degree: int
    theta: float
    coarsener: str
    stats: dict
    precision: PrecisionPolicy = dataclasses.field(
        default_factory=PrecisionPolicy.double)
    # distributed placement hint (PETSc ``-pc_gamg_process_eq_limit``):
    # levels whose equations-per-rank are at or below this leave the slab-sharded
    # path and run agglomerated (``repro.dist.solver.build_dist_gamg``).
    # ``None`` defers to the dist layer's default.
    coarse_eq_limit: "int | None" = None

    @property
    def n_levels(self) -> int:
        return len(self.levels) + 1


def setup(A: BlockCSR, B: Array, *, theta: float = 0.08,
          max_levels: int = 10, coarse_size: int = 100,
          smoother: str = "chebyshev", degree: int = 2,
          coarsener: str = "mis", precision=None,
          restriction: str = "transpose_free",
          coarse_eq_limit: "int | None" = None) -> GAMGSetup:
    """Cold GAMG setup on the block format (no scalar expansion anywhere).

    ``coarsener`` selects the aggregation path: ``"mis"`` (default) keeps
    even the cold graph phase on device via the jitted Luby-MIS coarsener
    (paper Sec. 6's future work); ``"greedy"`` is the classical host-side
    Vanek covering, kept as the fallback and the quality baseline
    (``tests/test_amg_convergence.py`` checks the two stay comparable).

    ``precision`` is a ``PrecisionPolicy`` / stock-policy name; ``None``
    resolves ``REPRO_PRECISION`` via ``repro.kernels.backend`` (default
    full fp64).  The *setup* math (strength, aggregation, prolongator
    smoothing) always runs at the operator dtype; the policy governs what
    ``recompute`` builds and what the solves run at.

    ``restriction`` selects how ``P^T`` is applied in the V-cycle:
    ``"transpose_free"`` (default) stores no restriction at all — a
    build-time ``EllTransposePlan`` lets the hot path restrict straight
    off ``p_ell``'s blocks, roughly halving prolongator-side hierarchy
    memory and shedding the setup transpose; ``"stored"`` keeps the legacy
    explicit ``R = transpose_bcsr(P)`` / ``r_ell`` (bitwise the
    pre-transpose-free behaviour).

    ``coarse_eq_limit`` is the distributed placement hint (equations per
    rank at or below which a level is agglomerated, PETSc's
    ``-pc_gamg_process_eq_limit``); the single-device path ignores it and
    ``repro.dist.solver.build_dist_gamg`` consumes it.
    """
    from repro.kernels.backend import resolve_precision
    precision = resolve_precision(precision)
    assert A.br == A.bc, "system operator must have square blocks"
    if restriction not in ("transpose_free", "stored"):
        raise ValueError(
            f"invalid restriction mode {restriction!r}: expected "
            f"'transpose_free' or 'stored'")
    levels: List[LevelSetup] = []
    Acur, Bcur = A, jnp.asarray(B)
    nns = int(Bcur.shape[1])
    stats = {"level_rows": [A.nbr * A.br], "level_nnzb": [A.nnzb],
             "level_bs": [A.br], "conversions_to_scalar": 0}
    if coarsener not in ("mis", "greedy"):
        raise ValueError(f"invalid coarsener {coarsener!r}: "
                         f"expected 'mis' or 'greedy'")
    while Acur.nbr > coarse_size and len(levels) < max_levels - 1:
        bs = Acur.br
        graph = strength_graph(Acur, theta)
        if coarsener == "mis":
            idx, mask = graph_to_ell(graph)
            aggr = aggregation_from_device(mis_aggregate_device(idx, mask))
            aggr = _repair_small_aggregates(aggr, graph,
                                            min_size=-(-nns // bs))
        else:
            aggr = greedy_aggregate(graph, min_size=-(-nns // bs))
        if aggr.n_agg >= Acur.nbr:        # no coarsening possible
            break
        Ptent, Bc = tentative_prolongator(aggr, Bcur, bs)
        P, omega, lam, _plans = smoothed_prolongator(Acur, Ptent)
        cache = ptap_symbolic(Acur, P)
        a_next_data = ptap_numeric_data(cache, Acur.data, P.data)
        Anext = BlockCSR.from_arrays(cache.ac_plan.indptr,
                                     cache.ac_plan.indices, a_next_data,
                                     cache.n_coarse)
        p_ell = P.to_ell()
        if restriction == "stored":
            R = transpose_bcsr(P)
            r_ell, pt = R.to_ell(), None
        else:
            R, r_ell = None, None
            pt = transpose_apply_plan(P, p_ell.kmax)
        levels.append(LevelSetup(
            A0=Acur, P=P, R=R, ptap_cache=cache,
            a_ell_plan=Acur.ell_plan(), p_ell=p_ell, r_ell=r_ell,
            aggr=aggr, omega=omega, n_fine=Acur.nbr, n_coarse=aggr.n_agg,
            pt=pt))
        stats["level_rows"].append(Anext.nbr * Anext.br)
        stats["level_nnzb"].append(Anext.nnzb)
        stats["level_bs"].append(Anext.br)
        Acur, Bcur = Anext, Bc
    return GAMGSetup(levels=levels, coarse_struct=Acur, bs_fine=A.br,
                     nns_dim=nns, smoother=smoother, degree=degree,
                     theta=theta, coarsener=coarsener, stats=stats,
                     precision=precision, coarse_eq_limit=coarse_eq_limit)


def _repair_small_aggregates(aggr: Aggregation, graph, min_size: int
                             ) -> Aggregation:
    """Merge undersized MIS aggregates into neighbors (host, cold)."""
    agg = aggr.node_to_agg.copy()
    sizes = np.bincount(agg, minlength=aggr.n_agg)
    indptr, indices = graph.indptr, graph.indices
    for i in range(len(agg)):
        a = agg[i]
        if sizes[a] >= min_size:
            continue
        nbrs = indices[indptr[i]:indptr[i + 1]]
        cand = nbrs[agg[nbrs] != a] if len(nbrs) else nbrs
        if len(cand):
            t = agg[cand[0]]
            sizes[t] += sizes[a]
            sizes[a] = 0
            agg[agg == a] = t
    uniq, agg = np.unique(agg, return_inverse=True)
    return Aggregation(node_to_agg=agg.astype(np.int64), n_agg=len(uniq))


# ---------------------------------------------------------------------------
# Hot numeric recompute (the paper's state-gated PtAP chain).
# ---------------------------------------------------------------------------

def level_state(ls: LevelSetup, a_data: Array,
                policy: PrecisionPolicy = None) -> LevelState:
    """Numeric level state from hierarchy-dtype payloads ``a_data``.

    The dense diagonal inversion runs at ``policy.factor_dtype`` (LAPACK
    has no sub-f32 kernels) and the D^{-1}A scaling accumulates at
    ``policy.accum_dtype``; everything is *stored* at the hierarchy dtype.
    A full-fp64 policy leaves every operation bitwise unchanged.

    Shared verbatim by the scalar baseline (``scalar_path``) and the
    distributed path's agglomerated levels (``repro.dist.solver``) — the
    rank-redundant replicated tail IS the single-device computation, which
    is what makes agglomerated-vs-single parity exact by construction.
    """
    policy = policy or PrecisionPolicy.double()
    h = jnp.dtype(policy.hierarchy_dtype)
    acc = jnp.promote_types(h, jnp.dtype(policy.accum_dtype))
    A = ls.A0.with_data(a_data)
    diag = A.diagonal_blocks()
    dinv = invert_diag_blocks(
        diag.astype(policy.factor_dtype)).astype(h)
    a_ell = ls.a_ell_plan.build(a_data)
    dinva_ell = jnp.einsum("nab,nkbc->nkac", dinv.astype(acc),
                           a_ell.data.astype(acc),
                           preferred_element_type=acc).astype(h)
    lam = lambda_max_dinv_a(a_ell.indices, dinva_ell, a_ell.mask,
                            A.nbr, A.br)
    r_ell = ls.r_ell.astype(h) if ls.r_ell is not None else None
    return LevelState(a_ell=a_ell, p_ell=ls.p_ell.astype(h),
                      r_ell=r_ell, dinv=dinv, lam_max=lam, p_t=ls.pt)


def jittered_cholesky(densef: Array, base_scale: float,
                      retry_scale: float) -> Array:
    """Dense Cholesky with a one-shot jitter-escalation retry (jittable).

    The base factorization adds ``base_scale * trace/n`` to the diagonal
    (the legacy guard, bitwise when it succeeds — ``lax.cond`` evaluates
    only the taken branch and adds no host sync).  A NaN factor — XLA's
    Cholesky reports an indefinite or rank-deficient matrix as NaNs, it
    never aborts — triggers one retry with the much larger
    ``retry_scale * |trace|/n`` shift, which lifts any eigenvalue the
    base jitter could not.  A factor that is NaN even after the retry
    (corrupted payloads) is returned as-is: the V-cycle propagates it,
    the Krylov health flags catch it within one iteration, and the
    recovery ladder escalates to a re-setup.

    Single source of truth for the coarse factorization — shared by
    ``coarse_cholesky`` here and the distributed ``_rank_coarse_chol``.
    """
    n = densef.shape[0]
    eye = jnp.eye(n, dtype=densef.dtype)
    jitter = base_scale * jnp.trace(densef) / n
    chol = jnp.linalg.cholesky(densef + jitter * eye)
    # |trace|: an indefinite operator can have a tiny or negative trace,
    # and a negative "jitter" would dig the retry deeper
    retry_jitter = retry_scale * jnp.abs(jnp.trace(densef)) / n
    return jax.lax.cond(
        jnp.isfinite(chol).all(),
        lambda: chol,
        lambda: jnp.linalg.cholesky(densef + retry_jitter * eye))


def coarse_cholesky(dense: Array, policy: PrecisionPolicy) -> Array:
    """Jittered dense Cholesky of the coarsest operator.

    fp64 keeps the legacy 1e-12 relative jitter bitwise; reduced-precision
    chains carry O(eps) rounding into the coarse operator, so the guard
    scales with the hierarchy eps (``PrecisionPolicy.coarse_jitter_scale``)
    and the factorization runs at ``factor_dtype``.  A NaN base factor
    (indefinite/rank-deficient coarse operator) is retried once at the
    escalated ``coarse_retry_scale`` jitter — see ``jittered_cholesky``.
    """
    fd = jnp.dtype(policy.factor_dtype)
    chol = jittered_cholesky(dense.astype(fd),
                             policy.coarse_jitter_scale(),
                             policy.coarse_retry_scale())
    return chol.astype(policy.hierarchy_dtype)


def recompute(setupd: GAMGSetup, a_fine_data: Array) -> Hierarchy:
    """Hot numeric hierarchy rebuild: pure function of the fine values.

    The hierarchy (level payloads, transfer payloads, dinv, coarse factor)
    is built and stored at ``setupd.precision.hierarchy_dtype``; the PtAP
    chain runs at that dtype too, so the value traffic of the whole
    recompute scales with the policy's width.  Mixed policies additionally
    keep a krylov-dtype copy of the *finest* operator
    (``Hierarchy.a_fine_ell``) for the outer iteration.

    Wrap with ``make_recompute`` for the jitted production entry point.
    """
    policy = setupd.precision
    h = jnp.dtype(policy.hierarchy_dtype)
    a_in = jnp.asarray(a_fine_data)
    states = []
    a_data = a_in.astype(h)
    span = obs_trace.span
    for li, ls in enumerate(setupd.levels):
        # level-gated payload-corruption site (trace-time identity unless
        # a fault schedule is installed — repro.robust.inject)
        a_data = inject.maybe("hierarchy", a_data, level=li)
        with span(f"recompute/level{li}/smoother_data"):
            states.append(level_state(ls, a_data, policy))
        with span(f"recompute/level{li}/ptap"):
            a_data = ptap_numeric_data(ls.ptap_cache, a_data,
                                       ls.P.data.astype(h),
                                       accum_dtype=policy.kernel_accum_dtype)
    a_data = inject.maybe("hierarchy", a_data, level=len(setupd.levels))
    Ac = setupd.coarse_struct.with_data(a_data)
    with span("recompute/coarse_chol"):
        chol = coarse_cholesky(Ac.to_dense(), policy)
    a_fine_ell = None
    if policy.mixed and setupd.levels:
        a_fine_ell = setupd.levels[0].a_ell_plan.build(
            a_in.astype(policy.krylov_dtype))
    return Hierarchy(levels=tuple(states), coarse_chol=chol,
                     a_fine_ell=a_fine_ell)


def make_recompute(setupd: GAMGSetup):
    """Jitted hot-recompute closure (symbolic data baked in as constants)."""
    return jax.jit(partial(recompute, setupd))


def make_coeff_recompute(setupd: GAMGSetup, assembler):
    """Jitted coefficient hot path: ``(E, nu) -> Hierarchy``.

    Fuses device FEM assembly (vmapped quadrature -> cached blocked-COO
    scatter, ``repro.fem.device_stiffness.DeviceAssembler.coo_data``) with
    the state-gated PtAP recompute into ONE traced program — the whole
    ``update -> set_values_coo -> recompute`` step of the quasi-static hot
    loop runs device-resident with zero host transfers.  The assembler's
    plan and the setup's symbolic data are baked in as constants; the
    program retraces only if those structures change.
    """
    nnzb = setupd.levels[0].A0.nnzb if setupd.levels \
        else setupd.coarse_struct.nnzb
    if assembler.plan.nnzb != nnzb:
        # out-of-range gathers clamp silently under jit — a mismatched
        # plan would "converge" against a garbage operator
        raise ValueError(
            f"assembler plan does not match the setup's fine operator: "
            f"plan has {assembler.plan.nnzb} output blocks, the fine "
            f"level has {nnzb}")

    def coeff_recompute(E, nu):
        return recompute(setupd, assembler.coo_data(E, nu))

    return jax.jit(coeff_recompute)


def hier_solve(setupd: GAMGSetup, hier: Hierarchy, b: Array,
               x0: "Array | None" = None, *, rtol: float = 1e-8,
               maxiter: int = 200) -> CGResult:
    """Traceable AMG-PCG solve on a hierarchy — the body ``make_solve``
    jits, exposed unjitted so larger device programs can compose it (the
    ``repro.sim`` march fuses it with assembly + recompute inside one
    ``lax.scan`` segment).

    ``x0`` warm-starts CG from a prior iterate (``None`` = cold zero
    start) — the time-march knob: consecutive quasi-static steps solve
    nearby systems, so seeding with the previous step's solution starts
    from a small residual and saves iterations (``pcg`` docstring).
    """
    def apply_a(x):
        return spmv_ell(fine_operator(hier), x)

    def apply_m(r):
        return vcycle(hier, r, smoother=setupd.smoother,
                      degree=setupd.degree)

    return pcg(apply_a, apply_m, b, x0=x0, rtol=rtol, maxiter=maxiter,
               precond_dtype=setupd.precision.smoother_dtype)


def make_solve(setupd: GAMGSetup, rtol: float = 1e-8, maxiter: int = 200,
               obs=None):
    """Jitted hot KSPSolve: AMG-preconditioned CG on a Hierarchy pytree.

    The jitted closure's optional third argument warm-starts the solve:
    ``solve(hier, b, x0)`` seeds CG with a prior iterate (a previous
    time/Newton step's solution), ``solve(hier, b)`` is the cold start
    and stays bitwise the pre-warm-start closure (one jit cache entry
    per calling form).

    The outer CG runs at the policy's ``krylov_dtype`` (the dtype of
    ``b`` / the ``fine_operator`` copy); the V-cycle preconditioner runs
    at ``smoother_dtype`` with the cast at the ``pcg`` boundary —
    iterative refinement around a reduced-precision hierarchy.

    The observability mode (``obs=`` > ``use`` scope > ``REPRO_OBS``,
    resolved here at closure-build time, matching the knob's trace-time
    contract) selects the counted variant: under ``"counters"`` a
    ``repro.obs.trace.CycleTally`` rides the CG carry and the returned
    ``CGResult.counters`` reports level visits, smoother/operator/coarse
    applications and the modeled HBM bytes
    (``repro.obs.model.vcycle_traffic`` x V-cycle invocations).  Off
    (the default) this closure is bitwise the pre-obs one.
    """
    smoother, degree = setupd.smoother, setupd.degree
    precond_dtype = setupd.precision.smoother_dtype
    counted = obs_trace.counters_enabled(obs)
    if counted:
        from repro.obs.model import vcycle_traffic
        itemsize = jnp.dtype(setupd.precision.hierarchy_dtype).itemsize
        cycle_bytes = float(
            vcycle_traffic(setupd, itemsize=itemsize)["total"])
        n_levels = setupd.n_levels

    @partial(jax.jit, static_argnames=())
    def solve(hier: Hierarchy, b: Array,
              x0: "Array | None" = None) -> CGResult:
        if counted:
            def apply_a(x):
                return spmv_ell(fine_operator(hier), x)

            def apply_m(r, tl):
                return vcycle(hier, r, smoother=smoother, degree=degree,
                              tally=tl)
            res = pcg(apply_a, apply_m, b, x0=x0, rtol=rtol,
                      maxiter=maxiter, precond_dtype=precond_dtype,
                      tally=obs_trace.zero_tally(n_levels))
            return res._replace(counters=obs_trace.attach_model_bytes(
                res.counters, cycle_bytes))

        return hier_solve(setupd, hier, b, x0, rtol=rtol,
                          maxiter=maxiter)

    return solve


def make_coeff_solve(setupd: GAMGSetup, assembler, rtol: float = 1e-8,
                     maxiter: int = 200):
    """Jitted fused march step: ``(E, nu, b, x0) -> CGResult``.

    The segmented march's per-step primitive — device FEM assembly
    (``DeviceAssembler.coo_data``), the state-gated PtAP recompute and
    the warm-started AMG-PCG solve in ONE traced program with zero host
    transfers.  ``x0`` is the previous step's iterate (pass
    ``jnp.zeros_like(b)`` for a cold start — the signature keeps it
    positional so the jit cache stays at one entry across the march).
    The fully-fused scan/while segments (scenario law + staleness
    monitor riding along) live in ``repro.sim.driver``.
    """
    nnzb = setupd.levels[0].A0.nnzb if setupd.levels \
        else setupd.coarse_struct.nnzb
    if assembler.plan.nnzb != nnzb:
        raise ValueError(
            f"assembler plan does not match the setup's fine operator: "
            f"plan has {assembler.plan.nnzb} output blocks, the fine "
            f"level has {nnzb}")

    def coeff_solve(E, nu, b, x0):
        hier = recompute(setupd, assembler.coo_data(E, nu))
        return hier_solve(setupd, hier, b, x0, rtol=rtol,
                          maxiter=maxiter)

    return jax.jit(coeff_solve)


# ---------------------------------------------------------------------------
# Convenience front door
# ---------------------------------------------------------------------------

class GAMGSolver:
    """PETSc-shaped convenience wrapper: setup once, re-solve many times."""

    def __init__(self, A: BlockCSR, B: Array, **opts):
        # "obs" rides along to make_solve/make_block_solve (counters mode)
        solve_opts = {k: opts.pop(k) for k in ("rtol", "maxiter", "obs")
                      if k in opts}
        self.setup_data = setup(A, B, **opts)
        self._recompute = make_recompute(self.setup_data)
        self._solve = make_solve(self.setup_data, **solve_opts)
        self._solve_opts = solve_opts
        self._solve_many = None
        self.hierarchy = self._recompute(A.data)
        self.n_recomputes = 0

    def update_operator(self, a_fine_data: Array) -> None:
        """Hot path: new operator values, same structure (Newton step)."""
        self.hierarchy = self._recompute(a_fine_data)
        self.n_recomputes += 1

    def bind_assembler(self, assembler) -> None:
        """Attach a ``repro.fem`` DeviceAssembler, enabling coefficient
        updates: ``update_coefficients(E, nu)`` then runs assembly +
        recompute as one jitted device program."""
        self.assembler = assembler
        self._coeff_recompute = make_coeff_recompute(self.setup_data,
                                                     assembler)

    def update_coefficients(self, E, nu) -> None:
        """Hot path: new *material fields* (per-element E/nu arrays or
        scalars), same mesh/structure — device assembly fused with the
        state-gated PtAP chain (``make_coeff_recompute``)."""
        if getattr(self, "assembler", None) is None:
            raise ValueError(
                "update_coefficients needs a bound DeviceAssembler: "
                "call bind_assembler(problem.assembler) (device assembly "
                "path) first")
        E, nu = self.assembler.as_fields(E, nu)
        self.hierarchy = self._coeff_recompute(E, nu)
        self.n_recomputes += 1

    def solve(self, b: Array, x0: "Array | None" = None) -> CGResult:
        """Solve; ``x0`` warm-starts CG from a prior iterate (the
        time-march knob — pass the previous step's solution).  The cold
        form keeps its own single jit cache entry."""
        if x0 is None:
            return self._solve(self.hierarchy, b)
        return self._solve(self.hierarchy, b, x0)

    def solve_many(self, B: Array, x0: "Array | None" = None):
        """Panel solve: ``B (n, k)`` -> ``BlockCGResult`` (per-column
        masked PCG, one operator stream for all k columns).  ``x0``
        warm-starts every column from a prior ``(n, k)`` iterate panel.

        Retraces once per distinct k — stream workloads should go through
        ``repro.multirhs.AMGSolveServer``, which buckets k statically.
        """
        if self._solve_many is None:
            from repro.multirhs.block_krylov import make_block_solve
            self._solve_many = make_block_solve(self.setup_data,
                                                **self._solve_opts)
        if x0 is None:
            return self._solve_many(self.hierarchy, B)
        return self._solve_many(self.hierarchy, B, x0)

    def march(self, prob, scenario, cfg, **kw):
        """Front door to the device-resident time march
        (``repro.sim.driver.march``): quasi-static coefficient evolution
        through fused assembly + recompute + warm-started solve steps,
        with adaptive re-coarsening at staleness boundaries.  ``prob``
        must be the assembled problem this solver was built from."""
        from repro.sim.driver import march as _march
        kw.setdefault("setup_opts", {})
        return _march(prob, scenario, cfg, **kw)
