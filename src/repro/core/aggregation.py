"""Aggregation — greedy host coarsener + device Luby-MIS coarsener.

The paper keeps the aggregation graph phase on the host (Sec. 3.2): it is
irregular, serial-leaning work, built once and reused across every solve.
``greedy_aggregate`` is that path — the classical smoothed-aggregation
greedy disjoint covering (Vanek et al.):

  pass 1  visit nodes in order; a node whose strong neighborhood is fully
          unaggregated roots a new aggregate containing the neighborhood;
  pass 2  remaining nodes join the strongest adjacent aggregate;
  pass 3  still-isolated nodes become singletons, then undersized
          aggregates (fewer block rows than needed to keep the tentative
          prolongator full column rank) merge into an adjacent aggregate.

``luby_mis_device`` implements the paper's *future-work* device coarsener
(MATCOARSENMISKOKKOS, Sec. 6): parallel Luby rounds with deterministic hash
weights, entirely in ``jax.lax`` control flow (jitted, shapes static per
level), followed by a device root-attach pass.  It is ``gamg.setup``'s
*default* aggregation path (``coarsener="mis"``; the host greedy covering
stays available as ``coarsener="greedy"``) and keeps even the cold graph
phase on device for single-shard problems — completing the fully
device-resident cold setup the paper sketches.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strength import StrengthGraph


@dataclasses.dataclass(frozen=True)
class Aggregation:
    node_to_agg: np.ndarray   # (n,) aggregate id per node
    n_agg: int

    def sizes(self) -> np.ndarray:
        return np.bincount(self.node_to_agg, minlength=self.n_agg)


def greedy_aggregate(graph: StrengthGraph, min_size: int = 2) -> Aggregation:
    """Greedy disjoint covering of the strong-coupling graph (host)."""
    n = graph.n
    agg = np.full(n, -1, dtype=np.int64)
    indptr, indices = graph.indptr, graph.indices
    n_agg = 0
    # pass 1: root aggregates on untouched neighborhoods
    for i in range(n):
        if agg[i] >= 0:
            continue
        nbrs = indices[indptr[i]:indptr[i + 1]]
        if len(nbrs) and (agg[nbrs] >= 0).any():
            continue
        agg[i] = n_agg
        agg[nbrs] = n_agg
        n_agg += 1
    # pass 2: attach stragglers to the strongest adjacent aggregate
    weights = graph.weights
    for i in range(n):
        if agg[i] >= 0:
            continue
        sl = slice(indptr[i], indptr[i + 1])
        nbrs = indices[sl]
        if len(nbrs):
            aggd = agg[nbrs] >= 0
            if aggd.any():
                w = weights[sl][aggd]
                agg[i] = agg[nbrs[aggd][np.argmax(w)]]
                continue
        # pass 3 inline: isolated node roots a singleton
        agg[i] = n_agg
        n_agg += 1
    # undersized-aggregate repair: merge into an adjacent aggregate so the
    # tentative prolongator stays full column rank (bs_f * size >= nns)
    sizes = np.bincount(agg, minlength=n_agg)
    for i in range(n):
        a = agg[i]
        if sizes[a] >= min_size:
            continue
        nbrs = indices[indptr[i]:indptr[i + 1]]
        cand = nbrs[agg[nbrs] != a] if len(nbrs) else nbrs
        if len(cand):
            target = agg[cand[0]]
            sizes[target] += sizes[a]
            sizes[a] = 0
            agg[agg == a] = target
    # compact ids
    uniq, agg = np.unique(agg, return_inverse=True)
    return Aggregation(node_to_agg=agg.astype(np.int64), n_agg=len(uniq))


# ---------------------------------------------------------------------------
# Device Luby-MIS coarsener (paper Sec. 6 future work, implemented).
# ---------------------------------------------------------------------------

def _hash_weights(n: int, seed: int) -> jax.Array:
    """Deterministic per-vertex hash weights (Luby round priorities)."""
    x = jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(seed * 2654435761 + 1)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("seed",))
def luby_mis_device(nbr_idx: jax.Array, nbr_mask: jax.Array,
                    seed: int = 0) -> jax.Array:
    """Maximal independent set via deterministic Luby rounds, on device.

    nbr_idx:  (n, kmax) padded neighbor lists (ELL of the strength graph)
    nbr_mask: (n, kmax) validity
    returns   (n,) int32 state: 1 = in MIS, 0 = excluded
    """
    n = nbr_idx.shape[0]
    w = _hash_weights(n, seed)
    # state: 0 undecided, 1 in MIS, 2 excluded
    state0 = jnp.zeros(n, dtype=jnp.int32)

    def round_body(carry):
        state, it = carry
        undecided = state == 0
        # a vertex enters the MIS if it is undecided and its weight beats
        # every undecided neighbor (ties broken by index)
        nw = w[nbr_idx]                                    # (n, kmax)
        n_undecided = (state[nbr_idx] == 0) & nbr_mask
        my_key = w.astype(jnp.uint64) * n + jnp.arange(n, dtype=jnp.uint64)
        nbr_key = (nw.astype(jnp.uint64) * n
                   + nbr_idx.astype(jnp.uint64))
        beats = jnp.where(n_undecided, nbr_key > my_key[:, None], True)
        winner = undecided & jnp.all(beats, axis=1)
        state = jnp.where(winner, 1, state)
        # exclude neighbors of fresh winners
        nbr_in_mis = jnp.any((state[nbr_idx] == 1) & nbr_mask, axis=1)
        state = jnp.where((state == 0) & nbr_in_mis, 2, state)
        return state, it + 1

    def cond(carry):
        state, it = carry
        return jnp.any(state == 0) & (it < n + 2)

    state, _ = jax.lax.while_loop(cond, round_body, (state0, 0))
    return (state == 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("seed",))
def mis_aggregate_device(nbr_idx: jax.Array, nbr_mask: jax.Array,
                         seed: int = 0) -> jax.Array:
    """MIS roots claim their neighborhoods — device aggregation.

    Returns (n,) aggregate id per node (root nodes numbered densely), with
    non-adjacent leftovers attached to the nearest root within two hops.
    """
    n = nbr_idx.shape[0]
    in_mis = luby_mis_device(nbr_idx, nbr_mask)
    root_id = jnp.cumsum(in_mis) - 1                     # dense ids for roots
    agg = jnp.where(in_mis == 1, root_id, -1)

    def attach(agg, _):
        # undecided nodes adopt the first aggregated neighbor's id
        nbr_agg = jnp.where(nbr_mask, agg[nbr_idx], -1)   # (n, kmax)
        best = jnp.max(nbr_agg, axis=1)
        return jnp.where((agg < 0) & (best >= 0), best, agg), None

    agg, _ = jax.lax.scan(attach, agg, None, length=2)   # two hops
    # any leftovers (isolated): give each its own fresh id
    leftover = agg < 0
    fresh = jnp.cumsum(leftover) - 1 + jnp.max(agg) + 1
    return jnp.where(leftover, fresh, agg).astype(jnp.int32)


def aggregation_from_device(agg_dev: jax.Array) -> Aggregation:
    agg = np.asarray(agg_dev, dtype=np.int64)
    uniq, agg = np.unique(agg, return_inverse=True)
    return Aggregation(node_to_agg=agg, n_agg=len(uniq))


def graph_to_ell(graph: StrengthGraph):
    """Pad the strength graph to ELL for the device coarsener."""
    counts = np.diff(graph.indptr)
    kmax = max(int(counts.max()) if len(counts) else 0, 1)
    idx = np.zeros((graph.n, kmax), dtype=np.int32)
    mask = np.zeros((graph.n, kmax), dtype=bool)
    r = np.repeat(np.arange(graph.n), counts)
    within = np.arange(graph.nedges) - np.repeat(graph.indptr[:-1], counts)
    idx[r, within] = graph.indices
    mask[r, within] = True
    return jnp.asarray(idx), jnp.asarray(mask)
