"""Tentative prolongator from the near-null space (paper Sec. 2.2).

Each aggregate contributes ``nns`` coarse degrees of freedom (six rigid-body
modes for 3D elasticity), so the tentative prolongator P~ has rectangular
``bs_f x nns`` blocks — the shape square-BSR vendor formats cannot store and
the reason this framework exists.

Construction: stack the near-null-space rows of every aggregate, batched
(reduced) QR on device, Q gives the prolongator blocks and R the coarse
near-null space.  Aggregates are padded to the maximum size with zero rows;
because R is invertible (the aggregator guarantees >= nns rows per
aggregate), padded rows of Q are exactly zero and are simply not stored.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import Aggregation
from repro.core.block_csr import BlockCSR

Array = jnp.ndarray


def tentative_prolongator(aggr: Aggregation, B: Array, bs_f: int
                          ) -> Tuple[BlockCSR, Array]:
    """Build P~ (block rows = fine nodes, block cols = aggregates) and B_c.

    B: (n_nodes * bs_f, nns) fine near-null space.
    Returns (P~ as BlockCSR with (bs_f x nns) blocks, B_c (n_agg*nns, nns)).
    """
    n_nodes = len(aggr.node_to_agg)
    nns = B.shape[1]
    assert B.shape[0] == n_nodes * bs_f, (B.shape, n_nodes, bs_f)
    sizes = aggr.sizes()
    max_sz = int(sizes.max())
    assert (sizes * bs_f >= nns).all(), (
        "aggregate too small for full-rank tentative prolongator; "
        "the aggregator's min_size repair should prevent this")
    # order nodes by aggregate; position of each node within its aggregate
    order = np.argsort(aggr.node_to_agg, kind="stable")
    agg_sorted = aggr.node_to_agg[order]
    starts = np.zeros(aggr.n_agg + 1, dtype=np.int64)
    np.add.at(starts, agg_sorted + 1, 1)
    starts = np.cumsum(starts)
    pos_in_agg = np.arange(n_nodes) - starts[agg_sorted]

    # padded per-aggregate near-null blocks: (n_agg, max_sz, bs_f, nns)
    Bn = B.reshape(n_nodes, bs_f, nns)
    padded = jnp.zeros((aggr.n_agg, max_sz, bs_f, nns), B.dtype)
    padded = padded.at[agg_sorted, pos_in_agg].set(Bn[order])
    stacked = padded.reshape(aggr.n_agg, max_sz * bs_f, nns)

    Q, R = jnp.linalg.qr(stacked)            # (n_agg, max_sz*bs_f, nns)
    # sign-fix for determinism: positive R diagonal
    sgn = jnp.sign(jnp.diagonal(R, axis1=1, axis2=2))
    sgn = jnp.where(sgn == 0, 1.0, sgn)
    Q = Q * sgn[:, None, :]
    R = R * sgn[:, :, None]

    # extract each node's (bs_f x nns) slice of its aggregate's Q
    Qb = Q.reshape(aggr.n_agg, max_sz, bs_f, nns)
    p_data = Qb[agg_sorted, pos_in_agg]      # (n_nodes, bs_f, nns) sorted
    # back to node order; one block per node row, column = aggregate
    inv = np.empty(n_nodes, dtype=np.int64)
    inv[order] = np.arange(n_nodes)
    p_data = p_data[inv]
    indptr = np.arange(n_nodes + 1, dtype=np.int64)
    indices = aggr.node_to_agg.astype(np.int32)
    P = BlockCSR.from_arrays(indptr, indices, p_data, aggr.n_agg)
    B_c = R.reshape(aggr.n_agg * nns, nns)
    return P, B_c
