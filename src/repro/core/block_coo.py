"""Blocked COO assembly — ``MatCOOUseBlockIndices`` (paper Secs. 3.4, 5).

PETSc's device-assembly path is coordinate format: declare the (i, j)
coordinates of every contribution once (``MatSetPreallocationCOO``), build a
cached communication-and-scatter plan, then every numeric assembly is a
single device scatter-sum (``MatSetValuesCOO``).  The paper generalizes the
coordinates to address dense ``bs_r x bs_c`` blocks, shrinking every plan
array by the block area.

Functional JAX rendering:

* ``BlockCOOPlan`` = the symbolic phase.  Built once on the host from the
  block coordinates; owns the output ``BlockCSR`` structure, the stable sort
  order and the duplicate-summation segment map.
* ``set_values_coo(plan, values)`` = the numeric phase.  A single jitted
  gather + sorted ``segment_sum`` over block payloads (or the Pallas
  ``block_seg_sum`` kernel), entirely device-resident.

Negative coordinates are ignored (the PETSc convention used by boundary
conditions); their payloads are dropped by the plan, not branched on at
runtime.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_csr import BlockCSR, coo_to_csr_structure

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BlockCOOPlan:
    """Cached symbolic assembly plan (the PETSc COO preallocation plan)."""

    indptr: np.ndarray        # output structure
    indices: np.ndarray
    nbr: int
    nbc: int
    br: int
    bc: int
    nnzb: int                 # deduped output blocks
    keep: np.ndarray          # indices of non-ignored input coordinates
    out_idx_sorted: np.ndarray  # per *sorted* kept coordinate: output slot
    order: np.ndarray         # stable sort of kept coordinates by (row, col)
    n_input: int              # declared coordinates (before drop/dedup)

    @property
    def plan_bytes(self) -> int:
        """Bytes of plan index data — the quantity the paper's blocked COO
        shrinks by the block area (Sec. 5)."""
        return (self.indptr.nbytes + self.indices.nbytes + self.keep.nbytes
                + self.out_idx_sorted.nbytes + self.order.nbytes)


def preallocate_coo(rows, cols, nbr: int, nbc: int, br: int, bc: int
                    ) -> BlockCOOPlan:
    """Symbolic phase: sort/unique block coordinates, build the scatter map.

    ``rows``/``cols`` are *block* coordinates of every contribution,
    duplicates allowed, negatives ignored.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    # ValueError, not assert: validation must survive ``python -O``
    if rows.shape != cols.shape:
        raise ValueError(f"rows/cols shape mismatch: {rows.shape} != "
                         f"{cols.shape}")
    keep = np.flatnonzero((rows >= 0) & (cols >= 0))
    kr, kc = rows[keep], cols[keep]
    if len(kr) and (kr.max() >= nbr or kc.max() >= nbc):
        raise ValueError(
            f"block coordinate out of range: max (row, col) = "
            f"({int(kr.max())}, {int(kc.max())}) for a {nbr} x {nbc} "
            f"block grid")
    indptr, indices, order, out_idx, nnzb = coo_to_csr_structure(
        kr, kc, nbr, sum_duplicates=True)
    # re-express out_idx in sorted order so the numeric segment_sum sees
    # monotone segment ids (indices_are_sorted=True fast path).
    out_idx_sorted = out_idx[order]
    return BlockCOOPlan(indptr=indptr, indices=indices, nbr=nbr, nbc=nbc,
                        br=br, bc=bc, nnzb=nnzb, keep=keep,
                        out_idx_sorted=out_idx_sorted.astype(np.int32),
                        order=order.astype(np.int64),
                        n_input=len(rows))


def set_values_coo(plan: BlockCOOPlan, values: Array, *,
                   use_kernel: bool | None = None,
                   interpret: bool | None = None) -> BlockCSR:
    """Numeric phase: one device scatter-sum of dense block payloads.

    ``values``: (n_input, br, bc) dense blocks, one per declared coordinate,
    in declaration order — exactly PETSc's MatSetValuesCOO value stream.
    ``use_kernel``/``interpret`` default per backend (Pallas streaming
    segment-sum on TPU, jnp ``segment_sum`` elsewhere).
    """
    from repro.kernels import backend as _backend
    expected = (plan.n_input, plan.br, plan.bc)
    if values.shape != expected:
        raise ValueError(f"value stream shape {values.shape} != {expected} "
                         f"(one ({plan.br}, {plan.bc}) block per declared "
                         f"coordinate, in declaration order)")
    vals = values[jnp.asarray(plan.keep)][jnp.asarray(plan.order)]
    seg = jnp.asarray(plan.out_idx_sorted)
    if _backend.resolve_use_kernel(use_kernel):
        from repro.kernels.block_seg_sum import ops as _k
        data = _k.block_seg_sum(
            vals, seg, plan.nnzb,
            interpret=_backend.resolve_interpret(interpret))
    else:
        data = jax.ops.segment_sum(vals, seg, num_segments=plan.nnzb,
                                   indices_are_sorted=True)
    return BlockCSR.from_arrays(plan.indptr, plan.indices, data, plan.nbc)


def set_values_coo_data(plan: BlockCOOPlan, values: Array) -> Array:
    """Numeric phase returning only the data array (for jitted pipelines)."""
    vals = values[jnp.asarray(plan.keep)][jnp.asarray(plan.order)]
    return jax.ops.segment_sum(vals, jnp.asarray(plan.out_idx_sorted),
                               num_segments=plan.nnzb,
                               indices_are_sorted=True)


def scalar_coo_plan_bytes(plan: BlockCOOPlan) -> int:
    """Index bytes the equivalent *scalar* COO plan would need.

    Every block coordinate expands to br*bc scalar coordinates, each carrying
    its own sort/scatter entries — the factor-of-block-area growth the paper
    removes (Sec. 5).  Used by benchmarks/table5_traffic.py.
    """
    area = plan.br * plan.bc
    n_in = len(plan.keep) * area
    nnz = plan.nnzb * area
    # indptr + indices + keep + out_idx + order at scalar granularity
    return (8 * (plan.nbr * plan.br + 1) + 4 * nnz + 8 * n_in + 4 * n_in
            + 8 * n_in)
