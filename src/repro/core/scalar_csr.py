"""Scalar AIJ (CSR) baseline — the format the paper compares against.

The paper's point is precisely that GAMG historically *required* this
expansion: every ``br x bc`` block becomes ``br*bc`` scalar entries, each
carrying its own 4-byte column index (paper Sec. 2.3 byte accounting).  This
module provides that expansion and keeps it quarantined: nothing on the
blocked coarsening path imports it (asserted by
``tests/test_no_scalar_expansion.py``), it exists only so the benchmarks can
measure the scalar baseline the paper measures.

A scalar CSR matrix is simply a ``BlockCSR`` with 1x1 blocks, so the whole
numeric machinery (SpMV, two-phase SpGEMM, PtAP, COO) is reused verbatim at
``bs=1`` — the same algorithm in both formats, which is what makes the
iteration-count parity test (paper Sec. 4.1) meaningful.
"""
from __future__ import annotations

import numpy as np

from repro.core.block_csr import BlockCSR


def expand_bcsr(A: BlockCSR) -> BlockCSR:
    """Expand blocked storage to scalar CSR (the AIJ conversion).

    This is the conversion the paper *eliminates* from the coarsening path;
    benchmarks use it to build the scalar baseline.
    """
    br, bc = A.br, A.bc
    nbr = A.nbr
    counts = np.diff(A.indptr)               # blocks per block row
    # scalar row i = I*br + a has counts[I]*bc entries
    s_counts = np.repeat(counts, br) * bc
    s_indptr = np.zeros(nbr * br + 1, dtype=np.int64)
    np.cumsum(s_counts, out=s_indptr[1:])
    # entries of scalar row (I, a): for each block k in row I (in order),
    # columns J*bc + [0..bc)
    blk_rows = np.repeat(np.arange(nbr), counts)           # per block nnz
    # order scalar entries as: block row I -> a in [0,br) -> block k -> b
    # within-row block offsets:
    order_cols = (A.indices[:, None] * bc
                  + np.arange(bc)[None, :]).astype(np.int32)  # (nnzb, bc)
    s_indices = np.empty(int(s_indptr[-1]), dtype=np.int32)
    data = np.asarray(A.data)                                  # (nnzb,br,bc)
    s_data = np.empty(int(s_indptr[-1]), dtype=data.dtype)
    # vectorized fill: for each block nnz, its bc columns appear in br rows.
    # scalar position of (block nnz k, a, b):
    #   s_indptr[I*br + a] + (k - indptr[I])*bc + b
    k_idx = np.arange(A.nnzb)
    base_in_row = (k_idx - A.indptr[blk_rows]) * bc            # (nnzb,)
    for a in range(br):
        pos = s_indptr[blk_rows * br + a] + base_in_row        # (nnzb,)
        cols_flat = order_cols.reshape(-1)
        pos_flat = (pos[:, None] + np.arange(bc)[None, :]).reshape(-1)
        s_indices[pos_flat] = cols_flat
        s_data[pos_flat] = data[:, a, :].reshape(-1)
    return BlockCSR.from_arrays(s_indptr, s_indices,
                                s_data.reshape(-1, 1, 1), A.nbc * bc)


def csr_matrix_bytes(A: BlockCSR, value_bytes: int = 8,
                     index_bytes: int = 4) -> int:
    """Steady-state matrix bytes in scalar CSR (paper Sec. 4.2 accounting)."""
    nnz = A.nnzb * A.br * A.bc
    nrows = A.nbr * A.br
    return nnz * (value_bytes + index_bytes) + (nrows + 1) * 8


def bcsr_matrix_bytes(A: BlockCSR, value_bytes: int = 8,
                      index_bytes: int = 4) -> int:
    """Steady-state matrix bytes in blocked storage: one index per block."""
    return (A.nnzb * (A.br * A.bc * value_bytes + index_bytes)
            + (A.nbr + 1) * 8)
