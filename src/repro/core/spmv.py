"""Blocked SpMV / SpMM — the V-cycle's dominant kernel (paper Sec. 4.2).

The blocked SpMV moves one 4-byte column index per ``br x bc`` block instead
of ``br*bc`` indexed scalars; for bs=3/fp64 that is 76 B per block vs 108 B
scalar — the paper's 1.42x traffic ceiling.  ``benchmarks/table5_traffic.py``
re-derives that accounting from these containers.

Two execution paths:

* ``spmv_ref`` — pure-jnp oracle over the ELL layout (always available).
* ``spmv`` — dispatches to the Pallas TPU kernel (``repro.kernels.block_spmv``)
  when ``use_kernel=True`` (validated in interpret mode on CPU), else the ref.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_csr import BlockCSR, BlockELL, EllTransposePlan
from repro.obs import trace as obs_trace

Array = jax.Array


@jax.jit
def spmv_ell(ell: BlockELL, x: Array) -> Array:
    """y = A @ x on the padded ELL layout.  x: (nbc*bc,) -> y: (nbr*br,)."""
    with obs_trace.span("spmv_ell"):
        nbc, bc, br = ell.nbc, ell.bc, ell.br
        xb = x.reshape(nbc, bc)
        gathered = xb[ell.indices]  # (nbr, kmax, bc); padded rows hit col 0,
        # but padded data blocks are exactly zero so they contribute nothing.
        y = jnp.einsum("rkab,rkb->ra", ell.data, gathered,
                       preferred_element_type=ell.data.dtype)
        return y.reshape(ell.nbr * br)


@jax.jit
def spmm_ell(ell: BlockELL, X: Array) -> Array:
    """Y = A @ X for multiple right-hand sides. X: (nbc*bc, m).

    ``m == 1`` delegates to ``spmv_ell`` so the single-column panel is
    *bitwise* the single-RHS result (same reduction graph) — the multi-RHS
    layer's k=1 exactness contract rests on this.
    """
    with obs_trace.span("spmm_ell"):
        nbc, bc, br = ell.nbc, ell.bc, ell.br
        m = X.shape[1]
        if m == 1:
            return spmv_ell(ell, X[:, 0])[:, None]
        xb = X.reshape(nbc, bc, m)
        gathered = xb[ell.indices]  # (nbr, kmax, bc, m)
        y = jnp.einsum("rkab,rkbm->ram", ell.data, gathered,
                       preferred_element_type=ell.data.dtype)
        return y.reshape(ell.nbr * br, m)


@jax.jit
def apply_ell_t(ell: BlockELL, pt: EllTransposePlan, x: Array) -> Array:
    """y = A^T @ x straight off A's ELL blocks (transpose-free restriction).

    ``pt`` (``repro.core.block_csr.transpose_apply_plan``) addresses A's own
    flattened ``(nbr*kmax, br, bc)`` payload, so the restriction reuses the
    prolongator's value stream byte-for-byte — the stored ``r_ell``
    duplicate is gone from the hierarchy.  Padded plan slots point at slot
    0 (a real block) and are zeroed by the mask.  Panel-polymorphic like
    ``apply_ell``: ``x`` is ``(nbr*br,)`` or ``(nbr*br, k)``.
    """
    with obs_trace.span("apply_ell_t"):
        nbr, kmax, br, bc = ell.data.shape
        blocks = ell.data.reshape(nbr * kmax, br, bc)[pt.gather]
        blocks = jnp.where(pt.mask[..., None, None], blocks, 0)
        xb = x.reshape((nbr, br) + x.shape[1:])
        xg = xb[pt.rows]                        # (nbc, tkmax, br[, k])
        y = jnp.einsum("ckab,cka...->cb...", blocks, xg,
                       preferred_element_type=ell.data.dtype)
        return y.reshape((ell.nbc * bc,) + x.shape[1:])


def apply_ell(ell: BlockELL, x: Array) -> Array:
    """Shape-polymorphic ELL apply: (n,) -> spmv_ell, (n, k) -> panel SpMM.

    The V-cycle and both Krylov paths route every operator application
    through this, so the whole solve hierarchy accepts column panels
    without duplicating the recursion.  The panel branch resolves the
    backend SpMM path (``repro.kernels.backend.resolve_spmm_path``), so
    the Pallas ``block_spmm`` kernel engages inside the jitted solves on
    TPU.  Resolution happens at *trace* time: like the cached
    ``backend()`` probe, ``REPRO_SPMM_PATH`` must be set before the
    first solve trace to affect a jitted hot path.
    """
    return spmv_ell(ell, x) if x.ndim == 1 else spmm(ell, x)


def spmv_bcsr_ref(A: BlockCSR, x: Array) -> Array:
    """Reference SpMV straight off BCSR (gather + segment-sum).

    Used as the oracle for property tests; the production path is the ELL
    kernel (regular layout — the TPU adaptation of the paper's BSR kernel).
    """
    rows = np.repeat(np.arange(A.nbr), np.diff(A.indptr))
    xb = x.reshape(A.nbc, A.bc)
    contrib = jnp.einsum("nab,nb->na", A.data, xb[A.indices])
    y = jax.ops.segment_sum(contrib, jnp.asarray(rows), num_segments=A.nbr,
                            indices_are_sorted=True)
    return y.reshape(A.nbr * A.br)


def spmv(A, x: Array, *, use_kernel: bool | None = None,
         interpret: bool | None = None, tile_rows: int | None = None,
         accum_dtype=None) -> Array:
    """Front door: accepts BlockCSR (converts) or BlockELL.

    ``use_kernel=None`` / ``interpret=None`` resolve per backend: the Pallas
    kernel compiled natively on TPU, the jnp reference elsewhere (see
    ``repro.kernels.backend``).  ``tile_rows=None`` resolves through the
    autotuner (``repro.kernels.autotune``, governed by ``REPRO_TUNE``) with
    the static default as fallback.  ``accum_dtype`` threads the kernel
    accumulator rule (None = native; the jnp reference path accumulates
    natively and low-precision callers should use the kernel path).
    """
    from repro.kernels import backend as _backend
    ell = A.to_ell() if isinstance(A, BlockCSR) else A
    if _backend.resolve_use_kernel(use_kernel):
        from repro.kernels.block_spmv import ops as _k
        return _k.block_spmv(ell, x,
                             interpret=_backend.resolve_interpret(interpret),
                             tile_rows=tile_rows, accum_dtype=accum_dtype)
    return spmv_ell(ell, x)


def spmm(A, X: Array, *, path: str | None = None,
         interpret: bool | None = None, tile_rows: int | None = None,
         accum_dtype=None) -> Array:
    """Multi-RHS front door: Y = A @ X, X: (n, k), A BlockCSR or BlockELL.

    ``path=None`` resolves per backend (``repro.kernels.backend
    .resolve_spmm_path``): the Pallas panel kernel where it compiles
    natively (TPU), the jnp reference elsewhere; ``REPRO_SPMM_PATH``
    forces it globally.  ``tile_rows=None`` resolves through the autotuner
    (``REPRO_TUNE``); ``accum_dtype`` threads the kernel accumulator
    (None = native).
    """
    from repro.kernels import backend as _backend
    ell = A.to_ell() if isinstance(A, BlockCSR) else A
    if _backend.resolve_spmm_path(path) == "kernel":
        from repro.kernels.block_spmm import ops as _k
        return _k.block_spmm(ell, X,
                             interpret=_backend.resolve_interpret(interpret),
                             tile_rows=tile_rows, accum_dtype=accum_dtype)
    return spmm_ell(ell, X)


# ---------------------------------------------------------------------------
# Scalar-CSR baseline SpMV (the format the paper compares against).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("nrows",))
def spmv_csr_ref(indices: Array, data: Array, row_of_nnz: Array, nrows: int,
                 x: Array) -> Array:
    """Scalar CSR SpMV via gather + sorted segment-sum (AIJ baseline)."""
    contrib = data * x[indices]
    return jax.ops.segment_sum(contrib, row_of_nnz, num_segments=nrows,
                               indices_are_sorted=True)


def residual(A, x: Array, b: Array, **kw) -> Array:
    return b - spmv(A, x, **kw)


@partial(jax.jit, static_argnames=("transpose_blocks",))
def block_diag_apply(diag_inv: Array, x: Array,
                     transpose_blocks: bool = False) -> Array:
    """y_i = D_i^{-1} x_i given pre-inverted (nbr, bs, bs) diagonal blocks.

    This is the point-block Jacobi application (paper's pbjacobi smoother).
    """
    nbr, bs = diag_inv.shape[0], diag_inv.shape[1]
    xb = x.reshape(nbr, bs)
    eq = "nba,nb->na" if transpose_blocks else "nab,nb->na"
    return jnp.einsum(eq, diag_inv, xb).reshape(-1)
