"""Prolongator smoothing P = (I - omega D^{-1} A) P~ (paper Sec. 2.2).

All blocked, no scalar conversion:

* ``D^{-1}`` is the batched inverse of the diagonal blocks (pbjacobi data —
  shared with the smoother);
* ``D^{-1} A`` is a block-row scaling of A's payloads (no structure change);
* the product with P~ uses the cached two-phase SpGEMM;
* the final subtraction is the *native block AXPY* over the union sparsity —
  the operation whose scalar fallback is the one residual conversion in the
  paper's cold path (Sec. 4.9), implemented natively here.

``omega = (4/3) / lambda_max(D^{-1}A)`` with lambda_max from a short device
power iteration (deterministic start vector).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_csr import BlockCSR
from repro.core.spgemm import (
    BlockAXPYPlan,
    block_axpy_numeric_data,
    block_axpy_symbolic,
    spgemm_numeric_data,
    spgemm_symbolic,
    SpGEMMPlan,
)

Array = jax.Array


@jax.jit
def invert_diag_blocks(diag: Array) -> Array:
    """Batched small-block inverse; the pbjacobi setup kernel."""
    return jnp.linalg.inv(diag)


def scale_rows_data(A: BlockCSR, dinv: Array) -> Array:
    """Payloads of D^{-1} A: left-multiply each block by its row's D^{-1}."""
    rows = np.repeat(np.arange(A.nbr), np.diff(A.indptr))
    return jnp.einsum("nab,nbc->nac", dinv[jnp.asarray(rows)], A.data,
                      preferred_element_type=A.data.dtype)


@partial(jax.jit, static_argnames=("nbr", "bs", "iters"))
def lambda_max_dinv_a(ell_indices: Array, dinva_ell_data: Array,
                      ell_mask: Array, nbr: int, bs: int,
                      iters: int = 10) -> Array:
    """lambda_max(D^{-1}A) by power iteration on the ELL layout (device)."""

    def spmv(xb):
        g = xb[ell_indices]                       # (nbr, kmax, bs)
        return jnp.einsum("rkab,rkb->ra", dinva_ell_data, g,
                          preferred_element_type=xb.dtype)

    x0 = jnp.ones((nbr, bs), dinva_ell_data.dtype)
    x0 = x0 / jnp.linalg.norm(x0)

    def body(_, x):
        y = spmv(x)
        # finfo tiny, not a literal: 1e-300 underflows to 0 below f64
        return y / jnp.maximum(jnp.linalg.norm(y), jnp.finfo(y.dtype).tiny)

    x = jax.lax.fori_loop(0, iters, body, x0)
    y = spmv(x)
    return jnp.linalg.norm(y)  # Rayleigh-ish estimate, GAMG style


def smoothed_prolongator(A: BlockCSR, P_tent: BlockCSR,
                         omega_scale: float = 4.0 / 3.0,
                         lam_max: Optional[Array] = None
                         ) -> Tuple[BlockCSR, Array, Array, dict]:
    """One damped-Jacobi smoothing step of the tentative prolongator.

    Returns (P, omega, lam_max, plans) where plans carries the cached
    symbolic pieces so hot hierarchy recomputes can redo the numeric
    smoothing without symbolic work.
    """
    dinv = invert_diag_blocks(A.diagonal_blocks())
    dinva_data = scale_rows_data(A, dinv)
    if lam_max is None:
        plan = A.ell_plan()
        lam_max = lambda_max_dinv_a(jnp.asarray(plan.indices),
                                    plan.ell_data(dinva_data),
                                    jnp.asarray(plan.mask), A.nbr, A.br)
    omega = omega_scale / lam_max
    DinvA = A.with_data(dinva_data)
    ap_plan = spgemm_symbolic(DinvA, P_tent)
    ap_data = spgemm_numeric_data(ap_plan, dinva_data, P_tent.data)
    AP = BlockCSR.from_arrays(ap_plan.indptr, ap_plan.indices, ap_data,
                              ap_plan.nbc)
    axpy_plan = block_axpy_symbolic(AP, P_tent)
    p_data = block_axpy_numeric_data(axpy_plan, -omega, ap_data, P_tent.data)
    P = BlockCSR.from_arrays(axpy_plan.indptr, axpy_plan.indices, p_data,
                             axpy_plan.nbc)
    plans = dict(ap_plan=ap_plan, axpy_plan=axpy_plan)
    return P, omega, lam_max, plans


def resmooth_prolongator_data(ap_plan: SpGEMMPlan, axpy_plan: BlockAXPYPlan,
                              a_data: Array, dinv: Array, omega: Array,
                              p_tent_data: Array,
                              row_of_nnz: Array) -> Array:
    """Hot numeric re-smoothing with cached plans (new A values, same P~)."""
    dinva = jnp.einsum("nab,nbc->nac", dinv[row_of_nnz], a_data,
                       preferred_element_type=a_data.dtype)
    ap = spgemm_numeric_data(ap_plan, dinva, p_tent_data)
    return block_axpy_numeric_data(axpy_plan, -omega, ap, p_tent_data)
