"""Galerkin triple product A_c = P^T A P with device-resident, state-gated
reuse — paper Sec. 3.5.

Production AMG reuses the hierarchy (P fixed) while A changes every
Newton/time step.  The paper caches everything on the prolongator side —
R = P^T, the off-process rows P_oth, the stacked operand and the symbolic
products — and gates the cache on P's object state, so the *hot* numeric
PtAP is a local blocked triple product plus an off-process reduction with no
host round trip.

Functional rendering: ``ptap_symbolic(A, P)`` builds a ``PtAPCache`` (host
symbolic work, done once); ``ptap_numeric(cache, a_data, p_data)`` is a pure
jitted function — the hot PtAP.  ``ptap()`` front door checks the state gate
exactly like PetscObjectState: if the caller passes a cache built for this
(P structure, A structure), zero symbolic work happens.

The distributed version (slab halo of the off-process operands over the rank
mesh, with the off-process prolongator rows P_oth cached device-side) lives
in ``repro.dist.pamg``; this module is the single-device core it shares.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_csr import BlockCSR, transpose_structure
from repro.core.spgemm import (
    SpGEMMPlan,
    spgemm_numeric_data,
    spgemm_symbolic,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PtAPCache:
    """Prolongator-side cached data, valid while (P, A) structures hold."""

    r_indptr: np.ndarray        # R = P^T structure
    r_indices: np.ndarray
    r_perm: np.ndarray          # numeric transpose permutation
    ap_plan: SpGEMMPlan         # A @ P
    ac_plan: SpGEMMPlan         # R @ (A @ P)
    p_state: int                # state gate: P's token at build time
    a_struct_state: int         # A's *structure* token (values may change)
    n_coarse: int               # coarse block dim
    bs_c: int                   # coarse block size

    @property
    def plan_bytes(self) -> int:
        return (self.r_indptr.nbytes + self.r_indices.nbytes
                + self.r_perm.nbytes + self.ap_plan.plan_bytes
                + self.ac_plan.plan_bytes)


def ptap_symbolic(A: BlockCSR, P: BlockCSR) -> PtAPCache:
    """Cold symbolic phase: transpose plan + both SpGEMM plans.

    Everything here is structure-only; it never touches A.data/P.data, so the
    same cache serves every numeric recompute with new values.
    """
    assert A.nbc == P.nbr and A.bc == P.br, "A (f x f) must feed P (f x c)"
    r_indptr, r_indices, r_perm = transpose_structure(P.indptr, P.indices,
                                                      P.nbc)
    # R is (n_coarse x n_fine) with (bs_c x bs_f) blocks
    R_struct = BlockCSR(r_indptr, r_indices,
                        jnp.zeros((P.nnzb, P.bc, P.br), P.data.dtype),
                        P.nbr, state_token=P.state_token)
    ap_plan = spgemm_symbolic(A, P)
    AP_struct = BlockCSR(ap_plan.indptr, ap_plan.indices,
                         jnp.zeros((ap_plan.nnzb, ap_plan.br, ap_plan.bc),
                                   A.data.dtype),
                         ap_plan.nbc, state_token=0)
    ac_plan = spgemm_symbolic(R_struct, AP_struct)
    return PtAPCache(r_indptr=r_indptr, r_indices=r_indices, r_perm=r_perm,
                     ap_plan=ap_plan, ac_plan=ac_plan,
                     p_state=P.state_token, a_struct_state=A.state_token,
                     n_coarse=P.nbc, bs_c=P.bc)


def ptap_numeric_data(cache: PtAPCache, a_data: Array, p_data: Array,
                      **kw) -> Array:
    """Hot PtAP: pure device function (local blocked triple product).

    Both Galerkin products (A @ P and R @ (A P)) share the SpGEMM numeric
    machinery; ``path=`` / ``interpret=`` flow through, so the backend
    default dispatches the fused tiled kernel on accelerators.
    """
    r_data = p_data[jnp.asarray(cache.r_perm)].transpose(0, 2, 1)
    ap_data = spgemm_numeric_data(cache.ap_plan, a_data, p_data, **kw)
    return spgemm_numeric_data(cache.ac_plan, r_data, ap_data, **kw)


def ptap_numeric(cache: PtAPCache, A: BlockCSR, P: BlockCSR, **kw
                 ) -> BlockCSR:
    data = ptap_numeric_data(cache, A.data, P.data, **kw)
    return BlockCSR.from_arrays(cache.ac_plan.indptr, cache.ac_plan.indices,
                                data, cache.n_coarse)


def ptap(A: BlockCSR, P: BlockCSR, cache: Optional[PtAPCache] = None,
         **kw) -> Tuple[BlockCSR, PtAPCache]:
    """Front door with the state gate.

    Matches PETSc semantics: MAT_REUSE_MATRIX with an up-to-date
    PetscObjectState reuses the cached prolongator-side data; anything else
    rebuilds symbolically (the "ungated" path measured in paper Table 3).
    """
    gate_ok = (cache is not None
               and cache.p_state == P.state_token
               and cache.a_struct_state == A.state_token)
    if not gate_ok:
        cache = ptap_symbolic(A, P)
    return ptap_numeric(cache, A, P, **kw), cache


def galerkin_flops(cache: PtAPCache, bs_f: int) -> int:
    """Useful flop count of the numeric phase (for the traffic model)."""
    # each AP pair: (br x bk)(bk x bc) => 2*br*bk*bc
    ap = cache.ap_plan
    ac = cache.ac_plan
    return (2 * ap.npairs * ap.br * bs_f * ap.bc
            + 2 * ac.npairs * ac.br * bs_f * ac.bc)
