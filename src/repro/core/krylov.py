"""Preconditioned conjugate gradients (the paper's Krylov accelerator).

Convergence is monitored on the *unpreconditioned* residual norm, matching
the paper's Sec. 4.1 ("with this norm the two formats converge in the same
iteration count to the same true residual") — which makes the blocked/scalar
iteration-parity test exact.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class CGResult(NamedTuple):
    x: Array
    iters: Array
    relres: Array
    converged: Array


def wrap_precond(apply_m: Callable[[Array], Array], precond_dtype,
                 outer_dtype) -> Callable[[Array], Array]:
    """The mixed-precision preconditioner boundary, in one place.

    Casts the residual down to ``precond_dtype`` before ``apply_m`` and
    the preconditioned direction back to ``outer_dtype`` after —
    iterative-refinement style.  Returns ``apply_m`` unchanged when no
    cast is needed, so full-precision callers stay bitwise.  Shared by
    ``pcg``, ``block_pcg`` and the distributed ``_rank_pcg``.
    """
    if precond_dtype is None:
        return apply_m
    pd = jnp.dtype(precond_dtype)
    outer = jnp.dtype(outer_dtype)
    if pd == outer:
        return apply_m

    def wrapped(r):
        return apply_m(r.astype(pd)).astype(outer)

    return wrapped


def pcg(apply_a: Callable[[Array], Array],
        apply_m: Callable[[Array], Array],
        b: Array, x0: Array | None = None, rtol: float = 1e-8,
        maxiter: int = 200, record_history: bool = False,
        precond_dtype=None):
    """Standard PCG; fixed SPD preconditioner (one AMG V-cycle).

    ``record_history=True`` (a static, trace-time switch — the default
    jitted hot path is unchanged) additionally returns the per-iteration
    unpreconditioned residual-norm trace as a fixed-size ``(maxiter,)``
    buffer: slot ``i`` holds ``||r||`` after iteration ``i+1``; slots past
    ``iters`` stay NaN.  Used by the benchmark/convergence plots.

    ``precond_dtype`` (static) is the mixed-precision boundary: when set,
    the residual is cast to that dtype before ``apply_m`` and the
    preconditioned direction cast back to ``b.dtype`` afterwards —
    iterative-refinement style, so the outer iteration (dots, updates,
    convergence monitor) stays at the Krylov dtype while the AMG V-cycle
    runs on a reduced-precision hierarchy (``PrecisionPolicy``).  ``None``
    or ``b.dtype`` leaves the call chain bitwise unchanged.

    Breakdown floor: the relative-residual denominator is floored at
    ``finfo(b.dtype).tiny`` — a *dtype-aware* floor, because a literal
    like 1e-300 underflows to 0 below f64 and turns the ``b == 0`` case
    into a 0/0 NaN ``relres``.  An all-zero right-hand side therefore
    reports ``converged=True, iters=0, relres=0`` at every Krylov dtype
    (``x = 0`` is its exact solution).
    """
    apply_m = wrap_precond(apply_m, precond_dtype, b.dtype)
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - apply_a(x)
    z = apply_m(r)
    p = z
    rz = jnp.vdot(r, z)
    bnorm = jnp.maximum(jnp.linalg.norm(b), jnp.finfo(b.dtype).tiny)
    rnorm = jnp.linalg.norm(r)

    def cond(state):
        x, r, z, p, rz, rnorm, k, hist = state
        return (rnorm > rtol * bnorm) & (k < maxiter)

    def body(state):
        x, r, z, p, rz, rnorm, k, hist = state
        Ap = apply_a(p)
        alpha = rz / jnp.vdot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_m(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        rnorm = jnp.linalg.norm(r)
        if record_history:
            hist = hist.at[k].set(rnorm)
        return x, r, z, p, rz_new, rnorm, k + 1, hist

    hist0 = (jnp.full((maxiter,), jnp.nan, rnorm.dtype) if record_history
             else jnp.zeros((0,), rnorm.dtype))
    state = (x, r, z, p, rz, rnorm, jnp.asarray(0), hist0)
    x, r, z, p, rz, rnorm, k, hist = jax.lax.while_loop(cond, body, state)
    res = CGResult(x=x, iters=k, relres=rnorm / bnorm,
                   converged=rnorm <= rtol * bnorm)
    return (res, hist) if record_history else res
