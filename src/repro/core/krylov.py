"""Preconditioned conjugate gradients (the paper's Krylov accelerator).

Convergence is monitored on the *unpreconditioned* residual norm, matching
the paper's Sec. 4.1 ("with this norm the two formats converge in the same
iteration count to the same true residual") — which makes the blocked/scalar
iteration-parity test exact.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class CGResult(NamedTuple):
    x: Array
    iters: Array
    relres: Array
    converged: Array


def pcg(apply_a: Callable[[Array], Array],
        apply_m: Callable[[Array], Array],
        b: Array, x0: Array | None = None, rtol: float = 1e-8,
        maxiter: int = 200) -> CGResult:
    """Standard PCG; fixed SPD preconditioner (one AMG V-cycle)."""
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - apply_a(x)
    z = apply_m(r)
    p = z
    rz = jnp.vdot(r, z)
    bnorm = jnp.maximum(jnp.linalg.norm(b), 1e-300)
    rnorm = jnp.linalg.norm(r)

    def cond(state):
        x, r, z, p, rz, rnorm, k = state
        return (rnorm > rtol * bnorm) & (k < maxiter)

    def body(state):
        x, r, z, p, rz, rnorm, k = state
        Ap = apply_a(p)
        alpha = rz / jnp.vdot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_m(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return x, r, z, p, rz_new, jnp.linalg.norm(r), k + 1

    state = (x, r, z, p, rz, rnorm, jnp.asarray(0))
    x, r, z, p, rz, rnorm, k = jax.lax.while_loop(cond, body, state)
    return CGResult(x=x, iters=k, relres=rnorm / bnorm,
                    converged=rnorm <= rtol * bnorm)
