"""Preconditioned conjugate gradients (the paper's Krylov accelerator).

Convergence is monitored on the *unpreconditioned* residual norm, matching
the paper's Sec. 4.1 ("with this norm the two formats converge in the same
iteration count to the same true residual") — which makes the blocked/scalar
iteration-parity test exact.

Health monitoring (ISSUE 6): the while-loop carry additionally tracks
NaN/Inf, CG-breakdown and stagnation flags plus the best (minimum-residual)
iterate, surfaced as a structured ``SolveHealth`` on ``CGResult``.  All of
it is derived from reductions the recurrence already computes, so the
healthy path stays bitwise identical to the unmonitored loop (no extra
syncs, no retraces — pinned by ``tests/test_robust.py``).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.obs import trace as obs_trace
from repro.robust import inject
from repro.robust.health import SolveHealth, status_of

Array = jax.Array


class CGResult(NamedTuple):
    x: Array
    iters: Array
    relres: Array
    converged: Array
    health: SolveHealth
    # device-side solve counters (repro.obs.trace.CycleTally) when the
    # solve ran under REPRO_OBS=counters; None otherwise.  None is an
    # empty pytree node, so the default changes no traced structure.
    counters: "obs_trace.CycleTally | None" = None


def wrap_precond(apply_m: Callable[[Array], Array], precond_dtype,
                 outer_dtype) -> Callable[[Array], Array]:
    """The mixed-precision preconditioner boundary, in one place.

    Casts the residual down to ``precond_dtype`` before ``apply_m`` and
    the preconditioned direction back to ``outer_dtype`` after —
    iterative-refinement style.  Returns ``apply_m`` unchanged when no
    cast is needed, so full-precision callers stay bitwise.  Shared by
    ``pcg``, ``block_pcg`` and the distributed ``_rank_pcg``.
    """
    if precond_dtype is None:
        return apply_m
    pd = jnp.dtype(precond_dtype)
    outer = jnp.dtype(outer_dtype)
    if pd == outer:
        return apply_m

    def wrapped(r):
        return apply_m(r.astype(pd)).astype(outer)

    return wrapped


def pcg(apply_a: Callable[[Array], Array],
        apply_m: Callable[[Array], Array],
        b: Array, x0: Array | None = None, rtol: float = 1e-8,
        maxiter: int = 200, record_history: bool = False,
        precond_dtype=None, stall_window: int = 40, tally=None):
    """Standard PCG; fixed SPD preconditioner (one AMG V-cycle).

    ``x0`` warm-starts the iteration from a prior iterate (``None`` is
    the cold zero start, bitwise the classic recurrence).  CG's theory
    is start-agnostic — only the initial residual ``b - A x0`` matters —
    so a good seed (the previous quasi-static/Newton step's solution,
    threaded by the ``repro.sim`` march) begins within a few digits of
    the tolerance and converges in a fraction of the cold count.  An
    exact-solution seed reports ``iters=0, converged=True``: the
    pre-loop residual check is the same monitor the loop uses.

    ``record_history=True`` (a static, trace-time switch — the default
    jitted hot path is unchanged) additionally returns the per-iteration
    unpreconditioned residual-norm trace as a fixed-size ``(maxiter,)``
    buffer: slot ``i`` holds ``||r||`` after iteration ``i+1``; slots past
    ``iters`` stay NaN.  Used by the benchmark/convergence plots.

    ``precond_dtype`` (static) is the mixed-precision boundary: when set,
    the residual is cast to that dtype before ``apply_m`` and the
    preconditioned direction cast back to ``b.dtype`` afterwards —
    iterative-refinement style, so the outer iteration (dots, updates,
    convergence monitor) stays at the Krylov dtype while the AMG V-cycle
    runs on a reduced-precision hierarchy (``PrecisionPolicy``).  ``None``
    or ``b.dtype`` leaves the call chain bitwise unchanged.

    Breakdown floor: the relative-residual denominator is floored at
    ``finfo(b.dtype).tiny`` — a *dtype-aware* floor, because a literal
    like 1e-300 underflows to 0 below f64 and turns the ``b == 0`` case
    into a 0/0 NaN ``relres``.  An all-zero right-hand side therefore
    reports ``converged=True, iters=0, relres=0`` at every Krylov dtype
    (``x = 0`` is its exact solution).

    Health (``CGResult.health``, a ``SolveHealth``): the loop exits early
    on a NaN/Inf residual, on CG breakdown (non-positive ``p·Ap`` or
    ``r·z`` on an active step — e.g. an indefinite reduced-precision
    preconditioner) or after ``stall_window`` iterations without a new
    best residual (stagnation/divergence).  A broken step's update is
    discarded, and any non-converged exit returns the *minimum-residual*
    iterate — never a diverged or NaN one.  On a clean converging run
    every flag stays false and the iterates, iteration count and relres
    are bitwise those of the unmonitored recurrence.

    Counters (``tally=``, ISSUE 7): pass a ``repro.obs.trace.CycleTally``
    to thread device-side solve counters through the carry — ``apply_m``
    must then have the threaded signature ``(r, tally) -> (z, tally)``
    (``vcycle(..., tally=...)`` is exactly that) and the result's
    ``counters`` field carries the totals.  ``tally=None`` (default)
    adds an *empty* pytree node to the carry — zero leaves, zero jaxpr
    residue, the recurrence bitwise unchanged (``tests/test_obs.py``).
    """
    counted = tally is not None
    if counted:
        apply_m = obs_trace.wrap_threaded_precond(apply_m, precond_dtype,
                                                  b.dtype)
    else:
        apply_m = wrap_precond(apply_m, precond_dtype, b.dtype)
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - apply_a(x)
    if counted:
        tally = tally._replace(operator_applies=tally.operator_applies + 1)
        z, tally = apply_m(r, tally)
    else:
        z = apply_m(r)
    tl0 = tally if counted else ()
    p = z
    rz = jnp.vdot(r, z)
    bnorm = jnp.maximum(jnp.linalg.norm(b), jnp.finfo(b.dtype).tiny)
    rnorm = jnp.linalg.norm(r)
    # a poison rhs / x0 or a NaN first preconditioner apply is flagged
    # before the first iteration; an indefinite M shows as r·z <= 0
    nonf0 = ~jnp.isfinite(rnorm) | ~jnp.isfinite(rz)
    brk0 = ~nonf0 & (rz <= 0) & (rnorm > rtol * bnorm)

    def cond(state):
        (x, r, z, p, rz, rnorm, k, hist, best, stall, brk, nonf, tl) = state
        return ((rnorm > rtol * bnorm) & (k < maxiter)
                & ~brk & ~nonf & (stall < stall_window))

    def body(state):
        (x, r, z, p, rz, rnorm, k, hist,
         (best_x, best_rnorm, best_k), stall, brk, nonf, tl) = state
        Ap = inject.maybe("spmv", apply_a(p), step=k)
        pAp = jnp.vdot(p, Ap)
        alpha = rz / pAp
        x_new = x + alpha * p
        r_new = r - alpha * Ap
        if counted:
            tl = tl._replace(operator_applies=tl.operator_applies + 1)
            z_new, tl = apply_m(r_new, tl)
            z_new = inject.maybe("precond", z_new, step=k)
        else:
            z_new = inject.maybe("precond", apply_m(r_new), step=k)
        rz_new = jnp.vdot(r_new, z_new)
        beta = rz_new / rz
        p_new = z_new + beta * p
        rnorm_new = jnp.linalg.norm(r_new)
        nonf_new = (~jnp.isfinite(pAp) | ~jnp.isfinite(rnorm_new)
                    | ~jnp.isfinite(rz_new))
        brk_new = ~nonf_new & ((pAp <= 0)
                               | ((rz_new <= 0)
                                  & (rnorm_new > rtol * bnorm)))
        ok_step = ~(nonf_new | brk_new)
        # a broken step's update is discarded — the carry keeps the last
        # healthy state and the loop exits through the flag
        x = jnp.where(ok_step, x_new, x)
        r = jnp.where(ok_step, r_new, r)
        z = jnp.where(ok_step, z_new, z)
        p = jnp.where(ok_step, p_new, p)
        rz = jnp.where(ok_step, rz_new, rz)
        rnorm = jnp.where(ok_step, rnorm_new, rnorm)
        if record_history:
            hist = hist.at[k].set(rnorm)
        improved = ok_step & (rnorm_new < best_rnorm)
        best_x = jnp.where(improved, x_new, best_x)
        best_rnorm = jnp.where(improved, rnorm_new, best_rnorm)
        best_k = jnp.where(improved, k + 1, best_k)
        stall = jnp.where(improved, 0, stall + 1)
        return (x, r, z, p, rz, rnorm, k + 1, hist,
                (best_x, best_rnorm, best_k), stall,
                brk | brk_new, nonf | nonf_new, tl)

    hist0 = (jnp.full((maxiter,), jnp.nan, rnorm.dtype) if record_history
             else jnp.zeros((0,), rnorm.dtype))
    # a NaN initial residual must not poison the best-so-far tracking
    # (identity when rnorm is finite, i.e. on every healthy run)
    best_rnorm0 = jnp.where(jnp.isfinite(rnorm), rnorm, jnp.inf)
    state = (x, r, z, p, rz, rnorm, jnp.asarray(0), hist0,
             (x, best_rnorm0, jnp.asarray(0)), jnp.asarray(0), brk0, nonf0,
             tl0)
    (x, r, z, p, rz, rnorm, k, hist,
     (best_x, best_rnorm, best_k), stall, brk, nonf, tl_out) = \
        jax.lax.while_loop(cond, body, state)
    converged = rnorm <= rtol * bnorm
    # early termination (breakdown, stagnation, max-iters) returns the
    # minimum-residual iterate, not the last one
    x_out = jnp.where(converged, x, best_x)
    rnorm_out = jnp.where(converged, rnorm, best_rnorm)
    stag = ~converged & ~brk & ~nonf & (stall >= stall_window)
    health = SolveHealth(
        status=status_of(converged, brk, nonf, stag),
        breakdown=brk, nonfinite=nonf, stagnation=stag,
        best_iter=jnp.asarray(best_k, jnp.int32),
        best_relres=best_rnorm / bnorm)
    res = CGResult(x=x_out, iters=k, relres=rnorm_out / bnorm,
                   converged=converged, health=health,
                   counters=tl_out if counted else None)
    return (res, hist) if record_history else res
