"""Two-phase rectangular-block SpGEMM (C = A @ B).

The Galerkin product is the paper's second hot kernel.  Mixed block sizes
(A: br_a x k, B: k x bc_b) are exactly what the vendor square-BSR formats
cannot express (paper Sec. 2.4) and what this module is templated on.

Phases, mirroring cuSPARSE/PETSc symbolic+numeric:

symbolic (host, cached)
    Expand the multiply into a flat *pair list*: pair p contributes
    ``A.data[pair_a[p]] @ B.data[pair_b[p]]`` to output block
    ``out_idx[p]``.  Pairs are sorted by output slot, so the numeric scatter
    is a sorted segment reduction.  The pair list is the JAX analogue of the
    spgemm symbolic buffer whose bs^2-inflated scalar version OOMs the GPU in
    paper Sec. 4.5 — ``plan_bytes``/``scalar_plan_bytes`` quantify that.

numeric (device, jitted)
    Three paths, selected by ``path=`` (``None`` -> backend default, see
    ``repro.kernels.backend``):

    "fused"      the hot path.  The symbolic phase additionally re-packs the
                 sorted pair list into a *tiled* fixed-width layout (one row
                 of ``pair_kmax`` zero-padded pair slots per output block,
                 ELL-of-pairs), and ``repro.kernels.fused_pair_gemm`` runs
                 gather -> rectangular block GEMM -> segment reduce as one
                 ``pallas_call`` that accumulates each output block in VMEM.
                 The ``(npairs, br, bc)`` pair-product array never touches
                 HBM.
    "pairs"      the unfused kernel chain: gather -> batched block GEMM
                 (``repro.kernels.block_pair_gemm``) -> streaming segment
                 sum (``repro.kernels.block_seg_sum``); materializes the
                 pair products.
    "reference"  einsum + sorted ``segment_sum`` — the always-available
                 oracle the fused path is validated against.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_csr import BlockCSR

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SpGEMMPlan:
    """Cached symbolic phase of C = A @ B (structure-only function)."""

    indptr: np.ndarray       # C structure
    indices: np.ndarray
    nbr: int                 # C block rows
    nbc: int                 # C block cols
    br: int                  # C block shape
    bc: int
    bk: int                  # inner (contracted) block dim: A.bc == B.br
    nnzb: int
    pair_a: np.ndarray       # (npairs,) indices into A.data
    pair_b: np.ndarray       # (npairs,) indices into B.data
    out_idx: np.ndarray      # (npairs,) sorted output slot per pair
    a_state: int             # state tokens of the operands the plan matches
    b_state: int
    # Tiled (ELL-of-pairs) layout for the fused one-pass numeric kernel:
    # each tile row holds up to ``pair_kmax`` zero-padded pair slots of ONE
    # output block, so each kernel grid step owns a contiguous run of rows
    # and reduces them entirely in VMEM.  ``pair_kmax`` is chosen from the
    # pair histogram to minimize modeled traffic; output blocks with more
    # pairs span several consecutive rows (``tile_seg`` maps row -> output
    # slot) and their partials are combined by an O(nnzb)-sized sorted
    # segment-sum — never an O(npairs) one.  When no slot overflows
    # (``tile_identity``) the kernel's output IS C.data: a true single pass.
    tile_pair_a: np.ndarray  # (tile_rows, pair_kmax) int32 into A.data
    tile_pair_b: np.ndarray  # (tile_rows, pair_kmax) int32 into B.data
    tile_mask: np.ndarray    # (tile_rows, pair_kmax) bool, False on padding
    tile_seg: np.ndarray     # (tile_rows,) int32 sorted output slot per row
    tile_identity: bool      # tile_seg == arange(nnzb): no combine needed

    @property
    def npairs(self) -> int:
        return int(self.pair_a.shape[0])

    @property
    def pair_kmax(self) -> int:
        """Tile width: pair slots per tile row (histogram-chosen)."""
        return int(self.tile_pair_a.shape[1])

    @property
    def tile_rows(self) -> int:
        return int(self.tile_pair_a.shape[0])

    @property
    def tile_fill(self) -> float:
        """Occupancy of the tiled layout (1.0 = no padding waste)."""
        cells = self.tile_pair_a.size
        return self.npairs / cells if cells else 1.0

    @property
    def plan_bytes(self) -> int:
        return (self.indptr.nbytes + self.indices.nbytes + self.pair_a.nbytes
                + self.pair_b.nbytes + self.out_idx.nbytes)

    @property
    def plan_tiled_bytes(self) -> int:
        """Index bytes of the tiled layout (the fused path's whole plan)."""
        return (self.indptr.nbytes + self.indices.nbytes
                + self.tile_pair_a.nbytes + self.tile_pair_b.nbytes
                + self.tile_mask.nbytes + self.tile_seg.nbytes)

    def numeric_intermediate_bytes(self, path: str = "fused",
                                   itemsize: int = 8) -> int:
        """Peak HBM bytes of numeric-phase intermediates.

        The unfused paths materialize the gathered operands *and* the
        ``(npairs, br, bc)`` pair-product array; the fused path streams the
        gathered tiled operands and reduces in VMEM — at worst it adds the
        O(nnzb)-sized row partials when the histogram forced row splits.
        """
        br, bk, bc = self.br, self.bk, self.bc
        if path == "fused":
            operands = self.tile_pair_a.size * (br * bk + bk * bc) * itemsize
            partials = (0 if self.tile_identity
                        else self.tile_rows * br * bc * itemsize)
            return operands + partials
        lhs_rhs = self.npairs * (br * bk + bk * bc) * itemsize
        prod = self.npairs * br * bc * itemsize
        return lhs_rhs + prod

    def scalar_plan_bytes(self, bk: int) -> int:
        """Pair-list bytes if the same product ran in scalar CSR.

        Each block pair (br x bk)·(bk x bc) expands to br*bc output scalars
        times bk scalar multiply pairs — the bs^2/bs^3 growth behind the
        cuSPARSE symbolic-buffer OOM of paper Sec. 4.5.
        """
        scalar_pairs = self.npairs * self.br * self.bc * bk
        scalar_nnz = self.nnzb * self.br * self.bc
        return (8 * (self.nbr * self.br + 1) + 4 * scalar_nnz
                + (4 + 4 + 4) * scalar_pairs)


def spgemm_symbolic(A: BlockCSR, B: BlockCSR) -> SpGEMMPlan:
    """Host symbolic phase: C structure + flat pair lists."""
    assert A.nbc == B.nbr, (A.nbc, B.nbr)
    assert A.bc == B.br, ("inner block size mismatch", A.bc, B.br)
    nbr, nbc = A.nbr, B.nbc
    a_counts = np.diff(A.indptr)
    a_rows = np.repeat(np.arange(nbr, dtype=np.int64), a_counts)
    j = A.indices.astype(np.int64)                    # mid index per A nnz
    b_counts = np.diff(B.indptr)
    per_a = b_counts[j]                               # B-row length per A nnz
    total = int(per_a.sum())
    pair_a = np.repeat(np.arange(A.nnzb, dtype=np.int64), per_a)
    starts = np.repeat(B.indptr[j], per_a)
    csum = np.zeros(A.nnzb + 1, dtype=np.int64)
    np.cumsum(per_a, out=csum[1:])
    within = np.arange(total, dtype=np.int64) - np.repeat(csum[:-1], per_a)
    pair_b = starts + within
    pair_row = np.repeat(a_rows, per_a)
    pair_col = B.indices[pair_b].astype(np.int64)
    # unique (row, col) -> C structure; sort pairs by output slot
    key = pair_row * nbc + pair_col
    order = np.argsort(key, kind="stable")
    skey = key[order]
    uniq, inv = np.unique(skey, return_inverse=True)
    u_rows = uniq // nbc
    u_cols = (uniq % nbc).astype(np.int32)
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, u_rows + 1, 1)
    indptr = np.cumsum(indptr)
    pair_a_s = pair_a[order]
    pair_b_s = pair_b[order]
    out_idx = inv.astype(np.int32)
    tile_a, tile_b, tile_mask, tile_seg, ident = _tile_pairs(
        pair_a_s, pair_b_s, out_idx, len(uniq), A.br, A.bc, B.bc)
    return SpGEMMPlan(indptr=indptr, indices=u_cols, nbr=nbr, nbc=nbc,
                      br=A.br, bc=B.bc, bk=A.bc, nnzb=len(uniq),
                      pair_a=pair_a_s, pair_b=pair_b_s, out_idx=out_idx,
                      a_state=A.state_token, b_state=B.state_token,
                      tile_pair_a=tile_a, tile_pair_b=tile_b,
                      tile_mask=tile_mask, tile_seg=tile_seg,
                      tile_identity=ident)


def _choose_tile_width(counts: np.ndarray, br: int, bk: int, bc: int) -> int:
    """Pick the tile width from the pair histogram by modeled traffic.

    Width k costs ``k * sum(ceil(c/k))`` operand cells (each moving one
    (br, bk) + one (bk, bc) block) plus, whenever any slot splits, a write +
    read of one (br, bc) partial per tile row.  Minimizing this trades ELL
    padding against the partial combine; skewed histograms (the R@AP stage)
    get a small k with row splits, tight ones get kmax and a true single
    pass.
    """
    kmax = int(counts.max())
    if kmax <= 1:
        return max(kmax, 1)
    hist = np.bincount(np.minimum(counts, kmax))
    vals = np.arange(len(hist), dtype=np.int64)
    nnzb = int((counts > 0).sum())
    operand = br * bk + bk * bc
    partial = 2 * br * bc
    if kmax <= 512:
        cands = np.arange(1, kmax + 1)
    else:  # pathological width: probe the histogram quantiles only
        qs = np.percentile(counts[counts > 0],
                           [25, 50, 75, 90, 95, 99]).astype(np.int64)
        cands = np.unique(np.clip(np.concatenate([qs, [kmax]]), 1, kmax))
    best_k, best_cost = kmax, None
    for k in cands:
        nrows = int((hist * -(-vals // k)).sum())
        cost = k * nrows * operand + (partial * nrows
                                      if nrows > nnzb else 0)
        if best_cost is None or cost < best_cost:
            best_cost, best_k = cost, int(k)
    return best_k


def _tile_pairs(pair_a: np.ndarray, pair_b: np.ndarray, out_idx: np.ndarray,
                nnzb: int, br: int, bk: int, bc: int):
    """Re-pack the sorted pair list into the fixed-width tiled layout.

    Rows of ``pair_kmax`` zero-padded pair slots; an output block with more
    pairs than the width gets consecutive rows (``tile_seg`` maps row ->
    slot).  Padded cells gather block 0 and are masked out (the numeric
    phase zeroes the gathered lhs, so padding contributes exactly 0.0).
    """
    npairs = len(out_idx)
    if not npairs or not nnzb:
        return (np.zeros((nnzb, 0), np.int32), np.zeros((nnzb, 0), np.int32),
                np.zeros((nnzb, 0), bool),
                np.arange(nnzb, dtype=np.int32), True)
    counts = np.bincount(out_idx, minlength=nnzb).astype(np.int64)
    width = _choose_tile_width(counts, br, bk, bc)
    rows_per_slot = -(-counts // width)          # ceil; 0 for empty slots
    nrows = int(rows_per_slot.sum())
    row_start = np.zeros(nnzb + 1, dtype=np.int64)
    np.cumsum(rows_per_slot, out=row_start[1:])
    seg = np.repeat(np.arange(nnzb, dtype=np.int32), rows_per_slot)
    starts = np.zeros(nnzb + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    within = np.arange(npairs, dtype=np.int64) - starts[out_idx]
    r_idx = row_start[out_idx] + within // width
    c_idx = within % width
    tile_a = np.zeros((nrows, width), dtype=np.int32)
    tile_b = np.zeros((nrows, width), dtype=np.int32)
    mask = np.zeros((nrows, width), dtype=bool)
    tile_a[r_idx, c_idx] = pair_a
    tile_b[r_idx, c_idx] = pair_b
    mask[r_idx, c_idx] = True
    ident = nrows == nnzb and bool(np.array_equal(
        seg, np.arange(nnzb, dtype=np.int32)))
    return tile_a, tile_b, mask, seg, ident


def spgemm_numeric_data(plan: SpGEMMPlan, a_data: Array, b_data: Array, *,
                        path: str | None = None,
                        use_kernel: bool | None = None,
                        interpret: bool | None = None,
                        tile_slots: int | None = None,
                        accum_dtype=None) -> Array:
    """Device numeric phase -> C.data.  Pure function of the plan + values.

    ``path`` selects the execution strategy ("fused" | "pairs" |
    "reference"); ``None`` resolves the backend default — fused on TPU,
    reference on CPU *and* GPU (Pallas does not lower these block shapes
    via Triton yet; see ``repro.kernels.backend``).  The
    legacy knob maps ``use_kernel=True`` to ``path="pairs"`` and an
    explicit ``use_kernel=False`` to ``path="reference"``.
    ``accum_dtype`` is the contraction/reduction accumulator on every path
    (None = native in ``a_data.dtype``; output always at ``a_data.dtype``).
    """
    from repro.kernels import backend as _backend
    if path is None and use_kernel is not None:
        path = "pairs" if use_kernel else "reference"
    path = _backend.resolve_spgemm_path(path)
    interpret = _backend.resolve_interpret(interpret)
    if path == "fused":
        return _fused_numeric(plan, a_data, b_data, interpret=interpret,
                              tile_slots=tile_slots,
                              accum_dtype=accum_dtype)
    pa = jnp.asarray(plan.pair_a)
    pb = jnp.asarray(plan.pair_b)
    seg = jnp.asarray(plan.out_idx)
    lhs = a_data[pa]                     # (npairs, br, bk)
    rhs = b_data[pb]                     # (npairs, bk, bc)
    if path == "pairs":
        # cast the operands up *before* the kernel chain so the pair
        # products stay at the accumulator between block_pair_gemm and
        # block_seg_sum (rounding each product back to the payload dtype
        # in between would violate the round-once accumulator rule)
        acc = (jnp.dtype(accum_dtype) if accum_dtype is not None
               else a_data.dtype)
        from repro.kernels.block_pair_gemm import ops as _kg
        prod = _kg.block_pair_gemm(lhs.astype(acc), rhs.astype(acc),
                                   interpret=interpret)
        from repro.kernels.block_seg_sum import ops as _ks
        out = _ks.block_seg_sum(prod, seg, plan.nnzb, interpret=interpret)
        return out.astype(a_data.dtype)
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else a_data.dtype
    prod = jnp.einsum("pij,pjk->pik", lhs.astype(acc), rhs.astype(acc),
                      preferred_element_type=acc)
    return jax.ops.segment_sum(prod, seg, num_segments=plan.nnzb,
                               indices_are_sorted=True).astype(a_data.dtype)


def _fused_numeric(plan: SpGEMMPlan, a_data: Array, b_data: Array, *,
                   interpret: bool, tile_slots: int | None = None,
                   accum_dtype=None) -> Array:
    """One-pass numeric phase over the tiled plan layout.

    Gathers the A/B blocks into the fixed-width ELL-of-pairs operand stream
    (padded lhs slots zeroed, so padding contributes exactly 0.0) and hands
    it to the fused Pallas kernel, which contracts and reduces each output
    block in VMEM.  No array of shape ``(npairs, br, bc)`` is ever built.
    """
    from repro.kernels.fused_pair_gemm import ops as _kf
    ta = jnp.asarray(plan.tile_pair_a)
    tb = jnp.asarray(plan.tile_pair_b)
    mask = jnp.asarray(plan.tile_mask)
    lhs = jnp.where(mask[..., None, None], a_data[ta], 0)
    rhs = b_data[tb]                     # (tile_rows, kmax, bk, bc)
    out = _kf.fused_pair_gemm(lhs, rhs, interpret=interpret,
                              tile_slots=tile_slots,
                              accum_dtype=accum_dtype)
    if plan.tile_identity:
        return out
    # histogram-forced row splits: combine the O(nnzb)-sized row partials
    # (never the O(npairs) pair products), at the accumulator dtype
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else out.dtype
    return jax.ops.segment_sum(out.astype(acc), jnp.asarray(plan.tile_seg),
                               num_segments=plan.nnzb,
                               indices_are_sorted=True).astype(out.dtype)


def spgemm_numeric(plan: SpGEMMPlan, A: BlockCSR, B: BlockCSR, **kw
                   ) -> BlockCSR:
    data = spgemm_numeric_data(plan, A.data, B.data, **kw)
    return BlockCSR.from_arrays(plan.indptr, plan.indices, data, plan.nbc)


def spgemm(A: BlockCSR, B: BlockCSR, **kw) -> BlockCSR:
    """One-shot product (symbolic + numeric).  Hot paths cache the plan."""
    return spgemm_numeric(spgemm_symbolic(A, B), A, B, **kw)


# ---------------------------------------------------------------------------
# Native block AXPY (paper Sec. 4.9 future work — implemented here).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockAXPYPlan:
    """Union-sparsity plan for C = alpha*X + Y with different patterns.

    PETSc's MatAXPY falls back to a scalar conversion when the operands do
    not share a sparsity pattern — the one residual conversion in the
    paper's cold path.  This plan makes it native: a one-time symbolic union
    plus numeric scatter of both operands.
    """
    indptr: np.ndarray
    indices: np.ndarray
    nbr: int
    nbc: int
    x_slot: np.ndarray     # output slot of every X block
    y_slot: np.ndarray     # output slot of every Y block
    nnzb: int
    x_state: int
    y_state: int


def block_axpy_symbolic(X: BlockCSR, Y: BlockCSR) -> BlockAXPYPlan:
    assert X.nbr == Y.nbr and X.nbc == Y.nbc
    assert X.block_shape == Y.block_shape
    nbr, nbc = X.nbr, X.nbc
    xr = np.repeat(np.arange(nbr, dtype=np.int64), np.diff(X.indptr))
    yr = np.repeat(np.arange(nbr, dtype=np.int64), np.diff(Y.indptr))
    keys = np.concatenate([xr * nbc + X.indices, yr * nbc + Y.indices])
    uniq, inv = np.unique(keys, return_inverse=True)
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, (uniq // nbc) + 1, 1)
    return BlockAXPYPlan(indptr=np.cumsum(indptr),
                         indices=(uniq % nbc).astype(np.int32),
                         nbr=nbr, nbc=nbc,
                         x_slot=inv[:X.nnzb].astype(np.int64),
                         y_slot=inv[X.nnzb:].astype(np.int64),
                         nnzb=len(uniq),
                         x_state=X.state_token, y_state=Y.state_token)


def block_axpy_numeric_data(plan: BlockAXPYPlan, alpha, x_data: Array,
                            y_data: Array) -> Array:
    br, bc = x_data.shape[1], x_data.shape[2]
    out = jnp.zeros((plan.nnzb, br, bc), x_data.dtype)
    out = out.at[jnp.asarray(plan.x_slot)].add(alpha * x_data)
    out = out.at[jnp.asarray(plan.y_slot)].add(y_data)
    return out


def block_axpy(alpha, X: BlockCSR, Y: BlockCSR) -> BlockCSR:
    """C = alpha*X + Y, natively blocked, no scalar conversion."""
    plan = block_axpy_symbolic(X, Y)
    data = block_axpy_numeric_data(plan, alpha, X.data, Y.data)
    return BlockCSR.from_arrays(plan.indptr, plan.indices, data, plan.nbc)
