"""Two-phase rectangular-block SpGEMM (C = A @ B).

The Galerkin product is the paper's second hot kernel.  Mixed block sizes
(A: br_a x k, B: k x bc_b) are exactly what the vendor square-BSR formats
cannot express (paper Sec. 2.4) and what this module is templated on.

Phases, mirroring cuSPARSE/PETSc symbolic+numeric:

symbolic (host, cached)
    Expand the multiply into a flat *pair list*: pair p contributes
    ``A.data[pair_a[p]] @ B.data[pair_b[p]]`` to output block
    ``out_idx[p]``.  Pairs are sorted by output slot, so the numeric scatter
    is a sorted segment reduction.  The pair list is the JAX analogue of the
    spgemm symbolic buffer whose bs^2-inflated scalar version OOMs the GPU in
    paper Sec. 4.5 — ``plan_bytes``/``scalar_plan_bytes`` quantify that.

numeric (device, jitted)
    gather -> batched rectangular block GEMM -> sorted segment-sum.  The
    batched GEMM is the MXU hot spot and has a Pallas kernel
    (``repro.kernels.block_pair_gemm``); the segment-sum has
    ``repro.kernels.block_seg_sum``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_csr import BlockCSR

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SpGEMMPlan:
    """Cached symbolic phase of C = A @ B (structure-only function)."""

    indptr: np.ndarray       # C structure
    indices: np.ndarray
    nbr: int                 # C block rows
    nbc: int                 # C block cols
    br: int                  # C block shape
    bc: int
    nnzb: int
    pair_a: np.ndarray       # (npairs,) indices into A.data
    pair_b: np.ndarray       # (npairs,) indices into B.data
    out_idx: np.ndarray      # (npairs,) sorted output slot per pair
    a_state: int             # state tokens of the operands the plan matches
    b_state: int

    @property
    def npairs(self) -> int:
        return int(self.pair_a.shape[0])

    @property
    def plan_bytes(self) -> int:
        return (self.indptr.nbytes + self.indices.nbytes + self.pair_a.nbytes
                + self.pair_b.nbytes + self.out_idx.nbytes)

    def scalar_plan_bytes(self, bk: int) -> int:
        """Pair-list bytes if the same product ran in scalar CSR.

        Each block pair (br x bk)·(bk x bc) expands to br*bc output scalars
        times bk scalar multiply pairs — the bs^2/bs^3 growth behind the
        cuSPARSE symbolic-buffer OOM of paper Sec. 4.5.
        """
        scalar_pairs = self.npairs * self.br * self.bc * bk
        scalar_nnz = self.nnzb * self.br * self.bc
        return (8 * (self.nbr * self.br + 1) + 4 * scalar_nnz
                + (4 + 4 + 4) * scalar_pairs)


def spgemm_symbolic(A: BlockCSR, B: BlockCSR) -> SpGEMMPlan:
    """Host symbolic phase: C structure + flat pair lists."""
    assert A.nbc == B.nbr, (A.nbc, B.nbr)
    assert A.bc == B.br, ("inner block size mismatch", A.bc, B.br)
    nbr, nbc = A.nbr, B.nbc
    a_counts = np.diff(A.indptr)
    a_rows = np.repeat(np.arange(nbr, dtype=np.int64), a_counts)
    j = A.indices.astype(np.int64)                    # mid index per A nnz
    b_counts = np.diff(B.indptr)
    per_a = b_counts[j]                               # B-row length per A nnz
    total = int(per_a.sum())
    pair_a = np.repeat(np.arange(A.nnzb, dtype=np.int64), per_a)
    starts = np.repeat(B.indptr[j], per_a)
    csum = np.zeros(A.nnzb + 1, dtype=np.int64)
    np.cumsum(per_a, out=csum[1:])
    within = np.arange(total, dtype=np.int64) - np.repeat(csum[:-1], per_a)
    pair_b = starts + within
    pair_row = np.repeat(a_rows, per_a)
    pair_col = B.indices[pair_b].astype(np.int64)
    # unique (row, col) -> C structure; sort pairs by output slot
    key = pair_row * nbc + pair_col
    order = np.argsort(key, kind="stable")
    skey = key[order]
    uniq, inv = np.unique(skey, return_inverse=True)
    u_rows = uniq // nbc
    u_cols = (uniq % nbc).astype(np.int32)
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, u_rows + 1, 1)
    indptr = np.cumsum(indptr)
    return SpGEMMPlan(indptr=indptr, indices=u_cols, nbr=nbr, nbc=nbc,
                      br=A.br, bc=B.bc, nnzb=len(uniq),
                      pair_a=pair_a[order], pair_b=pair_b[order],
                      out_idx=inv.astype(np.int32),
                      a_state=A.state_token, b_state=B.state_token)


def spgemm_numeric_data(plan: SpGEMMPlan, a_data: Array, b_data: Array, *,
                        use_kernel: bool = False, interpret: bool = True
                        ) -> Array:
    """Device numeric phase -> C.data.  Pure function of the plan + values."""
    pa = jnp.asarray(plan.pair_a)
    pb = jnp.asarray(plan.pair_b)
    seg = jnp.asarray(plan.out_idx)
    lhs = a_data[pa]                     # (npairs, br, bk)
    rhs = b_data[pb]                     # (npairs, bk, bc)
    if use_kernel:
        from repro.kernels.block_pair_gemm import ops as _kg
        prod = _kg.block_pair_gemm(lhs, rhs, interpret=interpret)
        from repro.kernels.block_seg_sum import ops as _ks
        return _ks.block_seg_sum(prod, seg, plan.nnzb, interpret=interpret)
    prod = jnp.einsum("pij,pjk->pik", lhs, rhs,
                      preferred_element_type=a_data.dtype)
    return jax.ops.segment_sum(prod, seg, num_segments=plan.nnzb,
                               indices_are_sorted=True)


def spgemm_numeric(plan: SpGEMMPlan, A: BlockCSR, B: BlockCSR, **kw
                   ) -> BlockCSR:
    data = spgemm_numeric_data(plan, A.data, B.data, **kw)
    return BlockCSR.from_arrays(plan.indptr, plan.indices, data, plan.nbc)


def spgemm(A: BlockCSR, B: BlockCSR, **kw) -> BlockCSR:
    """One-shot product (symbolic + numeric).  Hot paths cache the plan."""
    return spgemm_numeric(spgemm_symbolic(A, B), A, B, **kw)


# ---------------------------------------------------------------------------
# Native block AXPY (paper Sec. 4.9 future work — implemented here).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockAXPYPlan:
    """Union-sparsity plan for C = alpha*X + Y with different patterns.

    PETSc's MatAXPY falls back to a scalar conversion when the operands do
    not share a sparsity pattern — the one residual conversion in the
    paper's cold path.  This plan makes it native: a one-time symbolic union
    plus numeric scatter of both operands.
    """
    indptr: np.ndarray
    indices: np.ndarray
    nbr: int
    nbc: int
    x_slot: np.ndarray     # output slot of every X block
    y_slot: np.ndarray     # output slot of every Y block
    nnzb: int
    x_state: int
    y_state: int


def block_axpy_symbolic(X: BlockCSR, Y: BlockCSR) -> BlockAXPYPlan:
    assert X.nbr == Y.nbr and X.nbc == Y.nbc
    assert X.block_shape == Y.block_shape
    nbr, nbc = X.nbr, X.nbc
    xr = np.repeat(np.arange(nbr, dtype=np.int64), np.diff(X.indptr))
    yr = np.repeat(np.arange(nbr, dtype=np.int64), np.diff(Y.indptr))
    keys = np.concatenate([xr * nbc + X.indices, yr * nbc + Y.indices])
    uniq, inv = np.unique(keys, return_inverse=True)
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, (uniq // nbc) + 1, 1)
    return BlockAXPYPlan(indptr=np.cumsum(indptr),
                         indices=(uniq % nbc).astype(np.int32),
                         nbr=nbr, nbc=nbc,
                         x_slot=inv[:X.nnzb].astype(np.int64),
                         y_slot=inv[X.nnzb:].astype(np.int64),
                         nnzb=len(uniq),
                         x_state=X.state_token, y_state=Y.state_token)


def block_axpy_numeric_data(plan: BlockAXPYPlan, alpha, x_data: Array,
                            y_data: Array) -> Array:
    br, bc = x_data.shape[1], x_data.shape[2]
    out = jnp.zeros((plan.nnzb, br, bc), x_data.dtype)
    out = out.at[jnp.asarray(plan.x_slot)].add(alpha * x_data)
    out = out.at[jnp.asarray(plan.y_slot)].add(y_data)
    return out


def block_axpy(alpha, X: BlockCSR, Y: BlockCSR) -> BlockCSR:
    """C = alpha*X + Y, natively blocked, no scalar conversion."""
    plan = block_axpy_symbolic(X, Y)
    data = block_axpy_numeric_data(plan, alpha, X.data, Y.data)
    return BlockCSR.from_arrays(plan.indptr, plan.indices, data, plan.nbc)
