"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early fusion, VQ image tokens share the text vocab (the
modality frontend is a stub: inputs are token ids over the fused vocab).
[arXiv:2405.09818; unverified]  Full attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536, activation="swiglu",
    subquadratic=False,
    notes="early-fusion VQ image tokens; frontend stubbed per spec")
