"""The paper's own configuration: 3D Q1/Q2 hex elasticity + GAMG.

Mirrors the experimental setup of Sec. 4.1: block size 3, GAMG with a
point-block-Jacobi-preconditioned smoother and a CG accelerator,
unpreconditioned residual norm, reused interpolation across solves, and the
weak-scaling ladder (one rank per accelerator, 98 304 unknowns per device).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ElasticityConfig:
    m: int                       # grid nodes per edge (m^3 node grid)
    order: int = 1               # 1 = Q1 (paper main), 2 = Q2 (Sec. 4.6)
    E: float = 1.0               # Young's modulus
    nu: float = 0.3              # Poisson ratio
    theta: float = 0.08          # strength-of-connection threshold
    smoother: str = "chebyshev"  # pbjacobi-preconditioned (paper default)
    degree: int = 2
    coarse_size: int = 100
    coarsener: str = "greedy"    # "mis" = device Luby-MIS (paper Sec. 6)
    rtol: float = 1e-8           # unpreconditioned residual norm
    maxiter: int = 200
    reuse_interpolation: bool = True   # -pc_gamg_reuse_interpolation
    # assembly path: "device" (JAX vmapped quadrature + DeviceAssembler —
    # enables the jitted update_coefficients hot loop) or "host" (numpy
    # golden reference)
    assembly: str = "device"
    # distributed placement: agglomerate levels at or below this many equations
    # per rank (PETSc -pc_gamg_process_eq_limit; None = dist default,
    # 0 = keep every level slab-sharded)
    coarse_eq_limit: "int | None" = None

    def build(self):
        """Assemble the problem and the solver (cold setup)."""
        from repro.core.gamg import GAMGSolver
        from repro.fem.assemble import assemble_elasticity
        prob = assemble_elasticity(self.m, order=self.order, E=self.E,
                                   nu=self.nu, path=self.assembly)
        solver = GAMGSolver(prob.A, prob.B, theta=self.theta,
                            smoother=self.smoother, degree=self.degree,
                            coarse_size=self.coarse_size,
                            coarsener=self.coarsener, rtol=self.rtol,
                            maxiter=self.maxiter,
                            coarse_eq_limit=self.coarse_eq_limit)
        if prob.assembler is not None:
            # device path: enable the jitted coefficient hot loop
            solver.bind_assembler(prob.assembler)
        return prob, solver


# the paper's weak-scaling ladder: m^3 node grids on {1, 8, 27, 64} devices,
# 98 304 unknowns per device (Sec. 4.1)
PAPER_LADDER: Tuple[Tuple[int, int], ...] = (
    (32, 1), (64, 8), (96, 27), (128, 64))

# the capacity experiment of Sec. 4.5: 128^3 packed onto 8 devices
CAPACITY_CASE = (128, 8)

# CPU-scale ladder used by benchmarks/ (same shapes, reduced m)
CPU_LADDER: Tuple[int, ...] = (7, 10, 13)

CONFIG = ElasticityConfig(m=32)
