"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "llama4-maverick-400b-a17b",
    "deepseek-v2-236b",
    "hymba-1.5b",
    "mistral-large-123b",
    "phi4-mini-3.8b",
    "gemma-7b",
    "qwen2-0.5b",
    "chameleon-34b",
    "falcon-mamba-7b",
    "whisper-small",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch == "elasticity":
        raise ValueError("elasticity config is solver-side: use "
                         "repro.configs.elasticity")
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
