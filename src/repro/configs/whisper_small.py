"""whisper-small [audio] — enc-dec, 12L decoder (+12L encoder),
d_model=768 12H d_ff=3072 vocab=51865; conv frontend is a STUB —
input_specs() provides precomputed frame embeddings (B, 1500, 768).
[arXiv:2212.04356; unverified]

Enc-dec (not encoder-only) -> decode shapes RUN (decoder + cross-attn
over cached encoder output); full attention -> long_500k skipped.
"""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865, activation="gelu",
    encdec=EncDecConfig(n_encoder_layers=12, encoder_frames=1500),
    subquadratic=False)
