"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 routed top-1 + 1 shared expert, early
fusion (text/image token stub).  [hf:meta-llama/Llama-4-*; unverified]

Full attention -> long_500k skipped (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048, activation="swiglu", rope_theta=5e5,
    moe=MoEConfig(n_experts=128, top_k=1, n_shared=1, d_ff_expert=8192,
                  moe_every=2),   # interleaved dense/MoE (400B total,
    #                               17B active; all-MoE would be ~780B)
    subquadratic=False,
    notes="early-fusion multimodal; image tokens share the vocab (stub)")
