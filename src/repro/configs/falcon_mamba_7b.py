"""falcon-mamba-7b [ssm] — 64L d_model=4096, attention-free mamba-1
blocks, ssm_state=16, vocab=65024.  [arXiv:2410.05355; unverified]
Attention-free -> long_500k RUNS."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=65024, attention="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    subquadratic=True)
