"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400; MLA kv_lora=512, q_lora=1536, qk 128 nope + 64 rope, v 128;
MoE 2 shared + 160 routed top-6.  [arXiv:2405.04434; hf]

Deviation noted in DESIGN.md: DSv2's first dense layer is made MoE so the
stack stays scan-homogeneous.  Full attention -> long_500k skipped.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=12288, vocab_size=102400, attention="mla", activation="swiglu",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
    subquadratic=False)
