"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads fused with
per-branch output norms.  [arXiv:2411.13676; hf]

Sliding-window attention (all layers; the paper's 3 global layers are
noted as a deviation) + SSM branch -> sub-quadratic: long_500k RUNS.
Meta tokens are omitted (frontend stub).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001, activation="swiglu", sliding_window=1024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    hybrid_parallel_ssm=True, subquadratic=True)
