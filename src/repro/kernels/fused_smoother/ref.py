"""Pure-jnp oracle for the fused smoother recurrence step."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("accum_dtype",))
def smoother_step_ref(indices: jax.Array, data: jax.Array, dinv: jax.Array,
                      b_blocks: jax.Array, x_blocks: jax.Array,
                      d_blocks: jax.Array, coef: jax.Array, *,
                      accum_dtype=None):
    """Same contract as the kernel: one step of

        d' = c1 * d + c2 * D^{-1}(b - A x),   x' = x + d'

    over (nbr, bs[, k]) block vectors, A in padded BlockELL form.
    ``accum_dtype`` mirrors the kernel's accumulator rule (None = native);
    results round back to ``data.dtype``.
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    xg = x_blocks[indices].astype(acc)            # (nbr, kmax, bs[, k])
    ax = jnp.einsum("rkab,rkb...->ra...", data.astype(acc), xg,
                    preferred_element_type=acc)
    r = b_blocks.astype(acc) - ax
    z = jnp.einsum("rab,rb...->ra...", dinv.astype(acc), r,
                   preferred_element_type=acc)
    d_new = (coef[0].astype(acc) * d_blocks.astype(acc)
             + coef[1].astype(acc) * z)
    x_new = x_blocks.astype(acc) + d_new
    return x_new.astype(data.dtype), d_new.astype(data.dtype)
