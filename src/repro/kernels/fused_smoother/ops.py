"""Jit'd wrapper for the fused smoother step on flat vectors.

``repro.core.vcycle.apply_smoother`` dispatches here when the smoother
path resolves to "fused" (``REPRO_SMOOTH_PATH``); the dist solver's
replicated tail rides the same dispatch, so single-device and distributed
smoothing share one source of truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_csr import BlockELL
from repro.kernels.fused_smoother.fused_smoother import smoother_step_ell
from repro.obs import trace as obs_trace


def smoother_step(a_ell: BlockELL, dinv: jax.Array, b: jax.Array,
                  x: jax.Array, d: jax.Array, c1, c2, *,
                  interpret: bool = True, tile_rows: int | None = None,
                  accum_dtype=None):
    """One fused step: d' = c1*d + c2*D^{-1}(b - A x), x' = x + d'.

    b/x/d are flat ``(n,)`` vectors or ``(n, k)`` panels; returns
    ``(x', d')`` in the same shape.  ``c1``/``c2`` may be python scalars
    or traced values.  ``tile_rows=None`` resolves through the autotuner
    (``repro.kernels.autotune``, governed by ``REPRO_TUNE``; static
    default 8).
    """
    with obs_trace.span("kernels/fused_smoother"):
        nbr, kmax, bs, _ = a_ell.data.shape
        if tile_rows is None:
            from repro.kernels import autotune
            tile_rows = autotune.resolve_param(
                "fused_smoother",
                dict(br=bs, bc=bs, kmax=kmax,
                     dtype=jnp.dtype(a_ell.data.dtype).name),
                "tile_rows", None, 8)
        shape = (nbr, bs) + b.shape[1:]
        dt = a_ell.data.dtype
        coef = jnp.stack([jnp.asarray(c1, dt), jnp.asarray(c2, dt)])
        x_new, d_new = smoother_step_ell(
            a_ell.indices, a_ell.data, dinv, b.reshape(shape),
            x.reshape(shape), d.reshape(shape), coef,
            tile_rows=tile_rows, interpret=interpret,
            accum_dtype=accum_dtype)
        return x_new.reshape(b.shape), d_new.reshape(b.shape)
