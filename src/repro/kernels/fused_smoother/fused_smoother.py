"""Pallas TPU kernel: one fused Chebyshev/Jacobi smoother recurrence step.

The unfused smoother recurrences in ``repro.core.vcycle`` materialize two
HBM intermediates per step — the residual ``r = b - A x`` and the
preconditioned residual ``z = D^{-1} r`` — each written by one dispatch and
re-read by the next.  This kernel computes the whole step

    d' = c1 * d + c2 * D^{-1}(b - A x)
    x' = x + d'

in a single pass per row tile: the A-row contraction, the dinv block
matvec, the direction recurrence and the iterate update all happen
on-register, so ``r`` and ``z`` never touch HBM.  Both smoothers are this
one step with different coefficients (Chebyshev: ``c1 = 0, c2 = 1/theta``
first, then ``c1 = rho' rho, c2 = 2 rho'/delta``; damped block-Jacobi:
``c1 = 0, c2 = omega`` every step) — see ``repro.core.vcycle``.

The residual is formed fresh from the *current* iterate each step (the
paper's ``x += f(D^{-1}(b - A x))`` form), which is mathematically
identical to the unfused incremental update ``r -= A d`` and differs only
in rounding.

Layout / tiling (mirrors ``block_spmv``)
  grid       = (ceil(nbr / TR),)                 sequential over row tiles
  coef       = (2,)               VMEM, whole    [c1, c2] at accum dtype
  index tile = (TR, kmax)         VMEM (int32)
  data tile  = (TR, kmax, bs, bs) VMEM           streamed per grid step
  dinv tile  = (TR, bs, bs)       VMEM
  b/d tiles  = (TR, bs[, k])      VMEM
  x          = (nbr, bs[, k])     VMEM, whole    (gathered by A's indices;
                                                  block-vector resident
                                                  like ``block_spmv``'s x)
  out tiles  = x' and d' (TR, bs[, k])

``accum_dtype`` follows the family contract: operands cast up on-register,
contracted/updated at that dtype, results rounded back to the payload
dtype (None = native).  Padded rows carry zero data/dinv/b/d blocks, so
the padded outputs are exact zeros and are sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _smoother_kernel(acc_dt, tr, coef_ref, idx_ref, data_ref, dinv_ref,
                     b_ref, d_ref, x_ref, ox_ref, od_ref):
    """One row tile: residual, precondition, recurrence, update — fused."""
    i = pl.program_id(0)
    idx = idx_ref[...]                        # (TR, kmax) int32
    kmax = idx.shape[1]
    x = x_ref[...]                            # (nbr, bs[, k]) whole
    # A x on this tile: gather whole x blocks, contract against A's tile
    xg = jnp.take(x, idx.reshape(-1), axis=0).reshape(
        (tr, kmax) + x.shape[1:]).astype(acc_dt)
    ax = jnp.einsum("rkab,rkb...->ra...", data_ref[...].astype(acc_dt), xg,
                    preferred_element_type=acc_dt)
    r = b_ref[...].astype(acc_dt) - ax        # residual, on-register only
    z = jnp.einsum("rab,rb...->ra...", dinv_ref[...].astype(acc_dt), r,
                   preferred_element_type=acc_dt)
    c1 = coef_ref[0].astype(acc_dt)
    c2 = coef_ref[1].astype(acc_dt)
    d_new = c1 * d_ref[...].astype(acc_dt) + c2 * z
    x_own = jax.lax.dynamic_slice_in_dim(x, i * tr, tr).astype(acc_dt)
    ox_ref[...] = (x_own + d_new).astype(ox_ref.dtype)
    od_ref[...] = d_new.astype(od_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_rows", "interpret", "accum_dtype"))
def smoother_step_ell(indices: jax.Array, data: jax.Array, dinv: jax.Array,
                      b_blocks: jax.Array, x_blocks: jax.Array,
                      d_blocks: jax.Array, coef: jax.Array, *,
                      tile_rows: int = 8, interpret: bool = True,
                      accum_dtype=None):
    """(x', d') for one fused recurrence step over block vectors.

    indices/data: A in padded BlockELL form (square: nbc == nbr)
    dinv:         (nbr, bs, bs) pre-inverted diagonal blocks
    b/x/d_blocks: (nbr, bs) or (nbr, bs, k) block vectors
    coef:         (2,) = [c1, c2]
    returns       (x', d') at ``data.dtype``
    """
    nbr, kmax, br, _ = data.shape
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    tr = min(tile_rows, nbr)
    pad = (-nbr) % tr
    vpad = ((0, pad), (0, 0)) + ((0, 0),) * (b_blocks.ndim - 2)
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        data = jnp.pad(data, ((0, pad), (0, 0), (0, 0), (0, 0)))
        dinv = jnp.pad(dinv, ((0, pad), (0, 0), (0, 0)))
        b_blocks = jnp.pad(b_blocks, vpad)
        d_blocks = jnp.pad(d_blocks, vpad)
        x_blocks = jnp.pad(x_blocks, vpad)
    grid = ((nbr + pad) // tr,)
    coef = coef.astype(acc_dt)
    vshape = (tr, br) + b_blocks.shape[2:]
    vmap_ = (lambda i: (i, 0)) if b_blocks.ndim == 2 else (
        lambda i: (i, 0, 0))
    xwhole = (lambda i: (0, 0)) if b_blocks.ndim == 2 else (
        lambda i: (0, 0, 0))
    out_shape = (nbr + pad, br) + b_blocks.shape[2:]
    x_new, d_new = pl.pallas_call(
        functools.partial(_smoother_kernel, acc_dt, tr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((tr, kmax), lambda i: (i, 0)),
            pl.BlockSpec((tr, kmax, br, br), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tr, br, br), lambda i: (i, 0, 0)),
            pl.BlockSpec(vshape, vmap_),
            pl.BlockSpec(vshape, vmap_),
            pl.BlockSpec(x_blocks.shape, xwhole),
        ],
        out_specs=(pl.BlockSpec(vshape, vmap_),
                   pl.BlockSpec(vshape, vmap_)),
        out_shape=(jax.ShapeDtypeStruct(out_shape, data.dtype),
                   jax.ShapeDtypeStruct(out_shape, data.dtype)),
        interpret=interpret,
    )(coef, indices, data, dinv, b_blocks, d_blocks, x_blocks)
    return x_new[:nbr], d_new[:nbr]
