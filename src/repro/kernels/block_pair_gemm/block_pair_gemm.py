"""Pallas TPU kernel: batched rectangular block GEMM (SpGEMM numeric hot
spot, paper Secs. 3.4/4.4).

The numeric Galerkin phase is a stream of tiny rectangular products
``(br x bk) @ (bk x bc)`` — the <3,3,6> shapes of the paper's
``RunNumericAB_SeqBAIJKokkos<3,3,6>`` kernel (Table 5).  On the GPU these are
one-warp-per-pair; on TPU the right shape is *batched VPU work*: a tile of
``TP`` pairs is one ``(TP, br, bk) x (TP, bk, bc)`` contraction, unrolled
over the tiny ``bk`` dimension so it maps onto 8x128 vector registers with
the pair dimension on the lanes.

The arithmetic-intensity argument (paper Sec. 4.7) carries over: a pair
moves O(bs^2) bytes and performs O(bs^3) flops plus one amortized index; at
bs=3..6 and fp64 this stays far below the TPU ridge, so the kernel is
bandwidth-bound and the win is moving bs^2x fewer index bytes.

Layout / tiling
  grid     = (ceil(npairs / TP),)
  lhs tile = (TP, br, bk)  VMEM
  rhs tile = (TP, bk, bc)  VMEM
  out tile = (TP, br, bc)  VMEM
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pair_gemm_kernel(acc_dt, lhs_ref, rhs_ref, o_ref):
    lhs = lhs_ref[...].astype(acc_dt)        # (TP, br, bk)
    rhs = rhs_ref[...].astype(acc_dt)        # (TP, bk, bc)
    # unroll the tiny contraction dim: TP stays on lanes, no transposes
    acc = jnp.zeros(o_ref.shape, acc_dt)
    for k in range(lhs.shape[2]):
        acc = acc + lhs[:, :, k][:, :, None] * rhs[:, k, :][:, None, :]
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_pairs", "interpret", "accum_dtype"))
def block_pair_gemm(lhs: jax.Array, rhs: jax.Array, *,
                    tile_pairs: int = 128, interpret: bool = True,
                    accum_dtype=None) -> jax.Array:
    """(npairs, br, bk) @ (npairs, bk, bc) -> (npairs, br, bc).

    ``accum_dtype`` is the on-register contraction dtype (None = native in
    ``lhs.dtype``, bitwise legacy); the output rounds back to ``lhs.dtype``.
    """
    npairs, br, bk = lhs.shape
    _, bk2, bc = rhs.shape
    assert bk == bk2, (bk, bk2)
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype is not None else lhs.dtype
    tp = min(tile_pairs, max(npairs, 1))
    pad = (-npairs) % tp
    if pad:
        lhs = jnp.pad(lhs, ((0, pad), (0, 0), (0, 0)))
        rhs = jnp.pad(rhs, ((0, pad), (0, 0), (0, 0)))
    grid = ((npairs + pad) // tp,)
    out = pl.pallas_call(
        functools.partial(_pair_gemm_kernel, acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp, br, bk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tp, bk, bc), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tp, br, bc), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((npairs + pad, br, bc), lhs.dtype),
        interpret=interpret,
    )(lhs, rhs)
    return out[:npairs]
