"""Jit'd wrapper for the batched block GEMM kernel."""
from repro.kernels.block_pair_gemm.block_pair_gemm import (
    block_pair_gemm as _block_pair_gemm,
)
from repro.obs import trace as obs_trace

__all__ = ["block_pair_gemm"]


def block_pair_gemm(*args, **kwargs):
    """Front door with the observability span (trace-time no-op when off)."""
    with obs_trace.span("kernels/block_pair_gemm"):
        return _block_pair_gemm(*args, **kwargs)
