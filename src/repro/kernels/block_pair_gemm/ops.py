"""Jit'd wrapper for the batched block GEMM kernel."""
from repro.kernels.block_pair_gemm.block_pair_gemm import block_pair_gemm

__all__ = ["block_pair_gemm"]
