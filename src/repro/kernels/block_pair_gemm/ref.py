"""Pure-jnp oracle for the batched rectangular block GEMM kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def block_pair_gemm_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    return jnp.einsum("pij,pjk->pik", lhs, rhs,
                      preferred_element_type=lhs.dtype)
