"""Pure-jnp oracle for the batched rectangular block GEMM kernel."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("accum_dtype",))
def block_pair_gemm_ref(lhs: jax.Array, rhs: jax.Array, *,
                        accum_dtype=None) -> jax.Array:
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else lhs.dtype
    return jnp.einsum("pij,pjk->pik", lhs.astype(acc), rhs.astype(acc),
                      preferred_element_type=acc).astype(lhs.dtype)
