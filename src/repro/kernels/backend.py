"""Backend detection and kernel-dispatch defaults.

The seed hardcoded ``interpret=True`` on every Pallas entry point, so a run
on a real TPU would silently execute the kernels through the (slow, jax-level)
interpreter.  This module centralizes the decision:

* ``backend()``          — the active JAX platform ("tpu", "gpu", "cpu"),
                           overridable with ``REPRO_BACKEND`` for testing.
* ``resolve_interpret``  — ``None`` means "interpret only when no accelerator
                           can compile the kernel" (i.e. CPU).
* ``resolve_use_kernel`` — ``None`` means "use the Pallas kernels exactly when
                           they compile natively" (TPU).
* ``resolve_spgemm_path``— default numeric SpGEMM path: the fused tiled
                           kernel on TPU, the einsum+segment_sum reference
                           on CPU and GPU (interpret-mode Pallas is strictly
                           slower on CPU; Triton rejects these block tiles
                           on GPU).  ``REPRO_SPGEMM_PATH`` forces a path
                           globally ("fused" | "pairs" | "reference").
* ``resolve_spmm_path``  — multi-RHS block SpMM path: the Pallas panel
                           kernel on TPU, the jnp reference elsewhere;
                           forced globally with ``REPRO_SPMM_PATH``
                           ("kernel" | "reference").
* ``resolve_precision``  — the solver-stack ``PrecisionPolicy``:
                           ``None`` falls back to ``REPRO_PRECISION``
                           ("f64" | "f32" | "bf16"), default full fp64.
* ``resolve_faults``     — the fault-injection ``FaultSchedule``:
                           ``None`` falls back to ``REPRO_FAULTS``
                           (semicolon-separated
                           ``site:kind[@step][:level=N][:index=N]
                           [:persistent]`` specs), default no injection.
* ``resolve_recover``    — the breakdown-recovery ``RecoveryPolicy``:
                           ``None`` falls back to ``REPRO_RECOVER``
                           ("off" | "on" | max-attempts integer),
                           default off (``None``).
* ``resolve_obs``        — the observability mode (``repro.obs``):
                           ``None`` falls back to ``REPRO_OBS``
                           ("off" | "spans" | "counters"), default off —
                           the zero-jaxpr-residue contract.
* ``resolve_smooth_path``— V-cycle smoother execution path: the fused
                           Pallas recurrence step (``repro.kernels.
                           fused_smoother``) on TPU, the unfused jnp
                           recurrences elsewhere; forced globally with
                           ``REPRO_SMOOTH_PATH`` ("fused" | "reference").
* ``resolve_tune``       — the kernel tile autotuner mode
                           (``repro.kernels.autotune``): ``None`` falls
                           back to ``REPRO_TUNE`` ("off" | "cache" |
                           "sweep"), default "cache" — use cached tuned
                           tiles when present, static defaults otherwise
                           ("off" is bitwise the pre-tune behaviour;
                           "sweep" measures and records on cache miss).
* ``resolve_overlap``    — distributed halo-exchange schedule
                           (``repro.dist``): ``None`` falls back to
                           ``REPRO_OVERLAP`` ("on" | "off"), default
                           "on" — split interior/boundary apply with the
                           exchange in flight; "off" is bitwise the
                           blocking pre-split path.

Every front door (``spmv``, ``spgemm_numeric_data``, ``set_values_coo``)
accepts ``None`` for these knobs and resolves them here, so the same call
site does the right thing on laptop CI and on a pod slice.
"""
from __future__ import annotations

import functools
import os


@functools.lru_cache(maxsize=None)
def _platform() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax init failure
        return "cpu"


def backend() -> str:
    """Active platform name; honours the REPRO_BACKEND override.

    Only the jax platform probe is cached — the env override is re-read on
    every call so tests can flip it mid-process.
    """
    return os.environ.get("REPRO_BACKEND") or _platform()


def on_accelerator() -> bool:
    """True where the Pallas kernels compile natively.

    Deliberately TPU-only: the kernels' tiny rectangular block shapes
    violate Triton's power-of-2 tile constraint, so a compiled-by-default
    dispatch on GPU would crash at lowering.  GPU runs get the jnp
    reference paths until the Triton lowering is exercised.
    """
    return backend() == "tpu"


def resolve_interpret(interpret: bool | None = None) -> bool:
    """None -> interpret Pallas only where it cannot compile natively."""
    if interpret is None:
        return not on_accelerator()
    return interpret


def resolve_use_kernel(use_kernel: bool | None = None) -> bool:
    """None -> dispatch to Pallas kernels exactly where they compile."""
    if use_kernel is None:
        return on_accelerator()
    return use_kernel


def resolve_spgemm_path(path: str | None = None) -> str:
    """Default numeric SpGEMM path for this backend.

    "fused"     — tiled fused pair-GEMM + in-VMEM segment reduce (no
                  (npairs, br, bc) HBM intermediate); TPU default.
    "pairs"     — gather -> block_pair_gemm -> block_seg_sum (three
                  dispatches, materialized pair products).
    "reference" — einsum + sorted segment_sum oracle; CPU default.
    """
    if path is None:
        path = os.environ.get("REPRO_SPGEMM_PATH")
    if path is None:
        path = "fused" if on_accelerator() else "reference"
    if path not in ("fused", "pairs", "reference"):
        # ValueError, not assert: the validation must survive `python -O`,
        # and a typo'd REPRO_SPGEMM_PATH should fail loudly either way.
        raise ValueError(
            f"invalid SpGEMM path {path!r}: expected 'fused', 'pairs' or "
            f"'reference' (from REPRO_SPGEMM_PATH or the path= knob)")
    return path


def resolve_spmm_path(path: str | None = None) -> str:
    """Default multi-RHS SpMM execution path for this backend.

    "kernel"    — the Pallas ``block_spmm`` panel kernel (compiled on TPU,
                  interpret-mode elsewhere when forced).
    "reference" — the jnp ``spmm_ell`` einsum; CPU/GPU default (same Triton
                  tile-shape exclusion as the other kernels).

    ``REPRO_SPMM_PATH`` forces a path globally, mirroring
    ``REPRO_SPGEMM_PATH``; re-read per call so tests can flip it
    mid-process.
    """
    if path is None:
        path = os.environ.get("REPRO_SPMM_PATH")
    if path is None:
        path = "kernel" if on_accelerator() else "reference"
    if path not in ("kernel", "reference"):
        raise ValueError(
            f"invalid SpMM path {path!r}: expected 'kernel' or 'reference' "
            f"(from REPRO_SPMM_PATH or the path= knob)")
    return path


def resolve_smooth_path(path: str | None = None) -> str:
    """Default V-cycle smoother execution path for this backend.

    "fused"     — the Pallas ``fused_smoother`` kernel: one pass per
                  recurrence step computing ``d' = c1*d + c2*D^{-1}(b -
                  A x)``, ``x' = x + d'`` with no ``r``/``z`` HBM
                  intermediates (compiled on TPU, interpret-mode when
                  forced elsewhere).
    "reference" — the unfused jnp recurrences in ``repro.core.vcycle``
                  (SpMV + pbjacobi + axpys); CPU/GPU default.

    ``REPRO_SMOOTH_PATH`` forces a path globally, mirroring
    ``REPRO_SPMM_PATH``; re-read per call so tests can flip it
    mid-process (consumed at *trace* time for jitted solves).
    """
    if path is None:
        path = os.environ.get("REPRO_SMOOTH_PATH")
    if path is None:
        path = "fused" if on_accelerator() else "reference"
    if path not in ("fused", "reference"):
        raise ValueError(
            f"invalid smoother path {path!r}: expected 'fused' or "
            f"'reference' (from REPRO_SMOOTH_PATH or the path= knob)")
    return path


def resolve_tune(mode: str | None = None) -> str:
    """Default autotuner mode; honours the ``REPRO_TUNE`` knob.

    "off"       — ignore the tuning cache entirely: every ``None`` tile
                  knob resolves to its static default.  Bitwise the
                  pre-autotuner behaviour.
    "cache"     (default) use a cached tuned tile when one exists for the
                  kernel signature on this machine/backend, else the
                  static default.  Never measures.
    "sweep"     — like "cache", but a miss triggers a timing sweep over
                  the candidate tiles on synthetic operands and records
                  the winner (``repro.kernels.autotune``).

    Re-read per call; like the path knobs it is consumed at *trace* time,
    so it must be set before the solver is built.  Invalid values raise
    ``ValueError``.
    """
    if mode is None:
        mode = os.environ.get("REPRO_TUNE")
    if mode is None:
        return "cache"
    key = str(mode).strip().lower()
    if key in ("", "0", "off", "false", "none"):
        return "off"
    if key in ("cache", "on", "1", "true"):
        return "cache"
    if key == "sweep":
        return "sweep"
    raise ValueError(
        f"invalid autotune mode {mode!r}: expected 'off', 'cache' or "
        f"'sweep' (from REPRO_TUNE or the mode= knob)")


def resolve_overlap(mode: str | None = None) -> str:
    """Distributed halo-exchange overlap mode; honours ``REPRO_OVERLAP``.

    "on"        (default) split apply: start the halo ``ppermute``s, run
                  the interior rows (no communication) while they fly,
                  finish the window, run the boundary rows.  Same per-row
                  summation order as blocking, so solutions are bitwise
                  identical — only the op *schedule* differs.
    "off"       — the blocking pre-refactor path: assemble the whole
                  window first, then one apply over all rows.  Bitwise
                  the pre-split jaxpr (zero residue).

    Re-read per call; consumed at *trace* time when the dist solver is
    staged, so it must be set before ``make_dist_solver``.  Invalid
    values raise ``ValueError``.
    """
    if mode is None:
        mode = os.environ.get("REPRO_OVERLAP")
    if mode is None:
        return "on"
    key = str(mode).strip().lower()
    if key in ("", "0", "off", "false", "blocking"):
        return "off"
    if key in ("on", "1", "true", "overlap"):
        return "on"
    raise ValueError(
        f"invalid overlap mode {mode!r}: expected 'on' or 'off' "
        f"(from REPRO_OVERLAP or the overlap= knob)")


def resolve_precision(precision=None):
    """Default precision policy; honours the REPRO_PRECISION override.

    ``precision`` may be a ``PrecisionPolicy``, a stock-policy name
    ("f64" | "f32" | "bf16"), or ``None`` — which reads
    ``REPRO_PRECISION`` (re-read per call, mirroring the path knobs) and
    falls back to full fp64, the paper's setting and the bitwise legacy
    behaviour.  Invalid names raise ``ValueError``.
    """
    from repro.core.precision import PrecisionPolicy
    if isinstance(precision, PrecisionPolicy):
        return precision
    if precision is None:
        precision = os.environ.get("REPRO_PRECISION")
    if precision is None:
        return PrecisionPolicy.double()
    return PrecisionPolicy.from_name(precision)


def resolve_faults(spec=None):
    """Default fault-injection schedule; honours ``REPRO_FAULTS``.

    ``spec`` may be a ``repro.robust.inject.FaultSchedule``, a spec string
    in the ``REPRO_FAULTS`` mini-language, or ``None`` — which reads
    ``REPRO_FAULTS`` (re-read per call, mirroring the other knobs) and
    falls back to no injection (``None``).  Invalid specs raise
    ``ValueError``.
    """
    from repro.robust import inject
    if spec is None:
        spec = os.environ.get("REPRO_FAULTS")
    if spec is None or isinstance(spec, inject.FaultSchedule):
        return spec
    return inject.parse_schedule(spec)


def resolve_obs(mode=None) -> str:
    """Default observability mode; honours the ``REPRO_OBS`` knob.

    "off"       (default) no spans, no counters — monitored hot paths are
                bitwise the unmonitored ones with zero jaxpr residue.
    "spans"     ``jax.named_scope``/``TraceAnnotation`` wrappers on every
                kernel family and V-cycle stage (metadata only, numerics
                unchanged).
    "counters"  spans plus the device-side ``CycleTally`` carry threaded
                through ``pcg``/``block_pcg``/``vcycle``.

    Re-read per call (mirroring the path knobs); like them, the mode is
    consumed at *trace* time, so it must be set before the solver under
    observation is built.  Invalid values raise ``ValueError``.
    """
    if mode is None:
        mode = os.environ.get("REPRO_OBS")
    if mode is None:
        return "off"
    key = str(mode).strip().lower()
    if key in ("", "0", "off", "false", "none"):
        return "off"
    if key in ("1", "on", "true", "spans"):
        return "spans"
    if key == "counters":
        return "counters"
    raise ValueError(
        f"invalid observability mode {mode!r}: expected 'off', 'spans' or "
        f"'counters' (from REPRO_OBS or the obs= knob)")


def resolve_recover(policy=None):
    """Default breakdown-recovery policy; honours ``REPRO_RECOVER``.

    ``policy`` may be a ``repro.robust.recover.RecoveryPolicy``, a knob
    string ("off"/"0" -> disabled, "on"/"1" -> defaults, an integer ->
    that many ladder attempts), or ``None`` — which reads
    ``REPRO_RECOVER`` (re-read per call) and falls back to disabled
    (``None``).  Invalid values raise ``ValueError``.
    """
    from repro.robust.recover import RecoveryPolicy
    if isinstance(policy, RecoveryPolicy):
        return policy
    if policy is None:
        policy = os.environ.get("REPRO_RECOVER")
    if policy is None:
        return None
    key = str(policy).strip().lower()
    if key in ("0", "off", "false", "none", ""):
        return None
    if key in ("1", "on", "true", "default"):
        return RecoveryPolicy()
    try:
        return RecoveryPolicy(max_attempts=int(key))
    except ValueError as e:
        raise ValueError(
            f"invalid recovery knob {policy!r}: expected 'off', 'on' or a "
            f"max-attempts integer (from REPRO_RECOVER or the recover= "
            f"knob)") from e
