"""Pallas TPU kernel: point-block Jacobi apply (the paper's smoother).

pbjacobi applies the inverse of each diagonal ``bs x bs`` block to the
residual block: ``y_i = D_i^{-1} r_i``.  The inverses are precomputed at
setup (cold); the hot kernel is a batched small matvec, fused with the
damped-Jacobi update ``x += omega * y`` so the smoother reads r and x once.

Layout / tiling
  grid      = (ceil(nbr / TR),)
  dinv tile = (TR, bs, bs)  VMEM
  r tile    = (TR, bs)      VMEM
  x tile    = (TR, bs)      VMEM
  out tile  = (TR, bs)      VMEM
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pbjacobi_kernel(acc_dt, omega_ref, dinv_ref, r_ref, x_ref, o_ref):
    dinv = dinv_ref[...].astype(acc_dt)       # (TR, bs, bs)
    r = r_ref[...].astype(acc_dt)             # (TR, bs)
    y = jnp.einsum("nab,nb->na", dinv, r,
                   preferred_element_type=acc_dt)
    out = x_ref[...].astype(acc_dt) + omega_ref[0].astype(acc_dt) * y
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_rows", "interpret", "accum_dtype"))
def pbjacobi_update(dinv: jax.Array, r: jax.Array, x: jax.Array,
                    omega: jax.Array, *, tile_rows: int = 64,
                    interpret: bool = True, accum_dtype=None) -> jax.Array:
    """x + omega * D^{-1} r over (nbr, bs) block vectors.

    ``accum_dtype`` is the on-register dtype of the block matvec and the
    damped update (None = native in ``dinv.dtype``, bitwise legacy); the
    result is rounded back to ``dinv.dtype``.
    """
    nbr, bs, _ = dinv.shape
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype is not None else dinv.dtype
    tr = min(tile_rows, nbr)
    pad = (-nbr) % tr
    if pad:
        dinv = jnp.pad(dinv, ((0, pad), (0, 0), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = ((nbr + pad) // tr,)
    omega = jnp.asarray(omega, acc_dt).reshape(1)
    out = pl.pallas_call(
        functools.partial(_pbjacobi_kernel, acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tr, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((tr, bs), lambda i: (i, 0)),
            pl.BlockSpec((tr, bs), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tr, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr + pad, bs), dinv.dtype),
        interpret=interpret,
    )(omega, dinv, r, x)
    return out[:nbr]
