"""Pallas TPU kernel: point-block Jacobi apply (the paper's smoother).

pbjacobi applies the inverse of each diagonal ``bs x bs`` block to the
residual block: ``y_i = D_i^{-1} r_i``.  The inverses are precomputed at
setup (cold); the hot kernel is a batched small matvec, fused with the
damped-Jacobi update ``x += omega * y`` so the smoother reads r and x once.

Layout / tiling
  grid      = (ceil(nbr / TR),)
  dinv tile = (TR, bs, bs)  VMEM
  r tile    = (TR, bs)      VMEM
  x tile    = (TR, bs)      VMEM
  out tile  = (TR, bs)      VMEM
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pbjacobi_kernel(omega_ref, dinv_ref, r_ref, x_ref, o_ref):
    dinv = dinv_ref[...]                      # (TR, bs, bs)
    r = r_ref[...]                            # (TR, bs)
    y = jnp.einsum("nab,nb->na", dinv, r,
                   preferred_element_type=o_ref.dtype)
    o_ref[...] = x_ref[...] + omega_ref[0] * y


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def pbjacobi_update(dinv: jax.Array, r: jax.Array, x: jax.Array,
                    omega: jax.Array, *, tile_rows: int = 64,
                    interpret: bool = True) -> jax.Array:
    """x + omega * D^{-1} r over (nbr, bs) block vectors."""
    nbr, bs, _ = dinv.shape
    tr = min(tile_rows, nbr)
    pad = (-nbr) % tr
    if pad:
        dinv = jnp.pad(dinv, ((0, pad), (0, 0), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = ((nbr + pad) // tr,)
    omega = jnp.asarray(omega, dinv.dtype).reshape(1)
    out = pl.pallas_call(
        _pbjacobi_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tr, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((tr, bs), lambda i: (i, 0)),
            pl.BlockSpec((tr, bs), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tr, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr + pad, bs), dinv.dtype),
        interpret=interpret,
    )(omega, dinv, r, x)
    return out[:nbr]
