"""Pure-jnp oracle for the fused pbjacobi update."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("accum_dtype",))
def pbjacobi_update_ref(dinv: jax.Array, r: jax.Array, x: jax.Array,
                        omega, *, accum_dtype=None) -> jax.Array:
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else dinv.dtype
    y = jnp.einsum("nab,nb->na", dinv.astype(acc), r.astype(acc),
                   preferred_element_type=acc)
    out = x.astype(acc) + jnp.asarray(omega).astype(acc) * y
    return out.astype(dinv.dtype)
