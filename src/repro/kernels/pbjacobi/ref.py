"""Pure-jnp oracle for the fused pbjacobi update."""
import jax
import jax.numpy as jnp


@jax.jit
def pbjacobi_update_ref(dinv: jax.Array, r: jax.Array, x: jax.Array,
                        omega) -> jax.Array:
    return x + omega * jnp.einsum("nab,nb->na", dinv, r,
                                  preferred_element_type=dinv.dtype)
