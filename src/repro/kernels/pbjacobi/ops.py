"""Jit'd wrapper for the pbjacobi smoother update on flat vectors."""
from __future__ import annotations

import jax

from repro.kernels.pbjacobi.pbjacobi import pbjacobi_update
from repro.obs import trace as obs_trace


def pbjacobi_apply(dinv: jax.Array, r: jax.Array, x: jax.Array, omega,
                   *, interpret: bool = True, accum_dtype=None) -> jax.Array:
    """Flat-vector front door: x, r are (nbr*bs,)."""
    with obs_trace.span("kernels/pbjacobi"):
        nbr, bs, _ = dinv.shape
        out = pbjacobi_update(dinv, r.reshape(nbr, bs), x.reshape(nbr, bs),
                              omega, interpret=interpret,
                              accum_dtype=accum_dtype)
        return out.reshape(-1)
