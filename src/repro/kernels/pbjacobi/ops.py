"""Jit'd wrapper for the pbjacobi smoother update on flat vectors."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pbjacobi.pbjacobi import pbjacobi_update
from repro.obs import trace as obs_trace


def pbjacobi_apply(dinv: jax.Array, r: jax.Array, x: jax.Array, omega,
                   *, interpret: bool = True, tile_rows: int | None = None,
                   accum_dtype=None) -> jax.Array:
    """Flat-vector front door: x, r are (nbr*bs,).

    ``tile_rows=None`` resolves through the autotuner
    (``repro.kernels.autotune``, governed by ``REPRO_TUNE``; static
    default 64 — the kernel's historic tile).
    """
    with obs_trace.span("kernels/pbjacobi"):
        nbr, bs, _ = dinv.shape
        if tile_rows is None:
            from repro.kernels import autotune
            tile_rows = autotune.resolve_param(
                "pbjacobi",
                dict(bs=bs, dtype=jnp.dtype(dinv.dtype).name),
                "tile_rows", None, 64)
        out = pbjacobi_update(dinv, r.reshape(nbr, bs), x.reshape(nbr, bs),
                              omega, tile_rows=tile_rows,
                              interpret=interpret, accum_dtype=accum_dtype)
        return out.reshape(-1)
