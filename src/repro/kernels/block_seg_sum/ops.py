"""Jit'd wrapper: sorted blocked segment-sum via the streaming cumsum kernel.

Segment boundaries are derived from the sorted ids (device) or supplied from
host-static indptr; the difference-of-prefix gather is a regular read with no
scatter, which is the TPU-legal formulation of the COO duplicate-sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_seg_sum.block_seg_sum import block_stream_cumsum
from repro.obs import trace as obs_trace


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "interpret", "tile_n",
                                    "accum_dtype"))
def block_seg_sum(vals: jax.Array, seg_ids: jax.Array, num_segments: int,
                  *, interpret: bool = True, tile_n: int = 256,
                  accum_dtype=None) -> jax.Array:
    """Sum (n, br, bc) blocks into (num_segments, br, bc) by sorted ids.

    Empty segments produce zero blocks (start == end collapses the prefix
    difference to 0).  ``accum_dtype`` is the dtype of the streamed prefix
    sum and its boundary differences (None = native, bitwise legacy); the
    per-segment results round back to ``vals.dtype``.
    """
    with obs_trace.span("kernels/block_seg_sum"):
        n = vals.shape[0]
        csum = block_stream_cumsum(vals, tile_n=tile_n, interpret=interpret,
                                   accum_dtype=accum_dtype)
        # end[s] = one past last input of segment s; start[s] = end[s-1]
        ends = jnp.searchsorted(seg_ids, jnp.arange(num_segments),
                                side="right")
        starts = jnp.searchsorted(seg_ids, jnp.arange(num_segments),
                                  side="left")
        zero = jnp.zeros((1,) + vals.shape[1:], csum.dtype)
        padded = jnp.concatenate([zero, csum], axis=0)   # prefix with 0
        return (padded[ends] - padded[starts]).astype(vals.dtype)
