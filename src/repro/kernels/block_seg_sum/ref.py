"""Pure-jnp oracle for the blocked segment reduction."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("num_segments", "accum_dtype"))
def block_seg_sum_ref(vals: jax.Array, seg_ids: jax.Array,
                      num_segments: int, *, accum_dtype=None) -> jax.Array:
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else vals.dtype
    out = jax.ops.segment_sum(vals.astype(acc), seg_ids,
                              num_segments=num_segments,
                              indices_are_sorted=True)
    return out.astype(vals.dtype)
