"""Pure-jnp oracle for the blocked segment reduction."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("num_segments",))
def block_seg_sum_ref(vals: jax.Array, seg_ids: jax.Array,
                      num_segments: int) -> jax.Array:
    return jax.ops.segment_sum(vals, seg_ids, num_segments=num_segments,
                               indices_are_sorted=True)
