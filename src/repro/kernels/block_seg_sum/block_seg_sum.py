"""Pallas TPU kernel: blocked segment reduction (COO scatter / SpGEMM
accumulate), paper Secs. 3.4 / 5.

GPU COO assembly scatters with atomics; TPUs have none, and Pallas TPU
writes must be tile-regular.  The TPU-native rendering of "sum duplicates
into their output slot" for *sorted* segment ids is a streaming prefix sum:

  1. kernel: blocked inclusive cumsum over the pair stream, carrying the
     running prefix across grid steps in a VMEM scratch accumulator — TPU
     grids execute sequentially, so the carry is legal and race-free (and,
     unlike GPU atomics, bit-for-bit deterministic);
  2. wrapper: the per-segment sum is ``csum[end-1] - csum[start-1]`` with the
     (static, host-side) segment boundaries — a regular gather, no scatter.

Everything the scalar path would stream (bs^2 coordinates per block) shrinks
to one coordinate per block — the paper's block-area saving on plan + traffic.

Layout / tiling
  grid       = (ceil(n / TN),)           sequential, carries prefix
  in tile    = (TN, br, bc)  VMEM
  out tile   = (TN, br, bc)  VMEM        inclusive cumsum of the stream
  scratch    = (1, br, bc)   VMEM        running carry
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _cumsum_kernel(x_ref, o_ref, carry_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(o_ref.dtype)           # (TN, br, bc)
    csum = jnp.cumsum(x, axis=0) + carry_ref[...]
    o_ref[...] = csum
    carry_ref[...] = csum[-1:, :, :]


@functools.partial(jax.jit,
                   static_argnames=("tile_n", "interpret", "accum_dtype"))
def block_stream_cumsum(x: jax.Array, *, tile_n: int = 256,
                        interpret: bool = True,
                        accum_dtype=None) -> jax.Array:
    """Inclusive cumsum over axis 0 of a (n, br, bc) block stream.

    The running prefix (output, VMEM carry) is held at ``accum_dtype``
    (None = native in ``x.dtype``): the difference-of-prefix trick in the
    wrapper cancels catastrophically below fp32, so low-precision streams
    must accumulate wider.  The *returned cumsum* stays at the accumulator
    dtype — the wrapper rounds only the final per-segment sums.
    """
    n, br, bc = x.shape
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype is not None else x.dtype
    tn = min(tile_n, max(n, 1))
    pad = (-n) % tn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    grid = ((n + pad) // tn,)
    out = pl.pallas_call(
        _cumsum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tn, br, bc), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tn, br, bc), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, br, bc), acc_dt),
        scratch_shapes=[pltpu.VMEM((1, br, bc), acc_dt)],
        interpret=interpret,
    )(x)
    return out[:n]
