"""Pallas TPU kernel: fused pair-GEMM + segment reduce over the tiled
(ELL-of-pairs) SpGEMM plan layout — the one-pass Galerkin numeric phase.

The unfused numeric SpGEMM runs as three device dispatches

    gather -> batched rectangular block GEMM -> sorted segment-sum

and materializes the full ``(npairs, br, bc)`` pair-product array in HBM
between the last two.  That intermediate is the JAX-level rendition of the
cuSPARSE symbolic/numeric buffer blowup the paper escapes (Sec. 4.5): it is
pure bandwidth with zero arithmetic intensity.

This kernel consumes the *tiled* plan layout instead (``SpGEMMPlan.tile_*``):
the sorted pair list is re-packed into one fixed-width row per output block
slot (width ``pair_kmax`` from the pair histogram, zero-padded), so

  * each grid step owns a contiguous run of ``TS`` output slots,
  * the ``(br, bk) @ (bk, bc)`` contractions of a slot's pairs are unrolled
    on-register, and
  * the per-slot reduction accumulates entirely in VMEM — the pair-product
    array never exists in HBM.

Layout / tiling
  grid     = (ceil(nslots / TS),)
  lhs tile = (TS, kmax, br, bk)  VMEM   gathered A blocks (padded slots = 0)
  rhs tile = (TS, kmax, bk, bc)  VMEM   gathered B blocks
  out tile = (TS, br, bc)        VMEM   fully reduced output blocks

The contraction keeps the slot dimension on the lanes (VPU-shaped, like
``block_pair_gemm``) and unrolls the tiny ``kmax``/``bk`` dims; with
bs = 3..6 the kernel stays bandwidth-bound and the win is the removed
``npairs * br * bc`` round trip plus the index bytes (paper Sec. 4.7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget for the two operand tiles of one grid step (bytes).  Half of
# the ~16 MB/core VMEM, leaving room for the output tile and double
# buffering.
_VMEM_TILE_BUDGET = 4 * 2 ** 20


def _fused_kernel(acc_dt, lhs_ref, rhs_ref, o_ref):
    kmax = lhs_ref.shape[1]
    bk = lhs_ref.shape[3]
    acc = jnp.zeros(o_ref.shape, acc_dt)
    for k in range(kmax):           # static unroll over the pair slots
        lhs = lhs_ref[:, k].astype(acc_dt)   # (TS, br, bk)
        rhs = rhs_ref[:, k].astype(acc_dt)   # (TS, bk, bc)
        for j in range(bk):         # unroll the tiny contraction dim
            acc = acc + lhs[:, :, j][:, :, None] * rhs[:, j, :][:, None, :]
    o_ref[...] = acc.astype(o_ref.dtype)


def default_tile_slots(nslots: int, kmax: int, br: int, bk: int, bc: int,
                       itemsize: int = 8) -> int:
    """Pick TS so both operand tiles fit the VMEM budget."""
    per_slot = max(1, kmax * (br * bk + bk * bc) * itemsize)
    ts = _VMEM_TILE_BUDGET // per_slot
    return max(1, min(256, ts, max(nslots, 1)))


@functools.partial(jax.jit,
                   static_argnames=("tile_slots", "interpret", "accum_dtype"))
def fused_pair_gemm(lhs: jax.Array, rhs: jax.Array, *,
                    tile_slots: int | None = None,
                    interpret: bool = True, accum_dtype=None) -> jax.Array:
    """(nslots, kmax, br, bk) @ (nslots, kmax, bk, bc) -> (nslots, br, bc).

    Contracts each slot's ``kmax`` padded block pairs and reduces them into
    the slot's output block in one pass (padded pairs must be zero blocks on
    at least one side).  ``accum_dtype`` is the VMEM accumulator dtype
    (None = native in ``lhs.dtype``, bitwise legacy); the output rounds
    back to ``lhs.dtype``.
    """
    nslots, kmax, br, bk = lhs.shape
    _, kmax2, bk2, bc = rhs.shape
    assert kmax == kmax2 and bk == bk2, (lhs.shape, rhs.shape)
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype is not None else lhs.dtype
    if nslots == 0 or kmax == 0:
        return jnp.zeros((nslots, br, bc), lhs.dtype)
    ts = tile_slots or default_tile_slots(nslots, kmax, br, bk, bc,
                                          lhs.dtype.itemsize)
    ts = min(ts, nslots)
    pad = (-nslots) % ts
    if pad:
        lhs = jnp.pad(lhs, ((0, pad), (0, 0), (0, 0), (0, 0)))
        rhs = jnp.pad(rhs, ((0, pad), (0, 0), (0, 0), (0, 0)))
    grid = ((nslots + pad) // ts,)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ts, kmax, br, bk), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((ts, kmax, bk, bc), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((ts, br, bc), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nslots + pad, br, bc), lhs.dtype),
        interpret=interpret,
    )(lhs, rhs)
    return out[:nslots]
