"""Jit'd wrapper for the fused tiled pair-GEMM + segment-reduce kernel."""
from repro.kernels.fused_pair_gemm.fused_pair_gemm import (
    default_tile_slots,
    fused_pair_gemm,
)

__all__ = ["fused_pair_gemm", "default_tile_slots"]
