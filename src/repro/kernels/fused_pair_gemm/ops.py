"""Jit'd wrapper for the fused tiled pair-GEMM + segment-reduce kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_pair_gemm.fused_pair_gemm import (
    default_tile_slots,
    fused_pair_gemm as _fused_pair_gemm,
)
from repro.obs import trace as obs_trace

__all__ = ["fused_pair_gemm", "default_tile_slots"]


def fused_pair_gemm(lhs: jax.Array, rhs: jax.Array, *,
                    tile_slots: int | None = None, interpret: bool = True,
                    accum_dtype=None) -> jax.Array:
    """Front door with the observability span (trace-time no-op when off).

    ``tile_slots=None`` resolves through the autotuner
    (``repro.kernels.autotune``, governed by ``REPRO_TUNE``); no cached
    winner falls back to the kernel's VMEM-budget ``default_tile_slots``.
    """
    with obs_trace.span("kernels/fused_pair_gemm"):
        if tile_slots is None:
            from repro.kernels import autotune
            nslots, kmax, br, bk = lhs.shape
            tile_slots = autotune.resolve_param(
                "fused_pair_gemm",
                dict(br=br, bk=bk, bc=rhs.shape[3], kmax=kmax,
                     dtype=jnp.dtype(lhs.dtype).name),
                "tile_slots", None, None)
        return _fused_pair_gemm(lhs, rhs, tile_slots=tile_slots,
                                interpret=interpret, accum_dtype=accum_dtype)
