"""Jit'd wrapper for the fused tiled pair-GEMM + segment-reduce kernel."""
from repro.kernels.fused_pair_gemm.fused_pair_gemm import (
    default_tile_slots,
    fused_pair_gemm as _fused_pair_gemm,
)
from repro.obs import trace as obs_trace

__all__ = ["fused_pair_gemm", "default_tile_slots"]


def fused_pair_gemm(*args, **kwargs):
    """Front door with the observability span (trace-time no-op when off)."""
    with obs_trace.span("kernels/fused_pair_gemm"):
        return _fused_pair_gemm(*args, **kwargs)
