"""Pure-jnp oracle for the fused tiled pair-GEMM (contract + reduce)."""
import jax
import jax.numpy as jnp


@jax.jit
def fused_pair_gemm_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    """(nslots, kmax, br, bk) @ (nslots, kmax, bk, bc) -> (nslots, br, bc)."""
    if lhs.shape[1] == 0:
        return jnp.zeros((lhs.shape[0], lhs.shape[2], rhs.shape[3]),
                         lhs.dtype)
    return jnp.einsum("skij,skjl->sil", lhs, rhs,
                      preferred_element_type=lhs.dtype)
