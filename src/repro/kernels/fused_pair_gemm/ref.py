"""Pure-jnp oracle for the fused tiled pair-GEMM (contract + reduce)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("accum_dtype",))
def fused_pair_gemm_ref(lhs: jax.Array, rhs: jax.Array, *,
                        accum_dtype=None) -> jax.Array:
    """(nslots, kmax, br, bk) @ (nslots, kmax, bk, bc) -> (nslots, br, bc)."""
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else lhs.dtype
    if lhs.shape[1] == 0:
        return jnp.zeros((lhs.shape[0], lhs.shape[2], rhs.shape[3]),
                         lhs.dtype)
    return jnp.einsum("skij,skjl->sil", lhs.astype(acc), rhs.astype(acc),
                      preferred_element_type=acc).astype(lhs.dtype)
