"""CLI for the kernel tile autotuner.

    python -m repro.kernels.autotune smoke
        One tiny interpret-mode sweep (block_spmv, 3x3/f64), then clear
        the in-process memo, reload the cache from disk and assert the
        winner round-trips.  The nightly workflow's autotune gate.

    python -m repro.kernels.autotune sweep [--family F] [--nbr N]
        Sweep the elasticity signatures (3x3, 3x6, 6x6 at f64) for one
        family or all of them, recording winners into the cache.

    python -m repro.kernels.autotune show
        Print the cache for this machine/backend.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.kernels import autotune


def _smoke() -> int:
    sig = {"br": 3, "bc": 3, "kmax": 4, "dtype": "float64"}
    won = autotune.sweep("block_spmv", sig, nbr=32, repeats=2,
                         interpret=True)
    autotune.clear_memo()
    reloaded = autotune.lookup("block_spmv", sig, "tile_rows")
    if reloaded != won["params"]["tile_rows"]:
        print(f"FAIL: cache round-trip: swept "
              f"{won['params']['tile_rows']}, reloaded {reloaded}")
        return 1
    resolved = autotune.resolve_param("block_spmv", sig, "tile_rows",
                                      None, 8)
    print(f"autotune smoke OK: {autotune.entry_key('block_spmv', sig)} -> "
          f"tile_rows={reloaded} ({won['best_us']:.1f} us), cache at "
          f"{autotune.cache_path()}, cache-mode resolve={resolved}")
    return 0


def _sweep(family: str | None, nbr: int) -> int:
    sigs = {
        "block_spmv": [{"br": b, "bc": b, "kmax": 8, "dtype": "float64"}
                       for b in (3, 6)],
        "block_spmm": [{"br": 3, "bc": 3, "kmax": 8, "k": 8,
                        "dtype": "float64"}],
        "pbjacobi": [{"bs": b, "dtype": "float64"} for b in (3, 6)],
        "fused_smoother": [{"br": b, "bc": b, "kmax": 8, "dtype": "float64"}
                           for b in (3, 6)],
        "fused_pair_gemm": [{"br": 3, "bk": 3, "bc": 3, "kmax": 8,
                             "dtype": "float64"}],
    }
    fams = [family] if family else sorted(sigs)
    for fam in fams:
        for sig in sigs[fam]:
            won = autotune.sweep(fam, sig, nbr=nbr)
            print(f"{autotune.entry_key(fam, sig)} -> {won['params']} "
                  f"({won['best_us']:.1f} us)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.kernels.autotune")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("smoke")
    sw = sub.add_parser("sweep")
    sw.add_argument("--family", choices=sorted(autotune.CANDIDATES),
                    default=None)
    sw.add_argument("--nbr", type=int, default=256)
    sub.add_parser("show")
    args = ap.parse_args(argv)
    if args.cmd == "smoke":
        return _smoke()
    if args.cmd == "sweep":
        return _sweep(args.family, args.nbr)
    cache = autotune.load_cache().get(autotune.machine_key(), {})
    print(json.dumps({autotune.machine_key(): cache}, indent=1,
                     sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
