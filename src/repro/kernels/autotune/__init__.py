"""Kernel tile autotuner: per-signature sweeps with an on-disk cache.

Every Pallas family in this package exposes tile parameters (row-tile
widths, pair-GEMM slot tiles, panel padding) that trade VMEM residency
against grid overhead.  The seed hardcoded one value per family; the right
value depends on the block shape, the ELL width, the dtype and the
machine.  This module closes that loop:

* each kernel front door accepts ``None`` for its tile knobs and calls
  ``resolve_param(family, signature, name, requested, default)``;
* the resolution mode comes from ``repro.kernels.backend.resolve_tune``
  (``REPRO_TUNE``): "off" -> always the static default (bitwise the
  pre-tune behaviour), "cache" (default) -> a cached winner when one
  exists, "sweep" -> measure on miss and record the winner;
* sweeps time each candidate on synthetic operands of the signature's
  shape through ``repro.obs.metrics.MetricsRegistry.measure`` — the
  compile/steady split the benchmarks use — and keep the best *steady*
  time (min over repeats);
* winners persist as JSON keyed by ``machine|backend`` then
  ``family|signature``, at ``REPRO_TUNE_CACHE`` or
  ``~/.cache/repro/autotune.json``.

CLI: ``python -m repro.kernels.autotune smoke|sweep|show`` (the nightly
workflow runs ``smoke``: one tiny interpret-mode sweep, cache written,
memo cleared, reloaded, winner asserted).
"""
from __future__ import annotations

import json
import os
import platform
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.kernels.backend import backend, resolve_interpret, resolve_tune

# candidate grids per family, keyed by the tile-parameter name; the static
# default each front door falls back to MUST be a member, so "sweep" can
# only ever match-or-beat the untuned path
CANDIDATES = {
    "block_spmv": {"tile_rows": (4, 8, 16, 32, 64)},
    "block_spmm": {"tile_rows": (4, 8, 16, 32), "pad_k_to": (1, 4, 8)},
    "pbjacobi": {"tile_rows": (16, 32, 64, 128, 256)},
    "fused_smoother": {"tile_rows": (4, 8, 16, 32, 64)},
    "fused_pair_gemm": {"tile_slots": (32, 64, 128, 256)},
}

_memo: dict = {}


def cache_path() -> Path:
    """Cache file: ``REPRO_TUNE_CACHE`` or ``~/.cache/repro/autotune.json``.

    Re-read per call so tests can point the cache at a tmpdir.
    """
    p = os.environ.get("REPRO_TUNE_CACHE")
    if p:
        return Path(p)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def machine_key() -> str:
    """Winners are per host *and* backend — an interpret-mode CPU sweep
    must never steer a TPU run."""
    return f"{platform.node()}|{backend()}"


def entry_key(family: str, signature: dict) -> str:
    """Stable text key: ``family|k=v,...`` with sorted signature items."""
    items = ",".join(f"{k}={signature[k]}" for k in sorted(signature))
    return f"{family}|{items}"


def clear_memo() -> None:
    """Drop the in-process cache memo (tests; the CLI smoke round-trip)."""
    _memo.clear()


def load_cache(path: Path | None = None) -> dict:
    """Parsed cache contents ({} when absent/corrupt), memoized on mtime."""
    path = path or cache_path()
    try:
        mtime = path.stat().st_mtime_ns
    except OSError:
        return {}
    key = (str(path), mtime)
    if key not in _memo:
        try:
            _memo.clear()           # one live file at a time
            _memo[key] = json.loads(path.read_text())
        except (OSError, ValueError):
            return {}
    return _memo[key]


def lookup(family: str, signature: dict, name: str):
    """Cached winner for one tile parameter, or None."""
    entry = load_cache().get(machine_key(), {}).get(
        entry_key(family, signature))
    if entry is None:
        return None
    return entry.get("params", {}).get(name)


def record(family: str, signature: dict, params: dict,
           best_us: float | None = None) -> Path:
    """Merge one signature's winning params into the cache (atomic write)."""
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    cache = dict(load_cache(path))
    mk = cache.setdefault(machine_key(), {})
    mk[entry_key(family, signature)] = {
        "params": dict(params),
        "best_us": best_us,
        "recorded": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    clear_memo()
    return path


def resolve_param(family: str, signature: dict, name: str, requested,
                  default):
    """One tile knob through the mode ladder.

    requested != None  -> the caller pinned it; use verbatim.
    mode "off"         -> the static default (bitwise pre-tune).
    mode "cache"       -> cached winner if present, else the default.
    mode "sweep"       -> cached winner if present, else sweep this
                          signature now, record, and use the winner.
    """
    if requested is not None:
        return requested
    mode = resolve_tune(None)
    if mode == "off":
        return default
    hit = lookup(family, signature, name)
    if hit is not None:
        return hit
    if mode == "sweep":
        won = sweep(family, signature)
        return won["params"].get(name, default)
    return default


# ---------------------------------------------------------------------------
# Sweeping
# ---------------------------------------------------------------------------

def _synthetic(family: str, signature: dict, nbr: int):
    """Deterministic operands of the signature's shape (rng seed 0)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    dt = np.dtype(signature["dtype"])
    if family == "fused_pair_gemm":
        br, bk, bc, kmax = (signature[k] for k in ("br", "bk", "bc", "kmax"))
        lhs = rng.standard_normal((nbr, kmax, br, bk)).astype(dt)
        rhs = rng.standard_normal((nbr, kmax, bk, bc)).astype(dt)
        return jnp.asarray(lhs), jnp.asarray(rhs)
    br, bc, kmax = signature["br"], signature["bc"], signature["kmax"]
    nbc = nbr                      # square-ish synthetic operator
    indices = jnp.asarray(
        rng.integers(0, nbc, size=(nbr, kmax)).astype(np.int32))
    data = jnp.asarray(rng.standard_normal((nbr, kmax, br, bc)).astype(dt))
    return indices, data, nbc


def _make_runner(family: str, signature: dict, params: dict,
                 interpret: bool, nbr: int):
    """Closure running one kernel call of the signature's shape."""
    import jax.numpy as jnp
    from repro.core.block_csr import BlockELL
    rng = np.random.default_rng(1)
    dt = np.dtype(signature["dtype"])
    if family == "fused_pair_gemm":
        lhs, rhs = _synthetic(family, signature, nbr)
        from repro.kernels.fused_pair_gemm import ops as _f
        return lambda: _f.fused_pair_gemm(lhs, rhs, interpret=interpret,
                                          **params)
    if family == "pbjacobi":
        bs = signature["bs"]
        dinv = jnp.asarray(
            rng.standard_normal((nbr, bs, bs)).astype(dt))
        r = jnp.asarray(rng.standard_normal(nbr * bs).astype(dt))
        x = jnp.asarray(rng.standard_normal(nbr * bs).astype(dt))
        from repro.kernels.pbjacobi import ops as _p
        return lambda: _p.pbjacobi_apply(dinv, r, x, 0.6,
                                         interpret=interpret, **params)
    indices, data, nbc = _synthetic(family, signature, nbr)
    br, bc = signature["br"], signature["bc"]
    mask = jnp.ones((nbr, signature["kmax"]), dtype=bool)
    ell = BlockELL(indices=indices, data=data, mask=mask, nbc=nbc)
    if family == "block_spmv":
        x = jnp.asarray(rng.standard_normal(nbc * bc).astype(dt))
        from repro.kernels.block_spmv import ops as _s
        return lambda: _s.block_spmv(ell, x, interpret=interpret, **params)
    if family == "block_spmm":
        X = jnp.asarray(
            rng.standard_normal((nbc * bc, signature["k"])).astype(dt))
        from repro.kernels.block_spmm import ops as _m
        return lambda: _m.block_spmm(ell, X, interpret=interpret, **params)
    if family == "fused_smoother":
        dinv = jnp.asarray(rng.standard_normal((nbr, br, br)).astype(dt))
        b = jnp.asarray(rng.standard_normal(nbr * br).astype(dt))
        x = jnp.asarray(rng.standard_normal(nbr * br).astype(dt))
        d = jnp.zeros_like(b)
        from repro.kernels.fused_smoother import ops as _fs
        return lambda: _fs.smoother_step(ell, dinv, b, x, d, 0.0, 0.5,
                                         interpret=interpret, **params)
    raise ValueError(f"unknown autotune family {family!r}")


def _param_grid(family: str):
    """Cartesian candidate grid as a list of param dicts."""
    import itertools
    cands = CANDIDATES[family]
    names = sorted(cands)
    return [dict(zip(names, vals))
            for vals in itertools.product(*(cands[n] for n in names))]


def sweep(family: str, signature: dict, *, nbr: int = 256, repeats: int = 3,
          interpret: bool | None = None, record_winner: bool = True) -> dict:
    """Time every candidate tiling for one signature; record the winner.

    Each candidate is measured through ``MetricsRegistry.measure`` — the
    first call files under ``.../compile``, the following ``repeats``
    under ``.../steady`` — and scored by its *min* steady seconds.
    Returns ``{"params", "best_us", "table"}`` (``table`` maps the
    candidate key to its best microseconds, for reporting).
    """
    from repro.obs.metrics import MetricsRegistry
    interpret = resolve_interpret(interpret)
    reg = MetricsRegistry()
    best = None
    table = {}
    for params in _param_grid(family):
        fn = _make_runner(family, signature, params, interpret, nbr)
        name = f"tune/{family}/" + ",".join(
            f"{k}={v}" for k, v in sorted(params.items()))
        for _ in range(repeats + 1):
            reg.measure(name, fn)
        us = reg.get(name + "/steady").snapshot()["min"] * 1e6
        table[",".join(f"{k}={v}" for k, v in sorted(params.items()))] = us
        if best is None or us < best[1]:
            best = (params, us)
    won = {"params": best[0], "best_us": best[1], "table": table}
    if record_winner:
        record(family, signature, best[0], best_us=best[1])
    return won
