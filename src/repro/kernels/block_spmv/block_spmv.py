"""Pallas TPU kernel: blocked ELL SpMV (the V-cycle hot spot).

TPU adaptation of the paper's BSR SpMV (Sec. 4.2).  A GPU BSR kernel assigns
a warp per block row and coalesces the per-block index gather; the TPU
analogue is *regular tiling*: the padded BlockELL layout gives every block
row exactly ``kmax`` slots, so the kernel is a dense einsum over a
``(TR, kmax, br, bc)`` VMEM tile plus one gather of ``x`` blocks — no
data-dependent control flow, which is what the TPU pipeline wants.

Index-traffic amortization (the paper's core argument) survives intact: the
kernel loads one int32 per block and reuses it across the whole ``br*bc``
payload; the ELL padding adds only zero blocks (measured padding overhead is
reported by the benchmarks).

Dtype polymorphism: the kernel accepts any floating payload dtype (f64 /
f32 / bf16).  ``accum_dtype`` selects the accumulator the contraction runs
in — the operands are cast up on-register, contracted at that dtype, and the
result is rounded back to the payload dtype on the way out (the value-HBM
traffic stays at the storage width).  ``None`` accumulates natively in the
payload dtype, which is bitwise the pre-policy behaviour; low-precision
inputs (bf16) should pass ``accum_dtype=jnp.float32``.

Layout / tiling
  grid        = (ceil(nbr / TR),)                sequential over row tiles
  data tile   = (TR, kmax, br, bc)  VMEM         streamed per grid step
  index tile  = (TR, kmax)          VMEM (int32)
  x           = (nbc, bc)           VMEM, whole  (block-vector resident;
                                                  fits VMEM for AMG levels —
                                                  nbc*bc*8 B; 16 MB VMEM
                                                  holds 2M fp64 entries)
  out tile    = (TR, br)            VMEM

For MXU alignment the wrapper pads ``TR`` to a multiple of 8 (sublane) and
relies on ``br*bc`` small blocks being vector (VPU) work — elasticity blocks
(3x3, 3x6, 6x6) are far below the 128-lane tile, so the einsum maps to VPU
FMAs with the index gather amortized over the block payload, which is the
whole point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(acc_dt, idx_ref, data_ref, x_ref, o_ref):
    """One row-tile: gather x blocks, contract against the data tile."""
    idx = idx_ref[...]                       # (TR, kmax) int32
    tr, kmax = idx.shape
    x = x_ref[...]                           # (nbc, bc)
    # gather whole bc-wide blocks of x: one index per (row, slot)
    xg = jnp.take(x, idx.reshape(-1), axis=0).reshape(tr, kmax, x.shape[1])
    # padded slots carry exactly-zero data blocks -> contribute 0
    o_ref[...] = jnp.einsum(
        "rkab,rkb->ra", data_ref[...].astype(acc_dt), xg.astype(acc_dt),
        preferred_element_type=acc_dt).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_rows", "interpret", "accum_dtype"))
def block_spmv_ell(indices: jax.Array, data: jax.Array, x_blocks: jax.Array,
                   *, tile_rows: int = 8, interpret: bool = True,
                   accum_dtype=None) -> jax.Array:
    """y = A @ x with A in padded BlockELL form.

    indices: (nbr, kmax) int32, padded slots point at block-col 0
    data:    (nbr, kmax, br, bc), padded slots are zero blocks
    x_blocks: (nbc, bc)
    returns  (nbr, br) at ``data.dtype``; ``accum_dtype`` sets the
    contraction accumulator (None = native)
    """
    nbr, kmax, br, bc = data.shape
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    tr = min(tile_rows, nbr)
    pad = (-nbr) % tr
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        data = jnp.pad(data, ((0, pad), (0, 0), (0, 0), (0, 0)))
    grid = ((nbr + pad) // tr,)
    out = pl.pallas_call(
        functools.partial(_spmv_kernel, acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, kmax), lambda i: (i, 0)),
            pl.BlockSpec((tr, kmax, br, bc), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec(x_blocks.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, br), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr + pad, br), data.dtype),
        interpret=interpret,
    )(indices, data, x_blocks)
    return out[:nbr]
