"""Jit'd wrapper dispatching the blocked SpMV kernel on a BlockELL."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_csr import BlockELL
from repro.kernels.block_spmv.block_spmv import block_spmv_ell
from repro.obs import trace as obs_trace


def block_spmv(ell: BlockELL, x: jax.Array, *, interpret: bool = True,
               tile_rows: int | None = None, accum_dtype=None) -> jax.Array:
    """y = A @ x, flat vectors in/out (matches repro.core.spmv.spmv_ell).

    ``tile_rows=None`` resolves through the autotuner
    (``repro.kernels.autotune``, governed by ``REPRO_TUNE``; static
    default 8 — the seed's hardcoded tile).
    """
    with obs_trace.span("kernels/block_spmv"):
        if tile_rows is None:
            from repro.kernels import autotune
            tile_rows = autotune.resolve_param(
                "block_spmv",
                dict(br=ell.br, bc=ell.bc, kmax=ell.kmax,
                     dtype=jnp.dtype(ell.data.dtype).name),
                "tile_rows", None, 8)
        xb = x.reshape(ell.nbc, ell.bc)
        y = block_spmv_ell(ell.indices, ell.data, xb, tile_rows=tile_rows,
                           interpret=interpret, accum_dtype=accum_dtype)
        return y.reshape(ell.nbr * ell.br)
