"""Pure-jnp oracle for the blocked ELL SpMV kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def block_spmv_ell_ref(indices: jax.Array, data: jax.Array,
                       x_blocks: jax.Array) -> jax.Array:
    """Same contract as the kernel: (nbr, kmax) x (nbr,kmax,br,bc) -> y."""
    xg = x_blocks[indices]  # (nbr, kmax, bc)
    return jnp.einsum("rkab,rkb->ra", data, xg,
                      preferred_element_type=data.dtype)
