"""Pure-jnp oracle for the blocked ELL SpMV kernel."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("accum_dtype",))
def block_spmv_ell_ref(indices: jax.Array, data: jax.Array,
                       x_blocks: jax.Array, *, accum_dtype=None) -> jax.Array:
    """Same contract as the kernel: (nbr, kmax) x (nbr,kmax,br,bc) -> y.

    ``accum_dtype`` mirrors the kernel's accumulator rule: contract at that
    dtype, round the result back to ``data.dtype`` (None = native).
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    xg = x_blocks[indices]  # (nbr, kmax, bc)
    return jnp.einsum("rkab,rkb->ra", data.astype(acc), xg.astype(acc),
                      preferred_element_type=acc).astype(data.dtype)
