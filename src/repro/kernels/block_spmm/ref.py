"""Pure-jnp oracle for the blocked ELL SpMM (column-panel) kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def block_spmm_ell_ref(indices: jax.Array, data: jax.Array,
                       x_panels: jax.Array) -> jax.Array:
    """Same contract as the kernel: (nbr,kmax) x (nbr,kmax,br,bc) x
    (nbc,bc,k) -> (nbr,br,k)."""
    xg = x_panels[indices]  # (nbr, kmax, bc, k)
    return jnp.einsum("rkab,rkbm->ram", data, xg,
                      preferred_element_type=data.dtype)
