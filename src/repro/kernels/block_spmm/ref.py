"""Pure-jnp oracle for the blocked ELL SpMM (column-panel) kernel."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("accum_dtype",))
def block_spmm_ell_ref(indices: jax.Array, data: jax.Array,
                       x_panels: jax.Array, *, accum_dtype=None) -> jax.Array:
    """Same contract as the kernel: (nbr,kmax) x (nbr,kmax,br,bc) x
    (nbc,bc,k) -> (nbr,br,k); ``accum_dtype`` mirrors the kernel's
    accumulator rule (contract there, round back to ``data.dtype``)."""
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    xg = x_panels[indices]  # (nbr, kmax, bc, k)
    return jnp.einsum("rkab,rkbm->ram", data.astype(acc), xg.astype(acc),
                      preferred_element_type=acc).astype(data.dtype)
