"""Pallas TPU kernel: blocked ELL SpMM over column panels (multi-RHS).

The paper's traffic argument (one 4-byte index amortized over a ``br x bc``
block payload) gets a second lever with multiple right-hand sides: the
*operator* stream — values AND indices — is amortized over ``k`` columns,
so arithmetic intensity rises with the panel width while the dominant HBM
traffic (the A values) stays constant.  ``benchmarks/table6_multirhs.py``
evaluates that model exactly.

Layout / tiling (extends ``block_spmv`` by one trailing panel axis):
  grid        = (ceil(nbr / TR),)                 sequential over row tiles
  data tile   = (TR, kmax, br, bc)   VMEM         streamed per grid step
  index tile  = (TR, kmax)           VMEM (int32)
  x panel     = (nbc, bc, kp)        VMEM, whole  (block-panel resident)
  out tile    = (TR, br, kp)         VMEM

``kp`` is the *padded* panel width: the wrapper pads ``k`` up to a multiple
of ``pad_k_to`` so the trailing axis — the TPU lane axis — stays aligned;
on a real TPU wide panels should use lane-width (128) multiples, while the
small static buckets the solve server uses (k <= 16) round to the sublane
granule.  Padded columns are zero and are sliced off by the wrapper, so
they cost only VPU lanes, never correctness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(acc_dt, idx_ref, data_ref, x_ref, o_ref):
    """One row-tile: gather x panels, contract against the data tile."""
    idx = idx_ref[...]                       # (TR, kmax) int32
    tr, kmax = idx.shape
    x = x_ref[...]                           # (nbc, bc, kp)
    # gather whole (bc, kp) panels of x: one index per (row, slot)
    xg = jnp.take(x, idx.reshape(-1), axis=0).reshape(
        tr, kmax, x.shape[1], x.shape[2])
    # padded slots carry exactly-zero data blocks -> contribute 0
    o_ref[...] = jnp.einsum(
        "rkab,rkbm->ram", data_ref[...].astype(acc_dt), xg.astype(acc_dt),
        preferred_element_type=acc_dt).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_rows", "interpret", "accum_dtype"))
def block_spmm_ell(indices: jax.Array, data: jax.Array, x_panels: jax.Array,
                   *, tile_rows: int = 8, interpret: bool = True,
                   accum_dtype=None) -> jax.Array:
    """Y = A @ X with A in padded BlockELL form and X a column panel.

    indices:  (nbr, kmax) int32, padded slots point at block-col 0
    data:     (nbr, kmax, br, bc), padded slots are zero blocks
    x_panels: (nbc, bc, k)
    returns   (nbr, br, k) at ``data.dtype``; ``accum_dtype`` sets the
    contraction accumulator (None = native — bitwise legacy; bf16 inputs
    should accumulate in fp32)
    """
    nbr, kmax, br, bc = data.shape
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    k = x_panels.shape[2]
    tr = min(tile_rows, nbr)
    pad = (-nbr) % tr
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        data = jnp.pad(data, ((0, pad), (0, 0), (0, 0), (0, 0)))
    grid = ((nbr + pad) // tr,)
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, acc_dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, kmax), lambda i: (i, 0)),
            pl.BlockSpec((tr, kmax, br, bc), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec(x_panels.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, br, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr + pad, br, k), data.dtype),
        interpret=interpret,
    )(indices, data, x_panels)
    return out[:nbr]
