"""Jit'd wrapper dispatching the blocked SpMM kernel on a BlockELL.

Pads the panel width to a ``pad_k_to`` multiple before the ``pallas_call``
(lane alignment — see the kernel docstring) and slices the padding back
off, so callers see exactly the ``(n, k)`` contract of
``repro.core.spmv.spmm_ell``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_csr import BlockELL
from repro.kernels.block_spmm.block_spmm import block_spmm_ell
from repro.obs import trace as obs_trace


def block_spmm(ell: BlockELL, X: jax.Array, *, interpret: bool = True,
               tile_rows: int = 8, pad_k_to: int = 8,
               accum_dtype=None) -> jax.Array:
    """Y = A @ X, flat (n, k) panels in/out (matches core ``spmm_ell``)."""
    with obs_trace.span("kernels/block_spmm"):
        k = X.shape[1]
        kp = -(-k // pad_k_to) * pad_k_to if pad_k_to > 1 else k
        xb = X.reshape(ell.nbc, ell.bc, k)
        if kp != k:
            xb = jnp.pad(xb, ((0, 0), (0, 0), (0, kp - k)))
        y = block_spmm_ell(ell.indices, ell.data, xb, tile_rows=tile_rows,
                           interpret=interpret, accum_dtype=accum_dtype)
        return y.reshape(ell.nbr * ell.br, kp)[:, :k]
