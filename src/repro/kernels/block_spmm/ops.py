"""Jit'd wrapper dispatching the blocked SpMM kernel on a BlockELL.

Pads the panel width to a ``pad_k_to`` multiple before the ``pallas_call``
(lane alignment — see the kernel docstring) and slices the padding back
off, so callers see exactly the ``(n, k)`` contract of
``repro.core.spmv.spmm_ell``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_csr import BlockELL
from repro.kernels.block_spmm.block_spmm import block_spmm_ell
from repro.obs import trace as obs_trace


def block_spmm(ell: BlockELL, X: jax.Array, *, interpret: bool = True,
               tile_rows: int | None = None, pad_k_to: int | None = None,
               accum_dtype=None) -> jax.Array:
    """Y = A @ X, flat (n, k) panels in/out (matches core ``spmm_ell``).

    ``tile_rows=None`` / ``pad_k_to=None`` resolve through the autotuner
    (``repro.kernels.autotune``, governed by ``REPRO_TUNE``; static
    defaults 8/8 — the seed's hardcoded tiling).
    """
    with obs_trace.span("kernels/block_spmm"):
        k = X.shape[1]
        if tile_rows is None or pad_k_to is None:
            from repro.kernels import autotune
            sig = dict(br=ell.br, bc=ell.bc, kmax=ell.kmax, k=k,
                       dtype=jnp.dtype(ell.data.dtype).name)
            if tile_rows is None:
                tile_rows = autotune.resolve_param(
                    "block_spmm", sig, "tile_rows", None, 8)
            if pad_k_to is None:
                pad_k_to = autotune.resolve_param(
                    "block_spmm", sig, "pad_k_to", None, 8)
        kp = -(-k // pad_k_to) * pad_k_to if pad_k_to > 1 else k
        xb = X.reshape(ell.nbc, ell.bc, k)
        if kp != k:
            xb = jnp.pad(xb, ((0, 0), (0, 0), (0, kp - k)))
        y = block_spmm_ell(ell.indices, ell.data, xb, tile_rows=tile_rows,
                           interpret=interpret, accum_dtype=accum_dtype)
        return y.reshape(ell.nbr * ell.br, kp)[:, :k]
