"""Model configuration for the assigned architecture zoo.

One frozen dataclass covers every family (dense / MoE / MLA / SSM / hybrid /
VLM / enc-dec audio); family-specific knobs live in optional sub-configs.
``repro.configs.<arch>`` modules instantiate these with the exact assigned
hyperparameters; ``reduced()`` shrinks any config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0          # routed expert hidden dim
    capacity_factor: float = 1.25
    moe_every: int = 1            # 2 = interleaved (dense, MoE) layer pairs


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2, arXiv:2405.04434)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM (arXiv:2312.00752)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 12
    encoder_frames: int = 1500    # Whisper 30 s @ 50 Hz (stub frontend)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    attention: str = "gqa"        # gqa|mla|none
    qkv_bias: bool = False
    activation: str = "swiglu"    # swiglu|geglu|gelu
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    hybrid_parallel_ssm: bool = False      # Hymba: attn ∥ mamba heads
    subquadratic: bool = False             # eligible for long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (per spec: small
        layers/width/experts/embeddings; same code paths)."""
        kw = dict(
            n_layers=2, d_model=64,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads or 1)),
            d_ff=128, vocab_size=256, head_dim=16)
        if self.moe:
            kw["moe"] = dataclasses.replace(self.moe, n_experts=4,
                                            top_k=min(2, self.moe.top_k),
                                            d_ff_expert=64)
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                  qk_nope_dim=16, qk_rope_dim=8,
                                  v_head_dim=16)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8)
        if self.encdec:
            kw["encdec"] = dataclasses.replace(self.encdec,
                                               n_encoder_layers=2,
                                               encoder_frames=16)
        if self.sliding_window:
            kw["sliding_window"] = 8
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train|prefill|decode


LM_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Dry-run applicability per the assignment rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention (skip per spec)")
    return True, ""
