"""Activation-sharding hooks + parameter partition specs.

The launch layer activates a mesh context (axis names for batch/model
parallel dims); model code calls ``constrain`` at strategic points and the
hooks become ``with_sharding_constraint`` under that context, or no-ops on a
single device (smoke tests).  Parameter specs implement FSDP (shard the
d_model-ish dim over "data") x TP (shard heads/ffn/experts/vocab over
"model"), with the pod axis folded into data parallelism.
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional  # noqa: F401

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: dict = {"batch_axes": None, "model_axis": None, "sizes": {}}


@contextlib.contextmanager
def axis_env(batch_axes, model_axis, sizes: Optional[dict] = None):
    """Activate activation-constraint axes (e.g. (("pod","data"),"model")).

    ``sizes``: mesh axis name -> size, for divisibility-aware specs.
    """
    old = dict(_ACTIVE)
    _ACTIVE["batch_axes"] = batch_axes
    _ACTIVE["model_axis"] = model_axis
    _ACTIVE["sizes"] = sizes or {}
    try:
        yield
    finally:
        _ACTIVE.update(old)


def _msize() -> int:
    m = _ACTIVE["model_axis"]
    return _ACTIVE["sizes"].get(m, 0) or 1


def _bsize() -> int:
    b = _ACTIVE["batch_axes"]
    n = 1
    for a in (b if isinstance(b, tuple) else (b,)):
        n *= _ACTIVE["sizes"].get(a, 1)
    return n


def constrain(x, kind: str):
    """Annotate an activation: kind in {btd, btf, bthd, ecd, logits}."""
    b, m = _ACTIVE["batch_axes"], _ACTIVE["model_axis"]
    if b is None:
        return x
    spec = {
        "btd": P(b, None, None),              # (B,S,D) batch-sharded
        "btf": P(b, None, m),                 # (B,S,F) ffn hidden TP
        "bthd": P(b, None, m, None),          # (B,S,H,hd) heads TP
        "ecd": P(m, None, None),              # (E,C,D) expert-parallel
        # (G,E,C,D) expert-major: E over the data axes, matching the
        # expert-weight placement (_expert) so expert matmuls are local
        "gecd": P(None, b, None, None),
        "gecd_back": P(b, None, None, None),  # (G,E,C,D) group-major
        "logits": P(b, None, m),              # (B,S,V) vocab TP
    }[kind]
    return jax.lax.with_sharding_constraint(x, spec)


def attn_strategy(n_heads: int, n_kv_heads: int) -> str:
    """How to shard attention internals over the TP axis.

    "kv"      kv-head count divides TP: shard the kv axis (no data motion).
    "repeat"  total heads divide TP but kv does not: materialize repeated
              K/V to H heads and shard H — trades ~2*S*H*hd bf16 of HBM
              traffic per layer for the multi-GiB reshard/all-gather XLA
              otherwise inserts around the grouped einsums (measured
              ~53 GiB/layer on mistral-large train_4k — §Perf iteration 4).
    "seq"     neither divides: sequence-parallel attention internals.
    """
    if _ACTIVE["batch_axes"] is None:
        return "kv"
    ms = _msize()
    if n_kv_heads % ms == 0:
        return "kv"
    if n_heads % ms == 0:
        return "repeat"
    return "seq"


def moe_groups(n_tokens: int) -> int:
    """MoE dispatch groups = data shards (1 when no mesh is active)."""
    if _ACTIVE["batch_axes"] is None:
        return 1
    g = _bsize()
    return g if n_tokens % g == 0 else 1


def constrain_heads(x, head_axis: int, seq_axis: Optional[int] = None):
    """Shard an attention tensor over heads if divisible, else sequence.

    Models whose head counts do not divide the TP degree (qwen2 14H/2kv,
    hymba 25H/5kv, whisper 12H) fall back to *sequence parallelism* for the
    attention internals; without this XLA resolves the mismatched operand
    shardings by all-reducing the full scores tensor (measured 3x7 GiB per
    layer on qwen2 train_4k — EXPERIMENTS.md §Perf iteration 1).
    """
    b, m = _ACTIVE["batch_axes"], _ACTIVE["model_axis"]
    if b is None:
        return x
    ms = _msize()
    parts = [None] * x.ndim
    parts[0] = b
    if x.shape[head_axis] % ms == 0:
        parts[head_axis] = m
    elif seq_axis is not None and x.shape[seq_axis] % ms == 0:
        parts[seq_axis] = m
    return jax.lax.with_sharding_constraint(x, P(*parts))


# ---------------------------------------------------------------------------
# Parameter partition specs (path-pattern -> PartitionSpec)
# ---------------------------------------------------------------------------

_RULES = [
    # pattern on the param path (joined with /), spec builder given ndim.
    # Stacked layer params have a leading L dim (never sharded).
    (r"embed", lambda nd, d, m: P(m, None)),
    (r"pos_embed", lambda nd, d, m: P(None, None)),
    (r"lm_head", lambda nd, d, m: P(None, m)),
    (r"(wq|wk|wv|wq_b|wk_b|wv_b|wq_a|wkv_a)$",
     lambda nd, d, m: _lastdims(nd, d, m)),
    (r"wo$", lambda nd, d, m: _lastdims(nd, m, d)),
    (r"(w_gate|w_up)$", lambda nd, d, m: _lastdims(nd, d, m)),
    (r"w_down$", lambda nd, d, m: _lastdims(nd, m, d)),
    (r"router$", lambda nd, d, m: _lastdims(nd, d, None)),
    (r"(we_gate|we_up)$",
     lambda nd, d, m: _expert(nd, d, m)),
    (r"we_down$",
     lambda nd, d, m: _expert_down(nd, d, m)),
    (r"(in_proj|x_proj)$", lambda nd, d, m: _lastdims(nd, d, m)),
    (r"out_proj$", lambda nd, d, m: _lastdims(nd, m, d)),
    (r"dt_proj$", lambda nd, d, m: _lastdims(nd, None, m)),
    (r"(A_log|conv_w)$", lambda nd, d, m: _lastdims(nd, None, m)),
]


def _lastdims(nd, a, b):
    """Spec sharding the last two dims as (a, b), leading dims replicated."""
    return P(*([None] * (nd - 2) + [a, b]))


def _expert(nd, d, m):
    """(..., E, din, dout) expert weights: EP over the data axis, TP over
    the last (ff-sided for gate/up, model-sided for down) dim.

    §Perf iteration 5: sharding experts' d_model dim over "data" (ZeRO
    style) forces a 2.5 GiB-per-MoE-layer weight all-gather in forward AND
    rematerialized backward (llama4 train_4k baseline: collective-bound).
    E over "data" + inner dim over "model" keeps every expert weight fully
    resident; the only MoE collectives left are the token dispatch
    all-to-alls and one output reduce per layer.
    """
    return P(*([None] * (nd - 3) + [d, None, m]))


def _expert_down(nd, d, m):
    """(..., E, ff, d_model): E over data, contraction dim ff over model —
    pairs with the model-sharded gate/up outputs so the down matmul is a
    local partial sum (one output reduce instead of an operand gather)."""
    return P(*([None] * (nd - 3) + [d, m, None]))


def param_partition_spec(path: str, ndim: int, data_axes="data",
                         model_axis="model"):
    for pat, fn in _RULES:
        if re.search(pat, path):
            spec = fn(ndim, data_axes, model_axis)
            # trim/pad spec to ndim
            parts = list(spec)
            if len(parts) > ndim:
                parts = parts[len(parts) - ndim:]
            while len(parts) < ndim:
                parts.insert(0, None)
            return P(*parts)
    return P(*([None] * ndim))   # biases, norms, scalars: replicated


def tree_partition_specs(params, data_axes="data", model_axis="model"):
    """PartitionSpec pytree matching a param pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        specs.append(param_partition_spec(name, leaf.ndim, data_axes,
                                          model_axis))
    return jax.tree_util.tree_unflatten(treedef, specs)
