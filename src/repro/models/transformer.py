"""Decoder stacks (dense/MoE/MLA/SSM/hybrid) + the Whisper-style enc-dec.

Layers are homogeneous per architecture, so parameters are *stacked* along a
leading L axis and the stack is a single ``lax.scan`` — one layer's HLO
regardless of depth (crucial for compiling 88-layer models on 512 devices).
Training wraps the scanned body in ``jax.checkpoint`` (full remat per layer,
the standard large-model policy).

Decode threads a stacked cache pytree through the same scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.sharding import constrain

Array = jax.Array
Params = Dict[str, Any]

# When True, layer stacks unroll instead of scanning.  Used by the dry-run
# depth probes: XLA's cost_analysis counts a while-loop body once whatever
# the trip count, so per-layer costs are extracted from unrolled depth-1/2
# lowers (cost(d2) - cost(d1) = exactly one layer).
UNROLL_LAYERS = False


def _scan_blocks(body, x, blocks):
    if UNROLL_LAYERS:
        n = jax.tree_util.tree_leaves(blocks)[0].shape[0]
        for i in range(n):
            x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], blocks))
        return x, None
    return jax.lax.scan(body, x, blocks)


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _interleaved(cfg: ModelConfig) -> bool:
    return cfg.moe is not None and cfg.moe.moe_every == 2


def init_block(key, cfg: ModelConfig, use_moe: Optional[bool] = None
               ) -> Params:
    ks = jax.random.split(key, 6)
    if use_moe is None:
        use_moe = cfg.moe is not None
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.attention == "mla":
        p["attn"] = L.init_mla(ks[0], cfg)
    elif cfg.attention == "gqa":
        p["attn"] = L.init_attention(ks[0], cfg)
    if cfg.ssm is not None:
        p["mamba"] = L.init_mamba(ks[1], cfg)
    if cfg.family != "ssm":                     # ssm blocks have no FFN
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ffn"] = (L.init_moe(ks[2], cfg) if use_moe
                    else L.init_ffn(ks[2], cfg.d_model, cfg.d_ff))
    if cfg.hybrid_parallel_ssm:
        # Hymba-style per-branch output norms for the parallel fusion
        p["attn_out_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ssm_out_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def init_block_unit(key, cfg: ModelConfig) -> Params:
    """Scan unit: one block, or a (dense, MoE) pair when interleaved."""
    if _interleaved(cfg):
        k1, k2 = jax.random.split(key)
        return {"a": init_block(k1, cfg, use_moe=False),
                "b": init_block(k2, cfg, use_moe=True)}
    return init_block(key, cfg)


def _mixer(p: Params, h: Array, cfg: ModelConfig, cdt) -> Array:
    """Sequence mixer (attention / mamba / parallel hybrid), train form."""
    if cfg.hybrid_parallel_ssm:
        a = L.attention_gqa(p["attn"], h, cfg, cdt)
        m, _ = L.mamba_block(p["mamba"], h, cfg, cdt)
        return 0.5 * (L.rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
                      + L.rms_norm(m, p["ssm_out_norm"], cfg.norm_eps))
    if cfg.family == "ssm":
        m, _ = L.mamba_block(p["mamba"], h, cfg, cdt)
        return m
    if cfg.attention == "mla":
        return L.attention_mla(p["attn"], h, cfg, cdt)
    return L.attention_gqa(p["attn"], h, cfg, cdt)


def block_apply(p: Params, x: Array, cfg: ModelConfig, cdt) -> Array:
    if "a" in p and "ln1" not in p:             # interleaved pair unit
        x = block_apply(p["a"], x, cfg, cdt)
        return block_apply(p["b"], x, cfg, cdt)
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + constrain(_mixer(p, h, cfg, cdt), "btd")
    if cfg.family == "ssm":
        return x
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    # the param structure records whether this sub-block routes (MoE)
    ff = (L.moe_ffn(p["ffn"], h, cfg, cdt) if "router" in p["ffn"]
          else L.glu_ffn(p["ffn"], h, cfg.activation, cdt))
    return x + constrain(ff, "btd")


def block_decode(p: Params, x: Array, cfg: ModelConfig, cdt,
                 cache: Dict[str, Array], pos: Array
                 ) -> Tuple[Array, Dict[str, Array]]:
    if "a" in p and "ln1" not in p:             # interleaved pair unit
        x, ca = block_decode(p["a"], x, cfg, cdt, cache["a"], pos)
        x, cb = block_decode(p["b"], x, cfg, cdt, cache["b"], pos)
        return x, {"a": ca, "b": cb}
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache)
    if cfg.hybrid_parallel_ssm:
        a, kv = L.attention_gqa_decode(p["attn"], h, cfg, cdt,
                                       {"k": cache["k"], "v": cache["v"]},
                                       pos)
        m, st = L.mamba_block(p["mamba"], h, cfg, cdt,
                              {"conv": cache["conv"], "ssm": cache["ssm"]})
        mix = 0.5 * (L.rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
                     + L.rms_norm(m, p["ssm_out_norm"], cfg.norm_eps))
        new_cache.update(k=kv["k"], v=kv["v"], conv=st["conv"],
                         ssm=st["ssm"])
    elif cfg.family == "ssm":
        mix, st = L.mamba_block(p["mamba"], h, cfg, cdt,
                                {"conv": cache["conv"],
                                 "ssm": cache["ssm"]})
        new_cache.update(conv=st["conv"], ssm=st["ssm"])
    elif cfg.attention == "mla":
        mix, kv = L.attention_mla_decode(p["attn"], h, cfg, cdt,
                                         {"c_kv": cache["c_kv"],
                                          "k_rope": cache["k_rope"]}, pos)
        new_cache.update(c_kv=kv["c_kv"], k_rope=kv["k_rope"])
    else:
        mix, kv = L.attention_gqa_decode(p["attn"], h, cfg, cdt,
                                         {"k": cache["k"],
                                          "v": cache["v"]}, pos)
        new_cache.update(k=kv["k"], v=kv["v"])
    x = x + mix
    if cfg.family != "ssm":
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        ff = (L.moe_ffn(p["ffn"], h, cfg, cdt) if "router" in p["ffn"]
              else L.glu_ffn(p["ffn"], h, cfg.activation, cdt))
        x = x + ff
    return x, new_cache


def init_layer_cache(cfg: ModelConfig, batch: int, seq_len: int, cdt,
                     _unit: bool = True) -> Dict[str, Array]:
    """One scan unit's decode cache for a maximum context of ``seq_len``."""
    if _unit and _interleaved(cfg):
        one = init_layer_cache(cfg, batch, seq_len, cdt, _unit=False)
        return {"a": one,
                "b": jax.tree_util.tree_map(jnp.copy, one)}
    hd = cfg.resolved_head_dim
    c: Dict[str, Array] = {}
    if cfg.family == "ssm" or cfg.hybrid_parallel_ssm:
        st = L.init_mamba_state(cfg, batch, cdt)
        c.update(conv=st["conv"], ssm=st["ssm"])
    if cfg.family != "ssm":
        if cfg.attention == "mla":
            m = cfg.mla
            c.update(
                c_kv=jnp.zeros((batch, seq_len, m.kv_lora_rank), cdt),
                k_rope=jnp.zeros((batch, seq_len, m.qk_rope_dim), cdt))
        else:
            s = (min(seq_len, cfg.sliding_window)
                 if cfg.sliding_window else seq_len)
            c.update(
                k=jnp.zeros((batch, s, cfg.n_kv_heads, hd), cdt),
                v=jnp.zeros((batch, s, cfg.n_kv_heads, hd), cdt))
    return c


# ---------------------------------------------------------------------------
# stacked decoder LM
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key) -> Params:
    k_emb, k_blocks, k_head, k_enc = jax.random.split(key, 4)
    if cfg.encdec is not None:
        block_init = init_decoder_block       # self + cross + ffn
    else:
        block_init = init_block_unit
    n_units = cfg.n_layers // (2 if _interleaved(cfg) else 1)
    blocks = jax.vmap(lambda k: block_init(k, cfg))(
        jax.random.split(k_blocks, n_units))
    p = {"embed": L._dense_init(k_emb, (cfg.vocab_size, cfg.d_model),
                                scale_dim=cfg.d_model),
         "blocks": blocks,
         "ln_f": jnp.zeros((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(k_head, (cfg.d_model, cfg.vocab_size))
    if cfg.encdec is not None:
        p["encoder"] = init_encoder(k_enc, cfg)
    return p


def _unembed(p: Params, x: Array, cfg: ModelConfig, cdt) -> Array:
    w = (p["embed"].T if cfg.tie_embeddings else p["lm_head"]).astype(cdt)
    return constrain(x @ w, "logits")


def forward_train(p: Params, tokens: Array, cfg: ModelConfig,
                  cdt=jnp.bfloat16, remat: bool = True,
                  enc_feats: Optional[Array] = None) -> Array:
    """tokens (B,S) -> logits (B,S,V).  One scan over stacked layers."""
    x = constrain(p["embed"].astype(cdt)[tokens], "btd")
    if cfg.encdec is not None:
        enc_out = encoder_apply(p["encoder"], enc_feats, cfg, cdt)

        def body(h, bp):
            return decoder_block_apply(bp, h, enc_out, cfg, cdt), None
    else:
        def body(h, bp):
            return block_apply(bp, h, cfg, cdt), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = _scan_blocks(body, x, p["blocks"])
    x = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    return _unembed(p, x, cfg, cdt)


def init_full_cache(cfg: ModelConfig, batch: int, seq_len: int,
                    cdt=jnp.bfloat16) -> Dict[str, Array]:
    one = init_layer_cache(cfg, batch, seq_len, cdt)
    n_units = cfg.n_layers // (2 if _interleaved(cfg) else 1)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n_units,) + a.shape)
        .copy(), one)


def decode_step(p: Params, token: Array, pos: Array, cache: Dict,
                cfg: ModelConfig, cdt=jnp.bfloat16,
                enc_out: Optional[Array] = None
                ) -> Tuple[Array, Dict]:
    """One new token against a cache of ``seq_len`` context (serve_step).

    token (B, 1) int32; pos () absolute position; cache stacked (L, ...).
    """
    x = p["embed"].astype(cdt)[token]

    def body(h, layer):
        bp, lc = layer
        if cfg.encdec is not None:
            h, nc = decoder_block_decode(bp, h, enc_out, cfg, cdt, lc, pos)
        else:
            h, nc = block_decode(bp, h, cfg, cdt, lc, pos)
        return h, nc

    if UNROLL_LAYERS:
        n = jax.tree_util.tree_leaves(cache)[0].shape[0]
        hs, ncs = x, []
        for i in range(n):
            hs, nc = body(hs, jax.tree_util.tree_map(
                lambda a: a[i], (p["blocks"], cache)))
            ncs.append(nc)
        x = hs
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *ncs)
    else:
        x, new_cache = jax.lax.scan(body, x, (p["blocks"], cache))
    x = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    return _unembed(p, x, cfg, cdt), new_cache


# ---------------------------------------------------------------------------
# encoder-decoder (Whisper-style backbone; conv frontend is a stub)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ModelConfig) -> Params:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {"wq": L._dense_init(ks[0], (d, cfg.n_heads * hd)),
            "wk": L._dense_init(ks[1], (d, cfg.n_heads * hd)),
            "wv": L._dense_init(ks[2], (d, cfg.n_heads * hd)),
            "wo": L._dense_init(ks[3], (cfg.n_heads * hd, d))}


def cross_attention(p: Params, x: Array, enc: Array, cfg: ModelConfig,
                    cdt) -> Array:
    B, Sq, _ = x.shape
    Sk = enc.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(cdt)).reshape(B, Sq, cfg.n_heads, hd)
    k = (enc @ p["wk"].astype(cdt)).reshape(B, Sk, cfg.n_heads, hd)
    v = (enc @ p["wv"].astype(cdt)).reshape(B, Sk, cfg.n_heads, hd)
    ctx = L._sdpa(q, k, v, None, cfg.n_heads)
    return ctx.reshape(B, Sq, -1) @ p["wo"].astype(cdt)


def init_encoder(key, cfg: ModelConfig) -> Params:
    e = cfg.encdec
    ks = jax.random.split(key, 3)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "attn": L.init_attention(k1, cfg),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "ffn": L.init_ffn(k2, cfg.d_model, cfg.d_ff)}

    return {"pos_embed": L._dense_init(ks[0],
                                       (e.encoder_frames, cfg.d_model)),
            "blocks": jax.vmap(enc_block)(
                jax.random.split(ks[1], e.n_encoder_layers)),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32)}


def encoder_apply(p: Params, feats: Array, cfg: ModelConfig, cdt) -> Array:
    """feats (B, frames, d): precomputed frame embeddings (stub frontend)."""
    x = feats.astype(cdt) + p["pos_embed"].astype(cdt)[None]

    def body(h, bp):
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        # bidirectional attention: no mask
        B, S, _ = a.shape
        hd = cfg.resolved_head_dim
        q, k, v = L._qkv(bp["attn"], a, cfg, cdt)
        ctx = L._sdpa(q, k, v, None, cfg.n_kv_heads)
        h = h + ctx.reshape(B, S, -1) @ bp["attn"]["wo"].astype(cdt)
        f = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        h = h + L.glu_ffn(bp["ffn"], f, "gelu", cdt)
        return h, None

    x, _ = _scan_blocks(body, x, p["blocks"])
    return L.rms_norm(x, p["ln_f"], cfg.norm_eps)


def init_decoder_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(ks[0], cfg),
            "ln_x": jnp.zeros((cfg.d_model,), jnp.float32),
            "xattn": init_cross_attention(ks[1], cfg),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
            "ffn": L.init_ffn(ks[2], cfg.d_model, cfg.d_ff)}


def decoder_block_apply(p: Params, x: Array, enc: Array, cfg: ModelConfig,
                        cdt) -> Array:
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + L.attention_gqa(p["attn"], h, cfg, cdt)
    h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
    x = x + cross_attention(p["xattn"], h, enc, cfg, cdt)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.glu_ffn(p["ffn"], h, "gelu", cdt)


def decoder_block_decode(p: Params, x: Array, enc: Array, cfg: ModelConfig,
                         cdt, cache: Dict[str, Array], pos: Array
                         ) -> Tuple[Array, Dict[str, Array]]:
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    mix, kv = L.attention_gqa_decode(p["attn"], h, cfg, cdt,
                                     {"k": cache["k"], "v": cache["v"]},
                                     pos)
    x = x + mix
    h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
    x = x + cross_attention(p["xattn"], h, enc, cfg, cdt)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.glu_ffn(p["ffn"], h, "gelu", cdt)
    nc = dict(cache)
    nc.update(k=kv["k"], v=kv["v"])
    return x, nc


def init_encdec_lm(cfg: ModelConfig, key) -> Params:
    """Whisper-style enc-dec (alias: init_lm dispatches on cfg.encdec)."""
    return init_lm(cfg, key)


def count_params(params) -> int:
    return sum(int(np.prod(a.shape))
               for a in jax.tree_util.tree_leaves(params))
