"""Model layers: norms, RoPE, GQA/MLA attention, GLU FFN, MoE, Mamba.

Pure-functional JAX (param pytrees + apply functions), no framework.
Conventions:

* params are kept fp32 (master copies); compute casts to ``cdt`` (bf16 on
  TPU) at use sites; softmax/scan accumulations run fp32.
* activation tensors are (B, S, D); attention internals (B, S, H, hd).
* every layer has a paired decode form operating on one new token + cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MLAConfig, ModelConfig, MoEConfig, SSMConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale_dim=None):
    scale = 1.0 / np.sqrt(scale_dim if scale_dim else shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: Array, dim: int, theta: float
                 ) -> Tuple[Array, Array]:
    """positions (...,) -> cos/sin tables (..., dim/2) in fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x (B, S, H, hd); cos/sin (B?, S, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, cfg.n_heads * hd)),
        "wk": _dense_init(ks[1], (d, cfg.n_kv_heads * hd)),
        "wv": _dense_init(ks[2], (d, cfg.n_kv_heads * hd)),
        "wo": _dense_init(ks[3], (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def _qkv(p: Params, x: Array, cfg: ModelConfig, cdt) -> Tuple[Array, ...]:
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(cdt)
    k = x @ p["wk"].astype(cdt)
    v = x @ p["wv"].astype(cdt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array],
          n_kv_heads: int) -> Array:
    """Grouped scaled-dot-product attention; softmax in fp32.

    q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd); H = G*Hkv.  Attention internals are
    sharded over kv-heads when the TP degree divides them, else over the
    query sequence (sequence parallelism) — see sharding.constrain_heads.
    """
    from repro.models.sharding import attn_strategy, constrain_heads
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    G = H // n_kv_heads
    strategy = attn_strategy(H, n_kv_heads)
    if strategy == "repeat":
        # materialize repeated K/V so every attention tensor carries the
        # TP-divisible H axis (see sharding.attn_strategy docstring)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        q = constrain_heads(q, head_axis=2)
        k = constrain_heads(k, head_axis=2)
        v = constrain_heads(v, head_axis=2)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32)
        scores = constrain_heads(scores, head_axis=1) / np.sqrt(hd)
        if mask is not None:
            scores = jnp.where(mask[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        probs = constrain_heads(probs, head_axis=1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                         preferred_element_type=jnp.float32).astype(q.dtype)
        return constrain_heads(ctx, head_axis=2)
    qg = q.reshape(B, Sq, n_kv_heads, G, hd)
    if Sq == 1 and strategy != "kv":
        # decode with TP-indivisible heads: keep the cache key-sequence
        # sharded (matches the cache layout; softmax partials combine via
        # psum) instead of moving the whole cache every layer
        qg = constrain_heads(qg, head_axis=2)
        k = constrain_heads(k, head_axis=2, seq_axis=1)
        v = constrain_heads(v, head_axis=2, seq_axis=1)
    else:
        qg = constrain_heads(qg, head_axis=2, seq_axis=1)
        k = constrain_heads(k, head_axis=2)   # training: K/V stay whole
        v = constrain_heads(v, head_axis=2)
    score_seq_axis = 4 if (Sq == 1 and strategy != "kv") else 3
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = constrain_heads(scores, head_axis=1, seq_axis=score_seq_axis)
    scores = scores / np.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    probs = constrain_heads(probs, head_axis=1, seq_axis=score_seq_axis)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    # pin ctx to the same heads-or-seq layout so forward and transpose
    # (backward) agree — otherwise XLA re-shards the remat'd probs tensor
    ctx = constrain_heads(ctx, head_axis=2, seq_axis=1)
    return ctx.reshape(B, Sq, H, hd)


def causal_mask(Sq: int, Sk: int, window: Optional[int] = None,
                offset: int = 0) -> Array:
    """(1, Sq, Sk) boolean keep-mask: causal + optional sliding window.

    ``offset`` = absolute position of query 0 minus key 0.
    """
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    keep = kpos <= qpos
    if window is not None:
        keep &= kpos > qpos - window
    return keep[None]


def attention_gqa(p: Params, x: Array, cfg: ModelConfig, cdt,
                  positions: Optional[Array] = None) -> Array:
    """Training/prefill attention (causal, optional sliding window)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(p, x, cfg, cdt)
    pos = positions if positions is not None else \
        jnp.arange(S)[None].astype(jnp.int32)
    cos, sin = rope_cos_sin(pos, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    mask = causal_mask(S, S, cfg.sliding_window)
    ctx = _sdpa(q, k, v, mask, cfg.n_kv_heads)
    return ctx.reshape(B, S, -1) @ p["wo"].astype(cdt)


def attention_gqa_decode(p: Params, x: Array, cfg: ModelConfig, cdt,
                         cache: Dict[str, Array], pos: Array
                         ) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode with a (possibly ring/sliding) KV cache.

    cache: {"k","v": (B, Scache, Hkv, hd)}; pos: () absolute position.
    For sliding-window configs the cache length is the window and writes
    wrap modulo the window (ring buffer).
    """
    B, S1, _ = x.shape
    assert S1 == 1
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(p, x, cfg, cdt)
    cos, sin = rope_cos_sin(pos[None, None], hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    Sc = cache["k"].shape[1]
    slot = jnp.where(cfg.sliding_window is None, pos,
                     pos % Sc).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    kpos = jnp.arange(Sc)
    if cfg.sliding_window is None:
        keep = kpos <= pos
    else:  # ring buffer: everything in the cache is within the window
        keep = (kpos <= pos) | (pos >= Sc)
    mask = jnp.broadcast_to(keep[None, None], (B, 1, Sc))
    ctx = _sdpa(q, ck, cv, mask, cfg.n_kv_heads)
    y = ctx.reshape(B, 1, -1) @ p["wo"].astype(cdt)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> Params:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": _dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "wq_b": _dense_init(ks[1], (m.q_lora_rank, H * qk)),
        "wkv_a": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim)),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
        "wk_b": _dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_dim)),
        "wv_b": _dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim)),
        "wo": _dense_init(ks[5], (H * m.v_head_dim, d)),
    }


def attention_mla(p: Params, x: Array, cfg: ModelConfig, cdt,
                  positions: Optional[Array] = None) -> Array:
    """Training/prefill MLA: latent-compressed KV, decoupled RoPE keys."""
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = rms_norm(x @ p["wq_a"].astype(cdt), p["q_norm"], cfg.norm_eps)
    q = (q @ p["wq_b"].astype(cdt)).reshape(
        B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    kv = x @ p["wkv_a"].astype(cdt)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_nope = (c_kv @ p["wk_b"].astype(cdt)).reshape(B, S, H, m.qk_nope_dim)
    v = (c_kv @ p["wv_b"].astype(cdt)).reshape(B, S, H, m.v_head_dim)
    pos = positions if positions is not None else \
        jnp.arange(S)[None].astype(jnp.int32)
    cos, sin = rope_cos_sin(pos, m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # shared head
    from repro.models.sharding import constrain_heads
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkod->bhqk", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    scores = constrain_heads(scores, head_axis=1, seq_axis=2)
    mask = causal_mask(S, S)
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                     preferred_element_type=jnp.float32).astype(cdt)
    return ctx.reshape(B, S, -1) @ p["wo"].astype(cdt)


def attention_mla_decode(p: Params, x: Array, cfg: ModelConfig, cdt,
                         cache: Dict[str, Array], pos: Array
                         ) -> Tuple[Array, Dict[str, Array]]:
    """Absorbed-matrix MLA decode over the *compressed* cache.

    cache: {"c_kv": (B, Sc, kv_lora), "k_rope": (B, Sc, rope_dim)} — the
    latent cache that makes MLA decoding cheap; per-head K/V are never
    materialized (the W_uk/W_uv absorption of arXiv:2405.04434 Sec. 2.1).
    """
    m: MLAConfig = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    q = rms_norm(x @ p["wq_a"].astype(cdt), p["q_norm"], cfg.norm_eps)
    q = (q @ p["wq_b"].astype(cdt)).reshape(
        B, 1, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    kv = x @ p["wkv_a"].astype(cdt)
    c_new, kr_new = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_new = rms_norm(c_new, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(pos[None, None], m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    kr_new = apply_rope(kr_new[:, :, None, :], cos, sin)[:, :, 0]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new, pos.astype(jnp.int32), axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new, pos.astype(jnp.int32), axis=1)
    # absorb W_uk into the query: q_eff (B,1,H,kv_lora)
    wk_b = p["wk_b"].astype(cdt).reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_eff = jnp.einsum("bqhd,chd->bqhc", q_nope, wk_b,
                       preferred_element_type=jnp.float32).astype(cdt)
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    scores = (jnp.einsum("bqhc,bkc->bhqk", q_eff, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    Sc = c_kv.shape[1]
    keep = jnp.arange(Sc)[None, None, None] <= pos
    scores = jnp.where(keep, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    ctx_c = jnp.einsum("bhqk,bkc->bqhc", probs, c_kv,
                       preferred_element_type=jnp.float32).astype(cdt)
    wv_b = p["wv_b"].astype(cdt).reshape(m.kv_lora_rank, H, m.v_head_dim)
    ctx = jnp.einsum("bqhc,chd->bqhd", ctx_c, wv_b,
                     preferred_element_type=jnp.float32).astype(cdt)
    y = ctx.reshape(B, 1, -1) @ p["wo"].astype(cdt)
    return y, {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# GLU FFN
# ---------------------------------------------------------------------------

def init_ffn(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {"w_gate": _dense_init(ks[0], (d_model, d_ff)),
            "w_up": _dense_init(ks[1], (d_model, d_ff)),
            "w_down": _dense_init(ks[2], (d_ff, d_model))}


def glu_ffn(p: Params, x: Array, activation: str, cdt) -> Array:
    g = x @ p["w_gate"].astype(cdt)
    u = x @ p["w_up"].astype(cdt)
    if activation == "swiglu":
        h = jax.nn.silu(g) * u
    elif activation == "geglu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        h = jax.nn.gelu(g, approximate=True)   # plain GELU: ignore gate mul
    return h @ p["w_down"].astype(cdt)


# ---------------------------------------------------------------------------
# MoE (sort-based, capacity-bounded dispatch — MegaBlocks-style on TPU)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Params:
    mc: MoEConfig = cfg.moe
    d, dff = cfg.d_model, mc.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {"router": _dense_init(ks[0], (d, mc.n_experts)),
         "we_gate": _dense_init(ks[1], (mc.n_experts, d, dff), scale_dim=d),
         "we_up": _dense_init(ks[2], (mc.n_experts, d, dff), scale_dim=d),
         "we_down": _dense_init(ks[3], (mc.n_experts, dff, d),
                                scale_dim=dff)}
    if mc.n_shared:
        p["shared"] = init_ffn(ks[4], d, cfg.d_ff)
    return p


def moe_ffn(p: Params, x: Array, cfg: ModelConfig, cdt) -> Array:
    """Grouped token-choice top-k with capacity (GShard/MegaBlocks shape).

    Tokens are split into G groups (one per data shard, from the active
    axis env) and each group sorts/dispatches *locally* into its
    (E, C_g, d) slice; the only cross-device movement is the single
    (G, E, C_g, d) re-shard from group-major to expert-major — the MoE
    all-to-all.  A global sort instead makes every scatter/gather span
    shards and SPMD replicates the full token payload (measured 6x20 GiB
    per step on llama4 train_4k — EXPERIMENTS.md §Perf iteration 2).
    Dropped (over-capacity) assignments pass through; compiled FLOPs scale
    with capacity, not with E.
    """
    from repro.models.sharding import constrain, moe_groups
    mc: MoEConfig = cfg.moe
    B, S, d = x.shape
    T = B * S
    k, E = mc.top_k, mc.n_experts
    G = moe_groups(T)
    Tg = T // G
    Cg = max(1, int(mc.capacity_factor * Tg * k / E))
    xf = x.reshape(T, d)
    xg = constrain(x.reshape(G, Tg, d), "btd")           # group == batch dim
    logits = (xg @ p["router"].astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                # (G, Tg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    flat_e = eidx.reshape(G, Tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    tok_of = order // k                                   # (G, Tg*k)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(
        sorted_e)
    pos_in_e = (jnp.arange(Tg * k)[None]
                - jnp.take_along_axis(starts, sorted_e, axis=1))
    payload = jnp.take_along_axis(xg, tok_of[..., None], axis=1)

    def scatter_group(e, pos, v):
        return jnp.zeros((E, Cg, d), cdt).at[e, pos].set(v, mode="drop")

    buf = jax.vmap(scatter_group)(sorted_e, pos_in_e, payload)
    h = constrain(buf, "gecd")         # group-major -> expert-major a2a
    g = jnp.einsum("gecd,edf->gecf", h, p["we_gate"].astype(cdt),
                   preferred_element_type=jnp.float32).astype(cdt)
    u = jnp.einsum("gecd,edf->gecf", h, p["we_up"].astype(cdt),
                   preferred_element_type=jnp.float32).astype(cdt)
    o = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u,
                   p["we_down"].astype(cdt),
                   preferred_element_type=jnp.float32).astype(cdt)
    o = constrain(o, "gecd_back")      # expert-major -> group-major a2a

    def gather_group(ob, e, pos):
        return ob.at[e, pos].get(mode="fill", fill_value=0)

    per_assign = jax.vmap(gather_group)(o, sorted_e, pos_in_e)  # (G,Tgk,d)
    gate_sorted = jnp.take_along_axis(gates.reshape(G, Tg * k), order,
                                      axis=1).astype(cdt)
    contrib = per_assign * gate_sorted[..., None]

    def combine_group(c, t):
        return jnp.zeros((Tg, d), cdt).at[t].add(c)

    out = jax.vmap(combine_group)(contrib, tok_of)        # (G, Tg, d)
    out = constrain(out, "btd").reshape(T, d)
    if mc.n_shared:
        out = out + glu_ffn(p["shared"], xf, cfg.activation, cdt)
    return out.reshape(B, S, d)


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig) -> Params:
    sc: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = sc.expand * d
    dtr = sc.resolved_dt_rank(d)
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, sc.d_state + 1, dtype=jnp.float32),
                         (d_in, sc.d_state))
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * d_in)),
        "conv_w": _dense_init(ks[1], (sc.d_conv, d_in), scale_dim=sc.d_conv),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": _dense_init(ks[2], (d_in, dtr + 2 * sc.d_state)),
        "dt_proj": _dense_init(ks[3], (dtr, d_in)),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus≈0.01
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _dense_init(ks[4], (d_in, d)),
    }


def _causal_conv(x: Array, w: Array, b: Array, cdt,
                 state: Optional[Array] = None) -> Array:
    """Depthwise causal conv along S.  x (B,S,Din); w (K,Din)."""
    K = w.shape[0]
    if state is not None:                       # decode: x is (B,1,Din)
        window = jnp.concatenate([state, x], axis=1)    # (B,K,Din)
        y = jnp.einsum("bkd,kd->bd", window, w.astype(cdt)) + b.astype(cdt)
        return y[:, None], window[:, 1:]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1]] * w[i].astype(cdt)
            for i in range(K))
    return y + b.astype(cdt), None


def _selective_scan(dA: Array, dBx: Array, C: Array,
                    h0: Optional[Array] = None,
                    chunk: int = 64) -> Tuple[Array, Array]:
    """h_t = dA_t * h_{t-1} + dBx_t ;  y_t = <h_t, C_t>.

    dA, dBx: (B, S, Din, N); C: (B, S, N).  Chunked: sequential lax.scan
    over S/chunk chunks, parallel associative scan inside each chunk —
    the TPU-friendly compromise between a length-S while loop (opaque to
    cost analysis) and a full-length associative scan (memory).
    """
    B, S, Din, N = dA.shape
    if S % chunk:
        pad = chunk - S % chunk
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = dA.shape[1]
    nchunk = Sp // chunk
    dA_c = dA.reshape(B, nchunk, chunk, Din, N).transpose(1, 0, 2, 3, 4)
    dBx_c = dBx.reshape(B, nchunk, chunk, Din, N).transpose(1, 0, 2, 3, 4)
    C_c = C.reshape(B, nchunk, chunk, N).transpose(1, 0, 2, 3)
    h_init = (jnp.zeros((B, Din, N), dA.dtype) if h0 is None else h0)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    def chunk_step(h, inp):
        da, dbx, c = inp                    # (B, chunk, Din, N), (B,chunk,N)
        aa, bb = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_t = aa * h[:, None] + bb          # (B, chunk, Din, N)
        y = jnp.einsum("bsdn,bsn->bsd", h_t, c)
        return h_t[:, -1], y

    h_last, ys = jax.lax.scan(chunk_step, h_init, (dA_c, dBx_c, C_c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, Sp, Din)[:, :S]
    return y, h_last


def mamba_block(p: Params, x: Array, cfg: ModelConfig, cdt,
                state: Optional[Dict[str, Array]] = None
                ) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """Mamba-1 block.  Training (state=None) or single-token decode."""
    sc: SSMConfig = cfg.ssm
    B, S, d = x.shape
    d_in = sc.expand * d
    dtr = sc.resolved_dt_rank(d)
    xz = x @ p["in_proj"].astype(cdt)
    xi, z = jnp.split(xz, 2, axis=-1)
    if state is None:
        xi, _ = _causal_conv(xi, p["conv_w"], p["conv_b"], cdt)
        conv_state = None
    else:
        xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], cdt,
                                      state["conv"])
    xi = jax.nn.silu(xi)
    proj = xi @ p["x_proj"].astype(cdt)
    dt, Bc, Cc = jnp.split(proj, [dtr, dtr + sc.d_state], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_proj"].astype(cdt)).astype(jnp.float32)
                         + p["dt_bias"])                  # (B,S,Din) fp32
    A = -jnp.exp(p["A_log"])                              # (Din,N)
    dA = jnp.exp(dt[..., None] * A)                       # (B,S,Din,N)
    dBx = (dt * xi.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[:, :, None, :]           # (B,S,Din,N)
    if state is None:
        y, h_last = _selective_scan(dA, dBx, Cc.astype(jnp.float32))
        new_state = None
    else:
        h = state["ssm"] * dA[:, 0] + dBx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0].astype(jnp.float32))[:, None]
        h_last = h
        new_state = {"conv": conv_state, "ssm": h_last}
    y = (y + xi.astype(jnp.float32) * p["D"]).astype(cdt)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(cdt), new_state


def init_mamba_state(cfg: ModelConfig, batch: int, cdt) -> Dict[str, Array]:
    sc: SSMConfig = cfg.ssm
    d_in = sc.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, sc.d_conv - 1, d_in), cdt),
            "ssm": jnp.zeros((batch, d_in, sc.d_state), jnp.float32)}
