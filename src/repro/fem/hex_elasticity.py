"""3D hexahedral linear elasticity — the paper's model problem.

Analogue of PETSc's ``src/ksp/ksp/tutorials/ex56`` (hand-assembled trilinear
Q1 hex elasticity) and the Q1/Q2 DMPlex harness of Sec. 4.6.  Isotropic
material, uniform grid, one face clamped, body-force load.

The block structure is exactly the paper's: bs = 3 displacement components
per node, element matrices are dense ``(3*nn x 3*nn)`` with natural 3x3 node
blocks, and the near-null space is the six rigid-body modes — so the AMG
coarse block size is 6 and the prolongator blocks are rectangular 3x6.

Dirichlet nodes are eliminated (reduced system over free nodes), keeping the
operator SPD and every node carrying a full 3x3 block.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Reference-element machinery (tensor-product Lagrange, order 1 or 2)
# ---------------------------------------------------------------------------

def _lagrange_1d(order: int):
    """Nodes, shape functions and derivatives of 1D Lagrange basis."""
    if order == 1:
        pts = np.array([-1.0, 1.0])
    elif order == 2:
        pts = np.array([-1.0, 0.0, 1.0])
    else:
        raise ValueError(f"unsupported order {order}")

    def shape(xi):
        vals = np.ones((len(pts), np.size(xi)))
        derv = np.zeros((len(pts), np.size(xi)))
        xi = np.atleast_1d(xi)
        for i, pi in enumerate(pts):
            others = [p for j, p in enumerate(pts) if j != i]
            denom = np.prod([pi - p for p in others])
            vals[i] = np.prod([xi - p for p in others], axis=0) / denom
            d = np.zeros_like(xi)
            for k in range(len(others)):
                term = np.ones_like(xi)
                for l, p in enumerate(others):
                    if l != k:
                        term = term * (xi - p)
                d = d + term
            derv[i] = d / denom
        return vals, derv

    return pts, shape


def _gauss_1d(npts: int):
    if npts == 2:
        a = 1.0 / np.sqrt(3.0)
        return np.array([-a, a]), np.array([1.0, 1.0])
    if npts == 3:
        a = np.sqrt(3.0 / 5.0)
        return np.array([-a, 0.0, a]), np.array([5, 8, 5]) / 9.0
    raise ValueError(npts)


def lame_parameters(E, nu):
    """Lame (lambda, mu) from Young's modulus / Poisson ratio.

    Plain arithmetic, so it serves numpy scalars, numpy arrays *and* traced
    jax arrays alike — the single source of the constitutive map for the
    host golden path and the device assembly path.
    """
    lam = E * nu / ((1 + nu) * (1 - 2 * nu))
    mu = E / (2 * (1 + nu))
    return lam, mu


#: Constitutive basis (Voigt: xx, yy, zz, xy, yz, zx): the isotropic D
#: matrix is linear in the Lame parameters, D = lam*D_LAM + mu*D_MU.
#: The device assembly path exploits this to keep material fields as bare
#: (lam, mu) arrays contracted against two constant matrices.
D_LAM = np.zeros((6, 6))
D_LAM[:3, :3] = 1.0
D_MU = np.zeros((6, 6))
D_MU[:3, :3] = 2 * np.eye(3)
D_MU[3:, 3:] = np.eye(3)
for _c in (D_LAM, D_MU):
    _c.flags.writeable = False


def isotropic_d_matrix(E: float, nu: float) -> np.ndarray:
    """6x6 constitutive matrix (Voigt: xx, yy, zz, xy, yz, zx)."""
    lam, mu = lame_parameters(E, nu)
    return lam * D_LAM + mu * D_MU


@lru_cache(maxsize=8)
def element_quadrature(order: int, h: float
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Quadrature-point strain matrices of the cube reference element.

    Returns ``(B, w)``: ``B`` is ``(nq, 6, 3*nn)`` — the strain-displacement
    matrix at every Gauss point — and ``w`` the ``(nq,)`` quadrature weights
    with the (constant, uniform-grid) Jacobian determinant folded in, so

        Ke(E, nu) = sum_q w[q] * B[q].T @ D(E, nu) @ B[q].

    This is the shared structural half of element assembly: the host golden
    path (``element_stiffness``) and the device path
    (``repro.fem.device_stiffness``) both contract exactly these arrays,
    differing only in where the contraction runs.
    """
    pts1d, shape1d = _lagrange_1d(order)
    nn1 = len(pts1d)
    nn = nn1 ** 3
    gp, gw = _gauss_1d(order + 1)
    scale = 2.0 / h                       # d(ref)/d(phys)
    detJ = (h / 2.0) ** 3
    Bs, ws = [], []
    for ig, (xi, wx) in enumerate(zip(gp, gw)):
        Nx, dNx = shape1d(np.array([xi]))
        for jg, (eta, wy) in enumerate(zip(gp, gw)):
            Ny, dNy = shape1d(np.array([eta]))
            for kg, (zeta, wz) in enumerate(zip(gp, gw)):
                Nz, dNz = shape1d(np.array([zeta]))
                # node (a,b,c) -> index a + nn1*(b + nn1*c), x fastest
                gx = np.einsum("a,b,c->abc", dNx[:, 0], Ny[:, 0],
                               Nz[:, 0]).reshape(-1, order="F")
                gy = np.einsum("a,b,c->abc", Nx[:, 0], dNy[:, 0],
                               Nz[:, 0]).reshape(-1, order="F")
                gz = np.einsum("a,b,c->abc", Nx[:, 0], Ny[:, 0],
                               dNz[:, 0]).reshape(-1, order="F")
                grad = np.stack([gx, gy, gz], axis=0) * scale  # (3, nn)
                B = np.zeros((6, 3 * nn))
                B[0, 0::3] = grad[0]
                B[1, 1::3] = grad[1]
                B[2, 2::3] = grad[2]
                B[3, 0::3] = grad[1]
                B[3, 1::3] = grad[0]
                B[4, 1::3] = grad[2]
                B[4, 2::3] = grad[1]
                B[5, 0::3] = grad[2]
                B[5, 2::3] = grad[0]
                Bs.append(B)
                ws.append(wx * wy * wz * detJ)
    Bq, wq = np.stack(Bs, axis=0), np.asarray(ws)
    Bq.flags.writeable = False
    wq.flags.writeable = False
    return Bq, wq


@lru_cache(maxsize=8)
def element_stiffness(order: int, h: float, E: float = 1.0,
                      nu: float = 0.3) -> np.ndarray:
    """(3*nn x 3*nn) stiffness of a cube element with edge ``h``.

    Uniform grids make the Jacobian constant (h/2 * I), so one element
    matrix serves every element sharing (E, nu) — the same economy ex56
    exploits.  This is the host-numpy **golden reference** the device
    assembly path is pinned against (``tests/test_assembly.py``).
    """
    Bq, wq = element_quadrature(order, h)
    D = isotropic_d_matrix(E, nu)
    Ke = np.zeros((Bq.shape[2], Bq.shape[2]))
    for B, w in zip(Bq, wq):
        Ke += w * (B.T @ D @ B)
    return 0.5 * (Ke + Ke.T)              # symmetrize roundoff


@dataclasses.dataclass(frozen=True)
class HexMesh:
    """Uniform hex mesh of the unit cube with ``m`` nodes per edge (Q1
    node count; Q2 uses the same elements with midside nodes)."""

    order: int
    n1: int                  # nodes per edge
    ne: int                  # elements per edge
    coords: np.ndarray       # (n_nodes, 3)
    connectivity: np.ndarray  # (n_elements, nn) global node ids
    h: float                 # element edge length

    @property
    def n_nodes(self) -> int:
        return self.n1 ** 3

    @property
    def n_elements(self) -> int:
        return self.ne ** 3


def hex_mesh(m: int, order: int = 1) -> HexMesh:
    """``m^3`` *grid* (element-corner) resolution; Q2 adds midside nodes.

    For order=1 this is the paper's ``m^3`` node grid; for order=2 the node
    grid is ``(2(m-1)+1)^3``, matching a DMPlex -petscfe_degree 2 refine.
    """
    ne = m - 1
    n1 = order * ne + 1
    h = 1.0 / ne
    xs = np.linspace(0.0, 1.0, n1)
    X, Y, Z = np.meshgrid(xs, xs, xs, indexing="ij")
    coords = np.stack([X.reshape(-1, order="F"), Y.reshape(-1, order="F"),
                       Z.reshape(-1, order="F")], axis=1)
    # global id = x + n1*(y + n1*z) with x fastest (order="F" reshape above)
    nn1 = order + 1
    conn = np.empty((ne ** 3, nn1 ** 3), dtype=np.int64)
    e = 0
    for kz in range(ne):
        for jy in range(ne):
            for ix in range(ne):
                base_x, base_y, base_z = order * ix, order * jy, order * kz
                local = 0
                for c in range(nn1):
                    for b in range(nn1):
                        for a in range(nn1):
                            gid = ((base_x + a)
                                   + n1 * ((base_y + b)
                                           + n1 * (base_z + c)))
                            # local index a + nn1*(b + nn1*c): x fastest,
                            # matching element_stiffness ordering
                            conn[e, a + nn1 * (b + nn1 * c)] = gid
                            local += 1
                e += 1
    return HexMesh(order=order, n1=n1, ne=ne, coords=coords,
                   connectivity=conn, h=h)


def rigid_body_modes(coords: np.ndarray) -> np.ndarray:
    """(3*n, 6) rigid-body near-null space (paper Sec. 2.2).

    Columns: 3 translations + 3 rotations about the centroid.
    """
    c = coords - coords.mean(axis=0)
    n = len(c)
    B = np.zeros((3 * n, 6))
    B[0::3, 0] = 1.0
    B[1::3, 1] = 1.0
    B[2::3, 2] = 1.0
    x, y, z = c[:, 0], c[:, 1], c[:, 2]
    B[1::3, 3] = -z
    B[2::3, 3] = y
    B[0::3, 4] = z
    B[2::3, 4] = -x
    B[0::3, 5] = -y
    B[1::3, 5] = x
    return B


def nnz_per_row_estimate(order: int) -> int:
    """Paper Sec. 4.6: ~78 (Q1) vs ~180 (Q2) scalar nonzeros per row."""
    return 81 if order == 1 else 187     # 27 / ~62 node-neighbors * 3 dofs
