"""Finite-element assembly through the blocked COO primitive (paper Sec. 5).

This is exactly the workload ``MatCOOUseBlockIndices`` was built for: every
element emits a dense grid of 3x3 node-pair blocks (duplicated across shared
nodes, unordered), declared once as block coordinates; each numeric assembly
is then a single device scatter-sum of the block value stream.

Two assembly paths share the one ``BlockCOOPlan``:

``path="device"`` (default)
    per-element stiffness blocks computed in JAX by vmapped quadrature
    (``repro.fem.device_stiffness``) from per-element material fields
    ``E(x), nu(x)`` — heterogeneous and jittable.  The problem carries a
    ``DeviceAssembler`` whose ``coo_data(E, nu)`` composes with
    ``gamg.recompute`` into one zero-host-transfer hot-update program
    (``ElasticityProblem.update_coefficients`` /
    ``GAMGSolver.update_coefficients``).

``path="host"``
    the numpy golden reference: one ``element_stiffness`` matrix per
    distinct material, broadcast (constant fields) or looped (varying
    fields) on the host.  ``tests/test_assembly.py`` pins the device path
    against it to f64 tolerance.

Coefficient-update contract: fields are **per-element** arrays (constant
within an element, sampled e.g. at centroids via ``element_centroids``);
scalars broadcast.  Updates change *values only* — mesh, boundary
conditions and the COO plan are fixed, which is what keeps the update
inside the cached-plan / state-gated reuse model.

Dirichlet handling: clamped nodes are *eliminated* — the assembled operator
is restricted to free nodes so every remaining node carries a full 3x3
diagonal block and the operator stays SPD (the reduced system PETSc's ex56
effectively solves through MatZeroRowsColumns).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_coo import BlockCOOPlan, preallocate_coo, set_values_coo
from repro.core.block_csr import BlockCSR
from repro.fem.device_stiffness import DeviceAssembler
from repro.fem.hex_elasticity import (
    HexMesh,
    element_stiffness,
    hex_mesh,
    rigid_body_modes,
)

Array = jax.Array
BS = 3  # displacement components per node


@dataclasses.dataclass
class ElasticityProblem:
    """Assembled reduced system + everything AMG needs."""

    A: BlockCSR              # (n_free*3) x (n_free*3), 3x3 blocks
    b: Array                 # body-force load on free dofs
    B: Array                 # (n_free*3, 6) rigid-body near-null space
    mesh: HexMesh
    free_nodes: np.ndarray   # global ids of free nodes
    coo_plan: BlockCOOPlan   # cached: numeric reassembly is one scatter
    values: Array            # current block value stream (for reassembly)
    assembler: Optional[DeviceAssembler] = None   # device path only
    E_field: Optional[Array] = None   # current per-element coefficients
    nu_field: Optional[Array] = None

    @property
    def n(self) -> int:
        return self.A.shape[0]

    def reassemble(self, scale: float | Array = 1.0) -> BlockCSR:
        """Hot numeric re-assembly (new coefficients, same mesh) — a single
        MatSetValuesCOO scatter with the cached plan."""
        return set_values_coo(self.coo_plan, self.values * scale)

    # ---- coefficient updates (device path) ------------------------------
    def coefficient_operator(self, E, nu) -> BlockCSR:
        """Pure re-assembly from new per-element fields: vmapped quadrature
        -> cached COO scatter.  Does not mutate the problem."""
        if self.assembler is None:
            raise ValueError(
                "coefficient updates need the device assembly path: "
                "assemble with path='device' (the default)")
        E, nu = self.assembler.as_fields(E, nu)
        return set_values_coo(self.coo_plan,
                              self.assembler.value_stream(E, nu))

    def update_coefficients(self, E, nu) -> BlockCSR:
        """In-place coefficient update: new material fields, same mesh/plan.

        Refreshes ``A``/``values``/``E_field``/``nu_field`` and returns the
        new operator.  The solver-side hot loop
        (``GAMGSolver.update_coefficients``) skips this container entirely
        and jits ``assembler.coo_data`` straight into the recompute.
        """
        if self.assembler is None:
            raise ValueError(
                "coefficient updates need the device assembly path: "
                "assemble with path='device' (the default)")
        E, nu = self.assembler.as_fields(E, nu)
        stream = self.assembler.value_stream(E, nu)
        self.A = set_values_coo(self.coo_plan, stream)
        self.values = stream
        self.E_field, self.nu_field = E, nu
        return self.A


def _element_block_stream(mesh: HexMesh, Ke: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Block coordinates + values of every element contribution."""
    nn = mesh.connectivity.shape[1]
    conn = mesh.connectivity                        # (ne, nn)
    rows = np.repeat(conn, nn, axis=1).reshape(-1)   # e,a,b -> conn[e,a]
    cols = np.tile(conn, (1, nn)).reshape(-1)        # e,a,b -> conn[e,b]
    blocks = Ke.reshape(nn, BS, nn, BS).transpose(0, 2, 1, 3)  # (a,b,3,3)
    vals = np.broadcast_to(blocks.reshape(1, nn * nn, BS, BS),
                           (mesh.n_elements, nn * nn, BS, BS))
    return rows, cols, vals.reshape(-1, BS, BS)


def _host_value_stream(mesh: HexMesh, E: np.ndarray,
                       nu: np.ndarray) -> np.ndarray:
    """Golden numpy value stream for per-element fields (host loop)."""
    nn = mesh.connectivity.shape[1]
    ne = mesh.n_elements
    vals = np.empty((ne, nn * nn, BS, BS))
    for e in range(ne):
        Ke = element_stiffness(mesh.order, mesh.h, float(E[e]),
                               float(nu[e]))
        vals[e] = Ke.reshape(nn, BS, nn, BS).transpose(0, 2, 1, 3) \
                    .reshape(nn * nn, BS, BS)
    return vals.reshape(-1, BS, BS)


def element_centroids(mesh: HexMesh) -> np.ndarray:
    """(n_elements, 3) element centroid coordinates — sample material
    functions here to make per-element coefficient fields."""
    return mesh.coords[mesh.connectivity].mean(axis=1)


def inclusion_fields(mesh: HexMesh, *, E_matrix: float = 1.0,
                     E_inclusion: float = 10.0, nu_matrix: float = 0.3,
                     nu_inclusion: float = 0.2,
                     center=(0.7, 0.7, 0.7), radius: float = 0.3
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Two-material test problem: a stiff spherical inclusion in a softer
    matrix (the heterogeneous workload of the regression battery)."""
    c = element_centroids(mesh)
    inside = np.sum((c - np.asarray(center)) ** 2, axis=1) <= radius ** 2
    E = np.where(inside, E_inclusion, E_matrix)
    nu = np.where(inside, nu_inclusion, nu_matrix)
    return E, nu


def assemble_elasticity(m: int, order: int = 1, E=1.0, nu=0.3,
                        fix_face: bool = True, path: str = "device"
                        ) -> ElasticityProblem:
    """Assemble the reduced elasticity operator on an ``m^3`` grid.

    ``E``/``nu`` may be scalars or per-element ``(n_elements,)`` arrays
    (heterogeneous materials).  ``path`` selects where the element blocks
    are computed: ``"device"`` (JAX vmapped quadrature, default — carries a
    ``DeviceAssembler`` for jitted coefficient updates) or ``"host"`` (the
    numpy golden reference).
    """
    if path not in ("device", "host"):
        raise ValueError(f"invalid assembly path {path!r}: expected "
                         f"'device' or 'host'")
    mesh = hex_mesh(m, order)
    ne = mesh.n_elements
    E_f = np.broadcast_to(np.asarray(E, np.float64), (ne,))
    nu_f = np.broadcast_to(np.asarray(nu, np.float64), (ne,))

    # block coordinates (identical for both paths — one plan); values are
    # path-specific, so only the index streams are built here
    nn = mesh.connectivity.shape[1]
    conn = mesh.connectivity
    rows = np.repeat(conn, nn, axis=1).reshape(-1)   # e,a,b -> conn[e,a]
    cols = np.tile(conn, (1, nn)).reshape(-1)        # e,a,b -> conn[e,b]

    # clamp the z=0 face (eliminate those nodes)
    if fix_face:
        fixed = mesh.coords[:, 2] == 0.0
    else:
        fixed = np.zeros(mesh.n_nodes, dtype=bool)
    free = np.flatnonzero(~fixed)
    # renumber: global node -> free index, fixed -> -1 (COO drops them)
    renum = np.full(mesh.n_nodes, -1, dtype=np.int64)
    renum[free] = np.arange(len(free))
    r2, c2 = renum[rows], renum[cols]

    plan = preallocate_coo(r2, c2, nbr=len(free), nbc=len(free),
                           br=BS, bc=BS)
    assembler = None
    if path == "device":
        assembler = DeviceAssembler.build(mesh, plan)
        Ej, nuj = assembler.as_fields(E_f, nu_f)
        values = assembler.value_stream(Ej, nuj)
    else:
        Ej = nuj = None
        if np.all(E_f == E_f[0]) and np.all(nu_f == nu_f[0]):
            Ke = element_stiffness(order, mesh.h, float(E_f[0]),
                                   float(nu_f[0]))
            _, _, vals = _element_block_stream(mesh, Ke)
        else:
            vals = _host_value_stream(mesh, E_f, nu_f)
        values = jnp.asarray(vals)
    A = set_values_coo(plan, values)

    # uniform body force (0, 0, -1) lumped to nodes
    b = np.zeros((len(free), BS))
    b[:, 2] = -mesh.h ** 3
    B = rigid_body_modes(mesh.coords[free])
    return ElasticityProblem(A=A, b=jnp.asarray(b.reshape(-1)),
                             B=jnp.asarray(B), mesh=mesh,
                             free_nodes=free, coo_plan=plan, values=values,
                             assembler=assembler, E_field=Ej, nu_field=nuj)
