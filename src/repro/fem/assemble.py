"""Finite-element assembly through the blocked COO primitive (paper Sec. 5).

This is exactly the workload ``MatCOOUseBlockIndices`` was built for: every
element emits a dense grid of 3x3 node-pair blocks (duplicated across shared
nodes, unordered), declared once as block coordinates; each numeric assembly
is then a single device scatter-sum of the block value stream.

Dirichlet handling: clamped nodes are *eliminated* — the assembled operator
is restricted to free nodes so every remaining node carries a full 3x3
diagonal block and the operator stays SPD (the reduced system PETSc's ex56
effectively solves through MatZeroRowsColumns).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_coo import BlockCOOPlan, preallocate_coo, set_values_coo
from repro.core.block_csr import BlockCSR
from repro.fem.hex_elasticity import (
    HexMesh,
    element_stiffness,
    hex_mesh,
    rigid_body_modes,
)

Array = jax.Array
BS = 3  # displacement components per node


@dataclasses.dataclass
class ElasticityProblem:
    """Assembled reduced system + everything AMG needs."""

    A: BlockCSR              # (n_free*3) x (n_free*3), 3x3 blocks
    b: Array                 # body-force load on free dofs
    B: Array                 # (n_free*3, 6) rigid-body near-null space
    mesh: HexMesh
    free_nodes: np.ndarray   # global ids of free nodes
    coo_plan: BlockCOOPlan   # cached: numeric reassembly is one scatter
    values: Array            # current block value stream (for reassembly)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    def reassemble(self, scale: float | Array = 1.0) -> BlockCSR:
        """Hot numeric re-assembly (new coefficients, same mesh) — a single
        MatSetValuesCOO scatter with the cached plan."""
        return set_values_coo(self.coo_plan, self.values * scale)


def _element_block_stream(mesh: HexMesh, Ke: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Block coordinates + values of every element contribution."""
    nn = mesh.connectivity.shape[1]
    conn = mesh.connectivity                        # (ne, nn)
    rows = np.repeat(conn, nn, axis=1).reshape(-1)   # e,a,b -> conn[e,a]
    cols = np.tile(conn, (1, nn)).reshape(-1)        # e,a,b -> conn[e,b]
    blocks = Ke.reshape(nn, BS, nn, BS).transpose(0, 2, 1, 3)  # (a,b,3,3)
    vals = np.broadcast_to(blocks.reshape(1, nn * nn, BS, BS),
                           (mesh.n_elements, nn * nn, BS, BS))
    return rows, cols, vals.reshape(-1, BS, BS)


def assemble_elasticity(m: int, order: int = 1, E: float = 1.0,
                        nu: float = 0.3, fix_face: bool = True
                        ) -> ElasticityProblem:
    """Assemble the reduced elasticity operator on an ``m^3`` grid."""
    mesh = hex_mesh(m, order)
    Ke = element_stiffness(order, mesh.h, E, nu)
    rows, cols, vals = _element_block_stream(mesh, Ke)

    # clamp the z=0 face (eliminate those nodes)
    if fix_face:
        fixed = mesh.coords[:, 2] == 0.0
    else:
        fixed = np.zeros(mesh.n_nodes, dtype=bool)
    free = np.flatnonzero(~fixed)
    # renumber: global node -> free index, fixed -> -1 (COO drops them)
    renum = np.full(mesh.n_nodes, -1, dtype=np.int64)
    renum[free] = np.arange(len(free))
    r2, c2 = renum[rows], renum[cols]

    plan = preallocate_coo(r2, c2, nbr=len(free), nbc=len(free),
                           br=BS, bc=BS)
    values = jnp.asarray(vals)
    A = set_values_coo(plan, values)

    # uniform body force (0, 0, -1) lumped to nodes
    b = np.zeros((len(free), BS))
    b[:, 2] = -mesh.h ** 3
    B = rigid_body_modes(mesh.coords[free])
    return ElasticityProblem(A=A, b=jnp.asarray(b.reshape(-1)),
                             B=jnp.asarray(B), mesh=mesh,
                             free_nodes=free, coo_plan=plan, values=values)
