"""Device-resident element stiffness — vmapped quadrature over elements.

The host golden path (``hex_elasticity.element_stiffness``) builds one
numpy ``Ke`` per distinct material and broadcasts it, which caps the
reachable operator updates at a global scalar ``reassemble(scale)``.  This
module computes **per-element** stiffness blocks in JAX from material
fields ``E(x), nu(x)`` given as per-element arrays, so the whole
quasi-static hot loop

    update_coefficients(E, nu) -> set_values_coo -> gamg.recompute -> solve

is one traced, zero-host-transfer device program (the paper's
recurring-recompute scenario with the *assembly* finally on device too).

Structure/value split mirrors the rest of the stack:

* ``DeviceAssembler`` is the cold, host-built symbolic object: the shared
  quadrature arrays (``hex_elasticity.element_quadrature`` — identical B
  matrices to the golden path), the element count and the cached
  ``BlockCOOPlan``.  Built once per mesh + boundary conditions.
* ``element_stiffness_blocks`` / ``DeviceAssembler.value_stream`` /
  ``DeviceAssembler.coo_data`` are pure jittable functions of the
  coefficient fields.  The constitutive matrix is linear in the Lame
  parameters (``D = lam*D_LAM + mu*D_MU``), so heterogeneity costs one
  broadcast, not a per-element D rebuild.

Everything runs at the value dtype (f64 by default — the existing
precision policy casts *down* inside ``gamg.recompute``, never here, so
the assembled stream is a full-precision golden input under every
policy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_coo import BlockCOOPlan, set_values_coo_data
from repro.fem.hex_elasticity import (
    D_LAM,
    D_MU,
    HexMesh,
    element_quadrature,
    lame_parameters,
)

Array = jax.Array
BS = 3  # displacement components per node


def element_stiffness_blocks(Bq, wq, E: Array, nu: Array) -> Array:
    """Per-element stiffness matrices by vmapped quadrature.

    ``Bq (nq, 6, 3*nn)`` / ``wq (nq,)`` are the shared quadrature arrays;
    ``E``/``nu`` are per-element coefficient arrays ``(ne,)``.  Returns
    ``(ne, 3*nn, 3*nn)`` symmetric element matrices:

        Ke_e = sum_q w_q B_q^T (lam_e D_LAM + mu_e D_MU) B_q
    """
    Bq = jnp.asarray(Bq)
    wq = jnp.asarray(wq)
    dl = jnp.asarray(D_LAM, Bq.dtype)
    dm = jnp.asarray(D_MU, Bq.dtype)
    lam, mu = lame_parameters(E, nu)

    def one(lam_e, mu_e):
        D = lam_e * dl + mu_e * dm                        # (6, 6)
        Ke = jnp.einsum("q,qia,ij,qjb->ab", wq, Bq, D, Bq)
        return 0.5 * (Ke + Ke.T)                          # mirror host path

    return jax.vmap(one)(lam, mu)


@dataclasses.dataclass(frozen=True, eq=False)
class DeviceAssembler:
    """Cold symbolic side of device assembly (host-built, hashable-by-id:
    ``eq=False`` keeps the identity hash — the array fields aren't
    field-hashable and two assemblers are never interchangeable anyway).

    Owns the quadrature arrays, the element/block bookkeeping and the
    cached ``BlockCOOPlan`` of the reduced (BC-eliminated) operator; the
    numeric side is the pure ``value_stream``/``coo_data`` functions of
    the coefficient fields.  Closures over an assembler (e.g.
    ``gamg.make_coeff_recompute``) bake the plan in as constants, exactly
    like the PtAP caches.
    """

    plan: BlockCOOPlan
    quad_b: np.ndarray      # (nq, 6, 3*nn) strain matrices
    quad_w: np.ndarray      # (nq,) weights * detJ
    n_elements: int
    nn: int                 # nodes per element
    dtype: np.dtype = np.dtype(np.float64)

    @staticmethod
    def build(mesh: HexMesh, plan: BlockCOOPlan,
              dtype=np.float64) -> "DeviceAssembler":
        Bq, wq = element_quadrature(mesh.order, mesh.h)
        return DeviceAssembler(plan=plan, quad_b=Bq, quad_w=wq,
                               n_elements=mesh.n_elements,
                               nn=mesh.connectivity.shape[1],
                               dtype=np.dtype(dtype))

    # ---- field plumbing -------------------------------------------------
    def as_fields(self, E, nu):
        """Scalars/arrays -> per-element ``(ne,)`` fields at the assembly
        dtype (force-cast, so callers at any dtype hit one traced program —
        the same no-retrace contract as the scatter staging in
        ``repro.dist``)."""
        ne = self.n_elements
        E = np.broadcast_to(np.asarray(E, self.dtype), (ne,))
        nu = np.broadcast_to(np.asarray(nu, self.dtype), (ne,))
        return jnp.asarray(E), jnp.asarray(nu)

    # ---- jittable numeric phase ----------------------------------------
    def element_blocks(self, E: Array, nu: Array) -> Array:
        """(ne, 3*nn, 3*nn) element matrices of the coefficient fields."""
        return element_stiffness_blocks(
            np.asarray(self.quad_b, self.dtype),
            np.asarray(self.quad_w, self.dtype), E, nu)

    def value_stream(self, E: Array, nu: Array) -> Array:
        """(n_input, 3, 3) blocked COO value stream in declaration order
        (element-major, then row-node, then col-node) — exactly the
        MatSetValuesCOO stream ``self.plan`` was preallocated for."""
        nn = self.nn
        Ke = self.element_blocks(E, nu)
        blocks = Ke.reshape(-1, nn, BS, nn, BS).transpose(0, 1, 3, 2, 4)
        return blocks.reshape(-1, BS, BS)

    def coo_data(self, E: Array, nu: Array) -> Array:
        """Assembled (nnzb, 3, 3) operator payload: value stream through
        the cached plan's scatter-sum.  Pure and jittable — compose with
        ``gamg.recompute`` for the one-program hot loop."""
        return set_values_coo_data(self.plan, self.value_stream(E, nu))
