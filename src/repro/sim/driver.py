"""Device-resident quasi-static time march with adaptive re-coarsening.

The outer loop the paper's reuse story was building toward: march a
material-evolution law (``repro.sim.scenarios``) through the fused
``coefficient update -> device assembly -> state-gated PtAP recompute ->
warm-started AMG-PCG`` step, entirely on device.  Each step feeds the
previous solution into the law and warm-starts CG from the previous
iterate (``x0`` threading, ``repro.core.krylov.pcg``).

Three march modes:

``"frozen"``
    one hierarchy for the whole march, the K-step loop fused into a
    single ``lax.scan`` program — compiles once, zero host transfers
    (``tests/test_march.py`` pins the jit cache size and an
    ``eval_shape`` round-trip), and is bitwise identical to the eager
    per-step loop (``make_step_fn``).

``"adaptive"``
    the production policy: frozen-hierarchy *segments* (a jitted
    ``lax.while_loop``, still zero host transfers while it runs) cut by
    the device-side staleness monitor (``repro.sim.staleness``) riding
    the carry.  At a segment boundary the host rebuilds aggregates and
    prolongator via ``gamg.setup`` against the current coefficient
    field — the explicit reuse-vs-rebuild runtime policy — and the
    march resumes warm.

``"resetup"``
    the accuracy baseline: a full ``gamg.setup`` before *every* step
    (segments of length one, unconditional rebuild).  The adaptive
    march must reach the same final state while doing strictly fewer
    setups — the acceptance pin.

Failure containment (the fault-battery contract): a step whose solve is
not ``HEALTHY`` does **not** advance the state — the segment exits with
the carry still at the last healthy trajectory point, and the host
recovery (mirroring ``repro.robust.recover``'s ladder) rebuilds the
hierarchy with transient faults suppressed and retries.  A step that
stays blocked through ``max_recoveries`` rebuilds fails the march
explicitly (``MarchResult.status == "failed"``) with the last healthy
state as the result — a failed march never silently marches on poison
and never returns a poisoned state.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gamg
from repro.core.block_coo import set_values_coo
from repro.obs import metrics as obs_metrics
from repro.robust import health, inject
from repro.sim.staleness import (
    StalenessConfig,
    StalenessState,
    staleness_init,
    staleness_update,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MarchConfig:
    """Static march knobs (baked into the traced step/segment programs)."""

    n_steps: int
    seg_len: int = 16            # max steps per traced adaptive segment
    rtol: float = 1e-8
    maxiter: int = 200
    warm_start: bool = True      # x0 = previous iterate (False: cold CG)
    staleness: StalenessConfig = StalenessConfig()
    max_recoveries: int = 2      # rebuild retries for one blocked step


class MarchCarry(NamedTuple):
    """The device-resident march state (a pytree; rides scan/while)."""

    x: Array             # last healthy solution
    scen: Any            # scenario evolution state pytree
    stale: StalenessState
    step: Array          # int32 next global step index


class StepRecord(NamedTuple):
    """Per-step diagnostics (fixed-size buffers inside the segment)."""

    iters: Array         # int32 CG iterations
    relres: Array        # final relative residual
    status: Array        # int32 SolveHealth status code
    tripped: Array       # bool: staleness tripped after this step
    coeff_drift: Array   # relative coefficient drift vs the rebuild


@dataclasses.dataclass
class SegmentInfo:
    """One frozen-hierarchy segment of the march (host bookkeeping)."""

    start: int           # global step index of the segment's first step
    steps: int           # steps *advanced* inside the segment
    setup_id: int        # which gamg.setup built its hierarchy
    reason: str          # "tripped" | "blocked" | "budget" | "done"
    iters: int           # CG iterations spent in the segment


@dataclasses.dataclass
class MarchResult:
    """Host-side march summary; per-step arrays cover advanced steps."""

    x: Array                    # final (last healthy) solution
    scen_state: Any             # final scenario state
    E: Array                    # coefficient fields at the final state
    nu: Array
    steps_done: int
    n_setups: int
    n_recoveries: int
    status: str                 # "ok" | "failed"
    iters: np.ndarray           # (steps_done,) int
    relres: np.ndarray
    step_status: np.ndarray     # (steps_done,) SolveHealth codes
    tripped: np.ndarray         # (steps_done,) bool
    coeff_drift: np.ndarray
    segments: List[SegmentInfo]
    attempts: List[dict]        # failed (non-advancing) step attempts
    worst_status: int           # health.worst_status over all attempts

    @property
    def total_iters(self) -> int:
        return int(self.iters.sum())


def _tree_where(pred: Array, a, b):
    """Elementwise select over two identically-structured pytrees."""
    return jax.tree_util.tree_map(
        lambda u, v: jnp.where(pred, u, v), a, b)


def make_step(setupd, assembler, scenario, cfg: MarchConfig):
    """The traceable march step: ``(carry, b) -> (carry', record,
    blocked)``.

    Fuses the scenario law, device assembly, the state-gated PtAP
    recompute and the warm-started solve; the carry advances only when
    the solve is ``HEALTHY`` — a blocked step leaves the trajectory
    (solution, scenario state, staleness monitor, step counter)
    untouched and raises the ``blocked`` flag for the segment loop.
    """
    def step(carry: MarchCarry, b: Array):
        E, nu, scen2 = scenario.step_fields(carry.scen, carry.x,
                                            carry.step)
        hier = gamg.recompute(setupd, assembler.coo_data(E, nu))
        x0 = carry.x if cfg.warm_start else None
        res = gamg.hier_solve(setupd, hier, b, x0,
                              rtol=cfg.rtol, maxiter=cfg.maxiter)
        ok = res.health.status == health.HEALTHY
        stale2 = staleness_update(carry.stale, res.iters, E,
                                  cfg.staleness)
        advanced = MarchCarry(x=res.x, scen=scen2, stale=stale2,
                              step=carry.step + 1)
        carry2 = _tree_where(ok, advanced, carry)
        rec = StepRecord(iters=jnp.asarray(res.iters, jnp.int32),
                         relres=res.relres,
                         status=res.health.status,
                         tripped=ok & stale2.tripped,
                         coeff_drift=stale2.coeff_drift)
        return carry2, rec, ~ok

    return step


def init_carry(scenario, b: Array) -> MarchCarry:
    """Initial march carry (zero displacement, pristine scenario state,
    staleness referenced against the step-0 coefficient field)."""
    scen = scenario.init_state()
    x = jnp.zeros_like(b)
    E, _, _ = scenario.step_fields(scen, x, jnp.asarray(0, jnp.int32))
    return MarchCarry(x=x, scen=scen, stale=staleness_init(E),
                      step=jnp.asarray(0, jnp.int32))


def make_scan_march(setupd, assembler, scenario, cfg: MarchConfig, *,
                    unroll: bool = False):
    """The frozen-hierarchy march as ONE jitted ``lax.scan`` program:
    ``(b, carry) -> (carry', StepRecord[(n_steps,)])``.

    Compiles once and runs all ``cfg.n_steps`` steps with zero host
    transfers.  A blocked step simply stops advancing: the remaining
    scan slots retry it (and record the failed attempts), so the final
    ``carry.step`` tells the host how far the march truly got.

    ``unroll=True`` fully unrolls the scan into a straight-line program.
    XLA compiles a *rolled* loop body with slightly different
    reduction/fusion ULP behaviour than the same step compiled top-level
    (observable only on the warm-start ``x0 != 0`` path, ~1e-15 after a
    few steps; iteration counts and statuses are unaffected) — the
    unrolled variant is bitwise identical to the eager per-step loop
    (``make_step_fn``), which is what the scan-vs-eager parity test
    pins.  The rolled default trades that last ULP for O(1) program
    size.
    """
    step = make_step(setupd, assembler, scenario, cfg)

    def run(b, carry):
        def body(c, _):
            c2, rec, _ = step(c, b)
            return c2, rec
        return jax.lax.scan(body, carry, None, length=cfg.n_steps,
                            unroll=cfg.n_steps if unroll else 1)

    return jax.jit(run)


def make_step_fn(setupd, assembler, scenario, cfg: MarchConfig):
    """The same step as an eagerly-callable jitted function — the
    hand-rolled Python-loop march for the scan-vs-eager bitwise parity
    test, and the primitive the dist selftest marches per rank."""
    step = make_step(setupd, assembler, scenario, cfg)
    return jax.jit(step)


def make_segment(setupd, assembler, scenario, cfg: MarchConfig):
    """One frozen-hierarchy adaptive segment as a jitted ``while_loop``:
    ``(b, carry, n_steps) -> (k, carry', StepRecord[(seg_len,)],
    blocked)``.

    Runs up to ``cfg.seg_len`` steps with zero host transfers, exiting
    early when the march completes, the staleness monitor trips, or a
    step blocks.  ``n_steps`` is a traced scalar so one compiled segment
    serves the whole march (the cache-size pin).  ``k`` counts *attempts*
    written into the record buffers; when ``blocked`` the last attempt
    (slot ``k - 1``) did not advance the carry.
    """
    step = make_step(setupd, assembler, scenario, cfg)
    L = cfg.seg_len

    def run(b, carry, n_steps):
        dtype = b.dtype
        recs0 = StepRecord(
            iters=jnp.full((L,), -1, jnp.int32),
            relres=jnp.full((L,), jnp.nan, dtype),
            status=jnp.full((L,), -1, jnp.int32),
            tripped=jnp.zeros((L,), bool),
            coeff_drift=jnp.full((L,), jnp.nan,
                                 carry.stale.coeff_drift.dtype))

        def cond(s):
            k, c, _, blocked = s
            return ((k < L) & (c.step < n_steps)
                    & ~c.stale.tripped & ~blocked)

        def body(s):
            k, c, recs, _ = s
            c2, rec, blocked = step(c, b)
            recs2 = jax.tree_util.tree_map(
                lambda buf, v: buf.at[k].set(v), recs, rec)
            return (k + 1, c2, recs2, blocked)

        state = (jnp.asarray(0, jnp.int32), carry, recs0,
                 jnp.asarray(False))
        return jax.lax.while_loop(cond, body, state)

    return jax.jit(run)


def _setup_from_fields(prob, E, nu, setup_opts: dict):
    """Host re-coarsening: assemble the operator at the current fields
    (cached COO plan) and run the cold symbolic ``gamg.setup``."""
    A = set_values_coo(prob.coo_plan, prob.assembler.value_stream(E, nu))
    return gamg.setup(A, prob.B, **setup_opts)


def march(prob, scenario, cfg: MarchConfig, *, mode: str = "adaptive",
          b: Optional[Array] = None,
          setup_opts: Optional[dict] = None) -> MarchResult:
    """Run the quasi-static march.  See the module docstring for modes.

    ``prob`` is an assembled ``ElasticityProblem`` on the device path
    (the march needs its ``DeviceAssembler`` and cached COO plan);
    ``setup_opts`` forwards to every ``gamg.setup`` (re)build.
    """
    if prob.assembler is None:
        raise ValueError(
            "the march needs the device assembly path: assemble with "
            "path='device' (the default)")
    if mode not in ("adaptive", "frozen", "resetup"):
        raise ValueError(f"invalid march mode {mode!r}: expected "
                         f"'adaptive', 'frozen' or 'resetup'")
    if mode == "resetup":
        cfg = dataclasses.replace(cfg, seg_len=1)
    assembler = prob.assembler
    b = prob.b if b is None else b
    setup_opts = dict(setup_opts or {})
    reg = obs_metrics.default_registry()
    labels = {"mode": mode}

    fields_fn = jax.jit(scenario.step_fields)
    carry = init_carry(scenario, b)
    E, nu, _ = fields_fn(carry.scen, carry.x, carry.step)
    setupd = _setup_from_fields(prob, E, nu, setup_opts)
    n_setups, n_recoveries = 1, 0
    reg.counter("march/setups",
                "gamg.setup builds performed by the march").inc(
                    1, labels=labels)

    rows: List[dict] = []
    attempts: List[dict] = []
    segments: List[SegmentInfo] = []
    status = "ok"

    if mode == "frozen":
        runner = make_scan_march(setupd, assembler, scenario, cfg)
        carry, recs = runner(b, carry)
        rec_np = {k: np.asarray(v) for k, v in recs._asdict().items()}
        advanced = rec_np["status"] == health.HEALTHY
        for i in range(cfg.n_steps):
            row = {k: v[i].item() for k, v in rec_np.items()}
            (rows if advanced[i] else attempts).append(row)
        steps_done = int(carry.step)
        if steps_done < cfg.n_steps:
            status = "failed"   # frozen mode has no recovery ladder
        segments.append(SegmentInfo(
            start=0, steps=steps_done, setup_id=0,
            reason="done" if status == "ok" else "blocked",
            iters=int(sum(r["iters"] for r in rows))))
    else:
        seg_runner = make_segment(setupd, assembler, scenario, cfg)
        n_total = jnp.asarray(cfg.n_steps, jnp.int32)
        need_rebuild = False
        retry_pending = False
        fail_step, fails_here = -1, 0
        while int(carry.step) < cfg.n_steps:
            seg_start = int(carry.step)
            ctx = (inject.suppress_transient() if retry_pending
                   else contextlib.nullcontext())
            with ctx:
                if need_rebuild:
                    E, nu, _ = fields_fn(carry.scen, carry.x, carry.step)
                    setupd = _setup_from_fields(prob, E, nu, setup_opts)
                    seg_runner = make_segment(setupd, assembler,
                                              scenario, cfg)
                    carry = carry._replace(stale=staleness_init(E))
                    n_setups += 1
                    reg.counter("march/setups").inc(1, labels=labels)
                    need_rebuild = False
                k, carry, recs, blocked = seg_runner(b, carry, n_total)
            retry_pending = False
            k, blocked = int(k), bool(blocked)
            tripped = bool(np.asarray(carry.stale.tripped))
            rec_np = {key: np.asarray(v)
                      for key, v in recs._asdict().items()}
            n_ok = k - 1 if blocked else k
            seg_rows = [{key: v[i].item() for key, v in rec_np.items()}
                        for i in range(n_ok)]
            rows.extend(seg_rows)
            seg_iters = int(sum(r["iters"] for r in seg_rows))
            if blocked:
                reason = "blocked"
            elif tripped:
                reason = "tripped"
            elif int(carry.step) >= cfg.n_steps:
                reason = "done"
            else:
                reason = "budget"
            segments.append(SegmentInfo(
                start=seg_start, steps=n_ok, setup_id=n_setups - 1,
                reason=reason, iters=seg_iters))
            reg.counter("march/segments",
                        "frozen-hierarchy march segments").inc(
                            1, labels=labels)
            reg.histogram("march/segment_steps",
                          "steps advanced per frozen segment",
                          buckets=obs_metrics.ITER_BUCKETS).observe(
                              n_ok, labels=labels)
            if blocked:
                bad = {key: v[k - 1].item()
                       for key, v in rec_np.items()}
                bad["step"] = int(carry.step)
                attempts.append(bad)
                if int(carry.step) == fail_step:
                    fails_here += 1
                else:
                    fail_step, fails_here = int(carry.step), 1
                if fails_here > cfg.max_recoveries:
                    status = "failed"
                    break
                # recovery ladder: rebuild against the current (last
                # healthy) trajectory point with transient faults
                # suppressed during the retraces, then retry the step
                n_recoveries += 1
                reg.counter("march/recoveries",
                            "blocked-step rebuild retries").inc(
                                1, labels=labels)
                need_rebuild, retry_pending = True, True
            elif tripped or mode == "resetup":
                need_rebuild = True

    reg.counter("march/steps", "march steps advanced").inc(
        len(rows), labels=labels)
    reg.counter("march/solve_iters", "total CG iterations").inc(
        sum(r["iters"] for r in rows), labels=labels)

    E, nu, _ = fields_fn(carry.scen, carry.x, carry.step)
    all_status = [r["status"] for r in rows] + \
        [a["status"] for a in attempts]
    return MarchResult(
        x=carry.x, scen_state=carry.scen, E=E, nu=nu,
        steps_done=int(carry.step), n_setups=n_setups,
        n_recoveries=n_recoveries, status=status,
        iters=np.asarray([r["iters"] for r in rows], np.int64),
        relres=np.asarray([r["relres"] for r in rows]),
        step_status=np.asarray([r["status"] for r in rows], np.int64),
        tripped=np.asarray([r["tripped"] for r in rows], bool),
        coeff_drift=np.asarray([r["coeff_drift"] for r in rows]),
        segments=segments, attempts=attempts,
        worst_status=int(health.worst_status(
            np.asarray(all_status))) if all_status else health.HEALTHY)
