"""Device-resident quasi-static simulation loops (time march).

The outer driver closing the paper's reuse loop: material evolution
marched through fused ``assembly -> recompute -> warm-started solve``
steps on device, with adaptive re-coarsening at staleness-tripped
segment boundaries.  See ``repro.sim.driver``.
"""
from repro.sim.driver import (
    MarchCarry,
    MarchConfig,
    MarchResult,
    SegmentInfo,
    StepRecord,
    init_carry,
    make_scan_march,
    make_segment,
    make_step,
    make_step_fn,
    march,
)
from repro.sim.scenarios import SofteningScenario, ThermalScenario
from repro.sim.staleness import (
    StalenessConfig,
    StalenessState,
    staleness_init,
    staleness_update,
)

__all__ = [
    "MarchCarry", "MarchConfig", "MarchResult", "SegmentInfo",
    "StepRecord", "init_carry", "make_scan_march", "make_segment",
    "make_step", "make_step_fn", "march",
    "SofteningScenario", "ThermalScenario",
    "StalenessConfig", "StalenessState", "staleness_init",
    "staleness_update",
]
