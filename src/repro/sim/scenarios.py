"""Built-in quasi-static material-evolution scenarios (the march laws).

A scenario is the coefficient-update law of the time march: a frozen
container of host-built constants exposing

* ``init_state()``                      — the evolution state pytree
  (damage field, nothing, ...) that rides the scan carry;
* ``step_fields(state, x, step)``       — pure and jittable: from the
  previous step's solution ``x`` and the evolution state, produce the
  per-element fields ``(E, nu)`` the step solves with plus the advanced
  state.  This is what the march feeds into the fused
  ``assembly -> recompute -> warm solve`` step, entirely on device.

Both built-ins update **values only** — mesh, boundary conditions and
the blocked-COO plan are fixed, which keeps every step inside the
cached-plan / state-gated reuse model (``repro.fem.assemble``).

``SofteningScenario`` — damage/plasticity-style softening: a
monotone per-element damage variable grows with the local displacement
magnitude and knocks down ``E``.  Softer elements displace more, so the
law feeds back on itself and the coefficient field walks steadily away
from the setup-time operator — the workload that makes adaptive
re-coarsening pay (``tests/test_march.py`` pins adaptive < frozen on
total CG iterations here).

``ThermalScenario`` — thermal-stress cycling: a stateless periodic
modulation of ``E`` with a per-element phase (a traveling hot spot).
Coefficients come back to where they started every period, so a frozen
hierarchy stays adequate — the counter-workload where the staleness
monitor should *not* trip with a tolerance above the cycle amplitude.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _element_dof_gather(mesh, free_nodes: np.ndarray) -> np.ndarray:
    """(ne, nn) gather map: element-local node -> padded free-node row.

    Fixed (eliminated) nodes map to row ``n_free`` — the caller appends a
    zero pad row to the reshaped solution, so clamped nodes contribute
    zero displacement without any masking in the traced law.
    """
    n_free = len(free_nodes)
    renum = np.full(mesh.n_nodes, n_free, dtype=np.int64)
    renum[free_nodes] = np.arange(n_free)
    return renum[mesh.connectivity]


def _padded_element_displacements(x: Array, gather: np.ndarray,
                                  n_free: int) -> Array:
    """(ne, nn, 3) per-element nodal displacements from the flat free-dof
    solution vector (clamped nodes read the zero pad row)."""
    u = x.reshape(n_free, 3)
    upad = jnp.concatenate([u, jnp.zeros((1, 3), u.dtype)], axis=0)
    return upad[gather]


@dataclasses.dataclass(frozen=True, eq=False)
class SofteningScenario:
    """Monotone damage softening: ``E = E0 * (1 - damage(x))``.

    ``damage' = clip(damage + rate * s_e, 0, d_max)`` with ``s_e`` the
    element-mean displacement magnitude — accumulating plasticity-style
    (damage never heals, so the law is monotone by construction) and
    capped at ``d_max`` so the operator stays SPD with a stiffness
    contrast of at most ``1 / (1 - d_max)``.  Elements that displace
    more soften faster and then displace more still — the positive
    feedback that drives the coefficient field heterogeneously toward
    the cap and makes the frozen prolongator go stale.
    """

    E0: Array                # (ne,) baseline stiffness
    nu0: Array               # (ne,) Poisson ratio (damage leaves it alone)
    gather: np.ndarray       # (ne, nn) element-dof gather map
    n_free: int
    rate: float = 0.01       # damage per unit element displacement, per step
    d_max: float = 0.99      # damage cap

    @classmethod
    def build(cls, prob, *, rate: float = 0.01, d_max: float = 0.99
              ) -> "SofteningScenario":
        """From an assembled ``ElasticityProblem`` (device path)."""
        ne = prob.mesh.n_elements
        E0 = (prob.E_field if prob.E_field is not None
              else jnp.ones((ne,), jnp.float64))
        nu0 = (prob.nu_field if prob.nu_field is not None
               else jnp.full((ne,), 0.3, jnp.float64))
        return cls(E0=jnp.asarray(E0), nu0=jnp.asarray(nu0),
                   gather=_element_dof_gather(prob.mesh, prob.free_nodes),
                   n_free=len(prob.free_nodes), rate=float(rate),
                   d_max=float(d_max))

    def init_state(self) -> Array:
        """Damage field, initially pristine."""
        return jnp.zeros_like(self.E0)

    def step_fields(self, state: Array, x: Array, step):
        ue = _padded_element_displacements(x, self.gather, self.n_free)
        s_e = jnp.linalg.norm(ue, axis=-1).mean(axis=-1)       # (ne,)
        damage = jnp.clip(state + self.rate * s_e, 0.0, self.d_max)
        return self.E0 * (1.0 - damage), self.nu0, damage


@dataclasses.dataclass(frozen=True, eq=False)
class ThermalScenario:
    """Thermal-stress cycling: ``E = E0 * (1 + amp * sin(2 pi t / period
    + phase))`` with a per-element phase from the element centroid —
    stateless, periodic, solution-independent."""

    E0: Array                # (ne,)
    nu0: Array               # (ne,)
    phase: Array             # (ne,) per-element phase offsets
    amp: float = 0.3         # relative modulation amplitude (< 1)
    period: float = 8.0      # steps per cycle

    @classmethod
    def build(cls, prob, *, amp: float = 0.3, period: float = 8.0
              ) -> "ThermalScenario":
        from repro.fem.assemble import element_centroids
        ne = prob.mesh.n_elements
        E0 = (prob.E_field if prob.E_field is not None
              else jnp.ones((ne,), jnp.float64))
        nu0 = (prob.nu_field if prob.nu_field is not None
               else jnp.full((ne,), 0.3, jnp.float64))
        c = element_centroids(prob.mesh)
        phase = 2.0 * np.pi * c.sum(axis=1) / max(c.sum(axis=1).max(), 1.0)
        return cls(E0=jnp.asarray(E0), nu0=jnp.asarray(nu0),
                   phase=jnp.asarray(phase), amp=float(amp),
                   period=float(period))

    def init_state(self):
        """No evolution state (empty pytree node in the carry)."""
        return ()

    def step_fields(self, state, x: Array, step):
        t = jnp.asarray(step, self.E0.dtype)
        mod = 1.0 + self.amp * jnp.sin(
            2.0 * jnp.pi * t / self.period + self.phase)
        return self.E0 * mod, self.nu0, state
