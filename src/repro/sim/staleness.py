"""Device-side hierarchy-staleness monitor (the re-coarsening policy).

The reuse model (PETSc ``-pc_gamg_reuse_interpolation``) freezes
aggregates and prolongator values at setup time; ``gamg.recompute`` only
refreshes operators and smoother data.  That is exactly right while the
coefficients drift a little — and measurably wrong once they drift a
lot: the frozen prolongator was smoothed against the *setup-time*
operator, and its interpolation quality (hence the CG iteration count)
decays as the true operator walks away from it.  SParSH-AMG frames
setup-reuse-vs-rebuild as an explicit runtime policy; this module is
that policy as a pure device function riding the march carry.

Two tripwires, both computed from quantities the march already holds —
no extra reductions over the hierarchy, no host syncs:

* **iteration drift** — a reference iteration count is established as
  the minimum over the first ``ref_window`` post-rebuild steps (warm
  starts settle within a couple of steps); once established, a step
  needing more than ``ref_iters + iter_drift`` iterations trips.
* **coefficient drift** — relative L2 distance of the per-element
  ``E`` field from its rebuild-time snapshot exceeding ``coeff_rtol``
  trips even before the iteration count degrades (the cheap leading
  indicator: the field is already on device and tiny compared to the
  operator).

``staleness_update`` is called once per march step inside the traced
segment; the ``tripped`` flag in the carry is what the segment's
``while_loop`` condition reads to cut a segment boundary.  The host then
rebuilds aggregates/prolongator via ``gamg.setup`` and resets the
monitor with ``staleness_init`` — see ``repro.sim.driver``.

Property contract (``tests/test_march.py``): monotone softening
eventually trips (the relative drift of ``E -> E * (1 - damage)`` grows
to the damage cap), constant coefficients never trip (zero drift,
iteration counts can only establish or match the reference).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

#: Sentinel "reference not yet established" iteration count.
_REF_UNSET = jnp.iinfo(jnp.int32).max


class StalenessConfig(NamedTuple):
    """Static policy knobs (baked into the traced segment)."""

    iter_drift: int = 4      # iterations above the reference that trip
    ref_window: int = 3      # steps that establish the reference count
    coeff_rtol: float = 0.4  # relative ||E - E_ref|| that trips


class StalenessState(NamedTuple):
    """Per-march monitor state (a pytree riding the scan carry)."""

    e_ref: Array        # (ne,) coefficient snapshot at the last rebuild
    ref_iters: Array    # int32 reference count (min over the window)
    steps_since: Array  # int32 steps since the last rebuild
    tripped: Array      # bool: a segment boundary is due
    coeff_drift: Array  # last relative coefficient drift (diagnostic)
    iter_excess: Array  # int32 last iters - reference (diagnostic)


def staleness_init(e_ref: Array) -> StalenessState:
    """Fresh monitor state for a hierarchy just built against ``e_ref``."""
    e_ref = jnp.asarray(e_ref)
    return StalenessState(
        e_ref=e_ref,
        ref_iters=jnp.asarray(_REF_UNSET, jnp.int32),
        steps_since=jnp.asarray(0, jnp.int32),
        tripped=jnp.asarray(False),
        coeff_drift=jnp.asarray(0.0, e_ref.dtype),
        iter_excess=jnp.asarray(0, jnp.int32))


def staleness_update(state: StalenessState, iters: Array, E: Array,
                     cfg: StalenessConfig) -> StalenessState:
    """One monitor step after a successful solve (pure, jittable).

    ``iters`` is the step's CG iteration count, ``E`` the per-element
    coefficient field the step solved with.  Inside the reference window
    the count only *establishes* the reference (min), so the first
    post-rebuild steps — whose warm starts are still settling — cannot
    trip the drift criterion themselves.
    """
    iters = jnp.asarray(iters, jnp.int32)
    in_window = state.steps_since < cfg.ref_window
    ref = jnp.where(in_window,
                    jnp.minimum(state.ref_iters, iters), state.ref_iters)
    # unset reference (e.g. ref_window=0) never reports an excess
    excess = iters - jnp.where(ref == _REF_UNSET, iters, ref)
    iter_trip = ~in_window & (excess > cfg.iter_drift)
    diff = jnp.linalg.norm(E - state.e_ref)
    base = jnp.maximum(jnp.linalg.norm(state.e_ref),
                       jnp.finfo(state.e_ref.dtype).tiny)
    drift = diff / base
    coeff_trip = drift > cfg.coeff_rtol
    return StalenessState(
        e_ref=state.e_ref,
        ref_iters=ref,
        steps_since=state.steps_since + 1,
        tripped=iter_trip | coeff_trip,
        coeff_drift=drift.astype(state.coeff_drift.dtype),
        iter_excess=excess.astype(jnp.int32))
