"""AdamW with global-norm clipping + optional int8 gradient compression.

Optimizer state is fp32 (m, v) regardless of param dtype; the update is a
pure function suitable for jit/SPMD — state shards inherit the parameter
sharding, giving ZeRO-style partitioning for free under FSDP specs.

Gradient compression (``compress_grads``) implements chunked int8
quantization with error feedback for the data-parallel all-reduce: at 1000+
node scale the DP gradient reduce-scatter is the dominant collective for
small models, and 4x shrink on the wire is the standard mitigation.  It is
exercised by tests and off by default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    import copy
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step: Array) -> Array:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig
                  ) -> Tuple[Any, Dict[str, Any], Array]:
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    lr = _schedule(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m / b1c
        vh = v / b2c
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (step_dir + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["mu"])
    flat_v = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm


# ---------------------------------------------------------------------------
# int8 chunked gradient compression with error feedback
# ---------------------------------------------------------------------------

def compress_grads(grads, err, chunk: int = 1024):
    """Quantize each leaf to int8 with per-chunk scales; carry residual.

    Returns (q_tree {q, scale}, new_err).  Decompress with
    ``decompress_grads``.  Error feedback makes the scheme unbiased over
    steps (Seide et al.; 1-bit Adam lineage).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        n = g32.size
        pad = (-n) % chunk
        flat = jnp.pad(g32.reshape(-1), (0, pad)).reshape(-1, chunk)
        scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(g.shape)
        return {"q": q, "scale": scale, "shape": g.shape}, g32 - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs]))


def decompress_grads(qtree):
    def one(d):
        n = 1
        for s in d["shape"]:
            n *= s
        flat = (d["q"].astype(jnp.float32) * d["scale"]).reshape(-1)[:n]
        return flat.reshape(d["shape"])

    return jax.tree_util.tree_map(one, qtree,
                                  is_leaf=lambda x: isinstance(x, dict)
                                  and "q" in x)


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
