"""train_step / serve_step builders — the functions the dry-run lowers.

``make_train_step``   forward (scan + per-layer remat) -> fp32 token-mean
                      cross-entropy -> backward -> AdamW update.  One jit.

``make_prefill``      causal forward producing logits for a prompt batch
                      (the prefill_32k cells).

``make_serve_step``   one-token decode against a seq_len KV cache — the
                      decode_32k / long_500k cells.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, apply_updates

Array = jax.Array


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Token-mean xent in fp32; labels < 0 are masked.

    The label log-prob is a one-hot *contraction*, not a gather: with the
    vocab dim sharded over "model", a take_along_axis gather forces XLA to
    all-gather the full (B,S,V) logits (measured: 24.7 GiB/device/step on
    qwen2 train_4k — EXPERIMENTS.md §Perf iteration 1); the contraction
    stays shard-local and reduces with one scalar-per-token psum.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, batch: Dict[str, Array], cfg: ModelConfig,
            cdt=jnp.bfloat16) -> Array:
    logits = T.forward_train(params, batch["tokens"], cfg, cdt,
                             enc_feats=batch.get("enc_feats"))
    return cross_entropy(logits, batch["labels"])


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    cdt=jnp.bfloat16):
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg, cdt)
        params, opt_state, gnorm = apply_updates(params, grads, opt_state,
                                                 opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill(cfg: ModelConfig, cdt=jnp.bfloat16):
    """prefill(params, tokens[, enc_feats]) -> logits (B, S, V)."""

    def prefill(params, tokens, enc_feats=None):
        return T.forward_train(params, tokens, cfg, cdt, remat=False,
                               enc_feats=enc_feats)

    return prefill


def make_serve_step(cfg: ModelConfig, cdt=jnp.bfloat16):
    """serve_step(params, cache, token, pos[, enc_out]) -> (logits, cache).

    ``cache`` is the stacked (L, ...) decode cache of ``init_full_cache``
    with capacity seq_len; ``pos`` the absolute position of the new token.
    """

    def serve_step(params, cache, token, pos, enc_out=None):
        return T.decode_step(params, token, pos, cache, cfg, cdt,
                             enc_out=enc_out)

    return serve_step


def make_init(cfg: ModelConfig):
    def init(key):
        return T.init_lm(cfg, key)

    return init
