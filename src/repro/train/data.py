"""Synthetic, deterministic, restartable token pipeline.

Production shape: each host owns a disjoint shard of the global batch
(``host_id``/``num_hosts``); batches are a pure function of (seed, step), so
a restart at step k regenerates bit-identical data without replaying the
stream — the property the fault-tolerance tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    enc_frames: int = 0          # >0 for enc-dec archs (stub frontend)
    d_model: int = 0


class SyntheticTokens:
    """Markov-ish synthetic LM data (not uniform noise, so loss can fall)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        B, S = self.local_batch, cfg.seq_len
        # structured stream: tokens follow a noisy linear-congruential walk,
        # giving the model a learnable next-token signal
        base = rng.integers(0, cfg.vocab_size, (B, 1))
        steps = rng.integers(1, 7, (B, S))
        toks = (base + np.cumsum(steps, axis=1)) % cfg.vocab_size
        noise = rng.random((B, S)) < 0.05
        toks = np.where(noise,
                        rng.integers(0, cfg.vocab_size, (B, S)), toks)
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if cfg.enc_frames:
            out["enc_feats"] = rng.standard_normal(
                (B, cfg.enc_frames, cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
