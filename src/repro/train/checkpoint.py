"""Sharded, integrity-checked checkpointing with elastic restore.

Format: one ``.npz`` per checkpoint (flattened path->array) + a JSON sidecar
with step, content hash and the mesh shape it was saved under.  Writes are
atomic (tmp + rename); ``CheckpointManager`` keeps the newest ``keep`` and
restores the newest *valid* one (corrupt/partial checkpoints are skipped —
the node-failure-during-save case).

Elastic restore: arrays are loaded host-side and ``jax.device_put`` against
whatever sharding the *new* mesh prescribes, so restoring onto a different
device count (scale up/down) is the same code path — tested 8 -> 4 devices.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _tree_hash(flat: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(np.ascontiguousarray(flat[k]).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    def _paths(self, step: int) -> Tuple[str, str]:
        base = os.path.join(self.directory, f"ckpt_{step:08d}")
        return base + ".npz", base + ".json"

    def save(self, step: int, tree, extra: Optional[dict] = None) -> str:
        flat = _flatten(tree)
        npz_path, meta_path = self._paths(step)
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        os.close(fd)
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, npz_path)          # atomic
        meta = {"step": step, "hash": _tree_hash(flat),
                "n_arrays": len(flat), "extra": extra or {},
                "mesh_devices": len(jax.devices())}
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        os.close(fd)
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, meta_path)
        self._prune()
        return npz_path

    def _prune(self):
        steps = self.available_steps()
        for s in steps[:-self.keep]:
            for p in self._paths(s):
                if os.path.exists(p):
                    os.remove(p)

    def available_steps(self):
        steps = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".json"):
                steps.append(int(f[5:13]))
        return sorted(steps)

    def restore_latest(self, template) -> Optional[Tuple[int, Any, dict]]:
        """Newest checkpoint that passes integrity; None if none valid."""
        for step in reversed(self.available_steps()):
            out = self.restore(step, template)
            if out is not None:
                return out
        return None

    def restore(self, step: int, template
                ) -> Optional[Tuple[int, Any, dict]]:
        npz_path, meta_path = self._paths(step)
        try:
            with open(meta_path) as f:
                meta = json.load(f)
            with np.load(npz_path) as z:
                flat = {k: z[k] for k in z.files}
            if _tree_hash(flat) != meta["hash"]:
                return None                       # corrupt payload
        except Exception:
            return None
        # rebuild in template order; elastic: device_put per template leaf
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            arr = flat[key]
            if hasattr(leaf, "sharding"):
                leaves.append(jax.device_put(arr.astype(leaf.dtype),
                                             leaf.sharding))
            else:
                leaves.append(arr)
        return meta["step"], jax.tree_util.tree_unflatten(treedef, leaves), \
            meta["extra"]
