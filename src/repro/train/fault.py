"""Fault tolerance: restart orchestration + straggler mitigation.

At thousand-node scale the failure model is: (a) a host dies mid-run
(restart from the last complete checkpoint), (b) a host dies mid-*save*
(the partial checkpoint must be detected and skipped), (c) a host runs slow
(straggler) and gates every synchronous collective.

``run_with_restarts`` drives a step function with checkpoint/resume and an
injectable failure schedule; because the data pipeline is a pure function of
(seed, step), a restarted run reproduces the uninterrupted run bit-for-bit —
asserted by the tests.

``StragglerMonitor`` implements the detection half of straggler mitigation
(robust z-score on per-host step durations); the mitigation hook reassigns
the slow host's data shard — in this single-process harness the reassignment
is recorded and tested, the collective semantics being host-count invariant
by construction of ``DataConfig``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.train.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class RestartReport:
    final_step: int
    restarts: int
    losses: List[float]
    resumed_from: List[int]


def run_with_restarts(init_state: Callable[[], Dict],
                      step_fn: Callable[[Dict, int], Dict],
                      loss_of: Callable[[Dict], float],
                      ckpt: CheckpointManager,
                      total_steps: int,
                      save_every: int = 5,
                      fail_at: Sequence[int] = (),
                      max_restarts: int = 10) -> RestartReport:
    """Drive training with checkpoint/restart.  ``fail_at`` injects a
    failure *before* those global steps complete (each fires once)."""
    pending_failures = sorted(set(fail_at))
    restarts = 0
    resumed_from: List[int] = []
    losses: List[float] = [float("nan")] * total_steps

    while True:
        state = init_state()
        start = 0
        restored = ckpt.restore_latest(state)
        if restored is not None:
            start, state, extra = restored
            resumed_from.append(start)
        try:
            for step in range(start, total_steps):
                if pending_failures and step == pending_failures[0]:
                    pending_failures.pop(0)
                    raise SimulatedFailure(f"injected at step {step}")
                state = step_fn(state, step)
                losses[step] = loss_of(state)
                if (step + 1) % save_every == 0 or step + 1 == total_steps:
                    ckpt.save(step + 1, state)
            return RestartReport(final_step=total_steps, restarts=restarts,
                                 losses=losses, resumed_from=resumed_from)
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise


@dataclasses.dataclass
class StragglerMonitor:
    """Robust per-host step-duration anomaly detector."""

    n_hosts: int
    window: int = 16
    threshold: float = 3.0       # robust z-score
    history: Optional[np.ndarray] = None
    reassignments: List[int] = dataclasses.field(default_factory=list)

    def observe(self, durations: Sequence[float]) -> List[int]:
        """Record one step's per-host durations; returns flagged hosts."""
        d = np.asarray(durations, dtype=np.float64)[None]
        self.history = d if self.history is None else \
            np.concatenate([self.history, d], axis=0)[-self.window:]
        med = np.median(self.history)
        mad = np.median(np.abs(self.history - med)) + 1e-9
        z = (self.history[-1] - med) / (1.4826 * mad)
        flagged = [i for i in range(self.n_hosts)
                   if z[i] > self.threshold]
        return flagged

    def mitigate(self, flagged: Sequence[int], num_hosts: int) -> Dict:
        """Reassign a flagged host's data shard to its neighbor (recorded;
        the data pipeline regenerates any shard from (seed, step, host))."""
        plan = {}
        for h in flagged:
            plan[h] = (h + 1) % num_hosts
            self.reassignments.append(h)
        return plan
