"""Hierarchy-reusing solve server: request streams -> bucketed panel solves.

The production shape of the paper's reuse model: one cold ``GAMGSetup``
(aggregates, prolongators, PtAP plans) serves *many* solves — Newton
steps, load cases, client requests.  The server accepts a stream of
right-hand sides against the cached hierarchy and drains it in panels:

* requests are batched into column panels and padded up to a small static
  set of bucket widths (default k in {1, 2, 4, 8, 16}), so the jitted
  panel solve traces **once per bucket**, never per request count;
* padding columns are zero vectors — inactive from the first masked-PCG
  iteration, they cost VPU lanes but no extra iterations;
* each request gets back its own column, per-column iteration count and
  relative residual (the per-column masking keeps those identical to a
  dedicated single-RHS solve);
* ``update_operator`` refreshes the hierarchy through the state-gated hot
  recompute (new values, same structure) without touching the buckets.

Robustness contract (ISSUE 6): a malformed request — wrong shape, a
payload that cannot convert to the panel dtype, or non-finite values —
is rejected at ``submit`` with a ``ValueError`` before it can poison a
panel.  Corruption that arises *in flight* (a faulted kernel, a poisoned
hierarchy) is quarantined per column by the masked PCG's health flags:
the broken column freezes, its neighbours finish untouched, and its
report carries ``status="degraded"`` (usable best iterate) or
``status="failed"`` (solution zeroed — an explicit failure must never
look like an answer).  A flush therefore *never* raises because one
request went bad, and never returns an unflagged NaN.  With a
``recover=`` policy (or ``REPRO_RECOVER``), failed/degraded columns get
one bounded retry on freshly traced closures under
``inject.suppress_transient()`` — transient faults vanish from the fresh
traces, persistent ones keep the explicit failure.

``examples/serve_amg.py`` drives this end to end;
``benchmarks/table6_multirhs.py`` measures the per-RHS amortization the
bucketing buys.
"""
from __future__ import annotations

import time
from typing import Hashable, List, NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import gamg
from repro.multirhs.block_krylov import make_block_solve
from repro.obs import trace as obs_trace
from repro.obs.server_metrics import ServerMetrics
from repro.robust import inject
from repro.robust.health import (
    BREAKDOWN,
    HEALTHY,
    NONFINITE,
    STATUS_NAMES,
)


class SolveReport(NamedTuple):
    request_id: Hashable
    x: np.ndarray         # (n,) solution for this request
    iters: int
    relres: float
    converged: bool
    k_bucket: int         # panel width the request was served in
    status: str = "ok"    # "ok" | "degraded" | "failed" | "recovered"
    health: int = HEALTHY  # raw health code (repro.robust.STATUS_NAMES)
    # observability (ISSUE 7): end-to-end submit->report latency (includes
    # any recovery retry this request triggered), submit->batch-start wait,
    # and — when the server records history — this request's per-iteration
    # residual-norm trace ((maxiter,), NaN past its final iteration).
    latency_s: float = 0.0
    queue_wait_s: float = 0.0
    history: "np.ndarray | None" = None


class AMGSolveServer:
    """Setup-once, serve-many front end over a cached GAMG hierarchy."""

    def __init__(self, setupd: gamg.GAMGSetup, a_fine_data, *,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16),
                 rtol: float = 1e-8, maxiter: int = 200,
                 assembler=None, recover=None, record_history=None):
        from repro.kernels.backend import resolve_recover
        buckets_in = [int(k) for k in buckets]
        if not buckets_in:
            raise ValueError("buckets must be a non-empty sequence of "
                             "panel widths")
        if min(buckets_in) < 1:
            raise ValueError(f"bucket widths must be positive ints, got "
                             f"{buckets_in}")
        if len(set(buckets_in)) != len(buckets_in):
            raise ValueError(f"duplicate bucket widths in {buckets_in}: "
                             f"each width traces one panel solve, list "
                             f"each once")
        buckets = tuple(sorted(buckets_in))
        self.setupd = setupd
        self.buckets = buckets
        self.n = int(setupd.stats["level_rows"][0])
        # panels are assembled at the policy's *Krylov* dtype (fp64 under
        # every stock policy): every rhs is force-cast to it at submit
        # time, so a mixed-dtype burst can never have one request's dtype
        # decide the panel's — and a reduced-precision-resident hierarchy
        # (e.g. ``precision="f32"``) still serves full-fp64 requests, the
        # cast to the hierarchy dtype happening only at the masked PCG's
        # preconditioner boundary.
        self.dtype = np.dtype(setupd.precision.krylov_dtype)
        self._rtol = rtol
        self._maxiter = maxiter
        # per-request residual-history recording (the block PCG's
        # record_history parity, ISSUE 7): None defers to the obs knob —
        # on whenever REPRO_OBS (or a ``use`` scope) is not "off".
        if record_history is None:
            record_history = obs_trace.resolve() != "off"
        self._record_history = bool(record_history)
        self._recompute = gamg.make_recompute(setupd)
        self._solve = make_block_solve(setupd, rtol=rtol, maxiter=maxiter,
                                       record_history=self._record_history)
        self._a_fine_data = jnp.asarray(a_fine_data)
        self.hierarchy = self._recompute(self._a_fine_data)
        # bounded per-column retry on flagged columns (None disables);
        # resolve_recover honours the REPRO_RECOVER env knob
        self.recover = resolve_recover(recover)
        # optional device-assembly binding: coefficient updates (material
        # fields, not value streams) run assembly + recompute as one
        # jitted program; built at construction so a mismatched plan
        # fails here, not at the first update.
        self.assembler = assembler
        self._coeff_recompute = None if assembler is None else \
            gamg.make_coeff_recompute(setupd, assembler)
        self._coeff_fields = None       # last (E, nu), for clean retries
        self._pending: List[tuple] = []
        self._next_id = 0
        self.stats = {
            "requests": 0, "batches": 0, "padded_columns": 0,
            "recomputes": 0, "coefficient_updates": 0,
            "solves_per_k": {k: 0 for k in buckets},
            "rejected": 0, "degraded": 0, "failed": 0, "recovered": 0,
        }
        # always-on host-side instrumentation (repro.obs.server_metrics):
        # pure clocks and counters around work the server already does, so
        # the traced programs — and the REPRO_OBS=off bitwise contract —
        # are untouched.
        self._metrics = ServerMetrics(buckets)

    # ---- observability ---------------------------------------------------
    def metrics(self) -> ServerMetrics:
        """The server's measurement surface (latency/padding histograms,
        outcome counters; export via ``.to_prometheus()``/``.to_jsonl()``)."""
        return self._metrics

    def snapshot(self) -> dict:
        """One plain-dict health/throughput summary (dashboard poll)."""
        return self._metrics.snapshot()

    # ---- operator lifecycle ---------------------------------------------
    def update_operator(self, a_fine_data) -> None:
        """Hot path: new fine values, same structure (state-gated PtAP)."""
        self._a_fine_data = jnp.asarray(a_fine_data)
        self._coeff_fields = None
        with self._metrics.registry.timer("server/recompute_seconds") as t:
            self.hierarchy = t.block(self._recompute(self._a_fine_data))
        self.stats["recomputes"] += 1

    def update_coefficients(self, E, nu) -> None:
        """Hot path: new material fields (per-element arrays or scalars).

        Device assembly (vmapped quadrature through the cached COO plan)
        fused with the state-gated recompute — the server's quasi-static
        client contract: ship two small coefficient arrays, not an
        ``(nnzb, 3, 3)`` value stream.  Fields are force-cast to the
        assembler dtype, so mixed-dtype clients share one traced program.
        """
        if self.assembler is None:
            raise ValueError(
                "update_coefficients needs an assembler: construct the "
                "server with assembler=problem.assembler (device assembly "
                "path)")
        E, nu = self.assembler.as_fields(E, nu)
        self._coeff_fields = (E, nu)
        with self._metrics.registry.timer(
                "server/coeff_update_seconds") as t:
            self.hierarchy = t.block(self._coeff_recompute(E, nu))
        self.stats["recomputes"] += 1
        self.stats["coefficient_updates"] += 1

    # ---- request stream --------------------------------------------------
    def submit(self, b, request_id: Optional[Hashable] = None) -> Hashable:
        """Queue one right-hand side; returns its request id.

        The validation gate: a rhs that is the wrong shape, cannot convert
        to the panel dtype, or carries NaN/Inf is rejected HERE with a
        ``ValueError`` — one poison request must never reach a shared
        panel (where rejecting it would mean re-solving its neighbours).
        """
        try:
            b = np.asarray(b, dtype=self.dtype)
        except (TypeError, ValueError) as e:
            self.stats["rejected"] += 1
            self._metrics.rejected.inc()
            raise ValueError(
                f"rhs does not convert to the panel dtype "
                f"{self.dtype}: {e}") from e
        if b.shape != (self.n,):
            self.stats["rejected"] += 1
            self._metrics.rejected.inc()
            raise ValueError(f"rhs shape {b.shape} != ({self.n},)")
        if not np.isfinite(b).all():
            self.stats["rejected"] += 1
            self._metrics.rejected.inc()
            raise ValueError(
                f"rhs contains {int((~np.isfinite(b)).sum())} non-finite "
                f"values — rejected before panel assembly")
        if request_id is None:
            request_id = self._next_id
            self._next_id += 1
        self._pending.append((request_id, b, time.perf_counter()))
        self._metrics.requests.inc()
        self._metrics.pending.set(len(self._pending))
        return request_id

    def _bucket_for(self, count: int) -> int:
        """Smallest bucket width holding ``count`` columns.

        ``count > buckets[-1]`` raises: ``flush`` caps chunks at the
        largest bucket, so a bigger count is a caller/bookkeeping bug —
        silently truncating it would drop requests.
        """
        if count < 1:
            raise ValueError(f"chunk must hold at least one request, "
                             f"got {count}")
        if count > self.buckets[-1]:
            raise ValueError(f"chunk of {count} requests exceeds the "
                             f"largest bucket width {self.buckets[-1]}")
        for k in self.buckets:
            if k >= count:
                return k
        raise AssertionError("unreachable: count <= buckets[-1]")

    # ---- flagged-column recovery ----------------------------------------
    def _retry_column(self, b: np.ndarray):
        """One bounded retry of a flagged column: fresh jitted closures +
        fresh hierarchy under ``suppress_transient`` (one-off corruption
        vanishes from fresh traces; persistent faults survive and keep
        the explicit failure)."""
        with self._metrics.registry.timer("server/retry_seconds") as t, \
                inject.suppress_transient():
            recompute = gamg.make_recompute(self.setupd)
            solve = make_block_solve(self.setupd, rtol=self._rtol,
                                     maxiter=self._maxiter)
            if self._coeff_fields is not None:
                coeff = gamg.make_coeff_recompute(self.setupd,
                                                  self.assembler)
                hier = coeff(*self._coeff_fields)
            else:
                hier = recompute(self._a_fine_data)
            return t.block(solve(hier, jnp.asarray(b[:, None])))

    def _classify(self, code: int, converged: bool) -> str:
        if code == HEALTHY and converged:
            return "ok"
        if code in (BREAKDOWN, NONFINITE):
            return "failed"
        return "degraded"       # maxiter / stagnation: best iterate usable

    def flush(self) -> List[SolveReport]:
        """Drain the queue: bucketed, padded, batched solves; one report
        per request, in submission order.

        Per-column health classification — a flagged column degrades or
        fails *its own report only* (the masked PCG froze it without
        touching its panel neighbours).  Failed columns return zeros,
        degraded columns their best iterate; neither ever carries a NaN.
        With ``self.recover`` set, flagged columns get one retry via
        ``_retry_column`` first.

        Every report carries its timing (ISSUE 7): ``queue_wait_s`` from
        submit to its batch starting, ``latency_s`` from submit to the
        report existing — computed *after* any recovery retry, so a
        retried request's latency owns the retry it caused (previously a
        recovered request would have under-reported its latency by the
        whole retry).  The batch's blocked solve wall time and the
        per-request numbers also land in ``self.metrics()``.
        """
        reports: List[SolveReport] = []
        kmax = self.buckets[-1]
        while self._pending:
            chunk = self._pending[:kmax]
            del self._pending[:kmax]
            self._metrics.pending.set(len(self._pending))
            t_batch = time.perf_counter()
            k = self._bucket_for(len(chunk))
            B = np.zeros((self.n, k), self.dtype)
            for j, (_, b, _) in enumerate(chunk):
                B[:, j] = b
            out = self._solve(self.hierarchy, jnp.asarray(B))
            res, hist = out if self._record_history else (out, None)
            x = np.asarray(res.x)
            iters = np.asarray(res.iters)
            relres = np.asarray(res.relres)
            conv = np.asarray(res.converged)
            codes = np.asarray(res.health.status)
            hist_np = None if hist is None else np.asarray(hist)
            # every result array is on host now — the clock stop is honest
            solve_s = time.perf_counter() - t_batch
            for j, (rid, b_j, t_sub) in enumerate(chunk):
                code = int(codes[j])
                status = self._classify(code, bool(conv[j]))
                x_j, it_j = x[:, j], int(iters[j])
                rr_j = float(relres[j])
                if status != "ok" and self.recover is not None:
                    r1 = self._retry_column(b_j)
                    c1 = int(np.asarray(r1.health.status)[0])
                    if c1 == HEALTHY and bool(np.asarray(r1.converged)[0]):
                        status, code = "recovered", c1
                        x_j = np.asarray(r1.x)[:, 0]
                        it_j = int(np.asarray(r1.iters)[0])
                        rr_j = float(np.asarray(r1.relres)[0])
                if status == "failed":
                    # explicit failure: never hand back a maybe-iterate
                    x_j = np.zeros_like(x_j)
                elif not np.isfinite(x_j).all():  # pragma: no cover
                    # belt-and-braces: the masked PCG's best-iterate
                    # tracking keeps flagged columns finite by
                    # construction; if that invariant ever breaks,
                    # fail the report rather than leak a NaN
                    status, x_j = "failed", np.zeros_like(x_j)
                if status in ("degraded", "failed", "recovered"):
                    self.stats[status] += 1
                # latency clocked here, after any retry: the client waited
                # through it, so this request's latency includes it
                queue_wait = t_batch - t_sub
                latency = time.perf_counter() - t_sub
                self._metrics.record_request(status, it_j, queue_wait,
                                             latency)
                reports.append(SolveReport(
                    request_id=rid, x=x_j, iters=it_j,
                    relres=rr_j, converged=bool(conv[j]) or
                    status == "recovered",
                    k_bucket=k, status=status, health=code,
                    latency_s=latency, queue_wait_s=queue_wait,
                    history=None if hist_np is None else hist_np[:, j]))
            self.stats["requests"] += len(chunk)
            self.stats["batches"] += 1
            self.stats["padded_columns"] += k - len(chunk)
            self.stats["solves_per_k"][k] += 1
            self._metrics.record_batch(k, len(chunk), solve_s)
        return reports

    def serve(self, rhs_list: Sequence) -> List[SolveReport]:
        """Convenience: submit a batch of RHS vectors and flush."""
        for b in rhs_list:
            self.submit(b)
        return self.flush()


__all__ = ["AMGSolveServer", "SolveReport", "STATUS_NAMES"]
