"""Batched PCG over column panels with per-column convergence masking.

One Krylov iteration on a ``(n, k)`` panel runs the operator and the AMG
preconditioner as SpMM — streaming A's values+indices once for all k
columns — while every CG scalar (``alpha``, ``beta``, ``rz``) becomes a
length-k vector of per-column reductions.  CG columns are mathematically
independent, so masking converged columns (their updates frozen at zero)
reproduces the looped single-RHS trajectories column by column: the same
iteration counts, the same solutions to fp tolerance
(``tests/test_multirhs.py`` + the property test assert both).

Convergence is monitored on the unpreconditioned residual norm per column,
matching ``repro.core.krylov.pcg`` — iteration-count parity with the
single-RHS path depends on the two monitors being identical.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.krylov import wrap_precond
from repro.core.vcycle import Hierarchy, fine_operator, vcycle
from repro.core.spmv import apply_ell

Array = jax.Array


class BlockCGResult(NamedTuple):
    x: Array          # (n, k) solutions
    iters: Array      # (k,)   iterations applied to each column
    relres: Array     # (k,)   final per-column relative residual
    converged: Array  # (k,)   bool


def _col_dot(a: Array, b: Array) -> Array:
    """Per-column dot: reduce every axis but the trailing panel axis."""
    return jnp.sum(a * b, axis=tuple(range(a.ndim - 1)))


def _col_norm(a: Array) -> Array:
    return jnp.sqrt(jnp.sum(a * a, axis=tuple(range(a.ndim - 1))))


def block_pcg(apply_a: Callable[[Array], Array],
              apply_m: Callable[[Array], Array],
              B: Array, x0: Array | None = None, rtol: float = 1e-8,
              maxiter: int = 200, *,
              col_dot: Callable[[Array, Array], Array] = _col_dot,
              col_norm: Callable[[Array], Array] = _col_norm,
              precond_dtype=None) -> BlockCGResult:
    """PCG on a panel ``B: (..., k)`` with per-column masking.

    A column is *active* while its residual exceeds ``rtol * ||b_col||``;
    frozen columns receive zero updates (``alpha = 0``) and keep their CG
    state, so the surviving columns' arithmetic is exactly the single-RHS
    recurrence.  The loop runs until every column converges or ``maxiter``.
    Zero columns (``||b|| ~ 0``) are inactive from the start (iters 0,
    converged, relres 0) — that is what makes the solve server's padding
    columns free.  Their denominator floor is ``finfo(B.dtype).tiny``
    (dtype-aware, like ``core.krylov.pcg``): a literal 1e-300 underflows
    to 0 below f64 and would NaN the zero columns' relres.

    ``col_dot`` / ``col_norm`` are the per-column reductions (everything
    but the trailing panel axis -> ``(k,)``).  The distributed path
    injects psum-reducing versions and runs this *same* recurrence over
    ``(rpad, bs, k)`` slabs inside shard_map — the dist-vs-single
    iteration-parity invariant depends on this body being the single
    source of truth (mirroring how ``core.vcycle`` shares the smoother
    recurrences).

    ``precond_dtype`` is the same mixed-precision boundary as
    ``core.krylov.pcg``: the panel residual is cast down before
    ``apply_m`` and the result cast back, so the masked outer recurrence
    stays at the Krylov dtype over a reduced-precision hierarchy.
    """
    apply_m = wrap_precond(apply_m, precond_dtype, B.dtype)
    x = jnp.zeros_like(B) if x0 is None else x0
    r = B - apply_a(x)
    z = apply_m(r)
    p = z
    rz = col_dot(r, z)
    bnorm = jnp.maximum(col_norm(B), jnp.finfo(B.dtype).tiny)
    rnorm = col_norm(r)

    def cond(state):
        x, r, z, p, rz, rnorm, iters, k = state
        return jnp.any(rnorm > rtol * bnorm) & (k < maxiter)

    def body(state):
        x, r, z, p, rz, rnorm, iters, k = state
        active = rnorm > rtol * bnorm
        Ap = apply_a(p)
        pAp = col_dot(p, Ap)
        # frozen columns: guard the denominators, zero the step
        alpha = jnp.where(active, rz / jnp.where(active, pAp, 1.0), 0.0)
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_m(r)
        rz_new = col_dot(r, z)
        beta = jnp.where(active, rz_new / jnp.where(active, rz, 1.0), 0.0)
        p = jnp.where(active, z + beta * p, p)
        rz = jnp.where(active, rz_new, rz)
        rnorm = col_norm(r)       # frozen columns: r unchanged -> unchanged
        iters = iters + active.astype(iters.dtype)
        return x, r, z, p, rz, rnorm, iters, k + 1

    iters0 = jnp.zeros(B.shape[-1], jnp.int32)
    state = (x, r, z, p, rz, rnorm, iters0, jnp.asarray(0))
    x, r, z, p, rz, rnorm, iters, k = jax.lax.while_loop(cond, body, state)
    return BlockCGResult(x=x, iters=iters, relres=rnorm / bnorm,
                         converged=rnorm <= rtol * bnorm)


def make_block_solve(setupd, rtol: float = 1e-8, maxiter: int = 200):
    """Jitted hot panel solve: ``(Hierarchy, B: (n, k)) -> BlockCGResult``.

    The multi-RHS twin of ``repro.core.gamg.make_solve`` — same smoother
    configuration, same hierarchy pytree, SpMM everywhere.  jax.jit traces
    once per distinct k; the solve server buckets request streams to a
    static k set precisely so this cache stays small.
    """
    smoother, degree = setupd.smoother, setupd.degree
    precond_dtype = setupd.precision.smoother_dtype

    @partial(jax.jit, static_argnames=())
    def solve(hier: Hierarchy, B: Array) -> BlockCGResult:
        def apply_a(X):
            return apply_ell(fine_operator(hier), X)

        def apply_m(R):
            return vcycle(hier, R, smoother=smoother, degree=degree)

        return block_pcg(apply_a, apply_m, B, rtol=rtol, maxiter=maxiter,
                         precond_dtype=precond_dtype)

    return solve
