"""Batched PCG over column panels with per-column convergence masking.

One Krylov iteration on a ``(n, k)`` panel runs the operator and the AMG
preconditioner as SpMM — streaming A's values+indices once for all k
columns — while every CG scalar (``alpha``, ``beta``, ``rz``) becomes a
length-k vector of per-column reductions.  CG columns are mathematically
independent, so masking converged columns (their updates frozen at zero)
reproduces the looped single-RHS trajectories column by column: the same
iteration counts, the same solutions to fp tolerance
(``tests/test_multirhs.py`` + the property test assert both).

Convergence is monitored on the unpreconditioned residual norm per column,
matching ``repro.core.krylov.pcg`` — iteration-count parity with the
single-RHS path depends on the two monitors being identical.

Health monitoring rides the same per-column masks: a column whose
recurrence goes NaN/Inf, breaks down or stagnates is *quarantined* — its
updates freeze exactly like a converged column's, its flags are recorded
per column in ``BlockCGResult.health``, and its panel neighbours keep
iterating untouched.  This is the mechanism the solve server's per-request
``degraded``/``failed`` statuses are built on.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.krylov import wrap_precond
from repro.core.vcycle import Hierarchy, fine_operator, vcycle
from repro.core.spmv import apply_ell
from repro.obs import trace as obs_trace
from repro.robust import inject
from repro.robust.health import SolveHealth, status_of

Array = jax.Array


class BlockCGResult(NamedTuple):
    x: Array          # (n, k) solutions
    iters: Array      # (k,)   iterations applied to each column
    relres: Array     # (k,)   final per-column relative residual
    converged: Array  # (k,)   bool
    health: SolveHealth  # per-column (k,) health record
    # device-side solve counters (repro.obs.trace.CycleTally) when the
    # panel ran under REPRO_OBS=counters; None (an empty pytree node —
    # no traced-structure change) otherwise.
    counters: "obs_trace.CycleTally | None" = None


def _col_dot(a: Array, b: Array) -> Array:
    """Per-column dot: reduce every axis but the trailing panel axis."""
    return jnp.sum(a * b, axis=tuple(range(a.ndim - 1)))


def _col_norm(a: Array) -> Array:
    return jnp.sqrt(jnp.sum(a * a, axis=tuple(range(a.ndim - 1))))


def block_pcg(apply_a: Callable[[Array], Array],
              apply_m: Callable[[Array], Array],
              B: Array, x0: Array | None = None, rtol: float = 1e-8,
              maxiter: int = 200, *,
              col_dot: Callable[[Array, Array], Array] = _col_dot,
              col_norm: Callable[[Array], Array] = _col_norm,
              precond_dtype=None, stall_window: int = 40,
              record_history: bool = False, tally=None):
    """PCG on a panel ``B: (..., k)`` with per-column masking.

    ``x0`` warm-starts every column from a prior ``(..., k)`` iterate
    panel (``None`` = cold zero start, bitwise unchanged); a column
    seeded within tolerance is inactive from iteration 0 — the same
    contract as ``core.krylov.pcg``'s warm start, column-wise.

    A column is *active* while its residual exceeds ``rtol * ||b_col||``
    and no health flag has tripped; frozen columns receive zero updates
    (``alpha = 0``) and keep their CG state, so the surviving columns'
    arithmetic is exactly the single-RHS recurrence.  The loop runs until
    every column converges or is flagged, or ``maxiter``.
    Zero columns (``||b|| ~ 0``) are inactive from the start (iters 0,
    converged, relres 0) — that is what makes the solve server's padding
    columns free.  Their denominator floor is ``finfo(B.dtype).tiny``
    (dtype-aware, like ``core.krylov.pcg``): a literal 1e-300 underflows
    to 0 below f64 and would NaN the zero columns' relres.

    ``col_dot`` / ``col_norm`` are the per-column reductions (everything
    but the trailing panel axis -> ``(k,)``).  The distributed path
    injects psum-reducing versions and runs this *same* recurrence over
    ``(rpad, bs, k)`` slabs inside shard_map — the dist-vs-single
    iteration-parity invariant depends on this body being the single
    source of truth (mirroring how ``core.vcycle`` shares the smoother
    recurrences).

    ``precond_dtype`` is the same mixed-precision boundary as
    ``core.krylov.pcg``: the panel residual is cast down before
    ``apply_m`` and the result cast back, so the masked outer recurrence
    stays at the Krylov dtype over a reduced-precision hierarchy.

    Health (``BlockCGResult.health``, per-column ``SolveHealth``): the
    operator and the V-cycle are column-independent, so corruption stays
    in its column; a flagged column is quarantined (frozen like a
    converged one, its broken step discarded) and its minimum-residual
    iterate is what the panel returns for it.  Clean columns' arithmetic,
    iteration counts and relres are bitwise unchanged.

    ``record_history=True`` (static, trace-time — parity with
    ``core.krylov.pcg``) additionally returns a ``(maxiter, k)`` buffer
    of per-column unpreconditioned residual norms: slot ``[i, c]`` holds
    column ``c``'s ``||r||`` after iteration ``i+1``, NaN once the column
    froze (converged, quarantined, or never active) — so a trace reads
    off each column's trajectory with its freeze point explicit.

    ``tally=`` (ISSUE 7) threads a ``repro.obs.trace.CycleTally`` through
    the carry exactly like ``pcg``; ``apply_m`` must then be the threaded
    ``(R, tally) -> (Z, tally)`` form.  The panel counts one operator /
    preconditioner application per *iteration* (SpMM streams A once for
    all columns — that is the point of the panel).  ``tally=None``
    (default) appends an empty pytree node: zero jaxpr residue.
    """
    counted = tally is not None
    if counted:
        apply_m = obs_trace.wrap_threaded_precond(apply_m, precond_dtype,
                                                  B.dtype)
    else:
        apply_m = wrap_precond(apply_m, precond_dtype, B.dtype)
    x = jnp.zeros_like(B) if x0 is None else x0
    r = B - apply_a(x)
    if counted:
        tally = tally._replace(operator_applies=tally.operator_applies + 1)
        z, tally = apply_m(r, tally)
    else:
        z = apply_m(r)
    tl0 = tally if counted else ()
    p = z
    rz = col_dot(r, z)
    bnorm = jnp.maximum(col_norm(B), jnp.finfo(B.dtype).tiny)
    rnorm = col_norm(r)
    nonf0 = ~jnp.isfinite(rnorm) | ~jnp.isfinite(rz)
    brk0 = ~nonf0 & (rz <= 0) & (rnorm > rtol * bnorm)

    def cond(state):
        (x, r, z, p, rz, rnorm, iters, k, best, stall, brk, nonf,
         hist, tl) = state
        active = ((rnorm > rtol * bnorm) & ~brk & ~nonf
                  & (stall < stall_window))
        return jnp.any(active) & (k < maxiter)

    def body(state):
        (x, r, z, p, rz, rnorm, iters, k,
         (best_x, best_rnorm, best_iter), stall, brk, nonf,
         hist, tl) = state
        active = ((rnorm > rtol * bnorm) & ~brk & ~nonf
                  & (stall < stall_window))
        Ap = inject.maybe("spmv", apply_a(p), step=k)
        pAp = col_dot(p, Ap)
        # frozen columns: guard the denominators, zero the step
        alpha = jnp.where(active, rz / jnp.where(active, pAp, 1.0), 0.0)
        x_new = x + alpha * p
        r_new = r - alpha * Ap
        if counted:
            tl = tl._replace(operator_applies=tl.operator_applies + 1)
            z_new, tl = apply_m(r_new, tl)
            z_new = inject.maybe("precond", z_new, step=k)
        else:
            z_new = inject.maybe("precond", apply_m(r_new), step=k)
        rz_new = col_dot(r_new, z_new)
        beta = jnp.where(active, rz_new / jnp.where(active, rz, 1.0), 0.0)
        rnorm_new = col_norm(r_new)
        nonf_new = active & (~jnp.isfinite(pAp) | ~jnp.isfinite(rnorm_new)
                             | ~jnp.isfinite(rz_new))
        brk_new = active & ~nonf_new & ((pAp <= 0)
                                        | ((rz_new <= 0)
                                           & (rnorm_new > rtol * bnorm)))
        ok_step = active & ~nonf_new & ~brk_new
        # a broken column's step is discarded — it keeps its last healthy
        # state, is quarantined by its flag, and its neighbours continue
        x = jnp.where(ok_step | ~active, x_new, x)
        r = jnp.where(ok_step | ~active, r_new, r)
        z = jnp.where(ok_step, z_new, z)
        p = jnp.where(ok_step, z_new + beta * p, p)
        rz = jnp.where(ok_step, rz_new, rz)
        rnorm = jnp.where(ok_step, rnorm_new, rnorm)
        improved = ok_step & (rnorm_new < best_rnorm)
        best_x = jnp.where(improved, x_new, best_x)
        best_rnorm = jnp.where(improved, rnorm_new, best_rnorm)
        best_iter = jnp.where(improved, k + 1, best_iter)
        stall = jnp.where(improved, 0, stall + active.astype(stall.dtype))
        iters = iters + active.astype(iters.dtype)
        if record_history:
            # frozen columns (converged / quarantined / broken step) stay
            # NaN — the trace shows exactly where each column stopped
            hist = hist.at[k].set(jnp.where(ok_step, rnorm_new, jnp.nan))
        return (x, r, z, p, rz, rnorm, iters, k + 1,
                (best_x, best_rnorm, best_iter), stall,
                brk | brk_new, nonf | nonf_new, hist, tl)

    iters0 = jnp.zeros(B.shape[-1], jnp.int32)
    # record_history=False contributes an *empty* carry node (like the
    # tally) — the default panel jaxpr is exactly the pre-obs one
    hist0 = (jnp.full((maxiter, B.shape[-1]), jnp.nan, rnorm.dtype)
             if record_history else ())
    # a NaN initial residual must not poison the best-so-far tracking
    best_rnorm0 = jnp.where(jnp.isfinite(rnorm), rnorm, jnp.inf)
    state = (x, r, z, p, rz, rnorm, iters0, jnp.asarray(0),
             (x, best_rnorm0, jnp.zeros(B.shape[-1], jnp.int32)),
             jnp.zeros(B.shape[-1], jnp.int32), brk0, nonf0, hist0, tl0)
    (x, r, z, p, rz, rnorm, iters, k,
     (best_x, best_rnorm, best_iter), stall, brk, nonf, hist, tl_out) = \
        jax.lax.while_loop(cond, body, state)
    converged = rnorm <= rtol * bnorm
    # a non-converged column reports its minimum-residual iterate
    x_out = jnp.where(converged, x, best_x)
    rnorm_out = jnp.where(converged, rnorm, best_rnorm)
    stag = ~converged & ~brk & ~nonf & (stall >= stall_window)
    health = SolveHealth(
        status=status_of(converged, brk, nonf, stag),
        breakdown=brk, nonfinite=nonf, stagnation=stag,
        best_iter=best_iter.astype(jnp.int32),
        best_relres=best_rnorm / bnorm)
    res = BlockCGResult(x=x_out, iters=iters, relres=rnorm_out / bnorm,
                        converged=converged, health=health,
                        counters=tl_out if counted else None)
    return (res, hist) if record_history else res


def make_block_solve(setupd, rtol: float = 1e-8, maxiter: int = 200,
                     record_history: bool = False, obs=None):
    """Jitted hot panel solve: ``(Hierarchy, B: (n, k)) -> BlockCGResult``
    (``(result, history)`` under ``record_history=True``).

    The multi-RHS twin of ``repro.core.gamg.make_solve`` — same smoother
    configuration, same hierarchy pytree, SpMM everywhere.  jax.jit traces
    once per distinct k; the solve server buckets request streams to a
    static k set precisely so this cache stays small.

    ``solve(hier, B, x0)`` warm-starts every column from a prior
    ``(n, k)`` iterate panel (the time-march knob — see
    ``core.krylov.pcg``); the two-argument cold form stays bitwise the
    pre-warm-start closure with its own single cache entry.

    The observability mode (``obs=`` > ``use`` scope > ``REPRO_OBS``) is
    resolved *here*, at closure-build time — matching the knob's
    trace-time contract.  Under ``"counters"`` the panel threads a
    ``CycleTally`` through the V-cycle and the result's ``counters``
    carries the totals plus the modeled HBM bytes
    (``repro.obs.model.vcycle_traffic`` x preconditioner applications).
    """
    smoother, degree = setupd.smoother, setupd.degree
    precond_dtype = setupd.precision.smoother_dtype
    counted = obs_trace.counters_enabled(obs)
    if counted:
        from repro.obs.model import vcycle_traffic
        itemsize = jnp.dtype(setupd.precision.hierarchy_dtype).itemsize
        cycle_bytes = float(
            vcycle_traffic(setupd, itemsize=itemsize)["total"])
        n_levels = setupd.n_levels

    @partial(jax.jit, static_argnames=())
    def solve(hier: Hierarchy, B: Array, x0: "Array | None" = None):
        def apply_a(X):
            return apply_ell(fine_operator(hier), X)

        if counted:
            def apply_m(R, tl):
                return vcycle(hier, R, smoother=smoother, degree=degree,
                              tally=tl)
            tally = obs_trace.zero_tally(n_levels)
        else:
            def apply_m(R):
                return vcycle(hier, R, smoother=smoother, degree=degree)
            tally = None

        out = block_pcg(apply_a, apply_m, B, x0=x0, rtol=rtol,
                        maxiter=maxiter, precond_dtype=precond_dtype,
                        record_history=record_history, tally=tally)
        if counted:
            res, hist = out if record_history else (out, None)
            res = res._replace(counters=obs_trace.attach_model_bytes(
                res.counters, cycle_bytes))
            return (res, hist) if record_history else res
        return out

    return solve
