"""Multi-RHS blocked solve subsystem.

The paper's hierarchy-reuse model (setup once, solve many times) pairs
naturally with *panel* solves: k right-hand sides against one cached
hierarchy amortize the operator's value+index HBM traffic over k columns
— the same arithmetic-intensity lever the blocked storage pulls per
block, applied along the RHS axis.

* ``block_krylov`` — batched PCG with per-column convergence masking, and
  the jitted panel-solve builder over a ``GAMGSetup``.
* ``server``       — a solve server that buckets/pads request streams to a
  small set of static panel widths (no retracing), runs batched solves on
  the cached hierarchy, and reports per-request iterations/residuals.
"""
from repro.multirhs.block_krylov import (  # noqa: F401
    BlockCGResult,
    block_pcg,
    make_block_solve,
)
from repro.multirhs.server import AMGSolveServer, SolveReport  # noqa: F401
