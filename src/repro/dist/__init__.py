"""Distributed (multi-device) AMG path — PETSc-style row-slab decomposition.

``partition``   process meshes and balanced contiguous block-row slabs:
                ``ProcessMesh`` structures the device set (1-D row slabs,
                or 2-D ``(pr, pc)`` meshes whose column axis splits each
                slab's halo-facing work), ``RowPartition`` the rank
                layout.
``pamg``        distributed blocked operators: slab halo exchange
                (neighbor ``ppermute`` windows, blocking or split into
                start/finish around the interior work), distributed ELL
                SpMV with a build-time interior/boundary row split, and
                the distributed PtAP stages with the off-process
                prolongator operand (P_oth) cached device-side.
``solver``      ``build_dist_gamg`` / ``make_dist_solver`` — the full
                device-resident hot path (numeric hierarchy recompute +
                AMG-preconditioned CG) as one ``shard_map`` program, with
                per-level placement: fine levels slab-sharded, coarse
                levels agglomerated into a replicated rank-redundant tail
                below the ``coarse_eq_limit`` equations-per-device
                threshold (PETSc GAMG process reduction).  The
                ``REPRO_OVERLAP`` knob picks the halo schedule
                (overlapped split apply by default, bitwise-identical
                blocking rendering with ``off``).
``measure``     traced collective counts of the V-cycle (the
                model-vs-measured column of the weak-scaling table).
``selftest``    subprocess entry point asserting distributed == single
                device parity (``python -m repro.dist.selftest <m>``).
"""
