"""Distributed (multi-device) AMG path — PETSc-style row-slab decomposition.

``partition``   balanced contiguous block-row slabs (the rank layout).
``pamg``        distributed blocked operators: slab halo exchange
                (neighbor ``ppermute`` windows), distributed ELL SpMV, and
                the distributed PtAP stages with the off-process
                prolongator operand (P_oth) cached device-side.
``solver``      ``build_dist_gamg`` / ``make_dist_solver`` — the full
                device-resident hot path (numeric hierarchy recompute +
                AMG-preconditioned CG) as one ``shard_map`` program, with
                per-level placement: fine levels slab-sharded, coarse
                levels agglomerated into a replicated rank-redundant tail
                below the ``coarse_eq_limit`` equations-per-rank threshold
                (PETSc GAMG process reduction).
``selftest``    subprocess entry point asserting distributed == single
                device parity (``python -m repro.dist.selftest <m>``).
"""
