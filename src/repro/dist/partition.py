"""Balanced contiguous block-row partition (PETSc ``PetscSplitOwnership``).

Every distributed object in ``repro.dist`` is laid out in row slabs: rank r
owns block rows ``[starts[r], starts[r+1])``.  Slabs differ by at most one
row, and ownership lookup is a ``searchsorted`` — the same layout PETSc uses
for Mat/Vec, which is what makes halo exchange a *neighbor* pattern on
mesh-ordered problems.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous row slabs over ``ndev`` ranks."""

    starts: np.ndarray        # (ndev + 1,) int64, starts[0] == 0

    @property
    def ndev(self) -> int:
        return len(self.starts) - 1

    @property
    def nrows(self) -> int:
        return int(self.starts[-1])

    @property
    def counts(self) -> np.ndarray:
        return np.diff(self.starts)

    @property
    def max_count(self) -> int:
        return int(self.counts.max()) if self.ndev else 0

    def owner_of(self, rows) -> np.ndarray:
        """Owning rank of each (global) row index."""
        rows = np.asarray(rows)
        return (np.searchsorted(self.starts, rows, side="right") - 1
                ).astype(np.int64)

    def local_of(self, rows) -> np.ndarray:
        """Slab-local offset of each (global) row index."""
        rows = np.asarray(rows, dtype=np.int64)
        return rows - self.starts[self.owner_of(rows)]

    def slab(self, rank: int) -> slice:
        return slice(int(self.starts[rank]), int(self.starts[rank + 1]))


def partition_rows(nrows: int, ndev: int) -> RowPartition:
    """Balanced contiguous partition: first ``nrows % ndev`` slabs get the
    extra row (max - min <= 1)."""
    assert nrows >= 0 and ndev >= 1
    base, rem = divmod(nrows, ndev)
    counts = np.full(ndev, base, dtype=np.int64)
    counts[:rem] += 1
    starts = np.zeros(ndev + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    return RowPartition(starts=starts)
