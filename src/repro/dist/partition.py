"""Process meshes and balanced contiguous block-row partitions.

Every distributed object in ``repro.dist`` is laid out in row slabs: rank r
owns block rows ``[starts[r], starts[r+1])``.  Slabs differ by at most one
row, and ownership lookup is a ``searchsorted`` — the same layout PETSc uses
for Mat/Vec, which is what makes halo exchange a *neighbor* pattern on
mesh-ordered problems (``RowPartition`` / ``partition_rows``).

``ProcessMesh`` structures the device set itself.  A 1-D ``(ndev,)`` mesh
is the legacy row-slab layout: every rank owns one slab and runs the whole
apply on it.  A 2-D ``(pr, pc)`` mesh partitions **block rows × halo
neighbors**: the first axis splits the rows into ``pr`` slabs (the same
``RowPartition`` contract), the second subdivides each slab's *halo-facing
work* — the ``pc`` ranks of one row group share the slab and split its
boundary-row traffic, which divides the per-rank halo bytes by ``pc``
(``repro.obs.model.dist_cycle_comm`` charges it that way).  The executable
``shard_map`` path consumes the row axis; the column axis is the scaling
lever for the paper's 27–64 GPU points where a pure 1-D slab of a 3-D
stencil has no interior left.

Validation here raises ``ValueError`` (never ``assert`` — the checks must
survive ``python -O``), mirroring the ``block_coo`` hardening.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous row slabs over ``ndev`` ranks."""

    starts: np.ndarray        # (ndev + 1,) int64, starts[0] == 0

    @property
    def ndev(self) -> int:
        return len(self.starts) - 1

    @property
    def nrows(self) -> int:
        return int(self.starts[-1])

    @property
    def counts(self) -> np.ndarray:
        return np.diff(self.starts)

    @property
    def max_count(self) -> int:
        return int(self.counts.max()) if self.ndev else 0

    def owner_of(self, rows) -> np.ndarray:
        """Owning rank of each (global) row index."""
        rows = np.asarray(rows)
        return (np.searchsorted(self.starts, rows, side="right") - 1
                ).astype(np.int64)

    def local_of(self, rows) -> np.ndarray:
        """Slab-local offset of each (global) row index."""
        rows = np.asarray(rows, dtype=np.int64)
        return rows - self.starts[self.owner_of(rows)]

    def slab(self, rank: int) -> slice:
        return slice(int(self.starts[rank]), int(self.starts[rank + 1]))


def partition_rows(nrows: int, ndev: int) -> RowPartition:
    """Balanced contiguous partition: first ``nrows % ndev`` slabs get the
    extra row (max - min <= 1).

    Raises ``ValueError`` (not assert — must survive ``python -O``) on a
    non-positive rank count or a negative row count.
    """
    nrows, ndev = int(nrows), int(ndev)
    if ndev < 1:
        raise ValueError(f"partition needs at least one rank, got "
                         f"ndev={ndev}")
    if nrows < 0:
        raise ValueError(f"cannot partition a negative row count "
                         f"(nrows={nrows})")
    base, rem = divmod(nrows, ndev)
    counts = np.full(ndev, base, dtype=np.int64)
    counts[:rem] += 1
    starts = np.zeros(ndev + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    return RowPartition(starts=starts)


def partition_padded(nrows_padded: int, ndev: int) -> RowPartition:
    """Equal slabs of an already-padded row count (stacked ``(ndev, rpad)``
    slabs flattened to ``ndev * rpad`` rows).

    The padded count must divide evenly — a remainder means the stacked
    slabs and the claimed rank count disagree, which would silently
    misattribute rows to ranks; raise instead.
    """
    nrows_padded, ndev = int(nrows_padded), int(ndev)
    if ndev < 1:
        raise ValueError(f"partition needs at least one rank, got "
                         f"ndev={ndev}")
    if nrows_padded < 0:
        raise ValueError(f"cannot partition a negative row count "
                         f"(nrows_padded={nrows_padded})")
    if nrows_padded % ndev != 0:
        raise ValueError(
            f"padded row count {nrows_padded} does not divide over "
            f"{ndev} ranks (remainder {nrows_padded % ndev}): stacked "
            f"slabs must be uniform")
    return partition_rows(nrows_padded, ndev)


@dataclasses.dataclass(frozen=True)
class ProcessMesh:
    """The device set as a (row, halo) mesh.

    ``shape == (ndev,)`` is the legacy 1-D slab layout (``pc == 1``);
    ``shape == (pr, pc)`` keeps ``pr`` row slabs and splits each slab's
    halo-facing work ``pc`` ways (module docstring).  Construction
    validates eagerly with ``ValueError`` so a bogus mesh never reaches
    the staging loops.
    """

    shape: Tuple[int, ...]

    def __post_init__(self):
        try:
            shape = tuple(int(s) for s in self.shape)
        except TypeError:
            raise ValueError(
                f"mesh shape must be a tuple of ints, got {self.shape!r}")
        if len(shape) not in (1, 2):
            raise ValueError(
                f"mesh shape must be (ndev,) or (pr, pc), got {shape!r}")
        if any(s < 1 for s in shape):
            raise ValueError(
                f"mesh axes must be positive (ndev < 1 is meaningless), "
                f"got {shape!r}")
        object.__setattr__(self, "shape", shape)

    @property
    def pr(self) -> int:
        """Row-slab ranks (the executable shard axis)."""
        return self.shape[0]

    @property
    def pc(self) -> int:
        """Halo-neighbor ranks per row group (1 on a 1-D mesh)."""
        return self.shape[1] if len(self.shape) == 2 else 1

    @property
    def ndev(self) -> int:
        return self.pr * self.pc

    def row_partition(self, nbr: int) -> RowPartition:
        """Slab partition of ``nbr`` block rows over the row axis.

        A mesh with more row ranks than block rows would stage empty
        slabs whose halo plans are degenerate; refuse it loudly.
        """
        nbr = int(nbr)
        if nbr > 0 and self.pr > nbr:
            raise ValueError(
                f"mesh row axis ({self.pr} ranks) larger than the "
                f"block-row count ({nbr}): every rank needs at least one "
                f"row slab")
        return partition_rows(nbr, self.pr)


def as_mesh(mesh_or_ndev) -> ProcessMesh:
    """Coerce the dist front doors' ``ndev``-or-mesh argument.

    An ``int`` is the legacy 1-D call convention (``build_dist_gamg(setupd,
    4)``); a ``ProcessMesh`` passes through.  Anything else is a loud
    error.
    """
    if isinstance(mesh_or_ndev, ProcessMesh):
        return mesh_or_ndev
    if isinstance(mesh_or_ndev, (int, np.integer)):
        return ProcessMesh((int(mesh_or_ndev),))
    raise ValueError(
        f"expected an int rank count or a ProcessMesh, got "
        f"{mesh_or_ndev!r}")
