"""Distributed GAMG: device-resident hot recompute + solve over row slabs.

``build_dist_gamg(setupd, ndev)`` is the cold, host-side staging pass: it
takes the single-device ``GAMGSetup`` (global structure + plans) and remaps
every plan into per-rank slabs — the distributed analogue of the paper's
prolongator-side cache, including the pre-gathered off-process P rows
(P_oth).  ``make_dist_solver`` wraps the hot path in one jitted
``shard_map`` program over a 1-D ``"rank"`` mesh:

    recompute   chained distributed PtAP (stage 1 entirely local thanks to
                the cached P_oth operand; stage 2's off-process reduction is
                a neighbor ppermute window over the A·P payload slabs),
                smoother data (pbjacobi inverses, distributed power
                iteration for the Chebyshev bound), coarse Cholesky
                (replicated — the coarsest level is tiny by construction).
    solve       AMG-preconditioned CG with ``psum`` reductions and halo
                windows for every level SpMV.

Level placement (the coarse-grid agglomeration of PETSc GAMG's process
reduction): coarse levels hold a few thousand rows per rank, where halo
*latency* — not bandwidth — dominates, so sharding them across all ranks
is a net loss.  ``build_dist_gamg`` therefore assigns every level a
placement: levels above the ``coarse_eq_limit`` equations-per-rank
threshold stay slab-sharded as before; levels at or below it are
**agglomerated** — their operator payloads, P/R transfers and smoother
data are reassembled once per recompute into a *replicated* global
representation (``DistReplicatedLevel``) and the V-cycle runs them
rank-redundantly with zero ppermute traffic.  The sharded->replicated
boundary (``DistSwitch``) costs exactly one ``all_gather`` per V-cycle
(the restriction of the fine residual) and one per recompute (the
Galerkin payload of the first replicated operator); the prolongation
re-slices the replicated correction back into row slabs with a
zero-communication ``"replicated"``-halo operator.  The replicated tail
runs the *single-device* core functions (``gamg.level_state``,
``ptap_numeric_data``, ``vcycle``'s smoothers, dense ``cho_solve``)
verbatim, so agglomerated-vs-single-device f64 parity is exact by
construction — and therefore so is sharded-vs-agglomerated iteration
parity, which ``repro.dist.selftest`` asserts.

Halo schedule: every sharded operator apply routes through ``_rank_spmv``,
which renders the exchange either *blocking* (assemble the window, then
apply — bitwise the historical path, ``REPRO_OVERLAP=off``) or
*overlapped* (``REPRO_OVERLAP=on``, the default): start the ppermutes, run
the build-time **interior** rows against the rank's own slab while they
fly, finish the window, run the **boundary** rows, scatter the disjoint
partials back into slab order.  Per-row summation order is identical, so
the two schedules produce bitwise-equal iterates — which the selftest's
``REPRO_SELFTEST_OVERLAP=1`` section pins.  The knob is resolved at trace
time (``repro.kernels.backend.resolve_overlap``); the stage-2 PtAP
reduction overlaps the same way at pair granularity
(``dist_stage_apply_overlap``).

Parity with the single-device path is exact in structure (same contribution
order per row, same plans) and floating-point-tight in value (the only
reassociations are the ``psum`` dot products), which is what
``repro.dist.selftest`` asserts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.core.block_csr import BlockCSR, BlockELL, EllTransposePlan
from repro.core.gamg import GAMGSetup, LevelSetup, coarse_cholesky, \
    jittered_cholesky, level_state, restriction_bcsr
from repro.core.krylov import wrap_precond
from repro.core.precision import PrecisionPolicy
from repro.core.ptap import ptap_numeric_data
from repro.core.spmv import apply_ell, apply_ell_t
from repro.core.vcycle import (
    LevelState,
    apply_restriction,
    apply_smoother,
    chebyshev_recurrence,
    pbjacobi_recurrence,
)
from repro.dist.pamg import (
    AXIS,
    DistEll,
    DistPairStage,
    build_diag_sel,
    build_dist_ell,
    build_payload_gather,
    build_row_gather,
    build_stage1,
    build_stage2,
    combine_split,
    dist_ell_apply,
    dist_ell_apply_boundary,
    dist_ell_apply_interior,
    dist_stage_apply,
    dist_stage_apply_overlap,
    finish_halo_exchange,
    halo_window,
    start_halo_exchange,
)
from repro.dist.partition import ProcessMesh, RowPartition, as_mesh, \
    partition_rows
from repro.kernels.backend import resolve_overlap
from repro.multirhs.block_krylov import block_pcg
from repro.obs import trace as obs_trace
from repro.robust import inject
from repro.robust.health import status_of

#: Default agglomeration threshold, in equations per rank (the PETSc
#: ``-pc_gamg_process_eq_limit`` default): a level whose global equation
#: count divided by ``ndev`` is at or below this leaves the fully-sharded
#: path.  ``coarse_eq_limit=0`` disables agglomeration entirely.
DEFAULT_COARSE_EQ_LIMIT = 50

Array = jax.Array
P = PartitionSpec


# ---------------------------------------------------------------------------
# Cold build
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistLevel:
    """Per-level rank-sharded plans (host numpy, stacked (ndev, ...)).

    ``p_op``/``r_op`` are ``None`` on the last sharded level when a
    replicated tail follows — the transfers across the placement boundary
    live in ``DistSwitch`` instead.
    """

    a_op: DistEll
    p_op: Optional[DistEll]
    r_op: Optional[DistEll]
    stage1: DistPairStage
    stage2: DistPairStage
    diag_sel: np.ndarray
    diag_mask: np.ndarray
    row_mask: np.ndarray          # (ndev, rpad) valid fine rows
    a_nnz_starts: np.ndarray      # (ndev + 1,) A payload slab offsets
    a_pad: int                    # fine payload slab length (max nnz + 1)
    bs: int
    rpad: int                     # fine row slab pad
    n_fine: int


@dataclasses.dataclass
class DistCoarse:
    """Replicated coarsest-level solve data (the level is tiny).

    Only staged when *no* AMG level is agglomerated — with a replicated
    tail the coarsest payload is already global and the Cholesky needs no
    gather of its own.
    """

    part: RowPartition
    sel: np.ndarray               # (nnzb,) window ids into gathered payload
    rows: np.ndarray              # (nnzb,) global block coords
    cols: np.ndarray
    row_sel: np.ndarray           # (nbr,) window ids into gathered vectors
    nbr: int
    bs: int
    rpad: int
    ac_pad: int


@dataclasses.dataclass
class DistReplicatedLevel:
    """One agglomerated level: the rank-redundant global representation.

    The staging is deliberately thin — the level IS the single-device
    level.  ``ls`` carries the global plans (A-ELL, PtAP cache, P/R
    payloads) that ``gamg.level_state`` / ``ptap_numeric_data`` consume;
    the hot path closes over them as replicated constants, so the V-cycle
    on this level does zero communication.
    """

    ls: LevelSetup
    n_eqs: int                    # global equations (the placement metric)


@dataclasses.dataclass
class DistSwitch:
    """Gather-boundary staging where placement flips sharded->replicated.

    ``payload_sel``/``row_sel`` are the gather-boundary plans
    (``repro.dist.pamg.build_payload_gather`` / ``build_row_gather``):
    window ids into one ``all_gather`` of the last sharded level's padded
    slabs that reassemble the global Galerkin payload (recompute) and the
    global fine residual (restriction).  The boundary restriction is
    applied rank-redundantly after that gather — through the stored global
    ``r_ell`` when the setup carries one, else transpose-free off the
    global prolongator payload (``p_g`` + the ``p_t`` plan, the default).
    ``p_b`` is the boundary prolongator — sharded fine rows whose plan
    indices address the *replicated* coarse correction directly
    (``"replicated"`` halo, zero traffic).
    """

    payload_sel: np.ndarray       # (nnzb,) into gathered stage2 payload slabs
    row_sel: np.ndarray           # (nbr_fine,) into gathered residual slabs
    r_ell: Optional[BlockELL]     # stored global restriction, or None
    p_b: DistEll                  # slab rows <- replicated coarse vector
    nbr_c: int                    # replicated coarse vector block rows
    bs_c: int
    p_g: Optional[BlockELL] = None          # global prolongator payload
    p_t: Optional[EllTransposePlan] = None  # transpose-free P^T plan


@dataclasses.dataclass
class DistGAMG:
    """Cold distributed staging — valid while the setup's structures hold.

    ``precision`` mirrors the setup's ``PrecisionPolicy``: the staged
    constant payloads (P/R blocks, the cached P_oth operand) are baked at
    ``hierarchy_dtype``, the rank-local recompute/V-cycle runs at that
    dtype (halving the halo/ppermute payload for f32), and the outer
    distributed PCG stays at ``krylov_dtype`` with the boundary cast.

    Placement: ``levels`` holds only the slab-sharded levels; ``repl``
    the agglomerated (replicated) tail, ``switch`` the gather boundary
    between them (``None`` when nothing is agglomerated, in which case
    ``coarse`` carries the legacy replicated-Cholesky staging).  Level 0
    always stays sharded — the scatter/gather front doors and the outer
    Krylov iteration are slab contracts.
    """

    ndev: int
    parts: List[RowPartition]     # per level, + the coarsest
    levels: List[DistLevel]       # the slab-sharded prefix
    coarse: Optional[DistCoarse]  # legacy coarsest staging (no repl tail)
    smoother: str
    degree: int
    precision: PrecisionPolicy = dataclasses.field(
        default_factory=PrecisionPolicy.double)
    repl: List[DistReplicatedLevel] = dataclasses.field(default_factory=list)
    switch: Optional[DistSwitch] = None
    coarse_struct: Optional[BlockCSR] = None   # coarsest structure (repl tail)
    coarse_eq_limit: int = 0
    #: The device set as a ``ProcessMesh``.  The executable shard_map path
    #: consumes the row axis (``mesh.pr == ndev`` slabs); a 2-D mesh's
    #: column axis splits each slab's halo traffic ``pc`` ways, which
    #: ``repro.obs.model.dist_cycle_comm`` accounts.
    mesh: Optional[ProcessMesh] = None

    @property
    def n_levels(self) -> int:
        """AMG levels (sharded + replicated), excluding the coarsest."""
        return len(self.levels) + len(self.repl)

    @property
    def placement(self) -> List[str]:
        """Per-level placement tags (+ the coarsest, always replicated)."""
        return (["sharded"] * len(self.levels)
                + ["replicated"] * len(self.repl) + ["replicated"])

    # ---- args bundle (the sharded operands of the hot program) ----------
    def sharded_args(self, setupd: Optional[GAMGSetup] = None):
        del setupd  # staged at build time; kept for the call-site shape
        def split_args(pre: str, op: DistEll):
            """The interior/boundary split plan of one sharded DistEll."""
            return {pre + "loc": jnp.asarray(op.indices_local),
                    pre + "msk": jnp.asarray(op.int_mask)}

        lv_args = []
        for lv in self.levels:
            if lv.p_op is not None:
                transfers = dict(
                    p_idx=jnp.asarray(lv.p_op.indices),
                    p_data=jnp.asarray(lv.p_op.data),
                    r_idx=jnp.asarray(lv.r_op.indices),
                    r_data=jnp.asarray(lv.r_op.data),
                    **split_args("p_", lv.p_op),
                    **split_args("r_", lv.r_op))
            else:   # switch boundary: the re-slicing prolongator's slabs
                # (replicated halo — zero traffic, no split plan needed)
                transfers = dict(
                    pb_idx=jnp.asarray(self.switch.p_b.indices),
                    pb_data=jnp.asarray(self.switch.p_b.data))
            lv_args.append(dict(
                transfers,
                a_idx=jnp.asarray(lv.a_op.indices),
                a_gather=jnp.asarray(lv.a_op.gather),
                **split_args("a_", lv.a_op),
                s1_lhs=jnp.asarray(lv.stage1.lhs_gather),
                s1_rhs=jnp.asarray(lv.stage1.rhs_data),
                s1_seg=jnp.asarray(lv.stage1.seg),
                s2_lhs=jnp.asarray(lv.stage2.lhs_data),
                s2_rhs=jnp.asarray(lv.stage2.rhs_gather),
                s2_rhs_loc=jnp.asarray(lv.stage2.rhs_local),
                s2_msk=jnp.asarray(lv.stage2.local_mask),
                s2_seg=jnp.asarray(lv.stage2.seg),
                diag_sel=jnp.asarray(lv.diag_sel),
                diag_mask=jnp.asarray(lv.diag_mask),
                row_mask=jnp.asarray(lv.row_mask),
            ))
        return {"levels": lv_args}

    # ---- host-side scatter/gather (edges of the device-resident region) -
    @property
    def payload_stage_dtype(self) -> np.dtype:
        """Staging dtype of the fine payload slabs: wide enough for both
        the hierarchy chain (cast down once at the top of the rank
        recompute) and the mixed-policy krylov-dtype operator copy
        (``a_data_kr``).  Staging at the *policy's* dtype rather than the
        caller's means an fp64 operator update into an fp32-resident
        hierarchy neither retraces the jitted hot program nor poisons the
        staged dtype."""
        return np.dtype(jnp.promote_types(self.precision.hierarchy_dtype,
                                          self.precision.krylov_dtype))

    def scatter_fine_payloads(self, data: Array) -> Array:
        """Global (nnzb, bs, bs) fine values -> (ndev, a_pad, bs, bs).

        Slabs are allocated at ``payload_stage_dtype`` (policy-derived,
        never the caller's dtype) so repeat updates at varying caller
        dtypes hit the same compiled program.
        """
        data = np.asarray(data)
        lv = self.levels[0]
        out = np.zeros((self.ndev, lv.a_pad) + data.shape[1:],
                       self.payload_stage_dtype)
        for r in range(self.ndev):
            s, e = int(lv.a_nnz_starts[r]), int(lv.a_nnz_starts[r + 1])
            out[r, :e - s] = data[s:e]
        return jnp.asarray(out)

    def scatter_vector(self, b: Array) -> Array:
        """Global fine vector (n,) or panel (n, k) -> (ndev, rpad, bs[, k])
        padded slabs, staged at the policy's ``krylov_dtype`` (the dtype
        the outer distributed PCG runs at — never the caller's)."""
        lv, part = self.levels[0], self.parts[0]
        b = np.asarray(b)
        trailing = b.shape[1:]
        b2 = b.reshape((part.nrows, lv.bs) + trailing)
        out = np.zeros((self.ndev, lv.rpad, lv.bs) + trailing,
                       np.dtype(self.precision.krylov_dtype))
        for r in range(self.ndev):
            sl = part.slab(r)
            out[r, :sl.stop - sl.start] = b2[sl]
        return jnp.asarray(out)

    def gather_vector(self, x: Array) -> np.ndarray:
        """(ndev, rpad, bs[, k]) padded slabs -> global (n,) or (n, k)."""
        part = self.parts[0]
        xs = np.asarray(x)
        chunks = [xs[r, :part.counts[r]] for r in range(self.ndev)]
        cat = np.concatenate(chunks, axis=0)
        return cat.reshape((-1,) + xs.shape[3:])


@dataclasses.dataclass
class DistAssembly:
    """Per-rank device-assembly staging: the distributed rendering of the
    cached ``BlockCOOPlan``.

    ``plan.out_idx_sorted`` is monotone, so the contributions feeding rank
    ``r``'s fine payload slab (global output blocks
    ``a_nnz_starts[r]:a_nnz_starts[r+1]``) are one *contiguous* range of
    the globally sorted contribution stream — each rank owns a slice of
    the same scatter-sum the single-device ``set_values_coo`` runs, in the
    same order, which is what makes assembled-slab parity exact.

    A contribution is (element, node-pair); elements touching a slab
    boundary appear on both ranks, so each rank stages the ids of the
    elements it needs (``elem_ids``, padded) and recomputes their
    stiffness blocks rank-locally — the scatter front door
    (``scatter_fields``) then moves only two small per-element coefficient
    slabs, never a value stream.
    """

    elem_ids: np.ndarray      # (ndev, epad) global element ids (pad -> 0)
    contrib_elem: np.ndarray  # (ndev, cpad) rank-local element index
    contrib_pa: np.ndarray    # (ndev, cpad) row-node within element
    contrib_pb: np.ndarray    # (ndev, cpad) col-node within element
    contrib_seg: np.ndarray   # (ndev, cpad) local slot in the payload slab
    contrib_mask: np.ndarray  # (ndev, cpad) valid contributions
    quad_b: np.ndarray        # shared quadrature arrays (replicated consts)
    quad_w: np.ndarray
    nn: int                   # nodes per element
    bs: int
    a_pad: int                # fine payload slab length (dg.levels[0])
    n_elements: int
    stage_dtype: np.dtype     # dg.payload_stage_dtype (policy's, not caller's)

    @property
    def ndev(self) -> int:
        return self.elem_ids.shape[0]

    def sharded_args(self):
        """The (ndev, ...) stacked operands of the rank assembly."""
        return dict(elem=jnp.asarray(self.contrib_elem),
                    pa=jnp.asarray(self.contrib_pa),
                    pb=jnp.asarray(self.contrib_pb),
                    seg=jnp.asarray(self.contrib_seg),
                    mask=jnp.asarray(self.contrib_mask))

    def scatter_fields(self, E, nu):
        """Global per-element fields (or scalars) -> (ndev, epad) slabs.

        Staged at the policy-derived payload dtype (mirroring
        ``DistGAMG.scatter_fine_payloads``): repeat updates at varying
        caller dtypes hit the same compiled program.
        """
        ne = self.n_elements
        E = np.broadcast_to(np.asarray(E, self.stage_dtype), (ne,))
        nu = np.broadcast_to(np.asarray(nu, self.stage_dtype), (ne,))
        return (jnp.asarray(E[self.elem_ids]),
                jnp.asarray(nu[self.elem_ids]))


def build_dist_assembly(dg: DistGAMG, assembler) -> DistAssembly:
    """Cold staging of device FEM assembly over the fine payload slabs.

    ``assembler`` is the problem's ``repro.fem.device_stiffness
    .DeviceAssembler`` (its ``BlockCOOPlan`` must be the one the fine
    operator of ``dg``'s setup was assembled with).
    """
    plan = assembler.plan
    lv0 = dg.levels[0]
    nn = assembler.nn
    if int(lv0.a_nnz_starts[-1]) != plan.nnzb:
        raise ValueError(
            f"assembler plan does not match the staged fine operator: "
            f"plan has {plan.nnzb} output blocks, the fine level has "
            f"{int(lv0.a_nnz_starts[-1])}")
    sorted_input = plan.keep[plan.order]          # declared-coordinate ids
    elem = sorted_input // (nn * nn)
    pair = sorted_input % (nn * nn)
    seg = plan.out_idx_sorted                     # monotone output blocks
    starts = lv0.a_nnz_starts
    los = np.searchsorted(seg, starts[:-1], side="left")
    his = np.searchsorted(seg, starts[1:], side="left")
    per_elem, per_loc, per_uniq = [], [], []
    for r in range(dg.ndev):
        er = elem[los[r]:his[r]]
        uniq, local = np.unique(er, return_inverse=True)
        per_uniq.append(uniq)
        per_elem.append(er)
        per_loc.append(local)
    epad = max(1, max(len(u) for u in per_uniq))
    cpad = max(1, int((his - los).max()))
    ndev = dg.ndev
    elem_ids = np.zeros((ndev, epad), dtype=np.int64)
    c_elem = np.zeros((ndev, cpad), dtype=np.int32)
    c_pa = np.zeros((ndev, cpad), dtype=np.int32)
    c_pb = np.zeros((ndev, cpad), dtype=np.int32)
    # padded contributions land in the (always unused) last slab slot:
    # slab lengths are at most a_pad - 1 by construction
    c_seg = np.full((ndev, cpad), lv0.a_pad - 1, dtype=np.int32)
    c_mask = np.zeros((ndev, cpad), dtype=bool)
    for r in range(ndev):
        lo, hi = los[r], his[r]
        k = hi - lo
        elem_ids[r, :len(per_uniq[r])] = per_uniq[r]
        c_elem[r, :k] = per_loc[r]
        c_pa[r, :k] = pair[lo:hi] // nn
        c_pb[r, :k] = pair[lo:hi] % nn
        c_seg[r, :k] = seg[lo:hi] - starts[r]
        c_mask[r, :k] = True
    return DistAssembly(elem_ids=elem_ids, contrib_elem=c_elem,
                        contrib_pa=c_pa, contrib_pb=c_pb, contrib_seg=c_seg,
                        contrib_mask=c_mask,
                        quad_b=np.asarray(assembler.quad_b),
                        quad_w=np.asarray(assembler.quad_w),
                        nn=nn, bs=plan.br, a_pad=lv0.a_pad,
                        n_elements=assembler.n_elements,
                        stage_dtype=dg.payload_stage_dtype)


def _placement_split(setupd: GAMGSetup, ndev: int, limit: int) -> int:
    """First level index that leaves the fully-sharded path.

    A level is agglomerated when its global equation count per rank is at
    or below ``limit`` (PETSc's ``-pc_gamg_process_eq_limit`` rule).
    Level 0
    never qualifies — the fine level is the scatter/gather and outer-Krylov
    slab contract.  Level sizes shrink monotonically, so the split is a
    single index: ``levels[:split]`` sharded, ``levels[split:]`` replicated.
    """
    n = len(setupd.levels)
    if limit <= 0:
        return n
    for li in range(1, n):
        ls = setupd.levels[li]
        if ls.n_fine * ls.A0.br <= limit * ndev:
            return li
    return n


def build_dist_gamg(setupd: GAMGSetup, ndev, *,
                    coarse_eq_limit: Optional[int] = None) -> DistGAMG:
    """Cold distributed staging of a single-device GAMG setup.

    ``ndev`` is an int rank count (the legacy 1-D slab convention) or a
    ``ProcessMesh``: the executable slabs follow the mesh's *row* axis
    (``mesh.pr``), while a 2-D mesh's column axis is recorded for the
    communication model (each row group's ``pc`` ranks split its halo
    traffic — ``repro.obs.model.dist_cycle_comm``).

    Constant payloads (P, R, the cached P_oth) are staged at the policy's
    ``hierarchy_dtype`` — the distributed rendering of "the hierarchy is
    stored at hierarchy_dtype".

    ``coarse_eq_limit`` is the placement threshold in equations per rank:
    levels at or below it are agglomerated into the replicated tail (see module
    docstring).  ``None`` defers to ``setupd.coarse_eq_limit`` and then to
    ``DEFAULT_COARSE_EQ_LIMIT``; ``0`` keeps every level slab-sharded (the
    pre-placement behaviour).
    """
    assert setupd.levels, "distributed path needs at least one AMG level"
    mesh = as_mesh(ndev)
    mesh.row_partition(setupd.levels[0].A0.nbr)   # validate rows >= pr
    ndev = mesh.pr
    if coarse_eq_limit is None:
        coarse_eq_limit = setupd.coarse_eq_limit
    if coarse_eq_limit is None:
        coarse_eq_limit = DEFAULT_COARSE_EQ_LIMIT
    # the eq-per-rank placement rule counts every device of the mesh
    # (pr * pc), not just the row axis the slabs follow — a 2-D mesh
    # agglomerates exactly like the equally-sized 1-D one would
    n_sharded = _placement_split(setupd, mesh.ndev, coarse_eq_limit)
    h_np = setupd.precision.hierarchy_dtype
    parts = [partition_rows(ls.n_fine, ndev) for ls in setupd.levels]
    parts.append(partition_rows(setupd.coarse_struct.nbr, ndev))
    levels: List[DistLevel] = []
    for li, ls in enumerate(setupd.levels[:n_sharded]):
        fine, coarse = parts[li], parts[li + 1]
        boundary = li == n_sharded - 1 and n_sharded < len(setupd.levels)
        A0 = ls.A0
        a_nnz_starts = A0.indptr[fine.starts]
        a_pad = int(np.diff(a_nnz_starts).max()) + 1
        p_np = np.asarray(ls.P.data).astype(h_np)
        cache = ls.ptap_cache
        s1 = build_stage1(cache.ap_plan, fine, A0.indptr, p_np)
        s2 = build_stage2(cache.ac_plan, coarse, fine, cache.ap_plan.indptr,
                          s1.out_pad, p_np, cache.r_perm)
        diag_sel, diag_mask = build_diag_sel(A0.indptr, A0.indices, fine,
                                             a_pad)
        rpad = max(fine.max_count, 1)
        row_mask = (np.arange(rpad)[None, :]
                    < fine.counts[:, None])
        # the slab-sharded restriction slices a stored-form operand into
        # per-rank slabs; a transpose-free setup computes it here, cold,
        # at staging (it is never device-resident globally)
        R_sh = None if boundary else restriction_bcsr(ls)
        # at the switch boundary P/R are replaced by the gather-boundary
        # operators in DistSwitch; don't stage the unused sharded forms
        levels.append(DistLevel(
            a_op=build_dist_ell(A0, fine, fine, payload_pad=a_pad),
            p_op=None if boundary else
                build_dist_ell(ls.P, fine, coarse, const_data=p_np),
            r_op=None if boundary else
                build_dist_ell(R_sh, coarse, fine,
                               const_data=np.asarray(
                                   R_sh.data).astype(h_np)),
            stage1=s1, stage2=s2, diag_sel=diag_sel, diag_mask=diag_mask,
            row_mask=row_mask, a_nnz_starts=a_nnz_starts, a_pad=a_pad,
            bs=A0.br, rpad=rpad, n_fine=ls.n_fine))
    repl = [DistReplicatedLevel(ls=ls, n_eqs=ls.n_fine * ls.A0.br)
            for ls in setupd.levels[n_sharded:]]
    switch = None
    coarse_staging = None
    if repl:
        bls = setupd.levels[n_sharded - 1]       # last sharded level
        first = repl[0].ls                       # first replicated level
        fine = parts[n_sharded - 1]
        switch = DistSwitch(
            payload_sel=build_payload_gather(
                first.A0.indptr, parts[n_sharded],
                levels[-1].stage2.out_pad),
            row_sel=build_row_gather(fine, max(fine.max_count, 1)),
            r_ell=(bls.r_ell.astype(h_np)
                   if bls.r_ell is not None else None),
            p_g=(None if bls.r_ell is not None
                 else bls.p_ell.astype(h_np)),
            p_t=None if bls.r_ell is not None else bls.pt,
            p_b=build_dist_ell(bls.P, fine, parts[n_sharded],
                               const_data=np.asarray(
                                   bls.P.data).astype(h_np),
                               replicated_cols=True),
            nbr_c=first.A0.nbr, bs_c=first.A0.br)
    else:
        # legacy replicated coarsest-level maps (no agglomerated tail)
        Ac = setupd.coarse_struct
        c_part = parts[-1]
        ac_pad = levels[-1].stage2.out_pad
        c_rpad = max(c_part.max_count, 1)
        coarse_staging = DistCoarse(
            part=c_part,
            sel=build_payload_gather(Ac.indptr, c_part, ac_pad),
            rows=np.repeat(np.arange(Ac.nbr), np.diff(Ac.indptr)),
            cols=np.asarray(Ac.indices, dtype=np.int64),
            row_sel=build_row_gather(c_part, c_rpad),
            nbr=Ac.nbr, bs=Ac.br, rpad=c_rpad, ac_pad=ac_pad)
    return DistGAMG(ndev=ndev, parts=parts, levels=levels,
                    coarse=coarse_staging, smoother=setupd.smoother,
                    degree=setupd.degree, precision=setupd.precision,
                    repl=repl, switch=switch,
                    coarse_struct=setupd.coarse_struct if repl else None,
                    coarse_eq_limit=int(coarse_eq_limit), mesh=mesh)


# ---------------------------------------------------------------------------
# Hot path (per-rank functions, used inside shard_map)
# ---------------------------------------------------------------------------

def _pdot(a: Array, b: Array) -> Array:
    return lax.psum(jnp.vdot(a, b), AXIS)


def _pnorm(a: Array) -> Array:
    return jnp.sqrt(lax.psum(jnp.sum(a * a), AXIS))


def _pdot_cols(a: Array, b: Array) -> Array:
    """Per-column global dot over (rpad, bs, k) slabs -> (k,)."""
    return lax.psum(jnp.sum(a * b, axis=(0, 1)), AXIS)


def _pnorm_cols(a: Array) -> Array:
    return jnp.sqrt(lax.psum(jnp.sum(a * a, axis=(0, 1)), AXIS))


def _rank_lambda_max(lv: DistLevel, a, dinva_data: Array,
                     row_mask: Array, overlap: bool, iters: int = 10,
                     accum=None) -> Array:
    """Distributed power iteration — mirrors ``lambda_max_dinv_a``."""

    def spmv(x):
        return _rank_spmv(lv.a_op, a, "a_", dinva_data, x, overlap,
                          accum=accum)

    x0 = row_mask[:, None] * jnp.ones((lv.rpad, lv.bs), dinva_data.dtype)
    x0 = x0 / _pnorm(x0)

    def body(_, x):
        y = spmv(x)
        # finfo tiny, not a literal: 1e-300 underflows to 0 below f64
        return y / jnp.maximum(_pnorm(y), jnp.finfo(y.dtype).tiny)

    x = lax.fori_loop(0, iters, body, x0)
    return _pnorm(spmv(x))


def _rank_recompute(dg: DistGAMG, args, a_slab: Array, overlap: bool):
    """Distributed hot hierarchy rebuild: chained PtAP + smoother data.

    The payload chain runs at the policy's hierarchy dtype (the incoming
    fine slab is cast once at the top); under a mixed policy level 0
    additionally keeps a krylov-dtype payload gather (``a_data_kr``) for
    the outer CG's operator, mirroring ``Hierarchy.a_fine_ell``.

    With a replicated tail the sharded chain stops at the switch: the last
    sharded stage2 payload slabs are all-gathered once, the gather-boundary
    plan reassembles the first replicated operator's *global* payload, and
    the tail recompute is the single-device chain
    (``gamg.level_state`` + ``ptap_numeric_data``) run rank-redundantly —
    identical arithmetic to the single-device hot recompute.
    """
    policy = dg.precision
    h = jnp.dtype(policy.hierarchy_dtype)
    acc = policy.kernel_accum_dtype
    acc_p = jnp.promote_types(h, jnp.dtype(policy.accum_dtype))
    states = []
    a_cur = a_slab.astype(h)
    for li, lv in enumerate(dg.levels):
        a = args["levels"][li]
        a_ell_data = a_cur[a["a_gather"]]
        eye = jnp.eye(lv.bs, dtype=h)
        diag = jnp.where(a["diag_mask"][:, None, None], a_cur[a["diag_sel"]],
                         eye)
        dinv = jnp.linalg.inv(
            diag.astype(policy.factor_dtype)).astype(h)
        dinva = jnp.einsum("rab,rkbc->rkac", dinv.astype(acc_p),
                           a_ell_data.astype(acc_p),
                           preferred_element_type=acc_p).astype(h)
        lam = _rank_lambda_max(lv, a, dinva, a["row_mask"], overlap,
                               accum=acc)
        st = dict(a_data=a_ell_data, dinv=dinv, lam=lam)
        if li == 0 and policy.mixed:
            st["a_data_kr"] = a_slab.astype(
                policy.krylov_dtype)[a["a_gather"]]
        states.append(st)
        # next-level payload: local A@P (cached P_oth), then the
        # off-process reduction window for R@(AP)
        ap = dist_stage_apply(a_cur[a["s1_lhs"]], a["s1_rhs"], a["s1_seg"],
                              lv.stage1.out_pad, accum_dtype=acc)
        s2 = lv.stage2
        if overlap and s2.halo.strategy not in ("local", "replicated"):
            a_cur = dist_stage_apply_overlap(
                a["s2_lhs"], ap, s2.halo, a["s2_rhs"], a["s2_rhs_loc"],
                a["s2_msk"], a["s2_seg"], s2.out_pad, accum_dtype=acc)
        else:
            ap_win = halo_window(ap, s2.halo)
            a_cur = dist_stage_apply(a["s2_lhs"], ap_win[a["s2_rhs"]],
                                     a["s2_seg"], s2.out_pad,
                                     accum_dtype=acc)
    if dg.repl:
        g = lax.all_gather(a_cur, AXIS, axis=0, tiled=True)
        a_data = g[jnp.asarray(dg.switch.payload_sel)]
        for rl in dg.repl:
            states.append(level_state(rl.ls, a_data, policy))
            a_data = ptap_numeric_data(rl.ls.ptap_cache, a_data,
                                       rl.ls.P.data.astype(h),
                                       accum_dtype=acc)
        Ac = dg.coarse_struct.with_data(a_data)
        chol = coarse_cholesky(Ac.to_dense(), policy)
    else:
        chol = _rank_coarse_chol(dg, a_cur)
    return states, chol


def _rank_coarse_chol(dg: DistGAMG, ac_slab: Array) -> Array:
    """Replicated dense Cholesky of the (tiny) coarsest operator.

    Shares ``gamg.jittered_cholesky`` — including its NaN-detect
    jitter-escalation retry — so the dist path hardens against an
    indefinite coarse operator exactly like the single-device one.
    """
    c = dg.coarse
    policy = dg.precision
    g = lax.all_gather(ac_slab, AXIS, axis=0, tiled=True)
    blocks = g[jnp.asarray(c.sel)]
    dense4 = jnp.zeros((c.nbr, c.nbr, c.bs, c.bs), ac_slab.dtype)
    dense4 = dense4.at[jnp.asarray(c.rows), jnp.asarray(c.cols)].add(blocks)
    n = c.nbr * c.bs
    dense = dense4.transpose(0, 2, 1, 3).reshape(n, n)
    chol = jittered_cholesky(dense.astype(jnp.dtype(policy.factor_dtype)),
                             policy.coarse_jitter_scale(),
                             policy.coarse_retry_scale())
    return chol.astype(policy.hierarchy_dtype)


def _rank_coarse_solve(dg: DistGAMG, chol: Array, rhs: Array) -> Array:
    """Replicated coarse solve; every rank slices its own slab back out.

    ``rhs`` is the (rpad, bs) coarse slab or its (rpad, bs, k) panel —
    ``cho_solve`` broadcasts over matrix right-hand sides natively.
    """
    c = dg.coarse
    trailing = rhs.shape[2:]
    g = lax.all_gather(rhs, AXIS, axis=0, tiled=True)     # (ndev*rpad, bs..)
    rhs_g = g[jnp.asarray(c.row_sel)]                     # (nbr, bs[, k])
    xc = jax.scipy.linalg.cho_solve(
        (chol, True), rhs_g.reshape((c.nbr * c.bs,) + trailing))
    xcb = jnp.pad(xc.reshape((c.nbr, c.bs) + trailing),
                  ((0, c.rpad), (0, 0)) + ((0, 0),) * len(trailing))
    r = lax.axis_index(AXIS)
    start = jnp.asarray(dg.coarse.part.starts)[r]
    zero = jnp.zeros_like(start)
    mine = lax.dynamic_slice(xcb, (start, zero) + (zero,) * len(trailing),
                             (c.rpad, c.bs) + trailing)
    mask = jnp.arange(c.rpad) < jnp.asarray(c.part.counts)[r]
    return mine * mask.reshape((c.rpad,) + (1,) * (mine.ndim - 1))


def _rank_assemble(da: DistAssembly, aargs, E: Array, nu: Array) -> Array:
    """Rank-local device assembly: coefficient slabs -> fine payload slab.

    Vmapped quadrature over this rank's (padded) element set, then the
    rank's contiguous slice of the global scatter-sum — same contribution
    order as the single-device ``set_values_coo``, so the assembled slabs
    match ``scatter_fine_payloads`` of the globally assembled stream.
    Padded elements compute element 0's block (valid arithmetic, no NaN)
    and their contributions are masked out of the segment sum.
    """
    from repro.fem.device_stiffness import element_stiffness_blocks
    dt = E.dtype
    blocks = element_stiffness_blocks(da.quad_b.astype(dt),
                                      da.quad_w.astype(dt), E, nu)
    nn, bs = da.nn, da.bs
    bl = blocks.reshape(-1, nn, bs, nn, bs).transpose(0, 1, 3, 2, 4)
    contrib = bl[aargs["elem"], aargs["pa"], aargs["pb"]]
    contrib = contrib * aargs["mask"][:, None, None].astype(dt)
    return jax.ops.segment_sum(contrib, aargs["seg"],
                               num_segments=da.a_pad,
                               indices_are_sorted=True)


def _rank_spmv(op: DistEll, a, pre: str, data: Array, x: Array,
               overlap: bool, accum=None) -> Array:
    """Per-rank SpMV through one of the two exchange renderings.

    ``a`` is the level's sharded-args dict, ``pre`` the operator's key
    prefix (``"a_"``/``"p_"``/``"r_"``/``"pb_"``).  Blocking
    (``overlap=False``) is exactly the pre-split apply: assemble the whole
    window, one apply over all rows — bitwise the historical jaxpr.
    Overlapped: issue the exchange, contract the full slab against the
    rank's own vector while it flies, finish the window, contract it
    again off the window, select per row (interior rows keep the
    exchange-free lane, boundary rows the windowed one).
    Halos that move no bytes (``local``/``replicated``) have nothing to
    hide and always take the blocking rendering.
    """
    idx = a[pre + "idx"]
    if not overlap or op.halo.strategy in ("local", "replicated"):
        return dist_ell_apply(idx, data, halo_window(x, op.halo),
                              accum_dtype=accum)
    pend = start_halo_exchange(x, op.halo)
    y_int = dist_ell_apply_interior(a[pre + "loc"], data, x,
                                    accum_dtype=accum)
    win = finish_halo_exchange(pend)
    y_bnd = dist_ell_apply_boundary(idx, data, win, accum_dtype=accum)
    return combine_split(a[pre + "msk"], y_int, y_bnd)


def _rank_smooth(dg: DistGAMG, spmv, st, b: Array, x: Array) -> Array:
    """Same recurrences as the single-device V-cycle (single source of
    truth in ``repro.core.vcycle``) with per-rank spmv/pbjacobi closures —
    iteration parity with the single-device path depends on this."""
    acc = jnp.promote_types(st["dinv"].dtype,
                            jnp.dtype(dg.precision.accum_dtype))

    def pbj(r):
        return jnp.einsum("nab,nb...->na...", st["dinv"].astype(acc),
                          r.astype(acc),
                          preferred_element_type=acc).astype(r.dtype)

    if dg.smoother == "chebyshev":
        return chebyshev_recurrence(spmv, pbj, st["lam"], b, x, dg.degree)
    return pbjacobi_recurrence(spmv, pbj, b, x, dg.degree)


def _repl_smooth(dg: DistGAMG, st: LevelState, b: Array, x: Array) -> Array:
    """Smoother on a replicated level: literally the single-device one."""
    return apply_smoother(st, b, x, dg.smoother, dg.degree)


def _boundary_restrict(dg: DistGAMG, r: Array) -> Array:
    """Cross sharded->replicated: one all-gather of the fine residual
    slabs, reassemble the global vector, apply the global restriction
    rank-redundantly.  The only V-cycle communication the replicated tail
    costs."""
    sw = dg.switch
    g = lax.all_gather(r, AXIS, axis=0, tiled=True)   # (ndev*rpad, bs[, k])
    rg = g[jnp.asarray(sw.row_sel)]                   # (nbr_f, bs[, k])
    flat = rg.reshape((rg.shape[0] * rg.shape[1],) + rg.shape[2:])
    if sw.r_ell is not None:
        return apply_ell(sw.r_ell, flat)
    return apply_ell_t(sw.p_g, sw.p_t, flat)


def _boundary_prolong(dg: DistGAMG, a, xc: Array, overlap: bool,
                      accum=None) -> Array:
    """Cross replicated->sharded: the boundary prolongator's plan indices
    address the replicated correction directly (``"replicated"`` halo), so
    re-slicing the correction back into row slabs moves zero bytes — the
    split-apply router degenerates to the blocking rendering (nothing to
    hide) and the jaxpr is the historical one under either knob value.
    ``a`` is the boundary level's sharded-args dict (``pb_idx``/``pb_data``
    are this rank's slab of the re-slicing prolongator)."""
    sw = dg.switch
    xcb = xc.reshape((sw.nbr_c, sw.bs_c) + xc.shape[1:])
    return _rank_spmv(sw.p_b, a, "pb_", a["pb_data"], xcb, overlap,
                      accum=accum)


def _rank_vcycle(dg: DistGAMG, args, states, chol: Array, b: Array,
                 overlap: bool) -> Array:
    """One V-cycle over the placed hierarchy (zero initial guess).

    Sharded levels run the slab recurrences with halo-window SpMVs;
    replicated levels run the single-device core recurrences on global
    vectors, rank-redundantly, with zero communication.  The two layouts
    meet at the switch: restriction crosses it with one all-gather
    (``_boundary_restrict``), prolongation re-slices the replicated
    correction back into slabs for free (``_boundary_prolong``).

    Every sharded operator apply threads the policy's kernel accumulator
    so sub-fp32 hierarchies contract at ``accum_dtype`` (None — native —
    for the stock f64/f32 policies).
    """
    acc = dg.precision.kernel_accum_dtype
    ns = len(dg.levels)
    bs_stack, x_stack = [], []
    rhs = b
    for li, lv in enumerate(dg.levels):
        a = args["levels"][li]
        st = states[li]

        def spmv_a(v, a=a, st=st, lv=lv):
            return _rank_spmv(lv.a_op, a, "a_", st["a_data"], v, overlap,
                              accum=acc)

        x = _rank_smooth(dg, spmv_a, st, rhs, jnp.zeros_like(rhs))
        r = rhs - spmv_a(x)
        bs_stack.append(rhs)
        x_stack.append(x)
        if li == ns - 1 and dg.repl:
            rhs = _boundary_restrict(dg, r)
        else:
            rhs = _rank_spmv(lv.r_op, a, "r_", a["r_data"], r, overlap,
                             accum=acc)
    if dg.repl:
        # replicated tail: the single-device V-cycle on global vectors
        for li in range(ns, ns + len(dg.repl)):
            st = states[li]
            x = _repl_smooth(dg, st, rhs, jnp.zeros_like(rhs))
            r = rhs - apply_ell(st.a_ell, x)
            bs_stack.append(rhs)
            x_stack.append(x)
            rhs = apply_restriction(st, r)
        xc = jax.scipy.linalg.cho_solve((chol, True), rhs)
        for li in reversed(range(ns, ns + len(dg.repl))):
            st = states[li]
            x = x_stack[li] + apply_ell(st.p_ell, xc)
            xc = _repl_smooth(dg, st, bs_stack[li], x)
    else:
        xc = _rank_coarse_solve(dg, chol, rhs)
    for li in reversed(range(ns)):
        a = args["levels"][li]
        st = states[li]
        lv = dg.levels[li]

        def spmv_a(v, a=a, st=st, lv=lv):
            return _rank_spmv(lv.a_op, a, "a_", st["a_data"], v, overlap,
                              accum=acc)

        if li == ns - 1 and dg.repl:
            corr = _boundary_prolong(dg, a, xc, overlap, accum=acc)
        else:
            corr = _rank_spmv(lv.p_op, a, "p_", a["p_data"], xc, overlap,
                              accum=acc)
        x = x_stack[li] + corr
        xc = _rank_smooth(dg, spmv_a, st, bs_stack[li], x)
    return xc


def _rank_pcg(dg: DistGAMG, args, states, chol: Array, b: Array,
              rtol: float, maxiter: int, overlap: bool = False,
              stall_window: int = 40, x0: Array | None = None):
    """Distributed PCG — mirrors ``repro.core.krylov.pcg`` with psum dots.

    ``x0`` warm-starts from a prior iterate slab (``None`` = cold zero
    start, bitwise the classic recurrence) — the same contract as
    ``pcg(x0=...)``, threaded per rank by the warm dist march.

    Under a mixed policy the operator uses level 0's krylov-dtype payload
    copy and the V-cycle runs at the smoother dtype behind the same
    boundary cast as ``pcg(precond_dtype=...)``.

    Health mirrors ``pcg`` too: NaN/Inf, breakdown and stagnation flags
    folded into the int32 status the solver returns alongside
    (x, iters, relres, ok).  The flags read the psum reductions the
    recurrence already performs, and every rank computes them from the
    same replicated scalars — the exit decision is collective for free,
    no extra communication.  A faulted halo/spmv on ONE rank still trips
    every rank's flag within one iteration, because the corrupted value
    enters the global psum.  Clean runs are bitwise the pre-health loop.
    """
    a0 = args["levels"][0]
    st0 = states[0]
    a_data_kr = st0.get("a_data_kr", st0["a_data"])

    def apply_a(v):
        return _rank_spmv(dg.levels[0].a_op, a0, "a_", a_data_kr, v,
                          overlap)

    apply_m = wrap_precond(
        lambda r: _rank_vcycle(dg, args, states, chol, r, overlap),
        dg.precision.smoother_dtype, b.dtype)

    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - apply_a(x)
    z = apply_m(r)
    p = z
    rz = _pdot(r, z)
    # dtype-aware breakdown floor (see core.krylov.pcg): an all-zero rhs
    # reports converged=True, iters=0, relres=0 at any krylov dtype
    bnorm = jnp.maximum(_pnorm(b), jnp.finfo(b.dtype).tiny)
    rnorm = _pnorm(r)
    nonf0 = ~jnp.isfinite(rnorm) | ~jnp.isfinite(rz)
    brk0 = ~nonf0 & (rz <= 0) & (rnorm > rtol * bnorm)

    def cond(state):
        (x, r, z, p, rz, rnorm, k, best, stall, brk, nonf) = state
        return ((rnorm > rtol * bnorm) & (k < maxiter)
                & ~brk & ~nonf & (stall < stall_window))

    def body(state):
        (x, r, z, p, rz, rnorm, k,
         (best_x, best_rnorm), stall, brk, nonf) = state
        Ap = inject.maybe("spmv", apply_a(p), step=k)
        pAp = _pdot(p, Ap)
        alpha = rz / pAp
        x_new = x + alpha * p
        r_new = r - alpha * Ap
        z_new = inject.maybe("precond", apply_m(r_new), step=k)
        rz_new = _pdot(r_new, z_new)
        beta = rz_new / rz
        p_new = z_new + beta * p
        rnorm_new = _pnorm(r_new)
        nonf_new = (~jnp.isfinite(pAp) | ~jnp.isfinite(rnorm_new)
                    | ~jnp.isfinite(rz_new))
        brk_new = ~nonf_new & ((pAp <= 0)
                               | ((rz_new <= 0)
                                  & (rnorm_new > rtol * bnorm)))
        ok_step = ~(nonf_new | brk_new)
        x = jnp.where(ok_step, x_new, x)
        r = jnp.where(ok_step, r_new, r)
        z = jnp.where(ok_step, z_new, z)
        p = jnp.where(ok_step, p_new, p)
        rz = jnp.where(ok_step, rz_new, rz)
        rnorm = jnp.where(ok_step, rnorm_new, rnorm)
        improved = ok_step & (rnorm_new < best_rnorm)
        best_x = jnp.where(improved, x_new, best_x)
        best_rnorm = jnp.where(improved, rnorm_new, best_rnorm)
        stall = jnp.where(improved, 0, stall + 1)
        return (x, r, z, p, rz, rnorm, k + 1, (best_x, best_rnorm),
                stall, brk | brk_new, nonf | nonf_new)

    best_rnorm0 = jnp.where(jnp.isfinite(rnorm), rnorm, jnp.inf)
    state = (x, r, z, p, rz, rnorm, jnp.asarray(0), (x, best_rnorm0),
             jnp.asarray(0), brk0, nonf0)
    (x, r, z, p, rz, rnorm, k, (best_x, best_rnorm), stall, brk, nonf) = \
        lax.while_loop(cond, body, state)
    converged = rnorm <= rtol * bnorm
    x_out = jnp.where(converged, x, best_x)
    rnorm_out = jnp.where(converged, rnorm, best_rnorm)
    stag = ~converged & ~brk & ~nonf & (stall >= stall_window)
    status = status_of(converged, brk, nonf, stag)
    return x_out, k, rnorm_out / bnorm, converged, status


def _rank_block_pcg(dg: DistGAMG, args, states, chol: Array, b: Array,
                    rtol: float, maxiter: int, overlap: bool = False,
                    stall_window: int = 40, x0: Array | None = None):
    """Distributed masked panel PCG over (rpad, bs, k) slabs.

    The recurrence body is ``repro.multirhs.block_krylov.block_pcg``
    itself (single source of truth, like the shared smoother
    recurrences in ``core.vcycle``) with the per-column reductions
    replaced by psum versions — the per-column iteration parity with the
    single-device batched path that the selftest's multi-RHS check
    asserts depends on the two paths sharing this body.
    """
    a0 = args["levels"][0]
    st0 = states[0]
    a_data_kr = st0.get("a_data_kr", st0["a_data"])

    def apply_a(v):
        return _rank_spmv(dg.levels[0].a_op, a0, "a_", a_data_kr, v,
                          overlap)

    def apply_m(r):
        return _rank_vcycle(dg, args, states, chol, r, overlap)

    res = block_pcg(apply_a, apply_m, b, x0=x0, rtol=rtol, maxiter=maxiter,
                    col_dot=_pdot_cols, col_norm=_pnorm_cols,
                    precond_dtype=dg.precision.smoother_dtype,
                    stall_window=stall_window)
    return res.x, res.iters, res.relres, res.converged, res.health.status


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------

def make_dist_solver(dg: DistGAMG, setupd: GAMGSetup, mesh, *,
                     rtol: float = 1e-8, maxiter: int = 200,
                     warm_start: bool = False):
    """Jitted distributed hot path:
    ``(args, a0, b) -> (x, iters, relres, ok, status)``.

    ``warm_start=True`` is a *build-time* knob that adds a trailing
    ``x0`` slab input (scattered like ``b``) to the signature —
    ``(args, a0, b, x0)`` — warm-starting each rank's CG from the prior
    iterate, the distributed twin of ``pcg(x0=...)``.  The default
    signature and its traced program are unchanged.

    ``args`` from ``dg.sharded_args``, ``a0`` from
    ``dg.scatter_fine_payloads`` (new fine operator values — the Newton
    step), ``b`` from ``dg.scatter_vector``.  One shard_map program:
    recompute the hierarchy, then CG-solve.  Outputs are stacked per rank;
    iters/relres/converged/status are replicated, take index 0.
    ``status`` is the int32 health code of ``repro.robust.health``
    (``STATUS_NAMES``), scalar for a vector solve, per-column for a panel.

    ``b`` may be a single scattered vector (slabs ``(rpad, bs)``) or a
    scattered panel (``(rpad, bs, k)`` — ``dg.scatter_vector`` on an
    ``(n, k)`` payload): the panel case runs the masked multi-RHS PCG and
    iters/relres/converged come back per column (shape ``(k,)``).

    Placement is baked into ``dg``: agglomerated levels (``dg.repl``) are
    closed over as replicated constants, so the same program serves any
    sharded/replicated split without signature changes.
    """
    del setupd  # structure is baked into dg; kept for call-site symmetry

    def rank_body(args, a0, b, x0):
        # consumed at trace time, like the kernel path knobs: every rank
        # traces the same Python, so the schedule choice is collective-safe
        overlap = resolve_overlap() == "on"
        # metadata-only spans: identical on every rank, collective-safe
        with obs_trace.span("dist/recompute"):
            states, chol = _rank_recompute(dg, args, a0, overlap)
        run_pcg = _rank_block_pcg if b.ndim == 3 else _rank_pcg
        with obs_trace.span("dist/pcg"):
            x, k, relres, ok, status = run_pcg(dg, args, states, chol, b,
                                               rtol, maxiter, overlap,
                                               x0=x0)
        return (x[None], k[None], relres[None], ok[None], status[None])

    if warm_start:
        def rank_fn(args, a0, b, x0):
            args, a0, b, x0 = jax.tree.map(
                lambda t: t[0], (args, a0, b, x0))
            return rank_body(args, a0, b, x0)
        in_specs = (P(AXIS),) * 4
    else:
        def rank_fn(args, a0, b):
            args, a0, b = jax.tree.map(lambda t: t[0], (args, a0, b))
            return rank_body(args, a0, b, None)
        in_specs = (P(AXIS),) * 3

    sharded = shard_map(rank_fn, mesh, in_specs=in_specs,
                        out_specs=P(AXIS), check_rep=False)
    return _with_rank0_span(jax.jit(sharded), "dist/solve")


def make_dist_coeff_solver(dg: DistGAMG, da: DistAssembly, mesh, *,
                           rtol: float = 1e-8, maxiter: int = 200,
                           warm_start: bool = False):
    """Jitted distributed *coefficient* hot path:
    ``(args, aargs, E, nu, b) -> (x, iters, relres, ok, status)``.

    The quasi-static front door: instead of a pre-assembled value stream
    (``make_dist_solver``'s ``a0``), each rank receives its coefficient
    slabs (``da.scatter_fields``) and runs device FEM assembly, the
    state-gated recompute and the CG solve as one shard_map program —
    the distributed twin of ``gamg.make_coeff_recompute``.  ``aargs``
    from ``da.sharded_args()``; everything else as ``make_dist_solver``
    (panel ``b`` supported the same way).

    ``warm_start=True`` (build-time) appends an ``x0`` slab input —
    ``(args, aargs, E, nu, b, x0)`` — so a time march can feed each
    rank's previous iterate straight back in: the slab-sharded twin of
    the ``repro.sim`` march step, exercised by the
    ``REPRO_SELFTEST_MARCH`` selftest section.
    """

    def rank_body(args, aargs, E, nu, b, x0):
        overlap = resolve_overlap() == "on"
        with obs_trace.span("dist/assemble"):
            a_slab = _rank_assemble(da, aargs, E, nu)
        with obs_trace.span("dist/recompute"):
            states, chol = _rank_recompute(dg, args, a_slab, overlap)
        run_pcg = _rank_block_pcg if b.ndim == 3 else _rank_pcg
        with obs_trace.span("dist/pcg"):
            x, k, relres, ok, status = run_pcg(dg, args, states, chol, b,
                                               rtol, maxiter, overlap,
                                               x0=x0)
        return (x[None], k[None], relres[None], ok[None], status[None])

    if warm_start:
        def rank_fn(args, aargs, E, nu, b, x0):
            args, aargs, E, nu, b, x0 = jax.tree.map(
                lambda t: t[0], (args, aargs, E, nu, b, x0))
            return rank_body(args, aargs, E, nu, b, x0)
        in_specs = (P(AXIS),) * 6
    else:
        def rank_fn(args, aargs, E, nu, b):
            args, aargs, E, nu, b = jax.tree.map(
                lambda t: t[0], (args, aargs, E, nu, b))
            return rank_body(args, aargs, E, nu, b, None)
        in_specs = (P(AXIS),) * 5

    sharded = shard_map(rank_fn, mesh, in_specs=in_specs,
                        out_specs=P(AXIS), check_rep=False)
    return _with_rank0_span(jax.jit(sharded), "dist/coeff_solve")


def _with_rank0_span(jitted, name: str):
    """Wrap a jitted dist entry point in a rank-0 host timing span.

    Resolved at *build* time like every other obs decision: with spans off
    (the default) the jitted callable is returned untouched — zero wrapper,
    zero overhead.  Enabled, each call lands one blocked wall-clock
    observation in the default registry's ``{name}/seconds`` histogram,
    recorded only on process rank 0 (``obs_trace.rank0_span``) so
    multi-process runs stay collective-safe.
    """
    if not obs_trace.spans_enabled():
        return jitted

    def timed(*args):
        with obs_trace.rank0_span(name) as stop:
            return stop(jitted(*args))

    return timed
