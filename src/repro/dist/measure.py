"""Measured (traced) collective counts of the distributed V-cycle.

``repro.obs.model.dist_cycle_comm`` *predicts* the per-cycle message
traffic of the distributed hierarchy; this module *measures* it, by
staging the actual shard_map programs and counting the collective
equations in their jaxprs.  Every halo-exchanged slab is exactly one
``ppermute`` equation and every window/solve gather exactly one
``all_gather`` (``ndev - 1`` slab messages under recursive doubling), so
static equation counts of the *unrolled* V-cycle are the per-cycle
message counts — no timing, no devices doing real work, just traces.

The V-cycle is isolated by differencing: one trace runs the rank
recompute alone, a second runs recompute + one V-cycle; the recompute's
collectives (lambda-max power iterations, the stage-2 windows, the
coarse gather) cancel and the difference is one cycle.  The counts are
schedule-invariant — the overlapped split apply reorders the same
exchanges, it does not add or drop any — which is itself worth pinning.

CLI (``python -m repro.dist.measure m pr pc``) prints the comparison as
JSON; it needs ``XLA_FLAGS=--xla_force_host_platform_device_count=<pr>``
in the environment (the caller's job, exactly like the dist selftest),
which is why ``benchmarks/table1_weak_scaling.py`` runs it as a
subprocess for its model-vs-measured column.
"""
from __future__ import annotations

import json
import re
import sys

import numpy as np

_PRIMS = ("ppermute", "all_gather")


def count_collectives(jaxpr_text: str, ndev: int) -> dict:
    """Collective-equation counts of a jaxpr rendering -> message counts.

    ``msgs`` is per rank per execution: one slab message per ``ppermute``
    equation, ``ndev - 1`` per ``all_gather`` (each rank receives every
    other rank's slab).
    """
    counts = {p: len(re.findall(rf"\b{p}\[", jaxpr_text)) for p in _PRIMS}
    counts["msgs"] = (counts["ppermute"]
                      + (ndev - 1) * counts["all_gather"])
    return counts


def measured_cycle_comm(dg, mesh) -> dict:
    """Per-cycle collective counts of ``dg``'s V-cycle on ``mesh``.

    Returns ``{"cycle": {...}, "recompute": {...}}`` — the cycle entry is
    the recompute-differenced count (see module docstring).
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.dist import solver as ds

    P = PartitionSpec
    lv0 = dg.levels[0]
    nnzb = int(lv0.a_nnz_starts[-1])
    args = dg.sharded_args()
    a0 = jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype),
        dg.scatter_fine_payloads(
            np.zeros((nnzb, lv0.bs, lv0.bs), np.float64)))
    b = jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype),
        dg.scatter_vector(np.zeros(lv0.n_fine * lv0.bs, np.float64)))
    overlap = ds.resolve_overlap() == "on"

    def recompute_only(args, a0):
        args, a0 = jax.tree.map(lambda t: t[0], (args, a0))
        _, chol = ds._rank_recompute(dg, args, a0, overlap)
        return chol[None]

    def recompute_and_cycle(args, a0, b):
        args, a0, b = jax.tree.map(lambda t: t[0], (args, a0, b))
        states, chol = ds._rank_recompute(dg, args, a0, overlap)
        return ds._rank_vcycle(dg, args, states, chol, b, overlap)[None]

    def trace(f, *xs):
        sm = shard_map(f, mesh, in_specs=(P(ds.AXIS),) * len(xs),
                       out_specs=P(ds.AXIS), check_rep=False)
        return str(jax.make_jaxpr(sm)(*xs))

    rec = count_collectives(trace(recompute_only, args, a0), dg.ndev)
    full = count_collectives(trace(recompute_and_cycle, args, a0, b),
                             dg.ndev)
    cycle = {k: full[k] - rec[k] for k in full}
    return {"cycle": cycle, "recompute": rec}


def main(m: int, pr: int, pc: int) -> int:
    import jax

    from repro.core import gamg
    from repro.dist.partition import ProcessMesh
    from repro.dist.solver import build_dist_gamg
    from repro.fem.assemble import assemble_elasticity
    from repro.obs.model import dist_cycle_comm

    assert len(jax.devices()) >= pr, \
        (f"need XLA_FLAGS=--xla_force_host_platform_device_count={pr}, "
         f"got {len(jax.devices())} devices")
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:pr]), ("rank",))
    prob = assemble_elasticity(m)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    dg = build_dist_gamg(setupd, ProcessMesh((pr, pc)))
    measured = measured_cycle_comm(dg, mesh)
    model_rows = dist_cycle_comm(dg)
    model_msgs = sum(r["msgs"] for r in model_rows)
    print(json.dumps({"m": m, "pr": pr, "pc": pc,
                      "measured": measured,
                      "model_msgs": model_msgs,
                      "model_rows": model_rows}))
    return 0


if __name__ == "__main__":
    sys.exit(main(int(sys.argv[1]) if len(sys.argv) > 1 else 5,
                  int(sys.argv[2]) if len(sys.argv) > 2 else 2,
                  int(sys.argv[3]) if len(sys.argv) > 3 else 1))
