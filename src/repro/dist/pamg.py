"""Distributed blocked operators over row slabs — halos, SpMV, and PtAP.

The paper's distributed hot path keeps every operand device-resident and
pre-stages the *communication plan* on the host, once, gated on object
state.  The JAX rendering here follows the same split:

host (cold, this module's ``build_*``)
    Remap every global index into (owner rank, slab-local) coordinates,
    decide the halo pattern, split each rank's rows into **interior**
    (every ELL column inside the local slab) and **boundary** (reads the
    halo window) sets, and stack the per-rank plans into ``(ndev, ...)``
    arrays that ``shard_map`` splits over the rank axis.  Constant
    operands — the prolongator payloads, including the off-process rows
    **P_oth** — are pre-gathered per rank at build time (the paper's
    cached stacked operand), so the hot PtAP does *zero* communication for
    P.

device (hot, the ``*_apply`` / exchange functions)
    Pure per-rank functions used inside ``shard_map``.  The only
    communication is (a) vector halo windows for SpMV and (b) the
    off-process reduction window over the A·P payload slabs in the second
    Galerkin stage — both neighbor ``lax.ppermute`` slab exchanges on
    mesh-ordered problems (``Halo.strategy == "ppermute"``), with an
    ``all_gather`` fallback when a plan's reach exceeds the neighbor
    window.

    The exchange comes in two renderings sharing one op sequence:

    * blocking — ``halo_window(x, halo)`` issues the ppermutes and
      concatenates; the whole apply waits on the window.  This is the
      ``REPRO_OVERLAP=off`` path and is bitwise the historical behaviour.
    * overlapped — ``start_halo_exchange`` issues the same ppermutes and
      returns a ``PendingExchange``; the caller runs
      ``dist_ell_apply_interior`` on the rows that need no halo while the
      exchange is in flight, then ``finish_halo_exchange`` +
      ``dist_ell_apply_boundary`` for the rows that read the window, and
      ``combine_split`` scatters the two partial results back into slab
      order.  Each row is computed by exactly one path with the identical
      per-row contraction, so the overlapped apply is *bitwise* the
      blocking one — communication/computation overlap is free of any
      reassociation.

Agglomerated (replicated) coarse levels add a third input layout: when the
placement policy in ``repro.dist.solver`` takes a level off the sharded
path, its operands live *replicated* on every rank and operator applies do
zero communication.  Two pieces here support that:

* ``Halo.strategy == "replicated"`` — the input vector is already global,
  ``halo_window`` is the identity and plan indices are plain global block
  coordinates (``build_dist_ell(..., replicated_cols=True)`` emits them).
  Used by the boundary prolongator that re-slices the replicated coarse
  correction back into row slabs.
* the **gather-boundary plans** ``build_row_gather`` /
  ``build_payload_gather`` — window ids that reassemble a global vector /
  payload array from one ``all_gather`` of the padded per-rank slabs.  The
  switch level crosses the sharded->replicated boundary with exactly one
  such gather per V-cycle (restriction) and one per recompute (the
  Galerkin payload of the first replicated operator).

Padding discipline (what keeps the padded lanes exact):
    every payload slab is padded to ``max_count + 1`` so its last slot is
    guaranteed zero; padded plan entries either gather that zero slot or
    carry a zero *constant* operand, so they contribute exactly ``0.0``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.block_csr import BlockCSR
from repro.core.spgemm import SpGEMMPlan
from repro.dist.partition import RowPartition
from repro.robust import inject

Array = jax.Array

AXIS = "rank"


# ---------------------------------------------------------------------------
# Halo windows
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Halo:
    """Exchange pattern for one sharded operand axis.

    ``"replicated"`` marks an operand whose input vector is already global
    on every rank (an agglomerated level's correction): the window is the
    vector itself and no exchange happens — the all-gather that made it
    global is accounted at the switch boundary, not here.
    """

    width: int       # neighbor hops each side (0 = purely local)
    strategy: str    # "local" | "ppermute" | "allgather" | "replicated"
    cpad: int        # padded slab length of the exchanged axis
    ndev: int

    @property
    def window_len(self) -> int:
        if self.strategy == "allgather":
            return self.cpad * self.ndev
        if self.strategy == "replicated":
            return self.cpad
        return self.cpad * (2 * self.width + 1)

    @property
    def exchanged_slabs(self) -> int:
        """Slabs moved per rank per exchange (the halo traffic unit)."""
        if self.strategy in ("local", "replicated"):
            return 0
        return (self.ndev - 1 if self.strategy == "allgather"
                else 2 * self.width)


def make_halo(width: int, cpad: int, ndev: int) -> Halo:
    if width == 0 or ndev == 1:
        return Halo(0, "local", cpad, ndev)
    # neighbor windows beat allgather strictly below (ndev-1)/2 hops: at
    # 2w == ndev the (2w+1)-slab window already exceeds the ndev-slab one
    if width <= max(1, (ndev - 1) // 2):
        return Halo(width, "ppermute", cpad, ndev)
    return Halo(width, "allgather", cpad, ndev)


def window_coords(halo: Halo, owner: np.ndarray, local: np.ndarray,
                  rank: int) -> np.ndarray:
    """Host: window coordinate of (owner, slab-local) seen from ``rank``."""
    if halo.strategy == "replicated":
        return local                     # the window IS the global vector
    if halo.strategy == "allgather":
        return owner * halo.cpad + local
    return (owner - rank + halo.width) * halo.cpad + local


def center_coord(halo: Halo, rank: int) -> int:
    """A always-valid in-window coordinate for padded plan entries."""
    if halo.strategy == "replicated":
        return 0
    if halo.strategy == "allgather":
        return rank * halo.cpad
    return halo.width * halo.cpad


@dataclasses.dataclass
class PendingExchange:
    """An in-flight halo exchange: the issued collectives, not yet a window.

    ``start_halo_exchange`` issues every ppermute (or the all-gather) and
    returns immediately; ``finish_halo_exchange`` assembles the window.
    Between the two the caller is free to run communication-free work
    (the interior rows) — XLA's latency-hiding scheduler overlaps the
    collectives with whatever is issued before the first use of their
    results.
    """

    parts: tuple
    halo: Halo


def start_halo_exchange(x: Array, halo: Halo) -> PendingExchange:
    """Device (inside shard_map): issue the halo collectives of a slab.

    ``x`` is this rank's padded slab ``(cpad, ...)``; the pending parts
    are the neighbor slabs ``[-w..w]`` (ppermute), the gathered stack
    (allgather), or ``x`` itself (local/replicated — nothing moves).
    Edge ranks receive zero slabs, which padded plan entries never
    address.
    """
    if halo.strategy in ("local", "replicated"):
        return PendingExchange((x,), halo)
    if halo.strategy == "allgather":
        return PendingExchange(
            (lax.all_gather(x, AXIS, axis=0, tiled=True),), halo)
    parts = []
    for d in range(-halo.width, halo.width + 1):
        if d == 0:
            parts.append(x)
            continue
        # rank r receives slab r + d  <=>  src i sends to dst i - d
        perm = [(i, i - d) for i in range(halo.ndev)
                if 0 <= i - d < halo.ndev]
        parts.append(lax.ppermute(x, AXIS, perm))
    return PendingExchange(tuple(parts), halo)


def finish_halo_exchange(pend: PendingExchange) -> Array:
    """Device: assemble the halo window from an in-flight exchange.

    The "halo" fault-injection site lives here, on the *assembled* window
    — so on the split path a planted fault corrupts the exchanged payload
    before ``dist_ell_apply_boundary`` consumes it, exactly as the
    blocking window does (trace-time identity unless a schedule is
    installed — ``repro.robust.inject``); local/replicated strategies
    move no bytes and are exempt by construction.
    """
    halo = pend.halo
    if halo.strategy in ("local", "replicated"):
        return pend.parts[0]
    if halo.strategy == "allgather":
        return inject.maybe("halo", pend.parts[0])
    return inject.maybe("halo", jnp.concatenate(pend.parts, axis=0))


def halo_window(x: Array, halo: Halo) -> Array:
    """Device: the *blocking* window — issue the exchange and wait for it.

    Literally ``finish_halo_exchange(start_halo_exchange(x, halo))``: the
    op sequence (ppermute order, concatenation, fault-injection point) is
    the historical one, which is what keeps ``REPRO_OVERLAP=off`` bitwise
    the pre-overlap apply.
    """
    return finish_halo_exchange(start_halo_exchange(x, halo))


# ---------------------------------------------------------------------------
# Gather-boundary plans (the sharded -> replicated switch)
# ---------------------------------------------------------------------------

def build_row_gather(part: RowPartition, pad: int) -> np.ndarray:
    """Host: window id of every global block row in an all-gathered stack.

    ``lax.all_gather(slab, tiled=True)`` of per-rank ``(pad, ...)`` slabs
    yields ``(ndev*pad, ...)``; indexing it with the returned ``(nrows,)``
    map reassembles the *global* unpadded vector — the one all-gather an
    agglomerated level costs per V-cycle.
    """
    rows = np.arange(part.nrows)
    owner = part.owner_of(rows)
    return owner * pad + (rows - part.starts[owner])


def build_payload_gather(indptr: np.ndarray, part: RowPartition,
                         pad: int) -> np.ndarray:
    """Host: window ids reassembling a global ``(nnzb, ...)`` payload from
    all-gathered per-rank payload slabs (slab r holds the nnz of r's rows,
    padded to ``pad``).  The recompute-side twin of ``build_row_gather`` —
    used once per ``_rank_recompute`` at the switch level to hand the
    first replicated operator its Galerkin payload.
    """
    nbr = len(indptr) - 1
    rows = np.repeat(np.arange(nbr), np.diff(indptr))
    nnz_starts = indptr[part.starts]
    owner = part.owner_of(rows)
    local = np.arange(len(rows), dtype=np.int64) - nnz_starts[owner]
    return owner * pad + local


# ---------------------------------------------------------------------------
# Distributed padded-ELL operator (SpMV over slabs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistEll:
    """Per-rank stacked ELL operator: rows sharded, x gathered via halo.

    ``indices`` address the *halo window* of the input vector.  Values come
    either from a constant payload baked at build time (``data``; P and R
    under the reuse model) or are gathered from the rank's runtime payload
    slab (``gather`` into A values).

    The build-time **interior/boundary row split** (the overlap lever):
    ``int_mask`` marks, per rank, the slab rows whose every masked ELL
    column lives inside the local slab (interior — no communication
    needed); the rest read the halo window (boundary).
    ``indices_local`` carries the same plan re-addressed in slab-local
    coordinates (valid on interior rows; boundary/masked entries park at
    slot 0), so ``dist_ell_apply_interior`` gathers straight from the
    rank's own vector while the exchange is in flight.  Both split
    applies run at the *full* ``(rpad, ...)`` slab shape and
    ``combine_split`` selects per row — shape-identical contractions are
    what makes each row's result bitwise the blocking one (a
    subset-shaped einsum may lower with a different reduction strategy
    and drift by an ULP); the discarded half of each dual apply is the
    flop price of hiding the exchange.
    """

    halo: Halo
    indices: np.ndarray                 # (ndev, rpad, kmax) int32 window ids
    gather: Optional[np.ndarray]        # (ndev, rpad, kmax) into payload slab
    data: Optional[np.ndarray]          # (ndev, rpad, kmax, br, bc) constant
    rpad: int
    kmax: int
    br: int
    bc: int
    indices_local: Optional[np.ndarray] = None  # (ndev, rpad, kmax) slab ids
    int_mask: Optional[np.ndarray] = None       # (ndev, rpad) interior rows
    int_counts: Optional[np.ndarray] = None     # (ndev,) interior rows/rank
    bnd_counts: Optional[np.ndarray] = None     # (ndev,) boundary rows/rank


def build_dist_ell(A: BlockCSR, row_part: RowPartition,
                   col_part: RowPartition, *,
                   payload_pad: Optional[int] = None,
                   const_data: Optional[np.ndarray] = None,
                   replicated_cols: bool = False) -> DistEll:
    """Host: shard a BlockCSR's padded-ELL form over row slabs.

    Exactly one of ``payload_pad`` (runtime values, gather map into the
    rank's padded nnz slab whose last slot is zero) or ``const_data``
    (global (nnzb, br, bc) numpy payloads baked per rank) must be given.

    ``replicated_cols=True`` declares the input vector *replicated* (an
    agglomerated level's global correction): indices stay global block
    coordinates, the halo is ``"replicated"`` (identity window, zero
    traffic).  Only meaningful with ``const_data`` (the boundary
    prolongator).
    """
    assert (payload_pad is None) != (const_data is None)
    ndev = row_part.ndev
    plan = A.ell_plan()
    nbr, kmax = plan.indices.shape
    kmax = max(kmax, 1)
    idx = np.zeros((nbr, kmax), np.int64)
    msk = np.zeros((nbr, kmax), bool)
    gat = np.zeros((nbr, kmax), np.int64)
    idx[:, :plan.indices.shape[1]] = plan.indices
    msk[:, :plan.mask.shape[1]] = plan.mask
    gat[:, :plan.gather.shape[1]] = plan.gather
    if replicated_cols:
        assert const_data is not None, \
            "replicated_cols needs a constant payload"
        halo = Halo(0, "replicated", A.nbc, ndev)
        owner = np.zeros_like(idx)
    else:
        rank_of_row = row_part.owner_of(np.arange(nbr))
        owner = col_part.owner_of(idx)
        dist = np.abs(np.where(msk, owner - rank_of_row[:, None], 0))
        width = int(dist.max()) if dist.size else 0
        halo = make_halo(width, col_part.max_count, ndev)
    rpad = max(row_part.max_count, 1)
    col_local = idx - col_part.starts[owner]

    indices = np.zeros((ndev, rpad, kmax), np.int32)
    indices_local = np.zeros((ndev, rpad, kmax), np.int32)
    gather = np.zeros((ndev, rpad, kmax), np.int64)
    data = (np.zeros((ndev, rpad, kmax) + const_data.shape[1:],
                     const_data.dtype) if const_data is not None else None)
    nnz_starts = A.indptr[row_part.starts]
    int_mask = np.zeros((ndev, rpad), bool)
    for r in range(ndev):
        sl = row_part.slab(r)
        cnt = sl.stop - sl.start
        coords = window_coords(halo, owner[sl], col_local[sl], r)
        coords = np.where(msk[sl], coords, center_coord(halo, r))
        indices[r, :cnt] = coords
        indices[r, cnt:] = center_coord(halo, r)
        # interior/boundary split: a row is interior iff every masked
        # entry's column owner is this rank (replicated windows move no
        # bytes — every row is interior by construction).  The local
        # re-addressing gathers from the rank's own slab; entries that are
        # masked out or remote park at slot 0 (zero operand either way).
        if halo.strategy == "replicated":
            is_local = np.ones((cnt, kmax), bool)
            indices_local[r, :cnt] = coords
        else:
            is_local = owner[sl] == r
            indices_local[r, :cnt] = np.where(msk[sl] & is_local,
                                              col_local[sl], 0)
        # padding rows (cnt..rpad) count as interior: their plan gathers
        # slot 0 with a zero operand on both paths, so either side of the
        # select is the same 0.0
        int_mask[r, :cnt] = np.where(msk[sl], is_local, True).all(axis=1)
        int_mask[r, cnt:] = True
        if const_data is not None:
            blocks = const_data[gat[sl]] * msk[sl, :, None, None]
            data[r, :cnt] = blocks
        else:
            loc = np.where(msk[sl], gat[sl] - nnz_starts[r], payload_pad - 1)
            gather[r, :cnt] = loc
            gather[r, cnt:] = payload_pad - 1
    counts = row_part.counts
    real = np.arange(rpad)[None, :] < counts[:, None]
    int_counts = (int_mask & real).sum(axis=1)
    return DistEll(halo=halo, indices=indices,
                   gather=gather if const_data is None else None,
                   data=data, rpad=rpad, kmax=kmax, br=A.br, bc=A.bc,
                   indices_local=indices_local, int_mask=int_mask,
                   int_counts=int_counts,
                   bnd_counts=counts - int_counts)


def dist_ell_apply(indices: Array, data: Array, x_win: Array,
                   accum_dtype=None) -> Array:
    """Device per-rank SpMV/SpMM: (rpad, kmax, br, bc) x window -> (rpad, br).

    ``x_win`` may carry a trailing panel axis ``(win, bc, k)`` (multi-RHS
    slabs); the ellipsis broadcasts it, mirroring ``core.spmv.spmm_ell``.
    ``accum_dtype`` is the contraction accumulator for reduced-precision
    slabs (None = native in ``data.dtype``; output always at
    ``data.dtype``).  Note the *halo exchange itself* is dtype-agnostic:
    ``halo_window`` moves whatever width the slab carries, so a
    reduced-precision hierarchy halves the ppermute payload for free.
    """
    g = x_win[indices]                       # (rpad, kmax, bc[, k])
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    return jnp.einsum("rkab,rkb...->ra...", data.astype(acc), g.astype(acc),
                      preferred_element_type=acc).astype(data.dtype)


def dist_ell_apply_interior(indices_local: Array, data: Array, x: Array,
                            accum_dtype=None) -> Array:
    """Device: the interior partition of the split SpMV — no communication.

    Contracts the full slab against the rank's *own* vector
    (``indices_local`` addresses ``x`` directly, no window); runs while
    the halo exchange started by ``start_halo_exchange`` is still in
    flight.  The contraction is ``dist_ell_apply`` itself at the
    identical ``(rpad, ...)`` shape — the local slab sits verbatim inside
    the window, so each *interior* row's result is bitwise the blocking
    one; boundary rows compute a throwaway value off the parked slot-0
    operands that ``combine_split`` discards.
    """
    return dist_ell_apply(indices_local, data, x, accum_dtype=accum_dtype)


def dist_ell_apply_boundary(indices: Array, data: Array, x_win: Array,
                            accum_dtype=None) -> Array:
    """Device: the boundary partition — consumes the finished halo window.

    Literally ``dist_ell_apply`` on the window (so every row's result is
    the blocking one); called after ``finish_halo_exchange``, which is
    where the ``"halo"`` fault site fires — an injected fault corrupts
    exactly what the boundary rows read.  ``combine_split`` keeps only
    the boundary rows from this partial.
    """
    return dist_ell_apply(indices, data, x_win, accum_dtype=accum_dtype)


def combine_split(int_mask: Array, y_int: Array, y_bnd: Array) -> Array:
    """Device: per-row select between the two split partials.

    Interior rows take the exchange-free partial, boundary rows the
    window-fed one.  Both partials were computed at the full slab shape,
    so the selected value per row is bitwise the blocking apply's; the
    discarded lane of each row is the redundant-flop price of the
    overlap.  Padding rows are marked interior and both lanes agree at
    ``0.0`` for them.
    """
    m = int_mask.reshape(int_mask.shape + (1,) * (y_int.ndim - 1))
    return jnp.where(m, y_int, y_bnd)


# ---------------------------------------------------------------------------
# Distributed SpGEMM pair stages (the two Galerkin products)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistPairStage:
    """One rank-sharded numeric SpGEMM stage (pairs -> segment-sum).

    Mirrors ``SpGEMMPlan``'s sorted pair list, restricted to the pairs whose
    output block this rank owns (a contiguous range, since pairs are sorted
    by output slot and slots by row).  The lhs operand is always local —
    A payloads for A@P, the constant R blocks for R@(AP); the rhs is either
    the build-time-cached P_oth blocks (A@P: zero hot communication) or a
    halo window over the AP payload slabs (the off-process reduction).
    """

    halo: Optional[Halo]                # over rhs payload slabs (None=const)
    lhs_gather: Optional[np.ndarray]    # (ndev, ppad) into lhs payload slab
    lhs_data: Optional[np.ndarray]      # (ndev, ppad, br, bk) constant
    rhs_gather: Optional[np.ndarray]    # (ndev, ppad) into rhs window
    rhs_data: Optional[np.ndarray]      # (ndev, ppad, bk, bc) constant
    seg: np.ndarray                     # (ndev, ppad) int32 sorted out slots
    out_pad: int                        # output slab length (max nnz + 1)
    ppad: int
    # pair-level interior/boundary split of the windowed stage (stage 2):
    # pairs whose rhs payload block is rank-local vs pairs reading the
    # exchanged window.  Both renderings of the pair products run at the
    # full ``(ppad, ...)`` shape (one off the local slab, one off the
    # finished window) and ``jnp.where(local_mask, ...)`` selects per
    # pair, then the *same* sorted segment-sum runs — identical products,
    # identical reduction order, bitwise the blocking stage.  None on the
    # windowless stage 1.
    local_mask: Optional[np.ndarray] = None   # (ndev, ppad) local pairs
    rhs_local: Optional[np.ndarray] = None    # (ndev, ppad) into local slab
    local_counts: Optional[np.ndarray] = None  # (ndev,)
    bnd_counts: Optional[np.ndarray] = None    # (ndev,)


def _pair_ranges(plan: SpGEMMPlan, out_part: RowPartition):
    """Per-rank contiguous [lo, hi) into the sorted pair list + slot base."""
    slot_rows = np.repeat(np.arange(plan.nbr), np.diff(plan.indptr))
    pair_rows = slot_rows[plan.out_idx]
    pair_lo = np.searchsorted(pair_rows, out_part.starts[:-1], side="left")
    pair_hi = np.searchsorted(pair_rows, out_part.starts[1:] - 1,
                              side="right")
    slot_base = plan.indptr[out_part.starts]
    return pair_lo, pair_hi, slot_base


def build_stage1(ap_plan: SpGEMMPlan, fine_part: RowPartition,
                 a_indptr: np.ndarray, p_data: np.ndarray) -> DistPairStage:
    """A @ P with rank-cached P_oth: lhs gathered from the A slab, rhs
    constant (the stacked P blocks each rank's pairs touch, local or not)."""
    ndev = fine_part.ndev
    lo, hi, slot_base = _pair_ranges(ap_plan, fine_part)
    counts = hi - lo
    ppad = max(int(counts.max()), 1)
    a_nnz_starts = a_indptr[fine_part.starts]
    out_counts = slot_base[1:] - slot_base[:-1]
    out_pad = int(out_counts.max()) + 1
    lhs_gather = np.zeros((ndev, ppad), np.int64)
    rhs_data = np.zeros((ndev, ppad) + p_data.shape[1:], p_data.dtype)
    seg = np.full((ndev, ppad), out_pad - 1, np.int32)
    for r in range(ndev):
        s = slice(int(lo[r]), int(hi[r]))
        cnt = s.stop - s.start
        lhs_gather[r, :cnt] = ap_plan.pair_a[s] - a_nnz_starts[r]
        rhs_data[r, :cnt] = p_data[ap_plan.pair_b[s]]
        seg[r, :cnt] = ap_plan.out_idx[s] - slot_base[r]
    return DistPairStage(halo=None, lhs_gather=lhs_gather, lhs_data=None,
                         rhs_gather=None, rhs_data=rhs_data, seg=seg,
                         out_pad=out_pad, ppad=ppad)


def build_stage2(ac_plan: SpGEMMPlan, coarse_part: RowPartition,
                 fine_part: RowPartition, ap_indptr: np.ndarray,
                 ap_pad: int, p_data: np.ndarray, r_perm: np.ndarray
                 ) -> DistPairStage:
    """R @ (A P): lhs constant (R blocks from the fixed prolongator), rhs
    gathered from the halo window over the AP payload slabs — the
    off-process reduction of the distributed PtAP."""
    ndev = coarse_part.ndev
    r_data = p_data[r_perm].transpose(0, 2, 1)
    lo, hi, slot_base = _pair_ranges(ac_plan, coarse_part)
    counts = hi - lo
    ppad = max(int(counts.max()), 1)
    out_counts = slot_base[1:] - slot_base[:-1]
    out_pad = int(out_counts.max()) + 1
    # AP nnz -> (fine owner, slab-local offset)
    nbr_f = len(ap_indptr) - 1
    ap_rows = np.repeat(np.arange(nbr_f), np.diff(ap_indptr))
    ap_nnz_starts = ap_indptr[fine_part.starts]
    owner = fine_part.owner_of(ap_rows)
    local = np.arange(len(ap_rows), dtype=np.int64) - ap_nnz_starts[owner]
    # the per-rank ranges tile [0, npairs) contiguously, so rank_of_pair
    # aligns with the sorted pair list as-is
    rank_of_pair = np.repeat(np.arange(ndev), counts)
    width = 0
    if len(rank_of_pair):
        width = int(np.abs(owner[ac_plan.pair_b] - rank_of_pair).max())
    halo = make_halo(width, ap_pad, ndev)
    lhs_data = np.zeros((ndev, ppad) + r_data.shape[1:], r_data.dtype)
    rhs_gather = np.zeros((ndev, ppad), np.int64)
    rhs_local = np.zeros((ndev, ppad), np.int64)
    seg = np.full((ndev, ppad), out_pad - 1, np.int32)
    # padded pairs select the window lane (local=False): the full-shape
    # boundary product is literally the blocking product for every pair,
    # padded ones included (zero lhs block x the parked center slot)
    local_mask = np.zeros((ndev, ppad), bool)
    for r in range(ndev):
        s = slice(int(lo[r]), int(hi[r]))
        cnt = s.stop - s.start
        lhs_data[r, :cnt] = r_data[ac_plan.pair_a[s]]
        pb = ac_plan.pair_b[s]
        rhs_gather[r, :cnt] = window_coords(halo, owner[pb], local[pb], r)
        rhs_gather[r, cnt:] = center_coord(halo, r)
        seg[r, :cnt] = ac_plan.out_idx[s] - slot_base[r]
        # pair split: a pair is local iff its rhs AP block lives in this
        # rank's payload slab (replicated/local halos: everything local)
        is_local = (np.ones(cnt, bool)
                    if halo.strategy in ("local", "replicated")
                    else owner[pb] == r)
        rhs_local[r, :cnt] = np.where(is_local, local[pb], 0)
        local_mask[r, :cnt] = is_local
    local_counts = local_mask.sum(axis=1)
    return DistPairStage(halo=halo, lhs_gather=None, lhs_data=lhs_data,
                         rhs_gather=rhs_gather, rhs_data=None, seg=seg,
                         out_pad=out_pad, ppad=ppad,
                         local_mask=local_mask, rhs_local=rhs_local,
                         local_counts=local_counts,
                         bnd_counts=(hi - lo) - local_counts)


def dist_stage_apply(lhs: Array, rhs: Array, seg: Array, out_pad: int,
                     accum_dtype=None) -> Array:
    """Device per-rank numeric stage: pair products + sorted segment-sum.

    Padded pairs carry a zero operand on one side, so they add exactly 0.0
    into the (guaranteed-zero) last output slot.  ``accum_dtype`` is the
    contract/reduce accumulator for reduced-precision payload slabs (None
    = native; output at ``lhs.dtype``).
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else lhs.dtype
    prod = jnp.einsum("pij,pjk->pik", lhs.astype(acc), rhs.astype(acc),
                      preferred_element_type=acc)
    return jax.ops.segment_sum(prod, seg, num_segments=out_pad,
                               indices_are_sorted=True).astype(lhs.dtype)


def dist_stage_apply_overlap(lhs: Array, rhs_slab: Array, halo: Halo,
                             rhs_gather: Array, rhs_local: Array,
                             local_mask: Array, seg: Array, out_pad: int,
                             accum_dtype=None) -> Array:
    """Device: the overlapped rendering of the stage-2 off-process reduce.

    Pair products are elementwise, so splitting them needs no summation
    surgery: start the window exchange over the rhs payload slabs, form
    the products straight from the rank's own slab (``rhs_local``) while
    the ppermutes fly, finish the window, form them again from it, select
    per pair (``combine_split`` on the pair axis — local pairs gathered
    identical rhs blocks from the slab, boundary pairs need the window)
    and run the *same* sorted segment-sum as ``dist_stage_apply``.  Both
    product einsums run at the full ``(ppad, ...)`` shape, so each pair's
    selected product — and hence the reduction — is bitwise the blocking
    stage's.
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else lhs.dtype
    pend = start_halo_exchange(rhs_slab, halo)
    prod_loc = jnp.einsum("pij,pjk->pik", lhs.astype(acc),
                          rhs_slab[rhs_local].astype(acc),
                          preferred_element_type=acc)
    win = finish_halo_exchange(pend)
    prod_bnd = jnp.einsum("pij,pjk->pik", lhs.astype(acc),
                          win[rhs_gather].astype(acc),
                          preferred_element_type=acc)
    prod = combine_split(local_mask, prod_loc, prod_bnd)
    return jax.ops.segment_sum(prod, seg, num_segments=out_pad,
                               indices_are_sorted=True).astype(lhs.dtype)


def build_diag_sel(indptr: np.ndarray, indices: np.ndarray,
                   part: RowPartition, payload_pad: int):
    """Host: per-rank gather of the diagonal blocks from the payload slab.

    Returns ``(sel, mask)`` stacked ``(ndev, rpad)``; rows without a stored
    diagonal (or padding rows) select the zero slot and are masked so the
    smoother substitutes the identity before inversion.
    """
    ndev = part.ndev
    nbr = len(indptr) - 1
    rows = np.repeat(np.arange(nbr), np.diff(indptr))
    is_diag = indices == rows
    sel_global = np.full(nbr, -1, np.int64)
    sel_global[rows[is_diag]] = np.flatnonzero(is_diag)
    nnz_starts = indptr[part.starts]
    rpad = max(part.max_count, 1)
    sel = np.full((ndev, rpad), payload_pad - 1, np.int64)
    mask = np.zeros((ndev, rpad), bool)
    for r in range(ndev):
        sl = part.slab(r)
        cnt = sl.stop - sl.start
        g = sel_global[sl]
        ok = g >= 0
        sel[r, :cnt] = np.where(ok, g - nnz_starts[r], payload_pad - 1)
        mask[r, :cnt] = ok
    return sel, mask
