"""Distributed blocked operators over row slabs — halos, SpMV, and PtAP.

The paper's distributed hot path keeps every operand device-resident and
pre-stages the *communication plan* on the host, once, gated on object
state.  The JAX rendering here follows the same split:

host (cold, this module's ``build_*``)
    Remap every global index into (owner rank, slab-local) coordinates,
    decide the halo pattern, and stack the per-rank plans into
    ``(ndev, ...)`` arrays that ``shard_map`` splits over the rank axis.
    Constant operands — the prolongator payloads, including the off-process
    rows **P_oth** — are pre-gathered per rank at build time (the paper's
    cached stacked operand), so the hot PtAP does *zero* communication for
    P.

device (hot, the ``*_apply`` / ``halo_window`` functions)
    Pure per-rank functions used inside ``shard_map``.  The only
    communication is (a) vector halo windows for SpMV and (b) the
    off-process reduction window over the A·P payload slabs in the second
    Galerkin stage — both neighbor ``lax.ppermute`` slab exchanges on
    mesh-ordered problems (``Halo.strategy == "ppermute"``), with an
    ``all_gather`` fallback when a plan's reach exceeds the neighbor
    window.

Agglomerated (replicated) coarse levels add a third input layout: when the
placement policy in ``repro.dist.solver`` takes a level off the sharded
path, its operands live *replicated* on every rank and operator applies do
zero communication.  Two pieces here support that:

* ``Halo.strategy == "replicated"`` — the input vector is already global,
  ``halo_window`` is the identity and plan indices are plain global block
  coordinates (``build_dist_ell(..., replicated_cols=True)`` emits them).
  Used by the boundary prolongator that re-slices the replicated coarse
  correction back into row slabs.
* the **gather-boundary plans** ``build_row_gather`` /
  ``build_payload_gather`` — window ids that reassemble a global vector /
  payload array from one ``all_gather`` of the padded per-rank slabs.  The
  switch level crosses the sharded->replicated boundary with exactly one
  such gather per V-cycle (restriction) and one per recompute (the
  Galerkin payload of the first replicated operator).

Padding discipline (what keeps the padded lanes exact):
    every payload slab is padded to ``max_count + 1`` so its last slot is
    guaranteed zero; padded plan entries either gather that zero slot or
    carry a zero *constant* operand, so they contribute exactly ``0.0``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.block_csr import BlockCSR
from repro.core.spgemm import SpGEMMPlan
from repro.dist.partition import RowPartition
from repro.robust import inject

Array = jax.Array

AXIS = "rank"


# ---------------------------------------------------------------------------
# Halo windows
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Halo:
    """Exchange pattern for one sharded operand axis.

    ``"replicated"`` marks an operand whose input vector is already global
    on every rank (an agglomerated level's correction): the window is the
    vector itself and no exchange happens — the all-gather that made it
    global is accounted at the switch boundary, not here.
    """

    width: int       # neighbor hops each side (0 = purely local)
    strategy: str    # "local" | "ppermute" | "allgather" | "replicated"
    cpad: int        # padded slab length of the exchanged axis
    ndev: int

    @property
    def window_len(self) -> int:
        if self.strategy == "allgather":
            return self.cpad * self.ndev
        if self.strategy == "replicated":
            return self.cpad
        return self.cpad * (2 * self.width + 1)

    @property
    def exchanged_slabs(self) -> int:
        """Slabs moved per rank per exchange (the halo traffic unit)."""
        if self.strategy in ("local", "replicated"):
            return 0
        return (self.ndev - 1 if self.strategy == "allgather"
                else 2 * self.width)


def make_halo(width: int, cpad: int, ndev: int) -> Halo:
    if width == 0 or ndev == 1:
        return Halo(0, "local", cpad, ndev)
    # neighbor windows beat allgather strictly below (ndev-1)/2 hops: at
    # 2w == ndev the (2w+1)-slab window already exceeds the ndev-slab one
    if width <= max(1, (ndev - 1) // 2):
        return Halo(width, "ppermute", cpad, ndev)
    return Halo(width, "allgather", cpad, ndev)


def window_coords(halo: Halo, owner: np.ndarray, local: np.ndarray,
                  rank: int) -> np.ndarray:
    """Host: window coordinate of (owner, slab-local) seen from ``rank``."""
    if halo.strategy == "replicated":
        return local                     # the window IS the global vector
    if halo.strategy == "allgather":
        return owner * halo.cpad + local
    return (owner - rank + halo.width) * halo.cpad + local


def center_coord(halo: Halo, rank: int) -> int:
    """A always-valid in-window coordinate for padded plan entries."""
    if halo.strategy == "replicated":
        return 0
    if halo.strategy == "allgather":
        return rank * halo.cpad
    return halo.width * halo.cpad


def halo_window(x: Array, halo: Halo) -> Array:
    """Device (inside shard_map): build the halo window of a sharded slab.

    ``x`` is this rank's padded slab ``(cpad, ...)``; the result stacks the
    neighbor slabs ``[-w..w]`` (ppermute), everything (allgather), or is
    ``x`` itself (local).  Edge ranks receive zero slabs, which padded plan
    entries never address.
    """
    if halo.strategy in ("local", "replicated"):
        return x
    # "halo" fault-injection site: corrupts the *communicated* window
    # payload (trace-time identity unless a schedule is installed —
    # repro.robust.inject); local/replicated strategies move no bytes and
    # are exempt by construction.
    if halo.strategy == "allgather":
        return inject.maybe(
            "halo", lax.all_gather(x, AXIS, axis=0, tiled=True))
    parts = []
    for d in range(-halo.width, halo.width + 1):
        if d == 0:
            parts.append(x)
            continue
        # rank r receives slab r + d  <=>  src i sends to dst i - d
        perm = [(i, i - d) for i in range(halo.ndev)
                if 0 <= i - d < halo.ndev]
        parts.append(lax.ppermute(x, AXIS, perm))
    return inject.maybe("halo", jnp.concatenate(parts, axis=0))


# ---------------------------------------------------------------------------
# Gather-boundary plans (the sharded -> replicated switch)
# ---------------------------------------------------------------------------

def build_row_gather(part: RowPartition, pad: int) -> np.ndarray:
    """Host: window id of every global block row in an all-gathered stack.

    ``lax.all_gather(slab, tiled=True)`` of per-rank ``(pad, ...)`` slabs
    yields ``(ndev*pad, ...)``; indexing it with the returned ``(nrows,)``
    map reassembles the *global* unpadded vector — the one all-gather an
    agglomerated level costs per V-cycle.
    """
    rows = np.arange(part.nrows)
    owner = part.owner_of(rows)
    return owner * pad + (rows - part.starts[owner])


def build_payload_gather(indptr: np.ndarray, part: RowPartition,
                         pad: int) -> np.ndarray:
    """Host: window ids reassembling a global ``(nnzb, ...)`` payload from
    all-gathered per-rank payload slabs (slab r holds the nnz of r's rows,
    padded to ``pad``).  The recompute-side twin of ``build_row_gather`` —
    used once per ``_rank_recompute`` at the switch level to hand the
    first replicated operator its Galerkin payload.
    """
    nbr = len(indptr) - 1
    rows = np.repeat(np.arange(nbr), np.diff(indptr))
    nnz_starts = indptr[part.starts]
    owner = part.owner_of(rows)
    local = np.arange(len(rows), dtype=np.int64) - nnz_starts[owner]
    return owner * pad + local


# ---------------------------------------------------------------------------
# Distributed padded-ELL operator (SpMV over slabs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistEll:
    """Per-rank stacked ELL operator: rows sharded, x gathered via halo.

    ``indices`` address the *halo window* of the input vector.  Values come
    either from a constant payload baked at build time (``data``; P and R
    under the reuse model) or are gathered from the rank's runtime payload
    slab (``gather`` into A values).
    """

    halo: Halo
    indices: np.ndarray                 # (ndev, rpad, kmax) int32 window ids
    gather: Optional[np.ndarray]        # (ndev, rpad, kmax) into payload slab
    data: Optional[np.ndarray]          # (ndev, rpad, kmax, br, bc) constant
    rpad: int
    kmax: int
    br: int
    bc: int


def build_dist_ell(A: BlockCSR, row_part: RowPartition,
                   col_part: RowPartition, *,
                   payload_pad: Optional[int] = None,
                   const_data: Optional[np.ndarray] = None,
                   replicated_cols: bool = False) -> DistEll:
    """Host: shard a BlockCSR's padded-ELL form over row slabs.

    Exactly one of ``payload_pad`` (runtime values, gather map into the
    rank's padded nnz slab whose last slot is zero) or ``const_data``
    (global (nnzb, br, bc) numpy payloads baked per rank) must be given.

    ``replicated_cols=True`` declares the input vector *replicated* (an
    agglomerated level's global correction): indices stay global block
    coordinates, the halo is ``"replicated"`` (identity window, zero
    traffic).  Only meaningful with ``const_data`` (the boundary
    prolongator).
    """
    assert (payload_pad is None) != (const_data is None)
    ndev = row_part.ndev
    plan = A.ell_plan()
    nbr, kmax = plan.indices.shape
    kmax = max(kmax, 1)
    idx = np.zeros((nbr, kmax), np.int64)
    msk = np.zeros((nbr, kmax), bool)
    gat = np.zeros((nbr, kmax), np.int64)
    idx[:, :plan.indices.shape[1]] = plan.indices
    msk[:, :plan.mask.shape[1]] = plan.mask
    gat[:, :plan.gather.shape[1]] = plan.gather
    if replicated_cols:
        assert const_data is not None, \
            "replicated_cols needs a constant payload"
        halo = Halo(0, "replicated", A.nbc, ndev)
        owner = np.zeros_like(idx)
    else:
        rank_of_row = row_part.owner_of(np.arange(nbr))
        owner = col_part.owner_of(idx)
        dist = np.abs(np.where(msk, owner - rank_of_row[:, None], 0))
        width = int(dist.max()) if dist.size else 0
        halo = make_halo(width, col_part.max_count, ndev)
    rpad = max(row_part.max_count, 1)
    col_local = idx - col_part.starts[owner]

    indices = np.zeros((ndev, rpad, kmax), np.int32)
    gather = np.zeros((ndev, rpad, kmax), np.int64)
    data = (np.zeros((ndev, rpad, kmax) + const_data.shape[1:],
                     const_data.dtype) if const_data is not None else None)
    nnz_starts = A.indptr[row_part.starts]
    for r in range(ndev):
        sl = row_part.slab(r)
        cnt = sl.stop - sl.start
        coords = window_coords(halo, owner[sl], col_local[sl], r)
        coords = np.where(msk[sl], coords, center_coord(halo, r))
        indices[r, :cnt] = coords
        indices[r, cnt:] = center_coord(halo, r)
        if const_data is not None:
            blocks = const_data[gat[sl]] * msk[sl, :, None, None]
            data[r, :cnt] = blocks
        else:
            loc = np.where(msk[sl], gat[sl] - nnz_starts[r], payload_pad - 1)
            gather[r, :cnt] = loc
            gather[r, cnt:] = payload_pad - 1
    return DistEll(halo=halo, indices=indices,
                   gather=gather if const_data is None else None,
                   data=data, rpad=rpad, kmax=kmax, br=A.br, bc=A.bc)


def dist_ell_apply(indices: Array, data: Array, x_win: Array,
                   accum_dtype=None) -> Array:
    """Device per-rank SpMV/SpMM: (rpad, kmax, br, bc) x window -> (rpad, br).

    ``x_win`` may carry a trailing panel axis ``(win, bc, k)`` (multi-RHS
    slabs); the ellipsis broadcasts it, mirroring ``core.spmv.spmm_ell``.
    ``accum_dtype`` is the contraction accumulator for reduced-precision
    slabs (None = native in ``data.dtype``; output always at
    ``data.dtype``).  Note the *halo exchange itself* is dtype-agnostic:
    ``halo_window`` moves whatever width the slab carries, so a
    reduced-precision hierarchy halves the ppermute payload for free.
    """
    g = x_win[indices]                       # (rpad, kmax, bc[, k])
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else data.dtype
    return jnp.einsum("rkab,rkb...->ra...", data.astype(acc), g.astype(acc),
                      preferred_element_type=acc).astype(data.dtype)


# ---------------------------------------------------------------------------
# Distributed SpGEMM pair stages (the two Galerkin products)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistPairStage:
    """One rank-sharded numeric SpGEMM stage (pairs -> segment-sum).

    Mirrors ``SpGEMMPlan``'s sorted pair list, restricted to the pairs whose
    output block this rank owns (a contiguous range, since pairs are sorted
    by output slot and slots by row).  The lhs operand is always local —
    A payloads for A@P, the constant R blocks for R@(AP); the rhs is either
    the build-time-cached P_oth blocks (A@P: zero hot communication) or a
    halo window over the AP payload slabs (the off-process reduction).
    """

    halo: Optional[Halo]                # over rhs payload slabs (None=const)
    lhs_gather: Optional[np.ndarray]    # (ndev, ppad) into lhs payload slab
    lhs_data: Optional[np.ndarray]      # (ndev, ppad, br, bk) constant
    rhs_gather: Optional[np.ndarray]    # (ndev, ppad) into rhs window
    rhs_data: Optional[np.ndarray]      # (ndev, ppad, bk, bc) constant
    seg: np.ndarray                     # (ndev, ppad) int32 sorted out slots
    out_pad: int                        # output slab length (max nnz + 1)
    ppad: int


def _pair_ranges(plan: SpGEMMPlan, out_part: RowPartition):
    """Per-rank contiguous [lo, hi) into the sorted pair list + slot base."""
    slot_rows = np.repeat(np.arange(plan.nbr), np.diff(plan.indptr))
    pair_rows = slot_rows[plan.out_idx]
    pair_lo = np.searchsorted(pair_rows, out_part.starts[:-1], side="left")
    pair_hi = np.searchsorted(pair_rows, out_part.starts[1:] - 1,
                              side="right")
    slot_base = plan.indptr[out_part.starts]
    return pair_lo, pair_hi, slot_base


def build_stage1(ap_plan: SpGEMMPlan, fine_part: RowPartition,
                 a_indptr: np.ndarray, p_data: np.ndarray) -> DistPairStage:
    """A @ P with rank-cached P_oth: lhs gathered from the A slab, rhs
    constant (the stacked P blocks each rank's pairs touch, local or not)."""
    ndev = fine_part.ndev
    lo, hi, slot_base = _pair_ranges(ap_plan, fine_part)
    counts = hi - lo
    ppad = max(int(counts.max()), 1)
    a_nnz_starts = a_indptr[fine_part.starts]
    out_counts = slot_base[1:] - slot_base[:-1]
    out_pad = int(out_counts.max()) + 1
    lhs_gather = np.zeros((ndev, ppad), np.int64)
    rhs_data = np.zeros((ndev, ppad) + p_data.shape[1:], p_data.dtype)
    seg = np.full((ndev, ppad), out_pad - 1, np.int32)
    for r in range(ndev):
        s = slice(int(lo[r]), int(hi[r]))
        cnt = s.stop - s.start
        lhs_gather[r, :cnt] = ap_plan.pair_a[s] - a_nnz_starts[r]
        rhs_data[r, :cnt] = p_data[ap_plan.pair_b[s]]
        seg[r, :cnt] = ap_plan.out_idx[s] - slot_base[r]
    return DistPairStage(halo=None, lhs_gather=lhs_gather, lhs_data=None,
                         rhs_gather=None, rhs_data=rhs_data, seg=seg,
                         out_pad=out_pad, ppad=ppad)


def build_stage2(ac_plan: SpGEMMPlan, coarse_part: RowPartition,
                 fine_part: RowPartition, ap_indptr: np.ndarray,
                 ap_pad: int, p_data: np.ndarray, r_perm: np.ndarray
                 ) -> DistPairStage:
    """R @ (A P): lhs constant (R blocks from the fixed prolongator), rhs
    gathered from the halo window over the AP payload slabs — the
    off-process reduction of the distributed PtAP."""
    ndev = coarse_part.ndev
    r_data = p_data[r_perm].transpose(0, 2, 1)
    lo, hi, slot_base = _pair_ranges(ac_plan, coarse_part)
    counts = hi - lo
    ppad = max(int(counts.max()), 1)
    out_counts = slot_base[1:] - slot_base[:-1]
    out_pad = int(out_counts.max()) + 1
    # AP nnz -> (fine owner, slab-local offset)
    nbr_f = len(ap_indptr) - 1
    ap_rows = np.repeat(np.arange(nbr_f), np.diff(ap_indptr))
    ap_nnz_starts = ap_indptr[fine_part.starts]
    owner = fine_part.owner_of(ap_rows)
    local = np.arange(len(ap_rows), dtype=np.int64) - ap_nnz_starts[owner]
    # the per-rank ranges tile [0, npairs) contiguously, so rank_of_pair
    # aligns with the sorted pair list as-is
    rank_of_pair = np.repeat(np.arange(ndev), counts)
    width = 0
    if len(rank_of_pair):
        width = int(np.abs(owner[ac_plan.pair_b] - rank_of_pair).max())
    halo = make_halo(width, ap_pad, ndev)
    lhs_data = np.zeros((ndev, ppad) + r_data.shape[1:], r_data.dtype)
    rhs_gather = np.zeros((ndev, ppad), np.int64)
    seg = np.full((ndev, ppad), out_pad - 1, np.int32)
    for r in range(ndev):
        s = slice(int(lo[r]), int(hi[r]))
        cnt = s.stop - s.start
        lhs_data[r, :cnt] = r_data[ac_plan.pair_a[s]]
        pb = ac_plan.pair_b[s]
        rhs_gather[r, :cnt] = window_coords(halo, owner[pb], local[pb], r)
        rhs_gather[r, cnt:] = center_coord(halo, r)
        seg[r, :cnt] = ac_plan.out_idx[s] - slot_base[r]
    return DistPairStage(halo=halo, lhs_gather=None, lhs_data=lhs_data,
                         rhs_gather=rhs_gather, rhs_data=None, seg=seg,
                         out_pad=out_pad, ppad=ppad)


def dist_stage_apply(lhs: Array, rhs: Array, seg: Array, out_pad: int,
                     accum_dtype=None) -> Array:
    """Device per-rank numeric stage: pair products + sorted segment-sum.

    Padded pairs carry a zero operand on one side, so they add exactly 0.0
    into the (guaranteed-zero) last output slot.  ``accum_dtype`` is the
    contract/reduce accumulator for reduced-precision payload slabs (None
    = native; output at ``lhs.dtype``).
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else lhs.dtype
    prod = jnp.einsum("pij,pjk->pik", lhs.astype(acc), rhs.astype(acc),
                      preferred_element_type=acc)
    return jax.ops.segment_sum(prod, seg, num_segments=out_pad,
                               indices_are_sorted=True).astype(lhs.dtype)


def build_diag_sel(indptr: np.ndarray, indices: np.ndarray,
                   part: RowPartition, payload_pad: int):
    """Host: per-rank gather of the diagonal blocks from the payload slab.

    Returns ``(sel, mask)`` stacked ``(ndev, rpad)``; rows without a stored
    diagonal (or padding rows) select the zero slot and are masked so the
    smoother substitutes the identity before inversion.
    """
    ndev = part.ndev
    nbr = len(indptr) - 1
    rows = np.repeat(np.arange(nbr), np.diff(indptr))
    is_diag = indices == rows
    sel_global = np.full(nbr, -1, np.int64)
    sel_global[rows[is_diag]] = np.flatnonzero(is_diag)
    nnz_starts = indptr[part.starts]
    rpad = max(part.max_count, 1)
    sel = np.full((ndev, rpad), payload_pad - 1, np.int64)
    mask = np.zeros((ndev, rpad), bool)
    for r in range(ndev):
        sl = part.slab(r)
        cnt = sl.stop - sl.start
        g = sel_global[sl]
        ok = g >= 0
        sel[r, :cnt] = np.where(ok, g - nnz_starts[r], payload_pad - 1)
        mask[r, :cnt] = ok
    return sel, mask
