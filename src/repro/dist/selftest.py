"""Distributed == single-device parity selftest.

Run as a subprocess (``python -m repro.dist.selftest <m>``) with
``REPRO_SELFTEST_NDEV`` ranks faked on the host platform, so the
placeholder-device XLA flag never leaks into the parent process.

Checks, on an m^3 Q1 elasticity problem:

  * the distributed solve converges in the *same iteration count* as the
    single-device ``GAMGSolver`` and to an allclose solution;
  * a hot recompute (scaled operator values, same structure) through the
    *state-gated* path (reusing the staged ``DistGAMG``) matches the
    single-device hot recompute;
  * the *ungated* path (rebuilding the prolongator-side staging from
    scratch, the paper's Table 3 ablation) produces identical results to
    the gated one;
  * the level-0 halo really is the neighbor slab exchange
    (``halo=ppermute``) rather than an allgather fallback;
  * with ``REPRO_SELFTEST_MRHS=1``: a k-column panel through the *same*
    shard_map program (scattered ``(n, k)`` payload -> masked multi-RHS
    PCG) matches the single-device batched solve per column — same
    iteration counts, allclose solutions;
  * with ``REPRO_PRECISION`` set to a reduced policy (e.g. ``f32``): a
    distributed solve on the reduced-precision-resident hierarchy (fp64
    outer CG, boundary casts) still converges to rtol with at most a
    small iteration-count growth over the fp64 reference and an allclose
    solution.  The *parity* sections above always pin ``precision="f64"``
    — exact iteration parity is an fp64 contract, and the env override
    must not silently weaken it.
  * with ``REPRO_SELFTEST_AGG=1``: the **agglomerated placement** — a
    hierarchy with at least one mid level replicated (threshold forced
    high) solves in *exactly* the same iteration count as the
    sharded-only placement of the same setup and as the single-device
    solver, to an allclose solution; with ``REPRO_SELFTEST_MRHS=1`` the
    panel goes through the agglomerated program too (per-column parity).
    The sharded baselines in the sections above pin
    ``coarse_eq_limit=0`` so their coverage of the ppermute paths never
    silently shrinks as placement defaults evolve.
  * with ``REPRO_SELFTEST_COEFF=1``: the **coefficient hot loop** — per-slab
    element coefficient fields scattered through the assembly staging
    (``build_dist_assembly`` / ``DistAssembly.scatter_fields``) and
    assembled rank-locally inside the shard_map program
    (``make_dist_coeff_solver``) match (a) the value-stream path fed the
    globally assembled operator, exactly, and (b) the single-device jitted
    ``update_coefficients -> recompute -> solve`` loop on a heterogeneous
    (two-material inclusion) problem — same iteration count, allclose
    solution — with zero retraces across repeated updates
    (``_cache_size() == 1``, including an f32-typed caller).
  * with ``REPRO_SELFTEST_MARCH=1``: the **warm-started time march over
    the wire** — a 3-step softening-coefficient march through the
    ``warm_start=True`` dist coefficient program (each step's x-output
    slab fed straight back as the next step's x0 slab, no gather/scatter
    round trip) matches the single-device fused march primitive
    (``gamg.make_coeff_solve``) step for step — same iteration counts,
    allclose solutions — with one compiled program for the whole march
    and the warm final step no slower than a cold re-solve.
  * with ``REPRO_SELFTEST_OVERLAP=1``: the **overlap schedule parity** —
    the ``REPRO_OVERLAP=on`` split apply (interior rows while the
    exchange flies, boundary rows off the finished window) solves in
    exactly the same iteration count as the blocking schedule with a
    *bitwise*-identical solution (f64); an apply-level battery pins
    bitwise split-vs-blocking equality across halo strategies
    (``ppermute``/``allgather`` at 4+ ranks/``replicated``), vector and
    panel right-hand sides, f64 and f32 payloads; a jaxpr check pins
    ``REPRO_OVERLAP=off`` residue-free identical to the hand-rolled
    pre-refactor blocking apply; and a ``halo:nan`` fault is detected
    with the *same* status and iteration count under both schedules
    (detection latency unchanged by the overlap).
  * with ``REPRO_SELFTEST_FAULT=1``: the **fault battery over the wire** —
    a NaN planted into the halo-exchange windows (``repro.robust.inject``,
    site ``"halo"``) of a freshly traced program trips the collective
    health flags (not-ok, status != healthy) while the returned best
    iterate stays finite (no silent NaN escapes a rank); an Inf planted
    into the distributed CG's operator apply at a chosen step is flagged
    within one outer iteration; and a clean re-staging afterwards restores
    exact (bitwise) parity with the unfaulted solve.
  * always: the healthy-path status is ``HEALTHY`` on every section's
    solve, and scatter staging dtypes are the *policy's*, not the
    caller's — an f32-cast payload/rhs stages at the same dtype as the
    f64 one (same compiled program, no retrace, no dtype poisoning).

Prints ``OK`` on success (asserts otherwise).
"""
from __future__ import annotations

import os
import sys


def main(m: int) -> int:
    ndev = int(os.environ.get("REPRO_SELFTEST_NDEV", "4"))
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ndev} " + flags)

    import jax
    import numpy as np

    import repro.core  # noqa: F401  (x64 on)
    from repro.core import gamg
    from repro.dist.solver import build_dist_gamg, make_dist_solver
    from repro.fem.assemble import assemble_elasticity

    assert len(jax.devices()) == ndev, (jax.devices(), ndev)
    prob = assemble_elasticity(m)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    assert setupd.levels, \
        (f"m={m} gives only {prob.A.nbr} block rows (< coarse_size=30): "
         f"no AMG levels to distribute — use m >= 4")

    # single-device reference
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                             maxiter=200, precision="f64")
    ref0 = solver.solve(prob.b)

    # distributed: cold staging + hot solve (placement pinned fully
    # sharded — the agglomerated placement is checked against this below)
    mesh = jax.make_mesh((ndev,), ("rank",))
    dg = build_dist_gamg(setupd, ndev, coarse_eq_limit=0)
    args = dg.sharded_args(setupd)
    run = make_dist_solver(dg, setupd, mesh, rtol=1e-8, maxiter=200)
    a0 = dg.scatter_fine_payloads(prob.A.data)
    b = dg.scatter_vector(prob.b)

    # scatter staging is policy-dtyped, never caller-dtyped: an f32-cast
    # update stages identically to the f64 one (no retrace, no poisoning)
    a0_32 = dg.scatter_fine_payloads(np.asarray(prob.A.data, np.float32))
    b_32 = dg.scatter_vector(np.asarray(prob.b, np.float32))
    assert a0_32.dtype == a0.dtype == dg.payload_stage_dtype, \
        (a0_32.dtype, a0.dtype, dg.payload_stage_dtype)
    assert b_32.dtype == b.dtype == setupd.precision.krylov_dtype, \
        (b_32.dtype, b.dtype)
    x, iters, relres, ok, status = jax.block_until_ready(run(args, a0, b))
    x_g = dg.gather_vector(x)
    assert int(status[0]) == 0, f"healthy solve flagged: {status}"

    halo = dg.levels[0].a_op.halo
    widths = [lv.a_op.halo.width for lv in dg.levels]
    print(f"ndev={ndev} m={m} levels={len(dg.levels) + 1} "
          f"halo={halo.strategy} widths={widths} "
          f"s2_halo={[lv.stage2.halo.strategy for lv in dg.levels]}")

    assert bool(ok[0]), (iters, relres)
    assert int(iters[0]) == int(ref0.iters), \
        f"iteration parity: dist={int(iters[0])} single={int(ref0.iters)}"
    np.testing.assert_allclose(x_g, np.asarray(ref0.x), rtol=1e-6,
                               atol=1e-9)
    print(f"cold solve parity: iters={int(iters[0])} "
          f"relres={float(relres[0]):.3e}")

    # hot recompute: new values, same structure (the state-gated path)
    a_new = prob.A.data * 1.5
    solver.update_operator(a_new)
    ref1 = solver.solve(prob.b)
    x1, it1, rr1, ok1, _ = jax.block_until_ready(
        run(args, dg.scatter_fine_payloads(a_new), b))
    assert bool(ok1[0])
    assert int(it1[0]) == int(ref1.iters), (int(it1[0]), int(ref1.iters))
    np.testing.assert_allclose(dg.gather_vector(x1), np.asarray(ref1.x),
                               rtol=1e-6, atol=1e-9)
    print(f"gated recompute parity: iters={int(it1[0])}")

    # ungated: rebuild the prolongator-side staging from scratch; results
    # must be identical to the gated path (paper Table 3's ablation only
    # costs time, never accuracy)
    dg2 = build_dist_gamg(setupd, ndev, coarse_eq_limit=0)
    run2 = make_dist_solver(dg2, setupd, mesh, rtol=1e-8, maxiter=200)
    x2, it2, _, ok2, _ = jax.block_until_ready(
        run2(dg2.sharded_args(setupd), dg2.scatter_fine_payloads(a_new), b))
    assert bool(ok2[0]) and int(it2[0]) == int(it1[0])
    np.testing.assert_allclose(dg.gather_vector(x2),
                               dg.gather_vector(x1), rtol=0, atol=0)
    print("ungated rebuild parity: identical")

    if os.environ.get("REPRO_SELFTEST_MRHS") == "1":
        # multi-RHS panel through the SAME jitted shard_map program (only
        # the b payload grows a trailing axis) vs the single-device
        # batched masked PCG: per-column iteration parity + allclose.
        rng = np.random.default_rng(0)
        B3 = np.stack([np.asarray(prob.b),
                       0.5 * np.asarray(prob.b) + rng.standard_normal(prob.n),
                       rng.standard_normal(prob.n)], axis=1)
        ref_mr = solver.solve_many(jax.numpy.asarray(B3))
        xm, itm, rrm, okm, stm = jax.block_until_ready(
            run(args, dg.scatter_fine_payloads(a_new),
                dg.scatter_vector(B3)))
        assert (np.asarray(stm[0]) == 0).all(), stm
        assert bool(np.asarray(okm[0]).all()), (itm, rrm)
        assert np.array_equal(np.asarray(itm[0]), np.asarray(ref_mr.iters)), \
            f"mrhs iters: dist={np.asarray(itm[0])} " \
            f"single={np.asarray(ref_mr.iters)}"
        np.testing.assert_allclose(dg.gather_vector(xm),
                                   np.asarray(ref_mr.x), rtol=1e-6,
                                   atol=1e-9)
        print(f"mrhs (k={B3.shape[1]}) parity: "
              f"iters={np.asarray(itm[0]).tolist()}")

    if os.environ.get("REPRO_SELFTEST_AGG") == "1":
        # agglomerated placement: force the threshold high so every level
        # above the finest is replicated, then demand *exact* iteration
        # parity with the sharded-only placement of the same setup (an
        # fp64 contract, like the sections above).  When the main setup
        # has no mid level to replicate, coarsen deeper.
        if len(setupd.levels) >= 2:
            setup_a, a_vals, b_a = setupd, a_new, b
            dg_sh, run_sh = dg, run
            sh_x, sh_iters = x1, int(it1[0])
        else:
            setup_a = gamg.setup(prob.A, prob.B, coarse_size=12,
                                 precision="f64")
            assert len(setup_a.levels) >= 2, setup_a.stats["level_rows"]
            a_vals = prob.A.data
            dg_sh = build_dist_gamg(setup_a, ndev, coarse_eq_limit=0)
            run_sh = make_dist_solver(dg_sh, setup_a, mesh, rtol=1e-8,
                                      maxiter=200)
            b_a = dg_sh.scatter_vector(prob.b)
            xs, its, _, oks, _ = jax.block_until_ready(
                run_sh(dg_sh.sharded_args(setup_a),
                       dg_sh.scatter_fine_payloads(a_vals), b_a))
            assert bool(oks[0])
            sh_x, sh_iters = xs, int(its[0])
        dg_ag = build_dist_gamg(setup_a, ndev, coarse_eq_limit=1 << 30)
        assert dg_ag.repl and len(dg_ag.levels) == 1, dg_ag.placement
        assert not dg_sh.repl, dg_sh.placement
        run_ag = make_dist_solver(dg_ag, setup_a, mesh, rtol=1e-8,
                                  maxiter=200)
        args_ag = dg_ag.sharded_args(setup_a)
        a0_ag = dg_ag.scatter_fine_payloads(a_vals)
        xa, ita, rra, oka, _ = jax.block_until_ready(run_ag(args_ag, a0_ag,
                                                            b_a))
        assert bool(oka[0]), (ita, rra)
        assert int(ita[0]) == sh_iters, \
            f"agg parity: agglomerated={int(ita[0])} sharded={sh_iters}"
        np.testing.assert_allclose(dg_ag.gather_vector(xa),
                                   dg_sh.gather_vector(sh_x),
                                   rtol=1e-6, atol=1e-9)
        print(f"agglomerated parity: iters={int(ita[0])} "
              f"placement={dg_ag.placement}")
        if os.environ.get("REPRO_SELFTEST_MRHS") == "1":
            # the panel through the agglomerated program: per-column
            # parity with the sharded placement
            rng_a = np.random.default_rng(0)
            Ba = np.stack(
                [np.asarray(prob.b),
                 0.5 * np.asarray(prob.b) + rng_a.standard_normal(prob.n),
                 rng_a.standard_normal(prob.n)], axis=1)
            xm_s, itm_s, _, okm_s, _ = jax.block_until_ready(
                run_sh(dg_sh.sharded_args(setup_a),
                       dg_sh.scatter_fine_payloads(a_vals),
                       dg_sh.scatter_vector(Ba)))
            xm_a, itm_a, _, okm_a, _ = jax.block_until_ready(
                run_ag(args_ag, a0_ag, dg_ag.scatter_vector(Ba)))
            assert bool(np.asarray(okm_s[0]).all())
            assert bool(np.asarray(okm_a[0]).all())
            assert np.array_equal(np.asarray(itm_a[0]),
                                  np.asarray(itm_s[0])), (itm_a, itm_s)
            np.testing.assert_allclose(dg_ag.gather_vector(xm_a),
                                       dg_sh.gather_vector(xm_s),
                                       rtol=1e-6, atol=1e-9)
            print(f"agglomerated mrhs (k={Ba.shape[1]}) parity: "
                  f"iters={np.asarray(itm_a[0]).tolist()}")

    if os.environ.get("REPRO_SELFTEST_COEFF") == "1":
        # device-resident coefficient hot loop through the dist staging:
        # heterogeneous fields -> rank-local assembly -> recompute -> solve
        from repro.dist.solver import build_dist_assembly, \
            make_dist_coeff_solver
        from repro.fem.assemble import inclusion_fields
        assert prob.assembler is not None      # device assembly default
        da = build_dist_assembly(dg, prob.assembler)
        run_c = make_dist_coeff_solver(dg, da, mesh, rtol=1e-8, maxiter=200)
        aargs = da.sharded_args()
        E_h, nu_h = inclusion_fields(prob.mesh)
        solver.bind_assembler(prob.assembler)
        solver.update_coefficients(E_h, nu_h)
        ref_c = solver.solve(prob.b)
        xc, itc, rrc, okc, stc = jax.block_until_ready(
            run_c(args, aargs, *da.scatter_fields(E_h, nu_h), b))
        assert int(stc[0]) == 0, stc
        assert bool(okc[0]), (itc, rrc)
        assert int(itc[0]) == int(ref_c.iters), \
            f"coeff parity: dist={int(itc[0])} single={int(ref_c.iters)}"
        np.testing.assert_allclose(dg.gather_vector(xc),
                                   np.asarray(ref_c.x), rtol=1e-6,
                                   atol=1e-9)
        # rank-local assembly == globally assembled value stream, exactly
        A_h = prob.coefficient_operator(E_h, nu_h)
        xv, itv, _, okv, _ = jax.block_until_ready(
            run(args, dg.scatter_fine_payloads(A_h.data), b))
        assert bool(okv[0]) and int(itv[0]) == int(itc[0])
        np.testing.assert_allclose(dg.gather_vector(xv),
                                   dg.gather_vector(xc), rtol=1e-12,
                                   atol=1e-12)
        # zero retraces across repeated updates — even f32-typed callers
        # (fields stage at the policy dtype, mirroring the payload scatter)
        run_c(args, aargs,
              *da.scatter_fields(np.asarray(E_h, np.float32) * 1.5, nu_h), b)
        assert run_c._cache_size() == 1, run_c._cache_size()
        print(f"coefficient hot-loop parity: iters={int(itc[0])} "
              f"(assembled rank-locally, no retrace)")

    if os.environ.get("REPRO_SELFTEST_MARCH") == "1":
        # warm-started coefficient time march over the wire: the same
        # softening trajectory stepped by (a) the single-device fused
        # march primitive (gamg.make_coeff_solve) and (b) the
        # warm_start=True dist coefficient program, whose x output slab
        # feeds straight back in as the next step's x0 slab — no
        # gather/scatter round trip, the slab-sharded twin of the
        # repro.sim march step.  Per-step iteration parity + allclose.
        from repro.dist.solver import build_dist_assembly, \
            make_dist_coeff_solver
        from repro.robust.health import HEALTHY
        from repro.sim.scenarios import SofteningScenario
        assert prob.assembler is not None
        da_m = build_dist_assembly(dg, prob.assembler)
        run_cm = make_dist_coeff_solver(dg, da_m, mesh, rtol=1e-8,
                                        maxiter=200, warm_start=True)
        aargs_m = da_m.sharded_args()
        coeff_solve = gamg.make_coeff_solve(setupd, prob.assembler,
                                            rtol=1e-8, maxiter=200)
        scen = SofteningScenario.build(prob, rate=0.3)
        state = scen.init_state()
        x_ref = jax.numpy.zeros_like(prob.b)
        # commit the cold x0 slab to the program's output sharding so the
        # warm feedback (x output slab -> next x0 slab) never retraces
        from jax.sharding import NamedSharding, PartitionSpec
        x_slab = jax.device_put(
            np.asarray(dg.scatter_vector(np.zeros(prob.n))),
            NamedSharding(mesh, PartitionSpec("rank")))
        march_iters = []
        for s in range(3):
            E_s, nu_s, state = scen.step_fields(
                state, x_ref, jax.numpy.asarray(s, jax.numpy.int32))
            res_s = jax.block_until_ready(
                coeff_solve(E_s, nu_s, prob.b, x_ref))
            xm2, itm2, rrm2, okm2, stm2 = jax.block_until_ready(
                run_cm(args, aargs_m, *da_m.scatter_fields(E_s, nu_s),
                       b, x_slab))
            assert int(np.asarray(stm2)[0]) == HEALTHY, stm2
            assert bool(okm2[0]), (itm2, rrm2)
            assert int(itm2[0]) == int(res_s.iters), \
                f"march step {s}: dist={int(itm2[0])} " \
                f"single={int(res_s.iters)}"
            np.testing.assert_allclose(dg.gather_vector(xm2),
                                       np.asarray(res_s.x), rtol=1e-6,
                                       atol=1e-9)
            march_iters.append(int(itm2[0]))
            x_ref, x_slab = res_s.x, xm2
        # warm start earns its keep: the last step re-solved cold needs
        # at least as many iterations as the warm dist step took
        res_cold = coeff_solve(E_s, nu_s, prob.b,
                               jax.numpy.zeros_like(prob.b))
        assert march_iters[-1] <= int(res_cold.iters), \
            (march_iters, int(res_cold.iters))
        # one compiled program serves the whole warm march
        assert run_cm._cache_size() == 1, run_cm._cache_size()
        print(f"dist warm march parity (3 steps): iters={march_iters} "
              f"(cold last step: {int(res_cold.iters)})")

    if os.environ.get("REPRO_SELFTEST_FAULT") == "1":
        # fault battery over the wire.  The schedule must be live while
        # the program under test is TRACED (injection is trace-time), so
        # each case stages and jits a fresh program inside the context.
        from repro.robust import inject
        from repro.robust.health import HEALTHY, STATUS_NAMES

        # (a) NaN into the halo-exchange windows: every ppermute/allgather
        # window in the program (CG spmv halos, recompute stage-2 windows,
        # power-iteration halos) is poisoned; the collective flags must
        # trip on every rank and the returned best iterate stays finite.
        with inject.active(inject.parse_schedule("halo:nan")):
            dg_f = build_dist_gamg(setupd, ndev, coarse_eq_limit=0)
            run_f = make_dist_solver(dg_f, setupd, mesh, rtol=1e-8,
                                     maxiter=200)
            xf, itf, rrf, okf, stf = jax.block_until_ready(
                run_f(dg_f.sharded_args(setupd),
                      dg_f.scatter_fine_payloads(prob.A.data), b))
        st_f = int(np.asarray(stf)[0])
        assert not bool(okf[0]), "halo fault must prevent convergence"
        assert st_f != HEALTHY, STATUS_NAMES.get(st_f, st_f)
        assert np.isfinite(dg_f.gather_vector(xf)).all(), \
            "a silent NaN escaped the flagged halo-faulted solve"
        print(f"halo fault detected: status={STATUS_NAMES[st_f]} "
              f"iters={int(itf[0])}")

        # (b) Inf into the distributed CG's operator apply at step 2:
        # flagged within one outer iteration of the injection.
        with inject.active(inject.parse_schedule("spmv:inf@2")):
            dg_f2 = build_dist_gamg(setupd, ndev, coarse_eq_limit=0)
            run_f2 = make_dist_solver(dg_f2, setupd, mesh, rtol=1e-8,
                                      maxiter=200)
            xf2, itf2, _, okf2, stf2 = jax.block_until_ready(
                run_f2(dg_f2.sharded_args(setupd),
                       dg_f2.scatter_fine_payloads(prob.A.data), b))
        st_f2 = int(np.asarray(stf2)[0])
        assert not bool(okf2[0]) and st_f2 != HEALTHY
        assert int(itf2[0]) <= 3, \
            f"step-2 spmv fault flagged late: iters={int(itf2[0])}"
        assert np.isfinite(dg_f2.gather_vector(xf2)).all()
        print(f"spmv@2 fault detected: status={STATUS_NAMES[st_f2]} "
              f"iters={int(itf2[0])}")

        # (c) recovery: a clean re-staging (no schedule installed) must
        # restore exact parity with the unfaulted cold solve.
        assert inject.current() is None
        dg_r = build_dist_gamg(setupd, ndev, coarse_eq_limit=0)
        run_r = make_dist_solver(dg_r, setupd, mesh, rtol=1e-8, maxiter=200)
        xr, itr, _, okr, str_ = jax.block_until_ready(
            run_r(dg_r.sharded_args(setupd),
                  dg_r.scatter_fine_payloads(prob.A.data), b))
        assert bool(okr[0]) and int(np.asarray(str_)[0]) == HEALTHY
        assert int(itr[0]) == int(iters[0]), (int(itr[0]), int(iters[0]))
        np.testing.assert_allclose(dg_r.gather_vector(xr), x_g,
                                   rtol=0, atol=0)
        print("post-fault re-staging parity: identical")

    if os.environ.get("REPRO_SELFTEST_OVERLAP") == "1":
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        from repro.core.block_csr import BlockCSR
        from repro.dist import pamg
        from repro.dist import solver as dist_solver
        from repro.dist.partition import partition_rows
        from repro.robust import inject
        from repro.robust.health import HEALTHY
        P_ = PartitionSpec

        def solve_with(mode, schedule=None):
            """Fresh staging + trace under one REPRO_OVERLAP rendering."""
            os.environ["REPRO_OVERLAP"] = mode
            try:
                ctx = (inject.active(inject.parse_schedule(schedule))
                       if schedule else None)
                try:
                    if ctx is not None:
                        ctx.__enter__()
                    dg_m = build_dist_gamg(setupd, ndev, coarse_eq_limit=0)
                    run_m = make_dist_solver(dg_m, setupd, mesh,
                                             rtol=1e-8, maxiter=200)
                    out = jax.block_until_ready(
                        run_m(dg_m.sharded_args(setupd),
                              dg_m.scatter_fine_payloads(prob.A.data), b))
                finally:
                    if ctx is not None:
                        ctx.__exit__(None, None, None)
                return dg_m, out
            finally:
                os.environ.pop("REPRO_OVERLAP", None)

        # (a) full-solve parity: same iteration count, bitwise solution
        dg_on, (x_on, it_on, _, ok_on, st_on) = solve_with("on")
        dg_off, (x_off, it_off, _, ok_off, st_off) = solve_with("off")
        assert bool(ok_on[0]) and bool(ok_off[0]), (it_on, it_off)
        assert int(st_on[0]) == int(st_off[0]) == HEALTHY
        assert int(it_on[0]) == int(it_off[0]), \
            f"overlap parity: on={int(it_on[0])} off={int(it_off[0])}"
        np.testing.assert_array_equal(np.asarray(x_on), np.asarray(x_off))
        # the fine level genuinely has both partitions to overlap
        op0 = dg_on.levels[0].a_op
        print(f"overlap solve parity: iters={int(it_on[0])} bitwise "
              f"(int_rows min={int(op0.int_counts.min())} "
              f"bnd_rows max={int(op0.bnd_counts.max())})")

        # (b) apply-level bitwise battery: strategies x rhs shapes x dtypes
        def banded_op(offs, dtype, wrap):
            nbr, bs = 4 * ndev, 2
            cols = [sorted({i} | {((i + o) % nbr if wrap
                                   else min(max(i + o, 0), nbr - 1))
                                  for o in offs})
                    for i in range(nbr)]
            indptr = np.cumsum([0] + [len(c) for c in cols])
            indices = np.concatenate(cols).astype(np.int64)
            rng_b = np.random.default_rng(7)
            data = rng_b.standard_normal(
                (len(indices), bs, bs)).astype(dtype)
            A = BlockCSR.from_arrays(indptr, indices,
                                     jax.numpy.asarray(data), nbr)
            part = partition_rows(nbr, ndev)
            return A, part, data

        def scatter_slabs(part, pad, xg):
            out = np.zeros((ndev, pad) + xg.shape[1:], xg.dtype)
            for r in range(ndev):
                sl = part.slab(r)
                out[r, :sl.stop - sl.start] = xg[sl]
            return out

        def assert_bitwise(op, x_slabs):
            stack = tuple(jax.numpy.asarray(s) for s in (
                op.indices, op.indices_local, op.int_mask, op.data))

            def rank(idx, loc, msk, dat, x):
                idx, loc, msk, dat, x = jax.tree.map(
                    lambda t: t[0], (idx, loc, msk, dat, x))
                y0 = pamg.dist_ell_apply(idx, dat,
                                         pamg.halo_window(x, op.halo))
                pend = pamg.start_halo_exchange(x, op.halo)
                yi = pamg.dist_ell_apply_interior(loc, dat, x)
                win = pamg.finish_halo_exchange(pend)
                yb = pamg.dist_ell_apply_boundary(idx, dat, win)
                y1 = pamg.combine_split(msk, yi, yb)
                return y0[None], y1[None]

            f = shard_map(rank, mesh, in_specs=(P_("rank"),) * 5,
                          out_specs=P_("rank"), check_rep=False)
            y0, y1 = jax.jit(f)(*stack, jax.numpy.asarray(x_slabs))
            np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))

        cases = [("ppermute", (-1, 1), False)]
        if ndev >= 4:
            cases.append(("allgather", (4 * ndev // 2,), True))
        rng_x = np.random.default_rng(11)
        for name, offs, wrap in cases:
            for dtype in (np.float64, np.float32):
                A_c, part_c, data_c = banded_op(offs, dtype, wrap)
                op_c = pamg.build_dist_ell(A_c, part_c, part_c,
                                           const_data=data_c)
                assert op_c.halo.strategy == name, \
                    (name, op_c.halo.strategy)
                assert op_c.bnd_counts.max() > 0    # split is non-trivial
                for trail in ((), (3,)):            # vector + panel
                    xg = rng_x.standard_normal(
                        (A_c.nbr, 2) + trail).astype(dtype)
                    assert_bitwise(op_c, scatter_slabs(
                        part_c, op_c.halo.cpad, xg))
        # replicated halo: the split degenerates to all-interior and must
        # still be bitwise (every rank holds the global input)
        A_r, part_r, data_r = banded_op((-1, 1), np.float64, False)
        op_r = pamg.build_dist_ell(A_r, part_r, part_r, const_data=data_r,
                                   replicated_cols=True)
        assert op_r.halo.strategy == "replicated"
        xg_r = rng_x.standard_normal((A_r.nbr, 2))
        assert_bitwise(op_r, np.broadcast_to(
            xg_r, (ndev,) + xg_r.shape).copy())
        print(f"overlap apply battery bitwise: "
              f"strategies={[c[0] for c in cases] + ['replicated']} "
              f"x (vector, panel) x (f64, f32)")

        # (c) jaxpr residue: the off-rendering router IS the hand-rolled
        # blocking apply — identical jaxpr, not merely identical values
        A_j, part_j, data_j = banded_op((-1, 1), np.float64, False)
        op_j = pamg.build_dist_ell(A_j, part_j, part_j, const_data=data_j)
        # args pre-sliced *outside* the traced fns (as the solver's
        # sharded-args staging does), so the comparison covers exactly the
        # apply: the unused split-plan entries must leave zero residue
        a_j = {"a_idx": jax.numpy.asarray(op_j.indices[0]),
               "a_loc": jax.numpy.asarray(op_j.indices_local[0]),
               "a_msk": jax.numpy.asarray(op_j.int_mask[0])}
        dat_j = jax.numpy.asarray(op_j.data[0])
        xs_j = scatter_slabs(part_j, op_j.halo.cpad,
                             rng_x.standard_normal((A_j.nbr, 2)))

        def routed(x):
            return dist_solver._rank_spmv(
                op_j, a_j, "a_", dat_j, x[0], False)[None]

        def handrolled(x):
            return pamg.dist_ell_apply(
                a_j["a_idx"], dat_j,
                pamg.halo_window(x[0], op_j.halo))[None]

        jaxprs = [str(jax.make_jaxpr(shard_map(
            f, mesh, in_specs=P_("rank"), out_specs=P_("rank"),
            check_rep=False))(jax.numpy.asarray(xs_j)))
            for f in (routed, handrolled)]
        assert jaxprs[0] == jaxprs[1], \
            "REPRO_OVERLAP=off left residue vs the blocking apply"
        print("overlap off-path jaxpr: residue-free identical")

        # (d) fault-detection latency is schedule-independent: a halo NaN
        # trips the same status in the same iteration under either
        # rendering (the "halo" site fires on the assembled window in
        # finish_halo_exchange, shared by both)
        _, (xf_on, itf_on, _, okf_on, stf_on) = solve_with(
            "on", schedule="halo:nan")
        _, (xf_off, itf_off, _, okf_off, stf_off) = solve_with(
            "off", schedule="halo:nan")
        assert not bool(okf_on[0]) and not bool(okf_off[0])
        assert int(np.asarray(stf_on)[0]) == int(np.asarray(stf_off)[0]) \
            != HEALTHY, (stf_on, stf_off)
        assert int(itf_on[0]) == int(itf_off[0]), \
            f"halo-fault detection latency changed under overlap: " \
            f"on={int(itf_on[0])} off={int(itf_off[0])}"
        print(f"overlap fault-detection parity: status="
              f"{int(np.asarray(stf_on)[0])} iters={int(itf_on[0])}")

    prec = os.environ.get("REPRO_PRECISION")
    if prec and prec not in ("f64", "fp64", "float64", "double"):
        # reduced-precision-resident distributed hierarchy: fp64 outer CG,
        # boundary casts.  Convergence + bounded iteration growth + close
        # solution vs the fp64 reference (exact parity is an fp64 claim).
        setup_p = gamg.setup(prob.A, prob.B, coarse_size=30, precision=prec)
        dg_p = build_dist_gamg(setup_p, ndev)
        run_p = make_dist_solver(dg_p, setup_p, mesh, rtol=1e-8, maxiter=200)
        xp, itp, rrp, okp, _ = jax.block_until_ready(
            run_p(dg_p.sharded_args(setup_p),
                  dg_p.scatter_fine_payloads(prob.A.data), b))
        assert bool(okp[0]), (itp, rrp)
        bound = int(np.ceil(1.3 * int(ref0.iters))) + 1
        assert int(itp[0]) <= bound, \
            f"{prec} dist iters {int(itp[0])} > {bound} (f64: {ref0.iters})"
        np.testing.assert_allclose(dg_p.gather_vector(xp),
                                   np.asarray(ref0.x), rtol=1e-5, atol=1e-7)
        h_dt = setup_p.precision.hierarchy_dtype
        # level 0's prolongator moves to the switch boundary when the
        # default placement agglomerates the first mid level
        p_stage = (dg_p.levels[0].p_op if dg_p.levels[0].p_op is not None
                   else dg_p.switch.p_b)
        assert p_stage.data.dtype == h_dt
        print(f"reduced precision ({prec}): iters={int(itp[0])} "
              f"(f64 ref {int(ref0.iters)}) relres={float(rrp[0]):.3e}")

    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(int(sys.argv[1]) if len(sys.argv) > 1 else 5))
