"""Fused smoother kernel: one Pallas pass per recurrence step.

Covers ISSUE 8's smoother tentpole: kernel-vs-oracle exactness, fused vs
unfused recurrence parity (f64 tight, f32/bf16 at tolerance; vector and
panel RHS), the jaxpr zero-intermediates contract (no full-length
residual/gather arrays in the fused path — same style as the fused
Galerkin test), and the ``REPRO_SMOOTH_PATH`` knob resolution.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
import jax
import jax.numpy as jnp

from helpers import spd_bcsr
from repro.core import gamg
from repro.core.vcycle import apply_smoother
from repro.fem.assemble import assemble_elasticity
from repro.kernels import backend
from repro.kernels.fused_smoother import ops as fs_ops
from repro.kernels.fused_smoother.fused_smoother import smoother_step_ell
from repro.kernels.fused_smoother.ref import smoother_step_ref

RNG = np.random.default_rng(11)


def _tol(dtype):
    return {"float64": 1e-12, "float32": 2e-5, "bfloat16": 5e-2}[
        jnp.dtype(dtype).name]


def _operands(nbr=17, bs=3, k=None, dtype=np.float64):
    A = spd_bcsr(RNG, nbr, bs)
    ell = A.to_ell().astype(dtype)
    dinv = jnp.asarray(
        np.linalg.inv(np.asarray(
            A.to_dense()).reshape(nbr, bs, nbr, bs)[
                np.arange(nbr), :, np.arange(nbr), :])).astype(dtype)
    shape = (nbr * bs,) if k is None else (nbr * bs, k)
    b = jnp.asarray(RNG.standard_normal(shape)).astype(dtype)
    x = jnp.asarray(RNG.standard_normal(shape)).astype(dtype)
    d = jnp.asarray(RNG.standard_normal(shape)).astype(dtype)
    return ell, dinv, b, x, d


@pytest.mark.parametrize("k", [None, 3])
@pytest.mark.parametrize("dtype", [np.float64, np.float32, jnp.bfloat16])
def test_kernel_matches_reference(dtype, k):
    """The tiled kernel vs the pure-jnp oracle, vector and panel RHS.
    f64 must be bitwise (same per-row reduction order); low precision
    at the family tolerance (tile padding perturbs rounding)."""
    ell, dinv, b, x, d = _operands(k=k, dtype=dtype)
    nbr, bs = ell.nbr, ell.br
    coef = jnp.asarray([0.3, 0.7], ell.data.dtype)
    vshape = (nbr, bs) if k is None else (nbr, bs, k)
    args = (ell.indices, ell.data, dinv, b.reshape(vshape),
            x.reshape(vshape), d.reshape(vshape), coef)
    acc = jnp.float32 if jnp.dtype(dtype) == jnp.bfloat16 else None
    xr, dr = smoother_step_ref(*args, accum_dtype=acc)
    for tile in (4, 8, 32):
        xk, dk = smoother_step_ell(*args, tile_rows=tile, interpret=True,
                                   accum_dtype=acc)
        if jnp.dtype(dtype) == jnp.float64:
            np.testing.assert_array_equal(np.asarray(xk), np.asarray(xr))
            np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
        else:
            np.testing.assert_allclose(
                np.asarray(xk, np.float64), np.asarray(xr, np.float64),
                rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("k", [None, 3])
@pytest.mark.parametrize("smoother", ["chebyshev", "pbjacobi"])
def test_fused_matches_unfused_recurrence(smoother, k):
    """apply_smoother path parity on a real elasticity level.  pbjacobi is
    bitwise (both paths form the residual from scratch); Chebyshev's
    unfused recurrence updates the residual incrementally (r -= A d), so
    f64 agrees to rounding only — 'tight', not bitwise."""
    prob = assemble_elasticity(4)
    sd = gamg.setup(prob.A, prob.B, coarse_size=30)
    lv = gamg.recompute(sd, prob.A.data).levels[0]
    shape = prob.b.shape if k is None else (prob.b.shape[0], k)
    b = jnp.asarray(RNG.standard_normal(shape))
    x0 = jnp.zeros_like(b)
    xu = apply_smoother(lv, b, x0, smoother, 2, path="reference")
    xf = apply_smoother(lv, b, x0, smoother, 2, path="fused")
    assert xf.shape == xu.shape
    if smoother == "pbjacobi":
        np.testing.assert_array_equal(np.asarray(xf), np.asarray(xu))
    else:
        scale = float(jnp.abs(xu).max())
        np.testing.assert_allclose(np.asarray(xf), np.asarray(xu),
                                   rtol=0, atol=1e-13 * max(scale, 1.0))


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fused_low_precision_tolerance(dtype):
    ell, dinv, b, x, d = _operands(dtype=dtype)
    acc = jnp.float32
    x1, d1 = fs_ops.smoother_step(ell, dinv, b, x, d, 0.2, 0.5,
                                  interpret=True, accum_dtype=acc)
    nbr, bs = ell.nbr, ell.br
    xr, dr = smoother_step_ref(ell.indices, ell.data, dinv,
                               b.reshape(nbr, bs), x.reshape(nbr, bs),
                               d.reshape(nbr, bs),
                               jnp.asarray([0.2, 0.5], ell.data.dtype),
                               accum_dtype=acc)
    np.testing.assert_allclose(np.asarray(x1, np.float64),
                               np.asarray(xr.reshape(-1), np.float64),
                               rtol=_tol(dtype), atol=_tol(dtype))


def test_fused_path_has_no_full_length_intermediates():
    """The point of the fusion: the fused jaxpr must contain neither the
    full-length gathered-x array (nbr, kmax, bs) nor any full-length
    residual subtraction — the kernel only ever touches (tile, ...)
    slices, so r and z never exist at HBM size."""
    ell, dinv, b, x, d = _operands(nbr=32, bs=3)
    nbr, kmax, bs = ell.nbr, ell.kmax, ell.br
    tile = 8
    assert tile < nbr

    def walk(jaxpr, acc):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    acc.append((eqn.primitive.name, tuple(aval.shape)))
            for val in eqn.params.values():
                if isinstance(val, jax.core.ClosedJaxpr):
                    walk(val.jaxpr, acc)
                elif isinstance(val, jax.core.Jaxpr):
                    walk(val, acc)
        return acc

    fused = lambda bb, xx, dd: fs_ops.smoother_step(  # noqa: E731
        ell, dinv, bb, xx, dd, 0.3, 0.7, interpret=True, tile_rows=tile)
    shapes = walk(jax.make_jaxpr(fused)(b, x, d).jaxpr, [])
    full_gather = (nbr, kmax, bs)
    assert full_gather not in [s for _, s in shapes], \
        "fused path materialized the full gathered-x array"
    full_subs = [s for p, s in shapes
                 if p == "sub" and s in ((nbr * bs,), (nbr, bs))]
    assert not full_subs, \
        f"fused path materialized a full-length residual: {full_subs}"

    # sensitivity: the unfused recurrence does materialize both
    from repro.core.vcycle import LevelState, chebyshev_smooth
    lv = LevelState(a_ell=ell, p_ell=ell, r_ell=None, dinv=dinv,
                    lam_max=jnp.asarray(2.0), p_t=None)
    unfused = lambda bb, xx: chebyshev_smooth(lv, bb, xx)  # noqa: E731
    ushapes = walk(jax.make_jaxpr(unfused)(b, x).jaxpr, [])
    assert full_gather in [s for _, s in ushapes], "oracle not sensitive"
    assert any(p == "sub" and s == (nbr * bs,) for p, s in ushapes)


def test_smooth_path_knob_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SMOOTH_PATH", raising=False)
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert backend.resolve_smooth_path("fused") == "fused"
    assert backend.resolve_smooth_path("reference") == "reference"
    # default follows the accelerator rule
    monkeypatch.setenv("REPRO_BACKEND", "tpu")
    assert backend.resolve_smooth_path(None) == "fused"
    monkeypatch.setenv("REPRO_BACKEND", "cpu")
    assert backend.resolve_smooth_path(None) == "reference"
    monkeypatch.setenv("REPRO_SMOOTH_PATH", "fused")
    assert backend.resolve_smooth_path(None) == "fused"
    with pytest.raises(ValueError):
        backend.resolve_smooth_path("fast-ish")
