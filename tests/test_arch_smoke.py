"""Per-architecture smoke tests: reduced config, one forward/train step +
one decode step on CPU; assert output shapes + finiteness (no NaNs).

The FULL configs are exercised only by the dry-run (compile-only); these
reduced configs run the same code paths end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.models.config import cell_applicable, shape_by_name
from repro.train.data import DataConfig, SyntheticTokens
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.steps import (
    cross_entropy,
    make_prefill,
    make_serve_step,
    make_train_step,
)

B, S = 2, 32
CDT = jnp.float32   # CPU smoke runs fp32 for tight finiteness checks

# the heaviest reduced configs dominate tier-1 wall time; keep them opt-in
_SLOW_ARCHS = {"deepseek-v2-236b", "hymba-1.5b", "llama4-maverick-400b-a17b"}
ARCH_TRAIN_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
    for a in ARCH_IDS
]


def _batch(cfg):
    dc = DataConfig(vocab_size=cfg.vocab_size, global_batch=B, seq_len=S + 1,
                    enc_frames=cfg.encdec.encoder_frames if cfg.encdec else 0,
                    d_model=cfg.d_model)
    b = SyntheticTokens(dc).batch_at(0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCH_TRAIN_PARAMS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = T.init_lm(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), cdt=CDT))
    batch = _batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert loss > 0
    # params actually moved and stayed finite
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    for leaf in jax.tree_util.tree_leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_logits_shape(arch):
    cfg = get_config(arch).reduced()
    params = T.init_lm(cfg, jax.random.key(1))
    batch = _batch(cfg)
    prefill = jax.jit(make_prefill(cfg, cdt=CDT))
    logits = prefill(params, batch["tokens"], batch.get("enc_feats"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = T.init_lm(cfg, jax.random.key(2))
    seq_len = 16
    cache = T.init_full_cache(cfg, B, seq_len, cdt=CDT)
    serve = jax.jit(make_serve_step(cfg, cdt=CDT))
    enc_out = None
    if cfg.encdec is not None:
        feats = jnp.asarray(np.random.default_rng(0).standard_normal(
            (B, cfg.encdec.encoder_frames, cfg.d_model)), CDT)
        enc_out = T.encoder_apply(params["encoder"], feats, cfg, CDT)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = serve(params, cache, tok,
                              jnp.asarray(pos, jnp.int32), enc_out)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, :, :32], axis=-1).astype(jnp.int32)


def test_decode_matches_prefill_gqa():
    """Step-by-step decode must reproduce the causal forward logits."""
    cfg = get_config("qwen2-0.5b").reduced()
    params = T.init_lm(cfg, jax.random.key(3))
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 8)), jnp.int32)
    full = T.forward_train(params, toks, cfg, CDT, remat=False)
    cache = T.init_full_cache(cfg, 1, 8, cdt=CDT)
    outs = []
    for pos in range(8):
        lg, cache = T.decode_step(params, toks[:, pos:pos + 1],
                                  jnp.asarray(pos, jnp.int32), cache, cfg,
                                  CDT)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_mamba():
    cfg = get_config("falcon-mamba-7b").reduced()
    params = T.init_lm(cfg, jax.random.key(4))
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (1, 8)), jnp.int32)
    full = T.forward_train(params, toks, cfg, CDT, remat=False)
    cache = T.init_full_cache(cfg, 1, 8, cdt=CDT)
    outs = []
    for pos in range(8):
        lg, cache = T.decode_step(params, toks[:, pos:pos + 1],
                                  jnp.asarray(pos, jnp.int32), cache, cfg,
                                  CDT)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_long_context_applicability_table():
    runs = {a: cell_applicable(get_config(a), shape_by_name("long_500k"))[0]
            for a in ARCH_IDS}
    assert runs == {
        "llama4-maverick-400b-a17b": False, "deepseek-v2-236b": False,
        "hymba-1.5b": True, "mistral-large-123b": False,
        "phi4-mini-3.8b": False, "gemma-7b": False, "qwen2-0.5b": False,
        "chameleon-34b": False, "falcon-mamba-7b": True,
        "whisper-small": False}


def test_loss_decreases_briefly():
    cfg = get_config("qwen2-0.5b").reduced()
    params = T.init_lm(cfg, jax.random.key(5))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=5),
                                   cdt=CDT))
    dc = DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=33)
    data = SyntheticTokens(dc)
    losses = []
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-4:]) < np.mean(losses[:4]), losses
