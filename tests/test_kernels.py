"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Each kernel is swept over shapes and dtypes per the deliverables spec; the
blocked SpMV/SpGEMM paths are additionally validated end-to-end against the
core reference implementations.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax.numpy as jnp

from repro.core.spmv import spmm, spmm_ell, spmv, spmv_ell
from repro.core.spgemm import spgemm_symbolic, spgemm_numeric
from repro.kernels.block_spmv.block_spmv import block_spmv_ell
from repro.kernels.block_spmv.ref import block_spmv_ell_ref
from repro.kernels.block_spmm.block_spmm import block_spmm_ell
from repro.kernels.block_spmm.ops import block_spmm
from repro.kernels.block_spmm.ref import block_spmm_ell_ref
from repro.kernels.block_pair_gemm.block_pair_gemm import block_pair_gemm
from repro.kernels.block_pair_gemm.ref import block_pair_gemm_ref
from repro.kernels.block_seg_sum.ops import block_seg_sum
from repro.kernels.block_seg_sum.ref import block_seg_sum_ref
from repro.kernels.pbjacobi.pbjacobi import pbjacobi_update
from repro.kernels.pbjacobi.ref import pbjacobi_update_ref

from helpers import random_bcsr

RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(rtol=1e-12, atol=1e-12) if dtype == np.float64 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("nbr,kmax,br,bc",
                         [(5, 3, 3, 3), (16, 7, 3, 6), (33, 2, 6, 6),
                          (8, 4, 1, 1), (64, 9, 6, 3), (3, 1, 2, 5)])
def test_block_spmv_kernel_sweep(nbr, kmax, br, bc, dtype):
    nbc = nbr + 3
    indices = jnp.asarray(RNG.integers(0, nbc, (nbr, kmax)), jnp.int32)
    data = jnp.asarray(RNG.standard_normal((nbr, kmax, br, bc)), dtype)
    x = jnp.asarray(RNG.standard_normal((nbc, bc)), dtype)
    got = block_spmv_ell(indices, data, x, interpret=True)
    want = block_spmv_ell_ref(indices, data, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("tile_rows", [1, 4, 8, 32])
def test_block_spmv_kernel_tile_invariance(tile_rows):
    indices = jnp.asarray(RNG.integers(0, 10, (13, 5)), jnp.int32)
    data = jnp.asarray(RNG.standard_normal((13, 5, 3, 3)))
    x = jnp.asarray(RNG.standard_normal((10, 3)))
    got = block_spmv_ell(indices, data, x, tile_rows=tile_rows,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(block_spmv_ell_ref(
                                   indices, data, x)), rtol=1e-12)


def test_block_spmv_end_to_end_matches_core():
    A = random_bcsr(RNG, 20, 20, 3, 3, density=0.2)
    x = jnp.asarray(RNG.standard_normal(60))
    got = spmv(A, x, use_kernel=True, interpret=True)
    want = spmv_ell(A.to_ell(), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("nbr,kmax,br,bc,k",
                         [(5, 3, 3, 3, 1), (16, 7, 3, 6, 4),
                          (33, 2, 6, 6, 8), (8, 4, 1, 1, 3),
                          (64, 9, 6, 3, 16), (3, 1, 2, 5, 2)])
def test_block_spmm_kernel_sweep(nbr, kmax, br, bc, k, dtype):
    nbc = nbr + 3
    indices = jnp.asarray(RNG.integers(0, nbc, (nbr, kmax)), jnp.int32)
    data = jnp.asarray(RNG.standard_normal((nbr, kmax, br, bc)), dtype)
    x = jnp.asarray(RNG.standard_normal((nbc, bc, k)), dtype)
    got = block_spmm_ell(indices, data, x, interpret=True)
    want = block_spmm_ell_ref(indices, data, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("tile_rows,pad_k_to", [(1, 1), (4, 4), (8, 8),
                                                (32, 2)])
def test_block_spmm_wrapper_tile_and_pad_invariance(tile_rows, pad_k_to):
    A = random_bcsr(RNG, 13, 10, 3, 3, density=0.3)
    ell = A.to_ell()
    X = jnp.asarray(RNG.standard_normal((A.shape[1], 5)))
    got = block_spmm(ell, X, interpret=True, tile_rows=tile_rows,
                     pad_k_to=pad_k_to)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(spmm_ell(ell, X)), rtol=1e-12)


def test_block_spmm_end_to_end_matches_core():
    A = random_bcsr(RNG, 20, 20, 3, 3, density=0.2)
    X = jnp.asarray(RNG.standard_normal((60, 4)))
    got = spmm(A, X, path="kernel", interpret=True)
    want = spmm_ell(A.to_ell(), X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("npairs,br,bk,bc",
                         [(1, 3, 3, 3), (7, 3, 3, 6), (130, 6, 3, 6),
                          (256, 6, 6, 6), (9, 1, 1, 1), (50, 2, 4, 5)])
def test_block_pair_gemm_sweep(npairs, br, bk, bc, dtype):
    lhs = jnp.asarray(RNG.standard_normal((npairs, br, bk)), dtype)
    rhs = jnp.asarray(RNG.standard_normal((npairs, bk, bc)), dtype)
    got = block_pair_gemm(lhs, rhs, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(block_pair_gemm_ref(lhs, rhs)),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("n,nseg,br,bc",
                         [(12, 5, 3, 3), (100, 1, 3, 6), (64, 64, 6, 6),
                          (300, 37, 1, 1), (5, 9, 2, 2)])
def test_block_seg_sum_sweep(n, nseg, br, bc, dtype):
    # sorted segment ids, some segments possibly empty
    ids = np.sort(RNG.integers(0, nseg, n)).astype(np.int32)
    vals = jnp.asarray(RNG.standard_normal((n, br, bc)), dtype)
    got = block_seg_sum(vals, jnp.asarray(ids), nseg, interpret=True)
    want = block_seg_sum_ref(vals, jnp.asarray(ids), nseg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("tile_n", [1, 16, 256])
def test_block_seg_sum_carry_across_tiles(tile_n):
    """The cross-tile carry is the subtle part — sweep tile boundaries."""
    n, nseg = 40, 7
    ids = np.sort(RNG.integers(0, nseg, n)).astype(np.int32)
    vals = jnp.asarray(RNG.standard_normal((n, 3, 3)))
    got = block_seg_sum(vals, jnp.asarray(ids), nseg, tile_n=tile_n,
                        interpret=True)
    want = block_seg_sum_ref(vals, jnp.asarray(ids), nseg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_spgemm_with_kernels_matches_ref():
    A = random_bcsr(RNG, 10, 8, 3, 3)
    B = random_bcsr(RNG, 8, 6, 3, 6)
    plan = spgemm_symbolic(A, B)
    C_k = spgemm_numeric(plan, A, B, use_kernel=True, interpret=True)
    C_r = spgemm_numeric(plan, A, B)
    np.testing.assert_allclose(np.asarray(C_k.data), np.asarray(C_r.data),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("nbr,bs", [(4, 3), (100, 6), (17, 3), (1, 1)])
def test_pbjacobi_sweep(nbr, bs, dtype):
    dinv = jnp.asarray(RNG.standard_normal((nbr, bs, bs)), dtype)
    r = jnp.asarray(RNG.standard_normal((nbr, bs)), dtype)
    x = jnp.asarray(RNG.standard_normal((nbr, bs)), dtype)
    got = pbjacobi_update(dinv, r, x, 0.7, interpret=True)
    want = pbjacobi_update_ref(dinv, r, x, jnp.asarray(0.7, dtype))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))
