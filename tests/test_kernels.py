"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Each kernel is swept over shapes and dtypes per the deliverables spec —
f64 and f32 with native accumulation, bf16 with the explicit fp32
accumulator (the ``accum_dtype`` rule every kernel family shares; see
``src/repro/kernels/README.md``).  The blocked SpMV/SpGEMM paths are
additionally validated end-to-end against the core references.
"""
import ml_dtypes
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax.numpy as jnp

from repro.core.spmv import spmm, spmm_ell, spmv, spmv_ell
from repro.core.spgemm import spgemm_symbolic, spgemm_numeric
from repro.kernels.block_spmv.block_spmv import block_spmv_ell
from repro.kernels.block_spmv.ref import block_spmv_ell_ref
from repro.kernels.block_spmm.block_spmm import block_spmm_ell
from repro.kernels.block_spmm.ops import block_spmm
from repro.kernels.block_spmm.ref import block_spmm_ell_ref
from repro.kernels.block_pair_gemm.block_pair_gemm import block_pair_gemm
from repro.kernels.block_pair_gemm.ref import block_pair_gemm_ref
from repro.kernels.block_seg_sum.ops import block_seg_sum
from repro.kernels.block_seg_sum.ref import block_seg_sum_ref
from repro.kernels.pbjacobi.pbjacobi import pbjacobi_update
from repro.kernels.pbjacobi.ref import pbjacobi_update_ref

from helpers import random_bcsr

RNG = np.random.default_rng(7)

# dtype rows of the kernel sweeps: (value dtype, accum_dtype knob).  bf16
# uses the explicit fp32 accumulator — the supported low-precision mode.
DTYPES = [(np.float64, None), (np.float32, None),
          (ml_dtypes.bfloat16, np.float32)]
DTYPE_IDS = ["f64", "f32", "bf16"]


def _tol(dtype):
    if dtype == np.float64:
        return dict(rtol=1e-12, atol=1e-12)
    if dtype == ml_dtypes.bfloat16:
        # kernel and oracle share the fp32-accumulate/round-to-bf16 rule;
        # the slack covers reduction-order ulps at bf16 resolution
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=2e-5, atol=2e-5)


def _cast(a, dtype):
    """Numpy fp arrays -> jnp at the sweep dtype (bf16 via ml_dtypes)."""
    return jnp.asarray(np.asarray(a).astype(dtype))


@pytest.mark.parametrize("dtype,accum", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("nbr,kmax,br,bc",
                         [(5, 3, 3, 3), (16, 7, 3, 6), (33, 2, 6, 6),
                          (8, 4, 1, 1), (64, 9, 6, 3), (3, 1, 2, 5)])
def test_block_spmv_kernel_sweep(nbr, kmax, br, bc, dtype, accum):
    nbc = nbr + 3
    indices = jnp.asarray(RNG.integers(0, nbc, (nbr, kmax)), jnp.int32)
    data = _cast(RNG.standard_normal((nbr, kmax, br, bc)), dtype)
    x = _cast(RNG.standard_normal((nbc, bc)), dtype)
    got = block_spmv_ell(indices, data, x, interpret=True,
                         accum_dtype=accum)
    want = block_spmv_ell_ref(indices, data, x, accum_dtype=accum)
    assert got.dtype == data.dtype
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **_tol(dtype))


@pytest.mark.parametrize("tile_rows", [1, 4, 8, 32])
def test_block_spmv_kernel_tile_invariance(tile_rows):
    indices = jnp.asarray(RNG.integers(0, 10, (13, 5)), jnp.int32)
    data = jnp.asarray(RNG.standard_normal((13, 5, 3, 3)))
    x = jnp.asarray(RNG.standard_normal((10, 3)))
    got = block_spmv_ell(indices, data, x, tile_rows=tile_rows,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(block_spmv_ell_ref(
                                   indices, data, x)), rtol=1e-12)


def test_block_spmv_end_to_end_matches_core():
    A = random_bcsr(RNG, 20, 20, 3, 3, density=0.2)
    x = jnp.asarray(RNG.standard_normal(60))
    got = spmv(A, x, use_kernel=True, interpret=True)
    want = spmv_ell(A.to_ell(), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


@pytest.mark.parametrize("dtype,accum", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("nbr,kmax,br,bc,k",
                         [(5, 3, 3, 3, 1), (16, 7, 3, 6, 4),
                          (33, 2, 6, 6, 8), (8, 4, 1, 1, 3),
                          (64, 9, 6, 3, 16), (3, 1, 2, 5, 2)])
def test_block_spmm_kernel_sweep(nbr, kmax, br, bc, k, dtype, accum):
    nbc = nbr + 3
    indices = jnp.asarray(RNG.integers(0, nbc, (nbr, kmax)), jnp.int32)
    data = _cast(RNG.standard_normal((nbr, kmax, br, bc)), dtype)
    x = _cast(RNG.standard_normal((nbc, bc, k)), dtype)
    got = block_spmm_ell(indices, data, x, interpret=True,
                         accum_dtype=accum)
    want = block_spmm_ell_ref(indices, data, x, accum_dtype=accum)
    assert got.dtype == data.dtype
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **_tol(dtype))


@pytest.mark.parametrize("tile_rows,pad_k_to", [(1, 1), (4, 4), (8, 8),
                                                (32, 2)])
def test_block_spmm_wrapper_tile_and_pad_invariance(tile_rows, pad_k_to):
    A = random_bcsr(RNG, 13, 10, 3, 3, density=0.3)
    ell = A.to_ell()
    X = jnp.asarray(RNG.standard_normal((A.shape[1], 5)))
    got = block_spmm(ell, X, interpret=True, tile_rows=tile_rows,
                     pad_k_to=pad_k_to)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(spmm_ell(ell, X)), rtol=1e-12)


def test_block_spmm_end_to_end_matches_core():
    A = random_bcsr(RNG, 20, 20, 3, 3, density=0.2)
    X = jnp.asarray(RNG.standard_normal((60, 4)))
    got = spmm(A, X, path="kernel", interpret=True)
    want = spmm_ell(A.to_ell(), X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


@pytest.mark.parametrize("dtype,accum", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("npairs,br,bk,bc",
                         [(1, 3, 3, 3), (7, 3, 3, 6), (130, 6, 3, 6),
                          (256, 6, 6, 6), (9, 1, 1, 1), (50, 2, 4, 5)])
def test_block_pair_gemm_sweep(npairs, br, bk, bc, dtype, accum):
    lhs = _cast(RNG.standard_normal((npairs, br, bk)), dtype)
    rhs = _cast(RNG.standard_normal((npairs, bk, bc)), dtype)
    got = block_pair_gemm(lhs, rhs, interpret=True, accum_dtype=accum)
    want = block_pair_gemm_ref(lhs, rhs, accum_dtype=accum)
    assert got.dtype == lhs.dtype
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **_tol(dtype))


@pytest.mark.parametrize("dtype,accum", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("n,nseg,br,bc",
                         [(12, 5, 3, 3), (100, 1, 3, 6), (64, 64, 6, 6),
                          (300, 37, 1, 1), (5, 9, 2, 2)])
def test_block_seg_sum_sweep(n, nseg, br, bc, dtype, accum):
    # sorted segment ids, some segments possibly empty
    ids = np.sort(RNG.integers(0, nseg, n)).astype(np.int32)
    vals = _cast(RNG.standard_normal((n, br, bc)), dtype)
    got = block_seg_sum(vals, jnp.asarray(ids), nseg, interpret=True,
                        accum_dtype=accum)
    want = block_seg_sum_ref(vals, jnp.asarray(ids), nseg,
                             accum_dtype=accum)
    assert got.dtype == vals.dtype
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **_tol(dtype))


@pytest.mark.parametrize("tile_n", [1, 16, 256])
def test_block_seg_sum_carry_across_tiles(tile_n):
    """The cross-tile carry is the subtle part — sweep tile boundaries."""
    n, nseg = 40, 7
    ids = np.sort(RNG.integers(0, nseg, n)).astype(np.int32)
    vals = jnp.asarray(RNG.standard_normal((n, 3, 3)))
    got = block_seg_sum(vals, jnp.asarray(ids), nseg, tile_n=tile_n,
                        interpret=True)
    want = block_seg_sum_ref(vals, jnp.asarray(ids), nseg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_spgemm_with_kernels_matches_ref():
    A = random_bcsr(RNG, 10, 8, 3, 3)
    B = random_bcsr(RNG, 8, 6, 3, 6)
    plan = spgemm_symbolic(A, B)
    C_k = spgemm_numeric(plan, A, B, use_kernel=True, interpret=True)
    C_r = spgemm_numeric(plan, A, B)
    np.testing.assert_allclose(np.asarray(C_k.data), np.asarray(C_r.data),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype,accum", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("nbr,bs", [(4, 3), (100, 6), (17, 3), (1, 1)])
def test_pbjacobi_sweep(nbr, bs, dtype, accum):
    dinv = _cast(RNG.standard_normal((nbr, bs, bs)), dtype)
    r = _cast(RNG.standard_normal((nbr, bs)), dtype)
    x = _cast(RNG.standard_normal((nbr, bs)), dtype)
    got = pbjacobi_update(dinv, r, x, 0.7, interpret=True,
                          accum_dtype=accum)
    want = pbjacobi_update_ref(dinv, r, x, 0.7, accum_dtype=accum)
    assert got.dtype == dinv.dtype
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **_tol(dtype))
