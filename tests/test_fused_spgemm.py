"""Fused tiled SpGEMM path vs the einsum+segment_sum oracle.

Property coverage per the deliverables: rectangular block mixes
(3x3 @ 3x6, 6x3 @ 3x3, 6x6 @ 6x6), empty block rows, padded tile edges
(tile_slots sweeps), and the structural guarantee the fusion exists for —
no ``(npairs, br, bc)`` pair-product intermediate anywhere in the jaxpr.
All Pallas execution is interpret-mode (CPU CI).
"""
import ml_dtypes
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax
import jax.numpy as jnp

from repro.core.block_csr import BlockCSR
from repro.core.spgemm import spgemm_symbolic, spgemm_numeric_data
from repro.kernels.fused_pair_gemm.fused_pair_gemm import fused_pair_gemm
from repro.kernels.fused_pair_gemm.ref import fused_pair_gemm_ref

from helpers import random_bcsr

RNG = np.random.default_rng(11)


def _tol(dtype):
    if dtype == np.float64:
        return dict(rtol=1e-12, atol=1e-12)
    if dtype == ml_dtypes.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Kernel-level: fused contract+reduce vs pure-jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,accum",
                         [(np.float64, None), (np.float32, None),
                          (ml_dtypes.bfloat16, np.float32)],
                         ids=["f64", "f32", "bf16"])
@pytest.mark.parametrize("nslots,kmax,br,bk,bc",
                         [(1, 1, 3, 3, 3), (7, 3, 3, 3, 6), (33, 5, 6, 3, 6),
                          (64, 2, 6, 6, 6), (9, 4, 1, 1, 1), (20, 7, 2, 4, 5)])
def test_fused_pair_gemm_sweep(nslots, kmax, br, bk, bc, dtype, accum):
    lhs = jnp.asarray(
        RNG.standard_normal((nslots, kmax, br, bk)).astype(dtype))
    rhs = jnp.asarray(
        RNG.standard_normal((nslots, kmax, bk, bc)).astype(dtype))
    got = fused_pair_gemm(lhs, rhs, interpret=True, accum_dtype=accum)
    want = fused_pair_gemm_ref(lhs, rhs, accum_dtype=accum)
    assert got.dtype == lhs.dtype
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **_tol(dtype))


@pytest.mark.parametrize("tile_slots", [1, 3, 8, 64])
def test_fused_pair_gemm_tile_edge_invariance(tile_slots):
    """Padded tile edges: nslots not divisible by the grid tile."""
    lhs = jnp.asarray(RNG.standard_normal((13, 4, 3, 3)))
    rhs = jnp.asarray(RNG.standard_normal((13, 4, 3, 6)))
    got = fused_pair_gemm(lhs, rhs, tile_slots=tile_slots, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(fused_pair_gemm_ref(lhs, rhs)),
                               rtol=1e-12)


def test_fused_pair_gemm_zero_width():
    got = fused_pair_gemm(jnp.zeros((5, 0, 3, 3)), jnp.zeros((5, 0, 3, 6)),
                          interpret=True)
    assert got.shape == (5, 3, 6) and not np.asarray(got).any()


# ---------------------------------------------------------------------------
# Plan-level: tiled layout is an exact re-packing of the pair list
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n1,n2,br,bk,bc",
                         [(10, 8, 3, 3, 6), (8, 10, 6, 3, 3), (6, 6, 6, 6, 6),
                          (12, 5, 1, 2, 4)])
def test_tiled_layout_matches_pair_list(n1, n2, br, bk, bc):
    A = random_bcsr(RNG, n1, n2, br, bk, density=0.35)
    B = random_bcsr(RNG, n2, n1 + 1, bk, bc, density=0.35)
    plan = spgemm_symbolic(A, B)
    assert plan.bk == bk
    assert plan.tile_pair_a.shape == (plan.tile_rows, plan.pair_kmax)
    nonempty = int((np.bincount(plan.out_idx, minlength=plan.nnzb) > 0).sum())
    assert plan.tile_rows >= nonempty
    assert int(plan.tile_mask.sum()) == plan.npairs
    assert (np.diff(plan.tile_seg) >= 0).all(), "rows must stay sorted"
    # every (slot, pair) of the flat list appears in one of its slot's rows
    slot_pairs = {}
    for r in range(plan.tile_rows):
        s = int(plan.tile_seg[r])
        for a, b in zip(plan.tile_pair_a[r][plan.tile_mask[r]],
                        plan.tile_pair_b[r][plan.tile_mask[r]]):
            slot_pairs.setdefault(s, set()).add((a, b))
    for p in range(plan.npairs):
        assert (plan.pair_a[p], plan.pair_b[p]) in \
            slot_pairs[int(plan.out_idx[p])]
    assert plan.plan_tiled_bytes > 0
    assert 0 < plan.tile_fill <= 1.0


@pytest.mark.parametrize("n1,n2,br,bk,bc",
                         [(10, 8, 3, 3, 6), (8, 10, 6, 3, 3),
                          (6, 6, 6, 6, 6)])
@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_fused_numeric_matches_oracle(n1, n2, br, bk, bc, dtype):
    A = random_bcsr(RNG, n1, n2, br, bk, density=0.3, dtype=dtype)
    B = random_bcsr(RNG, n2, n1, bk, bc, density=0.3, dtype=dtype)
    plan = spgemm_symbolic(A, B)
    ref = spgemm_numeric_data(plan, A.data, B.data, path="reference")
    fused = spgemm_numeric_data(plan, A.data, B.data, path="fused",
                                interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               **_tol(dtype))


def test_fused_numeric_empty_block_rows():
    """Rows of A with zero stored blocks -> empty C rows, zero pairs."""
    indptr = np.array([0, 2, 2, 3, 3], dtype=np.int64)   # rows 1, 3 empty
    indices = np.array([0, 2, 1], dtype=np.int32)
    data = RNG.standard_normal((3, 3, 3))
    A = BlockCSR.from_arrays(indptr, indices, data, 3)
    B = random_bcsr(RNG, 3, 4, 3, 6, density=0.5)
    plan = spgemm_symbolic(A, B)
    ref = spgemm_numeric_data(plan, A.data, B.data, path="reference")
    fused = spgemm_numeric_data(plan, A.data, B.data, path="fused",
                                interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-12, atol=1e-12)


def test_fused_no_pair_product_intermediate():
    """The point of the fusion: the jaxpr must not contain any value of
    shape (npairs, br, bc) — the materialized pair-product array."""
    rng = np.random.default_rng(123)
    A = random_bcsr(rng, 16, 12, 3, 3, density=0.5)
    B = random_bcsr(rng, 12, 14, 3, 6, density=0.5)
    plan = spgemm_symbolic(A, B)
    # preconditions that keep the shape check meaningful: multi-pair tiles
    # and strictly fewer tile rows than pairs
    assert plan.pair_kmax > 1 and plan.tile_rows < plan.npairs
    assert plan.npairs != plan.nnzb
    bad = (plan.npairs, plan.br, plan.bc)

    def walk(jaxpr, acc):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    acc.append(tuple(aval.shape))
            for val in eqn.params.values():
                if isinstance(val, jax.core.ClosedJaxpr):
                    walk(val.jaxpr, acc)
                elif isinstance(val, jax.core.Jaxpr):
                    walk(val, acc)
        return acc

    fused_fn = lambda a, b: spgemm_numeric_data(  # noqa: E731
        plan, a, b, path="fused", interpret=True)
    jaxpr = jax.make_jaxpr(fused_fn)(A.data, B.data)
    fused_shapes = walk(jaxpr.jaxpr, [])
    assert bad not in fused_shapes, \
        f"fused path materialized a pair-product array {bad}"

    ref_fn = lambda a, b: spgemm_numeric_data(  # noqa: E731
        plan, a, b, path="reference")
    ref_shapes = walk(jax.make_jaxpr(ref_fn)(A.data, B.data).jaxpr, [])
    assert bad in ref_shapes, "oracle check is not sensitive"


def test_fused_ptap_on_elasticity_hierarchy():
    """Acceptance: fused A_c.data == oracle A_c.data on every level of the
    elasticity hierarchy (all block-size mixes of the Galerkin chain)."""
    from repro.core import gamg
    from repro.core.ptap import ptap_numeric_data
    from repro.fem.assemble import assemble_elasticity

    prob = assemble_elasticity(4)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=10)
    assert setupd.levels, "need at least one Galerkin level"
    a_data = prob.A.data * 1.25      # a "Newton step": new values
    for ls in setupd.levels:
        ref = ptap_numeric_data(ls.ptap_cache, a_data, ls.P.data,
                                path="reference")
        fused = ptap_numeric_data(ls.ptap_cache, a_data, ls.P.data,
                                  path="fused", interpret=True)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                                   rtol=1e-11, atol=1e-11)
        a_data = ref
