"""Multi-RHS subsystem: SpMM front door, batched V-cycle/PCG, solve server,
and the backend env-override dispatch contract.

The load-bearing invariants:

* ``spmm_ell(k=1)`` is *bitwise* ``spmv_ell`` (single-column delegation);
* the panel V-cycle and masked panel PCG are per-column identical to the
  looped single-RHS paths (same iteration counts, fp-tolerance solutions);
* the solve server buckets/pads request streams onto static panel widths
  and each request's answer matches a dedicated solve;
* ``REPRO_BACKEND`` / ``REPRO_SPGEMM_PATH`` / ``REPRO_SPMM_PATH`` flips
  mid-process change the resolved dispatch, and bad values raise
  ``ValueError`` (not assert — must survive ``python -O``).
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax.numpy as jnp

from repro.core import gamg
from repro.core.krylov import pcg
from repro.core.spmv import spmm, spmm_ell, spmv_ell
from repro.core.vcycle import vcycle
from repro.fem.assemble import assemble_elasticity
from repro.kernels import backend
from repro.multirhs import AMGSolveServer

from helpers import random_bcsr

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def prob():
    return assemble_elasticity(4)


@pytest.fixture(scope="module")
def solver(prob):
    # exact per-column iteration parity between the batched and looped
    # paths is an fp64 contract: pin the policy so a REPRO_PRECISION
    # override cannot weaken what this module asserts (the mixed-precision
    # batching behaviour is covered by tests/test_precision.py)
    return gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                           maxiter=100, precision="f64")


# ---------------------------------------------------------------------------
# SpMM front door
# ---------------------------------------------------------------------------

def test_spmm_ell_k1_is_exactly_spmv_ell():
    A = random_bcsr(RNG, 17, 13, 3, 6, density=0.3)
    ell = A.to_ell()
    x = jnp.asarray(RNG.standard_normal(A.shape[1]))
    got = spmm_ell(ell, x[:, None])
    want = spmv_ell(ell, x)
    assert got.shape == (A.shape[0], 1)
    np.testing.assert_array_equal(np.asarray(got[:, 0]), np.asarray(want))


def test_spmm_ell_matches_looped_spmv():
    A = random_bcsr(RNG, 20, 20, 3, 3, density=0.25)
    ell = A.to_ell()
    X = jnp.asarray(RNG.standard_normal((A.shape[1], 5)))
    Y = spmm_ell(ell, X)
    for j in range(5):
        np.testing.assert_allclose(np.asarray(Y[:, j]),
                                   np.asarray(spmv_ell(ell, X[:, j])),
                                   rtol=1e-13, atol=1e-13)


def test_spmm_front_door_kernel_matches_reference():
    A = random_bcsr(RNG, 15, 15, 3, 3, density=0.3)
    X = jnp.asarray(RNG.standard_normal((A.shape[1], 4)))
    got = spmm(A, X, path="kernel", interpret=True)
    want = spmm(A, X, path="reference")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# Batched V-cycle / coarse solve broadcast
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("smoother", ["chebyshev", "pbjacobi"])
def test_batched_vcycle_matches_looped(solver, prob, smoother):
    hier = solver.hierarchy
    R = jnp.asarray(RNG.standard_normal((prob.n, 4)))
    V = vcycle(hier, R, smoother=smoother)
    for j in range(4):
        vj = vcycle(hier, R[:, j], smoother=smoother)
        np.testing.assert_allclose(np.asarray(V[:, j]), np.asarray(vj),
                                   rtol=1e-12, atol=1e-12)


def test_coarse_cho_solve_broadcasts_over_columns(solver):
    """The coarse ``cho_solve`` accepts matrix RHS natively — the batched
    V-cycle leans on this, so pin it down explicitly."""
    import jax
    chol = solver.hierarchy.coarse_chol
    nc = chol.shape[0]
    R = jnp.asarray(RNG.standard_normal((nc, 3)))
    X = jax.scipy.linalg.cho_solve((chol, True), R)
    for j in range(3):
        xj = jax.scipy.linalg.cho_solve((chol, True), R[:, j])
        np.testing.assert_allclose(np.asarray(X[:, j]), np.asarray(xj),
                                   rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# Masked panel PCG
# ---------------------------------------------------------------------------

def test_block_pcg_matches_looped_pcg_per_column(solver, prob):
    cols = [np.asarray(prob.b)]
    cols += [RNG.standard_normal(prob.n) for _ in range(3)]
    B = jnp.asarray(np.stack(cols, axis=1))
    res = solver.solve_many(B)
    assert bool(np.asarray(res.converged).all())
    for j in range(B.shape[1]):
        single = solver.solve(B[:, j])
        assert int(res.iters[j]) == int(single.iters), \
            f"col {j}: batched {int(res.iters[j])} != single {int(single.iters)}"
        np.testing.assert_allclose(np.asarray(res.x[:, j]),
                                   np.asarray(single.x), rtol=1e-6,
                                   atol=1e-10)


def test_block_pcg_masks_converged_columns(solver, prob):
    """A zero column is converged at iteration 0 and must stay frozen while
    the live columns iterate to convergence."""
    B = np.zeros((prob.n, 2))
    B[:, 1] = np.asarray(prob.b)
    res = solver.solve_many(jnp.asarray(B))
    assert int(res.iters[0]) == 0
    assert bool(res.converged[0])
    np.testing.assert_array_equal(np.asarray(res.x[:, 0]), 0.0)
    assert int(res.iters[1]) == int(solver.solve(jnp.asarray(B[:, 1])).iters)


def test_pcg_record_history(solver, prob):
    from repro.core.spmv import apply_ell

    def apply_a(v):
        return apply_ell(solver.hierarchy.levels[0].a_ell, v)

    def apply_m(r):
        return vcycle(solver.hierarchy, r)

    b = jnp.asarray(prob.b)
    res, hist = pcg(apply_a, apply_m, b, maxiter=50, record_history=True)
    h = np.asarray(hist)
    assert h.shape == (50,)
    k = int(res.iters)
    assert np.isfinite(h[:k]).all()
    assert np.isnan(h[k:]).all()
    bnorm = float(jnp.linalg.norm(b))
    np.testing.assert_allclose(h[k - 1] / bnorm, float(res.relres),
                               rtol=1e-12)
    # default path is unchanged: plain CGResult, no history buffer
    res2 = pcg(apply_a, apply_m, b, maxiter=50)
    assert int(res2.iters) == k


# ---------------------------------------------------------------------------
# Solve server
# ---------------------------------------------------------------------------

def test_server_buckets_pads_and_matches_dedicated_solves(solver, prob):
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(1, 2, 4),
                         rtol=1e-8, maxiter=100)
    rhs = [np.asarray(prob.b)] + [RNG.standard_normal(prob.n)
                                  for _ in range(2)]
    reports = srv.serve(rhs)
    assert [r.request_id for r in reports] == [0, 1, 2]
    assert all(r.k_bucket == 4 for r in reports)   # 3 rides in the 4-bucket
    assert srv.stats["padded_columns"] == 1
    assert srv.stats["solves_per_k"] == {1: 0, 2: 0, 4: 1}
    for r, b in zip(reports, rhs):
        single = solver.solve(jnp.asarray(b))
        assert r.converged and r.iters == int(single.iters)
        np.testing.assert_allclose(r.x, np.asarray(single.x), rtol=1e-6,
                                   atol=1e-10)


def test_server_chunks_streams_over_max_bucket(solver, prob):
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(2, 4),
                         rtol=1e-8, maxiter=100)
    for _ in range(6):
        srv.submit(RNG.standard_normal(prob.n))
    reports = srv.flush()
    assert len(reports) == 6 and not srv._pending
    # 6 requests -> one full 4-panel + one 2-panel, no padding anywhere
    assert srv.stats["solves_per_k"] == {2: 1, 4: 1}
    assert srv.stats["padded_columns"] == 0
    assert all(r.converged for r in reports)


def test_server_update_operator_refreshes_hierarchy(solver, prob):
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(1, 2),
                         rtol=1e-8, maxiter=100)
    srv.update_operator(prob.A.data * 1.5)
    [rep] = srv.serve([np.asarray(prob.b)])
    direct = gamg.make_solve(solver.setup_data, rtol=1e-8, maxiter=100)(
        srv.hierarchy, jnp.asarray(prob.b))
    assert rep.converged and rep.iters == int(direct.iters)
    np.testing.assert_allclose(rep.x, np.asarray(direct.x), rtol=1e-6,
                               atol=1e-10)
    assert srv.stats["recomputes"] == 1


def test_server_recompute_preserves_bucketing_and_accounting(solver, prob):
    """``update_operator`` -> ``solve_many`` interaction: a hierarchy
    recompute invalidates *nothing* in the server's bucketing (same static
    bucket set, same jitted solves, no queue disturbance), and the
    recompute accounting is exact on both front doors."""
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(1, 2, 4),
                         rtol=1e-8, maxiter=100)
    # a pending request survives a mid-stream recompute untouched
    srv.submit(np.asarray(prob.b))
    before = dict(srv.stats, solves_per_k=dict(srv.stats["solves_per_k"]))
    srv.update_operator(prob.A.data * 2.0)
    assert srv.buckets == (1, 2, 4)
    assert len(srv._pending) == 1
    assert srv.stats["requests"] == before["requests"]
    assert srv.stats["batches"] == before["batches"]
    assert srv.stats["padded_columns"] == before["padded_columns"]
    assert srv.stats["solves_per_k"] == before["solves_per_k"]
    [rep] = srv.flush()
    # served against the *new* operator: A -> 2A halves the solution
    single = solver.solve(jnp.asarray(prob.b))
    assert rep.converged
    np.testing.assert_allclose(rep.x, np.asarray(single.x) / 2.0,
                               rtol=1e-5, atol=1e-12)
    # exact recompute accounting, server and GAMGSolver front doors alike
    assert srv.stats["recomputes"] == 1
    srv.update_operator(prob.A.data)
    srv.update_operator(prob.A.data * 3.0)
    assert srv.stats["recomputes"] == 3
    g = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                        maxiter=100, precision="f64")
    assert g.n_recomputes == 0          # __init__'s build is not an update
    for i in range(3):
        g.update_operator(prob.A.data * (1.0 + i))
    assert g.n_recomputes == 3
    # the bucket machinery still serves correctly after all the recomputes
    reports = srv.serve([np.asarray(prob.b),
                         RNG.standard_normal(prob.n)])
    assert len(reports) == 2 and all(r.k_bucket == 2 for r in reports)
    assert srv.stats["solves_per_k"][2] == 1


def test_server_rejects_bad_inputs(solver, prob):
    with pytest.raises(ValueError):
        AMGSolveServer(solver.setup_data, prob.A.data, buckets=())
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(1,))
    with pytest.raises(ValueError):
        srv.submit(np.zeros(prob.n + 1))


def test_server_validates_buckets(solver, prob):
    """Bucket validation: empties, non-positive widths and duplicates all
    raise at construction — a duplicate means the caller thinks two
    distinct panel widths exist where only one solve would trace."""
    for bad in ((), (0, 2), (-1,), (2, 4, 2)):
        with pytest.raises(ValueError):
            AMGSolveServer(solver.setup_data, prob.A.data, buckets=bad)
    # unsorted input is fine; the server sorts
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(4, 1, 2))
    assert srv.buckets == (1, 2, 4)


def test_server_bucket_for_rejects_oversized_chunk(solver, prob):
    """``_bucket_for`` must raise rather than silently truncate: ``flush``
    caps chunks at the largest bucket, so a bigger count is a bookkeeping
    bug that would drop requests."""
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(1, 4))
    assert srv._bucket_for(3) == 4
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        srv._bucket_for(5)
    with pytest.raises(ValueError, match="at least one"):
        srv._bucket_for(0)


def test_server_empty_queue_flush(solver, prob):
    srv = AMGSolveServer(solver.setup_data, prob.A.data, buckets=(1, 2))
    assert srv.flush() == []
    assert srv.stats["batches"] == 0 and srv.stats["requests"] == 0


# ---------------------------------------------------------------------------
# Backend env-override dispatch (REPRO_BACKEND / REPRO_SPGEMM_PATH /
# REPRO_SPMM_PATH flipped mid-process)
# ---------------------------------------------------------------------------

def test_backend_override_flips_dispatch_mid_process(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "tpu")
    assert backend.backend() == "tpu"
    assert backend.resolve_use_kernel(None) is True
    assert backend.resolve_interpret(None) is False
    assert backend.resolve_spgemm_path(None) == "fused"
    assert backend.resolve_spmm_path(None) == "kernel"
    monkeypatch.setenv("REPRO_BACKEND", "cpu")
    assert backend.resolve_use_kernel(None) is False
    assert backend.resolve_interpret(None) is True
    assert backend.resolve_spgemm_path(None) == "reference"
    assert backend.resolve_spmm_path(None) == "reference"


def test_path_override_changes_numeric_dispatch(monkeypatch):
    """REPRO_SPGEMM_PATH really reroutes the numeric SpGEMM mid-process
    (pairs kernels run in interpret mode on CPU and must agree with the
    reference), and REPRO_SPMM_PATH reroutes the SpMM front door."""
    from repro.core.spgemm import spgemm_symbolic, spgemm_numeric_data
    A = random_bcsr(RNG, 8, 6, 3, 3)
    Bm = random_bcsr(RNG, 6, 7, 3, 6)
    plan = spgemm_symbolic(A, Bm)
    ref = spgemm_numeric_data(plan, A.data, Bm.data)
    monkeypatch.setenv("REPRO_SPGEMM_PATH", "pairs")
    got = spgemm_numeric_data(plan, A.data, Bm.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-12, atol=1e-12)

    X = jnp.asarray(RNG.standard_normal((A.shape[1], 3)))
    want = spmm(A, X)                       # cpu default: reference
    monkeypatch.setenv("REPRO_SPMM_PATH", "kernel")
    got2 = spmm(A, X)                       # env forces the Pallas kernel
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_spmm_path_engages_in_panel_vcycle(monkeypatch, solver, prob):
    """REPRO_SPMM_PATH=kernel reroutes the panel V-cycle's operator
    applications (``apply_ell``'s panel branch) through the Pallas
    block_spmm kernel — interpret mode on CPU — and must agree with the
    reference path it replaces."""
    R = jnp.asarray(RNG.standard_normal((prob.n, 2)))
    want = vcycle(solver.hierarchy, R)
    monkeypatch.setenv("REPRO_SPMM_PATH", "kernel")
    got = vcycle(solver.hierarchy, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_invalid_paths_raise_value_error(monkeypatch):
    with pytest.raises(ValueError):
        backend.resolve_spgemm_path("bogus")
    with pytest.raises(ValueError):
        backend.resolve_spmm_path("bogus")
    monkeypatch.setenv("REPRO_SPGEMM_PATH", "nope")
    with pytest.raises(ValueError):
        backend.resolve_spgemm_path(None)
    monkeypatch.setenv("REPRO_SPMM_PATH", "nope")
    with pytest.raises(ValueError):
        backend.resolve_spmm_path(None)
