"""Scalar hot-PtAP chain == expansion of the blocked chain (per level)."""
import numpy as np

import repro.core  # noqa: F401
from repro.core import gamg
from repro.core.ptap import ptap_numeric_data
from repro.core.scalar_csr import expand_bcsr
from repro.core.scalar_path import build_scalar_ptap_chain
from repro.fem.assemble import assemble_elasticity


def test_scalar_chain_matches_blocked():
    prob = assemble_elasticity(5)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30)
    assert len(setupd.levels) >= 1
    sc_chain = build_scalar_ptap_chain(setupd)
    scalar_outs = sc_chain(prob.A.data)
    a_data = prob.A.data
    for ls, s_out in zip(setupd.levels, scalar_outs):
        a_data = ptap_numeric_data(ls.ptap_cache, a_data, ls.P.data)
        Ac = ls.ptap_cache  # blocked coarse payloads in a_data
        blocked = type(prob.A)(Ac.ac_plan.indptr, Ac.ac_plan.indices,
                               a_data, Ac.n_coarse)
        expanded = expand_bcsr(blocked)
        np.testing.assert_allclose(np.asarray(s_out).reshape(-1),
                                   np.asarray(expanded.data).reshape(-1),
                                   rtol=1e-11, atol=1e-12)
