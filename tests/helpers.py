"""Shared test helpers: random blocked matrices + dense oracles."""
from __future__ import annotations

import numpy as np

import repro.core  # noqa: F401  (enables x64)
from repro.core.block_csr import BlockCSR


def random_bcsr(rng: np.random.Generator, nbr: int, nbc: int, br: int,
                bc: int, density: float = 0.3, ensure_diag: bool = False,
                dtype=np.float64) -> BlockCSR:
    """Random rectangular-block CSR with at least one block per row."""
    mask = rng.random((nbr, nbc)) < density
    for i in range(nbr):
        if not mask[i].any():
            mask[i, rng.integers(nbc)] = True
        if ensure_diag and nbr == nbc:
            mask[i, i] = True
    rows, cols = np.nonzero(mask)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    data = rng.standard_normal((len(rows), br, bc)).astype(dtype)
    return BlockCSR.from_arrays(indptr, cols.astype(np.int32), data, nbc)


def spd_bcsr(rng: np.random.Generator, nbr: int, bs: int,
             density: float = 0.25) -> BlockCSR:
    """Random symmetric positive definite blocked matrix (for solvers)."""
    A = random_bcsr(rng, nbr, nbr, bs, bs, density, ensure_diag=True)
    dense = np.asarray(A.to_dense())
    sym = 0.5 * (dense + dense.T)
    n = dense.shape[0]
    spd = sym + n * np.eye(n)  # diagonally dominant => SPD
    # rebuild blocked structure from the symmetrized dense (union pattern)
    blocks = spd.reshape(nbr, bs, nbr, bs).transpose(0, 2, 1, 3)
    bmask = (np.abs(blocks).max(axis=(2, 3)) > 0)
    rows, cols = np.nonzero(bmask)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    return BlockCSR.from_arrays(np.cumsum(indptr), cols.astype(np.int32),
                                blocks[rows, cols], nbr)
