"""Device-resident time march (``repro.sim``) — ISSUE 10 contracts.

Tier-1 pins (small grid, seconds):

* a 3-step adaptive march on the softening scenario completes healthy
  end to end (assembly -> recompute -> warm solve fused per step, host
  bookkeeping consistent);
* scan-vs-eager parity: the unrolled one-program march is **bitwise**
  the hand-rolled jitted-step Python loop at f64; the rolled production
  scan matches on every integer record exactly and on the trajectory to
  ~1e-13 (XLA compiles a rolled loop body with different reduction ULP
  behaviour than the identical step compiled top-level — see
  ``make_scan_march``);
* zero host round trips per frozen segment: one jit cache entry across
  repeated runs and an ``eval_shape`` trace of the full march program;
* hypothesis properties of the staleness monitor alone (no solves):
  monotone softening eventually trips, constant coefficients never do.

Slow-marked (nightly) — the acceptance battery on the m=5 softening
trajectory: the adaptive march reaches the per-step full re-setup
baseline's final state (1e-10) with strictly fewer setups, and spends
fewer total CG iterations than the frozen-hierarchy march.
"""
import numpy as np
import pytest

try:        # property tests run under hypothesis when available, and as
    # a deterministic seed sweep otherwise (the container may lack it)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import repro.core  # noqa: F401,E402  (x64 on)
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import gamg  # noqa: E402
from repro.fem.assemble import assemble_elasticity  # noqa: E402
from repro.robust import health  # noqa: E402
from repro.sim import (  # noqa: E402
    MarchConfig,
    SofteningScenario,
    StalenessConfig,
    ThermalScenario,
    init_carry,
    make_scan_march,
    make_segment,
    make_step_fn,
    march,
    staleness_init,
    staleness_update,
)
from repro.sim.driver import _setup_from_fields  # noqa: E402

SETUP_OPTS = {"coarse_size": 8}


@pytest.fixture(scope="module")
def prob():
    return assemble_elasticity(4)


@pytest.fixture(scope="module")
def scen(prob):
    return SofteningScenario.build(prob, rate=0.3, d_max=0.99)


@pytest.fixture(scope="module")
def setupd(prob, scen):
    carry = init_carry(scen, prob.b)
    E, nu, _ = scen.step_fields(carry.scen, carry.x, carry.step)
    return _setup_from_fields(prob, E, nu, SETUP_OPTS)


# ---------------------------------------------------------------------------
# Tier-1: quick march end to end
# ---------------------------------------------------------------------------

def test_quick_adaptive_march(prob, scen):
    """3 warm-started steps, adaptive mode: healthy, finite, consistent
    host bookkeeping (the CI tier-1 march)."""
    cfg = MarchConfig(n_steps=3, seg_len=8, rtol=1e-8)
    res = march(prob, scen, cfg, mode="adaptive", setup_opts=SETUP_OPTS)
    assert res.status == "ok"
    assert res.steps_done == 3
    assert (res.step_status == health.HEALTHY).all()
    assert res.worst_status == health.HEALTHY
    assert np.isfinite(np.asarray(res.x)).all()
    assert np.isfinite(res.relres).all() and (res.relres <= 1e-8).all()
    assert res.n_setups >= 1 and res.n_recoveries == 0
    assert sum(s.steps for s in res.segments) == 3
    assert res.total_iters == int(res.iters.sum()) > 0
    # the softening law actually softened: damage grew, E dropped
    assert float(np.asarray(res.scen_state).max()) > 0
    assert float(np.asarray(res.E).min()) < float(np.asarray(scen.E0).min())


def test_march_mode_and_path_validation(prob, scen):
    cfg = MarchConfig(n_steps=1)
    with pytest.raises(ValueError, match="invalid march mode"):
        march(prob, scen, cfg, mode="bogus", setup_opts=SETUP_OPTS)


def test_gamg_solver_march_front_door(prob, scen):
    """``GAMGSolver.march`` delegates to the sim driver."""
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=8, rtol=1e-8,
                             maxiter=200, precision="f64")
    cfg = MarchConfig(n_steps=2, seg_len=4)
    res = solver.march(prob, scen, cfg, mode="frozen",
                       setup_opts=SETUP_OPTS)
    assert res.status == "ok" and res.steps_done == 2


# ---------------------------------------------------------------------------
# Tier-1: scan-vs-eager parity + zero-host-transfer pins
# ---------------------------------------------------------------------------

def _frozen_cfg(n_steps=3):
    # a monitor that never trips: pure frozen-hierarchy march
    return MarchConfig(n_steps=n_steps, seg_len=8, rtol=1e-9,
                       staleness=StalenessConfig(iter_drift=10**6,
                                                 ref_window=1,
                                                 coeff_rtol=10**6))


def test_scan_vs_eager_bitwise_parity(prob, scen, setupd):
    """K steps of the one-program march == the hand-rolled jitted-step
    Python loop, **bitwise** at f64 (the ``unroll=True`` program), and
    the rolled production scan agrees on every integer record exactly
    with the trajectory inside 1e-13."""
    cfg = _frozen_cfg(3)
    b = prob.b
    carry0 = init_carry(scen, b)

    runner = make_scan_march(setupd, prob.assembler, scen, cfg,
                             unroll=True)
    c_scan, recs = runner(b, carry0)

    step_fn = make_step_fn(setupd, prob.assembler, scen, cfg)
    c = carry0
    eager_recs = []
    for _ in range(cfg.n_steps):
        c, rec, blocked = step_fn(c, b)
        assert not bool(blocked)
        eager_recs.append(rec)

    assert int(c_scan.step) == int(c.step) == cfg.n_steps
    np.testing.assert_array_equal(np.asarray(c_scan.x), np.asarray(c.x))
    for leaf_s, leaf_e in zip(jax.tree_util.tree_leaves(c_scan.scen),
                              jax.tree_util.tree_leaves(c.scen)):
        np.testing.assert_array_equal(np.asarray(leaf_s),
                                      np.asarray(leaf_e))
    assert np.asarray(recs.iters).tolist() == \
        [int(r.iters) for r in eager_recs]
    assert np.asarray(recs.status).tolist() == \
        [int(r.status) for r in eager_recs]

    # the rolled default: exact integer records, trajectory to ~1e-13
    # (XLA's rolled loop body computes reductions with different ULP
    # rounding than the top-level-compiled step; warm-start path only)
    rolled = make_scan_march(setupd, prob.assembler, scen, cfg)
    c_roll, recs_roll = rolled(b, carry0)
    assert np.array_equal(np.asarray(recs_roll.iters),
                          np.asarray(recs.iters))
    assert np.array_equal(np.asarray(recs_roll.status),
                          np.asarray(recs.status))
    np.testing.assert_allclose(np.asarray(c_roll.x), np.asarray(c.x),
                               rtol=0, atol=1e-13)


def test_frozen_march_single_trace_zero_host_transfers(prob, scen, setupd):
    """The zero-host-transfer acceptance pins: the frozen march and the
    adaptive segment each compile ONCE (jit cache stays at one entry
    across repeated calls), and the whole march program shape-evaluates
    abstractly — a host round trip inside the traced program would make
    ``eval_shape`` impossible."""
    cfg = _frozen_cfg(3)
    b = prob.b
    carry0 = init_carry(scen, b)

    runner = make_scan_march(setupd, prob.assembler, scen, cfg)
    c1, _ = runner(b, carry0)
    runner(b, c1._replace(step=jnp.asarray(0, jnp.int32)))
    assert runner._cache_size() == 1, runner._cache_size()

    abstract = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), (b, carry0))
    out = jax.eval_shape(runner, *abstract)
    c_shape, recs_shape = out
    assert c_shape.x.shape == b.shape
    assert recs_shape.iters.shape == (cfg.n_steps,)

    seg = make_segment(setupd, prob.assembler, scen, cfg)
    n = jnp.asarray(3, jnp.int32)
    _, c2, _, _ = seg(b, carry0, n)
    # a different (traced) budget must NOT retrace
    seg(b, c2._replace(step=jnp.asarray(0, jnp.int32)),
        jnp.asarray(2, jnp.int32))
    assert seg._cache_size() == 1, seg._cache_size()
    jax.eval_shape(seg, *jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.asarray(a).dtype),
        (b, carry0, n)))


# ---------------------------------------------------------------------------
# Tier-1: staleness-monitor properties (pure monitor, no solves)
# ---------------------------------------------------------------------------

def _check_monotone_softening_trips(ne, seed, rate, coeff_rtol):
    """A monotone multiplicative softening walks the coefficient field
    arbitrarily far from the rebuild reference, so the drift criterion
    must fire in finitely many steps for any positive tolerance."""
    rng = np.random.default_rng(seed)
    E0 = jnp.asarray(1.0 + rng.random(ne))
    cfg = StalenessConfig(iter_drift=10**6, ref_window=1,
                          coeff_rtol=coeff_rtol)
    state = staleness_init(E0)
    E = np.asarray(E0)
    softening = 1.0 - rate * (0.5 + 0.5 * rng.random(ne))
    for _ in range(200):
        E = E * softening
        state = staleness_update(state, jnp.asarray(5, jnp.int32),
                                 jnp.asarray(E), cfg)
        if bool(state.tripped):
            return
    raise AssertionError(
        f"monotone softening never tripped: drift={float(state.coeff_drift)}")


def _check_constant_coefficients_quiet(ne, seed, iters, n_steps,
                                       iter_drift, coeff_rtol,
                                       ref_window):
    """Zero drift and flat iteration counts: the monitor must stay quiet
    for every configuration — a trip here would make the adaptive march
    degenerate into per-step re-setup."""
    rng = np.random.default_rng(seed)
    E0 = jnp.asarray(1.0 + rng.random(ne))
    cfg = StalenessConfig(iter_drift=iter_drift, ref_window=ref_window,
                          coeff_rtol=coeff_rtol)
    state = staleness_init(E0)
    for _ in range(n_steps):
        state = staleness_update(state, jnp.asarray(iters, jnp.int32),
                                 E0, cfg)
        assert not bool(state.tripped)
        assert float(state.coeff_drift) == 0.0


if HAVE_HYPOTHESIS:
    @given(ne=st.integers(4, 64), seed=st.integers(0, 2**31 - 1),
           rate=st.floats(0.01, 0.2), coeff_rtol=st.floats(0.05, 0.5))
    @settings(max_examples=25, deadline=None)
    def test_monotone_softening_eventually_trips(ne, seed, rate,
                                                 coeff_rtol):
        _check_monotone_softening_trips(ne, seed, rate, coeff_rtol)

    @given(ne=st.integers(4, 64), seed=st.integers(0, 2**31 - 1),
           iters=st.integers(1, 50), n_steps=st.integers(1, 40),
           iter_drift=st.integers(0, 10),
           coeff_rtol=st.floats(1e-3, 1.0), ref_window=st.integers(1, 5))
    @settings(max_examples=25, deadline=None)
    def test_constant_coefficients_never_trip(ne, seed, iters, n_steps,
                                              iter_drift, coeff_rtol,
                                              ref_window):
        _check_constant_coefficients_quiet(ne, seed, iters, n_steps,
                                           iter_drift, coeff_rtol,
                                           ref_window)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_monotone_softening_eventually_trips(seed):
        rng = np.random.default_rng(1000 + seed)
        _check_monotone_softening_trips(
            int(rng.integers(4, 64)), seed,
            float(rng.uniform(0.01, 0.2)), float(rng.uniform(0.05, 0.5)))

    @pytest.mark.parametrize("seed", range(12))
    def test_constant_coefficients_never_trip(seed):
        rng = np.random.default_rng(2000 + seed)
        _check_constant_coefficients_quiet(
            int(rng.integers(4, 64)), seed, int(rng.integers(1, 50)),
            int(rng.integers(1, 40)), int(rng.integers(0, 10)),
            float(rng.uniform(1e-3, 1.0)), int(rng.integers(1, 5)))


def test_thermal_cycle_stays_below_tolerance(prob):
    """The counter-workload: a periodic modulation bounded below the
    drift tolerance cycles forever without a trip."""
    scen = ThermalScenario.build(prob, amp=0.2, period=8.0)
    cfg = StalenessConfig(iter_drift=10**6, ref_window=1, coeff_rtol=0.5)
    x = jnp.zeros_like(prob.b)
    E_ref, _, _ = scen.step_fields((), x, jnp.asarray(0, jnp.int32))
    state = staleness_init(E_ref)
    for s in range(1, 17):      # two full periods
        E, _, _ = scen.step_fields((), x, jnp.asarray(s, jnp.int32))
        state = staleness_update(state, jnp.asarray(7, jnp.int32), E, cfg)
        assert not bool(state.tripped), (s, float(state.coeff_drift))


# ---------------------------------------------------------------------------
# Nightly: the acceptance battery (m=5 softening trajectory)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_march_acceptance_adaptive_vs_frozen_vs_resetup():
    """ISSUE 10 acceptance: on the built-in softening scenario the
    adaptive march reaches the same final state (1e-10) as per-step full
    re-setup while doing strictly fewer setups, and spends fewer total
    CG iterations than the frozen-hierarchy march on the same
    trajectory (the hypothesis-stated ``adaptive <= frozen`` property,
    pinned strictly here)."""
    prob = assemble_elasticity(5)
    scen = SofteningScenario.build(prob, rate=0.25, d_max=0.99)
    cfg = MarchConfig(n_steps=8, seg_len=8, rtol=1e-10, maxiter=400,
                      staleness=StalenessConfig(iter_drift=2,
                                                ref_window=2,
                                                coeff_rtol=0.25))
    runs = {mode: march(prob, scen, cfg, mode=mode,
                        setup_opts=SETUP_OPTS)
            for mode in ("frozen", "adaptive", "resetup")}
    for mode, res in runs.items():
        assert res.status == "ok", (mode, res.status)
        assert res.steps_done == cfg.n_steps, mode
        assert (res.step_status == health.HEALTHY).all(), mode

    adaptive, frozen, resetup = (runs["adaptive"], runs["frozen"],
                                 runs["resetup"])
    # same physics: the adaptive final state matches the per-step
    # re-setup baseline to the march tolerance
    x_ref = np.asarray(resetup.x)
    rel = (np.linalg.norm(np.asarray(adaptive.x) - x_ref)
           / np.linalg.norm(x_ref))
    assert rel <= 1e-10, rel
    # strictly fewer setups than the baseline, strictly fewer total CG
    # iterations than never re-coarsening
    assert adaptive.n_setups < resetup.n_setups, \
        (adaptive.n_setups, resetup.n_setups)
    assert adaptive.total_iters < frozen.total_iters, \
        (adaptive.total_iters, frozen.total_iters)
    # the frozen hierarchy genuinely degraded on this trajectory —
    # otherwise the comparison above is vacuous
    assert frozen.iters[-1] > frozen.iters[0], frozen.iters.tolist()
