"""ISSUE 5 test battery: device-resident heterogeneous FEM assembly.

Golden parity (device JAX assembly == host numpy assembly, f64 tight),
structural invariants (block-stream symmetry, SPD after BC elimination,
rigid-body modes annihilated on the free interior), and the end-to-end
jitted coefficient hot loop (update_coefficients -> recompute -> solve)
with pinned iteration counts, a no-retrace guarantee and no host
round-trips on the hot path.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
import jax
import jax.numpy as jnp

from repro.core import gamg
from repro.fem.assemble import (
    assemble_elasticity,
    element_centroids,
    inclusion_fields,
)
from repro.fem.hex_elasticity import hex_mesh

# the m=5 rungs are the heavy tail of the sweep (host golden loops every
# element); tier-1 keeps m in {3, 4}, nightly runs the full ladder
PARITY_CASES = [
    pytest.param(m, order, varying,
                 marks=([pytest.mark.slow] if m == 5 else []),
                 id=f"m{m}-q{order}-{'varying' if varying else 'const'}")
    for m in (3, 4, 5) for order in (1, 2) for varying in (False, True)
]


def _fields(m: int, order: int, varying: bool):
    mesh = hex_mesh(m, order)
    if not varying:
        return 1.0, 0.3
    # smooth positive fields sampled at element centroids
    c = element_centroids(mesh)
    E = 1.0 + 4.0 * c[:, 0] + 2.0 * c[:, 1] * c[:, 2]
    nu = 0.20 + 0.15 * c[:, 2]
    return E, nu


@pytest.mark.parametrize("m,order,varying", PARITY_CASES)
def test_device_matches_host_golden(m, order, varying):
    """Device assembly == host numpy golden reference, f64-tight, for
    constant and spatially varying E/nu, Q1 and Q2."""
    E, nu = _fields(m, order, varying)
    dev = assemble_elasticity(m, order=order, E=E, nu=nu, path="device")
    host = assemble_elasticity(m, order=order, E=E, nu=nu, path="host")
    assert dev.assembler is not None and host.assembler is None
    np.testing.assert_allclose(np.asarray(dev.A.data),
                               np.asarray(host.A.data),
                               rtol=1e-12, atol=1e-13)
    np.testing.assert_allclose(np.asarray(dev.b), np.asarray(host.b),
                               rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(dev.B), np.asarray(host.B),
                               rtol=0, atol=0)


@pytest.mark.parametrize("order", [1, 2])
def test_block_stream_symmetry(order):
    """Structural invariant: the element block stream is symmetric —
    vals[e, a, b] == vals[e, b, a]^T (each Ke is symmetric)."""
    E, nu = _fields(3, order, True)
    prob = assemble_elasticity(3, order=order, E=E, nu=nu)
    nn = prob.assembler.nn
    vals = np.asarray(prob.values).reshape(-1, nn, nn, 3, 3)
    np.testing.assert_allclose(
        vals, vals.transpose(0, 2, 1, 4, 3), rtol=1e-13, atol=1e-14)


@pytest.mark.parametrize("m,order", [(4, 1), (3, 2)])
def test_heterogeneous_operator_spd_after_elimination(m, order):
    """SPD after BC elimination holds for heterogeneous fields too."""
    E, nu = inclusion_fields(hex_mesh(m, order))
    prob = assemble_elasticity(m, order=order, E=E, nu=nu)
    D = np.asarray(prob.A.to_dense())
    np.testing.assert_allclose(D, D.T, atol=1e-12)
    w = np.linalg.eigvalsh(0.5 * (D + D.T))
    assert w.min() > 0, f"not SPD: min eig {w.min()}"


@pytest.mark.parametrize("order", [1, 2])
def test_rigid_body_modes_on_free_interior(order):
    """A @ rigid_body_modes ~ 0 on rows whose node neighborhoods are all
    free (interior): those rows of the reduced operator coincide with the
    full operator's, which annihilates rigid motions exactly."""
    E, nu = _fields(4, order, True)
    prob = assemble_elasticity(4, order=order, E=E, nu=nu)
    r = np.asarray(prob.A.to_dense()) @ np.asarray(prob.B)
    z = prob.mesh.coords[prob.free_nodes, 2]
    interior = z > prob.mesh.h + 1e-12      # not adjacent to the clamp
    assert interior.any()
    rows = np.repeat(interior, 3)
    np.testing.assert_allclose(r[rows], 0.0, atol=1e-10)
    assert np.abs(r[~rows]).max() > 1e-3    # the clamp really bites


def test_reassemble_and_const_coefficient_update_agree():
    """reassemble(s) == update_coefficients(s*E0, nu0): the legacy scalar
    hot path is the constant-field special case of the coefficient path
    (E enters the Lame parameters linearly)."""
    prob = assemble_elasticity(4)
    A_scaled = prob.reassemble(2.5)
    A_coeff = prob.coefficient_operator(2.5 * 1.0, 0.3)
    np.testing.assert_allclose(np.asarray(A_coeff.data),
                               np.asarray(A_scaled.data),
                               rtol=1e-12, atol=1e-13)


def test_update_coefficients_mutates_and_validates():
    prob = assemble_elasticity(3)
    E, nu = inclusion_fields(prob.mesh)
    A0 = np.asarray(prob.A.data).copy()
    prob.update_coefficients(E, nu)
    assert np.abs(np.asarray(prob.A.data) - A0).max() > 1e-3
    np.testing.assert_allclose(np.asarray(prob.E_field), E)
    # host-path problems have no assembler: coefficient updates fail loudly
    host = assemble_elasticity(3, path="host")
    with pytest.raises(ValueError, match="device"):
        host.coefficient_operator(E, nu)
    with pytest.raises(ValueError):
        assemble_elasticity(3, path="bogus")


def test_solver_requires_bound_assembler():
    prob = assemble_elasticity(3)
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30)
    with pytest.raises(ValueError, match="bind_assembler"):
        solver.update_coefficients(1.0, 0.3)


def test_bind_assembler_rejects_mismatched_plan():
    """A plan from a different mesh must fail loudly at bind time —
    out-of-range gathers clamp silently under jit, so a mismatched
    assembler would otherwise 'converge' against a garbage operator."""
    prob3 = assemble_elasticity(3)
    prob4 = assemble_elasticity(4)
    solver = gamg.GAMGSolver(prob4.A, prob4.B, coarse_size=30)
    with pytest.raises(ValueError, match="does not match"):
        solver.bind_assembler(prob3.assembler)
    from repro.multirhs.server import AMGSolveServer
    setupd = gamg.setup(prob4.A, prob4.B, coarse_size=30)
    with pytest.raises(ValueError, match="does not match"):
        AMGSolveServer(setupd, prob4.A.data, assembler=prob3.assembler)


def test_heterogeneous_update_loop_regression():
    """ISSUE 5 end-to-end regression: jitted update_coefficients ->
    recompute -> pcg on a two-material inclusion problem.  Pins iteration
    counts across a stiffness ramp, asserts zero retraces across repeated
    updates, and proves the hot path does no host round-trips (it traces
    abstractly — any np.asarray of a traced value would raise)."""
    prob = assemble_elasticity(5)
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30,
                             precision="f64", rtol=1e-8, maxiter=100)
    solver.bind_assembler(prob.assembler)
    mesh = prob.mesh
    iters = []
    for contrast in (10.0, 100.0, 1000.0):
        E, nu = inclusion_fields(mesh, E_inclusion=contrast)
        solver.update_coefficients(E, nu)
        res = solver.solve(prob.b)
        assert bool(res.converged), f"contrast {contrast}: {res.relres}"
        iters.append(int(res.iters))
    # pinned regression values (f64, default MIS coarsener, m=5):
    # iteration counts grow mildly with material contrast but must not
    # drift — a change here means the assembly or hierarchy changed
    assert iters == [10, 14, 17], iters

    # zero retraces: one traced program served every update/solve
    assert solver._coeff_recompute._cache_size() == 1
    assert solver._solve._cache_size() == 1
    # an f32-typed caller must not retrace either (fields are force-cast)
    E32 = np.asarray(inclusion_fields(mesh)[0], np.float32)
    solver.update_coefficients(E32, 0.3)
    assert solver._coeff_recompute._cache_size() == 1

    # no host round-trip on the hot path: the whole update program traces
    # with abstract inputs
    ne = mesh.n_elements
    spec = jax.ShapeDtypeStruct((ne,), jnp.float64)
    jax.eval_shape(solver._coeff_recompute, spec, spec)


def test_server_coefficient_updates():
    """AMGSolveServer serves the quasi-static loop: coefficient updates
    refresh the hierarchy without touching buckets, and requests solved
    after an update see the new operator."""
    from repro.multirhs.server import AMGSolveServer

    prob = assemble_elasticity(4)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30)
    server_no_asm = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2))
    with pytest.raises(ValueError, match="assembler"):
        server_no_asm.update_coefficients(1.0, 0.3)

    server = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2),
                            assembler=prob.assembler)
    E, nu = inclusion_fields(prob.mesh)
    server.update_coefficients(E, nu)
    assert server.stats["coefficient_updates"] == 1
    assert server.stats["recomputes"] == 1
    reports = server.serve([np.asarray(prob.b)])
    assert reports[0].converged
    # the served solution solves the *heterogeneous* operator
    A_h = prob.coefficient_operator(E, nu)
    r = np.asarray(prob.b) - np.asarray(A_h.to_dense()) @ reports[0].x
    assert np.linalg.norm(r) / np.linalg.norm(np.asarray(prob.b)) < 1e-7
