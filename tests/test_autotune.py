"""Kernel tile autotuner: cache round-trips, knob resolution, bitwise off.

Covers ISSUE 8's autotuner satellites: the on-disk winner cache
round-trips through ``record``/``clear_memo``/``lookup``, ``REPRO_TUNE``
resolves per the mode ladder, a tiny sweep records a winner that
subsequent resolution uses, and ``REPRO_TUNE=off`` is bitwise the
pre-tune path.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
import jax.numpy as jnp

from helpers import random_bcsr
from repro.kernels import autotune, backend
from repro.kernels.block_spmv import ops as spmv_ops

RNG = np.random.default_rng(3)
SIG = {"br": 3, "bc": 3, "kmax": 4, "dtype": "float64"}


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    autotune.clear_memo()
    yield path
    autotune.clear_memo()


def test_cache_round_trip(tmp_cache):
    assert autotune.lookup("block_spmv", SIG, "tile_rows") is None
    p = autotune.record("block_spmv", SIG, {"tile_rows": 32}, best_us=12.5)
    assert p == tmp_cache and tmp_cache.exists()
    autotune.clear_memo()
    assert autotune.lookup("block_spmv", SIG, "tile_rows") == 32
    # merging a second signature keeps the first
    sig2 = dict(SIG, br=6, bc=6)
    autotune.record("block_spmv", sig2, {"tile_rows": 16})
    assert autotune.lookup("block_spmv", SIG, "tile_rows") == 32
    assert autotune.lookup("block_spmv", sig2, "tile_rows") == 16
    # winners are keyed per machine|backend — a different key misses
    assert autotune.machine_key() in autotune.load_cache()


def test_resolve_tune_modes(monkeypatch):
    monkeypatch.delenv("REPRO_TUNE", raising=False)
    assert backend.resolve_tune(None) == "cache"
    for val, want in (("off", "off"), ("0", "off"), ("cache", "cache"),
                      ("on", "cache"), ("sweep", "sweep")):
        monkeypatch.setenv("REPRO_TUNE", val)
        assert backend.resolve_tune(None) == want
    with pytest.raises(ValueError):
        backend.resolve_tune("fastest")


def test_resolve_param_mode_ladder(tmp_cache, monkeypatch):
    # explicit request always wins
    monkeypatch.setenv("REPRO_TUNE", "sweep")
    assert autotune.resolve_param("block_spmv", SIG, "tile_rows", 16, 8) \
        == 16
    # off -> static default even with a cached winner present
    autotune.record("block_spmv", SIG, {"tile_rows": 64})
    monkeypatch.setenv("REPRO_TUNE", "off")
    assert autotune.resolve_param("block_spmv", SIG, "tile_rows", None, 8) \
        == 8
    # cache -> the winner
    monkeypatch.setenv("REPRO_TUNE", "cache")
    assert autotune.resolve_param("block_spmv", SIG, "tile_rows", None, 8) \
        == 64
    # cache miss -> default (never sweeps)
    miss = dict(SIG, kmax=9)
    assert autotune.resolve_param("block_spmv", miss, "tile_rows", None, 8) \
        == 8
    assert autotune.lookup("block_spmv", miss, "tile_rows") is None


def test_tiny_sweep_records_winner_used_by_resolution(tmp_cache,
                                                      monkeypatch):
    won = autotune.sweep("block_spmv", SIG, nbr=16, repeats=1,
                         interpret=True)
    assert won["params"]["tile_rows"] in \
        autotune.CANDIDATES["block_spmv"]["tile_rows"]
    assert won["best_us"] > 0 and len(won["table"]) == 5
    autotune.clear_memo()
    monkeypatch.setenv("REPRO_TUNE", "sweep")
    # the recorded winner satisfies sweep-mode resolution without
    # re-measuring (the cache hit short-circuits)
    assert autotune.resolve_param("block_spmv", SIG, "tile_rows", None, 8) \
        == won["params"]["tile_rows"]


def test_tune_off_is_bitwise_the_pretune_path(tmp_cache, monkeypatch):
    """REPRO_TUNE=off must reproduce the seed's hardcoded tiling exactly:
    resolving through the ladder with a (different) cached winner present
    changes nothing when the mode is off."""
    A = random_bcsr(RNG, 24, 24, 3, 3, density=0.3)
    ell = A.to_ell()
    x = jnp.asarray(RNG.standard_normal(A.shape[1]))
    pinned = spmv_ops.block_spmv(ell, x, interpret=True, tile_rows=8)
    autotune.record("block_spmv",
                    dict(br=3, bc=3, kmax=ell.kmax, dtype="float64"),
                    {"tile_rows": 16})
    monkeypatch.setenv("REPRO_TUNE", "off")
    off = spmv_ops.block_spmv(ell, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(pinned))
    # and the cached winner does engage in cache mode (same values —
    # tiling only repartitions the grid — but resolution must pick it up)
    monkeypatch.setenv("REPRO_TUNE", "cache")
    assert autotune.resolve_param(
        "block_spmv", dict(br=3, bc=3, kmax=ell.kmax, dtype="float64"),
        "tile_rows", None, 8) == 16
    cached = spmv_ops.block_spmv(ell, x, interpret=True)
    np.testing.assert_allclose(np.asarray(cached), np.asarray(pinned),
                               rtol=1e-12, atol=1e-14)
