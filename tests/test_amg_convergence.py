"""Integration tests: GAMG on 3D elasticity (the paper's model problem).

Covers: FEM sanity (RBM null space), AMG convergence + rough mesh
independence, blocked/scalar iteration parity (paper Sec. 4.1), hot
recompute state-gating (Sec. 3.5), and the device MIS coarsener (Sec. 6).
"""
import numpy as np
import pytest

import repro.core  # noqa: F401
import jax.numpy as jnp

from repro.core import gamg
from repro.core.scalar_path import recompute_scalar
from repro.core.krylov import pcg
from repro.core.spmv import spmv_ell
from repro.core.vcycle import fine_operator, vcycle
from repro.fem.assemble import assemble_elasticity
from repro.fem.hex_elasticity import element_stiffness, rigid_body_modes


def test_element_stiffness_rbm_null():
    """Ke must annihilate rigid-body modes (zero-energy modes)."""
    Ke = element_stiffness(1, 0.5)
    coords = np.array([[x, y, z] for z in (0, .5) for y in (0, .5)
                       for x in (0, .5)])
    B = rigid_body_modes(coords)
    assert Ke.shape == (24, 24)
    np.testing.assert_allclose(Ke @ B, 0.0, atol=1e-12)
    w = np.linalg.eigvalsh(Ke)
    assert (w > -1e-12).all(), "element stiffness must be PSD"
    assert (np.abs(w) < 1e-10).sum() == 6, "exactly 6 zero-energy modes"


def test_q2_element_stiffness_rbm_null():
    Ke = element_stiffness(2, 1.0)
    pts = np.linspace(0, 1.0, 3)
    coords = np.array([[x, y, z] for z in pts for y in pts for x in pts])
    B = rigid_body_modes(coords)
    assert Ke.shape == (81, 81)
    np.testing.assert_allclose(Ke @ B, 0.0, atol=1e-11)


def test_assembled_operator_spd_and_rbm():
    # without BCs the assembled operator annihilates the RBMs exactly
    prob = assemble_elasticity(4, fix_face=False)
    D = np.asarray(prob.A.to_dense())
    np.testing.assert_allclose(D, D.T, atol=1e-12)
    np.testing.assert_allclose(D @ np.asarray(prob.B), 0.0, atol=1e-10)
    # with BCs the reduced operator is SPD
    prob = assemble_elasticity(4, fix_face=True)
    D = np.asarray(prob.A.to_dense())
    w = np.linalg.eigvalsh(0.5 * (D + D.T))
    assert w.min() > 0, f"reduced elasticity operator not SPD: {w.min()}"


@pytest.mark.parametrize("m", [5, pytest.param(7, marks=pytest.mark.slow)])
def test_gamg_converges_elasticity(m):
    prob = assemble_elasticity(m)
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                             maxiter=100)
    res = solver.solve(prob.b)
    assert bool(res.converged), f"no convergence: relres={res.relres}"
    assert int(res.iters) < 40
    # true residual check (fine_operator: the krylov-dtype operator under
    # a reduced-precision REPRO_PRECISION policy)
    r = prob.b - spmv_ell(fine_operator(solver.hierarchy), res.x)
    assert float(jnp.linalg.norm(r) / jnp.linalg.norm(prob.b)) < 1e-7


@pytest.mark.slow
def test_gamg_mesh_independence_trend_full_ladder():
    """The original (5, 7, 9) ladder, kept opt-in for the heavy tail."""
    _mesh_independence_trend((5, 7, 9))


def test_gamg_mesh_independence_trend():
    """Iterations must not blow up with resolution (multigrid scalability)."""
    _mesh_independence_trend((4, 5, 7))


def _mesh_independence_trend(ladder):
    iters = []
    for m in ladder:
        prob = assemble_elasticity(m)
        solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                                 maxiter=100)
        iters.append(int(solver.solve(prob.b).iters))
    assert iters[-1] <= 2 * iters[0] + 5, f"not mesh independent: {iters}"


def test_blocked_scalar_iteration_parity():
    """Paper Sec. 4.1: both formats converge in the same iteration count to
    the same true residual (same algorithm, different storage).  Exact
    parity is an fp64 contract — pin the policy against REPRO_PRECISION."""
    prob = assemble_elasticity(5)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    hier_b = gamg.recompute(setupd, prob.A.data)
    hier_s = recompute_scalar(setupd, prob.A.data)

    def solve(hier):
        return pcg(lambda x: spmv_ell(hier.levels[0].a_ell, x),
                   lambda r: vcycle(hier, r), prob.b, rtol=1e-8, maxiter=100)

    rb, rs = solve(hier_b), solve(hier_s)
    assert int(rb.iters) == int(rs.iters), (int(rb.iters), int(rs.iters))
    np.testing.assert_allclose(np.asarray(rb.x), np.asarray(rs.x),
                               rtol=1e-6, atol=1e-10)


def test_hot_recompute_scaled_operator():
    """State-gated hot recompute: new values, same structure (Sec. 3.5)."""
    prob = assemble_elasticity(5)
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                             maxiter=100)
    x1 = solver.solve(prob.b).x
    solver.update_operator(prob.A.data * 2.0)   # "Newton step": A -> 2A
    res2 = solver.solve(prob.b)
    assert bool(res2.converged)
    np.testing.assert_allclose(np.asarray(res2.x), np.asarray(x1) / 2.0,
                               rtol=1e-5, atol=1e-12)
    # reassembly through the cached COO plan gives the same operator
    A2 = prob.reassemble(2.0)
    np.testing.assert_allclose(np.asarray(A2.data),
                               np.asarray(prob.A.data) * 2.0, rtol=1e-13)


def test_mis_coarsener_device():
    """Paper Sec. 6 future work: device Luby-MIS coarsener end-to-end.
    MIS is now ``setup``'s *default* aggregation path — this exercises it
    through the explicit knob."""
    prob = assemble_elasticity(5)
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30,
                             coarsener="mis", rtol=1e-8, maxiter=120)
    res = solver.solve(prob.b)
    assert bool(res.converged), f"MIS coarsener: relres={res.relres}"


def test_mis_greedy_coarsener_parity_and_quality():
    """The jitted device MIS default vs the numpy greedy fallback: both
    produce valid aggregations (full cover, dense ids, real coarsening)
    and hierarchies of comparable convergence quality."""
    from repro.core.aggregation import graph_to_ell, greedy_aggregate, \
        aggregation_from_device, mis_aggregate_device
    from repro.core.strength import strength_graph

    prob = assemble_elasticity(5)
    graph = strength_graph(prob.A, 0.08)
    idx, mask = graph_to_ell(graph)
    mis = aggregation_from_device(mis_aggregate_device(idx, mask))
    greedy = greedy_aggregate(graph, min_size=2)
    for aggr in (mis, greedy):
        assert aggr.node_to_agg.shape == (graph.n,)
        assert (aggr.node_to_agg >= 0).all()
        assert set(np.unique(aggr.node_to_agg)) == set(range(aggr.n_agg)), \
            "aggregate ids must be dense"
        assert 1 < aggr.n_agg < graph.n, "must genuinely coarsen"
    # comparable coarsening rates (within 3x of each other)
    assert mis.n_agg < 3 * greedy.n_agg and greedy.n_agg < 3 * mis.n_agg

    # end-to-end quality: iteration counts within a fixed bound
    iters = {}
    for c in ("mis", "greedy"):
        s = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, coarsener=c,
                            rtol=1e-8, maxiter=120)
        r = s.solve(prob.b)
        assert bool(r.converged), f"{c}: relres={r.relres}"
        iters[c] = int(r.iters)
    assert abs(iters["mis"] - iters["greedy"]) <= 5, iters


def test_setup_default_routes_through_device_mis():
    """The default aggregation is the jitted device MIS path; greedy stays
    reachable as the explicit fallback, bogus names fail loudly."""
    prob = assemble_elasticity(4)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30)
    assert setupd.coarsener == "mis"
    with pytest.raises(ValueError):
        gamg.setup(prob.A, prob.B, coarse_size=30, coarsener="bogus")


def test_coarsening_reduces_and_block_sizes():
    """bs: 3 -> 6 across the first transition (paper Sec. 2.3)."""
    prob = assemble_elasticity(7)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30)
    assert len(setupd.levels) >= 1
    assert setupd.levels[0].A0.br == 3
    assert setupd.levels[0].P.block_shape == (3, 6)
    if len(setupd.levels) > 1:
        assert setupd.levels[1].A0.br == 6
        assert setupd.levels[1].P.block_shape == (6, 6)
    rows = setupd.stats["level_rows"]
    assert all(rows[i + 1] < rows[i] for i in range(len(rows) - 1)), rows
