"""The fault battery (slow / nightly): injected faults across every site
of the solver stack must be *detected* (health flag within one outer
iteration of firing), then *recovered* (ladder) or *explicitly failed* —
and a corrupted solve must never hand back an unflagged NaN.

Tier-1 stays injection-free (``tests/test_robust.py`` pins the healthy
path bitwise); this module is where schedules actually fire.  The halo
site is distributed-only and exercised by the ``REPRO_SELFTEST_FAULT=1``
section of ``repro.dist.selftest`` (driven from ``tests/test_dist_amg.py``
and the nightly workflow).

Determinism note on ``bitflip``: the exponent-MSB flip turns a value in
``[1, 2)`` into Inf and a value below 1 into a finite-huge one — both
detectable.  But a value >= 2 flips *down* to a denormal-tiny one, a
genuinely benign SDC indistinguishable from rounding noise; the
deterministic cases below pick sites/steps where the flip is verified
detectable, and the property sweep sticks to nan/inf.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax.numpy as jnp

from repro.core import gamg
from repro.fem.assemble import assemble_elasticity
from repro.multirhs import AMGSolveServer
from repro.robust import health, inject
from repro.robust.recover import RecoveryPolicy, RobustSolver

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: property sweep skips,
    HAVE_HYPOTHESIS = False  # the deterministic battery still runs

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def prob():
    return assemble_elasticity(4)


def _fresh_solver(prob, **kw):
    """A solver whose traces capture the *currently installed* schedule
    (injection is baked in at trace time)."""
    opts = dict(coarse_size=30, rtol=1e-8, maxiter=100, precision="f64")
    opts.update(kw)
    return gamg.GAMGSolver(prob.A, prob.B, **opts)


def _assert_contained(res):
    """The no-silent-NaN contract: flagged, not converged, finite x."""
    assert int(np.asarray(res.health.status)) != health.HEALTHY
    assert not bool(np.asarray(res.converged))
    assert np.isfinite(np.asarray(res.x)).all(), \
        "a faulted solve must never return a non-finite iterate"
    assert np.isfinite(np.asarray(res.relres)) or \
        int(np.asarray(res.health.status)) == health.NONFINITE


# ---------------------------------------------------------------------------
# Deterministic per-site battery
# ---------------------------------------------------------------------------

CASES = [
    # step-gated Krylov-loop sites, all three kinds (bitflip steps are
    # verified-detectable: the corrupted slots hold sub-1 magnitudes, so
    # the exponent flip lands huge)
    ("spmv:nan@1", health.NONFINITE),
    ("spmv:inf@1", health.NONFINITE),
    ("spmv:bitflip@1", None),
    ("precond:nan@2", health.NONFINITE),
    ("precond:inf@2", health.NONFINITE),
    ("precond:bitflip@2", None),
    # V-cycle interior sites (fire on every application)
    ("vcycle:nan", health.NONFINITE),
    ("vcycle:inf", health.NONFINITE),
    ("coarse:nan", health.NONFINITE),
    ("coarse:inf", health.NONFINITE),
    # hierarchy payload corruption (fires inside recompute)
    ("hierarchy:nan", health.NONFINITE),
    ("hierarchy:inf", health.NONFINITE),
    ("hierarchy:nan:level=1", health.NONFINITE),
]


@pytest.mark.parametrize("spec,expect", CASES,
                         ids=[c[0] for c in CASES])
def test_fault_detected_and_contained(prob, spec, expect):
    with inject.active(inject.parse_schedule(spec)):
        s = _fresh_solver(prob)
        res = s.solve(jnp.asarray(prob.b))
    _assert_contained(res)
    if expect is not None:
        assert int(np.asarray(res.health.status)) == expect, \
            health.describe(res.health)


@pytest.mark.parametrize("spec,step", [
    ("spmv:nan@1", 1), ("spmv:inf@3", 3), ("precond:nan@2", 2),
])
def test_step_gated_fault_detected_within_one_iteration(prob, spec, step):
    """The ISSUE-6 detection-latency contract: a fault at CG step ``s``
    trips the flag in that very iteration — the loop exits with
    ``iters <= s + 1`` instead of burning the remaining budget."""
    with inject.active(inject.parse_schedule(spec)):
        s = _fresh_solver(prob)
        res = s.solve(jnp.asarray(prob.b))
    assert int(np.asarray(res.iters)) <= step + 1
    _assert_contained(res)


def test_clean_run_after_battery_is_bitwise_clean(prob):
    """Schedules never leak: a fresh solver built after the contexts above
    have exited matches a never-faulted solve bitwise."""
    assert inject.current() is None
    s1 = _fresh_solver(prob)
    r1 = s1.solve(jnp.asarray(prob.b))
    with inject.active(inject.parse_schedule("vcycle:nan")):
        pass  # installed and restored, never traced against
    s2 = _fresh_solver(prob)
    r2 = s2.solve(jnp.asarray(prob.b))
    assert int(r1.health.status) == int(r2.health.status) == health.HEALTHY
    assert int(r1.iters) == int(r2.iters)
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))


# ---------------------------------------------------------------------------
# Recovery ladder semantics
# ---------------------------------------------------------------------------

def test_ladder_recovers_transient_fault(prob):
    """A transient hierarchy corruption: the first rung's fresh traces
    (under ``suppress_transient``) are clean, so one recompute heals it."""
    with inject.active(inject.parse_schedule("hierarchy:nan")):
        rs = RobustSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                          maxiter=100, precision="f64")
        out = rs.solve(jnp.asarray(prob.b))
    assert out.status == "recovered"
    assert out.attempts == ("recompute",)
    assert rs.n_recoveries == 1
    assert float(out.result.relres) <= 1e-8
    assert np.isfinite(np.asarray(out.x)).all()
    assert rs.describe_last() == "recompute"


def test_ladder_explicit_failure_on_persistent_fault(prob):
    """A persistent V-cycle NaN survives every rung's retrace: the ladder
    exhausts and reports an explicit ``failed`` with a zeroed solution —
    never a NaN dressed up as an answer."""
    with inject.active(inject.parse_schedule("vcycle:nan:persistent")):
        rs = RobustSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                          maxiter=100, precision="f64")
        out = rs.solve(jnp.asarray(prob.b))
    assert out.status == "failed"
    assert out.attempts == ("recompute", "re-setup", "reference-path")
    np.testing.assert_array_equal(np.asarray(out.x),
                                  np.zeros_like(np.asarray(out.x)))
    assert int(out.result.health.status) != health.HEALTHY


def test_ladder_degraded_keeps_best_iterate(prob):
    """A persistent fault that fires *after* real progress leaves a
    usable minimum-residual iterate: the exhausted ladder reports
    ``degraded`` and returns it (finite, relres < 1), not zeros."""
    with inject.active(inject.parse_schedule("spmv:nan@4:persistent")):
        rs = RobustSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                          maxiter=100, precision="f64")
        out = rs.solve(jnp.asarray(prob.b))
    assert out.status == "degraded"
    rel = float(np.asarray(out.result.health.best_relres))
    assert np.isfinite(rel) and 0.0 < rel < 1.0
    assert np.isfinite(np.asarray(out.x)).all()


def test_ladder_bounded_attempts(prob):
    with inject.active(inject.parse_schedule("vcycle:nan:persistent")):
        rs = RobustSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                          maxiter=100, precision="f64",
                          recovery=RecoveryPolicy(max_attempts=1))
        out = rs.solve(jnp.asarray(prob.b))
    assert out.status == "failed"
    assert out.attempts == ("recompute",)


# ---------------------------------------------------------------------------
# Server panel quarantine + per-request recovery
# ---------------------------------------------------------------------------

def test_panel_quarantine_isolates_poison_column(prob):
    """A fault pinned to one panel column freezes and fails that request
    only; its neighbours converge to their dedicated-solve answers."""
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    clean = AMGSolveServer(setupd, prob.A.data, buckets=(4,),
                           rtol=1e-8, maxiter=100)
    [want] = clean.serve([np.asarray(prob.b)])
    with inject.active(inject.parse_schedule("precond:nan@2:index=1")):
        srv = AMGSolveServer(setupd, prob.A.data, buckets=(4,),
                             rtol=1e-8, maxiter=100)
        reps = srv.serve([np.asarray(prob.b)] * 3)
    assert [r.status for r in reps] == ["ok", "failed", "ok"]
    assert reps[1].health == health.NONFINITE
    np.testing.assert_array_equal(reps[1].x, np.zeros_like(reps[1].x))
    for r in (reps[0], reps[2]):
        assert r.converged and r.iters == want.iters
        np.testing.assert_allclose(r.x, want.x, rtol=1e-12, atol=1e-14)
    assert srv.stats["failed"] == 1 and srv.stats["degraded"] == 0


def test_server_recovers_transient_column_fault(prob):
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    with inject.active(inject.parse_schedule("precond:nan@2:index=1")):
        srv = AMGSolveServer(setupd, prob.A.data, buckets=(4,),
                             rtol=1e-8, maxiter=100, recover="on")
        reps = srv.serve([np.asarray(prob.b)] * 3)
    assert [r.status for r in reps] == ["ok", "recovered", "ok"]
    rec = reps[1]
    assert rec.converged and rec.relres <= 1e-8
    assert np.isfinite(rec.x).all()
    np.testing.assert_allclose(rec.x, reps[0].x, rtol=1e-9)
    assert srv.stats["recovered"] == 1 and srv.stats["failed"] == 0


def test_server_persistent_column_fault_stays_failed(prob):
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f64")
    spec = "precond:nan@2:index=1:persistent"
    with inject.active(inject.parse_schedule(spec)):
        srv = AMGSolveServer(setupd, prob.A.data, buckets=(4,),
                             rtol=1e-8, maxiter=100, recover="on")
        reps = srv.serve([np.asarray(prob.b)] * 3)
    assert [r.status for r in reps] == ["ok", "failed", "ok"]
    np.testing.assert_array_equal(reps[1].x, np.zeros_like(reps[1].x))
    assert srv.stats["failed"] == 1 and srv.stats["recovered"] == 0


# ---------------------------------------------------------------------------
# Mid-march injection (ISSUE 10): the march-level recovery ladder
# ---------------------------------------------------------------------------

MARCH_SETUP = {"coarse_size": 8}


def _march_prob():
    from repro.sim import MarchConfig, SofteningScenario
    prob = assemble_elasticity(4)
    scen = SofteningScenario.build(prob, rate=0.3, d_max=0.99)
    cfg = MarchConfig(n_steps=3, seg_len=8, rtol=1e-8)
    return prob, scen, cfg


def test_march_transient_fault_recovered_within_one_step():
    """A transient spmv NaN firing mid-march blocks the step it poisons
    — detected within that step (the CG loop exits one iteration after
    injection), the state does NOT advance — and the march recovery
    ladder rebuilds with transients suppressed and finishes healthy."""
    from repro.sim import march
    prob, scen, cfg = _march_prob()
    with inject.active(inject.parse_schedule("spmv:nan@1")):
        res = march(prob, scen, cfg, mode="adaptive",
                    setup_opts=MARCH_SETUP)
    assert res.status == "ok"
    assert res.steps_done == cfg.n_steps
    assert res.n_recoveries >= 1
    # every ADVANCED step is healthy; the poisoned attempt is on record
    assert (res.step_status == health.HEALTHY).all()
    assert len(res.attempts) >= 1
    bad = res.attempts[0]
    assert bad["status"] == health.NONFINITE
    assert bad["iters"] <= 2, bad   # flagged within one CG iteration
    assert res.worst_status == health.NONFINITE
    assert np.isfinite(np.asarray(res.x)).all()
    assert np.isfinite(res.relres).all() and (res.relres <= cfg.rtol).all()


def test_march_persistent_fault_fails_explicitly():
    """A persistent fault survives every rebuild's retrace: the march
    exhausts ``max_recoveries`` on the poisoned step and fails
    EXPLICITLY — the state never advances past the last healthy point
    and the returned solution is the (finite) last healthy iterate,
    never the poisoned one."""
    from repro.sim import march
    prob, scen, cfg = _march_prob()
    with inject.active(inject.parse_schedule("spmv:nan@1:persistent")):
        res = march(prob, scen, cfg, mode="adaptive",
                    setup_opts=MARCH_SETUP)
    assert res.status == "failed"
    assert res.steps_done == 0              # poisoned from step 0
    assert res.n_recoveries == cfg.max_recoveries
    assert len(res.attempts) == cfg.max_recoveries + 1
    assert all(a["status"] == health.NONFINITE for a in res.attempts)
    assert np.isfinite(np.asarray(res.x)).all()


def test_frozen_march_never_advances_on_poison():
    """The frozen march has no recovery ladder: a blocked step simply
    stops the trajectory — the remaining scan slots record failed
    attempts, the march reports ``failed``, and the carry still holds
    the last healthy state."""
    from repro.sim import march
    prob, scen, cfg = _march_prob()
    with inject.active(inject.parse_schedule("spmv:nan@1")):
        res = march(prob, scen, cfg, mode="frozen",
                    setup_opts=MARCH_SETUP)
    assert res.status == "failed"
    assert res.steps_done == 0
    assert len(res.iters) == 0              # nothing advanced
    assert len(res.attempts) == cfg.n_steps  # every slot retried + logged
    assert res.worst_status == health.NONFINITE
    assert np.isfinite(np.asarray(res.x)).all()


# ---------------------------------------------------------------------------
# Property sweep (hypothesis): detection latency + ladder containment
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    def _dense_spd(seed, n=24, logcond=3.0):
        rng = np.random.default_rng(seed)
        Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        eigs = np.logspace(0, logcond, n)
        A = (Q * eigs) @ Q.T
        return jnp.asarray(A), jnp.asarray(rng.standard_normal(n))

    @given(site=st.sampled_from(["spmv", "precond"]),
           kind=st.sampled_from(["nan", "inf"]),
           step=st.integers(0, 5),
           index=st.integers(0, 1000),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_detection_within_one_iteration(site, kind, step,
                                                     index, seed):
        """Any nan/inf fault at CG step ``s`` is flagged in that very
        iteration (``iters <= s + 1``), the loop exits, and the returned
        iterate is finite — for random operators, sites, steps and
        corrupted slots."""
        from repro.core.krylov import pcg
        A, b = _dense_spd(seed)
        dinv = 1.0 / jnp.diag(A)
        spec = f"{site}:{kind}@{step}:index={index}"
        with inject.active(inject.parse_schedule(spec)):
            res = pcg(lambda v: A @ v, lambda v: dinv * v, b,
                      rtol=1e-10, maxiter=100)
        assert int(np.asarray(res.iters)) <= step + 1
        assert int(np.asarray(res.health.status)) == health.NONFINITE
        assert np.isfinite(np.asarray(res.x)).all()

    @given(site=st.sampled_from(["spmv", "precond", "vcycle",
                                 "hierarchy"]),
           kind=st.sampled_from(["nan", "inf"]),
           persistent=st.booleans(),
           step=st.integers(0, 6))
    @settings(max_examples=8, deadline=None)
    def test_property_ladder_contains_every_fault(site, kind, persistent,
                                                  step):
        """The containment property: whatever is injected, the ladder
        either recovers (relres <= rtol), degrades (finite best iterate,
        relres < 1) or *explicitly* fails (zeroed x) — never a silent
        NaN, never an unflagged bad answer."""
        prob = assemble_elasticity(4)
        spec = f"{site}:{kind}@{step}" if site in ("spmv", "precond") \
            else f"{site}:{kind}"
        if persistent:
            spec += ":persistent"
        with inject.active(inject.parse_schedule(spec)):
            rs = RobustSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                              maxiter=100, precision="f64")
            out = rs.solve(jnp.asarray(prob.b))
        assert np.isfinite(np.asarray(out.x)).all()
        if out.status in ("ok", "recovered"):
            assert float(np.asarray(out.result.relres)) <= 1e-8
        elif out.status == "degraded":
            rel = float(np.asarray(out.result.health.best_relres))
            assert np.isfinite(rel) and rel < 1.0
        else:
            assert out.status == "failed"
            np.testing.assert_array_equal(
                np.asarray(out.x), np.zeros_like(np.asarray(out.x)))
        if not persistent:
            # a transient fault must never exhaust the ladder
            assert out.status in ("ok", "recovered")
