"""Distributed-AMG integration tests.

Run in subprocesses so the placeholder-device XLA flag never leaks into this
process (smoke tests and benches must see exactly 1 device — see dryrun
spec).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_selftest(ndev: int, m: int, extra_env: dict | None = None) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_SELFTEST_NDEV"] = str(ndev)
    env.pop("XLA_FLAGS", None)
    if extra_env:
        env.update(extra_env)
    out = subprocess.run(
        [sys.executable, "-m", "repro.dist.selftest", str(m)],
        capture_output=True, text=True, timeout=520, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("ndev,m", [
    (4, 5),
    pytest.param(8, 6, marks=pytest.mark.slow),   # ~20s: opt-in heavy case
])
def test_dist_amg_parity(ndev, m):
    """Distributed == single-device: same iterations, same solution,
    for both the state-gated and ungated-P_oth paths (paper Table 3)."""
    stdout = _run_selftest(ndev, m)
    assert "OK" in stdout
    assert "halo=ppermute" in stdout, stdout  # slab halos -> neighbor path


def test_dist_amg_mrhs_parity():
    """A (n, k) panel through the same shard_map program (masked multi-RHS
    PCG over sharded slabs) matches the single-device batched solve per
    column — iteration counts and solutions."""
    stdout = _run_selftest(2, 4, {"REPRO_SELFTEST_MRHS": "1"})
    assert "OK" in stdout
    assert "mrhs (k=3) parity" in stdout, stdout


def test_dist_amg_agglomerated_parity():
    """Agglomerated placement (coarse levels replicated, zero ppermute
    traffic below the switch) solves in exactly the same iteration count
    as the sharded-only placement — the tentpole's f64 contract.  The
    8-rank mid-level variant runs nightly."""
    stdout = _run_selftest(2, 5, {"REPRO_SELFTEST_AGG": "1"})
    assert "OK" in stdout
    assert "agglomerated parity" in stdout, stdout
    assert "'replicated'" in stdout, stdout


def test_dist_coefficient_update_parity():
    """ISSUE 5 acceptance: the jitted coefficient hot loop
    (update_coefficients -> rank-local device assembly -> recompute ->
    solve) through the DistGAMG staging at 2 fake ranks — exact iteration
    parity with the single-device loop and with the value-stream path on
    a heterogeneous (inclusion) problem, zero retraces across updates."""
    stdout = _run_selftest(2, 4, {"REPRO_SELFTEST_COEFF": "1"})
    assert "OK" in stdout
    assert "coefficient hot-loop parity" in stdout, stdout
    assert "no retrace" in stdout, stdout


def test_dist_overlap_parity():
    """ISSUE 9 acceptance: the overlapped split apply (interior rows
    contracted while the halo exchange flies) is *exact-iteration* and
    bitwise-solution identical to the blocking schedule; the apply
    battery pins bitwise equality across halo strategies, RHS shapes and
    dtypes; ``REPRO_OVERLAP=off`` leaves zero jaxpr residue vs the
    pre-split apply; and a halo fault is detected with the same latency
    under either schedule."""
    stdout = _run_selftest(2, 4, {"REPRO_SELFTEST_OVERLAP": "1"})
    assert "OK" in stdout
    assert "overlap solve parity" in stdout, stdout
    assert "overlap apply battery bitwise" in stdout, stdout
    assert "overlap off-path jaxpr: residue-free identical" in stdout, \
        stdout
    assert "overlap fault-detection parity" in stdout, stdout


@pytest.mark.slow
def test_dist_overlap_parity_8rank():
    """Nightly: the 8-rank overlap section — wider halos, the allgather
    battery case active, and the stage-2 off-process reduction taking the
    overlapped allgather window."""
    stdout = _run_selftest(8, 6, {"REPRO_SELFTEST_OVERLAP": "1"})
    assert "OK" in stdout
    assert "'allgather'" in stdout, stdout   # fallback strategy exercised
    assert "overlap solve parity" in stdout, stdout


def test_dist_warm_march_parity():
    """ISSUE 10 (tier-1): the warm-started time march over the wire — a
    3-step softening march through the ``warm_start=True`` dist
    coefficient program (x output slab fed back as the next x0 slab)
    matches the single-device fused march primitive step for step, with
    one compiled program for the whole march."""
    stdout = _run_selftest(2, 4, {"REPRO_SELFTEST_MARCH": "1"})
    assert "OK" in stdout
    assert "dist warm march parity (3 steps)" in stdout, stdout


@pytest.mark.slow
def test_dist_fault_injection_detected():
    """ISSUE 6 (nightly): the fault-injection section of the selftest —
    a NaN planted in one rank's halo window and an Inf in one rank's SpMV
    output are both detected *collectively* (every rank exits with the
    same non-healthy status within one iteration, via the psum-replicated
    health flags), solutions stay finite, and a clean re-staging
    afterwards is bitwise identical to the never-faulted run."""
    stdout = _run_selftest(2, 4, {"REPRO_SELFTEST_FAULT": "1"})
    assert "OK" in stdout
    assert "halo fault detected: status=nonfinite" in stdout, stdout
    assert "spmv@2 fault detected: status=nonfinite" in stdout, stdout
    assert "post-fault re-staging parity: identical" in stdout, stdout


def test_placement_and_scatter_staging_dtype():
    """Host-only checks (build_dist_gamg is pure staging, no devices):

    * the placement split obeys the equations-per-rank rule and level 0
      never leaves the sharded path;
    * scatter staging dtypes are the policy's, not the caller's — an
      fp64 operator update into an fp32-resident dist hierarchy stages
      at the same dtype as an fp32 one (no retrace, no dtype poisoning;
      the krylov-dtype fine-operator copy keeps full precision).
    """
    import numpy as np
    import repro.core  # noqa: F401
    from repro.core import gamg
    from repro.dist.solver import build_dist_gamg
    from repro.fem.assemble import assemble_elasticity

    prob = assemble_elasticity(5)
    for precision, pay_dt in (("f64", np.float64), ("f32", np.float64)):
        setupd = gamg.setup(prob.A, prob.B, coarse_size=12,
                            precision=precision)
        assert len(setupd.levels) >= 2, setupd.stats["level_rows"]
        dg_sh = build_dist_gamg(setupd, 2, coarse_eq_limit=0)
        dg_ag = build_dist_gamg(setupd, 2, coarse_eq_limit=1 << 30)
        assert not dg_sh.repl and dg_sh.coarse is not None
        assert dg_ag.repl and dg_ag.switch is not None
        assert dg_ag.placement[0] == "sharded"       # level 0 pinned
        assert dg_ag.placement[1:] == ["replicated"] * (dg_ag.n_levels)
        assert dg_ag.switch.p_b.halo.strategy == "replicated"
        assert dg_ag.switch.p_b.halo.exchanged_slabs == 0
        for dg in (dg_sh, dg_ag):
            a64 = dg.scatter_fine_payloads(np.asarray(prob.A.data))
            a32 = dg.scatter_fine_payloads(
                np.asarray(prob.A.data, np.float32))
            assert a64.dtype == a32.dtype == np.dtype(pay_dt)
            b64 = dg.scatter_vector(np.asarray(prob.b))
            b32 = dg.scatter_vector(np.asarray(prob.b, np.float32))
            assert b64.dtype == b32.dtype == \
                np.dtype(setupd.precision.krylov_dtype)


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1, jax.devices()
