"""Distributed-AMG integration tests.

Run in subprocesses so the placeholder-device XLA flag never leaks into this
process (smoke tests and benches must see exactly 1 device — see dryrun
spec).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_selftest(ndev: int, m: int, extra_env: dict | None = None) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_SELFTEST_NDEV"] = str(ndev)
    env.pop("XLA_FLAGS", None)
    if extra_env:
        env.update(extra_env)
    out = subprocess.run(
        [sys.executable, "-m", "repro.dist.selftest", str(m)],
        capture_output=True, text=True, timeout=520, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("ndev,m", [
    (4, 5),
    pytest.param(8, 6, marks=pytest.mark.slow),   # ~20s: opt-in heavy case
])
def test_dist_amg_parity(ndev, m):
    """Distributed == single-device: same iterations, same solution,
    for both the state-gated and ungated-P_oth paths (paper Table 3)."""
    stdout = _run_selftest(ndev, m)
    assert "OK" in stdout
    assert "halo=ppermute" in stdout, stdout  # slab halos -> neighbor path


def test_dist_amg_mrhs_parity():
    """A (n, k) panel through the same shard_map program (masked multi-RHS
    PCG over sharded slabs) matches the single-device batched solve per
    column — iteration counts and solutions."""
    stdout = _run_selftest(2, 4, {"REPRO_SELFTEST_MRHS": "1"})
    assert "OK" in stdout
    assert "mrhs (k=3) parity" in stdout, stdout


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1, jax.devices()
