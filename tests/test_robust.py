"""Robustness subsystem, tier-1 (injection-free) contracts.

What this module pins:

* the monitored ``pcg`` is **bitwise** the unmonitored recurrence on a
  healthy run (same primitives, the health ``where``-guards all-pass);
* zero retraces: the jitted solve closure's cache stays at 1 across
  repeated healthy solves, and ``inject.maybe`` leaves **zero jaxpr
  residue** when no schedule is installed;
* breakdown / stagnation / non-finite detection on constructed failures
  (no injection needed — an indefinite operator or an impossible rtol);
* best-iterate contract: any non-converged exit returns the
  minimum-residual iterate, at f32 and f64;
* ``jittered_cholesky`` hardening: a near-rank-deficient coarse grid that
  defeats the base jitter factorizes on the escalated retry;
* the fault-spec mini-language and the ``REPRO_FAULTS`` /
  ``REPRO_RECOVER`` resolvers;
* ``AMGSolveServer.submit`` validation (bad shape / dtype / non-finite
  rejected before panel assembly) and the recovery-ladder plumbing.

Injection *semantics* (faults actually firing) live in the slow-marked
``tests/test_fault_battery.py`` — tier-1 traces stay injection-free.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax
import jax.numpy as jnp

from repro.core import gamg
from repro.core.krylov import pcg
from repro.core.precision import PrecisionPolicy
from repro.fem.assemble import assemble_elasticity
from repro.kernels import backend
from repro.multirhs import AMGSolveServer
from repro.multirhs.block_krylov import block_pcg
from repro.robust import health, inject
from repro.robust.recover import (
    RecoveryPolicy,
    RobustSolver,
    ladder_solve,
)

RNG = np.random.default_rng(23)


@pytest.fixture(scope="module")
def prob():
    return assemble_elasticity(4)


@pytest.fixture(scope="module")
def solver(prob):
    return gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                           maxiter=100, precision="f64")


def _spd(n, dtype=np.float64, cond=1e4):
    """Dense SPD test operator with controlled conditioning."""
    Q, _ = np.linalg.qr(RNG.standard_normal((n, n)))
    eigs = np.logspace(0, np.log10(cond), n)
    return (Q * eigs) @ Q.T.astype(dtype)


# ---------------------------------------------------------------------------
# Healthy path: bitwise parity, zero retraces, zero jaxpr residue
# ---------------------------------------------------------------------------

def _vanilla_pcg(apply_a, apply_m, b, rtol, maxiter):
    """The pre-ISSUE-6 recurrence, same primitives, no monitoring."""
    x = jnp.zeros_like(b)
    r = b - apply_a(x)
    z = apply_m(r)
    p = z
    rz = jnp.vdot(r, z)
    bnorm = jnp.maximum(jnp.linalg.norm(b), jnp.finfo(b.dtype).tiny)
    rnorm = jnp.linalg.norm(r)

    def cond(state):
        x, r, z, p, rz, rnorm, k = state
        return (rnorm > rtol * bnorm) & (k < maxiter)

    def body(state):
        x, r, z, p, rz, rnorm, k = state
        Ap = apply_a(p)
        pAp = jnp.vdot(p, Ap)
        alpha = rz / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_m(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return (x, r, z, p, rz_new, jnp.linalg.norm(r), k + 1)

    state = (x, r, z, p, rz, rnorm, jnp.asarray(0))
    x, r, z, p, rz, rnorm, k = jax.lax.while_loop(cond, body, state)
    return x, k, rnorm / bnorm


def test_monitored_pcg_bitwise_matches_unmonitored():
    """The ISSUE-6 acceptance pin: monitoring is free on the healthy path.

    Every health guard is a ``jnp.where`` whose predicate is always-pass
    on a clean run, and ``inject.maybe`` is trace-time identity — so the
    iterates, the iteration count and the relres must come out *bitwise*
    equal to the hand-rolled unmonitored loop."""
    A = jnp.asarray(_spd(40))
    dinv = 1.0 / jnp.diag(A)
    b = jnp.asarray(RNG.standard_normal(40))
    apply_a = lambda v: A @ v                     # noqa: E731
    apply_m = lambda v: dinv * v                  # noqa: E731
    res = pcg(apply_a, apply_m, b, rtol=1e-10, maxiter=200)
    xv, kv, rrv = _vanilla_pcg(apply_a, apply_m, b, 1e-10, 200)
    assert bool(res.converged)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(xv))
    assert int(res.iters) == int(kv)
    np.testing.assert_array_equal(np.asarray(res.relres), np.asarray(rrv))
    assert int(res.health.status) == health.HEALTHY
    assert not bool(res.health.breakdown)
    assert not bool(res.health.nonfinite)
    assert not bool(res.health.stagnation)


def test_healthy_solve_cache_stays_at_one(solver, prob):
    """Zero retraces across repeated healthy monitored solves."""
    b = jnp.asarray(prob.b)
    r1 = solver.solve(b)
    r2 = solver.solve(2.0 * b)
    assert int(r1.health.status) == health.HEALTHY
    assert int(r2.health.status) == health.HEALTHY
    assert solver._solve._cache_size() == 1
    assert solver._recompute._cache_size() == 1


def test_inject_maybe_zero_jaxpr_residue():
    """With no schedule, ``maybe`` is trace-time identity: the jaxpr is
    the uninstrumented one; with a schedule active the trace changes;
    after the scope exits, new traces are clean again."""
    A = jnp.asarray(_spd(12))
    b = jnp.asarray(RNG.standard_normal(12))

    def mk():
        # a fresh closure per trace: jax caches traces on the function
        # object, which would mask (or fake) residue differences
        def f(b):
            return pcg(lambda v: A @ v, lambda v: v, b, rtol=1e-8,
                       maxiter=20).x
        return f

    assert inject.current() is None
    before = str(jax.make_jaxpr(mk())(b))
    with inject.active(inject.parse_schedule("spmv:nan@1")):
        during = str(jax.make_jaxpr(mk())(b))
    after = str(jax.make_jaxpr(mk())(b))
    assert before == after, "cleared schedule must leave zero residue"
    assert before != during, "an active schedule must change the trace"


def test_block_pcg_reports_per_column_health(solver, prob):
    B = jnp.stack([jnp.asarray(prob.b), 3.0 * jnp.asarray(prob.b)], axis=1)
    res = solver.solve_many(B)
    assert res.health.status.shape == (2,)
    assert np.array_equal(np.asarray(res.health.status), [0, 0])
    assert np.asarray(res.converged).all()
    assert np.asarray(res.health.best_relres).max() <= 1e-8


# ---------------------------------------------------------------------------
# Detection on constructed (injection-free) failures
# ---------------------------------------------------------------------------

def test_breakdown_detected_indefinite_preconditioner():
    """r·z < 0 at init: flagged before the first iteration."""
    A = jnp.asarray(_spd(20))
    b = jnp.asarray(RNG.standard_normal(20))
    res = pcg(lambda v: A @ v, lambda v: -v, b, rtol=1e-10, maxiter=50)
    assert int(res.health.status) == health.BREAKDOWN
    assert bool(res.health.breakdown)
    assert not bool(res.converged)
    assert int(res.iters) == 0
    assert np.isfinite(np.asarray(res.x)).all()


def test_breakdown_detected_indefinite_operator():
    """p·Ap < 0 on step 0: the in-loop breakdown flag, update discarded."""
    d = np.ones(10)
    d[0] = -50.0
    A = jnp.asarray(np.diag(d))
    b = jnp.ones(10, jnp.float64)
    res = pcg(lambda v: A @ v, lambda v: v, b, rtol=1e-10, maxiter=50)
    assert int(res.health.status) == health.BREAKDOWN
    assert not bool(res.converged)
    # the broken step's update was discarded: x is the (finite) best
    # iterate, here the initial guess
    assert np.isfinite(np.asarray(res.x)).all()


def test_nonfinite_detected_poison_rhs():
    A = jnp.asarray(_spd(8))
    b = jnp.asarray(RNG.standard_normal(8)).at[3].set(jnp.nan)
    res = pcg(lambda v: A @ v, lambda v: v, b, rtol=1e-10, maxiter=50)
    assert int(res.health.status) == health.NONFINITE
    assert bool(res.health.nonfinite)
    assert int(res.iters) == 0
    assert np.isfinite(np.asarray(res.x)).all(), \
        "a flagged solve must still return a finite iterate"


def test_stagnation_detected_no_new_best_over_window():
    """No new best residual for ``stall_window`` iterations trips the
    stagnation flag instead of burning maxiter: unpreconditioned CG on an
    ill-conditioned operator oscillates *above* the initial residual for
    its whole transient, which a tight window catches deterministically
    (dedicated rng: the fixture must not depend on test order)."""
    rng = np.random.default_rng(23)
    n = 30
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    A = jnp.asarray((Q * np.logspace(0, 4, n)) @ Q.T)
    b = jnp.asarray(rng.standard_normal(n))
    res = pcg(lambda v: A @ v, lambda v: v, b, rtol=1e-10, maxiter=5000,
              stall_window=10)
    assert int(res.health.status) == health.STAGNATION
    assert bool(res.health.stagnation)
    assert not bool(res.converged)
    assert int(res.iters) < 100, "stall window must cut the run short"
    # the returned iterate is the best seen (here: x0 — nothing improved
    # on the initial residual inside the window), finite, never diverged
    assert float(res.health.best_relres) <= 1.0
    assert np.isfinite(np.asarray(res.x)).all()


# ---------------------------------------------------------------------------
# Best-iterate contract (satellite a)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_best_iterate_on_early_termination(dtype):
    """A non-converged exit returns the minimum-residual iterate — at
    every Krylov dtype (the unpreconditioned CG residual is not monotone,
    so the last iterate can be strictly worse than an earlier one)."""
    n = 60
    A = jnp.asarray(_spd(n, cond=1e8).astype(dtype))
    b = jnp.asarray(RNG.standard_normal(n).astype(dtype))
    maxiter = 25
    res, hist = pcg(lambda v: A @ v, lambda v: v, b, rtol=1e-12,
                    maxiter=maxiter, record_history=True,
                    stall_window=10**6)
    assert not bool(res.converged)
    hist = np.asarray(hist)[:int(res.iters)]
    bnorm = max(float(np.linalg.norm(np.asarray(b))),
                float(np.finfo(dtype).tiny))
    r0 = float(np.linalg.norm(np.asarray(b)))  # x0 = 0 residual
    best_seen = min(r0, hist.min()) / bnorm
    got = float(res.health.best_relres)
    np.testing.assert_allclose(got, best_seen, rtol=10 * np.finfo(dtype).eps)
    # relres of the *returned* result is the best one, and the returned x
    # actually achieves it
    np.testing.assert_allclose(float(res.relres), best_seen,
                               rtol=10 * np.finfo(dtype).eps)
    true_rel = float(np.linalg.norm(np.asarray(b - A @ res.x))) / bnorm
    np.testing.assert_allclose(true_rel, best_seen, rtol=200 * float(
        np.finfo(dtype).eps) * np.sqrt(n) + 1e-30)
    # best_iter indexes the history slot that achieved it
    k = int(res.health.best_iter)
    if k > 0:
        np.testing.assert_allclose(hist[k - 1] / bnorm, best_seen,
                                   rtol=10 * np.finfo(dtype).eps)


def test_block_best_iterate_early_termination():
    """Same contract per column of the masked panel solve."""
    n = 60
    A = jnp.asarray(_spd(n, cond=1e8))

    def apply_a(V):
        return A @ V

    def apply_m(V):
        return V

    B = jnp.asarray(RNG.standard_normal((n, 3)))
    res = block_pcg(apply_a, apply_m, B, rtol=1e-12, maxiter=25,
                    stall_window=10**6)
    assert not np.asarray(res.converged).any()
    bn = np.linalg.norm(np.asarray(B), axis=0)
    true_rel = np.linalg.norm(np.asarray(B - A @ res.x), axis=0) / bn
    np.testing.assert_allclose(true_rel, np.asarray(res.relres),
                               rtol=1e-9)
    np.testing.assert_allclose(np.asarray(res.relres),
                               np.asarray(res.health.best_relres),
                               rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Coarse-solve hardening (satellite b)
# ---------------------------------------------------------------------------

def _near_singular_spd(n, bad=-1e-10):
    """SPD-but-for-rounding: one eigenvalue slightly negative, the classic
    rank-deficient coarse grid (rigid modes not fully pinned)."""
    Q, _ = np.linalg.qr(RNG.standard_normal((n, n)))
    eigs = np.ones(n)
    eigs[-1] = bad
    M = (Q * eigs) @ Q.T
    return 0.5 * (M + M.T)


def test_jittered_cholesky_base_path_bitwise_legacy():
    """On a healthy matrix the retry branch is dead code: the factor is
    bitwise the legacy single-jitter factorization."""
    dense = jnp.asarray(_spd(12))
    scale = PrecisionPolicy.double().coarse_jitter_scale()
    got = gamg.jittered_cholesky(dense, scale,
                                 PrecisionPolicy.double()
                                 .coarse_retry_scale())
    n = dense.shape[0]
    eye = jnp.eye(n, dtype=dense.dtype)
    legacy = jnp.linalg.cholesky(dense + scale * jnp.trace(dense) / n * eye)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


def test_jittered_cholesky_recovers_rank_deficient():
    """The ~-1e-10 eigenvalue defeats the 1e-12-relative base jitter but
    not the sqrt(eps)-relative retry."""
    dense = jnp.asarray(_near_singular_spd(16))
    pol = PrecisionPolicy.double()
    base = pol.coarse_jitter_scale()
    n = dense.shape[0]
    eye = jnp.eye(n, dtype=dense.dtype)
    naive = jnp.linalg.cholesky(dense + base * jnp.trace(dense) / n * eye)
    assert not bool(jnp.isfinite(naive).all()), \
        "fixture must actually defeat the base jitter"
    got = gamg.jittered_cholesky(dense, base, pol.coarse_retry_scale())
    assert bool(jnp.isfinite(got).all()), \
        "escalated retry jitter must factorize"
    # and the factor is usable: L L^T ~ dense + retry-jitter diag
    rec = np.asarray(got) @ np.asarray(got).T
    np.testing.assert_allclose(rec, np.asarray(dense), atol=1e-6)


def test_coarse_retry_scale_tracks_factor_dtype():
    assert PrecisionPolicy.double().coarse_retry_scale() == pytest.approx(
        np.sqrt(np.finfo(np.float64).eps))
    f32 = PrecisionPolicy.from_name("f32")
    assert f32.coarse_retry_scale() == pytest.approx(
        np.sqrt(np.finfo(f32.factor_dtype).eps))


# ---------------------------------------------------------------------------
# Fault-spec mini-language + resolvers (satellite e knobs)
# ---------------------------------------------------------------------------

def test_parse_schedule_round_trip():
    s = inject.parse_schedule(
        "precond:nan@3; halo:bitflip:index=7:persistent;"
        "hierarchy:inf:level=1")
    assert len(s.faults) == 3
    f0, f1, f2 = s.faults
    assert (f0.site, f0.kind, f0.step, f0.transient) == \
        ("precond", "nan", 3, True)
    assert (f1.site, f1.kind, f1.index, f1.transient) == \
        ("halo", "bitflip", 7, False)
    assert (f2.site, f2.kind, f2.level) == ("hierarchy", "inf", 1)
    # transient filtering keeps only the persistent fault
    kept = s.without_transient()
    assert kept is not None and len(kept.faults) == 1
    assert kept.faults[0].site == "halo"
    assert inject.parse_schedule("spmv:nan").without_transient() is None


@pytest.mark.parametrize("bad", [
    "bogus:nan",            # unknown site
    "spmv:frob",            # unknown kind
    "spmv",                 # missing kind
    "spmv:nan:wat=3",       # unknown option
    "spmv:nan:persistent:x",  # trailing garbage option
    "",                     # empty
])
def test_parse_schedule_rejects(bad):
    with pytest.raises(ValueError):
        inject.parse_schedule(bad)


def test_fault_corrupt_is_deterministic_and_gated():
    f = inject.Fault(site="spmv", kind="inf", step=2, index=1)
    x = jnp.arange(4.0)
    same = f.corrupt(x, step=jnp.asarray(0))
    np.testing.assert_array_equal(np.asarray(same), np.asarray(x))
    hit1 = f.corrupt(x, step=jnp.asarray(2))
    hit2 = f.corrupt(x, step=jnp.asarray(2))
    np.testing.assert_array_equal(np.asarray(hit1), np.asarray(hit2))
    assert np.isposinf(np.asarray(hit1)[1])
    # bitflip flips the exponent MSB: small value -> huge, still the same
    # array elsewhere
    fb = inject.Fault(site="spmv", kind="bitflip", index=0)
    src = jnp.full(4, 0.5)  # exponent MSB is 0: the flip lands finite-huge
    flipped = np.asarray(fb.corrupt(src, step=None))
    assert flipped[0] > 1e300 and np.isfinite(flipped[0])
    np.testing.assert_array_equal(flipped[1:], np.asarray(src)[1:])


def test_resolve_faults_env_and_passthrough(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert backend.resolve_faults() is None
    monkeypatch.setenv("REPRO_FAULTS", "spmv:nan@1")
    sched = backend.resolve_faults()
    assert isinstance(sched, inject.FaultSchedule)
    assert sched.faults[0].site == "spmv"
    explicit = inject.parse_schedule("halo:inf")
    assert backend.resolve_faults(explicit) is explicit
    monkeypatch.setenv("REPRO_FAULTS", "bogus:nan")
    with pytest.raises(ValueError):
        backend.resolve_faults()


def test_resolve_recover_env_and_passthrough(monkeypatch):
    monkeypatch.delenv("REPRO_RECOVER", raising=False)
    assert backend.resolve_recover() is None
    for off in ("off", "0", "false", "none"):
        monkeypatch.setenv("REPRO_RECOVER", off)
        assert backend.resolve_recover() is None
    monkeypatch.setenv("REPRO_RECOVER", "on")
    assert backend.resolve_recover() == RecoveryPolicy()
    monkeypatch.setenv("REPRO_RECOVER", "2")
    assert backend.resolve_recover().max_attempts == 2
    monkeypatch.delenv("REPRO_RECOVER", raising=False)
    pol = RecoveryPolicy(max_attempts=1)
    assert backend.resolve_recover(pol) is pol
    monkeypatch.setenv("REPRO_RECOVER", "sometimes")
    with pytest.raises(ValueError):
        backend.resolve_recover()


def test_recovery_policy_validation():
    with pytest.raises(ValueError):
        RecoveryPolicy(max_attempts=0)


# ---------------------------------------------------------------------------
# Server submit validation + exception containment (satellite c)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def server(solver, prob):
    return AMGSolveServer(solver.setup_data, prob.A.data, buckets=(1, 2),
                          rtol=1e-8, maxiter=100)


def test_submit_rejects_bad_shape(server):
    with pytest.raises(ValueError, match="shape"):
        server.submit(np.ones(7))
    with pytest.raises(ValueError, match="shape"):
        server.submit(np.ones((server.n, 1)))


def test_submit_rejects_bad_dtype(server):
    with pytest.raises(ValueError, match="dtype"):
        server.submit(np.array(["nope"] * server.n, dtype=object))


def test_submit_rejects_nonfinite(server):
    b = np.ones(server.n)
    b[5] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        server.submit(b)
    b[5] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        server.submit(b)


def test_rejected_requests_never_reach_a_panel(server, prob):
    """A rejected submit must not poison the queue: the next flush serves
    only the good requests, all healthy."""
    before = server.stats["rejected"]
    bad = np.full(server.n, np.inf)
    with pytest.raises(ValueError):
        server.submit(bad)
    server.submit(np.asarray(prob.b))
    reports = server.flush()
    assert server.stats["rejected"] == before + 1
    assert len(reports) == 1
    assert reports[0].status == "ok"
    assert reports[0].converged
    assert np.isfinite(reports[0].x).all()


def test_report_carries_status_fields(server, prob):
    [rep] = server.serve([np.asarray(prob.b)])
    assert rep.status == "ok"
    assert rep.health == health.HEALTHY
    assert rep.converged and rep.relres <= 1e-8


# ---------------------------------------------------------------------------
# Recovery ladder plumbing (injection-free; semantics in the battery)
# ---------------------------------------------------------------------------

def test_robust_solver_healthy_is_single_solve(prob):
    rs = RobustSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                      maxiter=100, precision="f64")
    out = rs.solve(jnp.asarray(prob.b))
    assert out.status == "ok"
    assert out.attempts == ()
    assert rs.describe_last() == "(no recovery needed)"
    assert rs.n_recoveries == 0
    assert int(out.result.health.status) == health.HEALTHY
    assert rs.hierarchy_ok()
    # the healthy path reuses the cached traces: a second solve does not
    # rebuild anything
    out2 = rs.solve(2.0 * jnp.asarray(prob.b))
    assert out2.status == "ok"
    assert rs._solve._cache_size() == 1


def test_rung_order_and_policy_gating(prob):
    rs = RobustSolver(prob.A, prob.B, coarse_size=30, precision="f64")
    names = [n for n, _, _ in rs._rungs()]
    # full-fp64 setup: no f64-rebuild rung, ladder capped at max_attempts
    assert names == ["recompute", "re-setup", "reference-path"]
    rs.recovery = RecoveryPolicy(max_attempts=1)
    assert [n for n, _, _ in rs._rungs()] == ["recompute"]
    rs.recovery = RecoveryPolicy(allow_recompute=False, max_attempts=4)
    assert [n for n, _, _ in rs._rungs()] == ["re-setup", "reference-path"]


def test_f64_rebuild_rung_offered_for_reduced_precision(prob):
    rs = RobustSolver(prob.A, prob.B, coarse_size=30, precision="f32",
                      recovery=RecoveryPolicy(max_attempts=4))
    names = [n for n, _, _ in rs._rungs()]
    assert "f64-rebuild" in names
    assert names.index("f64-rebuild") < names.index("reference-path")


def test_ladder_solve_one_shot(prob):
    out = ladder_solve(prob.A, prob.B, jnp.asarray(prob.b),
                       coarse_size=30, rtol=1e-8, maxiter=100,
                       precision="f64")
    assert out.status == "ok"
    assert float(out.result.relres) <= 1e-8
    assert np.isfinite(np.asarray(out.x)).all()


def test_env_scope_restores(monkeypatch):
    from repro.robust.recover import _env_scope
    import os
    monkeypatch.setenv("REPRO_SPGEMM_PATH", "pairs")
    monkeypatch.delenv("REPRO_SPMM_PATH", raising=False)
    with _env_scope({"REPRO_SPGEMM_PATH": "reference",
                     "REPRO_SPMM_PATH": "reference"}):
        assert os.environ["REPRO_SPGEMM_PATH"] == "reference"
        assert os.environ["REPRO_SPMM_PATH"] == "reference"
    assert os.environ["REPRO_SPGEMM_PATH"] == "pairs"
    assert "REPRO_SPMM_PATH" not in os.environ


def test_status_of_severity_order():
    t, f = jnp.asarray(True), jnp.asarray(False)
    assert int(health.status_of(t, f, f, f)) == health.HEALTHY
    assert int(health.status_of(f, f, f, f)) == health.MAXITER
    assert int(health.status_of(f, f, f, t)) == health.STAGNATION
    assert int(health.status_of(f, t, f, t)) == health.BREAKDOWN
    assert int(health.status_of(f, t, t, t)) == health.NONFINITE
    # elementwise for the panel case
    codes = health.status_of(jnp.asarray([True, False]),
                             jnp.asarray([False, True]),
                             jnp.asarray([False, False]),
                             jnp.asarray([False, False]))
    assert np.array_equal(np.asarray(codes), [0, 3])


def test_describe_and_hierarchy_finite(solver, prob):
    res = solver.solve(jnp.asarray(prob.b))
    line = health.describe(res.health)
    assert "healthy" in line and "best_relres" in line
    assert bool(np.asarray(health.hierarchy_finite(solver.hierarchy)))
