"""PrecisionPolicy: resolution, storage dtypes, and mixed-precision solves.

The load-bearing claims (ISSUE acceptance):

* with ``hierarchy_dtype=float32`` the elasticity PCG still reaches
  rtol 1e-8 at <= 1.3x the fp64 iteration count (fp64 outer Krylov on the
  fp64 fine operator, fp32 V-cycle behind the boundary cast);
* fp32- and fp64-preconditioned PCG converge to the *same* solution at
  rtol, with iteration counts within a fixed bound of each other
  (Demidov, arXiv:2202.09056) — swept deterministically here, and as a
  hypothesis property in ``tests/test_property.py``;
* the stored hierarchy really is at the policy dtype end to end, and the
  solve server can host an fp32-resident hierarchy serving fp64 requests.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax.numpy as jnp

from repro.core import gamg
from repro.core.krylov import pcg
from repro.core.precision import PrecisionPolicy
from repro.core.spmv import apply_ell, spmv_ell
from repro.core.vcycle import fine_operator, pbjacobi_apply
from repro.fem.assemble import assemble_elasticity
from repro.kernels import backend
from repro.multirhs import AMGSolveServer

from helpers import spd_bcsr

RNG = np.random.default_rng(3)


@pytest.fixture(scope="module")
def prob():
    return assemble_elasticity(5)


@pytest.fixture(scope="module")
def solver64(prob):
    return gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                           maxiter=100, precision="f64")


@pytest.fixture(scope="module")
def solver32(prob):
    return gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                           maxiter=100, precision="f32")


# ---------------------------------------------------------------------------
# Policy resolution
# ---------------------------------------------------------------------------

def test_policy_names_and_defaults():
    d = PrecisionPolicy.double()
    assert d == PrecisionPolicy.from_name("f64")
    assert not d.mixed
    assert d.kernel_accum_dtype is None
    f32 = PrecisionPolicy.from_name("f32")
    assert f32.hierarchy_dtype == np.dtype(np.float32)
    assert f32.smoother_dtype == np.dtype(np.float32)
    assert f32.krylov_dtype == np.dtype(np.float64)
    assert f32.mixed and f32.factor_dtype == np.dtype(np.float32)
    assert f32.kernel_accum_dtype is None    # fp32 accumulates natively
    bf = PrecisionPolicy.from_name("bf16")
    assert bf.hierarchy_dtype.itemsize == 2
    assert bf.factor_dtype == np.dtype(np.float32)   # LAPACK floor
    assert bf.kernel_accum_dtype == np.dtype(np.float32)
    assert bf.coarse_jitter_scale() > d.coarse_jitter_scale()


def test_resolve_precision_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_PRECISION", raising=False)
    assert backend.resolve_precision(None) == PrecisionPolicy.double()
    monkeypatch.setenv("REPRO_PRECISION", "f32")
    assert backend.resolve_precision(None) == \
        PrecisionPolicy.from_name("f32")
    # explicit knob beats the env, policy objects pass through
    assert backend.resolve_precision("f64") == PrecisionPolicy.double()
    p = PrecisionPolicy.from_name("bf16")
    assert backend.resolve_precision(p) is p


def test_invalid_precision_raises_value_error(monkeypatch):
    with pytest.raises(ValueError):
        PrecisionPolicy.from_name("f16-ish")
    monkeypatch.setenv("REPRO_PRECISION", "nope")
    with pytest.raises(ValueError):
        backend.resolve_precision(None)


# ---------------------------------------------------------------------------
# Hierarchy storage dtypes
# ---------------------------------------------------------------------------

def test_f32_hierarchy_stored_at_policy_dtype(solver32):
    h = solver32.hierarchy
    for lv in h.levels:
        assert lv.a_ell.data.dtype == jnp.float32
        assert lv.p_ell.data.dtype == jnp.float32
        # transpose-free default: no stored restriction duplicate — the
        # plan reuses p_ell's (already f32) payload
        assert lv.r_ell is None and lv.p_t is not None
        assert lv.dinv.dtype == jnp.float32
    assert h.coarse_chol.dtype == jnp.float32
    # mixed policy: krylov-dtype copy of the finest operator only
    assert h.a_fine_ell is not None
    assert h.a_fine_ell.data.dtype == jnp.float64
    assert fine_operator(h) is h.a_fine_ell


def test_f64_hierarchy_has_no_duplicate_fine_operator(solver64):
    h = solver64.hierarchy
    assert h.a_fine_ell is None
    assert fine_operator(h) is h.levels[0].a_ell
    assert h.levels[0].a_ell.data.dtype == jnp.float64


# ---------------------------------------------------------------------------
# Acceptance: f32 hierarchy reaches rtol 1e-8 within 1.3x fp64 iterations
# ---------------------------------------------------------------------------

def test_f32_hierarchy_converges_like_f64(prob, solver64, solver32):
    r64 = solver64.solve(prob.b)
    r32 = solver32.solve(prob.b)
    assert bool(r64.converged) and bool(r32.converged)
    assert float(r32.relres) <= 1e-8
    assert int(r32.iters) <= int(np.ceil(1.3 * int(r64.iters)))
    # same fp64 operator in the outer loop -> same solution to solver tol
    np.testing.assert_allclose(np.asarray(r32.x), np.asarray(r64.x),
                               rtol=1e-6, atol=1e-10)
    # the fp64 outer residual is a *true* residual of the fp64 operator
    r = prob.b - spmv_ell(fine_operator(solver32.hierarchy), r32.x)
    assert float(jnp.linalg.norm(r) / jnp.linalg.norm(prob.b)) < 1e-7


def test_f32_hot_recompute_stays_mixed(prob, solver64, solver32):
    """State-gated recompute under the mixed policy: both hierarchy copies
    refresh, dtypes hold, and A -> 2A halves the solution."""
    x_ref = solver64.solve(prob.b).x
    solver32.update_operator(prob.A.data * 2.0)
    res = solver32.solve(prob.b)
    assert bool(res.converged)
    assert solver32.hierarchy.levels[0].a_ell.data.dtype == jnp.float32
    assert solver32.hierarchy.a_fine_ell.data.dtype == jnp.float64
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_ref) / 2.0,
                               rtol=1e-5, atol=1e-10)
    solver32.update_operator(prob.A.data)        # restore for other tests


# ---------------------------------------------------------------------------
# Property (deterministic sweep): fp32- vs fp64-preconditioned PCG
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_f32_vs_f64_preconditioned_pcg_same_solution(seed):
    """pbjacobi-preconditioned CG on random SPD blocked operators: casting
    the preconditioner to fp32 (via the ``precond_dtype`` boundary) must
    reach the same solution at rtol with iterations within a fixed bound.
    The hypothesis twin lives in tests/test_property.py."""
    rng = np.random.default_rng(seed)
    A = spd_bcsr(rng, 8, 3)
    ell = A.to_ell()
    dinv64 = jnp.linalg.inv(A.diagonal_blocks())
    dinv32 = dinv64.astype(jnp.float32)
    b = jnp.asarray(rng.standard_normal(A.shape[0]))

    def apply_a(v):
        return apply_ell(ell, v)

    r64 = pcg(apply_a, lambda r: pbjacobi_apply(dinv64, r), b,
              rtol=1e-10, maxiter=200)
    r32 = pcg(apply_a, lambda r: pbjacobi_apply(dinv32, r), b,
              rtol=1e-10, maxiter=200, precond_dtype=jnp.float32)
    assert bool(r64.converged) and bool(r32.converged)
    assert abs(int(r32.iters) - int(r64.iters)) <= \
        max(3, int(np.ceil(0.3 * int(r64.iters))))
    np.testing.assert_allclose(np.asarray(r32.x), np.asarray(r64.x),
                               rtol=1e-6, atol=1e-8)


# ---------------------------------------------------------------------------
# Krylov breakdown floor: b = 0 must never NaN, at any krylov dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32, jnp.bfloat16],
                         ids=["f64", "f32", "bf16"])
def test_pcg_zero_rhs_converges_immediately(dtype):
    """The relres denominator floor is ``finfo(b.dtype).tiny``: the old
    1e-300 literal underflows to 0 below f64, turning b = 0 into a 0/0
    NaN relres.  An all-zero rhs reports converged, iters 0, relres 0 —
    one case per stock policy's candidate krylov dtype."""
    rng = np.random.default_rng(7)
    A = spd_bcsr(rng, 6, 3)
    ell = A.to_ell().astype(dtype)
    b = jnp.zeros(A.shape[0], dtype)
    res = pcg(lambda v: apply_ell(ell, v), lambda r: r, b, rtol=1e-8)
    assert bool(res.converged) and int(res.iters) == 0
    assert float(res.relres) == 0.0          # not NaN
    assert not np.any(np.isnan(np.asarray(res.x, np.float64)))


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32, jnp.bfloat16],
                         ids=["f64", "f32", "bf16"])
def test_block_pcg_zero_columns_stay_finite(dtype):
    """Panel twin: all-zero columns (the solve server's padding) are
    inactive from iteration 0 with relres 0, while live columns in the
    same panel still converge — at every candidate krylov dtype."""
    from repro.multirhs.block_krylov import block_pcg
    rng = np.random.default_rng(8)
    A = spd_bcsr(rng, 6, 3)
    ell = A.to_ell().astype(dtype)
    n = A.shape[0]
    B = jnp.stack([jnp.zeros(n, dtype),
                   jnp.asarray(rng.standard_normal(n), dtype)], axis=1)
    rtol = 1e-8 if dtype == jnp.float64 else 1e-2
    res = block_pcg(lambda v: apply_ell(ell, v), lambda r: r, B, rtol=rtol,
                    maxiter=200)
    relres = np.asarray(res.relres, np.float64)
    assert not np.any(np.isnan(relres)), relres
    assert int(res.iters[0]) == 0 and relres[0] == 0.0
    assert bool(res.converged[0])
    assert int(res.iters[1]) > 0


# ---------------------------------------------------------------------------
# Mixed-precision panels + the solve server
# ---------------------------------------------------------------------------

def test_f32_solve_many_converges_per_column(prob, solver32):
    cols = [np.asarray(prob.b)] + [RNG.standard_normal(prob.n)
                                   for _ in range(2)]
    B = jnp.asarray(np.stack(cols, axis=1))
    res = solver32.solve_many(B)
    assert res.x.dtype == jnp.float64           # krylov-dtype panel out
    assert bool(np.asarray(res.converged).all())
    for j in range(B.shape[1]):
        single = solver32.solve(B[:, j])
        assert abs(int(res.iters[j]) - int(single.iters)) <= 2
        np.testing.assert_allclose(np.asarray(res.x[:, j]),
                                   np.asarray(single.x), rtol=1e-6,
                                   atol=1e-8)


def test_server_hosts_f32_hierarchy_serving_f64_requests(prob, solver64):
    setupd = gamg.setup(prob.A, prob.B, coarse_size=30, precision="f32")
    srv = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2, 4),
                         rtol=1e-8, maxiter=100)
    assert srv.dtype == np.dtype(np.float64)    # panels at krylov dtype
    assert srv.hierarchy.levels[0].a_ell.data.dtype == jnp.float32
    rhs = [np.asarray(prob.b), RNG.standard_normal(prob.n)]
    reports = srv.serve(rhs)
    assert all(r.converged for r in reports)
    for rep, b in zip(reports, rhs):
        ref = solver64.solve(jnp.asarray(b))    # dedicated fp64 solve
        np.testing.assert_allclose(rep.x, np.asarray(ref.x), rtol=1e-6,
                                   atol=1e-8)
        assert rep.iters <= int(np.ceil(1.3 * int(ref.iters)))
