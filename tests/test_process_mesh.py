"""Host-only unit tests of the mesh/partition layer (no devices).

``repro.dist.partition`` is pure numpy staging, so these run in-process;
the validation contract is ``ValueError`` — never ``assert`` — so every
check here must still fire under ``python -O``.
"""
import numpy as np
import pytest

from repro.dist.partition import (
    ProcessMesh,
    as_mesh,
    partition_padded,
    partition_rows,
)


def test_partition_rows_balance_and_lookup():
    part = partition_rows(10, 3)
    assert part.ndev == 3 and part.nrows == 10
    assert list(part.counts) == [4, 3, 3]        # max - min <= 1
    assert part.max_count == 4
    assert list(part.owner_of([0, 3, 4, 9])) == [0, 0, 1, 2]
    assert list(part.local_of([0, 3, 4, 9])) == [0, 3, 0, 2]
    assert part.slab(1) == slice(4, 7)


def test_partition_rows_validation():
    with pytest.raises(ValueError, match="at least one rank"):
        partition_rows(10, 0)
    with pytest.raises(ValueError, match="at least one rank"):
        partition_rows(10, -2)
    with pytest.raises(ValueError, match="negative row count"):
        partition_rows(-1, 2)
    assert partition_rows(0, 2).nrows == 0       # empty is fine


def test_partition_padded_divisibility():
    assert list(partition_padded(8, 2).counts) == [4, 4]
    with pytest.raises(ValueError, match="does not divide"):
        partition_padded(9, 2)
    with pytest.raises(ValueError, match="at least one rank"):
        partition_padded(8, 0)


def test_process_mesh_shapes():
    m1 = ProcessMesh((4,))
    assert (m1.pr, m1.pc, m1.ndev) == (4, 1, 4)
    m2 = ProcessMesh((2, 32))
    assert (m2.pr, m2.pc, m2.ndev) == (2, 32, 64)
    # numpy ints coerce; the stored shape is plain ints
    m3 = ProcessMesh((np.int64(3), np.int64(2)))
    assert m3.shape == (3, 2)


def test_process_mesh_validation():
    with pytest.raises(ValueError, match="must be positive"):
        ProcessMesh((0,))
    with pytest.raises(ValueError, match="must be positive"):
        ProcessMesh((2, 0))
    with pytest.raises(ValueError, match=r"\(ndev,\) or \(pr, pc\)"):
        ProcessMesh((2, 2, 2))
    with pytest.raises(ValueError, match="tuple of ints"):
        ProcessMesh(3)          # an int is not a shape


def test_process_mesh_row_partition():
    mesh = ProcessMesh((2, 4))
    part = mesh.row_partition(5)
    assert part.ndev == 2 and part.nrows == 5    # rows follow pr only
    with pytest.raises(ValueError, match="larger than the block-row"):
        ProcessMesh((8, 1)).row_partition(5)
    # an empty operator partitions trivially on any mesh
    assert ProcessMesh((8, 1)).row_partition(0).nrows == 0


def test_as_mesh_coercion():
    assert as_mesh(3).shape == (3,)
    assert as_mesh(np.int32(2)).shape == (2,)
    mesh = ProcessMesh((2, 2))
    assert as_mesh(mesh) is mesh
    with pytest.raises(ValueError, match="int rank count or a ProcessMesh"):
        as_mesh("4")
    with pytest.raises(ValueError, match="int rank count or a ProcessMesh"):
        as_mesh((2, 2))          # a bare tuple must be wrapped explicitly


def test_build_dist_gamg_rejects_oversized_mesh():
    """The front door routes through row_partition's validation."""
    from repro.core import gamg
    from repro.dist.solver import build_dist_gamg
    from repro.fem.assemble import assemble_elasticity

    prob = assemble_elasticity(4)
    setupd = gamg.setup(prob.A, prob.B, coarse_size=12, precision="f64")
    nbr = setupd.levels[0].A0.nbr
    with pytest.raises(ValueError, match="larger than the block-row"):
        build_dist_gamg(setupd, ProcessMesh((nbr + 1, 1)))
    with pytest.raises(ValueError, match="int rank count or a ProcessMesh"):
        build_dist_gamg(setupd, 2.0)
