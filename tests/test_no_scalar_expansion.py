"""The paper's first invariant: no scalar AIJ expansion on the coarsening
path.  Enforced two ways: (a) the coarsening modules never import the
scalar-expansion module; (b) a full GAMG setup + hot recompute runs with
the expansion function instrumented to fail."""
import sys

import pytest

import repro.core  # noqa: F401
from repro.fem.assemble import assemble_elasticity


COARSENING_MODULES = [
    "repro.core.strength", "repro.core.aggregation", "repro.core.tentative",
    "repro.core.smooth", "repro.core.gamg", "repro.core.ptap",
    "repro.core.spgemm", "repro.core.block_coo", "repro.core.vcycle",
    "repro.core.krylov", "repro.dist.pamg", "repro.dist.solver",
]


def test_no_import_of_scalar_module():
    import importlib
    for name in COARSENING_MODULES:
        mod = importlib.import_module(name)
        src = open(mod.__file__).read()
        assert "scalar_csr" not in src.replace(
            "scalar_csr is quarantined", ""), \
            f"{name} references the scalar expansion module"


def test_setup_and_recompute_never_expand(monkeypatch):
    from repro.core import scalar_csr

    def boom(*a, **k):
        raise AssertionError("scalar expansion reached from blocked path")

    monkeypatch.setattr(scalar_csr, "expand_bcsr", boom)
    from repro.core import gamg
    prob = assemble_elasticity(5)
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-8,
                             maxiter=50)
    solver.update_operator(prob.A.data * 1.5)     # hot recompute
    res = solver.solve(prob.b)
    assert bool(res.converged)
