"""The paper's own config module builds and solves end to end."""
import dataclasses

import repro.core  # noqa: F401
from repro.configs.elasticity import CPU_LADDER, PAPER_LADDER, CONFIG


def test_paper_ladder_is_weak_scaling():
    # 98 304 unknowns per device on every rung (paper Sec. 4.1)
    for m, ndev in PAPER_LADDER:
        assert 3 * m ** 3 // ndev == 98304


def test_config_builds_and_solves():
    cfg = dataclasses.replace(CONFIG, m=CPU_LADDER[0], coarse_size=30,
                              maxiter=100)
    prob, solver = cfg.build()
    res = solver.solve(prob.b)
    assert bool(res.converged)
    # reuse model: hierarchy survives an operator refresh
    solver.update_operator(prob.A.data * 1.3)
    assert bool(solver.solve(prob.b).converged)
