"""Observability layer (ISSUE 7), tier-1 contracts.

What this module pins:

* ``REPRO_OBS=off`` is FREE: span wrappers and the counter plumbing leave
  **zero jaxpr residue** (the off-mode trace is byte-identical before and
  after an obs scope), and spans mode is **bitwise** the off-mode solve
  (named scopes are metadata only);
* zero retraces: a spans-mode ``GAMGSolver``'s jitted closures keep their
  cache at 1 across repeated solves;
* counter correctness: on a pinned 2-level problem the ``CycleTally``
  matches the analytic expectations of AMG-preconditioned CG exactly
  (one V-cycle per operator application, two smoother sweeps per visited
  level, one coarse solve per cycle), and the modeled bytes equal
  cycles x the exact traffic model;
* ``block_pcg`` ``record_history=`` parity: per-column residual traces,
  NaN-padded past each column's final iteration;
* ``MetricsRegistry`` bucket math, quantile estimates, compile/steady
  phase split, and the JSONL / Prometheus exporters (round-tripped
  through ``parse_prometheus``);
* ``AMGSolveServer`` end-to-end metrics: queue wait / latency / solve
  wall histograms, padding efficiency, per-bucket and per-status counts.
"""
import json
import math

import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax
import jax.numpy as jnp

from repro.core import gamg
from repro.fem.assemble import assemble_elasticity
from repro.kernels.backend import resolve_obs
from repro.multirhs import AMGSolveServer
from repro.multirhs.block_krylov import make_block_solve
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry, parse_prometheus
from repro.obs.model import vcycle_traffic

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def prob():
    return assemble_elasticity(4)


@pytest.fixture(scope="module")
def setupd(prob):
    # coarse_size=40 pins a 2-level hierarchy: one smoothed level + the
    # direct coarse grid — the analytic counter expectations below assume
    # exactly this shape.
    sd = gamg.setup(prob.A, prob.B, coarse_size=40, precision="f64")
    assert sd.n_levels == 2
    return sd


@pytest.fixture(scope="module")
def hier(setupd, prob):
    return gamg.make_recompute(setupd)(prob.A.data)


# ---------------------------------------------------------------------------
# Knob resolution
# ---------------------------------------------------------------------------

def test_resolve_obs_knob(monkeypatch):
    for raw, want in (("off", "off"), ("0", "off"), ("", "off"),
                      ("none", "off"), ("spans", "spans"), ("1", "spans"),
                      ("ON", "spans"), ("counters", "counters"),
                      ("Counters", "counters")):
        assert resolve_obs(raw) == want
    with pytest.raises(ValueError, match="invalid observability mode"):
        resolve_obs("verbose")
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert resolve_obs() == "off"
    monkeypatch.setenv("REPRO_OBS", "counters")
    assert resolve_obs() == "counters"
    assert obs_trace.resolve() == "counters"


def test_use_scope_overrides_env(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert obs_trace.resolve() == "off"
    with obs_trace.use("counters"):
        assert obs_trace.resolve() == "counters"
        assert obs_trace.counters_enabled()
        assert obs_trace.spans_enabled()
        # explicit arg still wins over the scope
        assert obs_trace.resolve("spans") == "spans"
    assert obs_trace.resolve() == "off"
    with pytest.raises(ValueError):
        obs_trace.use("loud").__enter__()


# ---------------------------------------------------------------------------
# Off-mode contract: zero jaxpr residue, bitwise parity, zero retraces
# ---------------------------------------------------------------------------

def test_off_mode_zero_jaxpr_residue(setupd, hier, prob):
    """The ISSUE-7 acceptance pin.  Fresh closures per trace (jax caches
    traces on the function object, which would mask — or fake — residue
    differences): the off-mode jaxpr is identical before and after a
    counters scope, and a counters-mode closure genuinely changes the
    trace (the tally carry exists)."""
    b = jnp.asarray(prob.b)

    def mk(obs=None):
        solve = gamg.make_solve(setupd, rtol=1e-8, maxiter=50, obs=obs)

        def f(b):
            return solve(hier, b).x
        return f

    before = str(jax.make_jaxpr(mk())(b))
    with obs_trace.use("counters"):
        during = str(jax.make_jaxpr(mk())(b))
    after = str(jax.make_jaxpr(mk())(b))
    assert before == after, "an exited obs scope must leave zero residue"
    assert before != during, "counters mode must thread the tally carry"


def test_spans_mode_bitwise_matches_off(setupd, hier, prob):
    """Named scopes are metadata: the spans-mode solve is bitwise the
    off-mode solve — same solution, same iteration count, same relres."""
    b = jnp.asarray(prob.b)
    res_off = gamg.make_solve(setupd, rtol=1e-8, maxiter=100,
                              obs="off")(hier, b)
    res_spans = gamg.make_solve(setupd, rtol=1e-8, maxiter=100,
                                obs="spans")(hier, b)
    assert bool(res_off.converged) and bool(res_spans.converged)
    np.testing.assert_array_equal(np.asarray(res_off.x),
                                  np.asarray(res_spans.x))
    assert int(res_off.iters) == int(res_spans.iters)
    np.testing.assert_array_equal(np.asarray(res_off.relres),
                                  np.asarray(res_spans.relres))
    assert res_off.counters is None and res_spans.counters is None


def test_counters_mode_matches_off_solution(setupd, hier, prob):
    """The tally rides the carry but never feeds back into the recurrence:
    counted iterates are bitwise the uncounted ones."""
    b = jnp.asarray(prob.b)
    res_off = gamg.make_solve(setupd, rtol=1e-8, maxiter=100)(hier, b)
    res_cnt = gamg.make_solve(setupd, rtol=1e-8, maxiter=100,
                              obs="counters")(hier, b)
    np.testing.assert_array_equal(np.asarray(res_off.x),
                                  np.asarray(res_cnt.x))
    assert int(res_off.iters) == int(res_cnt.iters)


def test_spans_solver_cache_stays_at_one(prob):
    """Zero retraces across repeated solves under span wrappers."""
    with obs_trace.use("spans"):
        solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=40,
                                 rtol=1e-8, maxiter=100, precision="f64")
        b = jnp.asarray(prob.b)
        r1 = solver.solve(b)
        r2 = solver.solve(2.0 * b)
    assert bool(r1.converged) and bool(r2.converged)
    assert solver._solve._cache_size() == 1
    assert solver._recompute._cache_size() == 1


# ---------------------------------------------------------------------------
# Counter correctness on the pinned 2-level problem
# ---------------------------------------------------------------------------

def _expected_tally(setupd, iters):
    """Analytic expectations for AMG-PCG on a 2-level hierarchy.

    CG applies the preconditioner once at init plus once per iteration:
    ``iters + 1`` V-cycles.  Every V-cycle visits the one smoothed level
    on the way down (pre-smooth) and again on the way up (post-smooth)
    and does one direct coarse solve.  The operator count matches the
    preconditioner count (one fine SpMV at init, one per iteration)."""
    cycles = iters + 1
    return {"precond": cycles, "op": cycles, "coarse": cycles,
            "level_visits": [cycles], "smoother": [2 * cycles]}


def test_cycle_tally_matches_analytic_counts(setupd, hier, prob):
    b = jnp.asarray(prob.b)
    res = gamg.make_solve(setupd, rtol=1e-8, maxiter=100,
                          obs="counters")(hier, b)
    assert bool(res.converged)
    tl = res.counters
    assert tl is not None
    want = _expected_tally(setupd, int(res.iters))
    assert int(tl.precond_applies) == want["precond"]
    assert int(tl.operator_applies) == want["op"]
    assert int(tl.coarse_solves) == want["coarse"]
    assert np.asarray(tl.level_visits).tolist() == want["level_visits"]
    assert np.asarray(tl.smoother_applies).tolist() == want["smoother"]
    # modeled bytes = cycles x the exact per-cycle traffic model
    itemsize = jnp.dtype(setupd.precision.hierarchy_dtype).itemsize
    cycle_bytes = vcycle_traffic(setupd, itemsize=itemsize)["total"]
    assert float(tl.modeled_bytes) == pytest.approx(
        want["precond"] * cycle_bytes)
    line = obs_trace.describe_tally(tl)
    assert f"precond={want['precond']}" in line and "modeled_MB=" in line


def test_block_tally_matches_single_rhs(setupd, hier, prob):
    """The panel solve counts cycles exactly like the single-RHS path
    (one V-cycle serves the whole panel)."""
    b = jnp.asarray(prob.b)
    B = jnp.stack([b, 2.0 * b, -0.5 * b], axis=1)
    solve = make_block_solve(setupd, rtol=1e-8, maxiter=100,
                             obs="counters")
    res = solve(hier, B)
    assert np.asarray(res.converged).all()
    tl = res.counters
    cycles = int(np.asarray(res.iters).max()) + 1
    assert int(tl.precond_applies) == cycles
    assert int(tl.coarse_solves) == cycles
    assert np.asarray(tl.smoother_applies).tolist() == [2 * cycles]


# ---------------------------------------------------------------------------
# block_pcg record_history parity
# ---------------------------------------------------------------------------

def test_block_record_history_nan_padding(setupd, hier, prob):
    b = jnp.asarray(prob.b)
    B = jnp.stack([b, 3.0 * b], axis=1)
    solve = make_block_solve(setupd, rtol=1e-8, maxiter=60,
                             record_history=True)
    res, hist = solve(hier, B)
    hist = np.asarray(hist)
    assert hist.shape == (60, 2)
    iters = np.asarray(res.iters)
    for j in range(2):
        k = int(iters[j])
        assert np.isfinite(hist[:k, j]).all(), "live steps must be finite"
        assert np.isnan(hist[k:, j]).all(), \
            "frozen/finished steps must be NaN-padded"
        assert hist[:k, j].min() > 0.0
        # the trace is the residual-norm recurrence: its last live entry
        # is the norm the reported relres was computed from
        bnorm = float(jnp.linalg.norm(B[:, j]))
        assert hist[k - 1, j] / bnorm == pytest.approx(
            float(np.asarray(res.relres)[j]))


def test_block_record_history_does_not_perturb_solution(setupd, hier, prob):
    b = jnp.asarray(prob.b)
    B = jnp.stack([b, 3.0 * b], axis=1)
    plain = make_block_solve(setupd, rtol=1e-8, maxiter=60)(hier, B)
    rec, _ = make_block_solve(setupd, rtol=1e-8, maxiter=60,
                              record_history=True)(hier, B)
    np.testing.assert_array_equal(np.asarray(plain.x), np.asarray(rec.x))
    np.testing.assert_array_equal(np.asarray(plain.iters),
                                  np.asarray(rec.iters))


# ---------------------------------------------------------------------------
# MetricsRegistry: instruments, bucket math, exporters
# ---------------------------------------------------------------------------

def test_histogram_bucket_math():
    reg = MetricsRegistry()
    h = reg.histogram("t", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(106.0)
    assert snap["min"] == 0.5 and snap["max"] == 100.0
    # upper-bound (le) semantics: 1.0 lands in the <=1 bucket, 100 in +Inf
    assert snap["buckets"] == {1.0: 2, 2.0: 1, 4.0: 1, math.inf: 1}
    # quantiles: linear-in-bucket estimate, clamped to observed max
    assert h.quantile(0.0) == pytest.approx(0.0)
    assert 0.0 < h.quantile(0.5) <= 2.0
    assert h.quantile(1.0) == pytest.approx(100.0)
    assert math.isnan(reg.histogram("empty").quantile(0.5))
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError, match="duplicate"):
        reg.histogram("dup", buckets=(1.0, 1.0))


def test_counter_gauge_contracts():
    reg = MetricsRegistry()
    c = reg.counter("req")
    c.inc()
    c.inc(2.5, labels={"k": "4"})
    assert c.value() == 1.0
    assert c.value({"k": "4"}) == 2.5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(3)
    g.set(1)
    assert g.value() == 1.0
    # one name, one kind
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("req")
    # re-request returns the same instrument
    assert reg.counter("req") is c


def test_measure_splits_compile_from_steady():
    reg = MetricsRegistry()

    @jax.jit
    def f(x):
        return x * 2.0

    x = jnp.ones(8)
    for _ in range(3):
        reg.measure("phase", f, x)
    compile_h = reg.get("phase/compile")
    steady_h = reg.get("phase/steady")
    assert compile_h.snapshot()["count"] == 1
    assert steady_h.snapshot()["count"] == 2


def test_timer_blocks_and_records():
    reg = MetricsRegistry()
    with reg.timer("span") as t:
        out = t.block(jnp.arange(4) + 1)
    assert t.seconds is not None and t.seconds >= 0.0
    assert reg.get("span").snapshot()["count"] == 1
    assert int(out.sum()) == 10
    # a raising span must not record a bogus duration
    with pytest.raises(RuntimeError):
        with reg.timer("span"):
            raise RuntimeError("boom")
    assert reg.get("span").snapshot()["count"] == 1


def test_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("server/requests_total", help="accepted").inc(7)
    reg.gauge("server/padding_efficiency").set(0.8125)
    h = reg.histogram("server/solve_wall_seconds", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.to_prometheus()
    assert "# TYPE server_requests_total counter" in text
    assert "# HELP server_requests_total accepted" in text
    parsed = parse_prometheus(text)
    assert parsed["server_requests_total"][""] == 7
    assert parsed["server_padding_efficiency"][""] == 0.8125
    buckets = parsed["server_solve_wall_seconds_bucket"]
    # cumulative le convention survives the round trip
    assert buckets['{le="0.01"}'] == 1
    assert buckets['{le="0.1"}'] == 2
    assert buckets['{le="1"}'] == 3
    assert buckets['{le="+Inf"}'] == 4
    assert parsed["server_solve_wall_seconds_count"][""] == 4
    assert parsed["server_solve_wall_seconds_sum"][""] == pytest.approx(
        5.555)


def test_jsonl_export_parses():
    reg = MetricsRegistry()
    reg.counter("a").inc(3)
    reg.histogram("b", buckets=(1.0,)).observe(0.5)
    lines = reg.to_jsonl(timestamp=123.0).splitlines()
    docs = [json.loads(ln) for ln in lines]
    assert {d["name"] for d in docs} == {"a", "b"}
    assert all(d["ts"] == 123.0 for d in docs)
    hdoc = next(d for d in docs if d["name"] == "b")
    assert hdoc["count"] == 1 and hdoc["buckets"]["1.0"] == 1


def test_rank0_span_records_when_enabled():
    reg = MetricsRegistry()
    with obs_trace.use("spans"):
        with obs_trace.rank0_span("dist/solve", registry=reg) as stop:
            out = stop(jnp.ones(4).sum())
    assert int(out) == 4
    assert reg.get("dist/solve/seconds").snapshot()["count"] == 1
    # off mode: same code path, nothing recorded
    reg2 = MetricsRegistry()
    with obs_trace.rank0_span("dist/solve", registry=reg2) as stop:
        stop(jnp.ones(4).sum())
    assert reg2.get("dist/solve/seconds") is None


def test_default_registry_reset():
    obs_metrics.reset_default_registry()
    reg = obs_metrics.default_registry()
    assert obs_metrics.default_registry() is reg
    obs_metrics.reset_default_registry()
    assert obs_metrics.default_registry() is not reg


# ---------------------------------------------------------------------------
# Server end-to-end metrics
# ---------------------------------------------------------------------------

def test_server_metrics_end_to_end(setupd, prob):
    server = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2, 4),
                            record_history=True)
    b = np.asarray(prob.b)
    for i in range(5):
        server.submit((1.0 + 0.25 * i) * b)
    assert server.metrics().pending.value() == 5.0
    reports = server.flush()
    assert len(reports) == 5
    assert all(r.status == "ok" and r.converged for r in reports)

    snap = server.snapshot()
    assert snap["requests"] == 5
    assert snap["batches"] == 2            # chunks of 4 + 1
    assert snap["pending"] == 0
    assert snap["status"] == {"ok": 5, "degraded": 0, "failed": 0,
                              "recovered": 0}
    assert snap["solves_per_k"] == {1: 1, 2: 0, 4: 1}
    assert snap["padded_columns"] == 0
    assert snap["padding_efficiency"] == pytest.approx(1.0)
    assert snap["latency_p50_s"] > 0.0
    assert snap["latency_p99_s"] >= snap["latency_p50_s"]

    for r in reports:
        # end-to-end latency owns the whole submit->report window, so it
        # bounds the queue wait from above
        assert r.latency_s >= r.queue_wait_s > 0.0
        # recorded history: finite through the final iteration, NaN after
        assert r.history is not None and r.history.shape == (200,)
        assert np.isfinite(r.history[:r.iters]).all()
        assert np.isnan(r.history[r.iters:]).all()

    text = server.metrics().to_prometheus()
    assert "server_request_latency_seconds_count 5" in text
    assert "server_solve_wall_seconds_count 2" in text
    assert "server_queue_wait_seconds_count 5" in text
    parsed = parse_prometheus(text)
    assert parsed["server_requests_total"][""] == 5
    assert parsed["server_batches_total"][""] == 2


def test_server_padding_efficiency_and_rejects(setupd, prob):
    server = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2, 4))
    b = np.asarray(prob.b)
    for i in range(3):
        server.submit((1.0 + i) * b)
    server.flush()                         # one k=4 panel, 1 padded column
    snap = server.snapshot()
    assert snap["padded_columns"] == 1
    assert snap["padding_efficiency"] == pytest.approx(3 / 4)
    with pytest.raises(ValueError):
        server.submit(np.full(server.n, np.nan))
    with pytest.raises(ValueError):
        server.submit(b[:-2])
    assert server.snapshot()["rejected"] == 2
    # stats mirror (legacy dict) agrees with the metrics surface
    assert server.stats["rejected"] == 2
    assert server.stats["padded_columns"] == 1


def test_server_history_off_by_default(setupd, prob, monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    server = AMGSolveServer(setupd, prob.A.data, buckets=(1, 2))
    server.submit(np.asarray(prob.b))
    (report,) = server.flush()
    assert report.history is None
    assert report.status == "ok"
    assert report.latency_s >= report.queue_wait_s > 0.0
