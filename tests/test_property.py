"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core  # noqa: F401,E402
import jax.numpy as jnp  # noqa: E402

from repro.core.block_coo import preallocate_coo, set_values_coo  # noqa
from repro.core.block_csr import transpose_bcsr  # noqa: E402
from repro.core.spgemm import block_axpy, spgemm  # noqa: E402
from repro.core.spmv import spmv  # noqa: E402
from repro.core.aggregation import (  # noqa: E402
    graph_to_ell,
    luby_mis_device,
)
from repro.core.strength import StrengthGraph  # noqa: E402
from repro.core.krylov import pcg  # noqa: E402
from repro.core.vcycle import pbjacobi_apply  # noqa: E402
from repro.dist.partition import partition_rows  # noqa: E402
from repro.multirhs.block_krylov import block_pcg  # noqa: E402

from helpers import random_bcsr, spd_bcsr  # noqa: E402


@st.composite
def bcsr_strategy(draw, max_n=6, square=False):
    seed = draw(st.integers(0, 2**31 - 1))
    nbr = draw(st.integers(1, max_n))
    nbc = nbr if square else draw(st.integers(1, max_n))
    br = draw(st.sampled_from([1, 2, 3, 6]))
    bc = br if square else draw(st.sampled_from([1, 2, 3, 6]))
    dens = draw(st.floats(0.1, 0.9))
    return random_bcsr(np.random.default_rng(seed), nbr, nbc, br, bc, dens)


@given(bcsr_strategy())
@settings(max_examples=25, deadline=None)
def test_spmv_linearity(A):
    """SpMV is linear: A(ax + by) == a*Ax + b*Ay."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(A.shape[1]))
    y = jnp.asarray(rng.standard_normal(A.shape[1]))
    lhs = spmv(A, 2.5 * x - 1.5 * y)
    rhs = 2.5 * spmv(A, x) - 1.5 * spmv(A, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-10, atol=1e-10)


@given(bcsr_strategy())
@settings(max_examples=25, deadline=None)
def test_transpose_involution_and_adjoint(A):
    """(A^T)^T == A and <Ax, y> == <x, A^T y>."""
    T2 = transpose_bcsr(transpose_bcsr(A))
    np.testing.assert_allclose(np.asarray(T2.to_dense()),
                               np.asarray(A.to_dense()), rtol=1e-13)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(A.shape[1]))
    y = jnp.asarray(rng.standard_normal(A.shape[0]))
    lhs = float(jnp.vdot(spmv(A, x), y))
    rhs = float(jnp.vdot(x, spmv(transpose_bcsr(A), y)))
    assert abs(lhs - rhs) < 1e-9 * (1 + abs(lhs))


@given(st.integers(0, 2**31 - 1), st.integers(1, 5), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_spgemm_associativity_with_dense(seed, n1, n2):
    rng = np.random.default_rng(seed)
    A = random_bcsr(rng, n1, n2, 3, 3)
    B = random_bcsr(rng, n2, n1, 3, 6)
    C = spgemm(A, B)
    np.testing.assert_allclose(
        np.asarray(C.to_dense()),
        np.asarray(A.to_dense()) @ np.asarray(B.to_dense()),
        rtol=1e-10, atol=1e-10)


@given(st.integers(0, 2**31 - 1), st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_coo_assembly_permutation_invariant(seed, n_contrib):
    """COO assembly must not depend on contribution order."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 4, n_contrib)
    cols = rng.integers(0, 4, n_contrib)
    vals = rng.standard_normal((n_contrib, 3, 3))
    perm = rng.permutation(n_contrib)
    p1 = preallocate_coo(rows, cols, 4, 4, 3, 3)
    p2 = preallocate_coo(rows[perm], cols[perm], 4, 4, 3, 3)
    A1 = set_values_coo(p1, jnp.asarray(vals))
    A2 = set_values_coo(p2, jnp.asarray(vals[perm]))
    np.testing.assert_allclose(np.asarray(A1.to_dense()),
                               np.asarray(A2.to_dense()), rtol=1e-12)


@given(bcsr_strategy(square=True))
@settings(max_examples=20, deadline=None)
def test_block_axpy_commutes_with_dense(A):
    rng = np.random.default_rng(2)
    B = random_bcsr(rng, A.nbr, A.nbc, A.br, A.bc, 0.3)
    C = block_axpy(0.7, A, B)
    np.testing.assert_allclose(
        np.asarray(C.to_dense()),
        0.7 * np.asarray(A.to_dense()) + np.asarray(B.to_dense()),
        rtol=1e-12, atol=1e-12)


@given(st.integers(0, 2**31 - 1), st.integers(2, 40),
       st.floats(0.05, 0.5))
@settings(max_examples=20, deadline=None)
def test_luby_mis_independent_and_maximal(seed, n, dens):
    """Device MIS: no two adjacent members; every non-member has one."""
    rng = np.random.default_rng(seed)
    mask = np.triu(rng.random((n, n)) < dens, 1)
    adj = mask | mask.T
    rows, cols = np.nonzero(adj)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    g = StrengthGraph(indptr=np.cumsum(indptr),
                      indices=cols.astype(np.int32),
                      weights=np.ones(len(cols)), n=n)
    idx, m = graph_to_ell(g)
    in_mis = np.asarray(luby_mis_device(idx, m)).astype(bool)
    assert not (adj & np.outer(in_mis, in_mis)).any(), "not independent"
    uncovered = ~in_mis & ~(adj @ in_mis.astype(int) > 0)
    assert not uncovered.any(), "not maximal"


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_batched_solve_matches_looped_singles(seed, k):
    """Masked panel PCG == k looped single-RHS PCG solves to fp tolerance
    (pbjacobi-preconditioned CG on a random SPD blocked operator)."""
    from repro.core.spmv import apply_ell
    rng = np.random.default_rng(seed)
    A = spd_bcsr(rng, 6, 3)
    ell = A.to_ell()
    dinv = jnp.linalg.inv(A.diagonal_blocks())

    def apply_a(v):
        return apply_ell(ell, v)

    def apply_m(r):
        return pbjacobi_apply(dinv, r)

    B = jnp.asarray(rng.standard_normal((A.shape[0], k)))
    res = block_pcg(apply_a, apply_m, B, rtol=1e-10, maxiter=100)
    assert bool(np.asarray(res.converged).all())
    for j in range(k):
        single = pcg(apply_a, apply_m, B[:, j], rtol=1e-10, maxiter=100)
        assert bool(single.converged)
        np.testing.assert_allclose(np.asarray(res.x[:, j]),
                                   np.asarray(single.x), rtol=1e-6,
                                   atol=1e-8)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_f32_and_f64_preconditioned_pcg_agree(seed):
    """ISSUE satellite: fp32-preconditioned PCG and fp64-preconditioned
    PCG (same operator, fp64 outer loop) converge to the same solution at
    rtol, with iteration counts within a fixed bound of each other."""
    from repro.core.spmv import apply_ell
    rng = np.random.default_rng(seed)
    A = spd_bcsr(rng, 7, 3)
    ell = A.to_ell()
    dinv = jnp.linalg.inv(A.diagonal_blocks())
    b = jnp.asarray(rng.standard_normal(A.shape[0]))

    def apply_a(v):
        return apply_ell(ell, v)

    r64 = pcg(apply_a, lambda r: pbjacobi_apply(dinv, r), b,
              rtol=1e-10, maxiter=200)
    dinv32 = dinv.astype(jnp.float32)
    r32 = pcg(apply_a, lambda r: pbjacobi_apply(dinv32, r), b,
              rtol=1e-10, maxiter=200, precond_dtype=jnp.float32)
    assert bool(r64.converged) and bool(r32.converged)
    bound = max(3, int(np.ceil(0.3 * int(r64.iters))))
    assert abs(int(r32.iters) - int(r64.iters)) <= bound, \
        (int(r32.iters), int(r64.iters))
    np.testing.assert_allclose(np.asarray(r32.x), np.asarray(r64.x),
                               rtol=1e-6, atol=1e-8)


_COEFF_PROBLEM = []


def _coeff_problem():
    """Build the m=3 device-assembled elasticity problem once per session
    (hypothesis re-runs the test body per example)."""
    if not _COEFF_PROBLEM:
        from repro.fem.assemble import assemble_elasticity
        _COEFF_PROBLEM.append(assemble_elasticity(3))
    return _COEFF_PROBLEM[0]


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_random_material_fields_spd_and_consistent(seed):
    """ISSUE 5 satellite: any positive per-element E/nu fields yield a
    symmetric positive definite reduced operator through the device
    assembly path, and the constant-field coefficient update agrees with
    the legacy scalar ``reassemble`` (its special case)."""
    prob = _coeff_problem()
    ne = prob.mesh.n_elements
    rng = np.random.default_rng(seed)
    E = rng.uniform(0.2, 8.0, ne)
    nu = rng.uniform(0.05, 0.45, ne)
    D = np.asarray(prob.coefficient_operator(E, nu).to_dense())
    np.testing.assert_allclose(D, D.T, atol=1e-11)
    w = np.linalg.eigvalsh(0.5 * (D + D.T))
    assert w.min() > 0, f"not SPD: min eig {w.min()}"

    scale = float(rng.uniform(0.5, 4.0))
    A_c = prob.coefficient_operator(np.full(ne, scale), np.full(ne, 0.3))
    A_r = prob.reassemble(scale)
    np.testing.assert_allclose(np.asarray(A_c.data), np.asarray(A_r.data),
                               rtol=1e-12, atol=1e-13)


@given(st.integers(1, 1000), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_partition_covers_and_balances(nbr, ndev):
    p = partition_rows(nbr, ndev)
    counts = p.counts
    assert counts.sum() == nbr
    assert counts.max() - counts.min() <= 1, "imbalance > 1 row"
    rows = np.arange(nbr)
    own = p.owner_of(rows)
    assert ((rows >= p.starts[own]) & (rows < p.starts[own + 1])).all()
