"""Warm-start (``x0``) contracts of the Krylov layer (ISSUE 10).

What this module pins, at f32 and f64:

* ``x0=zeros`` is **bitwise** ``x0=None`` — the warm-start plumbing adds
  nothing to the cold path (same initial residual, same recurrence);
* an exact-solution seed reports ``iters=0, converged=True`` — the
  pre-loop residual check is the same monitor the loop uses;
* ``x0`` with an all-zero right-hand side keeps the dtype-aware
  breakdown-floor contract: nothing divides by zero, nothing goes
  NaN/Inf, and the health flags stay meaningful;
* warm-starting from the previous solution on a slowly ramping
  coefficient field converges in strictly fewer iterations than a cold
  start — at the raw ``pcg``/``block_pcg`` level and end-to-end through
  ``GAMGSolver.solve(b, x0=...)`` on the device AMG path.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on)
import jax.numpy as jnp

from repro.core import gamg
from repro.core.krylov import pcg
from repro.fem.assemble import assemble_elasticity
from repro.multirhs.block_krylov import block_pcg
from repro.robust import health

RNG = np.random.default_rng(42)

DTYPES = [np.float32, np.float64]
RTOLS = {np.float32: 1e-4, np.float64: 1e-9}


def _spd(n, dtype=np.float64, cond=1e2):
    Q, _ = np.linalg.qr(RNG.standard_normal((n, n)))
    eigs = np.logspace(0, np.log10(cond), n)
    return ((Q * eigs) @ Q.T).astype(dtype)


def _ops(A):
    dinv = 1.0 / jnp.diag(A)
    return (lambda v: A @ v), (lambda r: dinv * r)


@pytest.mark.parametrize("dtype", DTYPES)
def test_x0_zeros_bitwise_matches_none(dtype):
    """The cold path is untouched: seeding with explicit zeros is the
    same program state as not seeding at all."""
    A = jnp.asarray(_spd(40, dtype))
    b = jnp.asarray(RNG.standard_normal(40).astype(dtype))
    apply_a, apply_m = _ops(A)
    rtol = RTOLS[dtype]
    res_none = pcg(apply_a, apply_m, b, rtol=rtol, maxiter=100)
    res_zero = pcg(apply_a, apply_m, b, x0=jnp.zeros_like(b), rtol=rtol,
                   maxiter=100)
    assert int(res_none.iters) == int(res_zero.iters)
    np.testing.assert_array_equal(np.asarray(res_none.x),
                                  np.asarray(res_zero.x))
    np.testing.assert_array_equal(np.asarray(res_none.relres),
                                  np.asarray(res_zero.relres))


@pytest.mark.parametrize("dtype", DTYPES)
def test_x0_exact_solution_zero_iters(dtype):
    """An exact seed converges before the first iteration."""
    A = jnp.asarray(_spd(40, dtype))
    x_star = jnp.asarray(RNG.standard_normal(40).astype(dtype))
    b = A @ x_star
    apply_a, apply_m = _ops(A)
    res = pcg(apply_a, apply_m, b, x0=x_star, rtol=RTOLS[dtype],
              maxiter=100)
    assert bool(res.converged)
    assert int(res.iters) == 0
    assert int(res.health.status) == health.HEALTHY
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(x_star))


@pytest.mark.parametrize("dtype", DTYPES)
def test_x0_zero_rhs_keeps_breakdown_floor(dtype):
    """``b = 0``: the dtype-aware ``finfo.tiny`` floor keeps relres out
    of 0/0 territory whatever the seed.  A zero seed is the exact
    solution (iters=0, relres=0); a nonzero seed iterates toward zero
    with every monitored quantity finite and a sane status."""
    A = jnp.asarray(_spd(40, dtype))
    b = jnp.zeros((40,), dtype)
    apply_a, apply_m = _ops(A)
    rtol = RTOLS[dtype]

    res0 = pcg(apply_a, apply_m, b, x0=jnp.zeros_like(b), rtol=rtol,
               maxiter=50)
    assert bool(res0.converged) and int(res0.iters) == 0
    assert float(res0.relres) == 0.0

    x0 = jnp.asarray(RNG.standard_normal(40).astype(dtype))
    res = pcg(apply_a, apply_m, b, x0=x0, rtol=rtol, maxiter=50)
    assert bool(jnp.isfinite(res.x).all())
    assert bool(jnp.isfinite(res.relres))
    assert int(res.health.status) in (health.HEALTHY, health.MAXITER,
                                      health.STAGNATION)
    assert not bool(res.health.nonfinite)


@pytest.mark.parametrize("dtype", DTYPES)
def test_warm_start_fewer_iters_on_ramp(dtype):
    """A slow coefficient ramp: re-solving the perturbed operator seeded
    with the unperturbed solution takes strictly fewer iterations than a
    cold start — CG only sees the initial residual."""
    n = 60
    A = _spd(n, np.float64, cond=1e3)
    d = 1.0 + 0.02 * RNG.random(n)            # heterogeneous 2% ramp
    A2 = (np.sqrt(d)[:, None] * A * np.sqrt(d)[None, :]).astype(dtype)
    A1 = A.astype(dtype)
    b = jnp.asarray(RNG.standard_normal(n).astype(dtype))
    rtol = RTOLS[dtype]

    a1, m1 = _ops(jnp.asarray(A1))
    res1 = pcg(a1, m1, b, rtol=rtol, maxiter=500)
    assert bool(res1.converged)

    a2, m2 = _ops(jnp.asarray(A2))
    cold = pcg(a2, m2, b, rtol=rtol, maxiter=500)
    warm = pcg(a2, m2, b, x0=res1.x, rtol=rtol, maxiter=500)
    assert bool(cold.converged) and bool(warm.converged)
    assert int(warm.iters) < int(cold.iters), \
        (int(warm.iters), int(cold.iters))


@pytest.mark.parametrize("dtype", DTYPES)
def test_block_pcg_x0_contracts(dtype):
    """The panel twin: per-column zero-seed bitwise parity with the cold
    start, and an exact seed panel converging at zero iterations in
    every column."""
    A = jnp.asarray(_spd(40, dtype))
    X_star = jnp.asarray(RNG.standard_normal((40, 3)).astype(dtype))
    B = A @ X_star
    dinv = 1.0 / jnp.diag(A)
    apply_a = lambda V: A @ V                    # noqa: E731
    apply_m = lambda R: dinv[:, None] * R        # noqa: E731
    rtol = RTOLS[dtype]

    res_none = block_pcg(apply_a, apply_m, B, rtol=rtol, maxiter=100)
    res_zero = block_pcg(apply_a, apply_m, B, x0=jnp.zeros_like(B),
                         rtol=rtol, maxiter=100)
    np.testing.assert_array_equal(np.asarray(res_none.x),
                                  np.asarray(res_zero.x))
    np.testing.assert_array_equal(np.asarray(res_none.iters),
                                  np.asarray(res_zero.iters))

    res_x = block_pcg(apply_a, apply_m, B, x0=X_star, rtol=rtol,
                      maxiter=100)
    assert bool(np.asarray(res_x.converged).all())
    assert (np.asarray(res_x.iters) == 0).all(), res_x.iters
    assert (np.asarray(res_x.health.status) == health.HEALTHY).all()


@pytest.fixture(scope="module")
def prob():
    return assemble_elasticity(4)


def test_gamg_solver_warm_start_end_to_end(prob):
    """``GAMGSolver.solve(b, x0=...)`` through the device AMG path: an
    exact seed is a zero-iteration solve, and on a small heterogeneous
    coefficient ramp the warm re-solve beats the cold one."""
    solver = gamg.GAMGSolver(prob.A, prob.B, coarse_size=30, rtol=1e-9,
                             maxiter=200, precision="f64")
    res = solver.solve(prob.b)
    assert bool(res.converged)

    res_seeded = solver.solve(prob.b, x0=res.x)
    assert bool(res_seeded.converged)
    assert int(res_seeded.iters) == 0

    # slow ramp: +5% stiffness on a random half of the elements
    solver.bind_assembler(prob.assembler)
    ne = prob.mesh.n_elements
    bump = 1.0 + 0.05 * (np.arange(ne) % 2)
    E = np.ones(ne) * bump
    nu = np.full(ne, 0.3)
    solver.update_coefficients(jnp.asarray(E), jnp.asarray(nu))
    cold = solver.solve(prob.b)
    warm = solver.solve(prob.b, x0=res.x)
    assert bool(cold.converged) and bool(warm.converged)
    assert int(warm.iters) < int(cold.iters), \
        (int(warm.iters), int(cold.iters))

    # the panel front door threads x0 the same way
    B = jnp.stack([prob.b, 0.5 * prob.b], axis=1)
    res_p = solver.solve_many(B)
    res_pw = solver.solve_many(B, x0=res_p.x)
    assert (np.asarray(res_pw.iters) == 0).all(), res_pw.iters
