"""Transpose-free restriction: apply P^T straight off the prolongator.

Covers ISSUE 8's restriction tentpole: ``apply_ell_t`` parity with the
stored ``r_ell`` apply across the elasticity block-shape mixes, the
default setup dropping the stored restriction duplicate from the
hierarchy, stored-vs-free solve parity, the traffic/storage model
reporting reduced bytes, and the dist switch staging the transpose-free
boundary restriction.
"""
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
import jax.numpy as jnp

from helpers import random_bcsr
from repro.core import gamg
from repro.core.block_csr import transpose_apply_plan, transpose_bcsr
from repro.core.spmv import apply_ell, apply_ell_t
from repro.fem.assemble import assemble_elasticity
from repro.obs.model import hierarchy_storage_bytes, vcycle_traffic

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("br,bc", [(3, 3), (3, 6), (6, 6)])
def test_apply_ell_t_matches_stored_restriction(br, bc):
    """P^T x off P's own ELL payload must equal the stored-R apply
    *bitwise*: the plan's slot order per output row is exactly
    ``transpose_structure``'s, so the summation order is identical."""
    P = random_bcsr(RNG, 20, 9, br, bc, density=0.35)
    ell = P.to_ell()
    pt = transpose_apply_plan(P, ell.kmax)
    r_ell = transpose_bcsr(P).to_ell()
    x = jnp.asarray(RNG.standard_normal(P.nbr * br))
    np.testing.assert_array_equal(np.asarray(apply_ell_t(ell, pt, x)),
                                  np.asarray(apply_ell(r_ell, x)))
    X = jnp.asarray(RNG.standard_normal((P.nbr * br, 3)))
    np.testing.assert_array_equal(np.asarray(apply_ell_t(ell, pt, X)),
                                  np.asarray(apply_ell(r_ell, X)))


def test_default_setup_drops_stored_restriction():
    """The transpose-free default stores no R/r_ell anywhere in the
    hierarchy — the prolongator-side transfer memory is P + plan only."""
    prob = assemble_elasticity(4)
    sd = gamg.setup(prob.A, prob.B, coarse_size=30)
    assert sd.levels, "need a non-trivial hierarchy"
    for ls in sd.levels:
        assert ls.R is None and ls.r_ell is None and ls.pt is not None
    h = gamg.recompute(sd, prob.A.data)
    for lv in h.levels:
        assert lv.r_ell is None and lv.p_t is not None

    sd_st = gamg.setup(prob.A, prob.B, coarse_size=30,
                       restriction="stored")
    for ls in sd_st.levels:
        assert ls.R is not None and ls.r_ell is not None and ls.pt is None

    with pytest.raises(ValueError):
        gamg.setup(prob.A, prob.B, coarse_size=30, restriction="bogus")


def test_stored_and_transpose_free_solve_parity():
    """Same aggregates, same P values, same summation order -> the two
    restriction modes produce bitwise-identical V-cycles and solves."""
    from repro.core.vcycle import vcycle
    prob = assemble_elasticity(5)
    sd_tf = gamg.setup(prob.A, prob.B, coarse_size=30)
    sd_st = gamg.setup(prob.A, prob.B, coarse_size=30,
                       restriction="stored")
    h_tf = gamg.recompute(sd_tf, prob.A.data)
    h_st = gamg.recompute(sd_st, prob.A.data)
    r = jnp.asarray(RNG.standard_normal(prob.b.shape))
    np.testing.assert_array_equal(np.asarray(vcycle(h_tf, r)),
                                  np.asarray(vcycle(h_st, r)))
    s_tf = gamg.make_solve(sd_tf)(h_tf, prob.b)
    s_st = gamg.make_solve(sd_st)(h_st, prob.b)
    assert int(s_tf.iters) == int(s_st.iters)
    np.testing.assert_array_equal(np.asarray(s_tf.x), np.asarray(s_st.x))


def test_traffic_and_storage_models_report_reduced_bytes():
    """The byte models must see the dropped r_ell: per-cycle modeled
    traffic shrinks (restriction stops charging a second value+index
    stream) and the transfer-operator storage roughly halves."""
    prob = assemble_elasticity(4)
    sd_tf = gamg.setup(prob.A, prob.B, coarse_size=30)
    sd_st = gamg.setup(prob.A, prob.B, coarse_size=30,
                       restriction="stored")
    t_tf = vcycle_traffic(sd_tf)
    t_st = vcycle_traffic(sd_st)
    assert t_tf["total"] < t_st["total"]
    assert t_tf["value"] < t_st["value"]
    # the scalar baseline always stores an expanded R: same charge either way
    assert vcycle_traffic(sd_tf, scalar=True) == \
        vcycle_traffic(sd_st, scalar=True)
    s_tf = hierarchy_storage_bytes(sd_tf)
    s_st = hierarchy_storage_bytes(sd_st)
    assert s_tf["operator"] == s_st["operator"]
    assert s_tf["coarse"] == s_st["coarse"]
    assert s_tf["transfer"] < 0.6 * s_st["transfer"], (s_tf, s_st)
    assert s_tf["total"] < s_st["total"]


def test_dist_switch_stages_transpose_free_boundary():
    """Agglomerated staging keeps the transpose-free form across the
    switch: no stored global r_ell, the boundary restriction rides P's
    payload + the plan.  (Iteration parity itself runs in the dist
    selftest, which now executes under this default.)"""
    from repro.dist.solver import build_dist_gamg
    prob = assemble_elasticity(5)
    sd = gamg.setup(prob.A, prob.B, coarse_size=12)
    dg = build_dist_gamg(sd, 2, coarse_eq_limit=1 << 30)
    assert dg.switch is not None
    assert dg.switch.r_ell is None
    assert dg.switch.p_g is not None and dg.switch.p_t is not None

    sd_st = gamg.setup(prob.A, prob.B, coarse_size=12,
                       restriction="stored")
    dg_st = build_dist_gamg(sd_st, 2, coarse_eq_limit=1 << 30)
    assert dg_st.switch.r_ell is not None
    assert dg_st.switch.p_g is None and dg_st.switch.p_t is None
