"""Fault tolerance: checkpoint integrity, restart determinism, corrupt
checkpoint skip, straggler detection, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticTokens
from repro.train.fault import StragglerMonitor, run_with_restarts
from repro.train.optimizer import (
    AdamWConfig,
    compress_grads,
    decompress_grads,
    init_error_feedback,
    init_opt_state,
)
from repro.train.steps import make_train_step

CFG = get_config("qwen2-0.5b").reduced()
OPT = AdamWConfig(lr=1e-3)


def _driver(tmp_path, fail_at=(), total=10, save_every=3):
    data = SyntheticTokens(DataConfig(vocab_size=CFG.vocab_size,
                                      global_batch=2, seq_len=17))
    step_jit = jax.jit(make_train_step(CFG, OPT, cdt=jnp.float32))

    def init_state():
        params = T.init_lm(CFG, jax.random.key(0))
        return {"params": params, "opt": init_opt_state(params),
                "loss": jnp.zeros(())}

    def step_fn(state, step):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        p, o, m = step_jit(state["params"], state["opt"], batch)
        return {"params": p, "opt": o, "loss": m["loss"]}

    ckpt = CheckpointManager(str(tmp_path), keep=2)
    return run_with_restarts(init_state, step_fn,
                             lambda s: float(s["loss"]), ckpt, total,
                             save_every=save_every, fail_at=fail_at), ckpt


def test_restart_reproduces_uninterrupted_run(tmp_path):
    clean, _ = _driver(tmp_path / "clean")
    faulty, ckpt = _driver(tmp_path / "faulty", fail_at=(4, 8))
    assert faulty.restarts == 2
    assert faulty.resumed_from == [3, 6]
    np.testing.assert_allclose(clean.losses, faulty.losses, rtol=1e-6)
    assert ckpt.available_steps()[-1] == 10


def test_corrupt_checkpoint_skipped(tmp_path):
    _, ckpt = _driver(tmp_path)
    steps = ckpt.available_steps()
    # corrupt the newest payload: restore must fall back to the previous one
    newest = steps[-1]
    npz_path, _ = ckpt._paths(newest)
    with open(npz_path, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef" * 8)
    params = T.init_lm(CFG, jax.random.key(0))
    template = {"params": params, "opt": init_opt_state(params),
                "loss": jnp.zeros(())}
    restored = ckpt.restore_latest(template)
    assert restored is not None
    assert restored[0] == steps[-2], "must skip the corrupt newest ckpt"


def test_straggler_detection_and_reassignment():
    mon = StragglerMonitor(n_hosts=8)
    rng = np.random.default_rng(0)
    for _ in range(10):
        assert mon.observe(rng.normal(1.0, 0.02, 8)) == []
    slow = rng.normal(1.0, 0.02, 8)
    slow[3] = 5.0
    flagged = mon.observe(slow)
    assert flagged == [3]
    plan = mon.mitigate(flagged, 8)
    assert plan == {3: 4}
    assert mon.reassignments == [3]


def test_gradient_compression_error_feedback():
    params = T.init_lm(CFG, jax.random.key(1))
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(np.random.default_rng(0)
                              .standard_normal(p.shape), jnp.float32),
        params)
    err = init_error_feedback(params)
    q, err2 = compress_grads(grads, err)
    deq = decompress_grads(q)
    # per-leaf quantization error bounded by scale/2 per element
    for g, d in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(deq)):
        assert g.shape == d.shape
        rel = float(jnp.linalg.norm(g - d) / (jnp.linalg.norm(g) + 1e-9))
        assert rel < 0.02, rel
    # error feedback carries the residual: g = deq + err2 exactly
    for g, d, e in zip(jax.tree_util.tree_leaves(grads),
                       jax.tree_util.tree_leaves(deq),
                       jax.tree_util.tree_leaves(err2)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(d + e),
                                   rtol=1e-5, atol=1e-6)
    # wire bytes shrink ~4x
    raw = sum(g.size * 4 for g in jax.tree_util.tree_leaves(grads))
    wire = sum(l["q"].size + l["scale"].size * 4
               for l in jax.tree_util.tree_leaves(
                   q, is_leaf=lambda x: isinstance(x, dict) and "q" in x))
    assert wire < raw / 3.5


def test_data_pipeline_restart_determinism():
    d1 = SyntheticTokens(DataConfig(vocab_size=100, global_batch=4,
                                    seq_len=9))
    a = d1.batch_at(7)
    b = d1.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host sharding: disjoint deterministic shards
    h0 = SyntheticTokens(DataConfig(vocab_size=100, global_batch=4,
                                    seq_len=9, host_id=0, num_hosts=2))
    h1 = SyntheticTokens(DataConfig(vocab_size=100, global_batch=4,
                                    seq_len=9, host_id=1, num_hosts=2))
    assert h0.local_batch == 2
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])
