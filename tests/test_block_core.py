"""Unit tests for the blocked sparse containers and two-phase products."""
import numpy as np
import pytest

import repro.core  # noqa: F401
import jax.numpy as jnp

from repro.core.block_coo import (
    preallocate_coo,
    scalar_coo_plan_bytes,
    set_values_coo,
)
from repro.core.block_csr import BlockCSR, identity_bcsr, transpose_bcsr
from repro.core.scalar_csr import (
    bcsr_matrix_bytes,
    csr_matrix_bytes,
    expand_bcsr,
)
from repro.core.spgemm import (
    block_axpy,
    spgemm,
    spgemm_numeric,
    spgemm_symbolic,
)
from repro.core.spmv import spmv, spmv_bcsr_ref, spmv_ell
from repro.core.ptap import ptap, ptap_numeric, ptap_symbolic

from helpers import random_bcsr


RNG = np.random.default_rng(0)


@pytest.mark.parametrize("br,bc", [(1, 1), (3, 3), (3, 6), (6, 3), (2, 5)])
def test_to_dense_roundtrip(br, bc):
    A = random_bcsr(RNG, 7, 5, br, bc)
    D = np.asarray(A.to_dense())
    assert D.shape == (7 * br, 5 * bc)
    # every stored block appears at the right slab
    rows = np.repeat(np.arange(A.nbr), np.diff(A.indptr))
    for k in range(A.nnzb):
        I, J = rows[k], A.indices[k]
        np.testing.assert_allclose(D[I*br:(I+1)*br, J*bc:(J+1)*bc],
                                   np.asarray(A.data[k]))


@pytest.mark.parametrize("br,bc", [(3, 3), (3, 6), (1, 1), (4, 2)])
def test_spmv_matches_dense(br, bc):
    A = random_bcsr(RNG, 9, 6, br, bc)
    x = RNG.standard_normal(6 * bc)
    y = np.asarray(spmv(A, jnp.asarray(x)))
    np.testing.assert_allclose(y, np.asarray(A.to_dense()) @ x, rtol=1e-12)


def test_spmv_ell_equals_bcsr_ref():
    A = random_bcsr(RNG, 12, 12, 3, 3, density=0.2)
    x = jnp.asarray(RNG.standard_normal(36))
    np.testing.assert_allclose(np.asarray(spmv_ell(A.to_ell(), x)),
                               np.asarray(spmv_bcsr_ref(A, x)), rtol=1e-13)


@pytest.mark.parametrize("bk", [3, 6])
def test_spgemm_matches_dense(bk):
    A = random_bcsr(RNG, 8, 6, 3, bk)
    B = random_bcsr(RNG, 6, 5, bk, 6)
    C = spgemm(A, B)
    np.testing.assert_allclose(np.asarray(C.to_dense()),
                               np.asarray(A.to_dense()) @
                               np.asarray(B.to_dense()),
                               rtol=1e-12, atol=1e-12)


def test_spgemm_plan_reuse_new_values():
    A = random_bcsr(RNG, 8, 8, 3, 3, ensure_diag=True)
    B = random_bcsr(RNG, 8, 4, 3, 6)
    plan = spgemm_symbolic(A, B)
    A2 = A.with_data(A.data * 2.0)
    C2 = spgemm_numeric(plan, A2, B)
    np.testing.assert_allclose(np.asarray(C2.to_dense()),
                               2 * np.asarray(A.to_dense()) @
                               np.asarray(B.to_dense()), rtol=1e-12,
                               atol=1e-12)


def test_transpose():
    A = random_bcsr(RNG, 6, 9, 3, 6)
    np.testing.assert_allclose(np.asarray(transpose_bcsr(A).to_dense()),
                               np.asarray(A.to_dense()).T)


def test_ptap_matches_dense_and_state_gate():
    A = random_bcsr(RNG, 10, 10, 3, 3, ensure_diag=True)
    P = random_bcsr(RNG, 10, 4, 3, 6)
    Ac, cache = ptap(A, P)
    Ad, Pd = np.asarray(A.to_dense()), np.asarray(P.to_dense())
    np.testing.assert_allclose(np.asarray(Ac.to_dense()), Pd.T @ Ad @ Pd,
                               rtol=1e-11, atol=1e-11)
    # hot recompute: new A values, same structures -> gate holds, same cache
    A2 = A.with_data(A.data * -3.0)
    Ac2, cache2 = ptap(A2, P, cache)
    assert cache2 is cache, "state gate must reuse the cache"
    np.testing.assert_allclose(np.asarray(Ac2.to_dense()),
                               Pd.T @ (-3 * Ad) @ Pd, rtol=1e-11, atol=1e-11)
    # structural change (new P object) -> gate trips
    P2 = BlockCSR.from_arrays(P.indptr, P.indices, P.data, P.nbc)
    _, cache3 = ptap(A, P2, cache)
    assert cache3 is not cache


def test_block_coo_assembly_sums_duplicates_and_ignores_negative():
    br, bc = 3, 6
    rows = np.array([0, 1, 1, -1, 2, 0])
    cols = np.array([0, 1, 1, 2, 0, -3])
    vals = jnp.asarray(RNG.standard_normal((6, br, bc)))
    plan = preallocate_coo(rows, cols, nbr=3, nbc=3, br=br, bc=bc)
    A = set_values_coo(plan, vals)
    D = np.asarray(A.to_dense())
    expect = np.zeros((9, 18))
    for k, (i, j) in enumerate(zip(rows, cols)):
        if i >= 0 and j >= 0:
            expect[i*br:(i+1)*br, j*bc:(j+1)*bc] += np.asarray(vals[k])
    np.testing.assert_allclose(D, expect, rtol=1e-13)
    # numeric re-assembly with the cached plan (hot path)
    A2 = set_values_coo(plan, 2.0 * vals)
    np.testing.assert_allclose(np.asarray(A2.to_dense()), 2 * expect,
                               rtol=1e-13)
    assert plan.plan_bytes < scalar_coo_plan_bytes(plan)


def test_block_coo_rejects_out_of_range_coordinates():
    # ValueError, not assert: validation must survive ``python -O``
    with pytest.raises(ValueError, match="out of range"):
        preallocate_coo(np.array([0, 3]), np.array([0, 0]),
                        nbr=3, nbc=3, br=2, bc=2)
    with pytest.raises(ValueError, match="out of range"):
        preallocate_coo(np.array([0, 1]), np.array([0, 5]),
                        nbr=3, nbc=3, br=2, bc=2)
    with pytest.raises(ValueError, match="shape mismatch"):
        preallocate_coo(np.array([0, 1]), np.array([0]),
                        nbr=3, nbc=3, br=2, bc=2)
    # negatives stay the PETSc ignore convention, never an error
    plan = preallocate_coo(np.array([0, -1]), np.array([0, 2]),
                           nbr=3, nbc=3, br=2, bc=2)
    assert plan.nnzb == 1


def test_block_coo_rejects_wrong_shape_value_stream():
    rows = np.array([0, 1, 2])
    cols = np.array([0, 1, 0])
    plan = preallocate_coo(rows, cols, nbr=3, nbc=3, br=2, bc=3)
    with pytest.raises(ValueError, match="value stream shape"):
        set_values_coo(plan, jnp.zeros((2, 2, 3)))     # wrong n_input
    with pytest.raises(ValueError, match="value stream shape"):
        set_values_coo(plan, jnp.zeros((3, 3, 2)))     # transposed blocks


def test_scalar_expansion_matches_and_costs_more():
    A = random_bcsr(RNG, 6, 6, 3, 3, ensure_diag=True)
    S = expand_bcsr(A)
    assert S.block_shape == (1, 1)
    np.testing.assert_allclose(np.asarray(S.to_dense()),
                               np.asarray(A.to_dense()))
    # paper Sec. 4.2: 108 B vs 76 B per 3x3 block => exact per-nnz bytes
    nnz_scalar = A.nnzb * 9
    assert csr_matrix_bytes(S) - 8 * (S.nbr + 1) == nnz_scalar * 12
    assert bcsr_matrix_bytes(A) - 8 * (A.nbr + 1) == A.nnzb * 76


def test_block_axpy_union_pattern():
    X = random_bcsr(RNG, 6, 6, 3, 3, density=0.2)
    Y = random_bcsr(RNG, 6, 6, 3, 3, density=0.2)
    C = block_axpy(-0.5, X, Y)
    np.testing.assert_allclose(np.asarray(C.to_dense()),
                               -0.5 * np.asarray(X.to_dense())
                               + np.asarray(Y.to_dense()), rtol=1e-13)


def test_identity():
    Ib = identity_bcsr(5, 3)
    np.testing.assert_allclose(np.asarray(Ib.to_dense()), np.eye(15))


def test_spmv_rectangular_prolongator_shapes():
    # P: fine nodes x aggregates with 3x6 blocks; P^T x maps fine->coarse
    P = random_bcsr(RNG, 12, 3, 3, 6)
    x_c = jnp.asarray(RNG.standard_normal(18))
    y_f = spmv(P, x_c)
    assert y_f.shape == (36,)
    R = transpose_bcsr(P)
    x_f = jnp.asarray(RNG.standard_normal(36))
    y_c = spmv(R, x_f)
    assert y_c.shape == (18,)
    np.testing.assert_allclose(np.asarray(y_c),
                               np.asarray(P.to_dense()).T @ np.asarray(x_f),
                               rtol=1e-12)
